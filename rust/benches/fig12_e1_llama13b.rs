//! Regenerates Fig.12: the main comparison on environment e1 — all 7 methods
//! x {100, 200} Mbps x {sporadic, bursty}, reported in ms/token.

use lime::util::bench::Bench;
use lime::util::stats::geomean;

fn main() {
    let b = Bench::new("fig12_e1_llama13b");
    let cells = lime::experiments::main_comparison("e1", 48);
    let sp = lime::experiments::speedups(&cells);
    if !sp.is_empty() {
        let g = geomean(&sp.iter().map(|(_, s)| *s).collect::<Vec<_>>());
        b.section("LIME speedups over completing baselines");
        for (label, s) in &sp {
            b.row(label, &format!("{s:.2}x"));
        }
        b.row("geomean speedup", &format!("{g:.2}x"));
    }
    b.finish();
}
