//! Real-path (PJRT) hot-path bench: per-token decode latency of TinyLM
//! under resident vs offloaded residency, plus artifact compile time.
//! Requires `make artifacts`.

use lime::runtime::Manifest;
use lime::serve::{Engine, LayerResidency};
use lime::util::bench::Bench;
use lime::workload::synthetic_prompt;

fn main() {
    let dir = std::path::PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    if !dir.join("manifest.json").exists() {
        println!("runtime_hotpath: artifacts missing, run `make artifacts` first");
        return;
    }
    let mut b = Bench::new("runtime_hotpath");

    b.time("manifest_load", 1, 10, || {
        let _ = Manifest::load(&dir).unwrap();
    });

    let manifest = Manifest::load(&dir).unwrap();
    let cfg = manifest.model.clone();
    let mut engine = Engine::new(manifest).unwrap();
    let prompt = synthetic_prompt(1, cfg.prefill_len, cfg.vocab);

    b.time("generate_16tok_all_resident", 1, 5, || {
        let _ = engine.generate(&prompt, 16).unwrap();
    });

    let mut plan = vec![LayerResidency::Resident; cfg.layers];
    plan[2] = LayerResidency::FullOffload;
    plan[3] = LayerResidency::MhaOffload;
    engine.set_residency(&plan).unwrap();
    b.time("generate_16tok_2layers_offloaded", 1, 5, || {
        let _ = engine.generate(&prompt, 16).unwrap();
    });

    engine
        .set_residency(&vec![LayerResidency::FullOffload; cfg.layers])
        .unwrap();
    b.time("generate_16tok_all_offloaded", 1, 3, || {
        let _ = engine.generate(&prompt, 16).unwrap();
    });

    println!(
        "  pjrt execute() calls so far: {} | ssd weight re-reads: {}",
        engine.runtime.exec_calls(),
        engine.weights.loads_from_disk()
    );
    b.finish();
}
