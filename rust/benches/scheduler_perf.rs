//! L3 hot-path microbenchmarks: the offline scheduler (Alg. 1), the cost
//! model, the online planner, and the DES executors. These are the knobs
//! the §Perf pass tunes.
//!
//! The canonical `interleaved_sim_*` measurements run with
//! `TraceMode::Off` — the configuration the experiment grids use — and the
//! `_fulltrace`/`_aggtrace` variants quantify what span materialization /
//! online uncovered-load accounting cost on top.
//! `offline_plan_80L_5dev` runs the `#Seg` sweep on the persistent
//! work-stealing pool (`util::pool`); `offline_plan_80L_5dev_1thread` is
//! the sequential reference. The `experiments_grid_e1_2bw*` pair measures
//! full-grid sweep throughput — grid cells fan out on the pool and LIME
//! cells nest their `plan()` candidates back into it — against the same
//! grid evaluated sequentially. The `fleet_stream_100k*` pair does the
//! same for `serve::fleet`: a 10^5-request stream sharded one cluster per
//! pool job versus the sequential reference it is byte-identical to, and
//! the `fleet_stream_1M_des`/`fleet_stream_1M_scan` pair isolates the
//! admission router itself at 10^6 requests — the event-driven heap
//! router against the legacy O(C) scan it is decision-identical to. The
//! `serving_continuous_batching_*` pair compares the FIFO admission path
//! against the step-level continuous driver (paged-KV accounting on) over
//! one oversubscribed bursty stream; the `mixed_length_stream_*` pair
//! replays it with bimodal per-request lengths, pricing the ragged-slot
//! arithmetic of the workload-mix axis.
//!
//! Pin the worker count with `LIME_THREADS=<n>` for stable timings (CI
//! does). `Bench::finish` writes `BENCH_scheduler_perf.json` and prints
//! speedups against the previous run's file: run once on the baseline
//! commit, once after a change, and commit both (see README.md
//! §Benchmarks). CI additionally diffs the fresh JSON against the
//! committed `ci/BENCH_scheduler_perf.baseline.json` via `lime
//! bench-check`, failing loudly outside the tolerance band.

use lime::baselines::all;
use lime::cluster::Cluster;
use lime::experiments::{grid_cells, grid_cells_sequential};
use lime::model::ModelSpec;
use lime::net::BandwidthTrace;
use lime::pipeline::{run_interleaved, ExecOptions};
use lime::plan::{plan, plan_with_threads, PlanOptions};
use lime::sim::TraceMode;
use lime::util::bench::Bench;
use lime::util::bytes::mbps;

fn main() {
    let mut b = Bench::new("scheduler_perf");
    b.row(
        "pool workers (LIME_THREADS to pin)",
        &format!("{}", lime::util::pool::configured_workers()),
    );
    let spec = ModelSpec::llama33_70b();
    let cluster = Cluster::lowmem_setting1();
    let opts = PlanOptions {
        empirical_tokens: 256,
        micro_batch: 1,
        bandwidth: mbps(200.0),
    };

    b.time("offline_plan_80L_5dev (full #Seg sweep)", 2, 20, || {
        let _ = plan(&spec, &cluster, &opts).unwrap();
    });
    b.time("offline_plan_80L_5dev_1thread", 2, 20, || {
        let _ = plan_with_threads(&spec, &cluster, &opts, 1).unwrap();
    });

    let alloc = plan(&spec, &cluster, &opts).unwrap().allocation;
    b.time("cost_model_t_total", 10, 1000, || {
        let _ = lime::cost::t_total(&alloc, &cluster, 256, 1, mbps(200.0));
    });

    let bw = BandwidthTrace::fixed_mbps(200.0);
    let off = ExecOptions {
        trace_mode: TraceMode::Off,
        ..ExecOptions::default()
    };
    let agg = ExecOptions {
        trace_mode: TraceMode::Aggregate,
        ..ExecOptions::default()
    };
    let full = ExecOptions::default();
    b.time("interleaved_sim_64tok_sporadic", 1, 10, || {
        let _ = run_interleaved(&alloc, &cluster, &bw, 1, 64, &off);
    });
    b.time("interleaved_sim_64tok_bursty5", 1, 10, || {
        let _ = run_interleaved(&alloc, &cluster, &bw, 5, 64, &off);
    });
    b.time("interleaved_sim_64tok_sporadic_fulltrace", 1, 10, || {
        let _ = run_interleaved(&alloc, &cluster, &bw, 1, 64, &full);
    });
    b.time("interleaved_sim_64tok_bursty5_fulltrace", 1, 10, || {
        let _ = run_interleaved(&alloc, &cluster, &bw, 5, 64, &full);
    });
    // Aggregate mode now maintains the uncovered-load structures online —
    // T_uncover cross-checks at near-Off cost, no spans materialized.
    b.time("interleaved_sim_64tok_bursty5_aggtrace", 1, 10, || {
        let r = run_interleaved(&alloc, &cluster, &bw, 5, 64, &agg);
        let acc: f64 = r.trace.uncovered_loads().iter().sum();
        std::hint::black_box(acc);
    });

    // Trace query path: uncovered_load is a sort/sweep over the span lanes.
    let traced = run_interleaved(&alloc, &cluster, &bw, 5, 64, &full);
    b.row(
        "spans materialized (bursty5, 64 tok, Full)",
        &format!("{}", traced.trace.span_count()),
    );
    b.time("trace_uncovered_load_all_devices", 2, 50, || {
        let acc: f64 = traced.trace.uncovered_loads().iter().sum();
        std::hint::black_box(acc);
    });

    // Full-grid sweep throughput: 7 methods × 2 bandwidths × 2 patterns on
    // E1. Pool cells nest LIME's #Seg candidates back into the same pool;
    // the sequential variant is the single-thread reference the speedup is
    // measured against.
    let grid_spec = ModelSpec::llama2_13b();
    let grid_cluster = Cluster::env_e1();
    let methods = all();
    let bandwidths = [100.0, 200.0];
    let pool_s = b
        .time("experiments_grid_e1_2bw (pool, nested plan)", 1, 5, || {
            let cells = grid_cells(&grid_spec, &grid_cluster, &methods, &bandwidths, 4);
            std::hint::black_box(cells.len());
        })
        .mean;
    let seq_s = b
        .time("experiments_grid_e1_2bw_sequential", 1, 5, || {
            let cells =
                grid_cells_sequential(&grid_spec, &grid_cluster, &methods, &bandwidths, 4);
            std::hint::black_box(cells.len());
        })
        .mean;
    if pool_s > 0.0 {
        b.row(
            "grid sweep speedup (sequential / pool)",
            &format!("{:.2}x", seq_s / pool_s),
        );
    }

    // Scenario-matrix throughput: the same E1 point with every new axis
    // active — #Seg overrides sharing one SegSweepCtx per planning point,
    // and a scripted memory dip driving the online planner mid-run.
    let matrix = lime::experiments::ScenarioMatrix::new(
        "bench",
        grid_spec.clone(),
        grid_cluster.clone(),
        &methods,
        vec![100.0, 200.0],
        vec![
            lime::workload::Pattern::Sporadic,
            lime::workload::Pattern::Bursty,
        ],
        4,
    )
    .with_segs(vec![
        lime::experiments::SegChoice::Auto,
        lime::experiments::SegChoice::Fixed(4),
    ])
    .with_mem_scenarios(vec![
        lime::adapt::MemScenario::none(),
        lime::adapt::MemScenario::dip("dip-d0", 0, lime::util::bytes::gib(4.0), 1, 3),
    ]);
    b.time("scenario_matrix_e1_allaxes (pool)", 1, 5, || {
        std::hint::black_box(matrix.eval().len());
    });

    // Joint-pressure throughput: the same point with the full pressure
    // axis — a correlated multi-device dip and a joint bandwidth-sag +
    // squeeze script, the lime-sweep-v3 default shapes.
    let joint_matrix = lime::experiments::ScenarioMatrix::new(
        "bench-joint",
        grid_spec.clone(),
        grid_cluster.clone(),
        &methods,
        vec![100.0, 200.0],
        vec![
            lime::workload::Pattern::Sporadic,
            lime::workload::Pattern::Bursty,
        ],
        4,
    )
    .with_segs(vec![
        lime::experiments::SegChoice::Auto,
        lime::experiments::SegChoice::Fixed(4),
    ])
    .with_pressure(vec![
        lime::adapt::Script::none(),
        lime::adapt::Script::from_mem(lime::adapt::MemScenario::correlated_dip(
            "corr-dip",
            &[0, 1],
            1,
            lime::util::bytes::gib(4.0),
            1,
            3,
        )),
        lime::adapt::Script::from_mem(lime::adapt::MemScenario::squeeze(
            "sq",
            0,
            lime::util::bytes::gib(4.0),
            1,
        ))
        .with_bandwidth_sag(0.5, 1, 3)
        .with_label("joint"),
    ]);
    b.time("scenario_matrix_e1_joint_pressure (pool)", 1, 5, || {
        std::hint::black_box(joint_matrix.eval().len());
    });

    // Continuous-serving path: a bursty 5-request stream served through
    // serve::simqueue on the unified executor core (per-request queueing
    // delay / TTFT / TBT metrics are the point of the path; the bench
    // guards the shared-timeline step driver's throughput).
    let serve_reqs = lime::workload::stream_requests(
        lime::workload::Pattern::Bursty,
        0xBE,
        5,
        0.5,
        64,
        32,
    );
    b.time("serving_stream_bursty5", 1, 10, || {
        let sr = lime::serve::serve_interleaved(
            &alloc,
            &cluster,
            &bw,
            cluster.len(),
            &off,
            &lime::adapt::Script::none(),
            &serve_reqs,
        );
        std::hint::black_box(sr.mean_queueing_delay());
    });

    // Batching-policy pair: the same oversubscribed bursty stream served
    // under FIFO epochs vs step-level continuous admission with paged-KV
    // accounting on (16-token pages, a generous no-spill budget) — the
    // continuous driver's extra per-step work (ready-queue joins, page
    // growth, eviction) must stay in the same band as the FIFO path it
    // generalizes. See docs/SERVING.md for the admission semantics.
    let batch_reqs = lime::workload::stream_requests(
        lime::workload::Pattern::Bursty,
        0xBF,
        2 * cluster.len(),
        0.5,
        64,
        32,
    );
    b.time("serving_continuous_batching_fifo", 1, 10, || {
        let sr = lime::serve::serve_interleaved(
            &alloc,
            &cluster,
            &bw,
            cluster.len(),
            &off,
            &lime::adapt::Script::none(),
            &batch_reqs,
        );
        std::hint::black_box(sr.mean_queueing_delay());
    });
    b.time("serving_continuous_batching_cont16", 1, 10, || {
        let sr = lime::serve::serve_interleaved_opts(
            &alloc,
            &cluster,
            &bw,
            cluster.len(),
            &off,
            &lime::adapt::Script::none(),
            &batch_reqs,
            &lime::serve::BatchingOpts::continuous(1)
                .with_kv_pages(lime::serve::KvPageConfig::for_alloc(&alloc, 16, 4096)),
        );
        std::hint::black_box(sr.mean_queueing_delay());
    });

    // Workload-mix pair: the same oversubscribed burst drawn from a
    // bimodal short-chat / long-context distribution. Ragged slots put
    // the per-slot prefill/KV arithmetic on its slow (non-uniform) path
    // and make request completions stagger, so the continuous driver's
    // slot recycling actually churns — the cost of the length-mix axis
    // must stay in the same band as the fixed-length pair above.
    let mixed_reqs = lime::workload::stream_requests_mix(
        lime::workload::Pattern::Bursty,
        0xBF,
        2 * cluster.len(),
        0.5,
        &lime::workload::LengthDist::Bimodal {
            short: (32, 8),
            long: (128, 48),
            long_frac: 0.5,
        },
    );
    b.time("mixed_length_stream_fifo", 1, 10, || {
        let sr = lime::serve::serve_interleaved(
            &alloc,
            &cluster,
            &bw,
            cluster.len(),
            &off,
            &lime::adapt::Script::none(),
            &mixed_reqs,
        );
        std::hint::black_box(sr.mean_queueing_delay());
    });
    b.time("mixed_length_stream_cont16", 1, 10, || {
        let sr = lime::serve::serve_interleaved_opts(
            &alloc,
            &cluster,
            &bw,
            cluster.len(),
            &off,
            &lime::adapt::Script::none(),
            &mixed_reqs,
            &lime::serve::BatchingOpts::continuous(1)
                .with_kv_pages(lime::serve::KvPageConfig::for_alloc(&alloc, 16, 4096)),
        );
        std::hint::black_box(sr.mean_queueing_delay());
    });

    // lime-sweep-v4 throughput: the joint-pressure matrix extended with a
    // continuous-stream arrival point, pooled vs sequential — the
    // pool-vs-sequential pair for the request-serving sweep.
    let arrivals_matrix = lime::experiments::ScenarioMatrix::new(
        "bench-arrivals",
        grid_spec.clone(),
        grid_cluster.clone(),
        &methods,
        vec![100.0, 200.0],
        vec![
            lime::workload::Pattern::Sporadic,
            lime::workload::Pattern::Bursty,
        ],
        4,
    )
    .with_segs(vec![
        lime::experiments::SegChoice::Auto,
        lime::experiments::SegChoice::Fixed(4),
    ])
    .with_pressure(vec![
        lime::adapt::Script::none(),
        lime::adapt::Script::from_mem(lime::adapt::MemScenario::dip(
            "dip-d0",
            0,
            lime::util::bytes::gib(4.0),
            1,
            3,
        )),
    ])
    .with_arrivals(vec![
        lime::experiments::ArrivalSpec::Single,
        lime::experiments::ArrivalSpec::Stream {
            count: 4,
            lambda: 0.5,
        },
    ]);
    let arrivals_pool_s = b
        .time("scenario_matrix_e1_arrivals_v4 (pool)", 1, 5, || {
            std::hint::black_box(arrivals_matrix.eval().len());
        })
        .mean;
    let arrivals_seq_s = b
        .time("scenario_matrix_e1_arrivals_v4_sequential", 1, 5, || {
            std::hint::black_box(arrivals_matrix.eval_sequential().len());
        })
        .mean;
    if arrivals_pool_s > 0.0 {
        b.row(
            "v4 arrivals sweep speedup (sequential / pool)",
            &format!("{:.2}x", arrivals_seq_s / arrivals_pool_s),
        );
    }

    // Fleet-sharded serving throughput: a 10^5-request sporadic stream
    // routed plan-aware across the four demo clusters, one cluster per
    // pool job, aggregated memory-flat (P²/reservoir sinks — no
    // per-request vectors retained). The sequential variant is the
    // byte-identical reference the speedup is measured against.
    let mut fleet = lime::serve::FleetSpec::demo(100_000, 4);
    fleet.routers = vec![lime::serve::RouterPolicy::PlanAware];
    fleet.patterns = vec![lime::workload::Pattern::Sporadic];
    let fleet_pool_s = b
        .time("fleet_stream_100k (pool)", 1, 3, || {
            let cells = lime::serve::run_fleet(&fleet);
            std::hint::black_box(cells[0].ttft.p99);
        })
        .mean;
    let fleet_seq_s = b
        .time("fleet_stream_100k_sequential", 1, 3, || {
            let cells = lime::serve::run_fleet_sequential(&fleet);
            std::hint::black_box(cells[0].ttft.p99);
        })
        .mean;
    if fleet_pool_s > 0.0 {
        b.row(
            "fleet stream speedup (sequential / pool)",
            &format!("{:.2}x", fleet_seq_s / fleet_pool_s),
        );
    }

    // Headline router pair: one 10^6-request sporadic stream routed
    // plan-aware across the four demo clusters — the event-driven
    // heap-indexed router (O(log C) per decision) against the legacy
    // O(C)-scan reference it is decision-identical to. Routing only: the
    // stream is pre-generated once and both sides emit just the
    // per-cluster u32 index lists, so memory stays flat at any scale.
    let route_reqs = lime::workload::stream_requests(
        lime::workload::Pattern::Sporadic,
        lime::serve::fleet::FLEET_SEED,
        1_000_000,
        200.0,
        64,
        4,
    );
    let des_s = b
        .time("fleet_stream_1M_des", 1, 5, || {
            let parts = lime::serve::fleet::route(
                lime::serve::RouterPolicy::PlanAware,
                &route_reqs,
                &fleet.clusters,
            );
            std::hint::black_box(parts[0].len());
        })
        .mean;
    let scan_s = b
        .time("fleet_stream_1M_scan", 1, 5, || {
            let parts = lime::serve::fleet::route_scan(
                lime::serve::RouterPolicy::PlanAware,
                &route_reqs,
                &fleet.clusters,
            );
            std::hint::black_box(parts[0].len());
        })
        .mean;
    if des_s > 0.0 {
        b.row(
            "1M-request routing speedup (scan / DES)",
            &format!("{:.2}x", scan_s / des_s),
        );
    }

    // DES engine raw throughput.
    b.time("des_engine_1M_events", 1, 5, || {
        let mut eng: lime::sim::Engine<u64> = lime::sim::Engine::new();
        let mut world = 0u64;
        for i in 0..1000 {
            eng.schedule(i as f64, move |e, w: &mut u64| {
                *w += 1;
                for _ in 0..999 {
                    e.schedule(0.5, |_, w2: &mut u64| *w2 += 1);
                }
            });
        }
        eng.run(&mut world);
        assert_eq!(world, 1_000_000);
    });
    b.finish();
}
