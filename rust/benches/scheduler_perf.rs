//! L3 hot-path microbenchmarks: the offline scheduler (Alg. 1), the cost
//! model, the online planner, and the DES executors. These are the knobs
//! the §Perf pass tunes.
//!
//! The canonical `interleaved_sim_*` measurements run with
//! `TraceMode::Off` — the configuration the experiment grids use — and the
//! `_fulltrace` variants quantify what span materialization costs on top.
//! `offline_plan_80L_5dev` runs with the default worker-thread fan-out;
//! `offline_plan_80L_5dev_1thread` is the sequential reference.
//!
//! `Bench::finish` writes `BENCH_scheduler_perf.json` and prints speedups
//! against the previous run's file: run once on the baseline commit, once
//! after a change, and commit both (see README.md §Benchmarks).

use lime::cluster::Cluster;
use lime::model::ModelSpec;
use lime::net::BandwidthTrace;
use lime::pipeline::{run_interleaved, ExecOptions};
use lime::plan::{plan, plan_with_threads, PlanOptions};
use lime::sim::TraceMode;
use lime::util::bench::Bench;
use lime::util::bytes::mbps;

fn main() {
    let mut b = Bench::new("scheduler_perf");
    let spec = ModelSpec::llama33_70b();
    let cluster = Cluster::lowmem_setting1();
    let opts = PlanOptions {
        empirical_tokens: 256,
        micro_batch: 1,
        bandwidth: mbps(200.0),
    };

    b.time("offline_plan_80L_5dev (full #Seg sweep)", 2, 20, || {
        let _ = plan(&spec, &cluster, &opts).unwrap();
    });
    b.time("offline_plan_80L_5dev_1thread", 2, 20, || {
        let _ = plan_with_threads(&spec, &cluster, &opts, 1).unwrap();
    });

    let alloc = plan(&spec, &cluster, &opts).unwrap().allocation;
    b.time("cost_model_t_total", 10, 1000, || {
        let _ = lime::cost::t_total(&alloc, &cluster, 256, 1, mbps(200.0));
    });

    let bw = BandwidthTrace::fixed_mbps(200.0);
    let off = ExecOptions {
        trace_mode: TraceMode::Off,
        ..ExecOptions::default()
    };
    let full = ExecOptions::default();
    b.time("interleaved_sim_64tok_sporadic", 1, 10, || {
        let _ = run_interleaved(&alloc, &cluster, &bw, 1, 64, &off);
    });
    b.time("interleaved_sim_64tok_bursty5", 1, 10, || {
        let _ = run_interleaved(&alloc, &cluster, &bw, 5, 64, &off);
    });
    b.time("interleaved_sim_64tok_sporadic_fulltrace", 1, 10, || {
        let _ = run_interleaved(&alloc, &cluster, &bw, 1, 64, &full);
    });
    b.time("interleaved_sim_64tok_bursty5_fulltrace", 1, 10, || {
        let _ = run_interleaved(&alloc, &cluster, &bw, 5, 64, &full);
    });

    // Trace query path: uncovered_load is a sort/sweep over the span lanes.
    let traced = run_interleaved(&alloc, &cluster, &bw, 5, 64, &full);
    b.row(
        "spans materialized (bursty5, 64 tok, Full)",
        &format!("{}", traced.trace.span_count()),
    );
    b.time("trace_uncovered_load_all_devices", 2, 50, || {
        let acc: f64 = traced.trace.uncovered_loads().iter().sum();
        std::hint::black_box(acc);
    });

    // DES engine raw throughput.
    b.time("des_engine_1M_events", 1, 5, || {
        let mut eng: lime::sim::Engine<u64> = lime::sim::Engine::new();
        let mut world = 0u64;
        for i in 0..1000 {
            eng.schedule(i as f64, move |e, w: &mut u64| {
                *w += 1;
                for _ in 0..999 {
                    e.schedule(0.5, |_, w2: &mut u64| *w2 += 1);
                }
            });
        }
        eng.run(&mut world);
        assert_eq!(world, 1_000_000);
    });
    b.finish();
}
