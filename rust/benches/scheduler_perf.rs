//! L3 hot-path microbenchmarks: the offline scheduler (Alg. 1), the cost
//! model, the online planner, and the DES executors. These are the knobs
//! the §Perf pass tunes.

use lime::cluster::Cluster;
use lime::model::ModelSpec;
use lime::net::BandwidthTrace;
use lime::pipeline::{run_interleaved, ExecOptions};
use lime::plan::{plan, PlanOptions};
use lime::util::bench::Bench;
use lime::util::bytes::mbps;

fn main() {
    let mut b = Bench::new("scheduler_perf");
    let spec = ModelSpec::llama33_70b();
    let cluster = Cluster::lowmem_setting1();
    let opts = PlanOptions {
        empirical_tokens: 256,
        micro_batch: 1,
        bandwidth: mbps(200.0),
    };

    b.time("offline_plan_80L_5dev (full #Seg sweep)", 2, 20, || {
        let _ = plan(&spec, &cluster, &opts).unwrap();
    });

    let alloc = plan(&spec, &cluster, &opts).unwrap().allocation;
    b.time("cost_model_t_total", 10, 1000, || {
        let _ = lime::cost::t_total(&alloc, &cluster, 256, 1, mbps(200.0));
    });

    let bw = BandwidthTrace::fixed_mbps(200.0);
    b.time("interleaved_sim_64tok_sporadic", 1, 10, || {
        let _ = run_interleaved(&alloc, &cluster, &bw, 1, 64, &ExecOptions::default());
    });
    b.time("interleaved_sim_64tok_bursty5", 1, 10, || {
        let _ = run_interleaved(&alloc, &cluster, &bw, 5, 64, &ExecOptions::default());
    });

    // DES engine raw throughput.
    b.time("des_engine_1M_events", 1, 5, || {
        let mut eng: lime::sim::Engine<u64> = lime::sim::Engine::new();
        let mut world = 0u64;
        for i in 0..1000 {
            eng.schedule(i as f64, move |e, w: &mut u64| {
                *w += 1;
                for _ in 0..999 {
                    e.schedule(0.5, |_, w2: &mut u64| *w2 += 1);
                }
            });
        }
        eng.run(&mut world);
        assert_eq!(world, 1_000_000);
    });
    b.finish();
}
