//! Regenerates Fig.13: the main comparison on environment e2 — all 7 methods
//! x {100, 200} Mbps x {sporadic, bursty}, reported in ms/token.

use lime::util::bench::Bench;
use lime::util::stats::geomean;

fn main() {
    let b = Bench::new("fig13_e2_qwen32b");
    let cells = lime::experiments::main_comparison("e2", 48);
    let sp = lime::experiments::speedups(&cells);
    if !sp.is_empty() {
        let g = geomean(&sp.iter().map(|(_, s)| *s).collect::<Vec<_>>());
        b.section("LIME speedups over completing baselines");
        for (label, s) in &sp {
            b.row(label, &format!("{s:.2}x"));
        }
        b.row("geomean speedup", &format!("{g:.2}x"));
    }
    b.finish();
}
