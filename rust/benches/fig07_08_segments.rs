//! Regenerates Figs 7-8: the #Seg sweet spot — too many segments inflate
//! T_comm, too few inflate memory pressure and uncovered loads.

use lime::util::bench::Bench;

fn main() {
    let b = Bench::new("fig07_08_segments");
    let rows = lime::experiments::fig78_segments(24);
    let best = rows
        .iter()
        .min_by(|a, b| a.1.partial_cmp(&b.1).unwrap())
        .expect("no feasible segment counts");
    b.row("optimal #Seg", &format!("{} ({:.1} ms/token)", best.0, best.1));
    b.finish();
}
