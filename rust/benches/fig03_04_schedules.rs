//! Regenerates Figs 3-4: traditional vs interleaved pipeline schedules
//! under sporadic and bursty request patterns (Gantt traces + latency).

use lime::util::bench::Bench;

fn main() {
    let b = Bench::new("fig03_04_schedules");
    lime::experiments::fig34_schedules(3);
    b.finish();
}
