//! Regenerates Fig. 2a (TP+offload vs PP+offload latency) and Fig. 2b
//! (model-shard vs KV-cache offload load latency growth).

use lime::util::bench::Bench;

fn main() {
    let mut b = Bench::new("fig02_motivation");

    b.section("Fig. 2a: TP+offload vs PP+offload, 200 Mbps, sporadic");
    let rows = lime::experiments::fig2a(24);
    for (label, tp, pp) in &rows {
        b.row(label, &format!("TP {tp:9.1} ms/tok | PP {pp:9.1} ms/tok | PP speedup {:.2}x", tp / pp));
    }

    b.section("Fig. 2b: per-step load latency, model-shard vs KV offload (AGX Orin 32)");
    let rows = lime::experiments::fig2b(600);
    for step in (0..rows.len()).step_by(50) {
        let (s, model_ms, kv_ms) = rows[step];
        b.row(
            &format!("step {s:4}"),
            &format!("model-shard {model_ms:7.2} ms | kv-offload {kv_ms:7.2} ms"),
        );
    }
    b.time("fig2b_600_steps_sim", 1, 5, || {
        let _ = lime::experiments::fig2b(600);
    });
    b.finish();
}
