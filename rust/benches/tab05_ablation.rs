//! Regenerates Table V: the ablation study — LIME vs LIME-without-KV-
//! transfer vs LIME-without-memory-aware-planner, sporadic and bursty.

use lime::util::bench::Bench;

fn main() {
    let b = Bench::new("tab05_ablation");
    let rows = lime::experiments::tab5(3072);
    if let Some((_, Some(ls), Some(lb))) = rows.last().cloned() {
        for (name, s, bst) in &rows[..rows.len() - 1] {
            if let (Some(s), Some(bst)) = (s, bst) {
                b.row(
                    &format!("{name} relative to LIME"),
                    &format!("{:.2}x sporadic, {:.2}x bursty (paper: 0.86x/0.87x, 0.67x/0.69x)", ls / s, lb / bst),
                );
            }
        }
    }
    b.finish();
}
