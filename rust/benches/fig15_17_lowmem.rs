//! Regenerates Figs 15-17: extremely-low-memory Settings 1-3 on
//! Llama3.3-70B, with OOM/OOT classification (40 s/tok sporadic,
//! 15 s/tok bursty).

use lime::util::bench::Bench;

fn main() {
    let b = Bench::new("fig15_17_lowmem");
    for setting in 1..=3 {
        let cells = lime::experiments::lowmem(setting, 32);
        let lime_ok = cells
            .iter()
            .filter(|c| c.method == "LIME")
            .all(|c| c.ms_per_token.is_some() && !c.is_oot());
        b.row(
            &format!("Setting {setting}: LIME completes all cells"),
            if lime_ok { "yes" } else { "NO" },
        );
    }
    b.finish();
}
