//! Regenerates Fig. 18: inference under randomly varying bandwidth
//! (50-250 Mbps walks), all methods, both patterns.

use lime::util::bench::Bench;

fn main() {
    let b = Bench::new("fig18_bandwidth");
    let cells = lime::experiments::fig18(64);
    // Report LIME's advantage under the storm.
    for pattern in [lime::workload::Pattern::Sporadic, lime::workload::Pattern::Bursty] {
        let lime_ms = cells
            .iter()
            .find(|c| c.method == "LIME" && c.pattern == pattern)
            .and_then(|c| c.ms_per_token);
        if let Some(lms) = lime_ms {
            let best_other = cells
                .iter()
                .filter(|c| c.method != "LIME" && c.pattern == pattern)
                .filter_map(|c| c.ms_per_token)
                .fold(f64::INFINITY, f64::min);
            b.row(
                &format!("{pattern:?}: LIME vs best baseline"),
                &format!("{lms:.1} vs {best_other:.1} ms/tok ({:.2}x)", best_other / lms),
            );
        }
    }
    b.finish();
}
