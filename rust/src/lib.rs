//! # LIME — Accelerating Collaborative Lossless LLM Inference on
//! # Memory-Constrained Edge Devices
//!
//! A full-system reproduction of the LIME paper (Sun et al., CS.DC 2025) as
//! a three-layer Rust + JAX + Pallas stack:
//!
//! * **Layer 3 (this crate)** — the coordination contribution: interleaved
//!   pipeline with model offloading ([`pipeline`]), the offload-oriented
//!   cost model ([`cost`]), the fine-grained offline allocation scheduler
//!   ([`plan`]), the online memory adaptation strategy ([`adapt`]), six
//!   baselines ([`baselines`]), a heterogeneous-edge discrete-event
//!   simulator ([`sim`], [`cluster`], [`net`]), and a real serving engine
//!   over PJRT ([`runtime`], [`serve`]).
//! * **Layer 2** — `python/compile/model.py`: the TinyLM JAX graph, lowered
//!   once to HLO text (`make artifacts`).
//! * **Layer 1** — `python/compile/kernels/attention.py`: the Pallas GQA
//!   decode-attention kernel baked into the layer artifacts.
//!
//! Python never runs on the request path: the Rust binary loads
//! `artifacts/*.hlo.txt` through the PJRT C API and owns every byte of
//! weight and KV-cache residency — which is precisely the resource LIME
//! schedules.
//!
//! See `docs/ARCHITECTURE.md` (repo root) for the module map, the
//! executor inventory, and the paper↔code table mapping every equation,
//! algorithm and figure to the functions and tests that realize them;
//! `docs/SWEEPS.md` documents the sweep-artifact schemas.

// The `pjrt` feature gates the real serving path, which needs the `xla`
// PJRT bindings — not declarable offline. Fail early with an actionable
// message instead of hundreds of unresolved-import errors; remove this
// guard after adding the dependency (see the note in Cargo.toml).
#[cfg(feature = "pjrt")]
compile_error!(
    "the `pjrt` feature requires the `xla` dependency (native xla_extension); \
     add it to rust/Cargo.toml as described there, then delete this guard in src/lib.rs"
);

pub mod adapt;
pub mod baselines;
pub mod cluster;
pub mod cost;
pub mod experiments;
pub mod metrics;
pub mod model;
pub mod net;
pub mod pipeline;
pub mod plan;
pub mod runtime;
pub mod serve;
pub mod sim;
pub mod util;
pub mod workload;
