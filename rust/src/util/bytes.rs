//! Byte-size arithmetic and formatting helpers used throughout the cost
//! model (everything memory-related is `u64` bytes; bandwidths are
//! `f64` bytes/second).

pub const KIB: u64 = 1024;
pub const MIB: u64 = 1024 * KIB;
pub const GIB: u64 = 1024 * MIB;

/// Megabits/s -> bytes/s (network bandwidths in the paper are Mbps).
pub fn mbps(v: f64) -> f64 {
    v * 1e6 / 8.0
}

/// Gibibytes -> bytes.
pub fn gib(v: f64) -> u64 {
    (v * GIB as f64) as u64
}

/// Mebibytes -> bytes.
pub fn mib(v: f64) -> u64 {
    (v * MIB as f64) as u64
}

/// Bytes / (bytes/s) -> seconds; panics on non-positive bandwidth.
pub fn transfer_secs(bytes: u64, bytes_per_sec: f64) -> f64 {
    assert!(bytes_per_sec > 0.0, "bandwidth must be positive");
    bytes as f64 / bytes_per_sec
}

/// Human-format a byte count.
pub fn fmt_bytes(b: u64) -> String {
    if b >= GIB {
        format!("{:.2} GiB", b as f64 / GIB as f64)
    } else if b >= MIB {
        format!("{:.2} MiB", b as f64 / MIB as f64)
    } else if b >= KIB {
        format!("{:.2} KiB", b as f64 / KIB as f64)
    } else {
        format!("{b} B")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mbps_conversion() {
        // 200 Mbps = 25 MB/s.
        assert!((mbps(200.0) - 25e6).abs() < 1.0);
    }

    #[test]
    fn transfer_time() {
        // 25 MB over 25 MB/s = 1s.
        assert!((transfer_secs(25_000_000, mbps(200.0)) - 1.0).abs() < 1e-9);
    }

    #[test]
    fn formatting() {
        assert_eq!(fmt_bytes(512), "512 B");
        assert_eq!(fmt_bytes(2 * KIB), "2.00 KiB");
        assert_eq!(fmt_bytes(3 * MIB), "3.00 MiB");
        assert_eq!(fmt_bytes(64 * GIB), "64.00 GiB");
    }

    #[test]
    fn gib_mib() {
        assert_eq!(gib(1.0), GIB);
        assert_eq!(mib(1.5), MIB + MIB / 2);
    }
}
