//! TOML-subset config parser substrate (the `toml` crate is unavailable
//! offline). Supports what LIME config files need: `[section]` and
//! `[[array-of-tables]]` headers, `key = value` with strings, integers,
//! floats, booleans, and homogeneous inline arrays, plus `#` comments.

use std::collections::BTreeMap;

/// A parsed TOML value.
#[derive(Debug, Clone, PartialEq)]
pub enum Value {
    Str(String),
    Int(i64),
    Float(f64),
    Bool(bool),
    Arr(Vec<Value>),
}

impl Value {
    pub fn as_str(&self) -> Option<&str> {
        match self {
            Value::Str(s) => Some(s),
            _ => None,
        }
    }
    pub fn as_i64(&self) -> Option<i64> {
        match self {
            Value::Int(n) => Some(*n),
            _ => None,
        }
    }
    pub fn as_u64(&self) -> Option<u64> {
        self.as_i64().and_then(|n| u64::try_from(n).ok())
    }
    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Value::Float(f) => Some(*f),
            Value::Int(n) => Some(*n as f64),
            _ => None,
        }
    }
    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Value::Bool(b) => Some(*b),
            _ => None,
        }
    }
    pub fn as_arr(&self) -> Option<&[Value]> {
        match self {
            Value::Arr(v) => Some(v),
            _ => None,
        }
    }
}

/// A `[section]` (or one element of a `[[section]]` list): flat key/value map.
pub type Table = BTreeMap<String, Value>;

/// Parsed document: top-level keys live in `root`; `[s]` in `tables`;
/// `[[s]]` in `table_arrays`.
#[derive(Debug, Default, Clone)]
pub struct Document {
    pub root: Table,
    pub tables: BTreeMap<String, Table>,
    pub table_arrays: BTreeMap<String, Vec<Table>>,
}

#[derive(Debug, thiserror::Error)]
#[error("toml parse error on line {line}: {msg}")]
pub struct TomlError {
    pub line: usize,
    pub msg: String,
}

enum Section {
    Root,
    Table(String),
    ArrayElem(String),
}

impl Document {
    pub fn parse(src: &str) -> Result<Document, TomlError> {
        let mut doc = Document::default();
        let mut section = Section::Root;

        for (idx, raw) in src.lines().enumerate() {
            let line = strip_comment(raw).trim();
            let lineno = idx + 1;
            if line.is_empty() {
                continue;
            }
            if let Some(name) = line.strip_prefix("[[").and_then(|s| s.strip_suffix("]]")) {
                let name = name.trim().to_string();
                doc.table_arrays.entry(name.clone()).or_default().push(Table::new());
                section = Section::ArrayElem(name);
            } else if let Some(name) = line.strip_prefix('[').and_then(|s| s.strip_suffix(']')) {
                let name = name.trim().to_string();
                doc.tables.entry(name.clone()).or_default();
                section = Section::Table(name);
            } else if let Some(eq) = find_eq(line) {
                let key = line[..eq].trim().to_string();
                if key.is_empty() {
                    return Err(TomlError { line: lineno, msg: "empty key".into() });
                }
                let value = parse_value(line[eq + 1..].trim(), lineno)?;
                let table = match &section {
                    Section::Root => &mut doc.root,
                    Section::Table(name) => doc.tables.get_mut(name).unwrap(),
                    Section::ArrayElem(name) => {
                        doc.table_arrays.get_mut(name).unwrap().last_mut().unwrap()
                    }
                };
                table.insert(key, value);
            } else {
                return Err(TomlError {
                    line: lineno,
                    msg: format!("cannot parse line: {line:?}"),
                });
            }
        }
        Ok(doc)
    }

    /// `doc.get("section", "key")`; section "" means root.
    pub fn get(&self, section: &str, key: &str) -> Option<&Value> {
        if section.is_empty() {
            self.root.get(key)
        } else {
            self.tables.get(section)?.get(key)
        }
    }
}

/// Strip a `#` comment, respecting string literals.
fn strip_comment(line: &str) -> &str {
    let mut in_str = false;
    for (i, c) in line.char_indices() {
        match c {
            '"' => in_str = !in_str,
            '#' if !in_str => return &line[..i],
            _ => {}
        }
    }
    line
}

/// Find the key/value `=`, respecting string literals.
fn find_eq(line: &str) -> Option<usize> {
    let mut in_str = false;
    for (i, c) in line.char_indices() {
        match c {
            '"' => in_str = !in_str,
            '=' if !in_str => return Some(i),
            _ => {}
        }
    }
    None
}

fn parse_value(s: &str, line: usize) -> Result<Value, TomlError> {
    let err = |msg: String| TomlError { line, msg };
    if s.is_empty() {
        return Err(err("empty value".into()));
    }
    if let Some(inner) = s.strip_prefix('"') {
        let inner = inner
            .strip_suffix('"')
            .ok_or_else(|| err("unterminated string".into()))?;
        return Ok(Value::Str(unescape(inner)));
    }
    if let Some(inner) = s.strip_prefix('[') {
        let inner = inner
            .strip_suffix(']')
            .ok_or_else(|| err("unterminated array".into()))?
            .trim();
        let mut items = Vec::new();
        if !inner.is_empty() {
            for part in split_top_level(inner) {
                items.push(parse_value(part.trim(), line)?);
            }
        }
        return Ok(Value::Arr(items));
    }
    match s {
        "true" => return Ok(Value::Bool(true)),
        "false" => return Ok(Value::Bool(false)),
        _ => {}
    }
    let cleaned = s.replace('_', "");
    if let Ok(n) = cleaned.parse::<i64>() {
        return Ok(Value::Int(n));
    }
    if let Ok(f) = cleaned.parse::<f64>() {
        return Ok(Value::Float(f));
    }
    Err(err(format!("cannot parse value: {s:?}")))
}

fn unescape(s: &str) -> String {
    let mut out = String::new();
    let mut chars = s.chars();
    while let Some(c) = chars.next() {
        if c == '\\' {
            match chars.next() {
                Some('n') => out.push('\n'),
                Some('t') => out.push('\t'),
                Some('"') => out.push('"'),
                Some('\\') => out.push('\\'),
                Some(other) => {
                    out.push('\\');
                    out.push(other);
                }
                None => out.push('\\'),
            }
        } else {
            out.push(c);
        }
    }
    out
}

/// Split a flat array body on commas outside string literals.
fn split_top_level(s: &str) -> Vec<&str> {
    let mut parts = Vec::new();
    let mut start = 0;
    let mut in_str = false;
    for (i, c) in s.char_indices() {
        match c {
            '"' => in_str = !in_str,
            ',' if !in_str => {
                parts.push(&s[start..i]);
                start = i + 1;
            }
            _ => {}
        }
    }
    parts.push(&s[start..]);
    parts
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = r#"
# cluster config
name = "e3"
seed = 42
bandwidth_mbps = 200.0   # shaped like tc

[model]
preset = "llama3.3-70b"
layers = 80

[[device]]
kind = "agx-orin-64"
mem_gb = 64

[[device]]
kind = "xavier-nx-16"
mem_gb = 16
disabled = false
tags = ["edge", "slow"]
"#;

    #[test]
    fn parses_sample() {
        let doc = Document::parse(SAMPLE).unwrap();
        assert_eq!(doc.get("", "name").unwrap().as_str(), Some("e3"));
        assert_eq!(doc.get("", "seed").unwrap().as_i64(), Some(42));
        assert_eq!(doc.get("", "bandwidth_mbps").unwrap().as_f64(), Some(200.0));
        assert_eq!(doc.get("model", "layers").unwrap().as_i64(), Some(80));
        let devices = &doc.table_arrays["device"];
        assert_eq!(devices.len(), 2);
        assert_eq!(devices[1]["mem_gb"].as_i64(), Some(16));
        assert_eq!(devices[1]["disabled"].as_bool(), Some(false));
        let tags = devices[1]["tags"].as_arr().unwrap();
        assert_eq!(tags[0].as_str(), Some("edge"));
    }

    #[test]
    fn comments_and_strings() {
        let doc = Document::parse("s = \"a # not comment\" # real\n").unwrap();
        assert_eq!(doc.get("", "s").unwrap().as_str(), Some("a # not comment"));
    }

    #[test]
    fn int_float_distinction() {
        let doc = Document::parse("a = 3\nb = 3.5\nc = 1_000\n").unwrap();
        assert_eq!(doc.get("", "a").unwrap().as_i64(), Some(3));
        assert_eq!(doc.get("", "a").unwrap().as_f64(), Some(3.0));
        assert_eq!(doc.get("", "b").unwrap().as_f64(), Some(3.5));
        assert_eq!(doc.get("", "b").unwrap().as_i64(), None);
        assert_eq!(doc.get("", "c").unwrap().as_i64(), Some(1000));
    }

    #[test]
    fn empty_array() {
        let doc = Document::parse("a = []\n").unwrap();
        assert_eq!(doc.get("", "a").unwrap().as_arr().unwrap().len(), 0);
    }

    #[test]
    fn escaped_string() {
        let doc = Document::parse(r#"s = "line\nnext""#).unwrap();
        assert_eq!(doc.get("", "s").unwrap().as_str(), Some("line\nnext"));
    }

    #[test]
    fn error_reports_line() {
        let err = Document::parse("ok = 1\nbroken line\n").unwrap_err();
        assert_eq!(err.line, 2);
    }

    #[test]
    fn negative_numbers() {
        let doc = Document::parse("a = -5\nb = -0.25\n").unwrap();
        assert_eq!(doc.get("", "a").unwrap().as_i64(), Some(-5));
        assert_eq!(doc.get("", "b").unwrap().as_f64(), Some(-0.25));
        assert_eq!(doc.get("", "a").unwrap().as_u64(), None);
    }
}
