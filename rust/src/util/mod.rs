//! Offline-built substrates: RNG, stats, JSON, TOML-subset config, CLI,
//! property testing, bench harness, and byte/bandwidth helpers.
//!
//! The crate registry is unavailable in this environment, so the usual
//! ecosystem crates (`rand`, `serde`, `clap`, `criterion`, `proptest`) are
//! replaced by these small, fully-tested implementations. See DESIGN.md.

pub mod bench;
pub mod bytes;
pub mod cli;
pub mod json;
pub mod pool;
pub mod prop;
pub mod rng;
pub mod stats;
pub mod toml;
