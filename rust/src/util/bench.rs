//! Bench harness substrate (`criterion` is unavailable offline).
//!
//! Benches are `harness = false` binaries: they build a [`Bench`], register
//! timed closures and *table rows* (the paper-figure regenerators print the
//! same rows/series the paper reports), and call [`Bench::finish`].

use std::time::Instant;

use super::stats::{summarize, Summary};

/// A registered measurement.
pub struct Measurement {
    pub name: String,
    pub summary: Summary,
}

/// Collector for one bench binary.
pub struct Bench {
    title: String,
    measurements: Vec<Measurement>,
}

impl Bench {
    pub fn new(title: &str) -> Self {
        println!("\n=== bench: {title} ===");
        Bench {
            title: title.to_string(),
            measurements: Vec::new(),
        }
    }

    /// Time `f` for `iters` iterations after `warmup` warmup runs; returns
    /// per-iteration seconds and records the summary.
    pub fn time(&mut self, name: &str, warmup: usize, iters: usize, mut f: impl FnMut()) -> Summary {
        for _ in 0..warmup {
            f();
        }
        let mut samples = Vec::with_capacity(iters);
        for _ in 0..iters {
            let t0 = Instant::now();
            f();
            samples.push(t0.elapsed().as_secs_f64());
        }
        let s = summarize(&samples);
        println!(
            "  {name:40} mean {:>12}  p50 {:>12}  p99 {:>12}  (n={})",
            fmt_secs(s.mean),
            fmt_secs(s.p50),
            fmt_secs(s.p99),
            s.n
        );
        self.measurements.push(Measurement {
            name: name.to_string(),
            summary: s.clone(),
        });
        s
    }

    /// Print a labelled table section (paper figure/table rows).
    pub fn section(&self, heading: &str) {
        println!("\n-- {heading} --");
    }

    /// Print one result row.
    pub fn row(&self, label: &str, value: &str) {
        println!("  {label:58} {value}");
    }

    pub fn finish(self) {
        println!("=== bench {} done ({} timed measurements) ===", self.title, self.measurements.len());
    }
}

/// Human-format a duration in seconds.
pub fn fmt_secs(s: f64) -> String {
    if s >= 1.0 {
        format!("{s:.3} s")
    } else if s >= 1e-3 {
        format!("{:.3} ms", s * 1e3)
    } else if s >= 1e-6 {
        format!("{:.3} us", s * 1e6)
    } else {
        format!("{:.1} ns", s * 1e9)
    }
}

/// Human-format milliseconds-per-token with OOM/OOT handling.
pub fn fmt_ms_tok(v: Option<f64>, oot_limit_ms: f64) -> String {
    match v {
        None => "OOM".to_string(),
        Some(ms) if ms > oot_limit_ms => format!("OOT (>{oot_limit_ms:.0} ms/tok)"),
        Some(ms) => format!("{ms:9.1} ms/tok"),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fmt_units() {
        assert_eq!(fmt_secs(2.5), "2.500 s");
        assert_eq!(fmt_secs(0.0025), "2.500 ms");
        assert_eq!(fmt_secs(2.5e-6), "2.500 us");
        assert!(fmt_secs(5e-9).ends_with("ns"));
    }

    #[test]
    fn fmt_ms_tok_states() {
        assert_eq!(fmt_ms_tok(None, 100.0), "OOM");
        assert!(fmt_ms_tok(Some(150.0), 100.0).starts_with("OOT"));
        assert!(fmt_ms_tok(Some(50.0), 100.0).contains("50.0"));
    }

    #[test]
    fn time_records() {
        let mut b = Bench::new("self-test");
        let s = b.time("noop", 1, 5, || {});
        assert_eq!(s.n, 5);
        assert_eq!(b.measurements.len(), 1);
        b.finish();
    }
}
