//! Bench harness substrate (`criterion` is unavailable offline).
//!
//! Benches are `harness = false` binaries: they build a [`Bench`], register
//! timed closures and *table rows* (the paper-figure regenerators print the
//! same rows/series the paper reports), and call [`Bench::finish`].
//!
//! `finish` also emits a machine-readable `BENCH_<title>.json` next to the
//! process working directory (override the directory with the
//! `LIME_BENCH_DIR` env var), and — when a previous JSON exists — prints the
//! per-measurement speedup against it before overwriting. That file is the
//! perf trajectory record: commit the before/after pair whenever a PR
//! touches a hot path. See README.md §Benchmarks for the schema.

use std::time::Instant;

use super::json::{obj, Json};
use super::stats::{summarize, Summary};

/// A registered measurement.
pub struct Measurement {
    pub name: String,
    pub summary: Summary,
}

/// Collector for one bench binary.
pub struct Bench {
    title: String,
    measurements: Vec<Measurement>,
}

impl Bench {
    pub fn new(title: &str) -> Self {
        println!("\n=== bench: {title} ===");
        Bench {
            title: title.to_string(),
            measurements: Vec::new(),
        }
    }

    /// Time `f` for `iters` iterations after `warmup` warmup runs; returns
    /// per-iteration seconds and records the summary.
    pub fn time(&mut self, name: &str, warmup: usize, iters: usize, mut f: impl FnMut()) -> Summary {
        for _ in 0..warmup {
            f();
        }
        let mut samples = Vec::with_capacity(iters);
        for _ in 0..iters {
            let t0 = Instant::now();
            f();
            samples.push(t0.elapsed().as_secs_f64());
        }
        let s = summarize(&samples);
        println!(
            "  {name:40} mean {:>12}  p50 {:>12}  p99 {:>12}  (n={})",
            fmt_secs(s.mean),
            fmt_secs(s.p50),
            fmt_secs(s.p99),
            s.n
        );
        self.measurements.push(Measurement {
            name: name.to_string(),
            summary: s.clone(),
        });
        s
    }

    /// Print a labelled table section (paper figure/table rows).
    pub fn section(&self, heading: &str) {
        println!("\n-- {heading} --");
    }

    /// Print one result row.
    pub fn row(&self, label: &str, value: &str) {
        println!("  {label:58} {value}");
    }

    /// Machine-readable snapshot of every timed measurement
    /// (schema `lime-bench-v1`).
    pub fn json(&self) -> Json {
        let measurements: Vec<Json> = self
            .measurements
            .iter()
            .map(|m| {
                obj(&[
                    ("name", m.name.as_str().into()),
                    ("n", m.summary.n.into()),
                    ("mean_s", m.summary.mean.into()),
                    ("std_dev_s", m.summary.std_dev.into()),
                    ("min_s", m.summary.min.into()),
                    ("max_s", m.summary.max.into()),
                    ("p50_s", m.summary.p50.into()),
                    ("p90_s", m.summary.p90.into()),
                    ("p99_s", m.summary.p99.into()),
                ])
            })
            .collect();
        obj(&[
            ("schema", "lime-bench-v1".into()),
            ("bench", self.title.as_str().into()),
            ("measurements", Json::Arr(measurements)),
        ])
    }

    /// `BENCH_<title>.json`, with the title sanitized to `[A-Za-z0-9_]`.
    pub fn json_file_name(&self) -> String {
        let sanitized: String = self
            .title
            .chars()
            .map(|c| if c.is_ascii_alphanumeric() { c } else { '_' })
            .collect();
        format!("BENCH_{sanitized}.json")
    }

    /// Output path: `LIME_BENCH_DIR` (default ".") + [`Bench::json_file_name`].
    pub fn json_path(&self) -> std::path::PathBuf {
        let dir = std::env::var("LIME_BENCH_DIR").unwrap_or_else(|_| ".".to_string());
        std::path::Path::new(&dir).join(self.json_file_name())
    }

    /// Print per-measurement speedups of `self` against a previously
    /// written `lime-bench-v1` JSON (matched by measurement name).
    fn print_deltas(&self, prev: &Json) {
        let Some(prev_measurements) = prev.get("measurements").and_then(Json::as_arr) else {
            return;
        };
        let mut prev_means = std::collections::BTreeMap::new();
        for m in prev_measurements {
            if let (Some(name), Some(mean)) = (
                m.get("name").and_then(Json::as_str),
                m.get("mean_s").and_then(Json::as_f64),
            ) {
                prev_means.insert(name.to_string(), mean);
            }
        }
        let mut printed_header = false;
        for m in &self.measurements {
            let Some(&prev_mean) = prev_means.get(&m.name) else {
                continue;
            };
            // NOTE `<= 0.0` alone would let NaN through (NaN compares false
            // both ways) and print a NaN "speedup"; require a pinned mean.
            if !pinned_mean(prev_mean) || !pinned_mean(m.summary.mean) {
                continue;
            }
            if !printed_header {
                println!("\n-- vs previous run --");
                printed_header = true;
            }
            let speedup = prev_mean / m.summary.mean;
            println!(
                "  {:40} {:>12} -> {:>12}  ({speedup:.2}x {})",
                m.name,
                fmt_secs(prev_mean),
                fmt_secs(m.summary.mean),
                if speedup >= 1.0 { "faster" } else { "slower" }
            );
        }
    }

    pub fn finish(self) {
        let path = self.json_path();
        self.finish_at(&path);
    }

    /// [`Bench::finish`] with an explicit output path (tests route output
    /// to a temp dir this way without touching process-global env).
    pub fn finish_at(self, path: &std::path::Path) {
        if !self.measurements.is_empty() {
            if let Ok(src) = std::fs::read_to_string(path) {
                if let Ok(prev) = Json::parse(&src) {
                    self.print_deltas(&prev);
                }
            }
            match std::fs::write(path, format!("{}\n", self.json())) {
                Ok(()) => println!("  wrote {}", path.display()),
                Err(e) => eprintln!("  could not write {}: {e}", path.display()),
            }
        }
        println!(
            "=== bench {} done ({} timed measurements) ===",
            self.title,
            self.measurements.len()
        );
    }
}

/// Outcome of diffing a fresh bench JSON against a committed baseline.
#[derive(Debug)]
pub struct RegressionReport {
    /// Human-readable per-measurement lines (always printed).
    pub lines: Vec<String>,
    /// Measurements slower than `tolerance ×` their baseline — CI fails
    /// loudly when this is non-empty.
    pub failures: Vec<String>,
    /// Baseline entries carrying no perf signal (`mean_s <= 0` placeholders
    /// or non-finite values) — `lime bench-check` surfaces this count so an
    /// all-unpinned baseline reads as "nothing gated", not as a green pass.
    pub unpinned: usize,
}

/// A mean carries a usable perf signal only when it is finite and positive.
/// `mean <= 0.0` alone misclassifies NaN (every comparison with NaN is
/// false), which would fall through to ratio checks that silently pass.
fn pinned_mean(mean: f64) -> bool {
    mean.is_finite() && mean > 0.0
}

/// Diff a fresh `lime-bench-v1` snapshot against a committed baseline with
/// a tolerance band: a measurement **fails** when
/// `current_mean > tolerance × baseline_mean`, or when a baselined
/// measurement disappeared from the current run (silent coverage loss).
///
/// Baseline entries with `mean_s <= 0` are *unpinned placeholders* — the
/// bootstrap baseline ships with zeros until a reference machine records
/// real numbers (see README §Benchmarks) — reported, never failed.
pub fn check_regression(
    current: &Json,
    baseline: &Json,
    tolerance: f64,
) -> Result<RegressionReport, String> {
    if tolerance < 1.0 {
        return Err(format!("tolerance must be >= 1.0, got {tolerance}"));
    }
    for (label, json) in [("current", current), ("baseline", baseline)] {
        match json.get("schema").and_then(Json::as_str) {
            Some("lime-bench-v1") => {}
            other => return Err(format!("{label}: expected schema lime-bench-v1, got {other:?}")),
        }
    }
    let means = |json: &Json| -> Result<std::collections::BTreeMap<String, f64>, String> {
        let arr = json
            .get("measurements")
            .and_then(Json::as_arr)
            .ok_or_else(|| "missing 'measurements' array".to_string())?;
        let mut out = std::collections::BTreeMap::new();
        for m in arr {
            let name = m
                .get("name")
                .and_then(Json::as_str)
                .ok_or_else(|| "measurement without 'name'".to_string())?;
            let mean = m
                .get("mean_s")
                .and_then(Json::as_f64)
                .ok_or_else(|| format!("measurement '{name}' without numeric 'mean_s'"))?;
            out.insert(name.to_string(), mean);
        }
        Ok(out)
    };
    let cur = means(current)?;
    let base = means(baseline)?;

    let mut report = RegressionReport {
        lines: Vec::new(),
        failures: Vec::new(),
        unpinned: 0,
    };
    for (name, &cur_mean) in &cur {
        match base.get(name) {
            None => report
                .lines
                .push(format!("  {name:48} {:>12}  (new, no baseline)", fmt_secs(cur_mean))),
            Some(&b) if !pinned_mean(b) => {
                report.unpinned += 1;
                report.lines.push(format!(
                    "  {name:48} {:>12}  (baseline unpinned — record one, see README)",
                    fmt_secs(cur_mean)
                ));
            }
            Some(&b) if !cur_mean.is_finite() => {
                // A NaN/inf current mean against a pinned baseline is a
                // broken measurement, not a pass — NaN ratios compare false
                // against any tolerance, so fail it explicitly.
                report.failures.push(format!(
                    "BROKEN     {name}: non-finite current mean {cur_mean} vs pinned baseline {}",
                    fmt_secs(b)
                ));
            }
            Some(&b) => {
                let ratio = cur_mean / b;
                let line = format!(
                    "  {name:48} {:>12} vs baseline {:>12}  ({ratio:.2}x, tolerance {tolerance:.2}x)",
                    fmt_secs(cur_mean),
                    fmt_secs(b)
                );
                if ratio > tolerance {
                    report.failures.push(format!("REGRESSION {}", line.trim_start()));
                } else {
                    report.lines.push(line);
                }
            }
        }
    }
    for (name, &b) in &base {
        if !cur.contains_key(name) {
            if !pinned_mean(b) {
                report.unpinned += 1;
                // Unpinned placeholders carry no perf signal; losing one is
                // renaming noise, not silent coverage loss.
                report.lines.push(format!(
                    "  {name:48} (unpinned baseline entry absent from the current run)"
                ));
            } else {
                report.failures.push(format!(
                    "MISSING    {name}: baselined measurement absent from the current run"
                ));
            }
        }
    }
    Ok(report)
}

/// Human-format a duration in seconds.
pub fn fmt_secs(s: f64) -> String {
    if s >= 1.0 {
        format!("{s:.3} s")
    } else if s >= 1e-3 {
        format!("{:.3} ms", s * 1e3)
    } else if s >= 1e-6 {
        format!("{:.3} us", s * 1e6)
    } else {
        format!("{:.1} ns", s * 1e9)
    }
}

/// Human-format milliseconds-per-token with OOM/OOT handling.
pub fn fmt_ms_tok(v: Option<f64>, oot_limit_ms: f64) -> String {
    match v {
        None => "OOM".to_string(),
        Some(ms) if ms > oot_limit_ms => format!("OOT (>{oot_limit_ms:.0} ms/tok)"),
        Some(ms) => format!("{ms:9.1} ms/tok"),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fmt_units() {
        assert_eq!(fmt_secs(2.5), "2.500 s");
        assert_eq!(fmt_secs(0.0025), "2.500 ms");
        assert_eq!(fmt_secs(2.5e-6), "2.500 us");
        assert!(fmt_secs(5e-9).ends_with("ns"));
    }

    #[test]
    fn fmt_ms_tok_states() {
        assert_eq!(fmt_ms_tok(None, 100.0), "OOM");
        assert!(fmt_ms_tok(Some(150.0), 100.0).starts_with("OOT"));
        assert!(fmt_ms_tok(Some(50.0), 100.0).contains("50.0"));
    }

    #[test]
    fn time_records() {
        // No finish(): unit tests must not write BENCH_*.json into the repo.
        let mut b = Bench::new("self-test");
        let s = b.time("noop", 1, 5, || {});
        assert_eq!(s.n, 5);
        assert_eq!(b.measurements.len(), 1);
    }

    #[test]
    fn json_schema_round_trips() {
        let mut b = Bench::new("json-self-test");
        b.time("work", 0, 3, || {
            std::hint::black_box(1 + 1);
        });
        let j = b.json();
        assert_eq!(j.get("schema").unwrap().as_str(), Some("lime-bench-v1"));
        assert_eq!(j.get("bench").unwrap().as_str(), Some("json-self-test"));
        let ms = j.get("measurements").unwrap().as_arr().unwrap();
        assert_eq!(ms.len(), 1);
        assert_eq!(ms[0].get("name").unwrap().as_str(), Some("work"));
        assert_eq!(ms[0].get("n").unwrap().as_usize(), Some(3));
        assert!(ms[0].get("mean_s").unwrap().as_f64().unwrap() >= 0.0);
        // The writer's output must parse back identically.
        let reparsed = Json::parse(&j.to_string()).unwrap();
        assert_eq!(reparsed, j);
    }

    fn bench_json(measurements: &[(&str, f64)]) -> Json {
        let rows: Vec<Json> = measurements
            .iter()
            .map(|&(name, mean)| obj(&[("name", name.into()), ("mean_s", mean.into())]))
            .collect();
        obj(&[
            ("schema", "lime-bench-v1".into()),
            ("bench", "t".into()),
            ("measurements", Json::Arr(rows)),
        ])
    }

    #[test]
    fn regression_gate_passes_within_tolerance() {
        let base = bench_json(&[("a", 1.0), ("b", 0.5)]);
        let cur = bench_json(&[("a", 1.4), ("b", 0.4)]);
        let r = check_regression(&cur, &base, 1.5).unwrap();
        assert!(r.failures.is_empty(), "{:?}", r.failures);
        assert_eq!(r.lines.len(), 2);
    }

    #[test]
    fn regression_gate_fails_loudly_beyond_tolerance() {
        let base = bench_json(&[("a", 1.0)]);
        let cur = bench_json(&[("a", 2.0)]);
        let r = check_regression(&cur, &base, 1.5).unwrap();
        assert_eq!(r.failures.len(), 1);
        assert!(r.failures[0].contains("REGRESSION"), "{}", r.failures[0]);
        assert!(r.failures[0].contains('a'));
    }

    #[test]
    fn regression_gate_skips_unpinned_and_new_entries() {
        // mean_s == 0 marks the committed bootstrap baseline as unpinned —
        // neither a slow current value nor the entry disappearing fails.
        let base = bench_json(&[("a", 0.0), ("gone-unpinned", 0.0)]);
        let cur = bench_json(&[("a", 99.0), ("brand-new", 1.0)]);
        let r = check_regression(&cur, &base, 1.5).unwrap();
        assert!(r.failures.is_empty(), "{:?}", r.failures);
        assert!(r.lines.iter().any(|l| l.contains("unpinned")));
        assert!(r.lines.iter().any(|l| l.contains("no baseline")));
        assert!(r.lines.iter().any(|l| l.contains("gone-unpinned")));
        assert_eq!(r.unpinned, 2, "both zero-mean entries counted as unpinned");
    }

    #[test]
    fn regression_gate_treats_nan_baseline_as_unpinned_not_pass() {
        // NaN compares false against everything, so the old `b <= 0.0`
        // guard let a NaN baseline fall through to a NaN ratio that could
        // never exceed tolerance — a silent pass. It must read as unpinned.
        let base = bench_json(&[("a", f64::NAN)]);
        let cur = bench_json(&[("a", 99.0)]);
        let r = check_regression(&cur, &base, 1.5).unwrap();
        assert!(r.failures.is_empty(), "{:?}", r.failures);
        assert_eq!(r.unpinned, 1);
        assert!(r.lines.iter().any(|l| l.contains("unpinned")));
    }

    #[test]
    fn regression_gate_fails_nonfinite_current_mean_loudly() {
        let base = bench_json(&[("a", 1.0)]);
        let cur = bench_json(&[("a", f64::NAN)]);
        let r = check_regression(&cur, &base, 1.5).unwrap();
        assert_eq!(r.failures.len(), 1, "{:?}", r.lines);
        assert!(r.failures[0].contains("BROKEN"), "{}", r.failures[0]);
    }

    #[test]
    fn regression_gate_counts_zero_unpinned_on_pinned_baselines() {
        let base = bench_json(&[("a", 1.0)]);
        let cur = bench_json(&[("a", 1.0)]);
        let r = check_regression(&cur, &base, 1.5).unwrap();
        assert_eq!(r.unpinned, 0);
    }

    #[test]
    fn regression_gate_flags_disappeared_measurements() {
        let base = bench_json(&[("a", 1.0), ("gone", 1.0)]);
        let cur = bench_json(&[("a", 1.0)]);
        let r = check_regression(&cur, &base, 2.0).unwrap();
        assert_eq!(r.failures.len(), 1);
        assert!(r.failures[0].contains("MISSING"));
    }

    #[test]
    fn regression_gate_rejects_bad_inputs() {
        let good = bench_json(&[("a", 1.0)]);
        let bad = obj(&[("schema", "other".into())]);
        assert!(check_regression(&good, &bad, 1.5).is_err());
        assert!(check_regression(&bad, &good, 1.5).is_err());
        assert!(check_regression(&good, &good, 0.5).is_err(), "tolerance < 1");
    }

    #[test]
    fn json_path_is_sanitized() {
        let b = Bench::new("weird title/with:stuff");
        let p = b.json_path();
        let name = p.file_name().unwrap().to_str().unwrap();
        assert_eq!(name, "BENCH_weird_title_with_stuff.json");
    }

    #[test]
    fn finish_writes_json_and_overwrites_on_rerun() {
        // Route output into a temp dir via finish_at — never through
        // process-global env, which other test threads read concurrently.
        let dir = std::env::temp_dir().join(format!("lime_bench_finish_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();

        let mut b = Bench::new("finish-self-test");
        b.time("work", 0, 2, || {});
        let path = dir.join(b.json_file_name());
        b.finish_at(&path);
        let first = std::fs::read_to_string(&path).expect("finish wrote the JSON");
        let parsed = Json::parse(first.trim()).unwrap();
        assert_eq!(parsed.get("bench").unwrap().as_str(), Some("finish-self-test"));
        assert_eq!(
            parsed.get("measurements").unwrap().as_arr().unwrap().len(),
            1
        );

        // Second run: exercises the previous-file parse + delta path, then
        // overwrites with the fresh snapshot.
        let mut b2 = Bench::new("finish-self-test");
        b2.time("work", 0, 3, || {});
        b2.finish_at(&path);
        let second = std::fs::read_to_string(&path).unwrap();
        let reparsed = Json::parse(second.trim()).unwrap();
        let ms = reparsed.get("measurements").unwrap().as_arr().unwrap();
        assert_eq!(ms[0].get("n").unwrap().as_usize(), Some(3), "overwritten");

        std::fs::remove_dir_all(&dir).ok();
    }
}
