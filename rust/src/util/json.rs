//! Minimal JSON substrate (serde facade unavailable offline): a value tree,
//! a recursive-descent parser (used to read `artifacts/manifest.json`), and a
//! writer (used for metrics / experiment reports).

use std::collections::BTreeMap;
use std::fmt;

/// A JSON value. Object keys are ordered (BTreeMap) for stable output.
#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

/// Parse error with byte offset context.
#[derive(Debug, thiserror::Error)]
#[error("json parse error at byte {offset}: {msg}")]
pub struct ParseError {
    pub offset: usize,
    pub msg: String,
}

impl Json {
    pub fn parse(src: &str) -> Result<Json, ParseError> {
        let mut p = Parser {
            bytes: src.as_bytes(),
            pos: 0,
        };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.pos != p.bytes.len() {
            return Err(p.err("trailing content"));
        }
        Ok(v)
    }

    // ---- typed accessors (None on type mismatch) ----

    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_u64(&self) -> Option<u64> {
        self.as_f64().and_then(|n| {
            if n >= 0.0 && n.fract() == 0.0 {
                Some(n as u64)
            } else {
                None
            }
        })
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_u64().map(|n| n as usize)
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(v) => Some(v),
            _ => None,
        }
    }

    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(m) => Some(m),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    /// Convenience: `obj.path(&["model", "layers"])`.
    pub fn path(&self, keys: &[&str]) -> Option<&Json> {
        let mut cur = self;
        for k in keys {
            cur = cur.get(k)?;
        }
        Some(cur)
    }
}

// --------------------------------------------------------------- builders

impl From<&str> for Json {
    fn from(s: &str) -> Self {
        Json::Str(s.to_string())
    }
}
impl From<String> for Json {
    fn from(s: String) -> Self {
        Json::Str(s)
    }
}
impl From<f64> for Json {
    fn from(n: f64) -> Self {
        Json::Num(n)
    }
}
impl From<u64> for Json {
    fn from(n: u64) -> Self {
        Json::Num(n as f64)
    }
}
impl From<usize> for Json {
    fn from(n: usize) -> Self {
        Json::Num(n as f64)
    }
}
impl From<bool> for Json {
    fn from(b: bool) -> Self {
        Json::Bool(b)
    }
}
impl<T: Into<Json>> From<Vec<T>> for Json {
    fn from(v: Vec<T>) -> Self {
        Json::Arr(v.into_iter().map(Into::into).collect())
    }
}

/// Build an object literal: `obj(&[("a", 1.0.into()), ...])`.
pub fn obj(pairs: &[(&str, Json)]) -> Json {
    Json::Obj(
        pairs
            .iter()
            .map(|(k, v)| (k.to_string(), v.clone()))
            .collect(),
    )
}

// ---------------------------------------------------------------- writer

impl fmt::Display for Json {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Json::Null => write!(f, "null"),
            Json::Bool(b) => write!(f, "{b}"),
            Json::Num(n) => {
                if n.fract() == 0.0 && n.abs() < 1e15 {
                    write!(f, "{}", *n as i64)
                } else {
                    write!(f, "{n}")
                }
            }
            Json::Str(s) => write_escaped(f, s),
            Json::Arr(v) => {
                write!(f, "[")?;
                for (i, x) in v.iter().enumerate() {
                    if i > 0 {
                        write!(f, ",")?;
                    }
                    write!(f, "{x}")?;
                }
                write!(f, "]")
            }
            Json::Obj(m) => {
                write!(f, "{{")?;
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        write!(f, ",")?;
                    }
                    write_escaped(f, k)?;
                    write!(f, ":{v}")?;
                }
                write!(f, "}}")
            }
        }
    }
}

fn write_escaped<W: fmt::Write>(f: &mut W, s: &str) -> fmt::Result {
    write!(f, "\"")?;
    for c in s.chars() {
        match c {
            '"' => write!(f, "\\\"")?,
            '\\' => write!(f, "\\\\")?,
            '\n' => write!(f, "\\n")?,
            '\r' => write!(f, "\\r")?,
            '\t' => write!(f, "\\t")?,
            c if (c as u32) < 0x20 => write!(f, "\\u{:04x}", c as u32)?,
            c => write!(f, "{c}")?,
        }
    }
    write!(f, "\"")
}

// -------------------------------------------------------- streaming writer

/// Incremental JSON emitter for artifacts too large to hold as one [`Json`]
/// tree (the fleet writer streams one cell at a time instead of retaining
/// per-request vectors). Byte-compatibility contract: the emitted bytes are
/// **identical** to `Json::Display` on the equivalent tree — same number
/// formatting (via `Display` on the values pushed), same escaping, no
/// whitespace — so artifacts written either way diff clean. Because `Display`
/// renders objects in `BTreeMap` (alphabetical) key order, [`StreamWriter::key`]
/// enforces strictly ascending keys per object and panics otherwise; panics
/// also flag structural misuse (value without a key, unbalanced `end`).
/// I/O errors surface as `io::Result`.
pub struct StreamWriter<W: std::io::Write> {
    out: W,
    stack: Vec<Frame>,
    /// Values written at the root (must end at exactly 1).
    root_values: usize,
    /// Reusable escape scratch for object keys.
    scratch: String,
}

enum Frame {
    Arr {
        count: usize,
    },
    Obj {
        count: usize,
        last_key: String,
        key_armed: bool,
    },
}

impl<W: std::io::Write> StreamWriter<W> {
    pub fn new(out: W) -> Self {
        StreamWriter {
            out,
            stack: Vec::new(),
            root_values: 0,
            scratch: String::new(),
        }
    }

    /// Separator/arming bookkeeping shared by every value-producing call.
    fn before_value(&mut self) -> std::io::Result<()> {
        match self.stack.last_mut() {
            None => {
                assert_eq!(self.root_values, 0, "JSON document has a single root");
                self.root_values = 1;
            }
            Some(Frame::Arr { count }) => {
                if *count > 0 {
                    self.out.write_all(b",")?;
                }
                *count += 1;
            }
            Some(Frame::Obj { key_armed, .. }) => {
                assert!(*key_armed, "object value requires a preceding key()");
                *key_armed = false;
            }
        }
        Ok(())
    }

    pub fn begin_obj(&mut self) -> std::io::Result<()> {
        self.before_value()?;
        self.stack.push(Frame::Obj {
            count: 0,
            last_key: String::new(),
            key_armed: false,
        });
        self.out.write_all(b"{")
    }

    pub fn begin_arr(&mut self) -> std::io::Result<()> {
        self.before_value()?;
        self.stack.push(Frame::Arr { count: 0 });
        self.out.write_all(b"[")
    }

    /// Emit an object key. Keys must arrive in strictly ascending order —
    /// the order `Json::Obj`'s BTreeMap would render them in.
    pub fn key(&mut self, k: &str) -> std::io::Result<()> {
        match self.stack.last_mut() {
            Some(Frame::Obj {
                count,
                last_key,
                key_armed,
            }) => {
                assert!(!*key_armed, "key() twice without a value");
                assert!(
                    *count == 0 || k > last_key.as_str(),
                    "keys must be strictly ascending to match Json::Display \
                     (got {k:?} after {last_key:?})"
                );
                if *count > 0 {
                    self.out.write_all(b",")?;
                }
                *count += 1;
                *key_armed = true;
                last_key.clear();
                last_key.push_str(k);
            }
            _ => panic!("key() outside an object"),
        }
        self.scratch.clear();
        write_escaped(&mut self.scratch, k).expect("string formatting");
        self.out.write_all(self.scratch.as_bytes())?;
        self.out.write_all(b":")
    }

    /// Emit a complete value (any `Json` tree) in place.
    pub fn value(&mut self, v: &Json) -> std::io::Result<()> {
        self.before_value()?;
        write!(self.out, "{v}")
    }

    /// Close the innermost open object/array.
    pub fn end(&mut self) -> std::io::Result<()> {
        match self.stack.pop() {
            Some(Frame::Arr { .. }) => self.out.write_all(b"]"),
            Some(Frame::Obj { key_armed, .. }) => {
                assert!(!key_armed, "object closed with a dangling key");
                self.out.write_all(b"}")
            }
            None => panic!("end() with nothing open"),
        }
    }

    /// Assert the document is complete and flush; returns the sink.
    pub fn finish(mut self) -> std::io::Result<W> {
        assert!(self.stack.is_empty(), "unclosed containers at finish()");
        assert_eq!(self.root_values, 1, "empty document at finish()");
        self.out.flush()?;
        Ok(self.out)
    }
}

// ---------------------------------------------------------------- parser

struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> ParseError {
        ParseError {
            offset: self.pos,
            msg: msg.to_string(),
        }
    }

    fn peek(&self) -> Option<u8> {
        self.bytes.get(self.pos).copied()
    }

    fn skip_ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.pos += 1;
        }
    }

    fn expect(&mut self, b: u8) -> Result<(), ParseError> {
        if self.peek() == Some(b) {
            self.pos += 1;
            Ok(())
        } else {
            Err(self.err(&format!("expected '{}'", b as char)))
        }
    }

    fn literal(&mut self, lit: &str, val: Json) -> Result<Json, ParseError> {
        if self.bytes[self.pos..].starts_with(lit.as_bytes()) {
            self.pos += lit.len();
            Ok(val)
        } else {
            Err(self.err(&format!("expected '{lit}'")))
        }
    }

    fn value(&mut self) -> Result<Json, ParseError> {
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.literal("true", Json::Bool(true)),
            Some(b'f') => self.literal("false", Json::Bool(false)),
            Some(b'n') => self.literal("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(self.err("expected value")),
        }
    }

    fn object(&mut self) -> Result<Json, ParseError> {
        self.expect(b'{')?;
        let mut map = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Json::Obj(map));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            self.skip_ws();
            let val = self.value()?;
            map.insert(key, val);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.pos += 1,
                Some(b'}') => {
                    self.pos += 1;
                    return Ok(Json::Obj(map));
                }
                _ => return Err(self.err("expected ',' or '}'")),
            }
        }
    }

    fn array(&mut self) -> Result<Json, ParseError> {
        self.expect(b'[')?;
        let mut v = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Json::Arr(v));
        }
        loop {
            self.skip_ws();
            v.push(self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.pos += 1,
                Some(b']') => {
                    self.pos += 1;
                    return Ok(Json::Arr(v));
                }
                _ => return Err(self.err("expected ',' or ']'")),
            }
        }
    }

    fn string(&mut self) -> Result<String, ParseError> {
        self.expect(b'"')?;
        let mut out = String::new();
        loop {
            match self.peek() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => {
                    self.pos += 1;
                    return Ok(out);
                }
                Some(b'\\') => {
                    self.pos += 1;
                    match self.peek() {
                        Some(b'"') => out.push('"'),
                        Some(b'\\') => out.push('\\'),
                        Some(b'/') => out.push('/'),
                        Some(b'n') => out.push('\n'),
                        Some(b't') => out.push('\t'),
                        Some(b'r') => out.push('\r'),
                        Some(b'b') => out.push('\u{8}'),
                        Some(b'f') => out.push('\u{c}'),
                        Some(b'u') => {
                            let hex = self
                                .bytes
                                .get(self.pos + 1..self.pos + 5)
                                .ok_or_else(|| self.err("short \\u escape"))?;
                            let code = u32::from_str_radix(
                                std::str::from_utf8(hex)
                                    .map_err(|_| self.err("bad \\u escape"))?,
                                16,
                            )
                            .map_err(|_| self.err("bad \\u escape"))?;
                            out.push(
                                char::from_u32(code)
                                    .ok_or_else(|| self.err("bad codepoint"))?,
                            );
                            self.pos += 4;
                        }
                        _ => return Err(self.err("bad escape")),
                    }
                    self.pos += 1;
                }
                Some(_) => {
                    // Consume one UTF-8 scalar.
                    let rest = &self.bytes[self.pos..];
                    let s = std::str::from_utf8(rest)
                        .map_err(|_| self.err("invalid utf-8"))?;
                    let c = s.chars().next().unwrap();
                    out.push(c);
                    self.pos += c.len_utf8();
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json, ParseError> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
            self.pos += 1;
        }
        if self.peek() == Some(b'.') {
            self.pos += 1;
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        if matches!(self.peek(), Some(b'e' | b'E')) {
            self.pos += 1;
            if matches!(self.peek(), Some(b'+' | b'-')) {
                self.pos += 1;
            }
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        let text = std::str::from_utf8(&self.bytes[start..self.pos]).unwrap();
        text.parse::<f64>()
            .map(Json::Num)
            .map_err(|_| self.err("bad number"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_scalars() {
        for src in ["null", "true", "false", "0", "-12.5", "\"hi\""] {
            let v = Json::parse(src).unwrap();
            assert_eq!(Json::parse(&v.to_string()).unwrap(), v);
        }
    }

    #[test]
    fn parse_nested() {
        let v = Json::parse(r#"{"a": [1, 2, {"b": "c"}], "d": null}"#).unwrap();
        assert_eq!(v.path(&["a"]).unwrap().as_arr().unwrap().len(), 3);
        assert_eq!(
            v.get("a").unwrap().as_arr().unwrap()[2]
                .get("b")
                .unwrap()
                .as_str(),
            Some("c")
        );
    }

    #[test]
    fn parse_escapes() {
        let v = Json::parse(r#""a\nb\t\"q\" A""#).unwrap();
        assert_eq!(v.as_str(), Some("a\nb\t\"q\" A"));
    }

    #[test]
    fn writer_escapes() {
        let v = Json::Str("a\"b\\c\nd".into());
        assert_eq!(Json::parse(&v.to_string()).unwrap(), v);
    }

    #[test]
    fn numbers_scientific() {
        assert_eq!(Json::parse("1e3").unwrap().as_f64(), Some(1000.0));
        assert_eq!(Json::parse("-2.5E-2").unwrap().as_f64(), Some(-0.025));
    }

    #[test]
    fn rejects_trailing() {
        assert!(Json::parse("1 2").is_err());
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
    }

    #[test]
    fn typed_accessors() {
        let v = Json::parse(r#"{"n": 3, "s": "x", "b": true}"#).unwrap();
        assert_eq!(v.get("n").unwrap().as_usize(), Some(3));
        assert_eq!(v.get("s").unwrap().as_str(), Some("x"));
        assert_eq!(v.get("b").unwrap().as_bool(), Some(true));
        assert_eq!(v.get("n").unwrap().as_str(), None);
        assert_eq!(v.get("missing"), None);
    }

    #[test]
    fn real_manifest_shape() {
        let src = r#"{"model":{"layers":8,"hidden":128},
                      "artifacts":{"lm_head":{"file":"lm_head.hlo.txt",
                      "params":[{"name":"x","shape":[1,1,128],"dtype":"float32"}]}}}"#;
        let v = Json::parse(src).unwrap();
        assert_eq!(v.path(&["model", "layers"]).unwrap().as_usize(), Some(8));
        let params = v
            .path(&["artifacts", "lm_head", "params"])
            .unwrap()
            .as_arr()
            .unwrap();
        assert_eq!(params[0].get("name").unwrap().as_str(), Some("x"));
    }

    #[test]
    fn obj_builder() {
        let v = obj(&[("x", 1.0.into()), ("y", "z".into())]);
        assert_eq!(v.to_string(), r#"{"x":1,"y":"z"}"#);
    }

    #[test]
    fn stream_writer_bytes_match_display_on_equivalent_tree() {
        // The artifact-writer contract: streaming the same document must be
        // byte-identical to rendering the monolithic tree.
        let cells: Vec<Json> = (0..3)
            .map(|i| {
                obj(&[
                    ("id", (i as u64).into()),
                    ("ttft_s", (0.5 + i as f64).into()),
                    ("tag", format!("cell-{i}").into()),
                ])
            })
            .collect();
        let tree = obj(&[
            ("cells", Json::Arr(cells.clone())),
            ("count", 3u64.into()),
            ("schema", "lime-fleet-v1".into()),
        ]);

        let mut w = StreamWriter::new(Vec::new());
        w.begin_obj().unwrap();
        w.key("cells").unwrap();
        w.begin_arr().unwrap();
        for c in &cells {
            w.value(c).unwrap();
        }
        w.end().unwrap();
        w.key("count").unwrap();
        w.value(&3u64.into()).unwrap();
        w.key("schema").unwrap();
        w.value(&"lime-fleet-v1".into()).unwrap();
        w.end().unwrap();
        let bytes = w.finish().unwrap();
        let streamed = String::from_utf8(bytes).unwrap();

        assert_eq!(streamed, tree.to_string());
        assert_eq!(Json::parse(&streamed).unwrap(), tree);
    }

    #[test]
    fn stream_writer_escapes_keys_and_nested_values() {
        let mut w = StreamWriter::new(Vec::new());
        w.begin_obj().unwrap();
        w.key("a\"b").unwrap();
        w.value(&Json::Str("x\ny".into())).unwrap();
        w.end().unwrap();
        let streamed = String::from_utf8(w.finish().unwrap()).unwrap();
        let expect = Json::Obj(
            [("a\"b".to_string(), Json::Str("x\ny".into()))]
                .into_iter()
                .collect(),
        );
        assert_eq!(streamed, expect.to_string());
        assert_eq!(Json::parse(&streamed).unwrap(), expect);
    }

    #[test]
    #[should_panic(expected = "strictly ascending")]
    fn stream_writer_rejects_out_of_order_keys() {
        let mut w = StreamWriter::new(Vec::new());
        w.begin_obj().unwrap();
        w.key("b").unwrap();
        w.value(&Json::Null).unwrap();
        w.key("a").unwrap();
    }

    #[test]
    #[should_panic(expected = "unclosed containers")]
    fn stream_writer_rejects_unbalanced_finish() {
        let mut w = StreamWriter::new(Vec::new());
        w.begin_arr().unwrap();
        let _ = w.finish();
    }
}
