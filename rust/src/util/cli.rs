//! Tiny CLI argument parser substrate (`clap` is unavailable offline).
//!
//! Supports `--flag`, `--key value`, `--key=value`, and positional args.
//! Each binary declares its options up front so `--help` is generated.

use std::collections::BTreeMap;

/// Declared option.
#[derive(Debug, Clone)]
struct OptSpec {
    name: String,
    help: String,
    default: Option<String>,
    is_flag: bool,
}

/// Declarative CLI parser.
#[derive(Debug, Default)]
pub struct Cli {
    program: String,
    about: String,
    opts: Vec<OptSpec>,
}

/// Parsed arguments.
#[derive(Debug, Default)]
pub struct Args {
    values: BTreeMap<String, String>,
    flags: BTreeMap<String, bool>,
    pub positional: Vec<String>,
}

impl Cli {
    pub fn new(program: &str, about: &str) -> Self {
        Cli {
            program: program.to_string(),
            about: about.to_string(),
            opts: Vec::new(),
        }
    }

    /// Declare a `--key <value>` option with a default.
    pub fn opt(mut self, name: &str, default: &str, help: &str) -> Self {
        self.opts.push(OptSpec {
            name: name.to_string(),
            help: help.to_string(),
            default: Some(default.to_string()),
            is_flag: false,
        });
        self
    }

    /// Declare a required `--key <value>` option (no default).
    pub fn req(mut self, name: &str, help: &str) -> Self {
        self.opts.push(OptSpec {
            name: name.to_string(),
            help: help.to_string(),
            default: None,
            is_flag: false,
        });
        self
    }

    /// Declare a boolean `--flag`.
    pub fn flag(mut self, name: &str, help: &str) -> Self {
        self.opts.push(OptSpec {
            name: name.to_string(),
            help: help.to_string(),
            default: None,
            is_flag: true,
        });
        self
    }

    pub fn usage(&self) -> String {
        let mut s = format!("{} — {}\n\nOptions:\n", self.program, self.about);
        for o in &self.opts {
            let left = if o.is_flag {
                format!("  --{}", o.name)
            } else {
                format!("  --{} <v>", o.name)
            };
            let default = o
                .default
                .as_ref()
                .map(|d| format!(" [default: {d}]"))
                .unwrap_or_default();
            s.push_str(&format!("{left:28} {}{default}\n", o.help));
        }
        s
    }

    /// Parse a raw argv slice (without the program name).
    pub fn parse_from(&self, argv: &[String]) -> Result<Args, String> {
        let mut args = Args::default();
        for o in &self.opts {
            if let Some(d) = &o.default {
                args.values.insert(o.name.clone(), d.clone());
            }
            if o.is_flag {
                args.flags.insert(o.name.clone(), false);
            }
        }
        let mut it = argv.iter().peekable();
        while let Some(a) = it.next() {
            if a == "--help" || a == "-h" {
                return Err(self.usage());
            }
            if let Some(body) = a.strip_prefix("--") {
                let (name, inline) = match body.split_once('=') {
                    Some((n, v)) => (n.to_string(), Some(v.to_string())),
                    None => (body.to_string(), None),
                };
                let spec = self
                    .opts
                    .iter()
                    .find(|o| o.name == name)
                    .ok_or_else(|| format!("unknown option --{name}\n\n{}", self.usage()))?;
                if spec.is_flag {
                    if inline.is_some() {
                        return Err(format!("flag --{name} takes no value"));
                    }
                    args.flags.insert(name, true);
                } else {
                    let val = match inline {
                        Some(v) => v,
                        None => it
                            .next()
                            .ok_or_else(|| format!("option --{name} needs a value"))?
                            .clone(),
                    };
                    args.values.insert(name, val);
                }
            } else {
                args.positional.push(a.clone());
            }
        }
        for o in &self.opts {
            if !o.is_flag && !args.values.contains_key(&o.name) {
                return Err(format!("missing required option --{}\n\n{}", o.name, self.usage()));
            }
        }
        Ok(args)
    }

    /// Parse `std::env::args()`; prints usage and exits on error/--help.
    pub fn parse(&self) -> Args {
        let argv: Vec<String> = std::env::args().skip(1).collect();
        match self.parse_from(&argv) {
            Ok(a) => a,
            Err(msg) => {
                eprintln!("{msg}");
                std::process::exit(2);
            }
        }
    }
}

impl Args {
    pub fn get(&self, name: &str) -> &str {
        self.values
            .get(name)
            .unwrap_or_else(|| panic!("option --{name} was not declared"))
    }

    pub fn get_usize(&self, name: &str) -> usize {
        self.get(name)
            .parse()
            .unwrap_or_else(|_| panic!("--{name} expects an integer"))
    }

    pub fn get_u64(&self, name: &str) -> u64 {
        self.get(name)
            .parse()
            .unwrap_or_else(|_| panic!("--{name} expects an integer"))
    }

    pub fn get_f64(&self, name: &str) -> f64 {
        self.get(name)
            .parse()
            .unwrap_or_else(|_| panic!("--{name} expects a number"))
    }

    pub fn get_flag(&self, name: &str) -> bool {
        *self
            .flags
            .get(name)
            .unwrap_or_else(|| panic!("flag --{name} was not declared"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cli() -> Cli {
        Cli::new("t", "test")
            .opt("steps", "10", "steps")
            .opt("model", "tiny", "model name")
            .flag("verbose", "chatty")
            .req("out", "output path")
    }

    fn parse(args: &[&str]) -> Result<Args, String> {
        cli().parse_from(&args.iter().map(|s| s.to_string()).collect::<Vec<_>>())
    }

    #[test]
    fn defaults_apply() {
        let a = parse(&["--out", "x.json"]).unwrap();
        assert_eq!(a.get_usize("steps"), 10);
        assert_eq!(a.get("model"), "tiny");
        assert!(!a.get_flag("verbose"));
    }

    #[test]
    fn explicit_values() {
        let a = parse(&["--steps", "32", "--out=o", "--verbose", "pos1"]).unwrap();
        assert_eq!(a.get_usize("steps"), 32);
        assert_eq!(a.get("out"), "o");
        assert!(a.get_flag("verbose"));
        assert_eq!(a.positional, vec!["pos1"]);
    }

    #[test]
    fn missing_required_fails() {
        assert!(parse(&[]).is_err());
    }

    #[test]
    fn unknown_option_fails() {
        assert!(parse(&["--out", "x", "--nope", "1"]).is_err());
    }

    #[test]
    fn flag_with_value_fails() {
        assert!(parse(&["--out", "x", "--verbose=1"]).is_err());
    }

    #[test]
    fn help_returns_usage() {
        let err = parse(&["--help"]).unwrap_err();
        assert!(err.contains("--steps"));
        assert!(err.contains("output path"));
    }
}
