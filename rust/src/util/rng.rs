//! Deterministic PRNG substrate (`rand` is unavailable offline).
//!
//! `splitmix64` seeds an `xoshiro256**` generator — the standard pairing:
//! splitmix's equidistribution fixes poorly-seeded low-entropy states, and
//! xoshiro256** passes BigCrush. Everything in the simulator / workload
//! generators that needs randomness takes an explicit `Rng` so experiment
//! runs are reproducible from a single seed.

/// xoshiro256** PRNG, seeded via splitmix64.
#[derive(Debug, Clone)]
pub struct Rng {
    s: [u64; 4],
}

fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9E37_79B9_7F4A_7C15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
    z ^ (z >> 31)
}

impl Rng {
    /// Create a generator from a 64-bit seed.
    pub fn new(seed: u64) -> Self {
        let mut sm = seed;
        let s = [
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
        ];
        Rng { s }
    }

    /// Derive an independent child generator (for per-device streams).
    pub fn fork(&mut self, stream: u64) -> Rng {
        Rng::new(self.next_u64() ^ stream.wrapping_mul(0x9E37_79B9_7F4A_7C15))
    }

    /// Next raw 64-bit output.
    pub fn next_u64(&mut self) -> u64 {
        let s = &mut self.s;
        let result = s[1]
            .wrapping_mul(5)
            .rotate_left(7)
            .wrapping_mul(9);
        let t = s[1] << 17;
        s[2] ^= s[0];
        s[3] ^= s[1];
        s[1] ^= s[2];
        s[0] ^= s[3];
        s[2] ^= t;
        s[3] = s[3].rotate_left(45);
        result
    }

    /// Uniform f64 in [0, 1).
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform f64 in [lo, hi).
    pub fn range_f64(&mut self, lo: f64, hi: f64) -> f64 {
        lo + self.f64() * (hi - lo)
    }

    /// Uniform u64 in [0, n) without modulo bias (Lemire's method).
    pub fn below(&mut self, n: u64) -> u64 {
        assert!(n > 0);
        let mut x = self.next_u64();
        let mut m = (x as u128) * (n as u128);
        let mut l = m as u64;
        if l < n {
            let t = n.wrapping_neg() % n;
            while l < t {
                x = self.next_u64();
                m = (x as u128) * (n as u128);
                l = m as u64;
            }
        }
        (m >> 64) as u64
    }

    /// Uniform usize in [lo, hi).
    pub fn range(&mut self, lo: usize, hi: usize) -> usize {
        assert!(lo < hi);
        lo + self.below((hi - lo) as u64) as usize
    }

    /// Standard normal via Box–Muller.
    pub fn normal(&mut self) -> f64 {
        let u1 = self.f64().max(1e-300);
        let u2 = self.f64();
        (-2.0 * u1.ln()).sqrt() * (std::f64::consts::TAU * u2).cos()
    }

    /// Exponential with rate `lambda` (mean 1/lambda): Poisson inter-arrivals.
    pub fn exponential(&mut self, lambda: f64) -> f64 {
        assert!(lambda > 0.0);
        -self.f64().max(1e-300).ln() / lambda
    }

    /// Bernoulli trial.
    pub fn chance(&mut self, p: f64) -> bool {
        self.f64() < p
    }

    /// Zipf-distributed integer in `[1, n]` with `P(k) ∝ k^{-s}`, via
    /// Hörmann–Derflinger rejection-inversion: O(1) expected draws, no
    /// precomputed table, so the session-id sampler stays cheap at 10^6
    /// requests. `s` must be finite and positive; `s > 1` concentrates
    /// mass on the head (hot sessions), `s < 1` flattens the tail.
    pub fn zipf(&mut self, n: u64, s: f64) -> u64 {
        assert!(n >= 1, "zipf support must be non-empty");
        assert!(s.is_finite() && s > 0.0, "zipf exponent must be positive");
        if n == 1 {
            return 1;
        }
        // H is (a shifted antiderivative of) the hull x^{-s}; H_inv inverts it.
        let h = |x: f64| (-s * x.ln()).exp();
        let big_h = |x: f64| {
            if s == 1.0 {
                x.ln()
            } else {
                (x.powf(1.0 - s) - 1.0) / (1.0 - s)
            }
        };
        let big_h_inv = |y: f64| {
            if s == 1.0 {
                y.exp()
            } else {
                (1.0 + y * (1.0 - s)).powf(1.0 / (1.0 - s))
            }
        };
        let h_x1 = big_h(1.5) - 1.0; // H(1.5) − h(1), h(1) = 1
        let h_n = big_h(n as f64 + 0.5);
        let guard = 2.0 - big_h_inv(big_h(2.5) - h(2.0));
        loop {
            let u = h_n + self.f64() * (h_x1 - h_n);
            let x = big_h_inv(u);
            let k = x.round().clamp(1.0, n as f64);
            if k - x <= guard || u >= big_h(k + 0.5) - h(k) {
                return k as u64;
            }
        }
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below((i + 1) as u64) as usize;
            xs.swap(i, j);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_by_seed() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_differ() {
        let mut a = Rng::new(1);
        let mut b = Rng::new(2);
        assert_ne!(
            (0..8).map(|_| a.next_u64()).collect::<Vec<_>>(),
            (0..8).map(|_| b.next_u64()).collect::<Vec<_>>()
        );
    }

    #[test]
    fn f64_in_unit_interval() {
        let mut r = Rng::new(7);
        for _ in 0..10_000 {
            let x = r.f64();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn below_is_unbiased_enough() {
        let mut r = Rng::new(3);
        let mut counts = [0usize; 5];
        for _ in 0..50_000 {
            counts[r.below(5) as usize] += 1;
        }
        for &c in &counts {
            assert!((9_000..11_000).contains(&c), "count {c}");
        }
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(9);
        let n = 100_000;
        let xs: Vec<f64> = (0..n).map(|_| r.normal()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.02, "mean {mean}");
        assert!((var - 1.0).abs() < 0.05, "var {var}");
    }

    #[test]
    fn exponential_mean() {
        let mut r = Rng::new(11);
        let n = 100_000;
        let mean = (0..n).map(|_| r.exponential(2.0)).sum::<f64>() / n as f64;
        assert!((mean - 0.5).abs() < 0.02, "mean {mean}");
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(5);
        let mut xs: Vec<u32> = (0..50).collect();
        r.shuffle(&mut xs);
        let mut sorted = xs.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..50).collect::<Vec<_>>());
    }

    #[test]
    fn zipf_is_deterministic_and_in_range() {
        let mut a = Rng::new(21);
        let mut b = Rng::new(21);
        for _ in 0..5_000 {
            let x = a.zipf(1000, 1.1);
            assert_eq!(x, b.zipf(1000, 1.1));
            assert!((1..=1000).contains(&x));
        }
    }

    #[test]
    fn zipf_head_is_hot() {
        // P(1) ∝ 1, P(2) ∝ 2^{-1.2}: rank 1 must dominate rank 2, and the
        // top-10 ranks must hold a large share of the mass.
        let mut r = Rng::new(17);
        let n = 50_000;
        let mut counts = vec![0usize; 1001];
        for _ in 0..n {
            counts[r.zipf(1000, 1.2) as usize] += 1;
        }
        assert!(counts[1] > counts[2], "{} vs {}", counts[1], counts[2]);
        assert!(counts[2] > counts[10], "{} vs {}", counts[2], counts[10]);
        let head: usize = counts[1..=10].iter().sum();
        assert!(head * 2 > n, "top-10 share too small: {head}/{n}");
    }

    #[test]
    fn zipf_exponent_one_uses_log_branch() {
        let mut r = Rng::new(29);
        for _ in 0..2_000 {
            let x = r.zipf(64, 1.0);
            assert!((1..=64).contains(&x));
        }
    }

    #[test]
    fn zipf_singleton_support() {
        let mut r = Rng::new(1);
        assert_eq!(r.zipf(1, 1.5), 1);
    }

    #[test]
    fn fork_streams_independent() {
        let mut root = Rng::new(1);
        let mut a = root.fork(0);
        let mut b = root.fork(1);
        assert_ne!(a.next_u64(), b.next_u64());
    }
}
