//! Scoped-thread fan-out for embarrassingly parallel sweeps (the offline
//! scheduler's `#Seg` candidates, the experiment harness's cell grids).
//!
//! No thread pool or external crates: `std::thread::scope` workers write
//! results *by index* into disjoint chunks of the output, so the caller
//! observes exactly the sequential order — parallelism never changes which
//! plan wins a tie or how a grid is printed.

thread_local! {
    /// Set for the lifetime of a [`par_map_indexed`] worker thread, so
    /// nested sweeps (a grid cell calling `plan()`, which fans out again)
    /// fall back to sequential instead of multiplying OS threads.
    static IN_PARALLEL_WORKER: std::cell::Cell<bool> = const { std::cell::Cell::new(false) };
}

/// Worker-thread count: 1 inside a [`par_map_indexed`] worker (nested
/// fan-out would oversubscribe), else the `LIME_THREADS` env override, else
/// the machine's available parallelism.
pub fn default_threads() -> usize {
    if IN_PARALLEL_WORKER.with(|flag| flag.get()) {
        return 1;
    }
    if let Ok(v) = std::env::var("LIME_THREADS") {
        if let Ok(n) = v.trim().parse::<usize>() {
            if n >= 1 {
                return n;
            }
        }
    }
    std::thread::available_parallelism()
        .map(|n| n.get())
        .unwrap_or(1)
}

/// Apply `f` to every job and return results in job order.
///
/// Workers claim jobs dynamically from a shared atomic cursor (cheap jobs
/// don't strand a worker while another serializes all the expensive ones —
/// experiment grids mix both by orders of magnitude) and send `(index,
/// result)` back; results are placed by index, so the output is
/// bit-identical to the sequential `jobs.iter().map(f)` loop regardless of
/// `threads` or scheduling (tested against thread counts 1, 2 and 8).
pub fn par_map_indexed<J, T>(
    threads: usize,
    jobs: &[J],
    f: impl Fn(&J) -> T + Sync,
) -> Vec<T>
where
    J: Sync,
    T: Send,
{
    if jobs.is_empty() {
        return Vec::new();
    }
    let threads = threads.clamp(1, jobs.len());
    let mut out: Vec<Option<T>> = Vec::with_capacity(jobs.len());
    out.resize_with(jobs.len(), || None);
    if threads <= 1 {
        for (slot, job) in out.iter_mut().zip(jobs) {
            *slot = Some(f(job));
        }
    } else {
        let next = std::sync::atomic::AtomicUsize::new(0);
        let (tx, rx) = std::sync::mpsc::channel::<(usize, T)>();
        let f = &f;
        let next = &next;
        std::thread::scope(|scope| {
            for _ in 0..threads {
                let tx = tx.clone();
                scope.spawn(move || {
                    IN_PARALLEL_WORKER.with(|flag| flag.set(true));
                    loop {
                        let i = next.fetch_add(1, std::sync::atomic::Ordering::Relaxed);
                        if i >= jobs.len() {
                            break;
                        }
                        if tx.send((i, f(&jobs[i]))).is_err() {
                            break;
                        }
                    }
                });
            }
            drop(tx); // workers hold the remaining senders
        });
        // The scope joined every worker, so the channel is closed and this
        // drains without blocking.
        for (i, result) in rx {
            out[i] = Some(result);
        }
    }
    out.into_iter()
        .map(|slot| slot.expect("every job index was claimed exactly once"))
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn preserves_job_order() {
        let jobs: Vec<usize> = (0..100).collect();
        let seq = par_map_indexed(1, &jobs, |&x| x * x);
        let par = par_map_indexed(4, &jobs, |&x| x * x);
        assert_eq!(seq, par);
        assert_eq!(seq[7], 49);
    }

    #[test]
    fn more_threads_than_jobs_is_fine() {
        let jobs = vec![1, 2, 3];
        assert_eq!(par_map_indexed(64, &jobs, |&x| x + 1), vec![2, 3, 4]);
    }

    #[test]
    fn empty_jobs_yield_empty() {
        let jobs: Vec<u32> = Vec::new();
        assert!(par_map_indexed(8, &jobs, |&x| x).is_empty());
    }

    #[test]
    fn zero_threads_clamps_to_one() {
        let jobs = vec![5];
        assert_eq!(par_map_indexed(0, &jobs, |&x| x * 2), vec![10]);
    }

    #[test]
    fn default_threads_positive() {
        assert!(default_threads() >= 1);
    }

    #[test]
    fn nested_fanout_is_capped_to_sequential() {
        // Inside a worker, default_threads() must report 1 so nested
        // sweeps (grid cell -> plan()) don't multiply OS threads.
        let jobs = vec![(); 4];
        let seen = par_map_indexed(4, &jobs, |_| default_threads());
        assert!(seen.iter().all(|&n| n == 1), "{seen:?}");
    }

    #[test]
    fn results_actually_come_from_workers() {
        // Heavier fan-out: every index mapped exactly once.
        let jobs: Vec<usize> = (0..1000).collect();
        let got = par_map_indexed(8, &jobs, |&x| x);
        assert_eq!(got, jobs);
    }
}
