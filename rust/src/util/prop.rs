//! Mini property-based testing substrate (`proptest` is unavailable offline).
//!
//! A property runs many times against values drawn from a [`Gen`]; on
//! failure the framework greedily shrinks the failing case (halving
//! integers, shortening vectors) and reports the minimal counterexample
//! together with the reproducing seed.

use super::rng::Rng;

/// A generator of values of type `T` plus a shrinker.
pub struct Gen<T> {
    gen: Box<dyn Fn(&mut Rng) -> T>,
    shrink: Box<dyn Fn(&T) -> Vec<T>>,
}

impl<T: Clone + 'static> Gen<T> {
    pub fn new(
        gen: impl Fn(&mut Rng) -> T + 'static,
        shrink: impl Fn(&T) -> Vec<T> + 'static,
    ) -> Self {
        Gen {
            gen: Box::new(gen),
            shrink: Box::new(shrink),
        }
    }

    /// Generator with no shrinking.
    pub fn plain(gen: impl Fn(&mut Rng) -> T + 'static) -> Self {
        Gen::new(gen, |_| Vec::new())
    }

    pub fn sample(&self, rng: &mut Rng) -> T {
        (self.gen)(rng)
    }

    pub fn shrinks(&self, v: &T) -> Vec<T> {
        (self.shrink)(v)
    }

    /// Map the generated value (loses shrinking of the mapped domain).
    pub fn map<U: Clone + 'static>(self, f: impl Fn(T) -> U + 'static) -> Gen<U> {
        Gen::plain(move |rng| f((self.gen)(rng)))
    }
}

/// usize in [lo, hi], shrinking toward lo.
pub fn usize_in(lo: usize, hi: usize) -> Gen<usize> {
    assert!(lo <= hi);
    Gen::new(
        move |rng| rng.range(lo, hi + 1),
        move |&v| {
            let mut out = Vec::new();
            if v > lo {
                out.push(lo);
                out.push(lo + (v - lo) / 2);
                out.push(v - 1);
            }
            out.dedup();
            out
        },
    )
}

/// f64 in [lo, hi), shrinking toward lo.
pub fn f64_in(lo: f64, hi: f64) -> Gen<f64> {
    Gen::new(
        move |rng| rng.range_f64(lo, hi),
        move |&v| {
            if v > lo + 1e-9 {
                vec![lo, lo + (v - lo) / 2.0]
            } else {
                Vec::new()
            }
        },
    )
}

/// Vec of `inner` with length in [min_len, max_len]; shrinks by dropping
/// elements and shrinking individual elements.
pub fn vec_of<T: Clone + 'static>(
    inner: Gen<T>,
    min_len: usize,
    max_len: usize,
) -> Gen<Vec<T>> {
    assert!(min_len <= max_len);
    let inner = std::rc::Rc::new(inner);
    let g = inner.clone();
    Gen::new(
        move |rng| {
            let len = rng.range(min_len, max_len + 1);
            (0..len).map(|_| g.sample(rng)).collect()
        },
        move |v: &Vec<T>| {
            let mut out = Vec::new();
            if v.len() > min_len {
                // Drop one element at a few positions.
                for i in [0, v.len() / 2, v.len() - 1] {
                    let mut shorter = v.clone();
                    shorter.remove(i.min(shorter.len() - 1));
                    out.push(shorter);
                }
            }
            // Shrink each element individually (first few positions).
            for i in 0..v.len().min(4) {
                for cand in inner.shrinks(&v[i]) {
                    let mut copy = v.clone();
                    copy[i] = cand;
                    out.push(copy);
                }
            }
            out
        },
    )
}

/// Pair generator.
pub fn pair<A: Clone + 'static, B: Clone + 'static>(a: Gen<A>, b: Gen<B>) -> Gen<(A, B)> {
    let a = std::rc::Rc::new(a);
    let b = std::rc::Rc::new(b);
    let (ga, gb) = (a.clone(), b.clone());
    Gen::new(
        move |rng| (ga.sample(rng), gb.sample(rng)),
        move |(x, y)| {
            let mut out: Vec<(A, B)> = Vec::new();
            for xs in a.shrinks(x) {
                out.push((xs, y.clone()));
            }
            for ys in b.shrinks(y) {
                out.push((x.clone(), ys));
            }
            out
        },
    )
}

/// Outcome of a property check.
#[derive(Debug)]
pub enum PropResult<T> {
    Pass { cases: usize },
    Fail { minimal: T, seed: u64, message: String },
}

/// Configuration for [`check`].
pub struct Config {
    pub cases: usize,
    pub seed: u64,
    pub max_shrink_steps: usize,
}

impl Default for Config {
    fn default() -> Self {
        Config {
            cases: 100,
            seed: 0x11E5_11E5,
            max_shrink_steps: 500,
        }
    }
}

/// Run `prop` against `cases` samples; shrink on failure.
/// `prop` returns Ok(()) or Err(description).
pub fn check<T: Clone + std::fmt::Debug + 'static>(
    cfg: &Config,
    gen: &Gen<T>,
    prop: impl Fn(&T) -> Result<(), String>,
) -> PropResult<T> {
    let mut rng = Rng::new(cfg.seed);
    for case in 0..cfg.cases {
        let value = gen.sample(&mut rng);
        if let Err(first_msg) = prop(&value) {
            // Greedy shrink.
            let mut best = value;
            let mut best_msg = first_msg;
            let mut steps = 0;
            'outer: while steps < cfg.max_shrink_steps {
                for cand in gen.shrinks(&best) {
                    steps += 1;
                    if let Err(msg) = prop(&cand) {
                        best = cand;
                        best_msg = msg;
                        continue 'outer;
                    }
                    if steps >= cfg.max_shrink_steps {
                        break;
                    }
                }
                break;
            }
            let _ = case;
            return PropResult::Fail {
                minimal: best,
                seed: cfg.seed,
                message: best_msg,
            };
        }
    }
    PropResult::Pass { cases: cfg.cases }
}

/// Assert helper: panics with the minimal counterexample on failure.
pub fn assert_prop<T: Clone + std::fmt::Debug + 'static>(
    name: &str,
    gen: &Gen<T>,
    prop: impl Fn(&T) -> Result<(), String>,
) {
    let cfg = Config::default();
    match check(&cfg, gen, prop) {
        PropResult::Pass { .. } => {}
        PropResult::Fail {
            minimal,
            seed,
            message,
        } => panic!(
            "property '{name}' failed (seed {seed}):\n  minimal counterexample: {minimal:?}\n  {message}"
        ),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_passes() {
        let gen = usize_in(0, 100);
        match check(&Config::default(), &gen, |&x| {
            if x <= 100 {
                Ok(())
            } else {
                Err("out of range".into())
            }
        }) {
            PropResult::Pass { cases } => assert_eq!(cases, 100),
            PropResult::Fail { .. } => panic!("should pass"),
        }
    }

    #[test]
    fn shrinks_to_minimal_int() {
        let gen = usize_in(0, 1000);
        match check(&Config::default(), &gen, |&x| {
            if x < 37 {
                Ok(())
            } else {
                Err(format!("{x} >= 37"))
            }
        }) {
            PropResult::Fail { minimal, .. } => assert_eq!(minimal, 37),
            PropResult::Pass { .. } => panic!("should fail"),
        }
    }

    #[test]
    fn shrinks_vec_length() {
        let gen = vec_of(usize_in(0, 9), 0, 50);
        match check(&Config::default(), &gen, |v: &Vec<usize>| {
            if v.len() < 3 {
                Ok(())
            } else {
                Err("too long".into())
            }
        }) {
            PropResult::Fail { minimal, .. } => assert_eq!(minimal.len(), 3),
            PropResult::Pass { .. } => panic!("should fail"),
        }
    }

    #[test]
    fn pair_shrinks_both_sides() {
        let gen = pair(usize_in(0, 100), usize_in(0, 100));
        match check(&Config::default(), &gen, |&(a, b)| {
            if a + b < 20 {
                Ok(())
            } else {
                Err("sum too big".into())
            }
        }) {
            PropResult::Fail { minimal: (a, b), .. } => {
                assert_eq!(a + b, 20, "minimal should sit on the boundary");
            }
            PropResult::Pass { .. } => panic!("should fail"),
        }
    }

    #[test]
    fn deterministic_given_seed() {
        let cfg = Config {
            cases: 50,
            seed: 99,
            max_shrink_steps: 100,
        };
        let gen = usize_in(0, 10_000);
        let run = || match check(&cfg, &gen, |&x| {
            if x % 97 != 13 {
                Ok(())
            } else {
                Err("hit".into())
            }
        }) {
            PropResult::Fail { minimal, .. } => Some(minimal),
            PropResult::Pass { .. } => None,
        };
        assert_eq!(run(), run());
    }
}
