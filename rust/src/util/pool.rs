//! Persistent work-stealing executor for the sweep fan-outs (the offline
//! scheduler's `#Seg` candidates, the experiment harness's grid cells, the
//! executors' scenario sweeps).
//!
//! PR 1's `util::threads::par_map_indexed` (now retired) spawned fresh
//! scoped threads on every call and forced nested fan-out (grid cell →
//! `plan()` candidates) to degrade to sequential so OS threads would not
//! multiply. This module replaces that substrate with a persistent pool,
//! std-only like everything else in the crate:
//!
//! * **One lazily-initialized global worker set** ([`global`]), sized by
//!   the `LIME_THREADS` env override (CI pins it for stable timings) or the
//!   machine's `available_parallelism`. Workers are spawned once and reused
//!   across every sweep in the process.
//! * **Lock-free per-worker deques (Chase–Lev), steal-half, longest
//!   victim first.** Each worker owns a bounded Chase–Lev deque built
//!   from std atomics only: the owner pushes and pops at the *bottom*
//!   (newest first — nested jobs run with hot caches) without taking any
//!   lock, and thieves CAS the *top* cursor to claim the oldest task. The
//!   `bottom − top` cursor distance doubles as the length mirror the old
//!   mutexed deques kept separately, so the longest-victim scan stays
//!   allocation- and lock-free; a thief still steals up to *half* of the
//!   longest deque (repeated single-task claims re-homed onto its own
//!   deque), so a skewed burst of jobs spreads in O(log n) steal rounds
//!   instead of bleeding one neighbour dry in fixed cyclic order. A full
//!   deque spills to the (mutexed, unbounded, cold-path) injector queue;
//!   the monotonic top cursor rules out ABA, and a raced-to-empty victim
//!   triggers a rescan exactly like the old under-lock re-check did.
//! * **Nested job submission.** [`Pool::map_indexed`] called from inside a
//!   pool job pushes the sub-jobs onto the calling worker's own deque and
//!   the worker *helps* (executes pool jobs) while it waits for its
//!   sub-results — a grid cell running on a worker fans its `#Seg`
//!   candidates back into the same pool instead of running them
//!   sequentially. External callers help through the shared injector
//!   queue, whose batches are pushed to the *front* so a helping thread's
//!   nested fan-out likewise runs its own sub-jobs before older unrelated
//!   jobs (depth-first, bounded helper stack).
//!
//! **Determinism contract:** `map_indexed` places results by job index and
//! callers reduce in submission order, so the output is bit-identical to
//! the sequential `jobs.iter().map(f)` loop at any worker count, under any
//! steal interleaving, and under nested submission (property-tested in
//! `rust/tests/pool.rs` at 1, 2 and 8 workers).
//!
//! **Panic containment:** a panicking job never kills a pool worker. The
//! panic payload is carried back to the `map_indexed` call that submitted
//! the job and re-raised there (lowest job index wins when several jobs
//! panic) — after every sibling job of the call has finished, so borrows
//! stay sound. The pool itself stays healthy and later calls proceed.

use std::collections::VecDeque;
use std::panic::{catch_unwind, resume_unwind, AssertUnwindSafe};
use std::sync::atomic::{fence, AtomicBool, AtomicIsize, AtomicPtr, AtomicUsize, Ordering};
use std::sync::mpsc::TryRecvError;
use std::sync::{Arc, Condvar, Mutex, OnceLock};
use std::time::Duration;

/// A lifetime-erased unit of work. Every task created by `map_indexed`
/// catches its own panics, so running one never unwinds into the worker.
type Task = Box<dyn FnOnce() + Send + 'static>;

/// How long a worker (or a helping caller) sleeps when no task and no
/// result is available. A wakeup is normally delivered through the condvar
/// (or the result channel) — the timeout only bounds the cost of a missed
/// wakeup.
const IDLE_WAIT: Duration = Duration::from_millis(10);
const HELP_WAIT: Duration = Duration::from_micros(200);

thread_local! {
    /// `(pool id, worker index)` when the current thread is a pool worker.
    static WORKER: std::cell::Cell<Option<(usize, usize)>> =
        const { std::cell::Cell::new(None) };
}

/// Globally unique pool ids so a worker of one pool is treated as an
/// external caller by every other pool.
static NEXT_POOL_ID: AtomicUsize = AtomicUsize::new(0);

/// Per-worker deque capacity. A power of two so ring indexing is a mask.
/// Batches larger than this spill their overflow to the injector (cold
/// path, unbounded); the big fan-outs — grid cells, fleet shards — sit
/// comfortably under it per worker.
const DEQUE_CAP: usize = 1024;

/// A task travels through the lock-free deque as a *thin* raw pointer:
/// `Task` is a fat `Box<dyn FnOnce()>`, so it is boxed once more and the
/// outer pointer is what the `AtomicPtr` slots carry.
type TaskPtr = *mut Task;

fn task_into_ptr(t: Task) -> TaskPtr {
    Box::into_raw(Box::new(t))
}

/// SAFETY: `p` must come from [`task_into_ptr`] and ownership must have
/// been transferred to the caller (a successful pop/steal, or `&mut`
/// drain in `Drop`).
unsafe fn task_from_ptr(p: TaskPtr) -> Task {
    *Box::from_raw(p)
}

enum Steal {
    /// The thief owns the task behind this pointer.
    Taken(TaskPtr),
    Empty,
    /// Lost the top-cursor CAS to another thief (or the owner's last-task
    /// pop) — the deque made progress, re-decide.
    Retry,
}

/// One worker's bounded lock-free deque — the C11 Chase–Lev design on std
/// atomics. The single OWNER thread pushes and pops at `bottom`; any
/// number of THIEVES claim the oldest task by CAS-ing `top` forward.
/// `top` only ever increases, so a stale thief loses its CAS instead of
/// resurrecting a recycled slot (no ABA), and the `bottom − top` distance
/// is the lock-free length mirror the victim-selection scan reads.
struct Deque {
    /// Thief end. Monotonically increasing.
    top: AtomicIsize,
    /// Owner end. Only the owner stores to it (thieves just read).
    bottom: AtomicIsize,
    slots: Box<[AtomicPtr<Task>]>,
}

impl Deque {
    fn new() -> Deque {
        Deque {
            top: AtomicIsize::new(0),
            bottom: AtomicIsize::new(0),
            slots: (0..DEQUE_CAP)
                .map(|_| AtomicPtr::new(std::ptr::null_mut()))
                .collect(),
        }
    }

    /// Snapshot length — exact for the owner, a heuristic for thieves
    /// (the victim may race to empty before the steal lands, which the
    /// caller handles by rescanning).
    fn len(&self) -> usize {
        let b = self.bottom.load(Ordering::Relaxed);
        let t = self.top.load(Ordering::Relaxed);
        (b - t).max(0) as usize
    }

    /// Owner-only. `Err` returns the task when the ring is full.
    fn push(&self, task: TaskPtr) -> Result<(), TaskPtr> {
        let b = self.bottom.load(Ordering::Relaxed);
        let t = self.top.load(Ordering::Acquire);
        if b - t >= DEQUE_CAP as isize {
            return Err(task);
        }
        self.slots[(b as usize) & (DEQUE_CAP - 1)].store(task, Ordering::Relaxed);
        // Publish the slot before the new bottom becomes visible to
        // thieves.
        fence(Ordering::Release);
        self.bottom.store(b + 1, Ordering::Relaxed);
        Ok(())
    }

    /// Owner-only LIFO pop from the bottom.
    fn pop(&self) -> Option<TaskPtr> {
        let b = self.bottom.load(Ordering::Relaxed) - 1;
        self.bottom.store(b, Ordering::Relaxed);
        // The store above must be ordered before the top load: it is what
        // makes a concurrent thief's CAS race *visible* as a race.
        fence(Ordering::SeqCst);
        let t = self.top.load(Ordering::Relaxed);
        if t <= b {
            let p = self.slots[(b as usize) & (DEQUE_CAP - 1)].load(Ordering::Relaxed);
            if t == b {
                // Last task: the owner races thieves for it via the same
                // top CAS a thief would use.
                let won = self
                    .top
                    .compare_exchange(t, t + 1, Ordering::SeqCst, Ordering::Relaxed)
                    .is_ok();
                self.bottom.store(b + 1, Ordering::Relaxed);
                if won {
                    Some(p)
                } else {
                    None
                }
            } else {
                Some(p)
            }
        } else {
            // Already empty: restore bottom.
            self.bottom.store(b + 1, Ordering::Relaxed);
            None
        }
    }

    /// Any-thread FIFO steal from the top.
    fn steal(&self) -> Steal {
        let t = self.top.load(Ordering::Acquire);
        fence(Ordering::SeqCst);
        let b = self.bottom.load(Ordering::Acquire);
        if t < b {
            let p = self.slots[(t as usize) & (DEQUE_CAP - 1)].load(Ordering::Relaxed);
            // The slot read may be stale if the owner wrapped the ring —
            // but wrapping slot `t` requires `top > t` (the push full-check
            // reads `top`), so this CAS fails and the stale read is
            // discarded.
            if self
                .top
                .compare_exchange(t, t + 1, Ordering::SeqCst, Ordering::Relaxed)
                .is_ok()
            {
                Steal::Taken(p)
            } else {
                Steal::Retry
            }
        } else {
            Steal::Empty
        }
    }
}

impl Drop for Deque {
    fn drop(&mut self) {
        // `&mut self`: no concurrent owner or thieves. Free any tasks a
        // shutdown stranded in the ring.
        while let Some(p) = self.pop() {
            // SAFETY: a successful pop transfers ownership; the pointer
            // came from `task_into_ptr`.
            drop(unsafe { task_from_ptr(p) });
        }
    }
}

struct Shared {
    pool_id: usize,
    /// FIFO queue for jobs submitted from threads outside this pool.
    injector: Mutex<VecDeque<Task>>,
    /// Per-worker lock-free Chase–Lev deques: the owner pushes/pops the
    /// bottom (LIFO), thieves CAS the oldest half off the top.
    deques: Vec<Deque>,
    /// Sleep coordination: submissions bump `epoch` and notify; a worker
    /// re-checks `epoch` under the lock before sleeping, so a submission
    /// between its (lock-free) scan and its wait cannot be lost.
    idle_lock: Mutex<()>,
    idle_cv: Condvar,
    epoch: AtomicUsize,
    shutdown: AtomicBool,
}

impl Shared {
    /// Pull one runnable task: own deque (lock-free LIFO pop), then the
    /// injector, then steal-half from a sibling — preferring the victim
    /// with the *longest* deque. `me` is the calling worker's index in
    /// *this* pool, or `None` for an external helper.
    fn find_task(&self, me: Option<usize>) -> Option<Task> {
        if let Some(i) = me {
            if let Some(p) = self.deques[i].pop() {
                // SAFETY: a successful pop transfers ownership.
                return Some(unsafe { task_from_ptr(p) });
            }
        }
        if let Some(t) = self.injector.lock().unwrap().pop_front() {
            return Some(t);
        }
        // Victim selection by deque length: one allocation-free,
        // lock-free max-tracking scan over the cursor-derived lengths,
        // then steal half of the LONGEST deque via repeated lock-free
        // single-task claims. That balances a skewed burst in fewer steal
        // rounds than fixed cyclic order, which repeatedly bled the same
        // neighbour dry one steal at a time. The snapshot may be stale by
        // the time the first CAS lands, so a raced-to-empty (or CAS-lost)
        // victim triggers a rescan. Results are still placed by job
        // index, so victim order never affects any `map_indexed` output
        // (the determinism contract).
        let n = self.deques.len();
        loop {
            let mut best: Option<(usize, usize)> = None; // (len, index)
            for v in 0..n {
                if Some(v) == me {
                    continue;
                }
                let len = self.deques[v].len();
                // `map_or` (not 1.82's `is_none_or`): the crate's MSRV
                // is 1.75 (see rust/Cargo.toml).
                if len > 0 && best.map_or(true, |(best_len, _)| len > best_len) {
                    best = Some((len, v));
                }
            }
            let Some((len, v)) = best else {
                return None;
            };
            let victim = &self.deques[v];
            let take = len.div_ceil(2);
            let first = match victim.steal() {
                Steal::Taken(p) => p,
                Steal::Empty | Steal::Retry => continue, // raced: rescan
            };
            // Steal-half: claim up to `take − 1` more tasks and re-home
            // them where the caller can pop them (or where other idle
            // workers will find them), then wake a sleeper.
            let mut moved = false;
            match me {
                Some(i) => {
                    let own = &self.deques[i];
                    for _ in 1..take {
                        match victim.steal() {
                            Steal::Taken(p) => {
                                moved = true;
                                if let Err(p) = own.push(p) {
                                    // Own ring full — spill to the
                                    // injector instead of dropping work.
                                    // SAFETY: the failed push returned
                                    // ownership of the stolen task.
                                    let t = unsafe { task_from_ptr(p) };
                                    self.injector.lock().unwrap().push_back(t);
                                }
                            }
                            Steal::Empty | Steal::Retry => break,
                        }
                    }
                }
                None => {
                    let mut surplus: Vec<Task> = Vec::new();
                    for _ in 1..take {
                        match victim.steal() {
                            // SAFETY: a successful steal transfers
                            // ownership.
                            Steal::Taken(p) => surplus.push(unsafe { task_from_ptr(p) }),
                            Steal::Empty | Steal::Retry => break,
                        }
                    }
                    if !surplus.is_empty() {
                        moved = true;
                        let mut inj = self.injector.lock().unwrap();
                        for t in surplus {
                            inj.push_back(t);
                        }
                    }
                }
            }
            if moved {
                self.notify();
            }
            // SAFETY: the successful first steal transferred ownership.
            return Some(unsafe { task_from_ptr(first) });
        }
    }

    fn notify(&self) {
        self.epoch.fetch_add(1, Ordering::SeqCst);
        let _guard = self.idle_lock.lock().unwrap();
        self.idle_cv.notify_all();
    }
}

fn worker_loop(shared: Arc<Shared>, index: usize) {
    WORKER.with(|w| w.set(Some((shared.pool_id, index))));
    loop {
        if shared.shutdown.load(Ordering::SeqCst) {
            return;
        }
        // Sample the epoch BEFORE scanning: a submission that lands after
        // the (empty) scan bumps the epoch, so the re-check under the lock
        // below catches it and the worker rescans instead of sleeping.
        let seen = shared.epoch.load(Ordering::SeqCst);
        if let Some(task) = shared.find_task(Some(index)) {
            task();
            continue;
        }
        let guard = shared.idle_lock.lock().unwrap();
        if shared.epoch.load(Ordering::SeqCst) != seen
            || shared.shutdown.load(Ordering::SeqCst)
        {
            continue; // something arrived between the scan and the lock
        }
        let _ = shared.idle_cv.wait_timeout(guard, IDLE_WAIT).unwrap();
    }
}

/// A persistent worker set. Most code uses the process-wide [`global`]
/// pool; tests and the sequential-reference paths build dedicated pools
/// with explicit worker counts.
pub struct Pool {
    shared: Arc<Shared>,
    workers: usize,
    handles: Mutex<Vec<std::thread::JoinHandle<()>>>,
}

impl Pool {
    /// Spawn a pool with `workers` threads (clamped to at least 1).
    pub fn new(workers: usize) -> Pool {
        let workers = workers.max(1);
        let shared = Arc::new(Shared {
            pool_id: NEXT_POOL_ID.fetch_add(1, Ordering::Relaxed),
            injector: Mutex::new(VecDeque::new()),
            deques: (0..workers).map(|_| Deque::new()).collect(),
            idle_lock: Mutex::new(()),
            idle_cv: Condvar::new(),
            epoch: AtomicUsize::new(0),
            shutdown: AtomicBool::new(false),
        });
        let handles = (0..workers)
            .map(|i| {
                let shared = shared.clone();
                std::thread::Builder::new()
                    .name(format!("lime-pool-{i}"))
                    .spawn(move || worker_loop(shared, i))
                    .expect("spawning pool worker")
            })
            .collect();
        Pool {
            shared,
            workers,
            handles: Mutex::new(handles),
        }
    }

    /// Worker-thread count (excludes helping callers).
    pub fn workers(&self) -> usize {
        self.workers
    }

    /// The calling thread's worker index in this pool, or `None` when the
    /// caller is external (including workers of *other* pools).
    fn me(&self) -> Option<usize> {
        WORKER.with(|w| match w.get() {
            Some((pool, idx)) if pool == self.shared.pool_id => Some(idx),
            _ => None,
        })
    }

    /// Enqueue a whole call's jobs under ONE lock acquisition and wake the
    /// workers once — n separate submits would broadcast n times and take
    /// 2n mutex acquisitions before the first result is drained.
    ///
    /// Worker callers push onto their own deque (popped LIFO). External
    /// callers push onto the injector's FRONT, keeping in-batch order:
    /// newest batch first makes a *nested* external call (a helping thread
    /// executing a job inline that fans out again) find its own sub-jobs
    /// before older unrelated jobs — without this, the helper would
    /// recursively execute every pending top-level job while waiting
    /// (stack depth growing with the grid size) instead of descending into
    /// its own fan-out. Relative order between separate calls carries no
    /// meaning: each call's results are placed by its own job indices.
    fn submit_batch(&self, tasks: Vec<Task>) {
        match self.me() {
            Some(i) => {
                // Lock-free pushes onto the calling worker's own deque;
                // overflow past the ring capacity spills to the injector
                // in one lock acquisition (cold path — only batches wider
                // than DEQUE_CAP per worker reach it).
                let own = &self.shared.deques[i];
                let mut spill: Vec<Task> = Vec::new();
                for t in tasks {
                    if let Err(p) = own.push(task_into_ptr(t)) {
                        // SAFETY: the failed push returned ownership.
                        spill.push(unsafe { task_from_ptr(p) });
                    }
                }
                if !spill.is_empty() {
                    let mut inj = self.shared.injector.lock().unwrap();
                    for t in spill {
                        inj.push_back(t);
                    }
                }
            }
            None => {
                let mut inj = self.shared.injector.lock().unwrap();
                for t in tasks.into_iter().rev() {
                    inj.push_front(t);
                }
            }
        }
        self.shared.notify();
    }

    /// Apply `f` to every job and return results in job order.
    ///
    /// Bit-identical to `jobs.iter().map(f).collect()` regardless of the
    /// worker count or steal schedule: workers claim jobs in any order but
    /// results are placed by index. Callable from anywhere — including from
    /// inside a pool job, in which case the sub-jobs go onto the calling
    /// worker's own deque and the worker executes pool work while waiting
    /// (nested submission never degrades to sequential and never
    /// deadlocks). If a job panics, the panic resurfaces here after every
    /// job of this call has finished.
    pub fn map_indexed<J, T>(&self, jobs: &[J], f: impl Fn(&J) -> T + Sync) -> Vec<T>
    where
        J: Sync,
        T: Send,
    {
        let n = jobs.len();
        if n == 0 {
            return Vec::new();
        }
        if n == 1 {
            return vec![f(&jobs[0])];
        }

        let (tx, rx) = std::sync::mpsc::channel::<(usize, std::thread::Result<T>)>();
        let tasks: Vec<Task> = (0..n)
            .map(|i| {
                let tx = tx.clone();
                let f = &f;
                let task: Box<dyn FnOnce() + Send + '_> = Box::new(move || {
                    let result = catch_unwind(AssertUnwindSafe(|| f(&jobs[i])));
                    let _ = tx.send((i, result));
                });
                // SAFETY: the task borrows `jobs`, `f` and `tx`, which live
                // on this call's stack. The drain loop below does not return
                // (and cannot unwind: helping runs only self-catching tasks)
                // until all `n` results have been received, and a task's
                // final action is the send — so every borrow is dead before
                // this frame ends.
                unsafe { erase_task_lifetime(task) }
            })
            .collect();
        self.submit_batch(tasks);
        drop(tx);

        let me = self.me();
        let mut out: Vec<Option<T>> = Vec::with_capacity(n);
        out.resize_with(n, || None);
        let mut first_panic: Option<(usize, Box<dyn std::any::Any + Send>)> = None;
        let mut received = 0usize;
        while received < n {
            let msg = match rx.try_recv() {
                Ok(m) => Some(m),
                Err(TryRecvError::Empty) => {
                    if let Some(task) = self.shared.find_task(me) {
                        task(); // help: run pool work while waiting
                        None
                    } else {
                        // Our remaining jobs are mid-flight on other
                        // threads; block briefly on the result channel.
                        rx.recv_timeout(HELP_WAIT).ok()
                    }
                }
                Err(TryRecvError::Disconnected) => {
                    panic!("pool result channel closed with jobs outstanding")
                }
            };
            if let Some((i, res)) = msg {
                received += 1;
                match res {
                    Ok(v) => out[i] = Some(v),
                    Err(p) => match &first_panic {
                        Some((pi, _)) if *pi < i => {}
                        _ => first_panic = Some((i, p)),
                    },
                }
            }
        }
        if let Some((_, payload)) = first_panic {
            resume_unwind(payload);
        }
        out.into_iter()
            .map(|slot| slot.expect("every job index reported exactly once"))
            .collect()
    }
}

impl Drop for Pool {
    fn drop(&mut self) {
        self.shared.shutdown.store(true, Ordering::SeqCst);
        self.shared.notify();
        let handles = std::mem::take(&mut *self.handles.lock().unwrap());
        for h in handles {
            let _ = h.join();
        }
    }
}

/// Worker count the global pool is built with: the `LIME_THREADS` env
/// override (≥ 1; CI pins this so bench timings are stable) or the
/// machine's available parallelism.
pub fn configured_workers() -> usize {
    if let Ok(v) = std::env::var("LIME_THREADS") {
        if let Ok(n) = v.trim().parse::<usize>() {
            if n >= 1 {
                return n;
            }
        }
    }
    std::thread::available_parallelism()
        .map(|n| n.get())
        .unwrap_or(1)
}

/// The process-wide pool, spawned on first use and reused by every sweep.
pub fn global() -> &'static Pool {
    static GLOBAL: OnceLock<Pool> = OnceLock::new();
    GLOBAL.get_or_init(|| Pool::new(configured_workers()))
}

/// [`Pool::map_indexed`] on the [`global`] pool.
///
/// ```
/// let squares = lime::util::pool::map_indexed(&[1u64, 2, 3, 4], |&x| x * x);
/// assert_eq!(squares, vec![1, 4, 9, 16]); // always in job order
/// ```
pub fn map_indexed<J, T>(jobs: &[J], f: impl Fn(&J) -> T + Sync) -> Vec<T>
where
    J: Sync,
    T: Send,
{
    global().map_indexed(jobs, f)
}

/// SAFETY: caller must guarantee the erased borrows outlive every use of
/// the task (see the invariant documented at the call site).
unsafe fn erase_task_lifetime<'a>(task: Box<dyn FnOnce() + Send + 'a>) -> Task {
    std::mem::transmute::<Box<dyn FnOnce() + Send + 'a>, Box<dyn FnOnce() + Send + 'static>>(
        task,
    )
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicU64;

    #[test]
    fn map_preserves_order() {
        let pool = Pool::new(4);
        let jobs: Vec<usize> = (0..200).collect();
        let got = pool.map_indexed(&jobs, |&x| x * x);
        let want: Vec<usize> = jobs.iter().map(|&x| x * x).collect();
        assert_eq!(got, want);
    }

    #[test]
    fn empty_and_singleton() {
        let pool = Pool::new(2);
        let none: Vec<u32> = Vec::new();
        assert!(pool.map_indexed(&none, |&x| x).is_empty());
        assert_eq!(pool.map_indexed(&[7], |&x| x + 1), vec![8]);
    }

    #[test]
    fn zero_workers_clamps_to_one() {
        let pool = Pool::new(0);
        assert_eq!(pool.workers(), 1);
        assert_eq!(pool.map_indexed(&[1, 2, 3], |&x| x * 2), vec![2, 4, 6]);
    }

    #[test]
    fn nested_submission_runs_on_the_same_pool() {
        let pool = Pool::new(3);
        let outer: Vec<usize> = (0..8).collect();
        let got = pool.map_indexed(&outer, |&o| {
            let inner: Vec<usize> = (0..6).collect();
            pool.map_indexed(&inner, |&i| o * 10 + i).iter().sum::<usize>()
        });
        let want: Vec<usize> = outer
            .iter()
            .map(|&o| (0..6).map(|i| o * 10 + i).sum::<usize>())
            .collect();
        assert_eq!(got, want);
    }

    #[test]
    fn deep_nesting_terminates() {
        let pool = Pool::new(2);
        fn depth_sum(pool: &Pool, depth: usize) -> usize {
            if depth == 0 {
                return 1;
            }
            let jobs = [0usize, 1];
            pool.map_indexed(&jobs, |_| depth_sum(pool, depth - 1))
                .iter()
                .sum()
        }
        assert_eq!(depth_sum(&pool, 5), 32);
    }

    #[test]
    fn panic_propagates_and_pool_survives() {
        let pool = Pool::new(2);
        let jobs: Vec<usize> = (0..16).collect();
        let outcome = std::panic::catch_unwind(AssertUnwindSafe(|| {
            pool.map_indexed(&jobs, |&x| {
                if x == 5 {
                    panic!("job five exploded");
                }
                x
            })
        }));
        let payload = outcome.expect_err("panic must propagate to the caller");
        let msg = payload
            .downcast_ref::<&str>()
            .copied()
            .unwrap_or("<non-str payload>");
        assert!(msg.contains("exploded"), "{msg}");
        // The pool is not poisoned: workers survived and later calls work.
        assert_eq!(pool.map_indexed(&jobs, |&x| x + 1)[15], 16);
    }

    #[test]
    fn lowest_index_panic_wins() {
        let pool = Pool::new(4);
        let jobs: Vec<usize> = (0..32).collect();
        for _ in 0..4 {
            let payload = std::panic::catch_unwind(AssertUnwindSafe(|| {
                pool.map_indexed(&jobs, |&x| {
                    if x % 7 == 3 {
                        panic!("boom at {x}");
                    }
                    x
                })
            }))
            .expect_err("must panic");
            let msg = payload
                .downcast_ref::<String>()
                .cloned()
                .unwrap_or_default();
            assert_eq!(msg, "boom at 3", "deterministic panic selection");
        }
    }

    #[test]
    fn external_callers_share_one_global_pool() {
        let a = global() as *const Pool;
        let b = global() as *const Pool;
        assert_eq!(a, b);
        assert!(global().workers() >= 1);
        let jobs = vec![1u64, 2, 3, 4];
        assert_eq!(map_indexed(&jobs, |&x| x * x), vec![1, 4, 9, 16]);
    }

    #[test]
    fn side_effects_happen_exactly_once_per_job() {
        let pool = Pool::new(8);
        let counter = AtomicU64::new(0);
        let jobs: Vec<usize> = (0..500).collect();
        let got = pool.map_indexed(&jobs, |&x| {
            counter.fetch_add(1, Ordering::Relaxed);
            x
        });
        assert_eq!(got, jobs);
        assert_eq!(counter.load(Ordering::Relaxed), 500);
    }

    #[test]
    fn configured_workers_positive() {
        assert!(configured_workers() >= 1);
    }

    #[test]
    fn deque_is_lifo_for_owner_and_fifo_for_thieves() {
        let d = Deque::new();
        let log = Arc::new(Mutex::new(Vec::new()));
        for i in 0..4 {
            let log = log.clone();
            let t: Task = Box::new(move || log.lock().unwrap().push(i));
            d.push(task_into_ptr(t)).expect("ring has room");
        }
        assert_eq!(d.len(), 4);
        // A thief claims the OLDEST task (0); the owner pops the NEWEST (3).
        match d.steal() {
            Steal::Taken(p) => unsafe { task_from_ptr(p)() },
            _ => panic!("steal from a non-empty deque"),
        }
        let p = d.pop().expect("owner pop");
        unsafe { task_from_ptr(p)() };
        assert_eq!(*log.lock().unwrap(), vec![0, 3]);
        assert_eq!(d.len(), 2);
    }

    #[test]
    fn deque_full_push_returns_the_task_and_drop_frees_leftovers() {
        let alive = Arc::new(());
        let d = Deque::new();
        for _ in 0..DEQUE_CAP {
            let a = alive.clone();
            let t: Task = Box::new(move || drop(a));
            d.push(task_into_ptr(t)).expect("ring has room");
        }
        let a = alive.clone();
        let t: Task = Box::new(move || drop(a));
        let p = d.push(task_into_ptr(t)).expect_err("ring is full");
        drop(unsafe { task_from_ptr(p) });
        drop(d); // must free the DEQUE_CAP stranded tasks
        assert_eq!(Arc::strong_count(&alive), 1, "a stranded task leaked");
    }

    #[test]
    fn worker_batch_overflow_spills_to_injector_and_completes() {
        // A nested submission wider than the ring capacity forces the
        // owner-push overflow path; every job still runs exactly once and
        // lands at its index.
        let pool = Pool::new(2);
        let outer = [0usize];
        let wide = 3 * DEQUE_CAP;
        let got = pool.map_indexed(&outer, |_| {
            let inner: Vec<usize> = (0..wide).collect();
            pool.map_indexed(&inner, |&i| i as u64)
                .into_iter()
                .sum::<u64>()
        });
        let n = wide as u64;
        assert_eq!(got, vec![n * (n - 1) / 2]);
    }

    #[test]
    fn heavy_contention_keeps_exactly_once_semantics() {
        // Repeated wide fan-outs on many workers: the lock-free claims
        // must neither lose nor duplicate a job.
        let pool = Pool::new(8);
        let counter = AtomicU64::new(0);
        for _ in 0..20 {
            let jobs: Vec<usize> = (0..900).collect();
            let got = pool.map_indexed(&jobs, |&x| {
                counter.fetch_add(1, Ordering::Relaxed);
                x as u64
            });
            assert_eq!(got.len(), 900);
            assert!(got.iter().enumerate().all(|(i, &v)| v == i as u64));
        }
        assert_eq!(counter.load(Ordering::Relaxed), 20 * 900);
    }

    #[test]
    fn dropping_a_pool_joins_workers() {
        let pool = Pool::new(3);
        let jobs: Vec<usize> = (0..50).collect();
        let _ = pool.map_indexed(&jobs, |&x| x + 1);
        drop(pool); // must not hang or leak panicking threads
    }
}
