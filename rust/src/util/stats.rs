//! Small statistics toolkit: batch summaries for benchmark reporting
//! (criterion is unavailable offline; `util::bench` builds on this) and
//! **streaming** quantile state for the fleet-scale serving artifacts —
//! [`P2Quantile`] (the Jain–Chlamtac P² estimator, O(1) memory per
//! tracked quantile) and [`Reservoir`] (Algorithm R sampling over the
//! crate's deterministic [`Rng`](crate::util::rng::Rng)), merged across
//! shards by [`weighted_percentile`]. Both are deterministic given the
//! input order and seed, which is what lets `serve::fleet` emit
//! byte-identical `lime-fleet-v1` artifacts at any worker count.

/// Summary statistics over a sample of f64 observations.
#[derive(Debug, Clone, PartialEq)]
pub struct Summary {
    pub n: usize,
    pub mean: f64,
    pub std_dev: f64,
    pub min: f64,
    pub max: f64,
    pub p50: f64,
    pub p90: f64,
    pub p99: f64,
}

/// Compute a [`Summary`]; panics on an empty sample.
pub fn summarize(xs: &[f64]) -> Summary {
    assert!(!xs.is_empty(), "empty sample");
    let n = xs.len();
    let mean = xs.iter().sum::<f64>() / n as f64;
    let var = if n > 1 {
        xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / (n - 1) as f64
    } else {
        0.0
    };
    let mut sorted = xs.to_vec();
    sorted.sort_by(|a, b| a.partial_cmp(b).unwrap());
    Summary {
        n,
        mean,
        std_dev: var.sqrt(),
        min: sorted[0],
        max: sorted[n - 1],
        p50: percentile_sorted(&sorted, 50.0),
        p90: percentile_sorted(&sorted, 90.0),
        p99: percentile_sorted(&sorted, 99.0),
    }
}

/// Linear-interpolated percentile of an already-sorted sample.
pub fn percentile_sorted(sorted: &[f64], p: f64) -> f64 {
    assert!(!sorted.is_empty());
    assert!((0.0..=100.0).contains(&p));
    if sorted.len() == 1 {
        return sorted[0];
    }
    let rank = p / 100.0 * (sorted.len() - 1) as f64;
    let lo = rank.floor() as usize;
    let hi = rank.ceil() as usize;
    let frac = rank - lo as f64;
    sorted[lo] + (sorted[hi] - sorted[lo]) * frac
}

/// Percentile of an unsorted sample.
pub fn percentile(xs: &[f64], p: f64) -> f64 {
    let mut sorted = xs.to_vec();
    sorted.sort_by(|a, b| a.partial_cmp(b).unwrap());
    percentile_sorted(&sorted, p)
}

/// Arithmetic mean.
pub fn mean(xs: &[f64]) -> f64 {
    assert!(!xs.is_empty());
    xs.iter().sum::<f64>() / xs.len() as f64
}

/// Streaming quantile estimator — the Jain & Chlamtac **P² algorithm**:
/// five markers (min, two intermediates, the tracked quantile, max) whose
/// heights are nudged by parabolic (or, when that overshoots, linear)
/// interpolation as observations arrive. O(1) memory and O(1) work per
/// observation, no samples retained — the state a fleet cell keeps per
/// latency metric instead of a million-entry vector.
///
/// Exact while fewer than five observations have arrived; deterministic
/// given the observation order (no randomness), so a sharded fleet run
/// reproduces it bit-for-bit at any worker count.
#[derive(Debug, Clone, PartialEq)]
pub struct P2Quantile {
    q: f64,
    /// Marker heights (h[2] is the running estimate once primed).
    h: [f64; 5],
    /// Actual marker positions (integers, kept as f64 for the formulas).
    pos: [f64; 5],
    desired: [f64; 5],
    inc: [f64; 5],
    count: usize,
    /// Buffer for the first five observations.
    init: [f64; 5],
}

impl P2Quantile {
    /// Track the `q`-quantile, `0 < q < 1` (e.g. `0.99` for p99).
    pub fn new(q: f64) -> P2Quantile {
        assert!(q > 0.0 && q < 1.0, "quantile must be in (0, 1), got {q}");
        P2Quantile {
            q,
            h: [0.0; 5],
            pos: [1.0, 2.0, 3.0, 4.0, 5.0],
            desired: [1.0, 1.0 + 2.0 * q, 1.0 + 4.0 * q, 3.0 + 2.0 * q, 5.0],
            inc: [0.0, q / 2.0, q, (1.0 + q) / 2.0, 1.0],
            count: 0,
            init: [0.0; 5],
        }
    }

    /// The tracked quantile in (0, 1).
    pub fn quantile(&self) -> f64 {
        self.q
    }

    /// Observations seen so far.
    pub fn count(&self) -> usize {
        self.count
    }

    /// Feed one observation.
    pub fn push(&mut self, x: f64) {
        if self.count < 5 {
            self.init[self.count] = x;
            self.count += 1;
            if self.count == 5 {
                let mut s = self.init;
                s.sort_by(f64::total_cmp);
                self.h = s;
            }
            return;
        }
        self.count += 1;
        // Locate the cell k with h[k] <= x < h[k+1], clamping the
        // extreme markers to the running min/max.
        let k = if x < self.h[0] {
            self.h[0] = x;
            0
        } else if x >= self.h[4] {
            self.h[4] = x;
            3
        } else {
            let mut k = 0;
            while k < 3 && x >= self.h[k + 1] {
                k += 1;
            }
            k
        };
        for i in (k + 1)..5 {
            self.pos[i] += 1.0;
        }
        for i in 0..5 {
            self.desired[i] += self.inc[i];
        }
        for i in 1..4 {
            let off = self.desired[i] - self.pos[i];
            if (off >= 1.0 && self.pos[i + 1] - self.pos[i] > 1.0)
                || (off <= -1.0 && self.pos[i - 1] - self.pos[i] < -1.0)
            {
                let d = off.signum();
                let candidate = self.parabolic(i, d);
                self.h[i] = if self.h[i - 1] < candidate && candidate < self.h[i + 1] {
                    candidate
                } else {
                    self.linear(i, d)
                };
                self.pos[i] += d;
            }
        }
    }

    /// Piecewise-parabolic marker adjustment (P² eq. for h'_i).
    fn parabolic(&self, i: usize, d: f64) -> f64 {
        let (hm, h, hp) = (self.h[i - 1], self.h[i], self.h[i + 1]);
        let (nm, n, np) = (self.pos[i - 1], self.pos[i], self.pos[i + 1]);
        h + d / (np - nm) * ((n - nm + d) * (hp - h) / (np - n) + (np - n - d) * (h - hm) / (n - nm))
    }

    /// Linear fallback when the parabola overshoots a neighbour.
    fn linear(&self, i: usize, d: f64) -> f64 {
        let j = if d > 0.0 { i + 1 } else { i - 1 };
        self.h[i] + d * (self.h[j] - self.h[i]) / (self.pos[j] - self.pos[i])
    }

    /// Current estimate. Exact below five observations; `NaN` when empty
    /// (callers that may see empty shards must guard).
    pub fn value(&self) -> f64 {
        if self.count == 0 {
            return f64::NAN;
        }
        if self.count < 5 {
            let mut s: Vec<f64> = self.init[..self.count].to_vec();
            s.sort_by(f64::total_cmp);
            return percentile_sorted(&s, self.q * 100.0);
        }
        self.h[2]
    }
}

/// Fixed-capacity uniform sample over an unbounded stream — **Algorithm
/// R** reservoir sampling on the crate's deterministic
/// [`Rng`](crate::util::rng::Rng). Each per-shard reservoir is seeded per
/// (cell, shard) so a sharded fleet run is reproducible at any worker
/// count; cross-shard quantiles come from [`weighted_percentile`] over
/// the union of reservoirs.
#[derive(Debug, Clone)]
pub struct Reservoir {
    samples: Vec<f64>,
    cap: usize,
    seen: u64,
    rng: crate::util::rng::Rng,
}

impl Reservoir {
    pub fn new(cap: usize, seed: u64) -> Reservoir {
        assert!(cap > 0, "reservoir capacity must be positive");
        Reservoir {
            samples: Vec::with_capacity(cap),
            cap,
            seen: 0,
            rng: crate::util::rng::Rng::new(seed),
        }
    }

    /// Feed one observation; O(1), never grows past the capacity.
    pub fn push(&mut self, x: f64) {
        self.seen += 1;
        if self.samples.len() < self.cap {
            self.samples.push(x);
        } else {
            let j = self.rng.below(self.seen);
            if (j as usize) < self.cap {
                self.samples[j as usize] = x;
            }
        }
    }

    /// The retained sample (unsorted, insertion/replacement order).
    pub fn samples(&self) -> &[f64] {
        &self.samples
    }

    /// Consume the reservoir, yielding the retained sample without a copy.
    pub fn into_samples(self) -> Vec<f64> {
        self.samples
    }

    /// Total observations fed (not the retained count).
    pub fn seen(&self) -> u64 {
        self.seen
    }
}

/// Percentile over weighted samples — e.g. the union of per-shard
/// reservoirs, each sample carrying `shard_total / shard_sample_count`
/// weight so shards of different sizes contribute proportionally. Sorts
/// by value (stable, `total_cmp`) and walks the cumulative weight to the
/// first sample at or past `p`% of the total: a deterministic
/// step-function quantile, tolerance-tested against the exact sorted
/// percentile. `p` is in percent (0–100) like [`percentile`].
pub fn weighted_percentile(samples: &mut [(f64, f64)], p: f64) -> f64 {
    assert!(!samples.is_empty(), "empty weighted sample");
    assert!((0.0..=100.0).contains(&p));
    samples.sort_by(|a, b| a.0.total_cmp(&b.0));
    let total: f64 = samples.iter().map(|s| s.1).sum();
    let target = p / 100.0 * total;
    let mut acc = 0.0;
    for &(v, w) in samples.iter() {
        acc += w;
        if acc >= target {
            return v;
        }
    }
    samples[samples.len() - 1].0
}

/// Geometric mean (used for speedup aggregation across workloads).
pub fn geomean(xs: &[f64]) -> f64 {
    assert!(!xs.is_empty());
    let log_sum = xs
        .iter()
        .map(|&x| {
            assert!(x > 0.0, "geomean requires positive values");
            x.ln()
        })
        .sum::<f64>();
    (log_sum / xs.len() as f64).exp()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn summary_basic() {
        let s = summarize(&[1.0, 2.0, 3.0, 4.0, 5.0]);
        assert_eq!(s.n, 5);
        assert!((s.mean - 3.0).abs() < 1e-12);
        assert_eq!(s.min, 1.0);
        assert_eq!(s.max, 5.0);
        assert!((s.p50 - 3.0).abs() < 1e-12);
    }

    #[test]
    fn summary_single_element() {
        let s = summarize(&[7.0]);
        assert_eq!(s.std_dev, 0.0);
        assert_eq!(s.p99, 7.0);
    }

    #[test]
    fn percentile_interpolates() {
        let xs = [0.0, 10.0];
        assert!((percentile(&xs, 50.0) - 5.0).abs() < 1e-12);
        assert!((percentile(&xs, 25.0) - 2.5).abs() < 1e-12);
    }

    #[test]
    fn percentile_unsorted_input() {
        let xs = [5.0, 1.0, 3.0, 2.0, 4.0];
        assert!((percentile(&xs, 50.0) - 3.0).abs() < 1e-12);
    }

    #[test]
    fn geomean_of_speedups() {
        let g = geomean(&[2.0, 8.0]);
        assert!((g - 4.0).abs() < 1e-12);
    }

    #[test]
    #[should_panic]
    fn empty_sample_panics() {
        summarize(&[]);
    }

    #[test]
    fn stddev_matches_known() {
        let s = summarize(&[2.0, 4.0, 4.0, 4.0, 5.0, 5.0, 7.0, 9.0]);
        // Sample std-dev of this classic set is ~2.138.
        assert!((s.std_dev - 2.13809).abs() < 1e-4, "{}", s.std_dev);
    }

    use crate::util::rng::Rng;

    /// Fuzzed observation streams from three distribution shapes:
    /// uniform, heavy-tailed exponential, and bimodal.
    fn fuzz_stream(seed: u64) -> Vec<f64> {
        let mut rng = Rng::new(0xF1EE7 ^ seed.wrapping_mul(0x9E37_79B9));
        let n = rng.range(300, 4000);
        (0..n)
            .map(|_| match seed % 3 {
                0 => rng.f64(),
                1 => rng.exponential(1.0),
                _ => {
                    if rng.chance(0.8) {
                        rng.f64()
                    } else {
                        10.0 + rng.f64()
                    }
                }
            })
            .collect()
    }

    /// Rank of `v` within `xs` as a fraction in [0, 1] — the tolerance
    /// metric for quantile estimates (value-space error is unbounded on
    /// heavy tails; rank-space error is what both estimators bound).
    fn rank_of(xs: &[f64], v: f64) -> f64 {
        xs.iter().filter(|&&x| x <= v).count() as f64 / xs.len() as f64
    }

    #[test]
    fn p2_is_exact_below_five_observations() {
        let mut est = P2Quantile::new(0.5);
        for x in [3.0, 1.0, 2.0] {
            est.push(x);
        }
        assert_eq!(est.value(), percentile(&[3.0, 1.0, 2.0], 50.0));
        assert_eq!(est.count(), 3);
        assert!(P2Quantile::new(0.9).value().is_nan(), "empty => NaN");
    }

    #[test]
    fn p2_tracks_exact_quantiles_on_fuzzed_streams() {
        for seed in 0..18u64 {
            let xs = fuzz_stream(seed);
            for q in [0.5, 0.95, 0.99] {
                let mut est = P2Quantile::new(q);
                for &x in &xs {
                    est.push(x);
                }
                let rank = rank_of(&xs, est.value());
                assert!(
                    (rank - q).abs() <= 0.1 + 5.0 / xs.len() as f64,
                    "seed {seed} q {q}: estimate {} sits at rank {rank} (n={})",
                    est.value(),
                    xs.len()
                );
            }
        }
    }

    #[test]
    fn p2_is_deterministic_and_monotone_across_quantiles() {
        let xs = fuzz_stream(1);
        let run = |q: f64| {
            let mut est = P2Quantile::new(q);
            for &x in &xs {
                est.push(x);
            }
            est.value()
        };
        assert_eq!(run(0.95).to_bits(), run(0.95).to_bits(), "deterministic");
        assert!(run(0.5) <= run(0.95) && run(0.95) <= run(0.99));
    }

    #[test]
    fn p2_error_is_bounded_at_a_million_samples() {
        // The fleet-scale contract: at 10^6 heavy-tailed (lognormal)
        // observations — the size of one `fleet_stream_1M_des` cell — the
        // five-marker P² estimate must sit within ~1.5 rank-points of the
        // exact sorted quantile, while holding O(1) state. Value-space
        // error is unbounded on the lognormal tail; rank space is the
        // bound the estimator actually provides.
        let mut rng = Rng::new(0x9_1E6_2026);
        let n = 1_000_000usize;
        let mut xs = Vec::with_capacity(n);
        let mut ests: Vec<P2Quantile> =
            [0.5, 0.9, 0.99].iter().map(|&q| P2Quantile::new(q)).collect();
        for _ in 0..n {
            let x = rng.normal().exp();
            xs.push(x);
            for est in &mut ests {
                est.push(x);
            }
        }
        for est in &ests {
            assert_eq!(est.count(), n);
            let rank = rank_of(&xs, est.value());
            assert!(
                (rank - est.quantile()).abs() <= 0.015,
                "q {}: estimate {} sits at rank {rank}",
                est.quantile(),
                est.value()
            );
        }
    }

    #[test]
    fn reservoir_keeps_everything_under_capacity() {
        let xs = [5.0, 1.0, 3.0];
        let mut r = Reservoir::new(8, 42);
        for &x in &xs {
            r.push(x);
        }
        assert_eq!(r.samples(), &xs);
        assert_eq!(r.seen(), 3);
    }

    #[test]
    fn reservoir_is_deterministic_by_seed_and_capacity_bounded() {
        let xs = fuzz_stream(2);
        let sample = |seed: u64| {
            let mut r = Reservoir::new(64, seed);
            for &x in &xs {
                r.push(x);
            }
            r.samples().to_vec()
        };
        assert_eq!(sample(7), sample(7));
        assert_eq!(sample(7).len(), 64);
        assert_ne!(sample(7), sample(8), "different seeds sample differently");
    }

    #[test]
    fn reservoir_weighted_percentile_tracks_exact_on_fuzzed_streams() {
        // The fleet merge shape: shard the stream, reservoir-sample each
        // shard, weight each sample by shard_total / shard_sample_count,
        // and take the weighted percentile of the union. Rank-space
        // tolerance ~ a few sampling standard errors at cap 512.
        for seed in 0..12u64 {
            let xs = fuzz_stream(seed);
            let shards: Vec<&[f64]> = xs.chunks(xs.len().div_ceil(3)).collect();
            let mut union: Vec<(f64, f64)> = Vec::new();
            for (si, shard) in shards.iter().enumerate() {
                let mut r = Reservoir::new(512, 0xCAFE + si as u64);
                for &x in shard.iter() {
                    r.push(x);
                }
                let w = shard.len() as f64 / r.samples().len() as f64;
                union.extend(r.samples().iter().map(|&v| (v, w)));
            }
            for (p, tol) in [(50.0, 0.12), (95.0, 0.06), (99.0, 0.03)] {
                let v = weighted_percentile(&mut union, p);
                let rank = rank_of(&xs, v);
                assert!(
                    (rank - p / 100.0).abs() <= tol + 5.0 / xs.len() as f64,
                    "seed {seed} p {p}: merged estimate {v} at rank {rank}"
                );
            }
        }
    }

    #[test]
    fn weighted_percentile_unweighted_matches_step_quantile() {
        // With unit weights the walk lands on the classic step-function
        // quantile of the sorted values.
        let mut s: Vec<(f64, f64)> = [4.0, 1.0, 3.0, 2.0].iter().map(|&v| (v, 1.0)).collect();
        assert_eq!(weighted_percentile(&mut s, 50.0), 2.0);
        assert_eq!(weighted_percentile(&mut s, 100.0), 4.0);
        assert_eq!(weighted_percentile(&mut s, 0.0), 1.0);
    }
}
