//! Small statistics toolkit for benchmark reporting (criterion is
//! unavailable offline; the bench harness in `util::bench` builds on this).

/// Summary statistics over a sample of f64 observations.
#[derive(Debug, Clone, PartialEq)]
pub struct Summary {
    pub n: usize,
    pub mean: f64,
    pub std_dev: f64,
    pub min: f64,
    pub max: f64,
    pub p50: f64,
    pub p90: f64,
    pub p99: f64,
}

/// Compute a [`Summary`]; panics on an empty sample.
pub fn summarize(xs: &[f64]) -> Summary {
    assert!(!xs.is_empty(), "empty sample");
    let n = xs.len();
    let mean = xs.iter().sum::<f64>() / n as f64;
    let var = if n > 1 {
        xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / (n - 1) as f64
    } else {
        0.0
    };
    let mut sorted = xs.to_vec();
    sorted.sort_by(|a, b| a.partial_cmp(b).unwrap());
    Summary {
        n,
        mean,
        std_dev: var.sqrt(),
        min: sorted[0],
        max: sorted[n - 1],
        p50: percentile_sorted(&sorted, 50.0),
        p90: percentile_sorted(&sorted, 90.0),
        p99: percentile_sorted(&sorted, 99.0),
    }
}

/// Linear-interpolated percentile of an already-sorted sample.
pub fn percentile_sorted(sorted: &[f64], p: f64) -> f64 {
    assert!(!sorted.is_empty());
    assert!((0.0..=100.0).contains(&p));
    if sorted.len() == 1 {
        return sorted[0];
    }
    let rank = p / 100.0 * (sorted.len() - 1) as f64;
    let lo = rank.floor() as usize;
    let hi = rank.ceil() as usize;
    let frac = rank - lo as f64;
    sorted[lo] + (sorted[hi] - sorted[lo]) * frac
}

/// Percentile of an unsorted sample.
pub fn percentile(xs: &[f64], p: f64) -> f64 {
    let mut sorted = xs.to_vec();
    sorted.sort_by(|a, b| a.partial_cmp(b).unwrap());
    percentile_sorted(&sorted, p)
}

/// Arithmetic mean.
pub fn mean(xs: &[f64]) -> f64 {
    assert!(!xs.is_empty());
    xs.iter().sum::<f64>() / xs.len() as f64
}

/// Geometric mean (used for speedup aggregation across workloads).
pub fn geomean(xs: &[f64]) -> f64 {
    assert!(!xs.is_empty());
    let log_sum = xs
        .iter()
        .map(|&x| {
            assert!(x > 0.0, "geomean requires positive values");
            x.ln()
        })
        .sum::<f64>();
    (log_sum / xs.len() as f64).exp()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn summary_basic() {
        let s = summarize(&[1.0, 2.0, 3.0, 4.0, 5.0]);
        assert_eq!(s.n, 5);
        assert!((s.mean - 3.0).abs() < 1e-12);
        assert_eq!(s.min, 1.0);
        assert_eq!(s.max, 5.0);
        assert!((s.p50 - 3.0).abs() < 1e-12);
    }

    #[test]
    fn summary_single_element() {
        let s = summarize(&[7.0]);
        assert_eq!(s.std_dev, 0.0);
        assert_eq!(s.p99, 7.0);
    }

    #[test]
    fn percentile_interpolates() {
        let xs = [0.0, 10.0];
        assert!((percentile(&xs, 50.0) - 5.0).abs() < 1e-12);
        assert!((percentile(&xs, 25.0) - 2.5).abs() < 1e-12);
    }

    #[test]
    fn percentile_unsorted_input() {
        let xs = [5.0, 1.0, 3.0, 2.0, 4.0];
        assert!((percentile(&xs, 50.0) - 3.0).abs() < 1e-12);
    }

    #[test]
    fn geomean_of_speedups() {
        let g = geomean(&[2.0, 8.0]);
        assert!((g - 4.0).abs() < 1e-12);
    }

    #[test]
    #[should_panic]
    fn empty_sample_panics() {
        summarize(&[]);
    }

    #[test]
    fn stddev_matches_known() {
        let s = summarize(&[2.0, 4.0, 4.0, 4.0, 5.0, 5.0, 7.0, 9.0]);
        // Sample std-dev of this classic set is ~2.138.
        assert!((s.std_dev - 2.13809).abs() < 1e-4, "{}", s.std_dev);
    }
}
