//! Experiment harness: one runner per paper figure/table (see README.md
//! for the index). Each runner returns structured rows *and* prints the
//! same series the paper reports, so the bench targets and the `lime
//! experiments` subcommand share one implementation. Grids evaluate their
//! independent cells on the persistent work-stealing pool
//! (`util::pool`) with `TraceMode::Off`; a cell's own fan-out (LIME's
//! `plan()` sweeping its `#Seg` candidates) nests onto the same pool.
//! Results are written by index — printed tables and returned rows are
//! bit-identical to the sequential loops
//! ([`grid_cells_sequential`] is the tested reference).
//!
//! The legacy (method × bandwidth × pattern) grid is the baseline slice of
//! the composable [`scenario::ScenarioMatrix`], which adds cluster-size,
//! `#Seg`-override, pressure (joint memory/bandwidth fluctuation script),
//! arrival-process (single run vs continuous queued stream, served
//! through `serve::simqueue`), batching-policy (FIFO vs step-level
//! continuous batching with paged-KV accounting, on stream cells only)
//! and device-churn (mid-stream Down/Up events with online re-planning
//! and KV migration) axes; the `--id sweep` experiment evaluates one
//! matrix per cluster point and writes one `lime-sweep-v7` JSON each,
//! with per-request queueing-delay/TTFT/TBT/length arrays on stream
//! cells, a workload-mix coordinate (fixed baseline vs bimodal
//! short-chat / long-context lengths), paged-KV counters on
//! continuous-batching cells and
//! replans/KV-migration/recovery counters on churn cells. Fleet-scale
//! admission lives next door in `serve::fleet`: the event-driven router
//! on `sim::Engine` emits its own `lime-fleet-v1`/`v2` artifact family
//! (v2 adds sticky-session affinity / KV-reuse counters), validated by
//! the same `lime sweep-check` entry point as the sweep schemas here.
//! See `docs/ARCHITECTURE.md` for the module map and `docs/SWEEPS.md`
//! for the artifact schemas.

pub mod scenario;

pub use scenario::{
    validate_sweep, validate_sweep_v2, validate_sweep_v3, validate_sweep_v4, validate_sweep_v5,
    validate_sweep_v6, validate_sweep_v7, ArrivalSpec, BatchingSpec, RequestLevel, ScenarioCell,
    ScenarioMatrix, SegChoice, SweepSummary,
};

use crate::adapt::{MemScenario, Script};
use crate::baselines::{all, by_name, Method};
use crate::cluster::{Cluster, DeviceSpec};
use crate::model::ModelSpec;
use crate::net::BandwidthTrace;
use crate::pipeline::{run_interleaved, run_traditional, ExecOptions, TradOptions};
use crate::plan::{plan, plan_with_segs, PlanOptions};
use crate::sim::{SsdModel, TraceMode};
use crate::util::bytes::{gib, mbps};
use crate::util::pool;
use crate::workload::{LengthDist, Pattern};

/// A single (method × bandwidth × pattern) measurement.
#[derive(Debug, Clone, PartialEq)]
pub struct Cell {
    pub method: &'static str,
    /// Stable machine key ([`Method::key`]) for JSON artifacts.
    pub method_key: &'static str,
    pub bandwidth_mbps: f64,
    pub pattern: Pattern,
    /// `None` = OOM. OOT is judged against `Pattern::oot_limit_ms`.
    pub ms_per_token: Option<f64>,
}

impl Cell {
    pub fn is_oot(&self) -> bool {
        matches!(self.ms_per_token, Some(ms) if ms > self.pattern.oot_limit_ms())
    }

    pub fn render(&self) -> String {
        match self.ms_per_token {
            None => "OOM".into(),
            Some(ms) if ms > self.pattern.oot_limit_ms() => "OOT".into(),
            Some(ms) => format!("{ms:9.1}"),
        }
    }
}

/// Evaluate the (method × bandwidth × pattern) grid on the work-stealing
/// pool. Cells are independent simulations; results are written by index,
/// so the returned order (and therefore every printed table) is identical
/// to the sequential triple loop. Cells run with `TraceMode::Off` — the
/// grid only reads `SimResult` numbers, and skipping span materialization
/// is a large part of sweep throughput. A cell whose method plans offline
/// (LIME and its ablations) fans its `#Seg` candidates out as *nested*
/// jobs on the same pool.
pub fn grid_cells(
    spec: &ModelSpec,
    cluster: &Cluster,
    methods: &[Box<dyn Method>],
    bandwidths: &[f64],
    tokens: usize,
) -> Vec<Cell> {
    grid_impl(spec, cluster, methods, bandwidths, tokens, true)
}

/// [`grid_cells`] evaluated with a plain sequential loop — the
/// bit-determinism reference (the pool-vs-sequential equivalence test in
/// `rust/tests/pool.rs` compares the two cell-for-cell).
pub fn grid_cells_sequential(
    spec: &ModelSpec,
    cluster: &Cluster,
    methods: &[Box<dyn Method>],
    bandwidths: &[f64],
    tokens: usize,
) -> Vec<Cell> {
    grid_impl(spec, cluster, methods, bandwidths, tokens, false)
}

fn grid_impl(
    spec: &ModelSpec,
    cluster: &Cluster,
    methods: &[Box<dyn Method>],
    bandwidths: &[f64],
    tokens: usize,
    parallel: bool,
) -> Vec<Cell> {
    // The legacy grid is the scenario matrix at its baseline point
    // (auto #Seg, no memory pressure); the cell order — methods outermost,
    // then bandwidths, then patterns — is the matrix's point order.
    let matrix = ScenarioMatrix::new(
        "grid",
        spec.clone(),
        cluster.clone(),
        methods,
        bandwidths.to_vec(),
        vec![Pattern::Sporadic, Pattern::Bursty],
        tokens,
    );
    let cells = if parallel {
        matrix.eval()
    } else {
        matrix.eval_sequential()
    };
    cells
        .into_iter()
        .map(|c| Cell {
            method: c.method,
            method_key: c.method_key,
            bandwidth_mbps: c.bandwidth_mbps,
            pattern: c.pattern,
            ms_per_token: c.ms_per_token,
        })
        .collect()
}

fn print_grid(title: &str, cells: &[Cell], bandwidths: &[f64]) {
    println!("\n== {title} ==");
    println!(
        "{:32} {:>12} {:>12} {:>12} {:>12}",
        "method (ms/token)", "spor@100", "burst@100", "spor@200", "burst@200"
    );
    let mut methods: Vec<&str> = Vec::new();
    for c in cells {
        if !methods.contains(&c.method) {
            methods.push(c.method);
        }
    }
    for m in methods {
        let cell = |bw: f64, p: Pattern| {
            cells
                .iter()
                .find(|c| c.method == m && c.bandwidth_mbps == bw && c.pattern == p)
                .map(|c| c.render())
                .unwrap_or_else(|| "-".into())
        };
        println!(
            "{:32} {:>12} {:>12} {:>12} {:>12}",
            m,
            cell(bandwidths[0], Pattern::Sporadic),
            cell(bandwidths[0], Pattern::Bursty),
            cell(bandwidths[1], Pattern::Sporadic),
            cell(bandwidths[1], Pattern::Bursty)
        );
    }
}

/// LIME's speedup over every other method that completed, per column.
pub fn speedups(cells: &[Cell]) -> Vec<(String, f64)> {
    let mut out = Vec::new();
    for &bw in &[100.0, 200.0] {
        for pattern in [Pattern::Sporadic, Pattern::Bursty] {
            let lime = cells.iter().find(|c| {
                c.method == "LIME" && c.bandwidth_mbps == bw && c.pattern == pattern
            });
            let Some(Cell {
                ms_per_token: Some(lime_ms),
                ..
            }) = lime
            else {
                continue;
            };
            for c in cells.iter().filter(|c| {
                c.method != "LIME" && c.bandwidth_mbps == bw && c.pattern == pattern
            }) {
                if let Some(ms) = c.ms_per_token {
                    out.push((
                        format!("{} @{}Mbps {:?}", c.method, bw, pattern),
                        ms / lime_ms,
                    ));
                }
            }
        }
    }
    out
}

// ----------------------------------------------------------- Fig. 2a / 2b

/// Fig. 2a: TP+offloading vs PP+offloading at 200 Mbps on two settings.
pub fn fig2a(tokens: usize) -> Vec<(String, f64, f64)> {
    // Two device settings per model, in the paper's "devices accommodate
    // the model, offloading covers the margin" regime.
    let cases = [
        ("Llama3.3-70B / setting A", ModelSpec::llama33_70b(), Cluster::env_e3()),
        ("Llama3.3-70B / setting B", ModelSpec::llama33_70b(), Cluster::lowmem_setting1()),
        ("Qwen3-32B / setting A", ModelSpec::qwen3_32b(), Cluster::env_e2()),
        ("Qwen3-32B / setting B", ModelSpec::qwen3_32b(), Cluster::env_e3()),
    ];
    let bw = BandwidthTrace::fixed_mbps(200.0);
    let tp = by_name("tpi-llm-offload").unwrap();
    let pp = by_name("pp-offload").unwrap();
    println!("\n== Fig. 2a: TP+offload vs PP+offload (200 Mbps, sporadic) ==");
    let rows: Vec<(String, f64, f64)> = pool::map_indexed(
        &cases,
        |(label, spec, cluster)| {
            let tp_ms = tp
                .run_mode(spec, cluster, &bw, Pattern::Sporadic, tokens, TraceMode::Off)
                .ms_per_token()
                .unwrap_or(f64::INFINITY);
            let pp_ms = pp
                .run_mode(spec, cluster, &bw, Pattern::Sporadic, tokens, TraceMode::Off)
                .ms_per_token()
                .unwrap_or(f64::INFINITY);
            (label.to_string(), tp_ms, pp_ms)
        },
    );
    for (label, tp_ms, pp_ms) in &rows {
        println!(
            "  {label:28} TP+off {tp_ms:9.1} ms/tok   PP+off {pp_ms:9.1} ms/tok   PP speedup {:.2}x",
            tp_ms / pp_ms
        );
    }
    rows
}

/// Fig. 2b: per-step extra load latency — offloading one MHA block vs
/// offloading the (growing) KV cache, on an AGX Orin 32 GB.
pub fn fig2b(steps: usize) -> Vec<(usize, f64, f64)> {
    let spec = ModelSpec::llama2_13b();
    let dev = DeviceSpec::agx_orin_32();
    let mut ssd_model = SsdModel::new(dev.ssd_read_bps, dev.ssd_write_bps, 2);
    let mut ssd_kv = SsdModel::new(dev.ssd_read_bps, dev.ssd_write_bps, 3);
    let mha = spec.mha_bytes();
    // Fig. 2b grows the KV until it reaches the MHA block's footprint.
    let kv_per_tok = spec.kv_bytes_per_token_layer() * spec.layers as u64;
    let mut rows = Vec::new();
    let mut t_model = 0.0f64;
    let mut t_kv = 0.0f64;
    for step in 0..steps {
        // Model-shard path: one stable read of the MHA block.
        let iv = ssd_model.read(t_model, mha);
        let model_ms = iv.duration() * 1e3;
        t_model = iv.end;
        // KV path: write the delta, read back the working set (capped at
        // the MHA footprint, per the figure's setup).
        let kv_bytes = (kv_per_tok * (step as u64 + 1)).min(mha);
        let w = ssd_kv.write(t_kv, kv_per_tok);
        let r = ssd_kv.read(w.end, kv_bytes);
        let kv_ms = (r.end - w.start) * 1e3;
        t_kv = r.end;
        rows.push((step, model_ms, kv_ms));
    }
    let crossover = rows.iter().find(|(_, m, k)| k > m).map(|(s, _, _)| *s);
    println!(
        "\n== Fig. 2b: per-step load latency, model-shard vs KV offload ==\n  model-shard is flat (~{:.1} ms); KV starts cheaper and crosses over at step {:?}",
        rows.first().map(|r| r.1).unwrap_or(0.0),
        crossover
    );
    rows
}

// ------------------------------------------------------- Figs 3/4 and 7/8

/// Figs 3–4: schedule traces, traditional vs interleaved, both patterns.
pub fn fig34_schedules(tokens: usize) -> (String, String, String, String) {
    let spec = ModelSpec::llama33_70b();
    let cluster = Cluster::lowmem_setting1();
    let popts = PlanOptions {
        empirical_tokens: 128,
        micro_batch: 1,
        bandwidth: mbps(200.0),
    };
    let alloc = plan(&spec, &cluster, &popts).unwrap().allocation;
    let bw = BandwidthTrace::fixed_mbps(200.0);
    let d = cluster.len();

    let trad_s = run_traditional(&alloc, &cluster, &bw, 1, tokens, &TradOptions::default());
    let lime_s = run_interleaved(&alloc, &cluster, &bw, 1, tokens, &ExecOptions::default());
    let trad_b = run_traditional(&alloc, &cluster, &bw, d, tokens, &TradOptions::default());
    let lime_b = run_interleaved(&alloc, &cluster, &bw, d, tokens, &ExecOptions::default());

    println!("\n== Fig. 3a: traditional pipeline + offloading (sporadic) ==");
    let a = trad_s.trace.render(d, 100);
    println!("{a}");
    println!("== Fig. 3b: interleaved pipeline (sporadic) ==");
    let b = lime_s.trace.render(d, 100);
    println!("{b}");
    println!("== Fig. 4a: traditional pipeline + offloading (bursty) ==");
    let c = trad_b.trace.render(d, 100);
    println!("{c}");
    println!("== Fig. 4b: interleaved pipeline (bursty) ==");
    let e = lime_b.trace.render(d, 100);
    println!("{e}");
    println!(
        "sporadic: traditional {:.1} ms/tok vs interleaved {:.1} ms/tok\nbursty:   traditional {:.1} ms/tok vs interleaved {:.1} ms/tok",
        trad_s.ms_per_token(),
        lime_s.ms_per_token(),
        trad_b.ms_per_token(),
        lime_b.ms_per_token()
    );
    (a, b, c, e)
}

/// Figs 7–8: latency vs segment count (too many segments hurt via T_comm,
/// too few via memory/extra offload).
pub fn fig78_segments(tokens: usize) -> Vec<(usize, f64)> {
    let spec = ModelSpec::llama33_70b();
    let cluster = Cluster::lowmem_setting1();
    let popts = PlanOptions {
        empirical_tokens: 128,
        micro_batch: 1,
        bandwidth: mbps(200.0),
    };
    let bw = BandwidthTrace::fixed_mbps(200.0);
    println!("\n== Figs 7-8: interleaved latency vs #Seg ==");
    let segs: Vec<usize> = (2..=10).collect();
    let exec = ExecOptions {
        trace_mode: TraceMode::Off,
        ..ExecOptions::default()
    };
    // One shared planning context across all candidates (plan_with_segs),
    // then the simulations fan out as pool jobs.
    let planned: Vec<(usize, crate::plan::Allocation)> = segs
        .iter()
        .zip(plan_with_segs(&spec, &cluster, &segs, &popts))
        .filter_map(|(&seg, alloc)| alloc.map(|a| (seg, a)))
        .collect();
    let rows: Vec<(usize, f64)> = pool::map_indexed(&planned, |(seg, alloc)| {
        let r = run_interleaved(alloc, &cluster, &bw, 1, tokens, &exec);
        (*seg, r.ms_per_token())
    });
    for &(seg, ms) in &rows {
        println!("  #Seg={seg:2}  {ms:9.1} ms/token");
    }
    rows
}

// ------------------------------------------------- main comparison (12-14)

/// Figs 12/13/14: all methods × {100,200} Mbps × {sporadic,bursty}.
pub fn main_comparison(env: &str, tokens: usize) -> Vec<Cell> {
    let (spec, cluster, fig) = match env {
        "e1" => (ModelSpec::llama2_13b(), Cluster::env_e1(), "Fig. 12 (E1, Llama2-13B)"),
        "e2" => (ModelSpec::qwen3_32b(), Cluster::env_e2(), "Fig. 13 (E2, Qwen3-32B)"),
        "e3" => (ModelSpec::llama33_70b(), Cluster::env_e3(), "Fig. 14 (E3, Llama3.3-70B)"),
        _ => panic!("unknown env {env}"),
    };
    let bandwidths = [100.0, 200.0];
    let cells = grid_cells(&spec, &cluster, &all(), &bandwidths, tokens);
    print_grid(fig, &cells, &bandwidths);
    cells
}

// -------------------------------------------------- low-memory (Figs 15-17)

/// Figs 15–17: extremely-low-memory settings on Llama3.3-70B.
pub fn lowmem(setting: usize, tokens: usize) -> Vec<Cell> {
    let spec = ModelSpec::llama33_70b();
    let (cluster, fig) = match setting {
        1 => (Cluster::lowmem_setting1(), "Fig. 15 (Setting 1)"),
        2 => (Cluster::lowmem_setting2(), "Fig. 16 (Setting 2)"),
        3 => (Cluster::lowmem_setting3(), "Fig. 17 (Setting 3)"),
        _ => panic!("setting must be 1..=3"),
    };
    let bandwidths = [100.0, 200.0];
    let cells = grid_cells(&spec, &cluster, &all(), &bandwidths, tokens);
    print_grid(fig, &cells, &bandwidths);
    cells
}

// ---------------------------------------------------------- Fig. 18 / Tab V

/// Fig. 18: varying bandwidth (random 50–250 Mbps walks).
pub fn fig18(tokens: usize) -> Vec<Cell> {
    let spec = ModelSpec::qwen3_32b();
    let cluster = Cluster::env_e2();
    let trace = BandwidthTrace::random_walk_mbps(0x18, 50.0, 250.0, 5, 40, tokens.max(64));
    println!("\n== Fig. 18: varying bandwidth (50-250 Mbps random walk), Qwen3-32B ==");
    let methods = all();
    let mut jobs: Vec<(usize, Pattern)> = Vec::new();
    for mi in 0..methods.len() {
        for pattern in [Pattern::Sporadic, Pattern::Bursty] {
            jobs.push((mi, pattern));
        }
    }
    let cells = pool::map_indexed(&jobs, |&(mi, pattern)| {
        let out = methods[mi].run_mode(&spec, &cluster, &trace, pattern, tokens, TraceMode::Off);
        Cell {
            method: methods[mi].name(),
            method_key: methods[mi].key(),
            bandwidth_mbps: -1.0,
            pattern,
            ms_per_token: out.ms_per_token(),
        }
    });
    for cell in &cells {
        println!("  {:32} {:?}: {}", cell.method, cell.pattern, cell.render());
    }
    cells
}

/// Table V: ablation study on the low-memory Llama3.3-70B deployment.
///
/// The adaptation machinery only matters once the KV cache outgrows the
/// offline plan's empirical-n reserve, so the sporadic run uses the full
/// `tokens` horizon and the bursty run `tokens/2` (its KV grows |D|x
/// faster) — long enough for thresholds to fire.
pub fn tab5(tokens: usize) -> Vec<(String, Option<f64>, Option<f64>)> {
    let spec = ModelSpec::llama33_70b();
    let cluster = Cluster::lowmem_setting1();
    let bw = BandwidthTrace::fixed_mbps(200.0);
    let variants = ["lime-no-kv-transfer", "lime-no-planner", "lime"];
    println!("\n== Table V: ablation (Llama3.3-70B, low-memory) ==");
    println!("{:36} {:>14} {:>14}", "method", "sporadic", "bursty");
    let rows: Vec<(String, Option<f64>, Option<f64>)> =
        pool::map_indexed(&variants, |key| {
            let m = by_name(key).unwrap();
            let spor = m
                .run_mode(&spec, &cluster, &bw, Pattern::Sporadic, tokens, TraceMode::Off)
                .ms_per_token();
            let burst = m
                .run_mode(&spec, &cluster, &bw, Pattern::Bursty, tokens / 2, TraceMode::Off)
                .ms_per_token();
            (m.name().to_string(), spor, burst)
        });
    for (name, spor, burst) in &rows {
        println!(
            "{:36} {:>11.1} ms {:>11.1} ms",
            name,
            spor.unwrap_or(f64::NAN),
            burst.unwrap_or(f64::NAN)
        );
    }
    if let (Some((_, Some(ls), Some(lb))), true) = (rows.last().cloned(), rows.len() == 3) {
        for (name, s, b) in &rows[..2] {
            if let (Some(s), Some(b)) = (s, b) {
                println!("  speedup of LIME over '{name}': {:.2}x sporadic, {:.2}x bursty", s / ls, b / lb);
            }
        }
    }
    rows
}

// ------------------------------------------------------- full-grid sweep

/// The pressure axis the lowmem sweep grids run. Single-device shapes
/// target device 0 (the Orin-64 — the planner's usual `d_target`, so
/// pressure there forces real re-planning); the multi-device shapes are
/// the paper's edge regime: a correlated thermal dip hitting devices 0–1
/// with a propagation lag, and a joint scenario where the link sags to
/// half capacity *while* device 0 is squeezed. Event steps scale with the
/// simulated horizon; events past the horizon simply never fire (tiny CI
/// runs stay valid).
fn lowmem_pressure_axis(tokens: usize) -> Vec<Script> {
    let down = tokens / 3;
    let up = (2 * tokens / 3).max(down + 1);
    let lag = (tokens / 6).max(1);
    vec![
        Script::none(),
        Script::from_mem(MemScenario::dip("dip-d0", 0, gib(4.0), down, up)),
        Script::from_mem(MemScenario::squeeze("squeeze-d0", 0, gib(6.0), tokens / 4)),
        Script::from_mem(MemScenario::correlated_dip(
            "corr-dip-d01",
            &[0, 1],
            lag,
            gib(4.0),
            down,
            up,
        )),
        Script::from_mem(MemScenario::squeeze("sq", 0, gib(6.0), tokens / 4))
            .with_bandwidth_sag(0.5, tokens / 4, (3 * tokens / 4).max(tokens / 4 + 1))
            .with_label("joint-sag-squeeze-d0"),
    ]
}

/// The device-churn axis shared by every sweep grid: the mandatory
/// no-churn baseline plus a mid-stream Down/Up blip of the cluster's
/// *last* device — the smallest-memory member in every sweep cluster, so
/// the survivor prefix is never empty and usually still plannable. The
/// event steps follow the pressure axis' thirds, so tiny CI horizons
/// still fire both the failure and the recovery inside the run.
fn churn_axis(cluster: &Cluster, tokens: usize) -> Vec<Script> {
    let down = (tokens / 3).max(1);
    let up = (2 * tokens / 3).max(down + 1);
    vec![
        Script::none(),
        Script::device_down_up("blip-last", cluster.len() - 1, down, up),
    ]
}

/// The stream point of the arrival axis for a cluster: twice the device
/// count of queued requests (so bursty admissions always need at least
/// two batches), Poisson rate 0.5 req/s on sporadic cells.
fn stream_arrivals(cluster: &Cluster) -> Vec<ArrivalSpec> {
    vec![
        ArrivalSpec::Single,
        ArrivalSpec::Stream {
            count: 2 * cluster.len(),
            lambda: 0.5,
        },
    ]
}

/// The batching-policy axis every sweep grid runs on its stream cells:
/// the FIFO baseline plus step-level continuous batching at 16 tokens per
/// KV page (vLLM's default block size). Because stream counts exceed the
/// bursty admission cap (2·|D| requests vs |D| micro-batches), the bursty
/// continuous cells genuinely overlap prefill with decode and show a
/// lower mean queueing delay than their FIFO twins.
fn batching_axis() -> Vec<BatchingSpec> {
    vec![BatchingSpec::Fifo, BatchingSpec::Continuous { page_tokens: 16 }]
}

/// The workload-mix axis every sweep grid runs on its stream cells: the
/// fixed pre-mix baseline (64-token prompts, `tokens` decode steps — the
/// exact global-knob shape, property-pinned bit-identical in
/// `rust/tests/workload_mix.rs`) plus a bimodal short-chat /
/// long-context mixture. Decode lengths scale with the horizon so tiny
/// CI sweeps stay fast, and the long mode's prompt only doubles the
/// baseline context — plan feasibility is judged from the planning
/// knobs, so the mix changes timings, never the OOM frontier.
fn workload_axis(tokens: usize) -> Vec<LengthDist> {
    vec![
        LengthDist::fixed(64, tokens),
        LengthDist::Bimodal {
            short: (32, (tokens / 2).max(1)),
            long: (128, 2 * tokens),
            long_frac: 0.25,
        },
    ]
}

/// The scenario matrices behind `--id sweep`: the three extremely-low-
/// memory settings (Figs 15–17, Llama3.3-70B) across the full bandwidth
/// axis, plus cluster-size points — 2/3/4-device subsets of the
/// heterogeneous E3 Jetson cluster (Qwen3-32B, the E2-scale model) — all
/// with `#Seg`-override, pressure-script (correlated multi-device dips
/// and joint bandwidth+memory scenarios included), arrival-process
/// (single run vs continuous 2·|D|-request stream), device-churn
/// (mid-stream Down/Up of the smallest device; the churn-capable
/// EdgeShard baseline rides the axis too and degrades honestly) and
/// workload-mix (fixed lengths vs a bimodal short-chat / long-context
/// distribution, stream cells only) axes.
fn sweep_matrices(methods: &[Box<dyn Method>], tokens: usize) -> Vec<ScenarioMatrix<'_>> {
    let mut out = Vec::new();
    let spec70 = ModelSpec::llama33_70b();
    let lowmem: [(&str, Cluster); 3] = [
        ("lowmem1", Cluster::lowmem_setting1()),
        ("lowmem2", Cluster::lowmem_setting2()),
        ("lowmem3", Cluster::lowmem_setting3()),
    ];
    for (label, cluster) in lowmem {
        let arrivals = stream_arrivals(&cluster);
        let churn = churn_axis(&cluster, tokens);
        out.push(
            ScenarioMatrix::new(
                label,
                spec70.clone(),
                cluster,
                methods,
                vec![50.0, 100.0, 150.0, 200.0, 250.0],
                vec![Pattern::Sporadic, Pattern::Bursty],
                tokens,
            )
            .with_segs(vec![SegChoice::Auto, SegChoice::Fixed(4), SegChoice::Fixed(8)])
            .with_pressure(lowmem_pressure_axis(tokens))
            .with_arrivals(arrivals)
            .with_churn(churn)
            .with_batching(batching_axis())
            .with_workloads(workload_axis(tokens)),
        );
    }

    let e3 = Cluster::env_e3();
    let spec32 = ModelSpec::qwen3_32b();
    let edges: [(&str, Vec<usize>); 3] = [
        ("edge2", vec![0, 2]),       // Orin64 + Orin32
        ("edge3", vec![0, 2, 3]),    // + XavierNX16
        ("edge4", vec![0, 1, 2, 3]), // the full E3 cluster
    ];
    for (label, idxs) in edges {
        let cluster = e3.subset(&idxs);
        let down = tokens / 3;
        let up = (2 * tokens / 3).max(down + 1);
        let dip = MemScenario::dip("dip-d0", 0, gib(4.0), down, up);
        // A correlated thermal dip across *every* device of the subset —
        // the EdgeShard-style co-located deployment where one cabinet
        // event throttles all neighbours, each lagging the previous by a
        // step.
        let all_devices: Vec<usize> = (0..cluster.len()).collect();
        let corr = MemScenario::correlated_dip("corr-dip-all", &all_devices, 1, gib(2.0), down, up);
        let arrivals = stream_arrivals(&cluster);
        let churn = churn_axis(&cluster, tokens);
        out.push(
            ScenarioMatrix::new(
                label,
                spec32.clone(),
                cluster,
                methods,
                vec![100.0, 200.0],
                vec![Pattern::Sporadic, Pattern::Bursty],
                tokens,
            )
            .with_segs(vec![SegChoice::Auto, SegChoice::Fixed(3), SegChoice::Fixed(6)])
            .with_pressure(vec![
                Script::none(),
                Script::from_mem(dip),
                Script::from_mem(corr),
            ])
            .with_arrivals(arrivals)
            .with_churn(churn)
            .with_batching(batching_axis())
            .with_workloads(workload_axis(tokens)),
        );
    }
    out
}

/// The `--id sweep` experiment: evaluate every scenario matrix —
/// extremely-low-memory settings plus cluster-size points, each crossing
/// bandwidth × pattern × method with `#Seg`-override, pressure-script
/// (correlated multi-device dips, joint bandwidth+memory scenarios),
/// arrival-process (single run vs continuous queued stream),
/// device-churn (mid-stream Down/Up with online re-planning, KV
/// migration and recovery-latency counters), batching-policy (FIFO
/// vs step-level continuous with paged-KV accounting, stream cells
/// only) and workload-mix (fixed vs bimodal per-request lengths,
/// stream cells only) axes — on the work-stealing pool, and emit **one
/// machine-readable JSON per grid** (schema `lime-sweep-v7`, validated
/// by `lime sweep-check`) into `out_dir`.
/// Returns the paths written; any I/O
/// failure is an error (the CLI exits non-zero), never a silently missing
/// artifact.
pub fn sweep(tokens: usize, out_dir: &str) -> anyhow::Result<Vec<std::path::PathBuf>> {
    use anyhow::Context;
    std::fs::create_dir_all(out_dir)
        .with_context(|| format!("sweep: cannot create output directory {out_dir}"))?;
    let methods = all();
    let matrices = sweep_matrices(&methods, tokens);
    let mut written = Vec::new();
    println!(
        "\n== sweep: {} grids × (bandwidth × pattern × {} methods, + #Seg/pressure/arrival axes on LIME) ==",
        matrices.len(),
        methods.len()
    );
    for matrix in &matrices {
        let cells = matrix.eval();
        let completed = cells.iter().filter(|c| c.ms_per_token.is_some()).count();
        let adapted: usize = cells
            .iter()
            .filter_map(|c| c.online_plans_fired)
            .sum();
        println!(
            "  grid {} ({}, {} devices): {} cells ({completed} completed, {} OOM, {adapted} online plans fired)",
            matrix.grid,
            matrix.spec.name,
            matrix.cluster.len(),
            cells.len(),
            cells.len() - completed
        );
        let json = matrix.to_json(&cells);
        let path = std::path::Path::new(out_dir).join(format!("SWEEP_{}.json", matrix.grid));
        std::fs::write(&path, format!("{json}\n"))
            .with_context(|| format!("sweep: could not write {}", path.display()))?;
        println!("  wrote {}", path.display());
        written.push(path);
    }
    Ok(written)
}

/// Collect the artifacts `lime sweep-check --dir` validates: every
/// `SWEEP_*.json` / `FLEET_*.json` directly under `dir`, sorted by path.
/// An unreadable directory or an empty match set is an `Err` — zero
/// artifacts is a failed check (the CLI exits 2), never a silent pass
/// that would let a sweep which wrote nothing slip through CI.
pub fn collect_sweep_artifacts(dir: &str) -> Result<Vec<std::path::PathBuf>, String> {
    let entries = std::fs::read_dir(dir)
        .map_err(|e| format!("sweep-check: cannot read directory {dir}: {e}"))?;
    let mut files: Vec<std::path::PathBuf> = entries
        .filter_map(|e| e.ok().map(|e| e.path()))
        // Only the artifacts sweep()/fleet write — a directory may also
        // hold bench JSONs or other tooling output.
        .filter(|p| {
            p.extension().is_some_and(|ext| ext == "json")
                && p.file_name().is_some_and(|n| {
                    let n = n.to_string_lossy();
                    n.starts_with("SWEEP_") || n.starts_with("FLEET_")
                })
        })
        .collect();
    files.sort();
    if files.is_empty() {
        return Err("sweep-check: no SWEEP_*.json or FLEET_*.json artifacts found".into());
    }
    Ok(files)
}

/// Dispatch used by `lime experiments --id <id>`. `sweep_out` is the
/// output directory for the `sweep` experiment's JSON artifacts.
pub fn run_by_id(id: &str, tokens: usize, sweep_out: &str) {
    match id {
        "fig2a" => {
            fig2a(tokens);
        }
        "fig2b" => {
            fig2b(tokens.max(256));
        }
        "fig3" | "fig4" | "fig34" => {
            fig34_schedules(tokens.min(4));
        }
        "fig7" | "fig8" | "fig78" => {
            fig78_segments(tokens);
        }
        "fig12" => {
            main_comparison("e1", tokens);
        }
        "fig13" => {
            main_comparison("e2", tokens);
        }
        "fig14" => {
            main_comparison("e3", tokens);
        }
        "lowmem" | "fig15" | "fig16" | "fig17" => {
            for s in 1..=3 {
                lowmem(s, tokens);
            }
        }
        "fig18" => {
            fig18(tokens);
        }
        "tab5" => {
            tab5(tokens);
        }
        "sweep" => {
            if let Err(e) = sweep(tokens, sweep_out) {
                eprintln!("{e:#}");
                std::process::exit(1);
            }
        }
        other => {
            eprintln!("unknown experiment id '{other}'");
            std::process::exit(2);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fig2a_pp_beats_tp() {
        // Fig. 2a headline: PP+offload 1.2x-1.6x faster than TP+offload.
        for (_, tp, pp) in fig2a(6) {
            assert!(pp < tp, "PP {pp:.1} should beat TP {tp:.1}");
        }
    }

    #[test]
    fn fig2b_kv_starts_cheap_then_crosses() {
        let rows = fig2b(400);
        // Early: KV offload cheaper than a full MHA-block read.
        assert!(rows[0].2 < rows[0].1);
        // Late: KV offload more expensive (crossover happened).
        let last = rows.last().unwrap();
        assert!(last.2 > last.1);
    }

    #[test]
    fn tab5_ordering_matches_paper() {
        let rows = tab5(160);
        let lime_s = rows[2].1.unwrap();
        let no_kv_s = rows[0].1.unwrap();
        let no_plan_s = rows[1].1.unwrap();
        // Paper: full LIME fastest; no-planner worst (0.67x), no-KV in
        // between (0.86x).
        assert!(lime_s <= no_kv_s * 1.02, "LIME {lime_s:.1} vs no-kv {no_kv_s:.1}");
        assert!(lime_s <= no_plan_s * 1.02, "LIME {lime_s:.1} vs no-planner {no_plan_s:.1}");
    }

    #[test]
    fn sweep_emits_one_valid_v7_json_per_grid() {
        use crate::util::json::Json;
        let dir = std::env::temp_dir().join(format!("lime_sweep_{}", std::process::id()));
        let out = dir.to_str().unwrap().to_string();
        let written = sweep(3, &out).expect("sweep writes its grids");
        assert_eq!(written.len(), 6, "three lowmem grids + three cluster-size grids");
        for path in &written {
            let src = std::fs::read_to_string(path).unwrap();
            let json = Json::parse(src.trim()).unwrap();
            let summary = validate_sweep(&json)
                .unwrap_or_else(|e| panic!("{}: {e}", path.display()));
            assert_eq!(summary.schema, "lime-sweep-v7");
            let lowmem = summary.grid.starts_with("lowmem");
            // Arrival cells per adaptive coordinate: 1 single + 1 stream
            // × 2 batching policies (fifo, cont16) × 2 workloads
            // (fixed, bimix25) = 5.
            // lowmem: 1 LIME × 5bw × 2pat × 3seg × 5scripts × 5arrival-cells
            //           × 2churn                                  = 1500
            //         + EdgeShard (churn-capable) 10 × 2churn     =   20
            //         + 5 rigid baselines × 10                    =   50.
            // edge:   1 LIME × 2bw × 2pat × 3seg × 3scripts × 5arrival-cells
            //           × 2churn                                  = 360
            //         + EdgeShard 4 × 2churn                      =   8
            //         + 5 rigid baselines × 4                     =  20.
            assert_eq!(summary.cells, if lowmem { 1570 } else { 388 }, "{}", summary.grid);
            assert_eq!(summary.completed + summary.oom, summary.cells);
            let mut stream_with_requests = 0usize;
            let mut churn_completed = 0usize;
            let mut continuous_with_pages = 0usize;
            let mut mixed_ragged = 0usize;
            for cell in json.get("cells").unwrap().as_arr().unwrap() {
                let key = cell.get("method").unwrap().as_str().unwrap();
                let oom = cell.get("oom").unwrap().as_bool().unwrap();
                let auto_seg = cell.get("seg").unwrap().as_str() == Some("auto");
                // LIME with its own scheduler always completes — in the
                // lowmem settings *and* on every cluster-size subset, under
                // every memory scenario. (A *forced* #Seg may be
                // legitimately infeasible: slot capacity scales with seg.)
                if key == "lime" && auto_seg {
                    assert!(!oom, "{}: {cell}", path.display());
                }
                let arrival = cell.get("arrival").unwrap().as_str().unwrap();
                if arrival != "single" && !oom {
                    assert!(
                        cell.get("requests").unwrap().get("ttft_s").is_some(),
                        "{}: stream cell without request metrics: {cell}",
                        path.display()
                    );
                    stream_with_requests += 1;
                }
                // Churn cells that completed must carry the robustness
                // counters (recovery slots, replans, migrated KV bytes).
                let churn = cell.get("churn").unwrap().as_str().unwrap();
                if churn != "none" && !oom {
                    assert!(
                        cell.get("recovery_steps").unwrap().as_arr().is_some(),
                        "{}: churn cell without recovery slots: {cell}",
                        path.display()
                    );
                    assert!(
                        cell.get("replans_fired").unwrap().as_u64().is_some()
                            && cell.get("kv_migrated_bytes").unwrap().as_u64().is_some(),
                        "{}: churn cell without counters: {cell}",
                        path.display()
                    );
                    churn_completed += 1;
                }
                // Continuous-batching cells account KV through the paged
                // allocator; FIFO cells keep the counters exactly zero.
                let batching = cell.get("batching").unwrap().as_str().unwrap();
                let pages = cell.get("kv_pages_allocated").unwrap().as_u64();
                if batching != "fifo" && !oom {
                    assert!(
                        pages.unwrap_or(0) > 0,
                        "{}: continuous cell without page accounting: {cell}",
                        path.display()
                    );
                    continuous_with_pages += 1;
                } else if !oom {
                    assert_eq!(pages, Some(0), "{}: {cell}", path.display());
                }
                // Mixed-workload cells draw per-request lengths from the
                // bimodal distribution; the arrays stay on-mode, and the
                // sporadic streams genuinely mix both modes. (Tiny bursty
                // streams may legitimately draw a single mode — e.g. the
                // 4-request edge2 burst — so raggedness is asserted per
                // grid, not per cell.)
                let workload = cell.get("workload").unwrap().as_str().unwrap();
                if workload != "fixed" && !oom {
                    let pl: Vec<u64> = cell
                        .get("requests")
                        .unwrap()
                        .get("prompt_len")
                        .unwrap()
                        .as_arr()
                        .unwrap()
                        .iter()
                        .map(|p| p.as_u64().unwrap())
                        .collect();
                    assert!(
                        pl.iter().all(|&p| p == 32 || p == 128),
                        "{}: off-mode prompt length in {cell}",
                        path.display()
                    );
                    if pl.contains(&32) && pl.contains(&128) {
                        mixed_ragged += 1;
                    }
                }
            }
            assert!(
                stream_with_requests > 0,
                "{}: no completed stream cells",
                path.display()
            );
            assert!(
                churn_completed > 0,
                "{}: no completed churn cells",
                path.display()
            );
            assert!(
                continuous_with_pages > 0,
                "{}: no completed continuous-batching cells",
                path.display()
            );
            assert!(
                mixed_ragged > 0,
                "{}: no mixed-length stream cells",
                path.display()
            );
        }
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn sweep_covers_the_new_axes() {
        // The acceptance shape: cluster-size points at 2/3/4 devices, and
        // #Seg-override / correlated multi-device / joint bandwidth+memory
        // / stream-arrival coordinates present in the evaluated cells.
        let methods = all();
        let matrices = sweep_matrices(&methods, 3);
        let sizes: std::collections::BTreeSet<usize> =
            matrices.iter().map(|m| m.cluster.len()).collect();
        assert!(sizes.contains(&2) && sizes.contains(&3) && sizes.contains(&4));
        let lowmem1 = &matrices[0];
        assert!(lowmem1.segs.len() == 3 && lowmem1.pressure.len() == 5);
        assert_eq!(lowmem1.arrivals.len(), 2);
        // Churn axis: the no-churn baseline plus one last-device blip.
        assert_eq!(lowmem1.churn.len(), 2);
        assert!(lowmem1.churn[0].churn.is_empty());
        assert!(!lowmem1.churn[1].churn.is_empty());
        assert!(matches!(
            lowmem1.arrivals[1],
            ArrivalSpec::Stream { count, .. } if count == 2 * lowmem1.cluster.len()
        ));
        // The correlated script really hits more than one device; the
        // joint script really carries both channels.
        let corr = &lowmem1.pressure[3];
        let devices: std::collections::BTreeSet<usize> =
            corr.mem.iter().map(|e| e.device).collect();
        assert!(devices.len() >= 2, "correlated dip must span devices");
        let joint = &lowmem1.pressure[4];
        assert!(!joint.mem.is_empty() && !joint.bw.is_empty());
        let cells = lowmem1.eval();
        assert!(cells.iter().any(|c| matches!(c.seg, SegChoice::Fixed(_))));
        assert!(cells.iter().any(|c| c.mem == "squeeze-d0"));
        assert!(cells.iter().any(|c| c.mem == "corr-dip-d01"));
        assert!(cells.iter().any(|c| c.mem == "joint-sag-squeeze-d0"));
        // Churn cells fire the Down/Up blip: every completed one records a
        // recovery slot, and LIME really migrates the departed KV.
        let churned: Vec<_> = cells
            .iter()
            .filter(|c| c.churn == "blip-last" && c.ms_per_token.is_some())
            .collect();
        assert!(!churned.is_empty(), "no completed churn cells");
        for c in &churned {
            assert_eq!(
                c.recovery_steps.as_ref().map(|r| r.len()),
                Some(1),
                "one Down event, one recovery slot"
            );
        }
        assert!(
            churned
                .iter()
                .any(|c| c.method_key == "lime" && c.kv_migrated_bytes.unwrap_or(0) > 0),
            "LIME never migrated KV under churn"
        );
        // Stream cells exist under BOTH arrival patterns and carry
        // per-request metrics (the continuous-serving acceptance shape).
        for pattern in [Pattern::Sporadic, Pattern::Bursty] {
            let stream = cells
                .iter()
                .find(|c| c.arrival != "single" && c.pattern == pattern && c.requests.is_some())
                .unwrap_or_else(|| panic!("no completed {pattern:?} stream cell"));
            let req = stream.requests.as_ref().unwrap();
            assert_eq!(req.queueing_delay_s.len(), 2 * lowmem1.cluster.len());
            assert!(req.ttft_s.iter().all(|&t| t > 0.0));
        }
        // Batching axis: FIFO baseline plus one continuous policy, and
        // continuous cells really account KV through the paged allocator.
        assert_eq!(lowmem1.batching.len(), 2);
        assert_eq!(lowmem1.batching[1], BatchingSpec::Continuous { page_tokens: 16 });
        let cont16 = cells
            .iter()
            .find(|c| c.batching == "cont16" && c.ms_per_token.is_some())
            .expect("no completed cont16 cell");
        assert!(cont16.kv_pages_allocated.unwrap() > 0);
        assert_eq!(cont16.kv_pages_spilled, Some(0), "sweep budget is no-spill");
        let frag = cont16.fragmentation.unwrap();
        assert!((0.0..=1.0).contains(&frag), "fragmentation {frag} out of [0,1]");
        // Workload axis: the fixed pre-mix baseline plus one bimodal
        // short-chat / long-context mix, and mixed cells really carry
        // ragged per-request length arrays (10 draws at 25% long mix
        // both modes under either arrival pattern).
        assert_eq!(lowmem1.workloads.len(), 2);
        assert!(lowmem1.workloads[0].is_fixed());
        assert_eq!(lowmem1.workloads[1].label(), "bimix25");
        for pattern in [Pattern::Sporadic, Pattern::Bursty] {
            let mixed = cells
                .iter()
                .find(|c| {
                    c.workload == "bimix25" && c.pattern == pattern && c.requests.is_some()
                })
                .unwrap_or_else(|| panic!("no completed {pattern:?} bimix25 cell"));
            let req = mixed.requests.as_ref().unwrap();
            assert_eq!(req.prompt_len.len(), 2 * lowmem1.cluster.len());
            assert!(req.prompt_len.iter().all(|&p| p == 32 || p == 128));
            assert!(
                req.prompt_len.contains(&32) && req.prompt_len.contains(&128),
                "bimodal stream must mix both modes: {:?}",
                req.prompt_len
            );
            assert!(req.steps.iter().all(|&s| s == 1 || s == 6));
        }
        // The headline acceptance cell: under BURSTY arrivals the stream
        // count 2·|D| exceeds the admission cap |D|, so FIFO queues a full
        // first epoch while continuous admits between decode steps — mean
        // queueing delay must drop STRICTLY, at every bandwidth point of
        // the unperturbed (seg-auto, no-pressure, no-churn) LIME slice.
        let mean_queueing = |c: &&ScenarioCell| {
            let q = &c.requests.as_ref().unwrap().queueing_delay_s;
            q.iter().sum::<f64>() / q.len() as f64
        };
        let slice = |batching: &str| -> Vec<&ScenarioCell> {
            cells
                .iter()
                .filter(|c| {
                    c.method_key == "lime"
                        && c.pattern == Pattern::Bursty
                        && c.seg == SegChoice::Auto
                        && c.mem == "none"
                        && c.churn == "none"
                        && c.batching == batching
                        && c.requests.is_some()
                })
                .collect()
        };
        let fifo = slice("fifo");
        let cont = slice("cont16");
        assert!(!fifo.is_empty() && fifo.len() == cont.len(), "twin slices must pair up");
        for (f, c) in fifo.iter().zip(&cont) {
            assert_eq!(f.bandwidth_mbps, c.bandwidth_mbps, "twins must share coordinates");
            assert!(
                mean_queueing(c) < mean_queueing(f),
                "continuous must strictly beat FIFO queueing at {} Mbps: {} vs {}",
                f.bandwidth_mbps,
                mean_queueing(c),
                mean_queueing(f)
            );
        }
        // Every edge matrix carries its whole-subset correlated dip.
        for m in &matrices[3..] {
            let corr = &m.pressure[2];
            assert_eq!(
                corr.mem.iter().map(|e| e.device).collect::<std::collections::BTreeSet<_>>().len(),
                m.cluster.len(),
                "{}: correlated dip must span the whole subset",
                m.grid
            );
        }
    }

    #[test]
    fn collect_sweep_artifacts_guards_the_empty_directory() {
        let dir = std::env::temp_dir().join(format!("lime_collect_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let d = dir.to_str().unwrap();
        // Unreadable directory: a distinct, descriptive error.
        let missing = dir.join("nope");
        let err = collect_sweep_artifacts(missing.to_str().unwrap()).unwrap_err();
        assert!(err.contains("cannot read directory"), "{err}");
        // A directory with only decoys counts as ZERO artifacts — that is
        // the regression this guard exists for (sweep wrote nothing, or
        // the glob drifted), so it must be an error, not an empty Ok.
        std::fs::write(dir.join("bench.json"), "{}").unwrap();
        std::fs::write(dir.join("SWEEP_notes.txt"), "").unwrap();
        let err = collect_sweep_artifacts(d).unwrap_err();
        assert!(err.contains("no SWEEP_*.json or FLEET_*.json"), "{err}");
        // Real artifacts are picked up sorted; decoys stay excluded.
        std::fs::write(dir.join("SWEEP_g.json"), "{}").unwrap();
        std::fs::write(dir.join("FLEET_f.json"), "{}").unwrap();
        let files = collect_sweep_artifacts(d).unwrap();
        let names: Vec<_> = files
            .iter()
            .map(|p| p.file_name().unwrap().to_string_lossy().into_owned())
            .collect();
        assert_eq!(names, ["FLEET_f.json", "SWEEP_g.json"]);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn lowmem3_marks_oom_for_rigid_methods() {
        let cells = lowmem(3, 6);
        let oom = |name: &str| {
            cells
                .iter()
                .filter(|c| c.method == name)
                .all(|c| c.ms_per_token.is_none())
        };
        assert!(oom("Galaxy"));
        assert!(oom("EdgeShard"));
        assert!(oom("Pipeline parallelism"));
        // LIME always completes.
        assert!(cells
            .iter()
            .filter(|c| c.method == "LIME")
            .all(|c| c.ms_per_token.is_some()));
    }
}
