//! Scenario-matrix sweeps: the composable generalization of the fixed
//! (method × bandwidth × pattern) experiment grid.
//!
//! A [`ScenarioMatrix`] crosses the classic axes with three new ones:
//!
//! * **cluster-size** — one matrix per [`Cluster`] point (2/3/4-device
//!   subsets of the heterogeneous environments, carved with
//!   [`Cluster::subset`]); the sweep emits one JSON per matrix.
//! * **`#Seg`-override** — [`SegChoice::Fixed`] candidates planned through
//!   [`plan_with_segs`], which shares one `SegSweepCtx` across every
//!   explicit candidate of a planning point; [`SegChoice::Auto`] is the
//!   scheduler's own Alg. 1 pick.
//! * **pressure** — scripted fluctuation [`Script`]s: single- and
//!   multi-device memory events (correlated thermal dips with lag,
//!   staggered squeezes, recovery ramps) *and* bandwidth capacity events,
//!   driven jointly through `adapt::OnlinePlanner::apply_pressure`, the
//!   KV-transfer protocol, and the link model mid-simulation
//!   ([`crate::pipeline::run_interleaved_scripted`]), so the §IV-D online
//!   adaptation machinery shows up in sweep outputs. On stream cells
//!   (below) scripts fire on the *stream* step timeline, spanning
//!   requests.
//! * **arrival process** — [`ArrivalSpec`]: the legacy single batched run
//!   ([`ArrivalSpec::Single`], the baseline point) vs a continuous stream
//!   of `count` queued requests ([`ArrivalSpec::Stream`]) served FIFO
//!   through `serve::simqueue` on one shared cluster timeline. Stream
//!   arrivals follow the cell's *pattern* coordinate (§V-A: sporadic →
//!   Poisson at `lambda` req/s, bursty → simultaneous submission) and
//!   admission batches are capped at the pattern's micro-batch count.
//!   Stream cells carry request-level metric arrays (queueing delay,
//!   TTFT, time between tokens).
//! * **batching policy** — [`BatchingSpec`]: queue-then-drain FIFO
//!   admission ([`BatchingSpec::Fifo`], the baseline point) vs step-level
//!   continuous batching ([`BatchingSpec::Continuous`]) through
//!   [`crate::serve::BatchingOpts`], with KV accounted by the paged
//!   allocator model (`serve::kvpages`). Only stream cells of adaptive
//!   methods expand along this axis — single-run and baseline cells are
//!   pinned to the FIFO label. Continuous cells surface the
//!   `kv_pages_allocated`/`kv_pages_spilled`/`fragmentation` counters.
//! * **device churn** — churn-only [`Script`]s (Down/Up faults on the
//!   stream step timeline) composed with the pressure axis per cell.
//!   Adaptive methods re-plan onto the survivors and migrate departed KV
//!   (cells record `replans_fired`, `kv_migrated_bytes` and per-fault
//!   `recovery_steps`); the churn-capable EdgeShard baseline expands
//!   along this axis alone and degrades without re-planning — the
//!   recovery-latency comparison the churn artifacts exist for.
//! * **workload mix** — [`LengthDist`]: the per-request length
//!   distribution stream cells draw their `(prompt_len, steps)` pairs
//!   from. The baseline point is the degenerate
//!   [`LengthDist::Fixed`] shape (every request prefills
//!   `prompt_tokens` and decodes the matrix's `tokens`) — bit-identical
//!   to the pre-mix streams; further points (bimodal short-chat /
//!   long-context mixes, uniform or truncated-geometric lengths) make
//!   request raggedness a sweepable quantity. Like batching, the axis
//!   expands stream cells of adaptive methods only, and cells record
//!   each request's own lengths in the `requests.prompt_len`/`steps`
//!   arrays.
//!
//! The override axes only have meaning for methods that plan offline and
//! adapt online (the LIME family — [`Method::adaptive_exec`] returns
//! `Some`); baseline methods are measured once per (bandwidth, pattern) at
//! the matrix's baseline point (auto seg, no pressure, single run), which
//! every matrix is required to contain.
//!
//! Cells are independent simulations and evaluate on the persistent
//! work-stealing pool with results written by index —
//! [`ScenarioMatrix::eval`] is bit-identical to
//! [`ScenarioMatrix::eval_sequential`] at any worker count (pinned in
//! `rust/tests/pool.rs`). Artifacts serialize as schema `lime-sweep-v7`,
//! a strict superset of `lime-sweep-v6` (itself a strict superset of
//! v5/v4/v3/v2): every v6 key keeps its meaning, plus the
//! `axes.workloads` metadata, a per-cell `workload` coordinate, and the
//! per-request `prompt_len`/`steps` arrays inside each stream cell's
//! `requests` object; [`validate_sweep`] accepts v2 through v7 and is
//! the machine check behind `lime sweep-check` and the CI artifact
//! gate. See `docs/SWEEPS.md` for the full schema reference.

use crate::adapt::{MemScenario, Script};
use crate::baselines::{by_name, plan_opts, Method};
use crate::cluster::Cluster;
use crate::model::ModelSpec;
use crate::net::BandwidthTrace;
use crate::pipeline::{run_interleaved_scripted, ExecOptions};
use crate::plan::{plan, plan_with_segs, Allocation};
use crate::serve::kvpages::KvPageConfig;
use crate::serve::simqueue::{serve_interleaved_opts, BatchingOpts};
use crate::sim::TraceMode;
use crate::util::json::{obj, Json};
use crate::util::pool;
use crate::workload::{stream_requests_mix, LengthDist, Pattern};

/// One value of the `#Seg`-override axis.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum SegChoice {
    /// Let the offline scheduler pick `#Seg` (Alg. 1 lines 31–38).
    Auto,
    /// Force this segment count (≥ 2), planned via [`plan_with_segs`].
    Fixed(usize),
}

impl SegChoice {
    fn json(&self) -> Json {
        match self {
            SegChoice::Auto => "auto".into(),
            SegChoice::Fixed(k) => (*k).into(),
        }
    }
}

/// Deterministic seed for the arrival-stream generator — fixed so every
/// cell of a matrix (and every worker count) draws the same stream.
const STREAM_SEED: u64 = 0x51DE_0A01;

/// One value of the arrival-process axis.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum ArrivalSpec {
    /// The legacy single batched run (micro-batch count from the pattern)
    /// — the baseline point every matrix starts with.
    Single,
    /// A continuous stream of `count` queued requests served FIFO through
    /// `serve::simqueue`. Arrival times follow the cell's pattern
    /// coordinate: sporadic → Poisson at `lambda` req/s, bursty → all at
    /// t = 0 (`lambda` unused). Each request decodes the matrix's
    /// `tokens`; admission batches are capped at the pattern's micro-batch
    /// count.
    Stream { count: usize, lambda: f64 },
}

impl ArrivalSpec {
    /// Stable axis label used as the per-cell coordinate in artifacts.
    ///
    /// The label encodes the request count only, so an axis may not carry
    /// two stream points with the same `count` and different rates —
    /// `with_arrivals` rejects that as a duplicate label. A
    /// rate-sensitivity axis should vary `count` alongside `lambda` (or
    /// run one matrix per rate); keeping `lambda` out of the label keeps
    /// cell coordinates comparable across artifacts.
    pub fn label(&self) -> String {
        match self {
            ArrivalSpec::Single => "single".into(),
            ArrivalSpec::Stream { count, .. } => format!("stream{count}"),
        }
    }

    fn json(&self) -> Json {
        match self {
            ArrivalSpec::Single => obj(&[
                ("label", "single".into()),
                ("kind", "single".into()),
            ]),
            ArrivalSpec::Stream { count, lambda } => obj(&[
                ("label", self.label().into()),
                ("kind", "stream".into()),
                ("count", (*count).into()),
                ("lambda", Json::Num(*lambda)),
            ]),
        }
    }
}

/// One value of the batching-policy axis — how stream cells admit queued
/// requests into the decode batch.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum BatchingSpec {
    /// Queue-then-drain FIFO admission (the baseline point): an admitted
    /// batch runs to completion before the next admission forms, and KV
    /// is modelled as a contiguous preallocation (no page accounting).
    Fifo,
    /// Step-level continuous batching through
    /// [`crate::serve::BatchingOpts`]: finished requests leave the batch
    /// between decode steps, waiting requests join mid-flight, and the
    /// next admission's prefill overlaps the current decode. KV is
    /// accounted through the paged allocator model
    /// ([`crate::serve::KvPagePool`]) at `page_tokens` tokens per page;
    /// sweep cells size the page budget so a full admissible batch stays
    /// resident, making FIFO-vs-continuous deltas pure admission-policy
    /// effects (spill costing is exercised by the simqueue/kvpages
    /// tests instead).
    Continuous { page_tokens: usize },
}

impl BatchingSpec {
    /// Stable axis label used as the per-cell coordinate in artifacts.
    pub fn label(&self) -> String {
        match self {
            BatchingSpec::Fifo => "fifo".into(),
            BatchingSpec::Continuous { page_tokens } => format!("cont{page_tokens}"),
        }
    }

    fn json(&self) -> Json {
        match self {
            BatchingSpec::Fifo => obj(&[("label", "fifo".into()), ("mode", "fifo".into())]),
            BatchingSpec::Continuous { page_tokens } => obj(&[
                ("label", self.label().into()),
                ("mode", "continuous".into()),
                ("page_tokens", (*page_tokens).into()),
            ]),
        }
    }
}

/// Axis metadata of one workload point (`axes.workloads[]`): the label,
/// the distribution kind, and its parameters.
fn workload_json(d: &LengthDist) -> Json {
    match *d {
        LengthDist::Fixed {
            prompt_tokens,
            steps,
        } => obj(&[
            ("label", d.label().into()),
            ("kind", "fixed".into()),
            ("prompt_tokens", prompt_tokens.into()),
            ("steps", steps.into()),
        ]),
        LengthDist::Uniform { prompt, steps } => obj(&[
            ("label", d.label().into()),
            ("kind", "uniform".into()),
            ("prompt_min", prompt.0.into()),
            ("prompt_max", prompt.1.into()),
            ("steps_min", steps.0.into()),
            ("steps_max", steps.1.into()),
        ]),
        LengthDist::Bimodal {
            short,
            long,
            long_frac,
        } => obj(&[
            ("label", d.label().into()),
            ("kind", "bimodal".into()),
            ("short_prompt", short.0.into()),
            ("short_steps", short.1.into()),
            ("long_prompt", long.0.into()),
            ("long_steps", long.1.into()),
            ("long_frac", Json::Num(long_frac)),
        ]),
        LengthDist::Geometric {
            prompt_tokens,
            mean_steps,
            max_steps,
        } => obj(&[
            ("label", d.label().into()),
            ("kind", "geometric".into()),
            ("prompt_tokens", prompt_tokens.into()),
            ("mean_steps", mean_steps.into()),
            ("max_steps", max_steps.into()),
        ]),
    }
}

/// Request-level metric arrays of one stream cell (one entry per
/// request; seconds for the latency arrays, token counts for the length
/// arrays). Entries are in admission order on FIFO cells and in
/// completion order on continuous-batching cells — see
/// `docs/SERVING.md`.
#[derive(Debug, Clone, PartialEq)]
pub struct RequestLevel {
    pub queueing_delay_s: Vec<f64>,
    pub ttft_s: Vec<f64>,
    pub tbt_s: Vec<f64>,
    /// Each request's own prompt length (v7 workload axis) — constant on
    /// fixed-workload cells, ragged on mixed ones.
    pub prompt_len: Vec<usize>,
    /// Each request's own decode length.
    pub steps: Vec<usize>,
}

/// One evaluated matrix cell. Superset of the legacy grid
/// [`crate::experiments::Cell`]: the axis coordinates plus the §IV-D
/// adaptation counters that make online behaviour visible in artifacts.
#[derive(Debug, Clone, PartialEq)]
pub struct ScenarioCell {
    pub method: &'static str,
    /// Stable machine key ([`Method::key`]).
    pub method_key: &'static str,
    pub bandwidth_mbps: f64,
    pub pattern: Pattern,
    pub seg: SegChoice,
    /// Label of the pressure [`Script`] this cell ran under.
    pub mem: String,
    /// Label of the [`ArrivalSpec`] this cell ran under (`"single"` for
    /// the legacy one-run point).
    pub arrival: String,
    /// Label of the churn [`Script`] this cell ran under (`"none"` for the
    /// baseline point).
    pub churn: String,
    /// Label of the [`BatchingSpec`] this cell ran under (`"fifo"` for
    /// the baseline point; continuous labels appear only on stream cells
    /// of adaptive methods).
    pub batching: String,
    /// Label of the [`LengthDist`] workload this cell's stream drew its
    /// request lengths from (`"fixed"` for the baseline point; mixed
    /// labels appear only on stream cells of adaptive methods).
    pub workload: String,
    /// `#Seg` of the allocation actually executed (None for baseline
    /// methods and OOM cells).
    pub planned_seg: Option<usize>,
    /// `None` = OOM (planning or placement failed). On stream cells this
    /// is the mean decode latency per generated token (queueing shows up
    /// in `requests` instead).
    pub ms_per_token: Option<f64>,
    pub online_plans_fired: Option<usize>,
    pub kv_tokens_transferred: Option<u64>,
    pub emergency_steps: Option<usize>,
    /// Link acquisitions that waited on the busy shared medium — inflated
    /// by scripted bandwidth sags.
    pub bw_stalls: Option<u64>,
    /// Online re-plans fired by churn events (Down re-plans onto the
    /// survivors, Up re-expansions). Zero for methods that cannot re-plan.
    pub replans_fired: Option<usize>,
    /// KV-cache bytes migrated off departing (and back onto rejoining)
    /// devices over the Eq. 8 volume model.
    pub kv_migrated_bytes: Option<u64>,
    /// Per-`Down`-fault recovery latency in steps (step time back within
    /// tolerance of the pre-fault baseline); `None` entries are faults the
    /// run never recovered from.
    pub recovery_steps: Option<Vec<Option<usize>>>,
    /// Cumulative KV pages the paged allocator model handed out. Zero
    /// everywhere except continuous-batching stream cells (FIFO models KV
    /// as a contiguous preallocation); `None` = OOM.
    pub kv_pages_allocated: Option<u64>,
    /// Cumulative KV pages spilled to SSD under page-budget pressure
    /// (write-only, costed via the Eq. 8 volume model). Zero on sweep
    /// cells by construction — the grids run a no-spill budget; `None` =
    /// OOM.
    pub kv_pages_spilled: Option<u64>,
    /// Peak internal fragmentation of the paged model: the wasted
    /// fraction of allocated page capacity, in `[0, 1]`. Exactly 0.0 off
    /// the continuous points; `None` = OOM.
    pub fragmentation: Option<f64>,
    /// Request-level metrics — `Some` exactly on completed stream cells.
    pub requests: Option<RequestLevel>,
}

impl ScenarioCell {
    pub fn is_oot(&self) -> bool {
        matches!(self.ms_per_token, Some(ms) if ms > self.pattern.oot_limit_ms())
    }
}

pub(crate) fn pattern_str(p: Pattern) -> &'static str {
    match p {
        Pattern::Sporadic => "sporadic",
        Pattern::Bursty => "bursty",
    }
}

/// The composable scenario matrix. Axis invariants (checked on every
/// evaluation/serialization):
///
/// * every axis is non-empty;
/// * `segs[0] == SegChoice::Auto`, `pressure[0]` has no events on either
///   channel, and `arrivals[0] == ArrivalSpec::Single` — the baseline
///   point non-adaptive methods are measured at;
/// * fixed seg values are ≥ 2 and unique; script labels are unique;
///   arrival labels are unique, stream counts ≥ 1, lambdas finite and
///   positive;
/// * memory events address devices inside the cluster; bandwidth scales
///   are finite and positive.
pub struct ScenarioMatrix<'a> {
    /// Grid label — names the JSON artifact (`SWEEP_<grid>.json`).
    pub grid: String,
    pub spec: ModelSpec,
    pub cluster: Cluster,
    pub methods: &'a [Box<dyn Method>],
    pub bandwidths_mbps: Vec<f64>,
    pub patterns: Vec<Pattern>,
    pub segs: Vec<SegChoice>,
    /// The pressure axis: joint memory/bandwidth fluctuation scripts.
    pub pressure: Vec<Script>,
    /// The arrival-process axis: single batched run vs queued streams.
    pub arrivals: Vec<ArrivalSpec>,
    /// The device-churn axis: churn-only scripts (Down/Up faults on the
    /// stream step timeline). Composed with the pressure axis per cell for
    /// adaptive methods; churn-capable baselines (EdgeShard) expand along
    /// this axis alone.
    pub churn: Vec<Script>,
    /// The batching-policy axis: FIFO vs step-level continuous admission.
    /// Expands stream-arrival cells of adaptive methods only.
    pub batching: Vec<BatchingSpec>,
    /// The workload-mix axis: the per-request length distribution stream
    /// cells draw from. Expands stream-arrival cells of adaptive methods
    /// only; `workloads[0]` must be the fixed baseline shape.
    pub workloads: Vec<LengthDist>,
    pub tokens: usize,
}

/// Pre-planned allocations of one (bandwidth, pattern) planning point.
struct PlannedPoint {
    auto: Option<Allocation>,
    /// One entry per `SegChoice::Fixed` in axis order.
    fixed: Vec<Option<Allocation>>,
}

/// Axis coordinates of one cell (indices into the matrix axes).
#[derive(Debug, Clone, Copy)]
struct PointRef {
    mi: usize,
    bi: usize,
    pi: usize,
    si: usize,
    mj: usize,
    ai: usize,
    ki: usize,
    wi: usize,
    ci: usize,
}

impl<'a> ScenarioMatrix<'a> {
    /// A matrix at the baseline point of the new axes — exactly the legacy
    /// (method × bandwidth × pattern) grid.
    pub fn new(
        grid: &str,
        spec: ModelSpec,
        cluster: Cluster,
        methods: &'a [Box<dyn Method>],
        bandwidths_mbps: Vec<f64>,
        patterns: Vec<Pattern>,
        tokens: usize,
    ) -> Self {
        ScenarioMatrix {
            grid: grid.to_string(),
            spec,
            cluster,
            methods,
            bandwidths_mbps,
            patterns,
            segs: vec![SegChoice::Auto],
            pressure: vec![Script::none()],
            arrivals: vec![ArrivalSpec::Single],
            churn: vec![Script::none()],
            batching: vec![BatchingSpec::Fifo],
            // The fixed baseline shape: every stream request prefills the
            // executor's default prompt length and decodes `tokens` —
            // exactly the pre-v7 stream generator.
            workloads: vec![LengthDist::fixed(
                ExecOptions::default().prompt_tokens,
                tokens,
            )],
            tokens,
        }
    }

    /// Replace the `#Seg`-override axis (must start with `Auto`).
    pub fn with_segs(mut self, segs: Vec<SegChoice>) -> Self {
        self.segs = segs;
        self.assert_valid();
        self
    }

    /// Replace the pressure axis with memory-only scenarios (must start
    /// with a no-event scenario). Convenience wrapper over
    /// [`ScenarioMatrix::with_pressure`] for callers that never script
    /// the bandwidth channel.
    pub fn with_mem_scenarios(self, mems: Vec<MemScenario>) -> Self {
        self.with_pressure(mems.into_iter().map(Script::from).collect())
    }

    /// Replace the pressure axis (must start with a script that has no
    /// events on either channel).
    pub fn with_pressure(mut self, scripts: Vec<Script>) -> Self {
        self.pressure = scripts;
        self.assert_valid();
        self
    }

    /// Replace the arrival-process axis (must start with
    /// [`ArrivalSpec::Single`], the baseline point).
    pub fn with_arrivals(mut self, arrivals: Vec<ArrivalSpec>) -> Self {
        self.arrivals = arrivals;
        self.assert_valid();
        self
    }

    /// Replace the device-churn axis. Scripts must be churn-only (memory
    /// and bandwidth pressure compose on the pressure axis), the first
    /// entry must have no events (the baseline point), and no prefix of
    /// any script's timeline may leave the cluster without a surviving
    /// device.
    pub fn with_churn(mut self, churn: Vec<Script>) -> Self {
        self.churn = churn;
        self.assert_valid();
        self
    }

    /// Replace the batching-policy axis (must start with
    /// [`BatchingSpec::Fifo`], the baseline point). The axis expands
    /// stream-arrival cells of adaptive methods only — a matrix without a
    /// stream arrival evaluates the same cells regardless of this axis.
    pub fn with_batching(mut self, batching: Vec<BatchingSpec>) -> Self {
        self.batching = batching;
        self.assert_valid();
        self
    }

    /// Replace the workload-mix axis (must start with a
    /// [`LengthDist::Fixed`] entry, the baseline point every pre-v7
    /// artifact implicitly ran). Like batching, the axis expands
    /// stream-arrival cells of adaptive methods only.
    pub fn with_workloads(mut self, workloads: Vec<LengthDist>) -> Self {
        self.workloads = workloads;
        self.assert_valid();
        self
    }

    fn assert_valid(&self) {
        assert!(!self.bandwidths_mbps.is_empty(), "matrix needs bandwidths");
        assert!(!self.patterns.is_empty(), "matrix needs patterns");
        assert!(!self.methods.is_empty(), "matrix needs methods");
        assert!(
            matches!(self.segs.first(), Some(SegChoice::Auto)),
            "segs[0] must be SegChoice::Auto (the baseline point)"
        );
        let mut seen = std::collections::BTreeSet::new();
        for s in &self.segs {
            if let SegChoice::Fixed(k) = s {
                assert!(*k >= 2, "fixed #Seg must be >= 2, got {k}");
                assert!(seen.insert(*k), "duplicate fixed #Seg {k}");
            }
        }
        assert!(
            self.pressure.first().is_some_and(Script::is_none),
            "pressure[0] must have no events (the baseline point)"
        );
        assert!(
            matches!(self.arrivals.first(), Some(ArrivalSpec::Single)),
            "arrivals[0] must be ArrivalSpec::Single (the baseline point)"
        );
        let mut arrival_labels = std::collections::BTreeSet::new();
        for a in &self.arrivals {
            assert!(
                arrival_labels.insert(a.label()),
                "duplicate arrival spec '{}'",
                a.label()
            );
            if let ArrivalSpec::Stream { count, lambda } = a {
                assert!(*count >= 1, "stream arrival needs at least one request");
                assert!(
                    lambda.is_finite() && *lambda > 0.0,
                    "stream arrival rate must be finite and > 0, got {lambda}"
                );
            }
        }
        let mut labels = std::collections::BTreeSet::new();
        for script in &self.pressure {
            assert!(
                labels.insert(script.label.as_str()),
                "duplicate scenario '{}'",
                script.label
            );
            for ev in &script.mem {
                assert!(
                    ev.device < self.cluster.len(),
                    "scenario '{}' addresses device {} of a {}-device cluster",
                    script.label,
                    ev.device,
                    self.cluster.len()
                );
            }
            for ev in &script.bw {
                assert!(
                    ev.scale.is_finite() && ev.scale > 0.0,
                    "scenario '{}' has non-positive bandwidth scale {}",
                    script.label,
                    ev.scale
                );
            }
            assert!(
                script.churn.is_empty(),
                "pressure scenario '{}' carries churn events — put them on the churn axis",
                script.label
            );
        }
        assert!(
            matches!(self.batching.first(), Some(BatchingSpec::Fifo)),
            "batching[0] must be BatchingSpec::Fifo (the baseline point)"
        );
        let mut batching_labels = std::collections::BTreeSet::new();
        for b in &self.batching {
            assert!(
                batching_labels.insert(b.label()),
                "duplicate batching spec '{}'",
                b.label()
            );
            if let BatchingSpec::Continuous { page_tokens } = b {
                assert!(*page_tokens >= 1, "continuous batching needs page_tokens >= 1");
            }
        }
        assert!(
            self.workloads.first().is_some_and(LengthDist::is_fixed),
            "workloads[0] must be a fixed length distribution (the baseline point)"
        );
        let mut workload_labels = std::collections::BTreeSet::new();
        for w in &self.workloads {
            assert!(
                workload_labels.insert(w.label()),
                "duplicate workload '{}'",
                w.label()
            );
            if let LengthDist::Uniform { prompt, steps } = w {
                assert!(
                    prompt.0 <= prompt.1 && steps.0 <= steps.1,
                    "workload '{}' has an unordered range",
                    w.label()
                );
            }
            if let LengthDist::Bimodal { long_frac, .. } = w {
                assert!(
                    long_frac.is_finite() && (0.0..=1.0).contains(long_frac),
                    "workload '{}' needs long_frac in [0, 1]",
                    w.label()
                );
            }
            if let LengthDist::Geometric { max_steps, .. } = w {
                assert!(
                    *max_steps >= 1,
                    "workload '{}' needs max_steps >= 1",
                    w.label()
                );
            }
        }
        assert!(
            self.churn.first().is_some_and(|s| s.churn.is_empty()),
            "churn[0] must have no churn events (the baseline point)"
        );
        let mut churn_labels = std::collections::BTreeSet::new();
        for script in &self.churn {
            assert!(
                churn_labels.insert(script.label.as_str()),
                "duplicate churn script '{}'",
                script.label
            );
            assert!(
                script.mem.is_empty() && script.bw.is_empty(),
                "churn script '{}' carries pressure events — put them on the pressure axis",
                script.label
            );
            // Every prefix of the timeline must leave a survivor: the
            // executor core treats losing the last device as a structured
            // error, and the stream driver relies on this check to unwrap.
            let mut down = vec![false; self.cluster.len()];
            for ev in &script.churn {
                assert!(
                    ev.device < self.cluster.len(),
                    "churn script '{}' addresses device {} of a {}-device cluster",
                    script.label,
                    ev.device,
                    self.cluster.len()
                );
                match ev.kind {
                    crate::adapt::ChurnKind::Down => down[ev.device] = true,
                    crate::adapt::ChurnKind::Up => down[ev.device] = false,
                }
                assert!(
                    down.iter().any(|d| !d),
                    "churn script '{}' leaves no surviving device at step {}",
                    script.label,
                    ev.at_step
                );
            }
        }
    }

    /// Cell coordinates in deterministic (index) order: methods outermost,
    /// then bandwidths, patterns, and — for adaptive methods — the seg,
    /// pressure, arrival, batching, workload and churn axes. The batching
    /// and workload axes only expand on stream-arrival points (single
    /// runs have no admission loop to re-batch and no stream to draw
    /// lengths for); churn-capable baselines (EdgeShard) expand along the
    /// churn axis only; other baselines stay on the single baseline
    /// point. With singleton override axes this is exactly the legacy
    /// grid's job order.
    fn points(&self) -> Vec<PointRef> {
        let mut pts = Vec::new();
        for mi in 0..self.methods.len() {
            let adaptive = self.methods[mi].adaptive_exec().is_some();
            let churny = self.methods[mi].churn_capable();
            for bi in 0..self.bandwidths_mbps.len() {
                for pi in 0..self.patterns.len() {
                    if adaptive {
                        for si in 0..self.segs.len() {
                            for mj in 0..self.pressure.len() {
                                for ai in 0..self.arrivals.len() {
                                    let stream =
                                        matches!(self.arrivals[ai], ArrivalSpec::Stream { .. });
                                    let batch_pts = if stream { self.batching.len() } else { 1 };
                                    let wl_pts = if stream { self.workloads.len() } else { 1 };
                                    for ki in 0..batch_pts {
                                        for wi in 0..wl_pts {
                                            for ci in 0..self.churn.len() {
                                                pts.push(PointRef {
                                                    mi,
                                                    bi,
                                                    pi,
                                                    si,
                                                    mj,
                                                    ai,
                                                    ki,
                                                    wi,
                                                    ci,
                                                });
                                            }
                                        }
                                    }
                                }
                            }
                        }
                    } else {
                        let churn_pts = if churny { self.churn.len() } else { 1 };
                        for ci in 0..churn_pts {
                            pts.push(PointRef {
                                mi,
                                bi,
                                pi,
                                si: 0,
                                mj: 0,
                                ai: 0,
                                ki: 0,
                                wi: 0,
                                ci,
                            });
                        }
                    }
                }
            }
        }
        pts
    }

    /// Total cells this matrix evaluates.
    pub fn cell_count(&self) -> usize {
        let base = self.bandwidths_mbps.len() * self.patterns.len();
        // The batching and workload axes multiply stream-arrival points
        // only.
        let arrival_cells: usize = self
            .arrivals
            .iter()
            .map(|a| match a {
                ArrivalSpec::Single => 1,
                ArrivalSpec::Stream { .. } => self.batching.len() * self.workloads.len(),
            })
            .sum();
        self.methods
            .iter()
            .map(|m| {
                if m.adaptive_exec().is_some() {
                    base * self.segs.len() * self.pressure.len() * arrival_cells * self.churn.len()
                } else if m.churn_capable() {
                    base * self.churn.len()
                } else {
                    base
                }
            })
            .sum()
    }

    /// Evaluate every cell on the work-stealing pool. Results are written
    /// by index, so the returned order — and every byte of the JSON built
    /// from it — is identical to [`ScenarioMatrix::eval_sequential`] at
    /// any worker count.
    pub fn eval(&self) -> Vec<ScenarioCell> {
        self.eval_impl(true)
    }

    /// The sequential bit-determinism reference for [`ScenarioMatrix::eval`].
    pub fn eval_sequential(&self) -> Vec<ScenarioCell> {
        self.eval_impl(false)
    }

    fn eval_impl(&self, parallel: bool) -> Vec<ScenarioCell> {
        self.assert_valid();
        // Positions of the Fixed entries within the seg axis, so cells can
        // index the pre-planned candidate list.
        let mut fixed_segs: Vec<usize> = Vec::new();
        let fixed_pos: Vec<Option<usize>> = self
            .segs
            .iter()
            .map(|s| match s {
                SegChoice::Auto => None,
                SegChoice::Fixed(k) => {
                    fixed_segs.push(*k);
                    Some(fixed_segs.len() - 1)
                }
            })
            .collect();

        // Pre-plan each (bandwidth, pattern) point once for the adaptive
        // methods: the auto plan plus every fixed candidate against one
        // shared SegSweepCtx (plan_with_segs). Cells then only simulate.
        let needs_plans = self.methods.iter().any(|m| m.adaptive_exec().is_some());
        let plan_points: Vec<(usize, usize)> = if needs_plans {
            let mut v = Vec::new();
            for bi in 0..self.bandwidths_mbps.len() {
                for pi in 0..self.patterns.len() {
                    v.push((bi, pi));
                }
            }
            v
        } else {
            Vec::new()
        };
        let plan_one = |&(bi, pi): &(usize, usize)| -> PlannedPoint {
            let trace = BandwidthTrace::fixed_mbps(self.bandwidths_mbps[bi]);
            let popts = plan_opts(&trace, self.patterns[pi], &self.cluster, self.tokens);
            let auto = plan(&self.spec, &self.cluster, &popts)
                .ok()
                .map(|r| r.allocation);
            let fixed = if fixed_segs.is_empty() {
                Vec::new()
            } else {
                plan_with_segs(&self.spec, &self.cluster, &fixed_segs, &popts)
            };
            PlannedPoint { auto, fixed }
        };
        let planned: Vec<PlannedPoint> = if parallel {
            pool::map_indexed(&plan_points, plan_one)
        } else {
            plan_points.iter().map(plan_one).collect()
        };

        let pts = self.points();
        let eval_cell = |p: &PointRef| -> ScenarioCell {
            let method = &self.methods[p.mi];
            let bw = self.bandwidths_mbps[p.bi];
            let pattern = self.patterns[p.pi];
            let trace = BandwidthTrace::fixed_mbps(bw);
            let mut cell = ScenarioCell {
                method: method.name(),
                method_key: method.key(),
                bandwidth_mbps: bw,
                pattern,
                seg: self.segs[p.si],
                mem: self.pressure[p.mj].label.clone(),
                arrival: self.arrivals[p.ai].label(),
                churn: self.churn[p.ci].label.clone(),
                batching: self.batching[p.ki].label(),
                workload: self.workloads[p.wi].label(),
                planned_seg: None,
                ms_per_token: None,
                online_plans_fired: None,
                kv_tokens_transferred: None,
                emergency_steps: None,
                bw_stalls: None,
                replans_fired: None,
                kv_migrated_bytes: None,
                recovery_steps: None,
                kv_pages_allocated: None,
                kv_pages_spilled: None,
                fragmentation: None,
                requests: None,
            };
            // The script a cell actually runs: the pressure script with the
            // churn point's fault timeline spliced onto its churn channel
            // (both axes are validated to own disjoint channels).
            let combined_storage;
            let script: &Script = if self.churn[p.ci].churn.is_empty() {
                &self.pressure[p.mj]
            } else {
                let mut s = self.pressure[p.mj].clone();
                s.churn.extend(self.churn[p.ci].churn.iter().cloned());
                s.churn.sort_by_key(|e| (e.at_step, e.device));
                combined_storage = s;
                &combined_storage
            };
            match method.adaptive_exec() {
                None => {
                    // Baseline method at its baseline point — churn-capable
                    // baselines additionally run each churn timeline.
                    if let crate::baselines::Outcome::Ok(r) = method.run_scripted(
                        &self.spec,
                        &self.cluster,
                        &trace,
                        pattern,
                        self.tokens,
                        TraceMode::Off,
                        script,
                    ) {
                        cell.ms_per_token = Some(r.ms_per_token());
                        cell.online_plans_fired = Some(r.online_plans_fired);
                        cell.kv_tokens_transferred = Some(r.kv_tokens_transferred);
                        cell.emergency_steps = Some(r.emergency_steps);
                        cell.bw_stalls = Some(r.bw_stalls);
                        cell.replans_fired = Some(r.replans_fired);
                        cell.kv_migrated_bytes = Some(r.kv_migrated_bytes);
                        cell.recovery_steps = Some(r.recovery_steps.clone());
                        cell.kv_pages_allocated = Some(r.kv_pages_allocated);
                        cell.kv_pages_spilled = Some(r.kv_pages_spilled);
                        cell.fragmentation = Some(r.kv_fragmentation);
                    }
                }
                Some(cfg) => {
                    let point = &planned[p.bi * self.patterns.len() + p.pi];
                    let alloc = match fixed_pos[p.si] {
                        None => point.auto.as_ref(),
                        Some(fi) => point.fixed[fi].as_ref(),
                    };
                    if let Some(alloc) = alloc {
                        let exec = ExecOptions {
                            planner: cfg.planner,
                            kv_transfer: cfg.kv_transfer,
                            trace_mode: TraceMode::Off,
                            ..ExecOptions::default()
                        };
                        match self.arrivals[p.ai] {
                            ArrivalSpec::Single => {
                                let r = run_interleaved_scripted(
                                    alloc,
                                    &self.cluster,
                                    &trace,
                                    pattern.micro_batches(&self.cluster),
                                    self.tokens,
                                    &exec,
                                    script,
                                );
                                cell.planned_seg = Some(alloc.seg);
                                cell.ms_per_token = Some(r.ms_per_token());
                                cell.online_plans_fired = Some(r.online_plans_fired);
                                cell.kv_tokens_transferred = Some(r.kv_tokens_transferred);
                                cell.emergency_steps = Some(r.emergency_steps);
                                cell.bw_stalls = Some(r.bw_stalls);
                                cell.replans_fired = Some(r.replans_fired);
                                cell.kv_migrated_bytes = Some(r.kv_migrated_bytes);
                                cell.recovery_steps = Some(r.recovery_steps.clone());
                                cell.kv_pages_allocated = Some(r.kv_pages_allocated);
                                cell.kv_pages_spilled = Some(r.kv_pages_spilled);
                                cell.fragmentation = Some(r.kv_fragmentation);
                            }
                            ArrivalSpec::Stream { count, lambda } => {
                                let workload = &self.workloads[p.wi];
                                let reqs = stream_requests_mix(
                                    pattern,
                                    STREAM_SEED,
                                    count,
                                    lambda,
                                    workload,
                                );
                                let max_batch = pattern.micro_batches(&self.cluster);
                                let batching = match self.batching[p.ki] {
                                    BatchingSpec::Fifo => BatchingOpts::fifo(),
                                    BatchingSpec::Continuous { page_tokens } => {
                                        // Budget the pages so a full
                                        // admissible batch stays resident:
                                        // spill only prices genuine
                                        // overcommit, which the grids avoid
                                        // to keep FIFO-vs-continuous deltas
                                        // pure admission-policy effects.
                                        // Round each context's demand up to
                                        // whole pages — the last page of a
                                        // context is partially filled, so a
                                        // token-count budget alone would
                                        // force spills at peak width. Mixed
                                        // workloads size for the longest
                                        // context the distribution can emit
                                        // (the fixed baseline reduces to
                                        // the old prompt+tokens formula).
                                        let per_ctx_pages = (workload.max_prompt_tokens()
                                            + workload.max_steps())
                                        .div_ceil(page_tokens);
                                        let budget = max_batch * per_ctx_pages * page_tokens;
                                        BatchingOpts::continuous(1).with_kv_pages(
                                            KvPageConfig::for_alloc(alloc, page_tokens, budget),
                                        )
                                    }
                                };
                                let sr = serve_interleaved_opts(
                                    alloc,
                                    &self.cluster,
                                    &trace,
                                    max_batch,
                                    &exec,
                                    script,
                                    &reqs,
                                    &batching,
                                );
                                cell.planned_seg = Some(alloc.seg);
                                cell.ms_per_token = Some(sr.ms_per_token());
                                cell.online_plans_fired = Some(sr.online_plans_fired);
                                cell.kv_tokens_transferred = Some(sr.kv_tokens_transferred);
                                cell.emergency_steps = Some(sr.emergency_steps);
                                cell.bw_stalls = Some(sr.bw_stalls);
                                cell.replans_fired = Some(sr.replans_fired);
                                cell.kv_migrated_bytes = Some(sr.kv_migrated_bytes);
                                cell.recovery_steps = Some(sr.recovery_steps.clone());
                                cell.kv_pages_allocated = Some(sr.kv_pages_allocated);
                                cell.kv_pages_spilled = Some(sr.kv_pages_spilled);
                                cell.fragmentation = Some(sr.kv_fragmentation);
                                // Length arrays must align entry-for-entry
                                // with the metric arrays, which follow the
                                // driver's emission order (admission order
                                // on FIFO, completion order on continuous)
                                // — so look each metric row's request up
                                // by id rather than assuming arrival order.
                                let by_id: std::collections::BTreeMap<u64, &crate::workload::Request> =
                                    reqs.iter().map(|r| (r.id, r)).collect();
                                cell.requests = Some(RequestLevel {
                                    queueing_delay_s: sr
                                        .requests
                                        .iter()
                                        .map(|r| r.queueing_delay)
                                        .collect(),
                                    ttft_s: sr.requests.iter().map(|r| r.ttft).collect(),
                                    tbt_s: sr.requests.iter().map(|r| r.tbt).collect(),
                                    prompt_len: sr
                                        .requests
                                        .iter()
                                        .map(|m| by_id[&m.id].prompt.len())
                                        .collect(),
                                    steps: sr
                                        .requests
                                        .iter()
                                        .map(|m| by_id[&m.id].steps)
                                        .collect(),
                                });
                            }
                        }
                    }
                }
            }
            cell
        };
        if parallel {
            pool::map_indexed(&pts, eval_cell)
        } else {
            pts.iter().map(eval_cell).collect()
        }
    }

    /// Serialize evaluated cells as a `lime-sweep-v7` artifact — a strict
    /// superset of `lime-sweep-v6` (itself a strict superset of
    /// v5/v4/v3/v2): every v6 key is present with its meaning, plus the
    /// `axes.workloads` metadata, the per-cell `workload` coordinate, and
    /// the per-request `prompt_len`/`steps` arrays inside each stream
    /// cell's `requests` object (constant on fixed-workload cells, ragged
    /// on mixed ones).
    pub fn to_json(&self, cells: &[ScenarioCell]) -> Json {
        self.assert_valid();
        let cell_rows: Vec<Json> = cells
            .iter()
            .map(|c| {
                let requests = match &c.requests {
                    None => Json::Null,
                    Some(r) => {
                        let arr = |v: &[f64]| Json::Arr(v.iter().map(|&x| Json::Num(x)).collect());
                        let ints =
                            |v: &[usize]| Json::Arr(v.iter().map(|&x| x.into()).collect());
                        obj(&[
                            ("queueing_delay_s", arr(&r.queueing_delay_s)),
                            ("ttft_s", arr(&r.ttft_s)),
                            ("tbt_s", arr(&r.tbt_s)),
                            ("prompt_len", ints(&r.prompt_len)),
                            ("steps", ints(&r.steps)),
                        ])
                    }
                };
                let recovery = match &c.recovery_steps {
                    None => Json::Null,
                    Some(v) => Json::Arr(
                        v.iter()
                            .map(|r| r.map_or(Json::Null, Into::into))
                            .collect(),
                    ),
                };
                obj(&[
                    ("method", c.method_key.into()),
                    ("method_name", c.method.into()),
                    ("bandwidth_mbps", c.bandwidth_mbps.into()),
                    ("pattern", pattern_str(c.pattern).into()),
                    ("seg", c.seg.json()),
                    ("mem", c.mem.as_str().into()),
                    ("arrival", c.arrival.as_str().into()),
                    ("churn", c.churn.as_str().into()),
                    ("batching", c.batching.as_str().into()),
                    ("workload", c.workload.as_str().into()),
                    (
                        "planned_seg",
                        c.planned_seg.map_or(Json::Null, Into::into),
                    ),
                    (
                        "ms_per_token",
                        c.ms_per_token.map_or(Json::Null, Json::Num),
                    ),
                    ("oom", c.ms_per_token.is_none().into()),
                    ("oot", c.is_oot().into()),
                    (
                        "online_plans_fired",
                        c.online_plans_fired.map_or(Json::Null, Into::into),
                    ),
                    (
                        "kv_tokens_transferred",
                        c.kv_tokens_transferred.map_or(Json::Null, Into::into),
                    ),
                    (
                        "emergency_steps",
                        c.emergency_steps.map_or(Json::Null, Into::into),
                    ),
                    ("bw_stalls", c.bw_stalls.map_or(Json::Null, Into::into)),
                    (
                        "replans_fired",
                        c.replans_fired.map_or(Json::Null, Into::into),
                    ),
                    (
                        "kv_migrated_bytes",
                        c.kv_migrated_bytes.map_or(Json::Null, Into::into),
                    ),
                    ("recovery_steps", recovery),
                    (
                        "kv_pages_allocated",
                        c.kv_pages_allocated.map_or(Json::Null, Into::into),
                    ),
                    (
                        "kv_pages_spilled",
                        c.kv_pages_spilled.map_or(Json::Null, Into::into),
                    ),
                    (
                        "fragmentation",
                        c.fragmentation.map_or(Json::Null, Json::Num),
                    ),
                    ("requests", requests),
                ])
            })
            .collect();
        let mem_events_json = |script: &Script| -> Vec<Json> {
            script
                .mem
                .iter()
                .map(|ev| {
                    obj(&[
                        ("at_step", ev.at_step.into()),
                        ("device", ev.device.into()),
                        ("delta_bytes", Json::Num(ev.delta_bytes as f64)),
                    ])
                })
                .collect()
        };
        // The v2-compatible projection: label + memory channel only.
        let mem_rows: Vec<Json> = self
            .pressure
            .iter()
            .map(|script| {
                obj(&[
                    ("label", script.label.as_str().into()),
                    ("events", Json::Arr(mem_events_json(script))),
                ])
            })
            .collect();
        // The full joint-script metadata (v3 addition).
        let script_rows: Vec<Json> = self
            .pressure
            .iter()
            .map(|script| {
                let bw_events: Vec<Json> = script
                    .bw
                    .iter()
                    .map(|ev| {
                        obj(&[
                            ("at_step", ev.at_step.into()),
                            ("scale", Json::Num(ev.scale)),
                        ])
                    })
                    .collect();
                obj(&[
                    ("label", script.label.as_str().into()),
                    ("mem_events", Json::Arr(mem_events_json(script))),
                    ("bw_events", Json::Arr(bw_events)),
                ])
            })
            .collect();
        let axes = obj(&[
            (
                "cluster",
                obj(&[
                    ("label", self.grid.as_str().into()),
                    (
                        "devices",
                        Json::Arr(
                            self.cluster
                                .device_names()
                                .into_iter()
                                .map(Into::into)
                                .collect(),
                        ),
                    ),
                ]),
            ),
            (
                "bandwidths_mbps",
                Json::Arr(self.bandwidths_mbps.iter().map(|&b| b.into()).collect()),
            ),
            (
                "patterns",
                Json::Arr(
                    self.patterns
                        .iter()
                        .map(|&p| pattern_str(p).into())
                        .collect(),
                ),
            ),
            (
                "methods",
                Json::Arr(self.methods.iter().map(|m| m.key().into()).collect()),
            ),
            (
                "segs",
                Json::Arr(self.segs.iter().map(SegChoice::json).collect()),
            ),
            ("mem_scenarios", Json::Arr(mem_rows)),
            ("pressure_scripts", Json::Arr(script_rows)),
            (
                "arrivals",
                Json::Arr(self.arrivals.iter().map(ArrivalSpec::json).collect()),
            ),
            (
                "batching",
                Json::Arr(self.batching.iter().map(BatchingSpec::json).collect()),
            ),
            (
                "workloads",
                Json::Arr(self.workloads.iter().map(workload_json).collect()),
            ),
            (
                "churn_scripts",
                Json::Arr(
                    self.churn
                        .iter()
                        .map(|script| {
                            let events: Vec<Json> = script
                                .churn
                                .iter()
                                .map(|ev| {
                                    obj(&[
                                        ("at_step", ev.at_step.into()),
                                        ("device", ev.device.into()),
                                        ("kind", ev.kind.name().into()),
                                    ])
                                })
                                .collect();
                            obj(&[
                                ("label", script.label.as_str().into()),
                                ("events", Json::Arr(events)),
                            ])
                        })
                        .collect(),
                ),
            ),
        ]);
        obj(&[
            ("schema", "lime-sweep-v7".into()),
            ("grid", self.grid.as_str().into()),
            ("model", self.spec.name.as_str().into()),
            ("tokens", self.tokens.into()),
            (
                "bandwidths_mbps",
                Json::Arr(self.bandwidths_mbps.iter().map(|&b| b.into()).collect()),
            ),
            ("axes", axes),
            ("cells", Json::Arr(cell_rows)),
        ])
    }
}

/// Summary returned by a successful [`validate_sweep`] pass.
#[derive(Debug, Clone, PartialEq)]
pub struct SweepSummary {
    pub grid: String,
    pub model: String,
    /// The schema version the artifact validated against
    /// ("lime-sweep-v2" .. "lime-sweep-v7").
    pub schema: String,
    pub cells: usize,
    pub completed: usize,
    pub oom: usize,
    pub oot: usize,
}

fn field<'j>(json: &'j Json, key: &str, ctx: &str) -> Result<&'j Json, String> {
    json.get(key)
        .ok_or_else(|| format!("{ctx}: missing '{key}'"))
}

/// Which sweep-artifact schema a validation pass enforces. Ordered:
/// every version is a strict superset of the previous one.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord)]
enum SweepSchema {
    V2,
    V3,
    V4,
    V5,
    V6,
    V7,
}

impl SweepSchema {
    fn name(self) -> &'static str {
        match self {
            SweepSchema::V2 => "lime-sweep-v2",
            SweepSchema::V3 => "lime-sweep-v3",
            SweepSchema::V4 => "lime-sweep-v4",
            SweepSchema::V5 => "lime-sweep-v5",
            SweepSchema::V6 => "lime-sweep-v6",
            SweepSchema::V7 => "lime-sweep-v7",
        }
    }
}

/// Validate one artifact against whichever supported schema it declares
/// (`lime-sweep-v2` through `lime-sweep-v7`) — the check behind
/// `lime sweep-check` and the CI artifact gate.
pub fn validate_sweep(json: &Json) -> Result<SweepSummary, String> {
    match json.get("schema").and_then(Json::as_str) {
        Some("lime-sweep-v2") => validate_sweep_impl(json, SweepSchema::V2),
        Some("lime-sweep-v3") => validate_sweep_impl(json, SweepSchema::V3),
        Some("lime-sweep-v4") => validate_sweep_impl(json, SweepSchema::V4),
        Some("lime-sweep-v5") => validate_sweep_impl(json, SweepSchema::V5),
        Some("lime-sweep-v6") => validate_sweep_impl(json, SweepSchema::V6),
        Some("lime-sweep-v7") => validate_sweep_impl(json, SweepSchema::V7),
        other => Err(format!(
            "expected schema lime-sweep-v2 .. lime-sweep-v7, got {other:?}"
        )),
    }
}

/// Validate one artifact strictly against the `lime-sweep-v2` schema
/// (artifacts produced before the pressure axis existed).
pub fn validate_sweep_v2(json: &Json) -> Result<SweepSummary, String> {
    match json.get("schema").and_then(Json::as_str) {
        Some("lime-sweep-v2") => validate_sweep_impl(json, SweepSchema::V2),
        other => Err(format!("expected schema lime-sweep-v2, got {other:?}")),
    }
}

/// Validate one artifact strictly against the `lime-sweep-v3` schema.
pub fn validate_sweep_v3(json: &Json) -> Result<SweepSummary, String> {
    match json.get("schema").and_then(Json::as_str) {
        Some("lime-sweep-v3") => validate_sweep_impl(json, SweepSchema::V3),
        other => Err(format!("expected schema lime-sweep-v3, got {other:?}")),
    }
}

/// Validate one artifact strictly against the `lime-sweep-v4` schema.
pub fn validate_sweep_v4(json: &Json) -> Result<SweepSummary, String> {
    match json.get("schema").and_then(Json::as_str) {
        Some("lime-sweep-v4") => validate_sweep_impl(json, SweepSchema::V4),
        other => Err(format!("expected schema lime-sweep-v4, got {other:?}")),
    }
}

/// Validate one artifact strictly against the `lime-sweep-v5` schema.
pub fn validate_sweep_v5(json: &Json) -> Result<SweepSummary, String> {
    match json.get("schema").and_then(Json::as_str) {
        Some("lime-sweep-v5") => validate_sweep_impl(json, SweepSchema::V5),
        other => Err(format!("expected schema lime-sweep-v5, got {other:?}")),
    }
}

/// Validate one artifact strictly against the `lime-sweep-v6` schema.
pub fn validate_sweep_v6(json: &Json) -> Result<SweepSummary, String> {
    match json.get("schema").and_then(Json::as_str) {
        Some("lime-sweep-v6") => validate_sweep_impl(json, SweepSchema::V6),
        other => Err(format!("expected schema lime-sweep-v6, got {other:?}")),
    }
}

/// Validate one artifact strictly against the `lime-sweep-v7` schema.
pub fn validate_sweep_v7(json: &Json) -> Result<SweepSummary, String> {
    match json.get("schema").and_then(Json::as_str) {
        Some("lime-sweep-v7") => validate_sweep_impl(json, SweepSchema::V7),
        other => Err(format!("expected schema lime-sweep-v7, got {other:?}")),
    }
}

/// The shared validation core: structural keys, axis metadata, per-cell
/// coordinate membership, `Method::key` round-trips, OOM/metric
/// consistency, cell uniqueness, and the exact per-method cell counts the
/// matrix cross implies. V3 additionally requires `axes.pressure_scripts`
/// (labels aligned with `axes.mem_scenarios`, baseline script empty on
/// both channels, positive bandwidth scales) and the per-cell `bw_stalls`
/// counter. V4 additionally requires `axes.arrivals` (first entry
/// `single`; stream entries with positive integer `count` and finite
/// positive `lambda`), the per-cell `arrival` coordinate, and the
/// per-cell `requests` arrays — present with `count` entries exactly on
/// completed stream cells, null otherwise. V5 additionally requires
/// `axes.churn_scripts` (first entry event-free; events with integer
/// `at_step`/`device` and `kind` down|up), the per-cell `churn`
/// coordinate (non-churn-capable baselines pinned to the first label),
/// and the per-cell `replans_fired`/`kv_migrated_bytes`/`recovery_steps`
/// counters (null iff OOM; `recovery_steps` an array of step counts or
/// nulls). V6 additionally requires `axes.batching` (first entry the
/// FIFO baseline; continuous entries with an integer `page_tokens` >= 1),
/// the per-cell `batching` coordinate (pinned to the FIFO label off
/// adaptive stream cells), and the per-cell
/// `kv_pages_allocated`/`kv_pages_spilled`/`fragmentation` paged-KV
/// counters (null iff OOM; `fragmentation` in `[0, 1]`; all exactly zero
/// on FIFO cells, which model KV as a contiguous preallocation). V7
/// additionally requires `axes.workloads` (first entry a `fixed`
/// distribution — the pre-mix baseline shape; entries with a unique
/// label, a known kind and that kind's numeric parameters), the per-cell
/// `workload` coordinate (pinned to the baseline label off adaptive
/// stream cells), and the per-request `prompt_len`/`steps` arrays inside
/// each completed stream cell's `requests` object (length `count`,
/// non-negative integers).
fn validate_sweep_impl(json: &Json, schema: SweepSchema) -> Result<SweepSummary, String> {
    let grid = field(json, "grid", "artifact")?
        .as_str()
        .ok_or("'grid' must be a string")?
        .to_string();
    let model = field(json, "model", "artifact")?
        .as_str()
        .ok_or("'model' must be a string")?
        .to_string();
    field(json, "tokens", "artifact")?
        .as_usize()
        .ok_or("'tokens' must be a non-negative integer")?;

    let axes = field(json, "axes", "artifact")?;
    let axis_strs = |key: &str| -> Result<Vec<String>, String> {
        let arr = field(axes, key, "axes")?
            .as_arr()
            .ok_or_else(|| format!("axes.{key} must be an array"))?;
        arr.iter()
            .map(|v| {
                v.as_str()
                    .map(str::to_string)
                    .ok_or_else(|| format!("axes.{key} entries must be strings"))
            })
            .collect()
    };
    let bandwidths: Vec<f64> = field(axes, "bandwidths_mbps", "axes")?
        .as_arr()
        .ok_or("axes.bandwidths_mbps must be an array")?
        .iter()
        .map(|v| v.as_f64().ok_or("axes.bandwidths_mbps entries must be numbers"))
        .collect::<Result<_, _>>()?;
    let patterns = axis_strs("patterns")?;
    for p in &patterns {
        if p != "sporadic" && p != "bursty" {
            return Err(format!("axes.patterns: unknown pattern '{p}'"));
        }
    }
    let methods = axis_strs("methods")?;
    let mut adaptive = std::collections::BTreeMap::new();
    let mut churn_cap = std::collections::BTreeMap::new();
    for key in &methods {
        let m = by_name(key).ok_or_else(|| format!("axes.methods: unknown method '{key}'"))?;
        adaptive.insert(key.clone(), m.adaptive_exec().is_some());
        churn_cap.insert(key.clone(), m.churn_capable());
    }
    let segs = field(axes, "segs", "axes")?
        .as_arr()
        .ok_or("axes.segs must be an array")?;
    let mut seg_labels = Vec::new();
    for (i, s) in segs.iter().enumerate() {
        match (s.as_str(), s.as_usize()) {
            (Some("auto"), _) => seg_labels.push("auto".to_string()),
            (None, Some(k)) if k >= 2 => seg_labels.push(k.to_string()),
            _ => return Err(format!("axes.segs[{i}] must be \"auto\" or an integer >= 2")),
        }
    }
    if seg_labels.first().map(String::as_str) != Some("auto") {
        return Err("axes.segs[0] must be \"auto\" (the baseline point)".into());
    }
    // Field-level check of one memory-event object, shared by the v2
    // `mem_scenarios` axis and the v3 `pressure_scripts` metadata.
    let check_mem_event = |ev: &Json, ctx: &str| -> Result<(), String> {
        for k in ["at_step", "device", "delta_bytes"] {
            if ev.get(k).and_then(Json::as_f64).is_none() {
                return Err(format!("{ctx}.{k} must be a number"));
            }
        }
        Ok(())
    };
    let mem_axis = field(axes, "mem_scenarios", "axes")?
        .as_arr()
        .ok_or("axes.mem_scenarios must be an array")?;
    let mut mem_labels = Vec::new();
    for (i, m) in mem_axis.iter().enumerate() {
        let label = field(m, "label", "mem_scenario")?
            .as_str()
            .ok_or_else(|| format!("axes.mem_scenarios[{i}].label must be a string"))?;
        let events = field(m, "events", "mem_scenario")?
            .as_arr()
            .ok_or_else(|| format!("axes.mem_scenarios[{i}].events must be an array"))?;
        for (j, ev) in events.iter().enumerate() {
            check_mem_event(ev, &format!("axes.mem_scenarios[{i}].events[{j}]"))?;
        }
        if i == 0 && !events.is_empty() {
            return Err("axes.mem_scenarios[0] must have no events (the baseline point)".into());
        }
        mem_labels.push(label.to_string());
    }

    // V3+: the full joint-script axis must exist and align with the v2
    // projection label-for-label.
    if schema >= SweepSchema::V3 {
        let scripts = field(axes, "pressure_scripts", "axes")?
            .as_arr()
            .ok_or("axes.pressure_scripts must be an array")?;
        if scripts.len() != mem_labels.len() {
            return Err(format!(
                "axes.pressure_scripts has {} entries but axes.mem_scenarios has {}",
                scripts.len(),
                mem_labels.len()
            ));
        }
        for (i, script) in scripts.iter().enumerate() {
            let ctx = format!("axes.pressure_scripts[{i}]");
            let label = field(script, "label", &ctx)?
                .as_str()
                .ok_or_else(|| format!("{ctx}.label must be a string"))?;
            if label != mem_labels[i] {
                return Err(format!(
                    "{ctx}.label '{label}' does not match axes.mem_scenarios[{i}] '{}'",
                    mem_labels[i]
                ));
            }
            let mem_events = field(script, "mem_events", &ctx)?
                .as_arr()
                .ok_or_else(|| format!("{ctx}.mem_events must be an array"))?;
            let bw_events = field(script, "bw_events", &ctx)?
                .as_arr()
                .ok_or_else(|| format!("{ctx}.bw_events must be an array"))?;
            if i == 0 && (!mem_events.is_empty() || !bw_events.is_empty()) {
                return Err(
                    "axes.pressure_scripts[0] must have no events (the baseline point)".into(),
                );
            }
            // The script's memory channel must BE the v2 projection: same
            // events, field for field — otherwise a consumer reading the
            // full metadata replays a script that never ran.
            let projection = mem_axis[i]
                .get("events")
                .and_then(Json::as_arr)
                .expect("checked above");
            if mem_events.len() != projection.len() {
                return Err(format!(
                    "{ctx}.mem_events has {} entries but axes.mem_scenarios[{i}].events has {}",
                    mem_events.len(),
                    projection.len()
                ));
            }
            for (j, (ev, proj)) in mem_events.iter().zip(projection).enumerate() {
                check_mem_event(ev, &format!("{ctx}.mem_events[{j}]"))?;
                for k in ["at_step", "device", "delta_bytes"] {
                    if ev.get(k).and_then(Json::as_f64) != proj.get(k).and_then(Json::as_f64) {
                        return Err(format!(
                            "{ctx}.mem_events[{j}].{k} disagrees with the \
                             axes.mem_scenarios[{i}] projection"
                        ));
                    }
                }
            }
            for (j, ev) in bw_events.iter().enumerate() {
                if ev.get("at_step").and_then(Json::as_usize).is_none() {
                    return Err(format!(
                        "{ctx}.bw_events[{j}].at_step must be a non-negative integer"
                    ));
                }
                match ev.get("scale").and_then(Json::as_f64) {
                    Some(s) if s.is_finite() && s > 0.0 => {}
                    _ => {
                        return Err(format!(
                            "{ctx}.bw_events[{j}].scale must be a finite number > 0"
                        ))
                    }
                }
            }
        }
    }

    // V4: the arrival-process axis — label-keyed entries, first one the
    // single-run baseline, stream entries with positive count and rate.
    // `arrival_counts` maps stream labels to their request counts so the
    // per-cell `requests` arrays can be length-checked below.
    let mut arrival_labels: Vec<String> = Vec::new();
    let mut arrival_counts: std::collections::BTreeMap<String, usize> =
        std::collections::BTreeMap::new();
    if schema >= SweepSchema::V4 {
        let arrivals = field(axes, "arrivals", "axes")?
            .as_arr()
            .ok_or("axes.arrivals must be an array")?;
        if arrivals.is_empty() {
            return Err("axes.arrivals must be non-empty".into());
        }
        for (i, a) in arrivals.iter().enumerate() {
            let ctx = format!("axes.arrivals[{i}]");
            let label = field(a, "label", &ctx)?
                .as_str()
                .ok_or_else(|| format!("{ctx}.label must be a string"))?;
            let kind = field(a, "kind", &ctx)?
                .as_str()
                .ok_or_else(|| format!("{ctx}.kind must be a string"))?;
            match kind {
                "single" => {}
                "stream" => {
                    let count = match a.get("count").and_then(Json::as_usize) {
                        Some(c) if c >= 1 => c,
                        _ => return Err(format!("{ctx}.count must be an integer >= 1")),
                    };
                    match a.get("lambda").and_then(Json::as_f64) {
                        Some(l) if l.is_finite() && l > 0.0 => {}
                        _ => {
                            return Err(format!("{ctx}.lambda must be a finite number > 0"));
                        }
                    }
                    arrival_counts.insert(label.to_string(), count);
                }
                other => return Err(format!("{ctx}.kind must be single|stream, got '{other}'")),
            }
            if i == 0 && kind != "single" {
                return Err("axes.arrivals[0] must be the single-run baseline".into());
            }
            if arrival_labels.iter().any(|l| l == label) {
                return Err(format!("{ctx}: duplicate arrival label '{label}'"));
            }
            arrival_labels.push(label.to_string());
        }
    }

    // V5: the device-churn axis — first entry event-free, events with
    // integer coordinates and a down|up kind.
    let mut churn_labels: Vec<String> = Vec::new();
    if schema >= SweepSchema::V5 {
        let scripts = field(axes, "churn_scripts", "axes")?
            .as_arr()
            .ok_or("axes.churn_scripts must be an array")?;
        if scripts.is_empty() {
            return Err("axes.churn_scripts must be non-empty".into());
        }
        for (i, script) in scripts.iter().enumerate() {
            let ctx = format!("axes.churn_scripts[{i}]");
            let label = field(script, "label", &ctx)?
                .as_str()
                .ok_or_else(|| format!("{ctx}.label must be a string"))?;
            let events = field(script, "events", &ctx)?
                .as_arr()
                .ok_or_else(|| format!("{ctx}.events must be an array"))?;
            if i == 0 && !events.is_empty() {
                return Err("axes.churn_scripts[0] must have no events (the baseline point)".into());
            }
            for (j, ev) in events.iter().enumerate() {
                for k in ["at_step", "device"] {
                    if ev.get(k).and_then(Json::as_usize).is_none() {
                        return Err(format!(
                            "{ctx}.events[{j}].{k} must be a non-negative integer"
                        ));
                    }
                }
                match ev.get("kind").and_then(Json::as_str) {
                    Some("down") | Some("up") => {}
                    other => {
                        return Err(format!(
                            "{ctx}.events[{j}].kind must be \"down\" or \"up\", got {other:?}"
                        ))
                    }
                }
            }
            if churn_labels.iter().any(|l| l == label) {
                return Err(format!("{ctx}: duplicate churn label '{label}'"));
            }
            churn_labels.push(label.to_string());
        }
    }

    // V6: the batching-policy axis — first entry the FIFO baseline,
    // continuous entries carrying their page-size knob.
    let mut batching_labels: Vec<String> = Vec::new();
    if schema >= SweepSchema::V6 {
        let batching = field(axes, "batching", "axes")?
            .as_arr()
            .ok_or("axes.batching must be an array")?;
        if batching.is_empty() {
            return Err("axes.batching must be non-empty".into());
        }
        for (i, b) in batching.iter().enumerate() {
            let ctx = format!("axes.batching[{i}]");
            let label = field(b, "label", &ctx)?
                .as_str()
                .ok_or_else(|| format!("{ctx}.label must be a string"))?;
            let mode = field(b, "mode", &ctx)?
                .as_str()
                .ok_or_else(|| format!("{ctx}.mode must be a string"))?;
            match mode {
                "fifo" => {}
                "continuous" => match b.get("page_tokens").and_then(Json::as_usize) {
                    Some(p) if p >= 1 => {}
                    _ => return Err(format!("{ctx}.page_tokens must be an integer >= 1")),
                },
                other => {
                    return Err(format!("{ctx}.mode must be fifo|continuous, got '{other}'"))
                }
            }
            if i == 0 && mode != "fifo" {
                return Err("axes.batching[0] must be the FIFO baseline".into());
            }
            if batching_labels.iter().any(|l| l == label) {
                return Err(format!("{ctx}: duplicate batching label '{label}'"));
            }
            batching_labels.push(label.to_string());
        }
    }

    // V7: the workload-mix axis — first entry the fixed baseline shape,
    // each entry carrying its distribution kind and parameters.
    let mut workload_labels: Vec<String> = Vec::new();
    if schema >= SweepSchema::V7 {
        let workloads = field(axes, "workloads", "axes")?
            .as_arr()
            .ok_or("axes.workloads must be an array")?;
        if workloads.is_empty() {
            return Err("axes.workloads must be non-empty".into());
        }
        for (i, w) in workloads.iter().enumerate() {
            let ctx = format!("axes.workloads[{i}]");
            let label = field(w, "label", &ctx)?
                .as_str()
                .ok_or_else(|| format!("{ctx}.label must be a string"))?;
            let kind = field(w, "kind", &ctx)?
                .as_str()
                .ok_or_else(|| format!("{ctx}.kind must be a string"))?;
            let need_ints = |keys: &[&str]| -> Result<(), String> {
                for k in keys {
                    if w.get(k).and_then(Json::as_usize).is_none() {
                        return Err(format!("{ctx}.{k} must be a non-negative integer"));
                    }
                }
                Ok(())
            };
            match kind {
                "fixed" => need_ints(&["prompt_tokens", "steps"])?,
                "uniform" => {
                    need_ints(&["prompt_min", "prompt_max", "steps_min", "steps_max"])?
                }
                "bimodal" => {
                    need_ints(&["short_prompt", "short_steps", "long_prompt", "long_steps"])?;
                    match w.get("long_frac").and_then(Json::as_f64) {
                        Some(f) if f.is_finite() && (0.0..=1.0).contains(&f) => {}
                        _ => {
                            return Err(format!("{ctx}.long_frac must be a number in [0, 1]"))
                        }
                    }
                }
                "geometric" => need_ints(&["prompt_tokens", "mean_steps", "max_steps"])?,
                other => {
                    return Err(format!(
                        "{ctx}.kind must be fixed|uniform|bimodal|geometric, got '{other}'"
                    ))
                }
            }
            if i == 0 && kind != "fixed" {
                return Err("axes.workloads[0] must be the fixed baseline shape".into());
            }
            if workload_labels.iter().any(|l| l == label) {
                return Err(format!("{ctx}: duplicate workload label '{label}'"));
            }
            workload_labels.push(label.to_string());
        }
    }

    let cells = field(json, "cells", "artifact")?
        .as_arr()
        .ok_or("'cells' must be an array")?;
    let mut seen = std::collections::BTreeSet::new();
    let mut per_method: std::collections::BTreeMap<String, usize> =
        std::collections::BTreeMap::new();
    let mut completed = 0usize;
    let mut oom = 0usize;
    let mut oot = 0usize;
    for (i, cell) in cells.iter().enumerate() {
        let ctx = format!("cells[{i}]");
        let key = field(cell, "method", &ctx)?
            .as_str()
            .ok_or_else(|| format!("{ctx}.method must be a string"))?;
        if !adaptive.contains_key(key) {
            return Err(format!("{ctx}: method '{key}' not in axes.methods"));
        }
        field(cell, "method_name", &ctx)?
            .as_str()
            .ok_or_else(|| format!("{ctx}.method_name must be a string"))?;
        let bw = field(cell, "bandwidth_mbps", &ctx)?
            .as_f64()
            .ok_or_else(|| format!("{ctx}.bandwidth_mbps must be a number"))?;
        if !bandwidths.contains(&bw) {
            return Err(format!("{ctx}: bandwidth {bw} not on the axis"));
        }
        let pattern = field(cell, "pattern", &ctx)?
            .as_str()
            .ok_or_else(|| format!("{ctx}.pattern must be a string"))?;
        if !patterns.iter().any(|p| p == pattern) {
            return Err(format!("{ctx}: pattern '{pattern}' not on the axis"));
        }
        let seg = field(cell, "seg", &ctx)?;
        let seg_label = match (seg.as_str(), seg.as_usize()) {
            (Some("auto"), _) => "auto".to_string(),
            (None, Some(k)) => k.to_string(),
            _ => return Err(format!("{ctx}.seg must be \"auto\" or an integer")),
        };
        if !seg_labels.contains(&seg_label) {
            return Err(format!("{ctx}: seg '{seg_label}' not on the axis"));
        }
        let mem = field(cell, "mem", &ctx)?
            .as_str()
            .ok_or_else(|| format!("{ctx}.mem must be a string"))?;
        if !mem_labels.iter().any(|m| m == mem) {
            return Err(format!("{ctx}: mem scenario '{mem}' not on the axis"));
        }
        if !adaptive[key] && (seg_label != "auto" || mem != mem_labels[0]) {
            return Err(format!(
                "{ctx}: non-adaptive method '{key}' off the baseline point"
            ));
        }
        // V4: the arrival coordinate, with non-adaptive methods pinned to
        // the single-run baseline. Pre-v4 artifacts carry no arrival key;
        // the uniqueness key below uses the baseline label for them.
        let arrival = if schema >= SweepSchema::V4 {
            let a = field(cell, "arrival", &ctx)?
                .as_str()
                .ok_or_else(|| format!("{ctx}.arrival must be a string"))?;
            if !arrival_labels.iter().any(|l| l == a) {
                return Err(format!("{ctx}: arrival '{a}' not on the axis"));
            }
            if !adaptive[key] && a != arrival_labels[0] {
                return Err(format!(
                    "{ctx}: non-adaptive method '{key}' off the single-run arrival point"
                ));
            }
            a.to_string()
        } else {
            "single".to_string()
        };
        // V5: the churn coordinate; methods that cannot run under churn
        // are pinned to the no-churn baseline label.
        let churn = if schema >= SweepSchema::V5 {
            let c = field(cell, "churn", &ctx)?
                .as_str()
                .ok_or_else(|| format!("{ctx}.churn must be a string"))?;
            if !churn_labels.iter().any(|l| l == c) {
                return Err(format!("{ctx}: churn '{c}' not on the axis"));
            }
            if !adaptive[key] && !churn_cap[key] && c != churn_labels[0] {
                return Err(format!(
                    "{ctx}: method '{key}' cannot run under churn but sits off the baseline"
                ));
            }
            c.to_string()
        } else {
            "none".to_string()
        };
        // V6: the batching coordinate. Continuous batching only has
        // meaning on the stream cells of adaptive methods — everything
        // else is pinned to the FIFO baseline label.
        let batching = if schema >= SweepSchema::V6 {
            let b = field(cell, "batching", &ctx)?
                .as_str()
                .ok_or_else(|| format!("{ctx}.batching must be a string"))?;
            if !batching_labels.iter().any(|l| l == b) {
                return Err(format!("{ctx}: batching '{b}' not on the axis"));
            }
            let is_stream = arrival_counts.contains_key(&arrival);
            if (!adaptive[key] || !is_stream) && b != batching_labels[0] {
                return Err(format!(
                    "{ctx}: batching '{b}' off the FIFO baseline on a non-stream cell"
                ));
            }
            b.to_string()
        } else {
            "fifo".to_string()
        };
        // V7: the workload coordinate. Mixed length distributions only
        // have meaning on the stream cells of adaptive methods —
        // everything else is pinned to the fixed baseline label.
        let workload = if schema >= SweepSchema::V7 {
            let w = field(cell, "workload", &ctx)?
                .as_str()
                .ok_or_else(|| format!("{ctx}.workload must be a string"))?;
            if !workload_labels.iter().any(|l| l == w) {
                return Err(format!("{ctx}: workload '{w}' not on the axis"));
            }
            let is_stream = arrival_counts.contains_key(&arrival);
            if (!adaptive[key] || !is_stream) && w != workload_labels[0] {
                return Err(format!(
                    "{ctx}: workload '{w}' off the fixed baseline on a non-stream cell"
                ));
            }
            w.to_string()
        } else {
            "fixed".to_string()
        };
        let is_oom = field(cell, "oom", &ctx)?
            .as_bool()
            .ok_or_else(|| format!("{ctx}.oom must be a bool"))?;
        let ms = field(cell, "ms_per_token", &ctx)?;
        if is_oom != (ms == &Json::Null) {
            return Err(format!("{ctx}: oom flag inconsistent with ms_per_token"));
        }
        if !is_oom && ms.as_f64().is_none() {
            return Err(format!("{ctx}.ms_per_token must be a number or null"));
        }
        let is_oot = field(cell, "oot", &ctx)?
            .as_bool()
            .ok_or_else(|| format!("{ctx}.oot must be a bool"))?;
        if is_oom && is_oot {
            return Err(format!("{ctx}: a cell cannot be both OOM and OOT"));
        }
        let counters: &[&str] = match schema {
            SweepSchema::V2 => &["online_plans_fired", "kv_tokens_transferred", "emergency_steps"],
            SweepSchema::V3 | SweepSchema::V4 => &[
                "online_plans_fired",
                "kv_tokens_transferred",
                "emergency_steps",
                "bw_stalls",
            ],
            SweepSchema::V5 | SweepSchema::V6 | SweepSchema::V7 => &[
                "online_plans_fired",
                "kv_tokens_transferred",
                "emergency_steps",
                "bw_stalls",
                "replans_fired",
                "kv_migrated_bytes",
            ],
        };
        for counter in counters {
            let v = field(cell, counter, &ctx)?;
            match (is_oom, v.as_u64()) {
                (true, _) if v == &Json::Null => {}
                (false, Some(_)) => {}
                _ => {
                    return Err(format!(
                        "{ctx}.{counter} must be a non-negative integer (null iff oom)"
                    ))
                }
            }
        }
        // V5: per-fault recovery latencies — an array of step counts (or
        // null for faults the run never recovered from) on completed
        // cells, null exactly on OOM cells.
        if schema >= SweepSchema::V5 {
            let rec = field(cell, "recovery_steps", &ctx)?;
            match (is_oom, rec) {
                (true, Json::Null) => {}
                (false, Json::Arr(entries)) => {
                    for (j, e) in entries.iter().enumerate() {
                        if e != &Json::Null && e.as_u64().is_none() {
                            return Err(format!(
                                "{ctx}.recovery_steps[{j}] must be a non-negative integer or null"
                            ));
                        }
                    }
                }
                _ => {
                    return Err(format!(
                        "{ctx}.recovery_steps must be an array of step counts (null iff oom)"
                    ))
                }
            }
        }
        // V6: the paged-KV counters — integers (null iff OOM), the
        // fragmentation ratio inside [0, 1], and all exactly zero off the
        // continuous points (FIFO models KV as contiguous preallocation).
        if schema >= SweepSchema::V6 {
            for counter in ["kv_pages_allocated", "kv_pages_spilled"] {
                let v = field(cell, counter, &ctx)?;
                match (is_oom, v.as_u64()) {
                    (true, _) if v == &Json::Null => {}
                    (false, Some(_)) => {}
                    _ => {
                        return Err(format!(
                            "{ctx}.{counter} must be a non-negative integer (null iff oom)"
                        ))
                    }
                }
            }
            let frag = field(cell, "fragmentation", &ctx)?;
            match (is_oom, frag.as_f64()) {
                (true, _) if frag == &Json::Null => {}
                (false, Some(f)) if (0.0..=1.0).contains(&f) => {}
                _ => {
                    return Err(format!(
                        "{ctx}.fragmentation must be a number in [0, 1] (null iff oom)"
                    ))
                }
            }
            if !is_oom && batching == batching_labels[0] {
                let pages = cell.get("kv_pages_allocated").and_then(Json::as_u64);
                let spilled = cell.get("kv_pages_spilled").and_then(Json::as_u64);
                let f = frag.as_f64();
                if pages != Some(0) || spilled != Some(0) || f != Some(0.0) {
                    return Err(format!(
                        "{ctx}: non-zero paged-KV counters on a FIFO cell"
                    ));
                }
            }
        }
        // V4: request-level metric arrays — an object with `count` equal-
        // length number arrays exactly on completed stream cells, null
        // everywhere else (single-run cells and OOM cells).
        if schema >= SweepSchema::V4 {
            let requests = field(cell, "requests", &ctx)?;
            match arrival_counts.get(&arrival) {
                Some(&count) if !is_oom => {
                    for rk in ["queueing_delay_s", "ttft_s", "tbt_s"] {
                        let arr = requests
                            .get(rk)
                            .and_then(Json::as_arr)
                            .ok_or_else(|| format!("{ctx}.requests.{rk} must be an array"))?;
                        if arr.len() != count {
                            return Err(format!(
                                "{ctx}.requests.{rk} has {} entries, expected {count} \
                                 (the '{arrival}' stream size)",
                                arr.len()
                            ));
                        }
                        if arr.iter().any(|v| v.as_f64().is_none()) {
                            return Err(format!(
                                "{ctx}.requests.{rk} entries must be numbers"
                            ));
                        }
                    }
                    // V7: each request's own lengths ride along with the
                    // metric arrays, entry-for-entry.
                    if schema >= SweepSchema::V7 {
                        for rk in ["prompt_len", "steps"] {
                            let arr = requests
                                .get(rk)
                                .and_then(Json::as_arr)
                                .ok_or_else(|| format!("{ctx}.requests.{rk} must be an array"))?;
                            if arr.len() != count {
                                return Err(format!(
                                    "{ctx}.requests.{rk} has {} entries, expected {count} \
                                     (the '{arrival}' stream size)",
                                    arr.len()
                                ));
                            }
                            if arr.iter().any(|v| v.as_usize().is_none()) {
                                return Err(format!(
                                    "{ctx}.requests.{rk} entries must be non-negative integers"
                                ));
                            }
                        }
                    }
                }
                _ => {
                    if requests != &Json::Null {
                        return Err(format!(
                            "{ctx}.requests must be null on single-run and OOM cells"
                        ));
                    }
                }
            }
        }
        let coords =
            format!("{key}|{bw}|{pattern}|{seg_label}|{mem}|{arrival}|{churn}|{batching}|{workload}");
        if !seen.insert(coords) {
            return Err(format!("{ctx}: duplicate cell coordinates"));
        }
        *per_method.entry(key.to_string()).or_default() += 1;
        if is_oom {
            oom += 1;
        } else {
            completed += 1;
        }
        if is_oot {
            oot += 1;
        }
    }
    let base = bandwidths.len() * patterns.len();
    // V6: the batching axis multiplies the stream arrival points only
    // (single-run cells have no admission loop to re-batch); V7 adds the
    // workload-mix factor on the same points.
    let arrival_cells = if schema >= SweepSchema::V6 {
        let streams = arrival_counts.len();
        let workload_factor = if schema >= SweepSchema::V7 {
            workload_labels.len()
        } else {
            1
        };
        (arrival_labels.len() - streams) + streams * batching_labels.len() * workload_factor
    } else if schema >= SweepSchema::V4 {
        arrival_labels.len()
    } else {
        1
    };
    let churn_axis_len = if schema >= SweepSchema::V5 {
        churn_labels.len()
    } else {
        1
    };
    for key in &methods {
        let expect = if adaptive[key] {
            base * seg_labels.len() * mem_labels.len() * arrival_cells * churn_axis_len
        } else if churn_cap[key] {
            base * churn_axis_len
        } else {
            base
        };
        let got = per_method.get(key).copied().unwrap_or(0);
        if got != expect {
            return Err(format!(
                "method '{key}': expected {expect} cells from the axis cross, found {got}"
            ));
        }
    }
    Ok(SweepSummary {
        grid,
        model,
        schema: schema.name().to_string(),
        cells: cells.len(),
        completed,
        oom,
        oot,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::baselines::all;

    fn tiny_matrix(methods: &[Box<dyn Method>]) -> ScenarioMatrix<'_> {
        ScenarioMatrix::new(
            "e1-test",
            ModelSpec::llama2_13b(),
            Cluster::env_e1(),
            methods,
            vec![100.0, 200.0],
            vec![Pattern::Sporadic, Pattern::Bursty],
            3,
        )
        .with_segs(vec![SegChoice::Auto, SegChoice::Fixed(4)])
        .with_mem_scenarios(vec![
            MemScenario::none(),
            MemScenario::squeeze("squeeze-d0", 0, crate::util::bytes::gib(2.0), 1),
        ])
        .with_arrivals(vec![
            ArrivalSpec::Single,
            ArrivalSpec::Stream {
                count: 3,
                lambda: 1.0,
            },
        ])
    }

    fn joint_matrix(methods: &[Box<dyn Method>]) -> ScenarioMatrix<'_> {
        ScenarioMatrix::new(
            "e1-joint",
            ModelSpec::llama2_13b(),
            Cluster::env_e1(),
            methods,
            vec![100.0, 200.0],
            vec![Pattern::Sporadic, Pattern::Bursty],
            4,
        )
        .with_pressure(vec![
            Script::none(),
            Script::from_mem(MemScenario::correlated_dip(
                "corr-dip",
                &[0, 1],
                1,
                crate::util::bytes::gib(2.0),
                1,
                3,
            )),
            Script::from_mem(MemScenario::squeeze(
                "sq",
                0,
                crate::util::bytes::gib(2.0),
                1,
            ))
            .with_bandwidth_sag(0.5, 1, 3)
            .with_label("joint-sag-squeeze"),
        ])
    }

    #[test]
    fn cell_count_expands_only_adaptive_methods() {
        let methods = all();
        let m = tiny_matrix(&methods);
        // 1 adaptive (LIME) × 2bw × 2pat × 2seg × 2mem × 2arrivals
        // + 6 baselines × 2bw × 2pat.
        assert_eq!(m.cell_count(), 32 + 24);
        assert_eq!(m.points().len(), m.cell_count());
    }

    #[test]
    fn baseline_methods_stay_on_baseline_point() {
        let methods = all();
        let m = tiny_matrix(&methods);
        for p in m.points() {
            if m.methods[p.mi].adaptive_exec().is_none() {
                assert_eq!((p.si, p.mj, p.ai), (0, 0, 0));
            }
        }
    }

    #[test]
    fn eval_emits_valid_v7_artifact() {
        let methods = all();
        let m = tiny_matrix(&methods);
        let cells = m.eval();
        assert_eq!(cells.len(), m.cell_count());
        let json = m.to_json(&cells);
        // Round-trip through the writer/parser, then validate.
        let parsed = Json::parse(&json.to_string()).unwrap();
        let summary = validate_sweep(&parsed).expect("artifact validates");
        assert_eq!(summary.grid, "e1-test");
        assert_eq!(summary.schema, "lime-sweep-v7");
        assert_eq!(summary.cells, m.cell_count());
        assert_eq!(summary.completed + summary.oom, summary.cells);
        // The dispatcher and the strict v7 validator agree; the strict
        // v2..v6 validators reject a v7 artifact by its schema tag.
        assert!(validate_sweep_v7(&parsed).is_ok());
        assert!(validate_sweep_v6(&parsed).is_err());
        assert!(validate_sweep_v5(&parsed).is_err());
        assert!(validate_sweep_v4(&parsed).is_err());
        assert!(validate_sweep_v3(&parsed).is_err());
        assert!(validate_sweep_v2(&parsed).is_err());
        // LIME completes on E1 at every override point; stream cells carry
        // per-request metric arrays of the stream size, single cells none.
        for c in cells.iter().filter(|c| c.method_key == "lime") {
            assert!(c.ms_per_token.is_some(), "{c:?}");
            assert!(c.planned_seg.is_some());
            assert!(c.bw_stalls.is_some());
            // Singleton batching axis: every cell sits on the FIFO point
            // with zeroed paged-KV counters.
            assert_eq!(c.batching, "fifo");
            // Singleton workload axis: every cell sits on the fixed
            // baseline shape.
            assert_eq!(c.workload, "fixed");
            assert_eq!(c.kv_pages_allocated, Some(0), "{c:?}");
            assert_eq!(c.kv_pages_spilled, Some(0), "{c:?}");
            assert_eq!(c.fragmentation, Some(0.0), "{c:?}");
            if let SegChoice::Fixed(k) = c.seg {
                assert_eq!(c.planned_seg, Some(k), "fixed seg must be honored");
            }
            if c.arrival == "single" {
                assert!(c.requests.is_none(), "{c:?}");
            } else {
                let r = c.requests.as_ref().expect("stream cell carries requests");
                assert_eq!(r.queueing_delay_s.len(), 3);
                assert_eq!(r.ttft_s.len(), 3);
                assert_eq!(r.tbt_s.len(), 3);
                assert!(r.ttft_s.iter().all(|&t| t > 0.0), "{c:?}");
                // Fixed-workload lengths: every request carries the
                // executor's default prompt and the matrix's tokens.
                assert_eq!(r.prompt_len, vec![64; 3], "{c:?}");
                assert_eq!(r.steps, vec![3; 3], "{c:?}");
            }
        }
        // Both arrival coordinates actually evaluated for LIME.
        assert!(cells.iter().any(|c| c.method_key == "lime" && c.arrival == "single"));
        assert!(cells.iter().any(|c| c.method_key == "lime" && c.arrival == "stream3"));
    }

    /// `tiny_matrix` without the stream arrival point — the shape whose
    /// artifacts downgrade to v3/v2 by schema relabel (a stream axis adds
    /// cells, which the older validators' exact-count checks reject).
    fn tiny_matrix_single_arrival(methods: &[Box<dyn Method>]) -> ScenarioMatrix<'_> {
        ScenarioMatrix::new(
            "e1-test",
            ModelSpec::llama2_13b(),
            Cluster::env_e1(),
            methods,
            vec![100.0, 200.0],
            vec![Pattern::Sporadic, Pattern::Bursty],
            3,
        )
        .with_segs(vec![SegChoice::Auto, SegChoice::Fixed(4)])
        .with_mem_scenarios(vec![
            MemScenario::none(),
            MemScenario::squeeze("squeeze-d0", 0, crate::util::bytes::gib(2.0), 1),
        ])
    }

    #[test]
    fn v7_artifact_downgrades_to_v3_by_relabel() {
        // Strict-superset chain: with singleton arrival, churn, batching
        // and workload axes, relabel a v7 artifact as v3 and it validates
        // as v3 (the extra arrival/churn/batching/workload keys are
        // ignored).
        let methods = all();
        let m = tiny_matrix_single_arrival(&methods);
        let cells = m.eval();
        let parsed = Json::parse(&m.to_json(&cells).to_string()).unwrap();
        let Json::Obj(mut map) = parsed else {
            panic!("artifact must be an object")
        };
        map.insert("schema".into(), "lime-sweep-v3".into());
        let v3 = Json::Obj(map);
        let summary = validate_sweep(&v3).expect("relabelled artifact validates as v3");
        assert_eq!(summary.schema, "lime-sweep-v3");
        assert!(validate_sweep_v3(&v3).is_ok());
        assert!(validate_sweep_v4(&v3).is_err());
    }

    #[test]
    fn v7_artifact_downgrades_to_v4_by_relabel() {
        // With singleton churn, batching and workload axes the cell set
        // is exactly a v4 cross: relabel the artifact as v4 and it
        // validates (the churn/paged-KV/workload keys are v5/v6/v7
        // additions v4 ignores).
        let methods = all();
        let m = tiny_matrix(&methods);
        let cells = m.eval();
        let parsed = Json::parse(&m.to_json(&cells).to_string()).unwrap();
        let Json::Obj(mut map) = parsed else {
            panic!("artifact must be an object")
        };
        map.insert("schema".into(), "lime-sweep-v4".into());
        let v4 = Json::Obj(map);
        let summary = validate_sweep(&v4).expect("relabelled artifact validates as v4");
        assert_eq!(summary.schema, "lime-sweep-v4");
        assert!(validate_sweep_v4(&v4).is_ok());
        assert!(validate_sweep_v5(&v4).is_err());
    }

    #[test]
    fn v7_artifact_downgrades_to_v5_by_relabel() {
        // With singleton batching and workload axes the cell set is
        // exactly a v5 cross: relabel the artifact as v5 and it validates
        // (the batching/paged-KV/workload keys are v6/v7 additions v5
        // ignores). The strict v6 validator rejects the relabelled
        // artifact by its schema tag.
        let methods = all();
        let m = tiny_matrix(&methods);
        let cells = m.eval();
        let parsed = Json::parse(&m.to_json(&cells).to_string()).unwrap();
        let Json::Obj(mut map) = parsed else {
            panic!("artifact must be an object")
        };
        map.insert("schema".into(), "lime-sweep-v5".into());
        let v5 = Json::Obj(map);
        let summary = validate_sweep(&v5).expect("relabelled artifact validates as v5");
        assert_eq!(summary.schema, "lime-sweep-v5");
        assert!(validate_sweep_v5(&v5).is_ok());
        assert!(validate_sweep_v6(&v5).is_err());
    }

    #[test]
    fn v7_artifact_downgrades_to_v6_by_relabel() {
        // With a singleton workload axis the cell set is exactly a v6
        // cross: relabel the artifact as v6 and it validates (the
        // workload axis, per-cell coordinate and length arrays are v7
        // additions v6 ignores). The strict v7 validator rejects the
        // relabelled artifact by its schema tag.
        let methods = all();
        let m = tiny_matrix(&methods);
        let cells = m.eval();
        let parsed = Json::parse(&m.to_json(&cells).to_string()).unwrap();
        let Json::Obj(mut map) = parsed else {
            panic!("artifact must be an object")
        };
        map.insert("schema".into(), "lime-sweep-v6".into());
        let v6 = Json::Obj(map);
        let summary = validate_sweep(&v6).expect("relabelled artifact validates as v6");
        assert_eq!(summary.schema, "lime-sweep-v6");
        assert!(validate_sweep_v6(&v6).is_ok());
        assert!(validate_sweep_v7(&v6).is_err());
    }

    #[test]
    fn joint_scripts_evaluate_and_serialize() {
        let methods = all();
        let m = joint_matrix(&methods);
        let cells = m.eval();
        assert_eq!(cells.len(), m.cell_count());
        // Correlated and joint cells exist and completed for LIME.
        for label in ["corr-dip", "joint-sag-squeeze"] {
            let cell = cells
                .iter()
                .find(|c| c.method_key == "lime" && c.mem == label)
                .unwrap_or_else(|| panic!("no lime cell for '{label}'"));
            assert!(cell.ms_per_token.is_some(), "{label}: {cell:?}");
            assert!(cell.bw_stalls.is_some());
        }
        let parsed = Json::parse(&m.to_json(&cells).to_string()).unwrap();
        let summary = validate_sweep(&parsed).expect("joint artifact validates");
        assert_eq!(summary.cells, m.cell_count());
        // Full script metadata survives serialization.
        let scripts = parsed
            .path(&["axes", "pressure_scripts"])
            .and_then(Json::as_arr)
            .expect("pressure_scripts axis");
        assert_eq!(scripts.len(), 3);
        let joint = &scripts[2];
        assert_eq!(
            joint.get("label").and_then(Json::as_str),
            Some("joint-sag-squeeze")
        );
        assert_eq!(
            joint.get("bw_events").and_then(Json::as_arr).map(<[Json]>::len),
            Some(2)
        );
        assert_eq!(
            joint.get("mem_events").and_then(Json::as_arr).map(<[Json]>::len),
            Some(1)
        );
    }

    #[test]
    fn validate_sweep_v2_still_accepts_v2_artifacts() {
        // Build a (singleton-arrival) v4 artifact, strip the v3 additions,
        // relabel as v2 — the compatibility path `lime sweep-check` keeps
        // for old artifacts.
        let methods = all();
        let m = tiny_matrix_single_arrival(&methods);
        let cells = m.eval();
        let parsed = Json::parse(&m.to_json(&cells).to_string()).unwrap();
        let Json::Obj(mut map) = parsed else {
            panic!("artifact must be an object")
        };
        map.insert("schema".into(), "lime-sweep-v2".into());
        if let Some(Json::Obj(axes)) = map.get_mut("axes") {
            axes.remove("pressure_scripts");
        }
        let v2 = Json::Obj(map);
        let summary = validate_sweep(&v2).expect("downgraded artifact validates as v2");
        assert_eq!(summary.schema, "lime-sweep-v2");
        assert!(validate_sweep_v2(&v2).is_ok());
        assert!(validate_sweep_v3(&v2).is_err());
    }

    #[test]
    fn validator_rejects_corruptions() {
        let methods = all();
        let m = tiny_matrix(&methods);
        let cells = m.eval();
        let good = m.to_json(&cells).to_string();
        assert!(validate_sweep(&Json::parse(&good).unwrap()).is_ok());
        for (needle, replacement, why) in [
            ("lime-sweep-v7", "lime-sweep-v1", "unknown schema"),
            ("\"sporadic\"", "\"sporadıc\"", "unknown pattern"),
            ("\"oom\":false", "\"oom\":true", "oom/ms inconsistency"),
            ("\"arrival\":\"stream3\"", "\"arrival\":\"stream9\"", "off-axis arrival"),
            ("\"churn\":\"none\"", "\"churn\":\"ghost\"", "off-axis churn"),
            ("\"batching\":\"fifo\"", "\"batching\":\"warp\"", "off-axis batching"),
            ("\"workload\":\"fixed\"", "\"workload\":\"warped\"", "off-axis workload"),
        ] {
            let bad = good.replacen(needle, replacement, 1);
            assert_ne!(bad, good, "{why}: replacement must apply");
            let parsed = Json::parse(&bad).unwrap();
            assert!(validate_sweep(&parsed).is_err(), "{why} must be rejected");
        }
        // Dropping one cell breaks the per-method count check.
        let parsed = Json::parse(&good).unwrap();
        if let Json::Obj(mut map) = parsed {
            if let Some(Json::Arr(cells)) = map.get_mut("cells") {
                cells.pop();
            }
            assert!(validate_sweep(&Json::Obj(map)).is_err());
        } else {
            panic!("artifact must be an object");
        }
        // Dropping the v3 script axis must fail a v3 artifact.
        let parsed = Json::parse(&good).unwrap();
        if let Json::Obj(mut map) = parsed {
            if let Some(Json::Obj(axes)) = map.get_mut("axes") {
                axes.remove("pressure_scripts");
            }
            assert!(validate_sweep(&Json::Obj(map)).is_err());
        } else {
            panic!("artifact must be an object");
        }
        // Corrupting a script's memory channel away from its v2 projection
        // must fail: the metadata would describe a script that never ran.
        let parsed = Json::parse(&good).unwrap();
        if let Json::Obj(mut map) = parsed {
            let Some(Json::Obj(axes)) = map.get_mut("axes") else {
                panic!("axes must be an object")
            };
            let Some(Json::Arr(scripts)) = axes.get_mut("pressure_scripts") else {
                panic!("pressure_scripts must be an array")
            };
            let Some(Json::Obj(script)) = scripts.get_mut(1) else {
                panic!("script 1 must be an object")
            };
            let Some(Json::Arr(events)) = script.get_mut("mem_events") else {
                panic!("mem_events must be an array")
            };
            let Some(Json::Obj(ev)) = events.get_mut(0) else {
                panic!("event 0 must be an object")
            };
            ev.insert("delta_bytes".into(), Json::Num(12345.0));
            let err = validate_sweep(&Json::Obj(map)).unwrap_err();
            assert!(err.contains("disagrees"), "unexpected error: {err}");
        } else {
            panic!("artifact must be an object");
        }
        // Dropping the v4 arrival axis must fail a v4+ artifact.
        let parsed = Json::parse(&good).unwrap();
        if let Json::Obj(mut map) = parsed {
            if let Some(Json::Obj(axes)) = map.get_mut("axes") {
                axes.remove("arrivals");
            }
            assert!(validate_sweep(&Json::Obj(map)).is_err());
        } else {
            panic!("artifact must be an object");
        }
        // Dropping the v5 churn axis must fail a v5 artifact.
        let parsed = Json::parse(&good).unwrap();
        if let Json::Obj(mut map) = parsed {
            if let Some(Json::Obj(axes)) = map.get_mut("axes") {
                axes.remove("churn_scripts");
            }
            assert!(validate_sweep(&Json::Obj(map)).is_err());
        } else {
            panic!("artifact must be an object");
        }
        // Dropping the v6 batching axis must fail a v6 artifact.
        let parsed = Json::parse(&good).unwrap();
        if let Json::Obj(mut map) = parsed {
            if let Some(Json::Obj(axes)) = map.get_mut("axes") {
                axes.remove("batching");
            }
            assert!(validate_sweep(&Json::Obj(map)).is_err());
        } else {
            panic!("artifact must be an object");
        }
        // Dropping the v7 workload axis must fail a v7 artifact.
        let parsed = Json::parse(&good).unwrap();
        if let Json::Obj(mut map) = parsed {
            if let Some(Json::Obj(axes)) = map.get_mut("axes") {
                axes.remove("workloads");
            }
            assert!(validate_sweep(&Json::Obj(map)).is_err());
        } else {
            panic!("artifact must be an object");
        }
        // A non-zero page counter on a FIFO cell must fail: FIFO models
        // KV as a contiguous preallocation, never pages.
        let bad = good.replacen("\"kv_pages_allocated\":0", "\"kv_pages_allocated\":7", 1);
        assert_ne!(bad, good, "a completed FIFO cell must exist");
        let err = validate_sweep(&Json::parse(&bad).unwrap()).unwrap_err();
        assert!(err.contains("FIFO cell"), "unexpected error: {err}");
        // Nulling a completed stream cell's request arrays must fail: the
        // per-request metrics are the point of the arrival axis.
        let parsed = Json::parse(&good).unwrap();
        if let Json::Obj(mut map) = parsed {
            let Some(Json::Arr(cells)) = map.get_mut("cells") else {
                panic!("cells must be an array")
            };
            let stream_cell = cells
                .iter_mut()
                .find(|c| {
                    c.get("arrival").and_then(Json::as_str) == Some("stream3")
                        && c.get("oom").and_then(Json::as_bool) == Some(false)
                })
                .expect("a completed stream cell exists");
            let Json::Obj(cell) = stream_cell else {
                panic!("cell must be an object")
            };
            cell.insert("requests".into(), Json::Null);
            let err = validate_sweep(&Json::Obj(map)).unwrap_err();
            assert!(err.contains("requests"), "unexpected error: {err}");
        } else {
            panic!("artifact must be an object");
        }
    }

    #[test]
    #[should_panic]
    fn segs_must_start_with_auto() {
        let methods = all();
        let _ = tiny_matrix(&methods).with_segs(vec![SegChoice::Fixed(4)]);
    }

    #[test]
    #[should_panic]
    fn scenarios_must_stay_inside_cluster() {
        let methods = all();
        let _ = tiny_matrix(&methods).with_mem_scenarios(vec![
            MemScenario::none(),
            MemScenario::squeeze("oob", 9, 1, 0),
        ]);
    }

    #[test]
    #[should_panic]
    fn pressure_must_start_with_empty_script() {
        let methods = all();
        let _ = tiny_matrix(&methods)
            .with_pressure(vec![Script::bandwidth_sag("sag-only", 0.5, 1, 2)]);
    }

    #[test]
    fn churn_axis_expands_lime_and_edgeshard() {
        let methods = all();
        let m = ScenarioMatrix::new(
            "e1-churn",
            ModelSpec::llama2_13b(),
            Cluster::env_e1(),
            &methods,
            vec![100.0, 200.0],
            vec![Pattern::Sporadic, Pattern::Bursty],
            8,
        )
        .with_churn(vec![
            Script::none(),
            Script::device_down_up("d1-blip", 1, 2, 6),
        ]);
        // 1 adaptive (LIME) × 4 base × 2 churn + EdgeShard × 4 × 2 churn
        // + 5 other baselines × 4.
        assert_eq!(m.cell_count(), 8 + 8 + 20);
        let cells = m.eval();
        assert_eq!(cells.len(), m.cell_count());

        // LIME under the fault: re-plans fire, KV migrates off the dead
        // device, and the fault's recovery latency is tracked.
        for c in cells.iter().filter(|c| c.method_key == "lime" && c.churn == "d1-blip") {
            assert!(c.ms_per_token.is_some(), "{c:?}");
            assert!(c.replans_fired.unwrap() >= 1, "{c:?}");
            assert!(c.kv_migrated_bytes.unwrap() > 0, "{c:?}");
            assert_eq!(c.recovery_steps.as_ref().unwrap().len(), 1, "{c:?}");
        }
        // EdgeShard runs the same fault without re-planning or migration —
        // the honest-degradation comparison. Its recovery latency is still
        // recorded by the executor core.
        for c in cells.iter().filter(|c| c.method_key == "edgeshard" && c.churn == "d1-blip") {
            assert!(c.ms_per_token.is_some(), "{c:?}");
            assert_eq!(c.replans_fired, Some(0), "{c:?}");
            assert_eq!(c.kv_migrated_bytes, Some(0), "{c:?}");
            assert_eq!(c.recovery_steps.as_ref().unwrap().len(), 1, "{c:?}");
            // Degradation shows up against the no-churn twin cell.
            let base = cells
                .iter()
                .find(|b| {
                    b.method_key == "edgeshard"
                        && b.churn == "none"
                        && b.bandwidth_mbps == c.bandwidth_mbps
                        && b.pattern == c.pattern
                })
                .expect("baseline twin exists");
            assert!(
                c.ms_per_token.unwrap() >= base.ms_per_token.unwrap(),
                "churn must not speed EdgeShard up: {c:?} vs {base:?}"
            );
        }
        // Non-churn-capable baselines stay on the baseline point.
        assert!(cells
            .iter()
            .filter(|c| c.method_key == "galaxy" || c.method_key == "pp")
            .all(|c| c.churn == "none"));

        // The artifact round-trips through the strict v7 validator.
        let parsed = Json::parse(&m.to_json(&cells).to_string()).unwrap();
        let summary = validate_sweep_v7(&parsed).expect("churned artifact validates");
        assert_eq!(summary.cells, m.cell_count());
    }

    #[test]
    #[should_panic]
    fn churn_must_start_with_no_events() {
        let methods = all();
        let _ = tiny_matrix(&methods)
            .with_churn(vec![Script::device_down_up("blip", 0, 1, 2)]);
    }

    #[test]
    #[should_panic]
    fn churn_scripts_must_leave_a_survivor() {
        let methods = all();
        let _ = tiny_matrix(&methods).with_churn(vec![
            Script::none(),
            Script::fleet_churn("kill-all", &[0, 1], 0, 1, 5),
        ]);
    }

    #[test]
    #[should_panic]
    fn arrivals_must_start_with_single() {
        let methods = all();
        let _ = tiny_matrix(&methods).with_arrivals(vec![ArrivalSpec::Stream {
            count: 4,
            lambda: 1.0,
        }]);
    }

    #[test]
    #[should_panic]
    fn batching_must_start_with_fifo() {
        let methods = all();
        let _ = tiny_matrix(&methods)
            .with_batching(vec![BatchingSpec::Continuous { page_tokens: 16 }]);
    }

    #[test]
    fn batching_axis_expands_stream_cells() {
        let methods = all();
        let m = tiny_matrix(&methods)
            .with_batching(vec![BatchingSpec::Fifo, BatchingSpec::Continuous { page_tokens: 16 }]);
        // LIME: 2bw × 2pat × 2seg × 2mem × (single + stream3 × 2 batching)
        // = 48; the 6 baselines stay at 2bw × 2pat each.
        assert_eq!(m.cell_count(), 48 + 24);
        let cells = m.eval();
        assert_eq!(cells.len(), m.cell_count());

        // Continuous points exist exactly on LIME's stream cells.
        for c in &cells {
            if c.batching != "fifo" {
                assert_eq!(c.method_key, "lime", "{c:?}");
                assert_eq!(c.arrival, "stream3", "{c:?}");
                assert_eq!(c.batching, "cont16", "{c:?}");
            }
        }
        for c in cells.iter().filter(|c| c.method_key == "lime") {
            assert!(c.ms_per_token.is_some(), "{c:?}");
            if c.batching == "cont16" {
                // The paged model accounted this cell; the grid budget is
                // sized so nothing spills.
                assert!(c.kv_pages_allocated.unwrap() > 0, "{c:?}");
                assert_eq!(c.kv_pages_spilled, Some(0), "{c:?}");
                let f = c.fragmentation.unwrap();
                assert!((0.0..=1.0).contains(&f), "{c:?}");
            } else {
                assert_eq!(c.kv_pages_allocated, Some(0), "{c:?}");
                assert_eq!(c.kv_pages_spilled, Some(0), "{c:?}");
                assert_eq!(c.fragmentation, Some(0.0), "{c:?}");
            }
        }
        // Continuous admission never queues a request longer than FIFO on
        // the same coordinates (prefill-ahead only admits earlier).
        for c in cells.iter().filter(|c| c.batching == "cont16") {
            let twin = cells
                .iter()
                .find(|f| {
                    f.batching == "fifo"
                        && f.method_key == c.method_key
                        && f.bandwidth_mbps == c.bandwidth_mbps
                        && f.pattern == c.pattern
                        && f.seg == c.seg
                        && f.mem == c.mem
                        && f.arrival == c.arrival
                })
                .expect("FIFO twin exists");
            let mean = |r: &RequestLevel| {
                r.queueing_delay_s.iter().sum::<f64>() / r.queueing_delay_s.len() as f64
            };
            let cont = mean(c.requests.as_ref().unwrap());
            let fifo = mean(twin.requests.as_ref().unwrap());
            assert!(
                cont <= fifo + 1e-12,
                "continuous queueing must not exceed FIFO: {cont} vs {fifo} in {c:?}"
            );
        }

        // Round-trips through the strict v7 validator; a v5 relabel fails
        // because the continuous cells break v5's exact axis cross.
        let parsed = Json::parse(&m.to_json(&cells).to_string()).unwrap();
        let summary = validate_sweep_v7(&parsed).expect("batched artifact validates");
        assert_eq!(summary.cells, m.cell_count());
        let Json::Obj(mut map) = parsed else {
            panic!("artifact must be an object")
        };
        map.insert("schema".into(), "lime-sweep-v5".into());
        assert!(validate_sweep(&Json::Obj(map)).is_err());
    }

    #[test]
    fn workload_axis_expands_stream_cells() {
        let methods = all();
        let m = tiny_matrix(&methods)
            .with_arrivals(vec![
                ArrivalSpec::Single,
                ArrivalSpec::Stream {
                    count: 12,
                    lambda: 2.0,
                },
            ])
            .with_workloads(vec![
                LengthDist::fixed(64, 3),
                LengthDist::Bimodal {
                    short: (32, 2),
                    long: (128, 8),
                    long_frac: 0.5,
                },
            ]);
        // LIME: 2bw × 2pat × 2seg × 2mem × (single + stream12 × 1 batching
        // × 2 workloads) = 48; the 6 baselines stay at 2bw × 2pat each.
        assert_eq!(m.cell_count(), 48 + 24);
        let cells = m.eval();
        assert_eq!(cells.len(), m.cell_count());

        // Mixed-length points exist exactly on LIME's stream cells.
        for c in &cells {
            if c.workload != "fixed" {
                assert_eq!(c.method_key, "lime", "{c:?}");
                assert_eq!(c.arrival, "stream12", "{c:?}");
                assert_eq!(c.workload, "bimix50", "{c:?}");
            }
        }
        // Per-request length arrays mirror the distribution that drew them:
        // the fixed coordinate reproduces the global-knob lengths exactly,
        // the bimodal coordinate is ragged across the two modes.
        for c in cells.iter().filter(|c| c.arrival == "stream12") {
            let r = c.requests.as_ref().expect("stream cells carry requests");
            assert_eq!(r.prompt_len.len(), 12, "{c:?}");
            assert_eq!(r.steps.len(), 12, "{c:?}");
            if c.workload == "fixed" {
                assert_eq!(r.prompt_len, vec![64; 12], "{c:?}");
                assert_eq!(r.steps, vec![3; 12], "{c:?}");
            } else {
                for (&p, &s) in r.prompt_len.iter().zip(&r.steps) {
                    assert!(
                        (p, s) == (32, 2) || (p, s) == (128, 8),
                        "off-mode request ({p}, {s}) in {c:?}"
                    );
                }
                assert!(
                    r.prompt_len.contains(&32) && r.prompt_len.contains(&128),
                    "bimodal stream must mix both modes: {:?}",
                    r.prompt_len
                );
            }
        }
        for c in cells.iter().filter(|c| c.method_key == "lime") {
            assert!(c.ms_per_token.is_some(), "{c:?}");
        }

        // Round-trips through the strict v7 validator with the workload
        // coordinate folded into the coverage cross.
        let parsed = Json::parse(&m.to_json(&cells).to_string()).unwrap();
        let summary = validate_sweep_v7(&parsed).expect("mixed artifact validates");
        assert_eq!(summary.cells, m.cell_count());
    }

    #[test]
    fn stream_cells_reflect_the_arrival_pattern() {
        // Bursty streams queue (every request after the first batch waits);
        // sporadic streams spread arrivals. Request-level arrays surface
        // exactly that.
        let methods = all();
        let m = tiny_matrix(&methods);
        let cells = m.eval();
        let stream = |pattern: Pattern| {
            cells
                .iter()
                .find(|c| {
                    c.method_key == "lime"
                        && c.pattern == pattern
                        && c.arrival == "stream3"
                        && c.seg == SegChoice::Auto
                        && c.mem == "none"
                })
                .and_then(|c| c.requests.as_ref())
                .expect("completed stream cell")
        };
        let bursty = stream(Pattern::Bursty);
        let sporadic = stream(Pattern::Sporadic);
        // All bursty requests arrive at t=0; the first is admitted with no
        // wait, so its delay is exactly zero.
        assert_eq!(bursty.queueing_delay_s[0], 0.0);
        assert!(bursty.queueing_delay_s.iter().all(|&q| q >= 0.0));
        assert!(sporadic.queueing_delay_s.iter().all(|&q| q >= 0.0));
        assert!(bursty.ttft_s.iter().zip(&bursty.queueing_delay_s).all(|(t, q)| t >= q));
    }
}
