//! Model shape descriptions and derived cost quantities.

pub mod spec;

pub use spec::ModelSpec;
