//! Model shape descriptions (paper Tab. III) and the derived byte/FLOP
//! quantities the cost model consumes: per-layer memory `l_size`, activation
//! size `h_size`, MHA/MLP memory proportions `p_A`/`p_M`, KV-cache bytes per
//! token, and decode FLOPs per token per layer.

/// Architectural description of a decoder-only LLM.
#[derive(Debug, Clone, PartialEq)]
pub struct ModelSpec {
    pub name: String,
    /// Number of decoder layers (`|L|` in the paper).
    pub layers: usize,
    pub hidden: usize,
    /// Query attention heads.
    pub heads: usize,
    /// KV heads (GQA); == heads for classic MHA.
    pub kv_heads: usize,
    /// SwiGLU / MLP inner width.
    pub ffn: usize,
    pub vocab: usize,
    /// Bytes per weight element (2 = fp16/bf16 deployment, 4 = f32).
    pub dtype_bytes: u64,
    /// Sliding-window attention cap: when set, a layer attends over (and
    /// caches KV for) at most this many trailing tokens, bounding KV
    /// bytes/context and attention FLOPs. `None` = full attention — the
    /// identity on every derived quantity (all Tab. III presets).
    pub sliding_window: Option<usize>,
}

impl ModelSpec {
    /// Llama2-13B-Instruct (Tab. III row 1): 40 layers, hidden 5120,
    /// 40 heads, 40 KV heads (MHA), ffn 13824.
    pub fn llama2_13b() -> Self {
        ModelSpec {
            name: "Llama2-13B-Instruct".into(),
            layers: 40,
            hidden: 5120,
            heads: 40,
            kv_heads: 40,
            ffn: 13824,
            vocab: 32000,
            dtype_bytes: 2,
            sliding_window: None,
        }
    }

    /// Qwen3-32B (Tab. III row 2): 64 layers, hidden 5120, 64 heads,
    /// 8 KV heads, ffn 25600.
    pub fn qwen3_32b() -> Self {
        ModelSpec {
            name: "Qwen3-32B".into(),
            layers: 64,
            hidden: 5120,
            heads: 64,
            kv_heads: 8,
            ffn: 25600,
            vocab: 151936,
            dtype_bytes: 2,
            sliding_window: None,
        }
    }

    /// Llama3.3-70B-Instruct (Tab. III row 3): 80 layers, hidden 8192,
    /// 64 heads, 8 KV heads, ffn 28672.
    pub fn llama33_70b() -> Self {
        ModelSpec {
            name: "Llama3.3-70B-Instruct".into(),
            layers: 80,
            hidden: 8192,
            heads: 64,
            kv_heads: 8,
            ffn: 28672,
            vocab: 128256,
            dtype_bytes: 2,
            sliding_window: None,
        }
    }

    /// TinyLM — the synthetic-weight model actually served through PJRT
    /// (python/compile/config.py must stay in sync).
    pub fn tiny_lm() -> Self {
        ModelSpec {
            name: "TinyLM".into(),
            layers: 8,
            hidden: 128,
            heads: 8,
            kv_heads: 2,
            ffn: 384,
            vocab: 256,
            dtype_bytes: 4,
            sliding_window: None,
        }
    }

    pub fn by_name(name: &str) -> Option<Self> {
        match name.to_ascii_lowercase().as_str() {
            "llama2-13b" | "llama2-13b-instruct" => Some(Self::llama2_13b()),
            "qwen3-32b" => Some(Self::qwen3_32b()),
            "llama3.3-70b" | "llama3.3-70b-instruct" | "llama33-70b" => {
                Some(Self::llama33_70b())
            }
            "tiny" | "tinylm" => Some(Self::tiny_lm()),
            _ => None,
        }
    }

    pub fn head_dim(&self) -> usize {
        self.hidden / self.heads
    }

    // ---------------------------------------------------- variant builders

    /// KV-shape variant: override the KV-head count (GQA/MQA ablations —
    /// `1` = MQA, `heads` = MHA). Scales `kv_bytes_per_token_layer` and
    /// the Wk/Wv parameter bytes exactly as a retrained variant would.
    pub fn with_kv_heads(mut self, kv_heads: usize) -> Self {
        assert!(
            kv_heads >= 1 && self.heads % kv_heads == 0,
            "kv_heads {kv_heads} must divide query heads {}",
            self.heads
        );
        self.kv_heads = kv_heads;
        self.name = format!("{}-kv{kv_heads}", self.name);
        self
    }

    /// KV-shape variant: cap attention (and cached KV) at a sliding
    /// window of `window` trailing tokens.
    pub fn with_sliding_window(mut self, window: usize) -> Self {
        assert!(window >= 1, "window must hold at least one token");
        self.sliding_window = Some(window);
        self.name = format!("{}-swa{window}", self.name);
        self
    }

    /// Tokens actually cached/attended at logical context `ctx`:
    /// `min(ctx, window)` under sliding-window attention, `ctx` (the
    /// identity) for full-attention specs — so every pre-variant spec
    /// keeps bit-identical derived quantities.
    pub fn kv_ctx(&self, ctx: usize) -> usize {
        match self.sliding_window {
            Some(w) => ctx.min(w),
            None => ctx,
        }
    }

    // ------------------------------------------------------------ memory

    /// MHA block parameter bytes: Wq + Wo (hidden x hidden each) and
    /// Wk + Wv (hidden x kv_heads*head_dim each), plus the attn RMSNorm.
    pub fn mha_bytes(&self) -> u64 {
        let h = self.hidden as u64;
        let kv = (self.kv_heads * self.head_dim()) as u64;
        (h * h + h * h + 2 * h * kv + h) * self.dtype_bytes
    }

    /// MLP block parameter bytes: gate + up + down projections plus norm.
    pub fn mlp_bytes(&self) -> u64 {
        let h = self.hidden as u64;
        let f = self.ffn as u64;
        (3 * h * f + h) * self.dtype_bytes
    }

    /// `l_size`: memory footprint of one decoder layer.
    pub fn layer_bytes(&self) -> u64 {
        self.mha_bytes() + self.mlp_bytes()
    }

    /// `p_A`: fraction of a layer's memory held by the MHA block.
    pub fn p_attn(&self) -> f64 {
        self.mha_bytes() as f64 / self.layer_bytes() as f64
    }

    /// `p_M`: fraction of a layer's memory held by the MLP block.
    pub fn p_mlp(&self) -> f64 {
        self.mlp_bytes() as f64 / self.layer_bytes() as f64
    }

    /// `h_size`: bytes of one micro-batch's activation between stages
    /// (batch 1, single token in decode).
    pub fn h_size(&self, micro_batch: usize) -> u64 {
        (micro_batch * self.hidden) as u64 * self.dtype_bytes
    }

    /// KV-cache bytes per token per layer (K and V for all KV heads).
    pub fn kv_bytes_per_token_layer(&self) -> u64 {
        2 * (self.kv_heads * self.head_dim()) as u64 * self.dtype_bytes
    }

    /// KV-cache bytes per token across `layer_count` resident layers.
    pub fn kv_bytes_per_token(&self, layer_count: usize) -> u64 {
        self.kv_bytes_per_token_layer() * layer_count as u64
    }

    /// Embedding + LM-head bytes (held by the first/last pipeline device).
    pub fn embed_bytes(&self) -> u64 {
        2 * (self.vocab * self.hidden) as u64 * self.dtype_bytes
    }

    /// Total parameter bytes of the decoder stack.
    pub fn total_bytes(&self) -> u64 {
        self.layer_bytes() * self.layers as u64 + self.embed_bytes()
    }

    // ----------------------------------------------------------- compute

    /// Decode-step FLOPs for one token through one layer: 2 * params
    /// (matmul dominated) + attention over the cached tokens (at most
    /// the sliding window when the spec caps one).
    pub fn layer_decode_flops(&self, ctx: usize) -> f64 {
        let ctx = self.kv_ctx(ctx);
        let param_elems = (self.layer_bytes() / self.dtype_bytes) as f64;
        let attn = 2.0 * 2.0 * (self.heads * self.head_dim() * ctx) as f64;
        2.0 * param_elems + attn
    }

    /// Prefill FLOPs for a `prompt` of tokens through one layer. Each
    /// position attends over at most `kv_ctx(prompt)` keys, so the
    /// quadratic term flattens to `prompt × window` under a sliding
    /// window (and is untouched for full attention).
    pub fn layer_prefill_flops(&self, prompt: usize) -> f64 {
        let param_elems = (self.layer_bytes() / self.dtype_bytes) as f64;
        let attn = 2.0 * 2.0 * (self.heads * self.head_dim()) as f64
            * (prompt * self.kv_ctx(prompt)) as f64
            / 2.0;
        2.0 * param_elems * prompt as f64 + attn
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::bytes::GIB;

    #[test]
    fn presets_match_table_iii() {
        let l13 = ModelSpec::llama2_13b();
        assert_eq!((l13.layers, l13.hidden, l13.heads, l13.kv_heads), (40, 5120, 40, 40));
        let q32 = ModelSpec::qwen3_32b();
        assert_eq!((q32.layers, q32.hidden, q32.heads, q32.kv_heads), (64, 5120, 64, 8));
        let l70 = ModelSpec::llama33_70b();
        assert_eq!((l70.layers, l70.hidden, l70.heads, l70.kv_heads), (80, 8192, 64, 8));
    }

    #[test]
    fn llama70b_roughly_140gb_fp16() {
        // Paper §I: Llama3.3-70B needs >= 130 GB for inference.
        let spec = ModelSpec::llama33_70b();
        let gb = spec.total_bytes() as f64 / GIB as f64;
        assert!((120.0..160.0).contains(&gb), "got {gb} GiB");
    }

    #[test]
    fn proportions_sum_to_one() {
        for spec in [
            ModelSpec::llama2_13b(),
            ModelSpec::qwen3_32b(),
            ModelSpec::llama33_70b(),
            ModelSpec::tiny_lm(),
        ] {
            assert!((spec.p_attn() + spec.p_mlp() - 1.0).abs() < 1e-12);
            assert!(spec.p_attn() > 0.0 && spec.p_mlp() > 0.0);
        }
    }

    #[test]
    fn gqa_shrinks_kv_cache() {
        let mha = ModelSpec::llama2_13b(); // 40 kv heads
        let gqa = ModelSpec::qwen3_32b(); // 8 kv heads
        assert!(
            mha.kv_bytes_per_token_layer() > gqa.kv_bytes_per_token_layer()
        );
        // Qwen3-32B: 8 kv heads * 80 head_dim * 2 (K,V) * 2 bytes = 2560 B.
        assert_eq!(gqa.kv_bytes_per_token_layer(), 2560);
    }

    #[test]
    fn mlp_dominates_llama_layers() {
        // For Llama-family shapes the MLP block is the bigger half —
        // matters for the fine-grained offload ordering in Alg. 1.
        let spec = ModelSpec::llama33_70b();
        assert!(spec.p_mlp() > spec.p_attn());
    }

    #[test]
    fn by_name_lookup() {
        assert!(ModelSpec::by_name("Qwen3-32B").is_some());
        assert!(ModelSpec::by_name("tiny").is_some());
        assert!(ModelSpec::by_name("gpt-5").is_none());
    }

    #[test]
    fn tinylm_matches_python_config() {
        let t = ModelSpec::tiny_lm();
        assert_eq!(t.layers, 8);
        assert_eq!(t.hidden, 128);
        assert_eq!(t.kv_heads, 2);
        assert_eq!(t.head_dim(), 16);
    }

    #[test]
    fn flops_monotone_in_context() {
        let spec = ModelSpec::llama33_70b();
        assert!(spec.layer_decode_flops(2048) > spec.layer_decode_flops(1));
        assert!(spec.layer_prefill_flops(256) > spec.layer_prefill_flops(16));
    }

    #[test]
    fn kv_head_variants_scale_kv_bytes() {
        let base = ModelSpec::llama2_13b(); // MHA: 40 kv heads
        let mqa = base.clone().with_kv_heads(1);
        let gqa = base.clone().with_kv_heads(8);
        assert_eq!(mqa.kv_bytes_per_token_layer() * 40, base.kv_bytes_per_token_layer());
        assert_eq!(gqa.kv_bytes_per_token_layer() * 5, base.kv_bytes_per_token_layer());
        // Variant names stay distinct (scenario coords key off them).
        assert_ne!(mqa.name, base.name);
        assert_ne!(gqa.name, mqa.name);
        // Smaller Wk/Wv shrink the MHA block too.
        assert!(mqa.mha_bytes() < base.mha_bytes());
    }

    #[test]
    fn sliding_window_caps_context_derived_quantities() {
        let full = ModelSpec::qwen3_32b();
        let swa = full.clone().with_sliding_window(512);
        // Identity below the window...
        assert_eq!(swa.kv_ctx(100), 100);
        assert_eq!(
            swa.layer_decode_flops(100).to_bits(),
            full.layer_decode_flops(100).to_bits()
        );
        // ...hard cap above it.
        assert_eq!(swa.kv_ctx(4096), 512);
        assert_eq!(
            swa.layer_decode_flops(4096).to_bits(),
            full.layer_decode_flops(512).to_bits()
        );
        assert!(swa.layer_prefill_flops(2048) < full.layer_prefill_flops(2048));
        // Full-attention specs are untouched (None = identity, pinning the
        // pre-variant path bit-identical).
        assert_eq!(full.kv_ctx(1 << 20), 1 << 20);
    }
}
