//! The paper's six comparison methods plus LIME itself (§V-A), all running
//! over the same simulation substrate so "who wins, by what factor, where
//! crossovers fall" is an apples-to-apples comparison.
//!
//! | Method | Parallelism | Allocation | Memory-constrained behaviour |
//! |---|---|---|---|
//! | LIME | interleaved PP + offload | Alg. 1 DP + blocks | online planner + KV transfer |
//! | Pipeline parallelism | PP | memory-proportional | OOM (recompute for KV) |
//! | Pipeline + offloading | PP + offload | memory-proportional | naive per-use loads |
//! | EdgeShard | PP | latency-aware DP | OOM |
//! | Galaxy | TP + SP | even shards | OOM |
//! | TPI-LLM | TP | even shards | sliding-window streaming |
//! | TPI-LLM + offloading | TP | even shards | larger window for KV |

pub mod edgeshard;

use crate::adapt::Script;
use crate::cluster::Cluster;
use crate::model::ModelSpec;
use crate::net::BandwidthTrace;
use crate::pipeline::{
    run_interleaved, run_tensor_parallel, run_traditional, run_traditional_scripted, ExecOptions,
    PlannerMode, SimResult, TpOptions, TradOptions,
};
use crate::plan::allocation::{Allocation, DeviceAssignment};
use crate::plan::{plan, PlanOptions};
use crate::sim::TraceMode;
use crate::workload::Pattern;

/// Result of running a method: latency or an out-of-memory failure.
/// (OOT classification is applied downstream by the experiment harness.)
#[derive(Debug, Clone)]
pub enum Outcome {
    Ok(SimResult),
    Oom(String),
}

impl Outcome {
    pub fn ms_per_token(&self) -> Option<f64> {
        match self {
            Outcome::Ok(r) => Some(r.ms_per_token()),
            Outcome::Oom(_) => None,
        }
    }
}

/// The interleaved-executor configuration of a method that runs LIME's
/// online-adaptation machinery. The scenario matrix uses this to drive the
/// `#Seg`-override and memory-fluctuation axes, which only make sense for
/// methods that plan offline and adapt online.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct AdaptiveExec {
    pub kv_transfer: bool,
    pub planner: PlannerMode,
}

/// A comparison method. `Sync` so the experiment harness can fan a method
/// set out across the work-stealing pool's workers.
pub trait Method: Sync {
    fn name(&self) -> &'static str;

    /// Stable machine-readable identifier (the `by_name` key) — used by
    /// sweep JSON artifacts so notebooks never parse display names.
    fn key(&self) -> &'static str;

    /// `Some` when this method runs the interleaved executor with LIME's
    /// offline planner + online adaptation — the methods the scenario
    /// matrix expands along its `#Seg`-override and memory-fluctuation
    /// axes. Baselines return `None` and are measured only at the matrix's
    /// baseline (auto-seg, no-pressure) point.
    fn adaptive_exec(&self) -> Option<AdaptiveExec> {
        None
    }

    /// `true` when the scenario matrix should also expand this method
    /// along its device-churn axis: the method runs under a scripted
    /// churn timeline ([`Method::run_scripted`]) and degrades honestly
    /// when a device drops mid-run. LIME-family methods are already
    /// covered through `adaptive_exec`; among the baselines only
    /// EdgeShard opts in — its static PP schedule keeps executing
    /// against the zeroed device capacity, which is exactly the
    /// degradation the recovery-latency artifacts compare LIME against.
    fn churn_capable(&self) -> bool {
        false
    }

    /// Run under a fluctuation [`Script`] (churn channel included).
    /// Default: ignore the script and take the baseline measurement —
    /// only [`Method::churn_capable`] methods override this.
    fn run_scripted(
        &self,
        spec: &ModelSpec,
        cluster: &Cluster,
        bw: &BandwidthTrace,
        pattern: Pattern,
        tokens: usize,
        trace: TraceMode,
        script: &Script,
    ) -> Outcome {
        let _ = script;
        self.run_mode(spec, cluster, bw, pattern, tokens, trace)
    }

    /// Run with an explicit [`TraceMode`]. Experiment grids pass
    /// `TraceMode::Off` (they only read `SimResult` numbers); the CLI's
    /// `--trace` path and tests use `Full`.
    fn run_mode(
        &self,
        spec: &ModelSpec,
        cluster: &Cluster,
        bw: &BandwidthTrace,
        pattern: Pattern,
        tokens: usize,
        trace: TraceMode,
    ) -> Outcome;

    /// Full-trace convenience wrapper (historic behavior).
    fn run(
        &self,
        spec: &ModelSpec,
        cluster: &Cluster,
        bw: &BandwidthTrace,
        pattern: Pattern,
        tokens: usize,
    ) -> Outcome {
        self.run_mode(spec, cluster, bw, pattern, tokens, TraceMode::Full)
    }
}

/// All methods in the paper's comparison order.
pub fn all() -> Vec<Box<dyn Method>> {
    vec![
        Box::new(Lime::default()),
        Box::new(PipelineParallelism),
        Box::new(PipelineOffload),
        Box::new(EdgeShardMethod),
        Box::new(Galaxy),
        Box::new(TpiLlm),
        Box::new(TpiLlmOffload),
    ]
}

/// Lookup by CLI name.
pub fn by_name(name: &str) -> Option<Box<dyn Method>> {
    match name.to_ascii_lowercase().as_str() {
        "lime" => Some(Box::new(Lime::default())),
        "lime-no-kv-transfer" => Some(Box::new(Lime {
            kv_transfer: false,
            planner: PlannerMode::FineGrained,
        })),
        "lime-no-planner" => Some(Box::new(Lime {
            kv_transfer: true,
            planner: PlannerMode::FullLayer,
        })),
        "lime-no-planner-no-kv-transfer" => Some(Box::new(Lime {
            kv_transfer: false,
            planner: PlannerMode::FullLayer,
        })),
        "lime-planner-off" => Some(Box::new(Lime {
            kv_transfer: true,
            planner: PlannerMode::Off,
        })),
        "lime-planner-off-no-kv-transfer" => Some(Box::new(Lime {
            kv_transfer: false,
            planner: PlannerMode::Off,
        })),
        "pp" | "pipeline" => Some(Box::new(PipelineParallelism)),
        "pp-offload" | "pipeline-offload" => Some(Box::new(PipelineOffload)),
        "edgeshard" => Some(Box::new(EdgeShardMethod)),
        "galaxy" => Some(Box::new(Galaxy)),
        "tpi-llm" => Some(Box::new(TpiLlm)),
        "tpi-llm-offload" => Some(Box::new(TpiLlmOffload)),
        _ => None,
    }
}

/// The planning operating point every LIME-family run uses (§IV-C: the
/// actual sequence length is unknown at planning time, so LIME plans for a
/// fixed empirical n; runs longer than this rely on the online memory
/// adaptation — which is exactly what Table V ablates). Public so the
/// scenario matrix pre-plans with bit-identical options to
/// [`Lime::run_mode`].
pub fn plan_opts(
    bw: &BandwidthTrace,
    pattern: Pattern,
    cluster: &Cluster,
    tokens: usize,
) -> PlanOptions {
    PlanOptions {
        empirical_tokens: 128,
        micro_batch: pattern.micro_batches(cluster),
        bandwidth: bw.mean_over(tokens.max(1)),
    }
}

// ---------------------------------------------------------------- LIME

/// LIME — with ablation switches for Table V.
pub struct Lime {
    pub kv_transfer: bool,
    pub planner: PlannerMode,
}

impl Default for Lime {
    fn default() -> Self {
        Lime {
            kv_transfer: true,
            planner: PlannerMode::FineGrained,
        }
    }
}

impl Method for Lime {
    fn name(&self) -> &'static str {
        match (self.kv_transfer, self.planner) {
            (true, PlannerMode::FineGrained) => "LIME",
            (false, PlannerMode::FineGrained) => "LIME w/o KV transfer",
            (true, PlannerMode::FullLayer) => "LIME w/o memory-aware planner",
            (false, PlannerMode::FullLayer) => "LIME w/o planner or KV transfer",
            (true, PlannerMode::Off) => "LIME w/o online planning",
            (false, PlannerMode::Off) => "LIME w/o online planning or KV transfer",
        }
    }

    fn adaptive_exec(&self) -> Option<AdaptiveExec> {
        Some(AdaptiveExec {
            kv_transfer: self.kv_transfer,
            planner: self.planner,
        })
    }

    // Exhaustive over both ablation axes so every configuration gets a
    // distinct, by_name-round-trippable key (sweep JSON relies on this).
    fn key(&self) -> &'static str {
        match (self.kv_transfer, self.planner) {
            (true, PlannerMode::FineGrained) => "lime",
            (false, PlannerMode::FineGrained) => "lime-no-kv-transfer",
            (true, PlannerMode::FullLayer) => "lime-no-planner",
            (false, PlannerMode::FullLayer) => "lime-no-planner-no-kv-transfer",
            (true, PlannerMode::Off) => "lime-planner-off",
            (false, PlannerMode::Off) => "lime-planner-off-no-kv-transfer",
        }
    }

    fn run_mode(
        &self,
        spec: &ModelSpec,
        cluster: &Cluster,
        bw: &BandwidthTrace,
        pattern: Pattern,
        tokens: usize,
        trace: TraceMode,
    ) -> Outcome {
        let popts = plan_opts(bw, pattern, cluster, tokens);
        let report = match plan(spec, cluster, &popts) {
            Ok(r) => r,
            Err(e) => return Outcome::Oom(e.to_string()),
        };
        let exec = ExecOptions {
            planner: self.planner,
            kv_transfer: self.kv_transfer,
            trace_mode: trace,
            ..ExecOptions::default()
        };
        Outcome::Ok(run_interleaved(
            &report.allocation,
            cluster,
            bw,
            pattern.micro_batches(cluster),
            tokens,
            &exec,
        ))
    }
}

// -------------------------------------------------- PP (memory-proportional)

/// Allocate layers proportional to usable memory. Returns None (OOM) if the
/// model does not fit when `allow_offload` is false.
fn memory_proportional_alloc(
    spec: &ModelSpec,
    cluster: &Cluster,
    allow_offload: bool,
) -> Option<Allocation> {
    // Budget per device: usable memory minus its embedding/LM-head share
    // (the first and last pipeline devices host those).
    let budget = |i: usize| -> u64 {
        let embed = if i == 0 || i + 1 == cluster.len() {
            spec.embed_bytes() / 2
        } else {
            0
        };
        cluster.devices[i].usable_mem().saturating_sub(embed)
    };
    let total_mem: u64 = (0..cluster.len()).map(budget).sum();
    let caps: Vec<usize> = (0..cluster.len())
        .map(|i| (budget(i) / spec.layer_bytes()) as usize)
        .collect();
    let mut counts: Vec<usize> = (0..cluster.len())
        .map(|i| (spec.layers as f64 * budget(i) as f64 / total_mem as f64).floor() as usize)
        .collect();
    let mut assigned: usize = counts.iter().sum();
    // Distribute the rounding remainder by free capacity.
    while assigned < spec.layers {
        let i = (0..cluster.len())
            .max_by_key(|&i| budget(i).saturating_sub(counts[i] as u64 * spec.layer_bytes()))
            .unwrap();
        counts[i] += 1;
        assigned += 1;
    }
    let mut devices = Vec::new();
    for i in 0..cluster.len() {
        let total = counts[i];
        let overflow = total.saturating_sub(caps[i]);
        if overflow > 0 && !allow_offload {
            return None;
        }
        devices.push(DeviceAssignment {
            total_layers: total,
            full_offload: overflow,
            mha_offload: 0,
            mlp_offload: 0,
        });
    }
    Some(Allocation::new(spec.clone(), 1, devices))
}

/// Classic pipeline parallelism (GPipe-style memory-capacity allocation).
pub struct PipelineParallelism;

impl Method for PipelineParallelism {
    fn name(&self) -> &'static str {
        "Pipeline parallelism"
    }

    fn key(&self) -> &'static str {
        "pp"
    }

    fn run_mode(
        &self,
        spec: &ModelSpec,
        cluster: &Cluster,
        bw: &BandwidthTrace,
        pattern: Pattern,
        tokens: usize,
        trace: TraceMode,
    ) -> Outcome {
        let Some(alloc) = memory_proportional_alloc(spec, cluster, false) else {
            return Outcome::Oom("model slices exceed device memory".into());
        };
        // Plain PP must ALSO hold the KV cache; it still runs when weights
        // barely fit, paying recompute once KV overflows.
        Outcome::Ok(run_traditional(
            &alloc,
            cluster,
            bw,
            pattern.micro_batches(cluster),
            tokens,
            &TradOptions {
                trace_mode: trace,
                ..TradOptions::default()
            },
        ))
    }
}

/// Pipeline + offloading: same allocation policy, overflow layers stream
/// from SSD with the naive per-use schedule.
pub struct PipelineOffload;

impl Method for PipelineOffload {
    fn name(&self) -> &'static str {
        "Pipeline + offloading"
    }

    fn key(&self) -> &'static str {
        "pp-offload"
    }

    fn run_mode(
        &self,
        spec: &ModelSpec,
        cluster: &Cluster,
        bw: &BandwidthTrace,
        pattern: Pattern,
        tokens: usize,
        trace: TraceMode,
    ) -> Outcome {
        let Some(alloc) = memory_proportional_alloc(spec, cluster, true) else {
            return Outcome::Oom("unreachable: offload always fits".into());
        };
        Outcome::Ok(run_traditional(
            &alloc,
            cluster,
            bw,
            pattern.micro_batches(cluster),
            tokens,
            &TradOptions {
                recompute_fallback: false, // offload variant spills KV
                trace_mode: trace,
                ..TradOptions::default()
            },
        ))
    }
}

// ------------------------------------------------------------- EdgeShard

/// EdgeShard: latency-aware DP partitioning (no offload).
pub struct EdgeShardMethod;

impl Method for EdgeShardMethod {
    fn name(&self) -> &'static str {
        "EdgeShard"
    }

    fn key(&self) -> &'static str {
        "edgeshard"
    }

    fn churn_capable(&self) -> bool {
        true
    }

    fn run_scripted(
        &self,
        spec: &ModelSpec,
        cluster: &Cluster,
        bw: &BandwidthTrace,
        pattern: Pattern,
        tokens: usize,
        trace: TraceMode,
        script: &Script,
    ) -> Outcome {
        let micro = pattern.micro_batches(cluster);
        match edgeshard::partition(spec, cluster, bw.mean_over(tokens.max(1)), tokens.max(128), micro)
        {
            // The partition is static: a Down zeroes the device's capacity
            // and EdgeShard pays overflow/recompute until the Up restores
            // it — no re-planning, no KV migration. The executor core still
            // records the recovery latency, which is the comparison the
            // churn artifacts exist for.
            Some(alloc) => Outcome::Ok(run_traditional_scripted(
                &alloc,
                cluster,
                bw,
                micro,
                tokens,
                &TradOptions {
                    trace_mode: trace,
                    ..TradOptions::default()
                },
                script,
            )),
            None => Outcome::Oom("no memory-feasible partition".into()),
        }
    }

    fn run_mode(
        &self,
        spec: &ModelSpec,
        cluster: &Cluster,
        bw: &BandwidthTrace,
        pattern: Pattern,
        tokens: usize,
        trace: TraceMode,
    ) -> Outcome {
        self.run_scripted(spec, cluster, bw, pattern, tokens, trace, &Script::none())
    }
}

// ------------------------------------------------------------ TP family

fn tp_shard_fits(spec: &ModelSpec, cluster: &Cluster, tokens: usize, micro: usize) -> bool {
    // Galaxy shards by device capability, so the binding constraint is the
    // aggregate: weights + KV working set must fit in total usable memory.
    let total: u64 = cluster.devices.iter().map(|d| d.usable_mem()).sum();
    let kv = spec.kv_bytes_per_token_layer() * spec.layers as u64 * (tokens * micro) as u64;
    spec.total_bytes() + kv <= total
}

/// Galaxy: TP + sequence-parallel overlap, no offload.
pub struct Galaxy;

impl Method for Galaxy {
    fn name(&self) -> &'static str {
        "Galaxy"
    }

    fn key(&self) -> &'static str {
        "galaxy"
    }

    fn run_mode(
        &self,
        spec: &ModelSpec,
        cluster: &Cluster,
        bw: &BandwidthTrace,
        pattern: Pattern,
        tokens: usize,
        trace: TraceMode,
    ) -> Outcome {
        let micro = pattern.micro_batches(cluster);
        if !tp_shard_fits(spec, cluster, tokens.min(64), micro) {
            return Outcome::Oom("tensor shard exceeds device memory".into());
        }
        Outcome::Ok(run_tensor_parallel(
            spec,
            cluster,
            bw,
            micro,
            tokens,
            &TpOptions {
                comm_overlap: 0.3,
                trace_mode: trace,
                ..TpOptions::default()
            },
        ))
    }
}

/// TPI-LLM: TP with sliding-window weight streaming.
pub struct TpiLlm;

impl Method for TpiLlm {
    fn name(&self) -> &'static str {
        "TPI-LLM"
    }

    fn key(&self) -> &'static str {
        "tpi-llm"
    }

    fn run_mode(
        &self,
        spec: &ModelSpec,
        cluster: &Cluster,
        bw: &BandwidthTrace,
        pattern: Pattern,
        tokens: usize,
        trace: TraceMode,
    ) -> Outcome {
        Outcome::Ok(run_tensor_parallel(
            spec,
            cluster,
            bw,
            pattern.micro_batches(cluster),
            tokens,
            &TpOptions {
                sliding_window: true,
                trace_mode: trace,
                ..TpOptions::default()
            },
        ))
    }
}

/// TPI-LLM + offloading: larger sliding window instead of recomputation.
pub struct TpiLlmOffload;

impl Method for TpiLlmOffload {
    fn name(&self) -> &'static str {
        "TPI-LLM + offloading"
    }

    fn key(&self) -> &'static str {
        "tpi-llm-offload"
    }

    fn run_mode(
        &self,
        spec: &ModelSpec,
        cluster: &Cluster,
        bw: &BandwidthTrace,
        pattern: Pattern,
        tokens: usize,
        trace: TraceMode,
    ) -> Outcome {
        Outcome::Ok(run_tensor_parallel(
            spec,
            cluster,
            bw,
            pattern.micro_batches(cluster),
            tokens,
            &TpOptions {
                sliding_window: true,
                offload_kv: true,
                trace_mode: trace,
                ..TpOptions::default()
            },
        ))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::bytes::mbps;

    fn bw200() -> BandwidthTrace {
        BandwidthTrace::Fixed(mbps(200.0))
    }

    #[test]
    fn all_methods_listed_in_paper_order() {
        let names: Vec<&str> = all().iter().map(|m| m.name()).collect();
        assert_eq!(names.len(), 7);
        assert_eq!(names[0], "LIME");
        assert!(names.contains(&"EdgeShard"));
        assert!(names.contains(&"TPI-LLM + offloading"));
    }

    #[test]
    fn by_name_roundtrip() {
        for key in [
            "lime",
            "pp",
            "pp-offload",
            "edgeshard",
            "galaxy",
            "tpi-llm",
            "tpi-llm-offload",
            "lime-no-kv-transfer",
            "lime-no-planner",
        ] {
            let m = by_name(key).expect(key);
            // Method::key is the by_name key — the sweep-JSON contract.
            assert_eq!(m.key(), key, "key() must round-trip through by_name");
            assert!(by_name(m.key()).is_some());
        }
        assert!(by_name("vllm").is_none());
    }

    #[test]
    fn every_lime_configuration_has_a_distinct_roundtrip_key() {
        let mut seen = std::collections::BTreeSet::new();
        for kv_transfer in [true, false] {
            for planner in [
                PlannerMode::FineGrained,
                PlannerMode::FullLayer,
                PlannerMode::Off,
            ] {
                let lime = Lime {
                    kv_transfer,
                    planner,
                };
                let key = lime.key();
                assert!(seen.insert(key), "duplicate key {key}");
                let back = by_name(key).expect(key);
                assert_eq!(back.key(), key, "by_name({key}) must reconstruct it");
            }
        }
    }

    #[test]
    fn lime_beats_all_baselines_in_lowmem() {
        // The paper's headline: in memory-constrained settings LIME wins
        // against every baseline that still runs.
        let spec = ModelSpec::llama33_70b();
        let cluster = Cluster::lowmem_setting1();
        let lime = Lime::default()
            .run(&spec, &cluster, &bw200(), Pattern::Sporadic, 12)
            .ms_per_token()
            .expect("LIME must run");
        for m in all().into_iter().skip(1) {
            if let Some(ms) = m
                .run(&spec, &cluster, &bw200(), Pattern::Sporadic, 12)
                .ms_per_token()
            {
                assert!(
                    lime < ms,
                    "{}: LIME {lime:.1} !< {ms:.1}",
                    m.name()
                );
            }
        }
    }

    #[test]
    fn galaxy_ooms_when_shard_too_big() {
        // §V-C: "Galaxy fails to handle scenarios in which a device cannot
        // accommodate a model slice".
        let spec = ModelSpec::llama33_70b();
        let cluster = Cluster::lowmem_setting3();
        match Galaxy.run(&spec, &cluster, &bw200(), Pattern::Sporadic, 8) {
            Outcome::Oom(_) => {}
            Outcome::Ok(r) => panic!("expected OOM, got {:.1} ms/tok", r.ms_per_token()),
        }
    }

    #[test]
    fn plain_pp_ooms_in_lowmem3() {
        let spec = ModelSpec::llama33_70b();
        let cluster = Cluster::lowmem_setting3();
        match PipelineParallelism.run(&spec, &cluster, &bw200(), Pattern::Sporadic, 8) {
            Outcome::Oom(_) => {}
            Outcome::Ok(r) => panic!("expected OOM, got {:.1} ms/tok", r.ms_per_token()),
        }
    }

    #[test]
    fn pp_offload_survives_lowmem3() {
        let spec = ModelSpec::llama33_70b();
        let cluster = Cluster::lowmem_setting3();
        assert!(PipelineOffload
            .run(&spec, &cluster, &bw200(), Pattern::Sporadic, 8)
            .ms_per_token()
            .is_some());
    }

    #[test]
    fn tpi_llm_runs_but_slowly_in_lowmem() {
        let spec = ModelSpec::llama33_70b();
        let cluster = Cluster::lowmem_setting3();
        let tpi = TpiLlm
            .run(&spec, &cluster, &bw200(), Pattern::Sporadic, 8)
            .ms_per_token()
            .expect("sliding window must survive");
        let lime = Lime::default()
            .run(&spec, &cluster, &bw200(), Pattern::Sporadic, 8)
            .ms_per_token()
            .expect("LIME must survive");
        assert!(tpi > lime);
    }

    #[test]
    fn ablations_degrade_lime() {
        let spec = ModelSpec::llama33_70b();
        let cluster = Cluster::lowmem_setting1();
        let tokens = 160;
        let full = Lime::default()
            .run(&spec, &cluster, &bw200(), Pattern::Sporadic, tokens)
            .ms_per_token()
            .unwrap();
        let no_planner = by_name("lime-no-planner")
            .unwrap()
            .run(&spec, &cluster, &bw200(), Pattern::Sporadic, tokens)
            .ms_per_token()
            .unwrap();
        assert!(
            full <= no_planner * 1.02,
            "full {full:.1} vs no-planner {no_planner:.1}"
        );
    }
}
