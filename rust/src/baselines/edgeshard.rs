//! EdgeShard-style layer partitioning: dynamic programming over contiguous
//! layer splits that minimizes the pipeline bottleneck stage time
//! (compute + activation hop), subject to each device's memory capacity —
//! faithful to EdgeShard's formulation (heterogeneous compute + network
//! aware, no offloading).

use crate::cluster::Cluster;
use crate::cost;
use crate::model::ModelSpec;
use crate::net::link_transfer_secs;
use crate::plan::allocation::{Allocation, DeviceAssignment};

/// DP partition of `spec.layers` contiguous layers over the pipeline.
/// Returns `None` when no memory-feasible split exists (OOM).
pub fn partition(
    spec: &ModelSpec,
    cluster: &Cluster,
    bw: f64,
    tokens: usize,
    micro: usize,
) -> Option<Allocation> {
    let d = cluster.len();
    let l = spec.layers;
    // Memory cap per device: weights + KV for the run must fit.
    let kv_per_layer = spec.kv_bytes_per_token_layer() * (tokens * micro) as u64;
    let caps: Vec<usize> = (0..d)
        .map(|i| {
            let embed = if i == 0 || i + 1 == d {
                spec.embed_bytes() / 2
            } else {
                0
            };
            let budget = cluster.devices[i].usable_mem().saturating_sub(embed);
            (budget / (spec.layer_bytes() + kv_per_layer)) as usize
        })
        .collect();

    let hop = link_transfer_secs(spec.h_size(micro), bw);
    // stage_time[i][k]: bottleneck contribution of assigning k layers to i.
    let stage = |i: usize, k: usize| -> f64 {
        cost::comp_time(spec, &cluster.devices[i], k, tokens, micro) + hop
    };

    const INF: f64 = f64::INFINITY;
    // dp[i][l]: minimal bottleneck using first i devices for first l layers.
    let mut dp = vec![vec![INF; l + 1]; d + 1];
    let mut choice = vec![vec![0usize; l + 1]; d + 1];
    dp[0][0] = 0.0;
    for i in 1..=d {
        for lay in 0..=l {
            for k in 0..=lay.min(caps[i - 1]) {
                let prev = dp[i - 1][lay - k];
                if !prev.is_finite() {
                    continue;
                }
                let cand = prev.max(if k > 0 { stage(i - 1, k) } else { 0.0 });
                if cand < dp[i][lay] {
                    dp[i][lay] = cand;
                    choice[i][lay] = k;
                }
            }
        }
    }
    if !dp[d][l].is_finite() {
        return None;
    }
    let mut counts = vec![0usize; d];
    let mut lay = l;
    for i in (1..=d).rev() {
        counts[i - 1] = choice[i][lay];
        lay -= counts[i - 1];
    }
    Some(Allocation::new(
        spec.clone(),
        1,
        counts.into_iter().map(DeviceAssignment::resident).collect(),
    ))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::bytes::mbps;

    #[test]
    fn partitions_respect_memory_caps() {
        let spec = ModelSpec::llama2_13b();
        let cluster = Cluster::env_e1();
        let alloc = partition(&spec, &cluster, mbps(200.0), 128, 1).unwrap();
        assert!(alloc.covers_model());
        assert!(cost::feasible(&alloc, &cluster, 128).is_ok());
    }

    #[test]
    fn favors_fast_devices() {
        let spec = ModelSpec::llama2_13b();
        let cluster = Cluster::env_e1(); // [Orin32 (fast), NX16 (slow)]
        let alloc = partition(&spec, &cluster, mbps(200.0), 128, 1).unwrap();
        assert!(
            alloc.devices[0].total_layers > alloc.devices[1].total_layers,
            "{}",
            alloc.describe()
        );
    }

    #[test]
    fn oom_when_model_cannot_fit() {
        let spec = ModelSpec::llama33_70b();
        let cluster = Cluster::lowmem_setting3();
        assert!(partition(&spec, &cluster, mbps(200.0), 128, 1).is_none());
    }

    #[test]
    fn beats_memory_proportional_on_bottleneck() {
        // EdgeShard's reason to exist: latency-aware splits beat
        // memory-proportional splits on heterogeneous clusters.
        let spec = ModelSpec::qwen3_32b();
        let cluster = Cluster::env_e2();
        let es = partition(&spec, &cluster, mbps(200.0), 128, 1).unwrap();
        let bottleneck = |a: &Allocation| -> f64 {
            (0..cluster.len())
                .map(|i| {
                    cost::comp_time(&spec, &cluster.devices[i], a.devices[i].total_layers, 128, 1)
                })
                .fold(0.0, f64::max)
        };
        // Memory-proportional strawman.
        let total_mem: u64 = cluster.devices.iter().map(|d| d.usable_mem()).sum();
        let counts: Vec<usize> = cluster
            .devices
            .iter()
            .map(|d| (spec.layers as f64 * d.usable_mem() as f64 / total_mem as f64).round() as usize)
            .collect();
        let drift = spec.layers as i64 - counts.iter().sum::<usize>() as i64;
        let mut counts = counts;
        counts[0] = (counts[0] as i64 + drift) as usize;
        let memprop = Allocation::new(
            spec.clone(),
            1,
            counts.into_iter().map(DeviceAssignment::resident).collect(),
        );
        assert!(bottleneck(&es) <= bottleneck(&memprop) + 1e-9);
    }
}
