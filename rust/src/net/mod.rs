//! Network substrate: bandwidth traces (the stand-in for the paper's Linux
//! `tc` shaping) and link transfer-time math.
//!
//! The paper's experiments use fixed 100/200 Mbps regimes plus a "varying"
//! regime that re-draws a bandwidth uniformly in [50, 250] Mbps after a
//! random number of generated tokens (§V-D). All three are expressible as a
//! [`BandwidthTrace`].

use crate::util::bytes::mbps;
use crate::util::rng::Rng;

/// Bandwidth over (token-)time. Queried by the simulator before every
/// auto-regressive step — exactly where Alg. 2 monitors `bw_net`.
#[derive(Debug, Clone)]
pub enum BandwidthTrace {
    /// Constant bandwidth (bytes/s).
    Fixed(f64),
    /// Piecewise-constant: (start_token, bytes/s) breakpoints, sorted.
    Piecewise(Vec<(usize, f64)>),
}

impl BandwidthTrace {
    /// Fixed bandwidth given in Mbps (paper's unit).
    pub fn fixed_mbps(v: f64) -> Self {
        BandwidthTrace::Fixed(mbps(v))
    }

    /// §V-D regime: re-draw uniformly in [lo, hi] Mbps after a random
    /// token count in [min_run, max_run]; generated ahead for `horizon`
    /// tokens so runs are reproducible by seed.
    pub fn random_walk_mbps(
        seed: u64,
        lo: f64,
        hi: f64,
        min_run: usize,
        max_run: usize,
        horizon: usize,
    ) -> Self {
        assert!(lo > 0.0 && hi >= lo && min_run >= 1 && max_run >= min_run);
        let mut rng = Rng::new(seed);
        let mut pieces = Vec::new();
        let mut tok = 0usize;
        while tok < horizon {
            pieces.push((tok, mbps(rng.range_f64(lo, hi))));
            tok += rng.range(min_run, max_run + 1);
        }
        BandwidthTrace::Piecewise(pieces)
    }

    /// Bandwidth (bytes/s) in effect at generated-token index `token`.
    pub fn at(&self, token: usize) -> f64 {
        match self {
            BandwidthTrace::Fixed(b) => *b,
            BandwidthTrace::Piecewise(pieces) => {
                let mut cur = pieces
                    .first()
                    .expect("piecewise trace must be non-empty")
                    .1;
                for &(start, b) in pieces {
                    if start <= token {
                        cur = b;
                    } else {
                        break;
                    }
                }
                cur
            }
        }
    }

    /// Mean bandwidth over the first `horizon` tokens.
    pub fn mean_over(&self, horizon: usize) -> f64 {
        (0..horizon.max(1)).map(|t| self.at(t)).sum::<f64>() / horizon.max(1) as f64
    }

    /// Overlay multiplicative capacity-scale events onto this trace: the
    /// result's bandwidth at token `t` is exactly `self.at(t) × s(t)`,
    /// where `s(t)` is the scale of the latest event with `at_step <= t`
    /// (1.0 before any event). This is how scripted bandwidth
    /// fluctuation ([`crate::adapt::BwEvent`]) composes with a sweep's
    /// base bandwidth axis — a sag script scales *whatever* the base
    /// trace provides, fixed or piecewise.
    ///
    /// With no events the trace is returned unchanged (clone), so an
    /// empty script stays bit-identical to the unscripted run.
    pub fn overlay_scales(&self, events: &[(usize, f64)]) -> BandwidthTrace {
        if events.is_empty() {
            return self.clone();
        }
        for &(_, scale) in events {
            assert!(
                scale.is_finite() && scale > 0.0,
                "bandwidth scale must be finite and > 0, got {scale}"
            );
        }
        let mut sorted: Vec<(usize, f64)> = events.to_vec();
        // Stable sort: the later entry of a same-step pair wins below.
        sorted.sort_by_key(|&(step, _)| step);
        let scale_at = |t: usize| -> f64 {
            let mut s = 1.0;
            for &(step, scale) in &sorted {
                if step <= t {
                    s = scale;
                } else {
                    break;
                }
            }
            s
        };
        // Breakpoints: token 0 plus every change point of either input.
        let mut starts: Vec<usize> = vec![0];
        if let BandwidthTrace::Piecewise(pieces) = self {
            starts.extend(pieces.iter().map(|&(start, _)| start));
        }
        starts.extend(sorted.iter().map(|&(step, _)| step));
        starts.sort_unstable();
        starts.dedup();
        BandwidthTrace::Piecewise(
            starts
                .into_iter()
                .map(|start| (start, self.at(start) * scale_at(start)))
                .collect(),
        )
    }
}

/// Seconds to move `bytes` across a link at `bytes_per_sec`, including a
/// fixed per-message latency floor (switch + stack traversal).
pub fn link_transfer_secs(bytes: u64, bytes_per_sec: f64) -> f64 {
    const PER_MESSAGE_LATENCY: f64 = 300e-6; // LAN RTT-ish floor
    PER_MESSAGE_LATENCY + bytes as f64 / bytes_per_sec
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fixed_trace_constant() {
        let t = BandwidthTrace::fixed_mbps(200.0);
        assert_eq!(t.at(0), t.at(10_000));
        assert!((t.at(0) - 25e6).abs() < 1.0);
    }

    #[test]
    fn piecewise_steps() {
        let t = BandwidthTrace::Piecewise(vec![(0, 10.0), (5, 20.0), (9, 5.0)]);
        assert_eq!(t.at(0), 10.0);
        assert_eq!(t.at(4), 10.0);
        assert_eq!(t.at(5), 20.0);
        assert_eq!(t.at(8), 20.0);
        assert_eq!(t.at(100), 5.0);
    }

    #[test]
    fn random_walk_in_range_and_deterministic() {
        let a = BandwidthTrace::random_walk_mbps(7, 50.0, 250.0, 3, 30, 500);
        let b = BandwidthTrace::random_walk_mbps(7, 50.0, 250.0, 3, 30, 500);
        for tok in 0..500 {
            let bw = a.at(tok);
            assert!((mbps(50.0)..=mbps(250.0)).contains(&bw));
            assert_eq!(bw, b.at(tok));
        }
    }

    #[test]
    fn random_walk_actually_varies() {
        let t = BandwidthTrace::random_walk_mbps(3, 50.0, 250.0, 3, 30, 500);
        let first = t.at(0);
        assert!((0..500).any(|tok| t.at(tok) != first));
    }

    #[test]
    fn overlay_on_fixed_is_exact() {
        let base = BandwidthTrace::fixed_mbps(200.0);
        let t = base.overlay_scales(&[(4, 0.5), (9, 1.0)]);
        for tok in 0..16 {
            let scale = if (4..9).contains(&tok) { 0.5 } else { 1.0 };
            assert_eq!(t.at(tok), base.at(tok) * scale, "token {tok}");
        }
    }

    #[test]
    fn overlay_on_piecewise_unions_breakpoints() {
        let base = BandwidthTrace::Piecewise(vec![(0, 10.0), (5, 20.0)]);
        let t = base.overlay_scales(&[(3, 0.5), (7, 1.0)]);
        assert_eq!(t.at(0), 10.0);
        assert_eq!(t.at(3), 5.0); // sag on the first piece
        assert_eq!(t.at(5), 10.0); // sag persists across the base breakpoint
        assert_eq!(t.at(7), 20.0); // restored on the second piece
    }

    #[test]
    fn overlay_with_no_events_is_identity() {
        let base = BandwidthTrace::random_walk_mbps(5, 50.0, 250.0, 3, 30, 100);
        let t = base.overlay_scales(&[]);
        for tok in 0..100 {
            assert_eq!(t.at(tok), base.at(tok));
        }
    }

    #[test]
    fn overlay_same_step_latest_event_wins() {
        let base = BandwidthTrace::Fixed(100.0);
        let t = base.overlay_scales(&[(2, 0.5), (2, 0.25)]);
        assert_eq!(t.at(2), 25.0);
    }

    #[test]
    #[should_panic]
    fn overlay_rejects_nonpositive_scale() {
        BandwidthTrace::Fixed(1.0).overlay_scales(&[(0, -1.0)]);
    }

    #[test]
    fn transfer_has_latency_floor() {
        let t = link_transfer_secs(0, mbps(100.0));
        assert!(t > 0.0 && t < 1e-3);
        // 12.5 MB at 100 Mbps = 1 s.
        let big = link_transfer_secs(12_500_000, mbps(100.0));
        assert!((big - 1.0).abs() < 1e-2);
    }
}
