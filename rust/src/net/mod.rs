//! Network substrate: bandwidth traces (the stand-in for the paper's Linux
//! `tc` shaping) and link transfer-time math.
//!
//! The paper's experiments use fixed 100/200 Mbps regimes plus a "varying"
//! regime that re-draws a bandwidth uniformly in [50, 250] Mbps after a
//! random number of generated tokens (§V-D). All three are expressible as a
//! [`BandwidthTrace`].

use crate::util::bytes::mbps;
use crate::util::rng::Rng;

/// Bandwidth over (token-)time. Queried by the simulator before every
/// auto-regressive step — exactly where Alg. 2 monitors `bw_net`.
#[derive(Debug, Clone)]
pub enum BandwidthTrace {
    /// Constant bandwidth (bytes/s).
    Fixed(f64),
    /// Piecewise-constant: (start_token, bytes/s) breakpoints, sorted.
    Piecewise(Vec<(usize, f64)>),
}

impl BandwidthTrace {
    /// Fixed bandwidth given in Mbps (paper's unit).
    pub fn fixed_mbps(v: f64) -> Self {
        BandwidthTrace::Fixed(mbps(v))
    }

    /// §V-D regime: re-draw uniformly in [lo, hi] Mbps after a random
    /// token count in [min_run, max_run]; generated ahead for `horizon`
    /// tokens so runs are reproducible by seed.
    pub fn random_walk_mbps(
        seed: u64,
        lo: f64,
        hi: f64,
        min_run: usize,
        max_run: usize,
        horizon: usize,
    ) -> Self {
        assert!(lo > 0.0 && hi >= lo && min_run >= 1 && max_run >= min_run);
        let mut rng = Rng::new(seed);
        let mut pieces = Vec::new();
        let mut tok = 0usize;
        while tok < horizon {
            pieces.push((tok, mbps(rng.range_f64(lo, hi))));
            tok += rng.range(min_run, max_run + 1);
        }
        BandwidthTrace::Piecewise(pieces)
    }

    /// Bandwidth (bytes/s) in effect at generated-token index `token`.
    pub fn at(&self, token: usize) -> f64 {
        match self {
            BandwidthTrace::Fixed(b) => *b,
            BandwidthTrace::Piecewise(pieces) => {
                let mut cur = pieces
                    .first()
                    .expect("piecewise trace must be non-empty")
                    .1;
                for &(start, b) in pieces {
                    if start <= token {
                        cur = b;
                    } else {
                        break;
                    }
                }
                cur
            }
        }
    }

    /// Mean bandwidth over the first `horizon` tokens.
    pub fn mean_over(&self, horizon: usize) -> f64 {
        (0..horizon.max(1)).map(|t| self.at(t)).sum::<f64>() / horizon.max(1) as f64
    }
}

/// Seconds to move `bytes` across a link at `bytes_per_sec`, including a
/// fixed per-message latency floor (switch + stack traversal).
pub fn link_transfer_secs(bytes: u64, bytes_per_sec: f64) -> f64 {
    const PER_MESSAGE_LATENCY: f64 = 300e-6; // LAN RTT-ish floor
    PER_MESSAGE_LATENCY + bytes as f64 / bytes_per_sec
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fixed_trace_constant() {
        let t = BandwidthTrace::fixed_mbps(200.0);
        assert_eq!(t.at(0), t.at(10_000));
        assert!((t.at(0) - 25e6).abs() < 1.0);
    }

    #[test]
    fn piecewise_steps() {
        let t = BandwidthTrace::Piecewise(vec![(0, 10.0), (5, 20.0), (9, 5.0)]);
        assert_eq!(t.at(0), 10.0);
        assert_eq!(t.at(4), 10.0);
        assert_eq!(t.at(5), 20.0);
        assert_eq!(t.at(8), 20.0);
        assert_eq!(t.at(100), 5.0);
    }

    #[test]
    fn random_walk_in_range_and_deterministic() {
        let a = BandwidthTrace::random_walk_mbps(7, 50.0, 250.0, 3, 30, 500);
        let b = BandwidthTrace::random_walk_mbps(7, 50.0, 250.0, 3, 30, 500);
        for tok in 0..500 {
            let bw = a.at(tok);
            assert!((mbps(50.0)..=mbps(250.0)).contains(&bw));
            assert_eq!(bw, b.at(tok));
        }
    }

    #[test]
    fn random_walk_actually_varies() {
        let t = BandwidthTrace::random_walk_mbps(3, 50.0, 250.0, 3, 30, 500);
        let first = t.at(0);
        assert!((0..500).any(|tok| t.at(tok) != first));
    }

    #[test]
    fn transfer_has_latency_floor() {
        let t = link_transfer_secs(0, mbps(100.0));
        assert!(t > 0.0 && t < 1e-3);
        // 12.5 MB at 100 Mbps = 1 s.
        let big = link_transfer_secs(12_500_000, mbps(100.0));
        assert!((big - 1.0).abs() < 1e-2);
    }
}
