//! The unified executor core — one event-driven step driver shared by all
//! three schedule policies (interleaved, traditional, tensor-parallel).
//!
//! Before this module existed, the three executors hand-rolled nearly
//! identical step loops: each one built its own trace, GPU/SSD/link
//! resources, counted `bw_stalls` and emergency steps, applied scripted
//! fluctuation events, and assembled the final [`SimResult`]. The core
//! now owns those shared mechanics; a [`SchedulePolicy`] owns only its
//! schedule-specific decisions (micro-batch fronts and cross-segment
//! offload overlap for the interleaved schedule, per-use loads for the
//! traditional schedule, collective rounds for tensor parallelism).
//!
//! The split:
//!
//! * [`CoreState`] — trace lanes, per-device GPU [`Resource`]s and
//!   [`SsdModel`]s, the shared LAN link with stall accounting
//!   ([`CoreState::link_acquire`]), and the scripted effective-memory caps
//!   ([`CoreState::mem_caps`]) every policy judges saturation against.
//! * [`SchedulePolicy`] — `begin_request` (reset per-request state and
//!   charge the prefill pass), `step` (one decode step), `on_mem_event`
//!   (shift policy-internal thresholds when the core applies a scripted
//!   memory event), and the §IV-D counters for result assembly.
//! * [`ExecutorCore`] — the driver. It fires scripted [`MemEvent`]s /
//!   `BwEvent`s on the **stream timeline** (global step counter), runs
//!   policy steps, counts emergency steps (at most once per step), and
//!   accumulates step latencies. [`ExecutorCore::run_request`] runs one
//!   request *without resetting the timeline*, which is what lets
//!   `serve::simqueue` simulate continuous request serving: back-to-back
//!   requests share the same resources, SSD jitter streams, bandwidth
//!   trace, and fluctuation script.
//!
//! The legacy single-request entry points (`run_interleaved`,
//! `run_traditional`, `run_tensor_parallel`) are thin wrappers over
//! [`run_single`] — a one-request stream starting at t = 0 — and are
//! property-tested bit-identical to the pre-refactor executors
//! (`rust/tests/serving_stream.rs`).

use crate::adapt::{MemEvent, Script};
use crate::cluster::Cluster;
use crate::net::BandwidthTrace;
use crate::pipeline::result::SimResult;
use crate::sim::{Interval, Resource, SsdModel, Trace, TraceMode};

/// The options every schedule policy shares, consumed by the core.
/// `ExecOptions`/`TradOptions`/`TpOptions` each carry these three fields
/// (with schedule-specific defaults) plus their policy-specific knobs, and
/// convert via `From<&…Options>`.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct CommonOptions {
    /// Prompt length charged as a prefill pass before decoding.
    pub prompt_tokens: usize,
    /// RNG seed for the SSD write-jitter streams.
    pub seed: u64,
    /// Span recording detail (never affects `SimResult` timing fields).
    pub trace_mode: TraceMode,
}

/// Per-step context handed to [`SchedulePolicy::step`].
#[derive(Debug, Clone, Copy)]
pub struct StepCtx {
    /// Step index on the stream timeline — indexes the bandwidth trace and
    /// the fluctuation script. Equals `local_step` for single-request runs.
    pub global_step: usize,
    /// Step index within the current request — the KV context grows with
    /// this one.
    pub local_step: usize,
    /// Absolute time the step begins (= previous step's end).
    pub step_start: f64,
    /// Micro-batches in flight for the current request.
    pub micro: usize,
}

/// Shared simulation state owned by the core: the mechanics that used to
/// be copy-pasted across the three executors.
pub struct CoreState {
    /// Span lanes (Gantt rendering + overlap accounting).
    pub trace: Trace,
    /// One exclusive compute server per device.
    pub gpus: Vec<Resource>,
    /// One SSD channel per device (deterministic reads, jittery writes).
    pub ssds: Vec<SsdModel>,
    /// The edge LAN is a shared medium: one exclusive link resource.
    net: Resource,
    /// Link capacity over steps; scripted `BwEvent`s are overlaid up
    /// front so every consumer sees the scaled capacity through one query.
    bw: BandwidthTrace,
    bw_stalls: u64,
    emergency_this_step: bool,
    /// Effective usable memory per device; scripted pressure events shift
    /// these away from the `DeviceSpec` capacities mid-run. Cumulative
    /// signed pressure is tracked against the unpressured base (mirroring
    /// `OnlinePlanner::apply_pressure`) so a dip that bottoms a device out
    /// restores exactly.
    mem_base: Vec<u64>,
    mem_pressure: Vec<i64>,
    /// Current effective per-device caps every policy judges saturation
    /// against (`== usable_mem()` while no script event has fired).
    pub mem_caps: Vec<u64>,
}

impl CoreState {
    fn new(cluster: &Cluster, bw: BandwidthTrace, common: &CommonOptions) -> Self {
        let d = cluster.len();
        let mem_base: Vec<u64> = (0..d).map(|i| cluster.devices[i].usable_mem()).collect();
        CoreState {
            trace: Trace::with_mode(common.trace_mode),
            gpus: (0..d).map(|_| Resource::new()).collect(),
            ssds: (0..d)
                .map(|i| {
                    SsdModel::new(
                        cluster.devices[i].ssd_read_bps,
                        cluster.devices[i].ssd_write_bps,
                        common.seed ^ (i as u64) << 8,
                    )
                })
                .collect(),
            net: Resource::new(),
            bw,
            bw_stalls: 0,
            emergency_this_step: false,
            mem_pressure: vec![0; d],
            mem_caps: mem_base.clone(),
            mem_base,
        }
    }

    /// Link capacity at a stream step (scripted scales already applied).
    pub fn bw_at(&self, global_step: usize) -> f64 {
        self.bw.at(global_step)
    }

    /// Acquire the shared link for `dur` seconds starting no earlier than
    /// `at`, counting a bandwidth stall when the medium was busy. The
    /// counter is purely observational — it never feeds back into timing.
    pub fn link_acquire(&mut self, at: f64, dur: f64) -> Interval {
        let iv = self.net.acquire(at, dur);
        if iv.start > at {
            self.bw_stalls += 1;
        }
        iv
    }

    /// Mark the current step as needing the emergency KV-spill fallback.
    /// The core counts each step at most once, however many devices
    /// overflow within it.
    pub fn mark_emergency(&mut self) {
        self.emergency_this_step = true;
    }

    /// Cumulative scripted pressure on device `i` (negative = memory taken
    /// away). Policies that rebuild per-request state re-apply this to
    /// their fresh planners so mid-stream resets keep the shifted slack.
    pub fn mem_pressure(&self, i: usize) -> i64 {
        self.mem_pressure[i]
    }

    /// Link acquisitions that had to wait on the busy shared medium.
    pub fn bw_stalls(&self) -> u64 {
        self.bw_stalls
    }

    fn apply_mem_event(&mut self, ev: &MemEvent) {
        self.mem_pressure[ev.device] = self.mem_pressure[ev.device].saturating_add(ev.delta_bytes);
        self.mem_caps[ev.device] =
            crate::adapt::planner::shifted(self.mem_base[ev.device], self.mem_pressure[ev.device]);
    }

    fn take_emergency(&mut self) -> bool {
        std::mem::replace(&mut self.emergency_this_step, false)
    }
}

/// A pipeline schedule: the policy-specific half of an executor. The core
/// drives implementations through `begin_request` → `step`*, firing
/// `on_mem_event` whenever a scripted memory event lands on the stream
/// timeline (the core has already shifted [`CoreState::mem_caps`]).
pub trait SchedulePolicy {
    /// Reset per-request state and charge the prefill pass for a request
    /// with `micro` micro-batches whose service begins at absolute time
    /// `at` (stream step `global_step`). Returns the decode-start time.
    fn begin_request(
        &mut self,
        core: &mut CoreState,
        at: f64,
        micro: usize,
        global_step: usize,
    ) -> f64;

    /// Simulate one decode step; returns the absolute step-end time.
    fn step(&mut self, core: &mut CoreState, ctx: &StepCtx) -> f64;

    /// A scripted memory event fired; shift any policy-internal thresholds
    /// (the effective cap shift has already been applied by the core).
    fn on_mem_event(&mut self, _ev: &MemEvent) {}

    /// KV tokens shipped between devices so far (stream total).
    fn kv_tokens_transferred(&self) -> u64 {
        0
    }

    /// Online offload plans fired so far (stream total).
    fn online_plans_fired(&self) -> usize {
        0
    }
}

/// Timing of one request run on the core's shared timeline.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct RequestRun {
    /// When service (the prefill pass) began.
    pub start: f64,
    /// When decoding began (prefill charged between `start` and here).
    pub decode_start: f64,
    /// Absolute completion time of each decode step.
    pub step_ends: Vec<f64>,
    /// Micro-batches the request ran with (= admitted batch size).
    pub micro: usize,
}

impl RequestRun {
    /// When the run's last token completed (= `decode_start` for empty
    /// runs).
    pub fn finish(&self) -> f64 {
        self.step_ends.last().copied().unwrap_or(self.decode_start)
    }
}

/// Reusable per-request scratch for long streams: holds the [`RequestRun`]
/// buffers that [`ExecutorCore::run_request`] would otherwise allocate per
/// call, so a 10^6-request stream touches the allocator O(1) times on the
/// core side. [`ExecutorCore::run_request_in`] resets it instead of
/// reallocating; the filled run is borrowed back until the next call.
/// (The policy-side analogue is `InterleavedPolicy`'s in-place request
/// reset — together they are the perf lever's "arena".)
#[derive(Debug, Clone, Default)]
pub struct CoreArena {
    run: RequestRun,
}

impl CoreArena {
    pub fn new() -> Self {
        CoreArena::default()
    }
}

/// Everything a finished core hands back: the trace plus the stream-level
/// accumulators the per-policy counters join for result assembly.
pub struct CoreTotals {
    pub trace: Trace,
    /// Per-step latencies — empty when the core ran with
    /// [`ExecutorCore::retain_step_times`] off (memory-flat streams).
    pub step_times: Vec<f64>,
    /// Running sum of every step latency, accumulated left-to-right in
    /// push order — bit-identical to `step_times.iter().sum()` whenever
    /// the vector is retained, and the only decode-time record when not.
    pub step_time_sum: f64,
    pub emergency_steps: usize,
    pub bw_stalls: u64,
    pub kv_tokens_transferred: u64,
    pub online_plans_fired: usize,
}

/// The unified step driver: owns the [`CoreState`] and the stream-global
/// step counter, runs requests back-to-back on one shared timeline.
pub struct ExecutorCore<'s, P: SchedulePolicy> {
    pub policy: P,
    pub state: CoreState,
    script: &'s Script,
    global_step: usize,
    emergency_steps: usize,
    step_times: Vec<f64>,
    step_time_sum: f64,
    retain_step_times: bool,
}

impl<'s, P: SchedulePolicy> ExecutorCore<'s, P> {
    /// Build a core over `cluster`. Scripted bandwidth events overlay the
    /// base trace up front — every consumer (prefill, hops, KV shipping,
    /// the Alg. 2 monitor) then sees the scaled capacity through one
    /// unchanged query path.
    pub fn new(
        policy: P,
        cluster: &Cluster,
        bw_trace: &BandwidthTrace,
        common: &CommonOptions,
        script: &'s Script,
    ) -> Self {
        // Owning the trace (one clone per *run*, an f64 for the Fixed
        // traces every sweep uses) keeps CoreState lifetime-free; the
        // overlay path materializes a scaled copy exactly as before.
        let bw = if script.bw.is_empty() {
            bw_trace.clone()
        } else {
            bw_trace.overlay_scales(&script.bw_scale_points())
        };
        ExecutorCore {
            policy,
            state: CoreState::new(cluster, bw, common),
            script,
            global_step: 0,
            emergency_steps: 0,
            step_times: Vec::new(),
            step_time_sum: 0.0,
            retain_step_times: true,
        }
    }

    /// Next step index on the stream timeline.
    pub fn global_step(&self) -> usize {
        self.global_step
    }

    /// Keep (default) or drop the per-step latency vector. Million-request
    /// fleet streams turn retention off so the core holds no per-request
    /// state; the left-to-right [`CoreTotals::step_time_sum`] still records
    /// total decode time bit-identically to summing the retained vector.
    pub fn retain_step_times(&mut self, retain: bool) {
        self.retain_step_times = retain;
    }

    /// Run one request (prefill + `tokens` decode steps, `micro_batches`
    /// micro-batches) starting no earlier than `at`, on the shared
    /// timeline: resources, SSD jitter streams, the global step counter
    /// and the fluctuation script all carry over from previous requests.
    pub fn run_request(&mut self, at: f64, micro_batches: usize, tokens: usize) -> RequestRun {
        let mut run = RequestRun {
            step_ends: Vec::with_capacity(tokens),
            ..RequestRun::default()
        };
        self.run_request_into(at, micro_batches, tokens, &mut run);
        run
    }

    /// [`ExecutorCore::run_request`] recycling `arena`'s buffers — the
    /// stream-serving entry point: no allocation once the step buffer has
    /// grown to the stream's widest request.
    pub fn run_request_in<'a>(
        &mut self,
        at: f64,
        micro_batches: usize,
        tokens: usize,
        arena: &'a mut CoreArena,
    ) -> &'a RequestRun {
        // Split-borrow: take the run out so `self` stays free for the loop.
        let mut run = std::mem::take(&mut arena.run);
        self.run_request_into(at, micro_batches, tokens, &mut run);
        arena.run = run;
        &arena.run
    }

    fn run_request_into(
        &mut self,
        at: f64,
        micro_batches: usize,
        tokens: usize,
        run: &mut RequestRun,
    ) {
        let micro = micro_batches.max(1);
        let decode_start = self
            .policy
            .begin_request(&mut self.state, at, micro, self.global_step);
        let mut t_prev = decode_start;
        let step_ends = &mut run.step_ends;
        step_ends.clear();
        step_ends.reserve(tokens);
        for local in 0..tokens {
            let g = self.global_step;
            // Scripted memory fluctuation, fired on the STREAM timeline —
            // applied before the policy's step so a lowered threshold
            // already counts as "imminent" for this step's Alg. 2
            // decisions.
            let script = self.script;
            for ev in script.mem.iter().filter(|ev| ev.at_step == g) {
                self.state.apply_mem_event(ev);
                self.policy.on_mem_event(ev);
            }
            let step_start = t_prev;
            let step_end = self.policy.step(
                &mut self.state,
                &StepCtx {
                    global_step: g,
                    local_step: local,
                    step_start,
                    micro,
                },
            );
            if self.state.take_emergency() {
                self.emergency_steps += 1;
            }
            let dt = step_end - step_start;
            self.step_time_sum += dt;
            if self.retain_step_times {
                self.step_times.push(dt);
            }
            step_ends.push(step_end);
            t_prev = step_end;
            self.global_step += 1;
        }
        run.start = at;
        run.decode_start = decode_start;
        run.micro = micro;
    }

    /// Tear down into the stream totals (trace, step latencies, counters).
    pub fn into_totals(self) -> CoreTotals {
        CoreTotals {
            kv_tokens_transferred: self.policy.kv_tokens_transferred(),
            online_plans_fired: self.policy.online_plans_fired(),
            emergency_steps: self.emergency_steps,
            bw_stalls: self.state.bw_stalls(),
            trace: self.state.trace,
            step_times: self.step_times,
            step_time_sum: self.step_time_sum,
        }
    }

    /// Assemble the [`SimResult`] of a single-request run (the legacy
    /// `run_*` contract: `total_time` measures decode only).
    pub fn into_result(self, run: RequestRun) -> SimResult {
        let total_time = run.finish() - run.decode_start;
        let totals = self.into_totals();
        SimResult {
            tokens: run.step_ends.len(),
            micro_batches: run.micro,
            total_time,
            step_times: totals.step_times,
            trace: totals.trace,
            kv_tokens_transferred: totals.kv_tokens_transferred,
            online_plans_fired: totals.online_plans_fired,
            emergency_steps: totals.emergency_steps,
            bw_stalls: totals.bw_stalls,
        }
    }
}

/// Run `policy` as a one-request stream starting at t = 0 — the shape of
/// the legacy `run_*` entry points, which are thin wrappers over this.
pub fn run_single<P: SchedulePolicy>(
    policy: P,
    cluster: &Cluster,
    bw_trace: &BandwidthTrace,
    micro_batches: usize,
    tokens: usize,
    common: &CommonOptions,
    script: &Script,
) -> SimResult {
    let mut core = ExecutorCore::new(policy, cluster, bw_trace, common, script);
    let run = core.run_request(0.0, micro_batches, tokens);
    core.into_result(run)
}

#[cfg(test)]
mod tests {
    use super::*;

    /// A degenerate policy: every step costs a fixed duration, device 0
    /// saturates when its cap drops below a threshold.
    struct FixedStep {
        dur: f64,
        saturate_below: u64,
        prefill: f64,
        events_seen: usize,
    }

    impl SchedulePolicy for FixedStep {
        fn begin_request(
            &mut self,
            _core: &mut CoreState,
            at: f64,
            _micro: usize,
            _global_step: usize,
        ) -> f64 {
            at + self.prefill
        }

        fn step(&mut self, core: &mut CoreState, ctx: &StepCtx) -> f64 {
            if core.mem_caps[0] < self.saturate_below {
                core.mark_emergency();
            }
            let _ = core.link_acquire(ctx.step_start, self.dur / 2.0);
            ctx.step_start + self.dur
        }

        fn on_mem_event(&mut self, _ev: &MemEvent) {
            self.events_seen += 1;
        }
    }

    fn common() -> CommonOptions {
        CommonOptions {
            prompt_tokens: 4,
            seed: 7,
            trace_mode: TraceMode::Off,
        }
    }

    #[test]
    fn single_run_counts_steps_and_measures_decode_only() {
        let cluster = Cluster::env_e1();
        let bw = BandwidthTrace::fixed_mbps(100.0);
        let policy = FixedStep {
            dur: 0.5,
            saturate_below: 0,
            prefill: 2.0,
            events_seen: 0,
        };
        let r = run_single(policy, &cluster, &bw, 1, 4, &common(), &Script::none());
        assert_eq!(r.tokens, 4);
        assert_eq!(r.step_times, vec![0.5; 4]);
        assert!((r.total_time - 2.0).abs() < 1e-12);
        assert_eq!(r.emergency_steps, 0);
    }

    #[test]
    fn scripted_mem_events_fire_on_the_stream_timeline() {
        use crate::adapt::MemScenario;
        let cluster = Cluster::env_e1();
        let bw = BandwidthTrace::fixed_mbps(100.0);
        // The squeeze lands at stream step 5 — inside the SECOND request
        // of a 2×4-step stream, so per-request step counters never see it.
        let script =
            Script::from_mem(MemScenario::squeeze("sq", 0, u64::MAX / 2, 5)).with_label("sq");
        let policy = FixedStep {
            dur: 0.25,
            saturate_below: u64::MAX / 4,
            prefill: 0.0,
            events_seen: 0,
        };
        let mut core = ExecutorCore::new(policy, &cluster, &bw, &common(), &script);
        let a = core.run_request(0.0, 1, 4);
        let b = core.run_request(a.finish(), 1, 4);
        assert_eq!(core.global_step(), 8);
        assert_eq!(core.policy.events_seen, 1, "event fires exactly once");
        assert!(b.finish() > a.finish());
        let totals = core.into_totals();
        // Steps 5..8 saturate: 3 emergency steps, none in request 1.
        assert_eq!(totals.emergency_steps, 3);
        assert_eq!(totals.step_times.len(), 8);
    }

    #[test]
    fn back_to_back_requests_share_the_link_timeline() {
        let cluster = Cluster::env_e1();
        let bw = BandwidthTrace::fixed_mbps(100.0);
        let policy = FixedStep {
            dur: 1.0,
            saturate_below: 0,
            prefill: 0.0,
            events_seen: 0,
        };
        let mut core = ExecutorCore::new(policy, &cluster, &bw, &common(), &Script::none());
        let a = core.run_request(0.0, 1, 2);
        // Admitted mid-flight of nothing: starts exactly at its arrival.
        let b = core.run_request(a.finish(), 1, 2);
        assert_eq!(b.start, a.finish());
        assert_eq!(b.decode_start, b.start);
        // The link was idle between requests — no stalls counted.
        let totals = core.into_totals();
        assert_eq!(totals.bw_stalls, 0);
    }

    fn jitter_policy() -> FixedStep {
        FixedStep {
            dur: 0.375,
            saturate_below: 0,
            prefill: 0.125,
            events_seen: 0,
        }
    }

    #[test]
    fn arena_runs_are_bit_identical_to_allocating_runs() {
        let cluster = Cluster::env_e1();
        let bw = BandwidthTrace::fixed_mbps(100.0);
        let shapes = [(0.0, 1, 4), (2.5, 2, 7), (2.5, 1, 0), (9.0, 3, 2)];

        let mut fresh = ExecutorCore::new(jitter_policy(), &cluster, &bw, &common(), &Script::none());
        let want: Vec<RequestRun> = shapes
            .iter()
            .map(|&(at, m, t)| fresh.run_request(at, m, t))
            .collect();

        let mut reused =
            ExecutorCore::new(jitter_policy(), &cluster, &bw, &common(), &Script::none());
        let mut arena = CoreArena::new();
        for (w, &(at, m, t)) in want.iter().zip(&shapes) {
            let run = reused.run_request_in(at, m, t, &mut arena);
            assert_eq!(run, w, "arena run diverged at shape {:?}", (at, m, t));
        }
        let (a, b) = (fresh.into_totals(), reused.into_totals());
        assert_eq!(a.step_times, b.step_times);
        assert_eq!(a.step_time_sum.to_bits(), b.step_time_sum.to_bits());
    }

    #[test]
    fn dropping_step_times_keeps_the_sum_bit_identical() {
        let cluster = Cluster::env_e1();
        let bw = BandwidthTrace::fixed_mbps(100.0);
        let mut retained =
            ExecutorCore::new(jitter_policy(), &cluster, &bw, &common(), &Script::none());
        let mut flat = ExecutorCore::new(jitter_policy(), &cluster, &bw, &common(), &Script::none());
        flat.retain_step_times(false);
        let mut arena = CoreArena::new();
        let mut t = 0.0;
        for _ in 0..5 {
            let a = retained.run_request(t, 1, 6);
            let b = flat.run_request_in(t, 1, 6, &mut arena);
            assert_eq!(&a, b);
            t = a.finish();
        }
        let (a, b) = (retained.into_totals(), flat.into_totals());
        assert_eq!(a.step_times.len(), 30);
        assert!(b.step_times.is_empty(), "memory-flat mode retains nothing");
        assert_eq!(a.step_times.iter().sum::<f64>().to_bits(), a.step_time_sum.to_bits());
        assert_eq!(a.step_time_sum.to_bits(), b.step_time_sum.to_bits());
    }
}
