//! The unified executor core — one event-driven step driver shared by all
//! three schedule policies (interleaved, traditional, tensor-parallel).
//!
//! Before this module existed, the three executors hand-rolled nearly
//! identical step loops: each one built its own trace, GPU/SSD/link
//! resources, counted `bw_stalls` and emergency steps, applied scripted
//! fluctuation events, and assembled the final [`SimResult`]. The core
//! now owns those shared mechanics; a [`SchedulePolicy`] owns only its
//! schedule-specific decisions (micro-batch fronts and cross-segment
//! offload overlap for the interleaved schedule, per-use loads for the
//! traditional schedule, collective rounds for tensor parallelism).
//!
//! The split:
//!
//! * [`CoreState`] — trace lanes, per-device GPU [`Resource`]s and
//!   [`SsdModel`]s, the shared LAN link with stall accounting
//!   ([`CoreState::link_acquire`]), and the scripted effective-memory caps
//!   ([`CoreState::mem_caps`]) every policy judges saturation against.
//! * [`SchedulePolicy`] — `begin_request` (reset per-request state and
//!   charge the prefill pass), `step` (one decode step), `on_mem_event`
//!   (shift policy-internal thresholds when the core applies a scripted
//!   memory event), and the §IV-D counters for result assembly.
//! * [`ExecutorCore`] — the driver. It fires scripted [`MemEvent`]s /
//!   `BwEvent`s on the **stream timeline** (global step counter), runs
//!   policy steps, counts emergency steps (at most once per step), and
//!   accumulates step latencies. [`ExecutorCore::run_request`] runs one
//!   request *without resetting the timeline*, which is what lets
//!   `serve::simqueue` simulate continuous request serving: back-to-back
//!   requests share the same resources, SSD jitter streams, bandwidth
//!   trace, and fluctuation script.
//!
//! The legacy single-request entry points (`run_interleaved`,
//! `run_traditional`, `run_tensor_parallel`) are thin wrappers over
//! [`run_single`] — a one-request stream starting at t = 0 — and are
//! property-tested bit-identical to the pre-refactor executors
//! (`rust/tests/serving_stream.rs`).

use crate::adapt::{ChurnEvent, ChurnKind, MemEvent, Script};
use crate::cluster::Cluster;
use crate::net::BandwidthTrace;
use crate::pipeline::result::SimResult;
use crate::sim::{Interval, Resource, SsdModel, Trace, TraceMode};

/// A churn script asked for the impossible: taking down the last
/// surviving device. Surfaced as a structured error (never a panic) by
/// the fallible run entry points ([`ExecutorCore::run_request`],
/// [`run_single_checked`]).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct ChurnError {
    /// Stream step the offending event fired at.
    pub at_step: usize,
    /// The device the script tried to take down.
    pub device: usize,
}

impl std::fmt::Display for ChurnError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "churn event at step {} takes down device {} — no surviving devices would remain",
            self.at_step, self.device
        )
    }
}

impl std::error::Error for ChurnError {}

/// Step-latency tolerance for recovery detection: a fault counts as
/// recovered once a decode step lands within 10% of the pre-fault mean.
const RECOVERY_TOLERANCE: f64 = 1.10;

/// Context handed to [`SchedulePolicy::on_churn_event`]: where on the
/// stream/request timeline the fault landed, so policies can size KV
/// migrations and time their link traffic.
#[derive(Debug, Clone, Copy)]
pub struct ChurnCtx {
    /// Absolute time the event applies (= the upcoming step's start).
    pub at: f64,
    /// Step index on the stream timeline.
    pub global_step: usize,
    /// Decode steps already completed within the current request.
    pub local_step: usize,
    /// Micro-batches in flight for the current request.
    pub micro: usize,
}

/// The options every schedule policy shares, consumed by the core.
/// `ExecOptions`/`TradOptions`/`TpOptions` each carry these three fields
/// (with schedule-specific defaults) plus their policy-specific knobs, and
/// convert via `From<&…Options>`.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct CommonOptions {
    /// Prompt length charged as a prefill pass before decoding.
    pub prompt_tokens: usize,
    /// RNG seed for the SSD write-jitter streams.
    pub seed: u64,
    /// Span recording detail (never affects `SimResult` timing fields).
    pub trace_mode: TraceMode,
}

/// Per-step context handed to [`SchedulePolicy::step`].
#[derive(Debug, Clone, Copy)]
pub struct StepCtx {
    /// Step index on the stream timeline — indexes the bandwidth trace and
    /// the fluctuation script. Equals `local_step` for single-request runs.
    pub global_step: usize,
    /// Step index within the current request — the KV context grows with
    /// this one.
    pub local_step: usize,
    /// Absolute time the step begins (= previous step's end).
    pub step_start: f64,
    /// Micro-batches in flight for the current request.
    pub micro: usize,
}

/// Shared simulation state owned by the core: the mechanics that used to
/// be copy-pasted across the three executors.
pub struct CoreState {
    /// Span lanes (Gantt rendering + overlap accounting).
    pub trace: Trace,
    /// One exclusive compute server per device.
    pub gpus: Vec<Resource>,
    /// One SSD channel per device (deterministic reads, jittery writes).
    pub ssds: Vec<SsdModel>,
    /// The edge LAN is a shared medium: one exclusive link resource.
    net: Resource,
    /// Link capacity over steps; scripted `BwEvent`s are overlaid up
    /// front so every consumer sees the scaled capacity through one query.
    bw: BandwidthTrace,
    bw_stalls: u64,
    emergency_this_step: bool,
    /// Effective usable memory per device; scripted pressure events shift
    /// these away from the `DeviceSpec` capacities mid-run. Cumulative
    /// signed pressure is tracked against the unpressured base (mirroring
    /// `OnlinePlanner::apply_pressure`) so a dip that bottoms a device out
    /// restores exactly.
    mem_base: Vec<u64>,
    mem_pressure: Vec<i64>,
    /// Current effective per-device caps every policy judges saturation
    /// against (`== usable_mem()` while no script event has fired).
    /// A churned-down device's cap is pinned at 0 until it rejoins, so
    /// non-adaptive policies degrade honestly through the same overflow
    /// fallbacks that handle scripted memory pressure.
    pub mem_caps: Vec<u64>,
    /// Which devices a churn script currently holds down.
    churn_down: Vec<bool>,
}

impl CoreState {
    fn new(cluster: &Cluster, bw: BandwidthTrace, common: &CommonOptions) -> Self {
        let d = cluster.len();
        let mem_base: Vec<u64> = (0..d).map(|i| cluster.devices[i].usable_mem()).collect();
        CoreState {
            trace: Trace::with_mode(common.trace_mode),
            gpus: (0..d).map(|_| Resource::new()).collect(),
            ssds: (0..d)
                .map(|i| {
                    SsdModel::new(
                        cluster.devices[i].ssd_read_bps,
                        cluster.devices[i].ssd_write_bps,
                        common.seed ^ (i as u64) << 8,
                    )
                })
                .collect(),
            net: Resource::new(),
            bw,
            bw_stalls: 0,
            emergency_this_step: false,
            mem_pressure: vec![0; d],
            mem_caps: mem_base.clone(),
            mem_base,
            churn_down: vec![false; d],
        }
    }

    /// Link capacity at a stream step (scripted scales already applied).
    pub fn bw_at(&self, global_step: usize) -> f64 {
        self.bw.at(global_step)
    }

    /// Acquire the shared link for `dur` seconds starting no earlier than
    /// `at`, counting a bandwidth stall when the medium was busy. The
    /// counter is purely observational — it never feeds back into timing.
    pub fn link_acquire(&mut self, at: f64, dur: f64) -> Interval {
        let iv = self.net.acquire(at, dur);
        if iv.start > at {
            self.bw_stalls += 1;
        }
        iv
    }

    /// Mark the current step as needing the emergency KV-spill fallback.
    /// The core counts each step at most once, however many devices
    /// overflow within it.
    pub fn mark_emergency(&mut self) {
        self.emergency_this_step = true;
    }

    /// Cumulative scripted pressure on device `i` (negative = memory taken
    /// away). Policies that rebuild per-request state re-apply this to
    /// their fresh planners so mid-stream resets keep the shifted slack.
    pub fn mem_pressure(&self, i: usize) -> i64 {
        self.mem_pressure[i]
    }

    /// Link acquisitions that had to wait on the busy shared medium.
    pub fn bw_stalls(&self) -> u64 {
        self.bw_stalls
    }

    /// Is device `i` currently churned down?
    pub fn device_down(&self, i: usize) -> bool {
        self.churn_down[i]
    }

    /// Indices of the devices currently up (in device order).
    pub fn survivors(&self) -> Vec<usize> {
        (0..self.churn_down.len())
            .filter(|&i| !self.churn_down[i])
            .collect()
    }

    fn apply_mem_event(&mut self, ev: &MemEvent) {
        self.mem_pressure[ev.device] = self.mem_pressure[ev.device].saturating_add(ev.delta_bytes);
        self.refresh_cap(ev.device);
    }

    /// Effective cap of device `i` from its base capacity, accumulated
    /// scripted pressure, and churn state (down pins the cap at 0).
    fn refresh_cap(&mut self, i: usize) {
        self.mem_caps[i] = if self.churn_down[i] {
            0
        } else {
            crate::adapt::planner::shifted(self.mem_base[i], self.mem_pressure[i])
        };
    }

    /// Apply one churn event. `Down` on the last surviving device is the
    /// structured [`ChurnError`]; repeated `Down`s (or `Up`s) on one
    /// device are idempotent.
    fn apply_churn_event(&mut self, ev: &ChurnEvent) -> Result<(), ChurnError> {
        match ev.kind {
            ChurnKind::Down => {
                let up_count = self.churn_down.iter().filter(|&&down| !down).count();
                if !self.churn_down[ev.device] && up_count == 1 {
                    return Err(ChurnError {
                        at_step: ev.at_step,
                        device: ev.device,
                    });
                }
                self.churn_down[ev.device] = true;
            }
            ChurnKind::Up => self.churn_down[ev.device] = false,
        }
        self.refresh_cap(ev.device);
        Ok(())
    }

    fn take_emergency(&mut self) -> bool {
        std::mem::replace(&mut self.emergency_this_step, false)
    }
}

/// A pipeline schedule: the policy-specific half of an executor. The core
/// drives implementations through `begin_request` → `step`*, firing
/// `on_mem_event` whenever a scripted memory event lands on the stream
/// timeline (the core has already shifted [`CoreState::mem_caps`]).
///
/// The continuous-batching serving driver decomposes admission into the
/// finer-grained [`SchedulePolicy::prefill_end`] (charge prefill while an
/// earlier epoch still decodes) / [`SchedulePolicy::begin_batch`] (reset
/// state at the epoch boundary) pair and signals mid-epoch batch-width
/// changes through [`SchedulePolicy::on_batch_resize`]; all three default
/// to behaviour that keeps FIFO-only policies correct unchanged.
pub trait SchedulePolicy {
    /// Reset per-request state and charge the prefill pass for a request
    /// with `micro` micro-batches whose service begins at absolute time
    /// `at` (stream step `global_step`). Returns the decode-start time.
    fn begin_request(
        &mut self,
        core: &mut CoreState,
        at: f64,
        micro: usize,
        global_step: usize,
    ) -> f64;

    /// Simulate one decode step; returns the absolute step-end time.
    fn step(&mut self, core: &mut CoreState, ctx: &StepCtx) -> f64;

    /// A scripted memory event fired; shift any policy-internal thresholds
    /// (the effective cap shift has already been applied by the core).
    fn on_mem_event(&mut self, _ev: &MemEvent) {}

    /// A scripted churn event fired: the core has already zeroed (Down)
    /// or restored (Up) the device's effective cap. Adaptive policies
    /// re-plan onto the survivors and migrate the departed device's
    /// resident KV over the shared link; the default no-op leaves
    /// non-adaptive policies to degrade through their overflow fallbacks
    /// against the zeroed cap.
    fn on_churn_event(&mut self, _core: &mut CoreState, _ev: &ChurnEvent, _ctx: &ChurnCtx) {}

    /// Charge the prefill pass only (no per-request state reset) for a
    /// request with `micro` micro-batches whose prefill begins at absolute
    /// time `at`. Pure time arithmetic: the continuous-batching driver
    /// calls this to overlap a *pending* admission's prefill with the
    /// current batch's decode, so implementations must not touch state the
    /// in-flight decode steps read. Returns the prefill-end time; the
    /// default charges nothing (policies without a prefill model).
    fn prefill_end(
        &mut self,
        _core: &mut CoreState,
        at: f64,
        _micro: usize,
        _global_step: usize,
    ) -> f64 {
        at
    }

    /// Reset per-request state for a batch epoch whose decode begins at
    /// `at`, *without* charging prefill (already charged through
    /// [`SchedulePolicy::prefill_end`] while the previous epoch decoded).
    /// Returns the decode-start time. The default composes the legacy
    /// path — [`SchedulePolicy::begin_request`] resets *and* charges
    /// prefill — so policies that never overlap keep one code path.
    fn begin_batch(
        &mut self,
        core: &mut CoreState,
        at: f64,
        micro: usize,
        global_step: usize,
    ) -> f64 {
        self.begin_request(core, at, micro, global_step)
    }

    /// The active batch width changed between decode steps (a finished
    /// request was evicted or a prefilled one joined). Implementations
    /// resize whatever per-micro-batch state they keep; the next
    /// [`SchedulePolicy::step`] sees the new `micro` in its [`StepCtx`].
    fn on_batch_resize(&mut self, _core: &mut CoreState, _micro: usize) {}

    /// Install the per-slot request lengths for the next admission charge
    /// or decode step: one `(prompt_len, completed_steps)` pair per active
    /// micro-batch slot. The serving driver (`serve::simqueue`) calls this
    /// so length-aware policies charge each slot's prefill FLOPs,
    /// activation volume and KV context from the request's *own* lengths;
    /// an empty slice (and the default no-op) means "use the global
    /// `CommonOptions::prompt_tokens` knob" — the pre-mix behaviour every
    /// non-serving entry point keeps bit-identically.
    fn set_slot_lengths(&mut self, _slots: &[(usize, usize)]) {}

    /// KV tokens shipped between devices so far (stream total).
    fn kv_tokens_transferred(&self) -> u64 {
        0
    }

    /// Online offload plans fired so far (stream total).
    fn online_plans_fired(&self) -> usize {
        0
    }

    /// Churn-triggered re-plans fired so far (stream total).
    fn replans_fired(&self) -> usize {
        0
    }

    /// KV bytes migrated off/onto churned devices so far (stream total).
    fn kv_migrated_bytes(&self) -> u64 {
        0
    }
}

/// Timing of one request run on the core's shared timeline.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct RequestRun {
    /// When service (the prefill pass) began.
    pub start: f64,
    /// When decoding began (prefill charged between `start` and here).
    pub decode_start: f64,
    /// Absolute completion time of each decode step.
    pub step_ends: Vec<f64>,
    /// Micro-batches the request ran with (= admitted batch size).
    pub micro: usize,
}

impl RequestRun {
    /// When the run's last token completed (= `decode_start` for empty
    /// runs).
    pub fn finish(&self) -> f64 {
        self.step_ends.last().copied().unwrap_or(self.decode_start)
    }
}

/// Reusable per-request scratch for long streams: holds the [`RequestRun`]
/// buffers that [`ExecutorCore::run_request`] would otherwise allocate per
/// call, so a 10^6-request stream touches the allocator O(1) times on the
/// core side. [`ExecutorCore::run_request_in`] resets it instead of
/// reallocating; the filled run is borrowed back until the next call.
/// (The policy-side analogue is `InterleavedPolicy`'s in-place request
/// reset — together they are the perf lever's "arena".)
#[derive(Debug, Clone, Default)]
pub struct CoreArena {
    run: RequestRun,
}

impl CoreArena {
    pub fn new() -> Self {
        CoreArena::default()
    }
}

/// Everything a finished core hands back: the trace plus the stream-level
/// accumulators the per-policy counters join for result assembly.
pub struct CoreTotals {
    pub trace: Trace,
    /// Per-step latencies — empty when the core ran with
    /// [`ExecutorCore::retain_step_times`] off (memory-flat streams).
    pub step_times: Vec<f64>,
    /// Running sum of every step latency, accumulated left-to-right in
    /// push order — bit-identical to `step_times.iter().sum()` whenever
    /// the vector is retained, and the only decode-time record when not.
    pub step_time_sum: f64,
    pub emergency_steps: usize,
    pub bw_stalls: u64,
    pub kv_tokens_transferred: u64,
    pub online_plans_fired: usize,
    pub replans_fired: usize,
    pub kv_migrated_bytes: u64,
    /// Per-`Down`-event recovery latency in steps (firing order): steps
    /// until a decode step lands back within [`RECOVERY_TOLERANCE`] of
    /// the pre-fault mean; `None` when the stream ends first.
    pub recovery_steps: Vec<Option<usize>>,
}

/// The unified step driver: owns the [`CoreState`] and the stream-global
/// step counter, runs requests back-to-back on one shared timeline.
pub struct ExecutorCore<'s, P: SchedulePolicy> {
    pub policy: P,
    pub state: CoreState,
    script: &'s Script,
    global_step: usize,
    emergency_steps: usize,
    step_times: Vec<f64>,
    step_time_sum: f64,
    retain_step_times: bool,
    /// One slot per fired `Down` event (firing order); filled in when the
    /// fault's step latency recovers, left `None` if the stream ends
    /// first.
    recovery_steps: Vec<Option<usize>>,
    /// Faults still counting toward recovery: `(slot, pre-fault mean
    /// step latency, steps elapsed since the fault)`.
    pending_recovery: Vec<(usize, f64, usize)>,
}

impl<'s, P: SchedulePolicy> ExecutorCore<'s, P> {
    /// Build a core over `cluster`. Scripted bandwidth events overlay the
    /// base trace up front — every consumer (prefill, hops, KV shipping,
    /// the Alg. 2 monitor) then sees the scaled capacity through one
    /// unchanged query path.
    pub fn new(
        policy: P,
        cluster: &Cluster,
        bw_trace: &BandwidthTrace,
        common: &CommonOptions,
        script: &'s Script,
    ) -> Self {
        // Owning the trace (one clone per *run*, an f64 for the Fixed
        // traces every sweep uses) keeps CoreState lifetime-free; the
        // overlay path materializes a scaled copy exactly as before.
        let bw = if script.bw.is_empty() {
            bw_trace.clone()
        } else {
            bw_trace.overlay_scales(&script.bw_scale_points())
        };
        ExecutorCore {
            policy,
            state: CoreState::new(cluster, bw, common),
            script,
            global_step: 0,
            emergency_steps: 0,
            step_times: Vec::new(),
            step_time_sum: 0.0,
            retain_step_times: true,
            recovery_steps: Vec::new(),
            pending_recovery: Vec::new(),
        }
    }

    /// Next step index on the stream timeline.
    pub fn global_step(&self) -> usize {
        self.global_step
    }

    /// Keep (default) or drop the per-step latency vector. Million-request
    /// fleet streams turn retention off so the core holds no per-request
    /// state; the left-to-right [`CoreTotals::step_time_sum`] still records
    /// total decode time bit-identically to summing the retained vector.
    pub fn retain_step_times(&mut self, retain: bool) {
        self.retain_step_times = retain;
    }

    /// Run one request (prefill + `tokens` decode steps, `micro_batches`
    /// micro-batches) starting no earlier than `at`, on the shared
    /// timeline: resources, SSD jitter streams, the global step counter
    /// and the fluctuation script all carry over from previous requests.
    ///
    /// Errs only when the script takes down the last surviving device
    /// ([`ChurnError`]) — impossible for churn-free scripts.
    pub fn run_request(
        &mut self,
        at: f64,
        micro_batches: usize,
        tokens: usize,
    ) -> Result<RequestRun, ChurnError> {
        let mut run = RequestRun {
            step_ends: Vec::with_capacity(tokens),
            ..RequestRun::default()
        };
        self.run_request_into(at, micro_batches, tokens, &mut run)?;
        Ok(run)
    }

    /// [`ExecutorCore::run_request`] recycling `arena`'s buffers — the
    /// stream-serving entry point: no allocation once the step buffer has
    /// grown to the stream's widest request.
    pub fn run_request_in<'a>(
        &mut self,
        at: f64,
        micro_batches: usize,
        tokens: usize,
        arena: &'a mut CoreArena,
    ) -> Result<&'a RequestRun, ChurnError> {
        // Split-borrow: take the run out so `self` stays free for the loop.
        let mut run = std::mem::take(&mut arena.run);
        let outcome = self.run_request_into(at, micro_batches, tokens, &mut run);
        arena.run = run;
        outcome?;
        Ok(&arena.run)
    }

    fn run_request_into(
        &mut self,
        at: f64,
        micro_batches: usize,
        tokens: usize,
        run: &mut RequestRun,
    ) -> Result<(), ChurnError> {
        let micro = micro_batches.max(1);
        let decode_start = self
            .policy
            .begin_request(&mut self.state, at, micro, self.global_step);
        let mut t_prev = decode_start;
        let step_ends = &mut run.step_ends;
        step_ends.clear();
        step_ends.reserve(tokens);
        for local in 0..tokens {
            let step_end = self.step_stream(t_prev, micro, local)?;
            step_ends.push(step_end);
            t_prev = step_end;
        }
        run.start = at;
        run.decode_start = decode_start;
        run.micro = micro;
        Ok(())
    }

    /// Advance the stream by exactly one decode step starting at `t_prev`
    /// with `micro` micro-batches in flight, `local_step` being the oldest
    /// active request's completed-step count. This is the single step body
    /// [`ExecutorCore::run_request_into`] loops over *and* the primitive
    /// the continuous-batching driver (`serve::simqueue`) calls directly —
    /// scripted mem/churn events fire on the stream timeline, emergency
    /// steps are counted, recovery trackers advance, and the global step
    /// counter increments. Returns the absolute step-end time.
    pub fn step_stream(
        &mut self,
        t_prev: f64,
        micro: usize,
        local_step: usize,
    ) -> Result<f64, ChurnError> {
        let g = self.global_step;
        // Scripted memory fluctuation, fired on the STREAM timeline —
        // applied before the policy's step so a lowered threshold
        // already counts as "imminent" for this step's Alg. 2
        // decisions.
        let script = self.script;
        for ev in script.mem.iter().filter(|ev| ev.at_step == g) {
            self.state.apply_mem_event(ev);
            self.policy.on_mem_event(ev);
        }
        // Churn fires after memory events within a step (the
        // [`Script::events`] order): the core flips the device's
        // availability and cap, opens a recovery tracker for Downs,
        // then lets the policy re-plan/migrate before the step runs.
        for ev in script.churn.iter().filter(|ev| ev.at_step == g) {
            self.state.apply_churn_event(ev)?;
            if ev.kind == ChurnKind::Down {
                let baseline = if g > 0 {
                    self.step_time_sum / g as f64
                } else {
                    f64::INFINITY
                };
                let slot = self.recovery_steps.len();
                self.recovery_steps.push(None);
                self.pending_recovery.push((slot, baseline, 0));
            }
            self.policy.on_churn_event(
                &mut self.state,
                ev,
                &ChurnCtx {
                    at: t_prev,
                    global_step: g,
                    local_step,
                    micro,
                },
            );
        }
        let step_start = t_prev;
        let step_end = self.policy.step(
            &mut self.state,
            &StepCtx {
                global_step: g,
                local_step,
                step_start,
                micro,
            },
        );
        if self.state.take_emergency() {
            self.emergency_steps += 1;
        }
        let dt = step_end - step_start;
        self.step_time_sum += dt;
        if self.retain_step_times {
            self.step_times.push(dt);
        }
        if !self.pending_recovery.is_empty() {
            let recovered = &mut self.recovery_steps;
            self.pending_recovery.retain_mut(|(slot, baseline, steps)| {
                *steps += 1;
                if dt <= *baseline * RECOVERY_TOLERANCE {
                    recovered[*slot] = Some(*steps);
                    false
                } else {
                    true
                }
            });
        }
        self.global_step += 1;
        Ok(step_end)
    }

    /// Tear down into the stream totals (trace, step latencies, counters).
    pub fn into_totals(self) -> CoreTotals {
        CoreTotals {
            kv_tokens_transferred: self.policy.kv_tokens_transferred(),
            online_plans_fired: self.policy.online_plans_fired(),
            replans_fired: self.policy.replans_fired(),
            kv_migrated_bytes: self.policy.kv_migrated_bytes(),
            recovery_steps: self.recovery_steps,
            emergency_steps: self.emergency_steps,
            bw_stalls: self.state.bw_stalls(),
            trace: self.state.trace,
            step_times: self.step_times,
            step_time_sum: self.step_time_sum,
        }
    }

    /// Assemble the [`SimResult`] of a single-request run (the legacy
    /// `run_*` contract: `total_time` measures decode only).
    pub fn into_result(self, run: RequestRun) -> SimResult {
        let total_time = run.finish() - run.decode_start;
        let totals = self.into_totals();
        SimResult {
            tokens: run.step_ends.len(),
            micro_batches: run.micro,
            total_time,
            step_times: totals.step_times,
            trace: totals.trace,
            kv_tokens_transferred: totals.kv_tokens_transferred,
            online_plans_fired: totals.online_plans_fired,
            emergency_steps: totals.emergency_steps,
            bw_stalls: totals.bw_stalls,
            replans_fired: totals.replans_fired,
            kv_migrated_bytes: totals.kv_migrated_bytes,
            recovery_steps: totals.recovery_steps,
            // Single-request runs model KV as contiguous preallocation;
            // paged accounting exists only on the continuous-batching
            // serving path (`serve::kvpages`).
            kv_pages_allocated: 0,
            kv_pages_spilled: 0,
            kv_fragmentation: 0.0,
        }
    }
}

/// Run `policy` as a one-request stream starting at t = 0 — the shape of
/// the legacy `run_*` entry points, which are thin wrappers over this.
/// Panics if the script takes down the last surviving device; churn
/// scripts that can do so must go through [`run_single_checked`].
pub fn run_single<P: SchedulePolicy>(
    policy: P,
    cluster: &Cluster,
    bw_trace: &BandwidthTrace,
    micro_batches: usize,
    tokens: usize,
    common: &CommonOptions,
    script: &Script,
) -> SimResult {
    run_single_checked(policy, cluster, bw_trace, micro_batches, tokens, common, script)
        .unwrap_or_else(|e| panic!("{e}; use run_single_checked for fallible churn scripts"))
}

/// Fallible [`run_single`]: surfaces a churn script that takes down the
/// last surviving device as a structured [`ChurnError`] instead of a
/// panic.
pub fn run_single_checked<P: SchedulePolicy>(
    policy: P,
    cluster: &Cluster,
    bw_trace: &BandwidthTrace,
    micro_batches: usize,
    tokens: usize,
    common: &CommonOptions,
    script: &Script,
) -> Result<SimResult, ChurnError> {
    let mut core = ExecutorCore::new(policy, cluster, bw_trace, common, script);
    let run = core.run_request(0.0, micro_batches, tokens)?;
    Ok(core.into_result(run))
}

#[cfg(test)]
mod tests {
    use super::*;

    /// A degenerate policy: every step costs a fixed duration, device 0
    /// saturates when its cap drops below a threshold.
    struct FixedStep {
        dur: f64,
        saturate_below: u64,
        prefill: f64,
        events_seen: usize,
    }

    impl SchedulePolicy for FixedStep {
        fn begin_request(
            &mut self,
            _core: &mut CoreState,
            at: f64,
            _micro: usize,
            _global_step: usize,
        ) -> f64 {
            at + self.prefill
        }

        fn step(&mut self, core: &mut CoreState, ctx: &StepCtx) -> f64 {
            if core.mem_caps[0] < self.saturate_below {
                core.mark_emergency();
            }
            let _ = core.link_acquire(ctx.step_start, self.dur / 2.0);
            ctx.step_start + self.dur
        }

        fn on_mem_event(&mut self, _ev: &MemEvent) {
            self.events_seen += 1;
        }
    }

    fn common() -> CommonOptions {
        CommonOptions {
            prompt_tokens: 4,
            seed: 7,
            trace_mode: TraceMode::Off,
        }
    }

    #[test]
    fn single_run_counts_steps_and_measures_decode_only() {
        let cluster = Cluster::env_e1();
        let bw = BandwidthTrace::fixed_mbps(100.0);
        let policy = FixedStep {
            dur: 0.5,
            saturate_below: 0,
            prefill: 2.0,
            events_seen: 0,
        };
        let r = run_single(policy, &cluster, &bw, 1, 4, &common(), &Script::none());
        assert_eq!(r.tokens, 4);
        assert_eq!(r.step_times, vec![0.5; 4]);
        assert!((r.total_time - 2.0).abs() < 1e-12);
        assert_eq!(r.emergency_steps, 0);
    }

    #[test]
    fn scripted_mem_events_fire_on_the_stream_timeline() {
        use crate::adapt::MemScenario;
        let cluster = Cluster::env_e1();
        let bw = BandwidthTrace::fixed_mbps(100.0);
        // The squeeze lands at stream step 5 — inside the SECOND request
        // of a 2×4-step stream, so per-request step counters never see it.
        let script =
            Script::from_mem(MemScenario::squeeze("sq", 0, u64::MAX / 2, 5)).with_label("sq");
        let policy = FixedStep {
            dur: 0.25,
            saturate_below: u64::MAX / 4,
            prefill: 0.0,
            events_seen: 0,
        };
        let mut core = ExecutorCore::new(policy, &cluster, &bw, &common(), &script);
        let a = core.run_request(0.0, 1, 4).unwrap();
        let b = core.run_request(a.finish(), 1, 4).unwrap();
        assert_eq!(core.global_step(), 8);
        assert_eq!(core.policy.events_seen, 1, "event fires exactly once");
        assert!(b.finish() > a.finish());
        let totals = core.into_totals();
        // Steps 5..8 saturate: 3 emergency steps, none in request 1.
        assert_eq!(totals.emergency_steps, 3);
        assert_eq!(totals.step_times.len(), 8);
    }

    #[test]
    fn back_to_back_requests_share_the_link_timeline() {
        let cluster = Cluster::env_e1();
        let bw = BandwidthTrace::fixed_mbps(100.0);
        let policy = FixedStep {
            dur: 1.0,
            saturate_below: 0,
            prefill: 0.0,
            events_seen: 0,
        };
        let mut core = ExecutorCore::new(policy, &cluster, &bw, &common(), &Script::none());
        let a = core.run_request(0.0, 1, 2).unwrap();
        // Admitted mid-flight of nothing: starts exactly at its arrival.
        let b = core.run_request(a.finish(), 1, 2).unwrap();
        assert_eq!(b.start, a.finish());
        assert_eq!(b.decode_start, b.start);
        // The link was idle between requests — no stalls counted.
        let totals = core.into_totals();
        assert_eq!(totals.bw_stalls, 0);
    }

    fn jitter_policy() -> FixedStep {
        FixedStep {
            dur: 0.375,
            saturate_below: 0,
            prefill: 0.125,
            events_seen: 0,
        }
    }

    #[test]
    fn arena_runs_are_bit_identical_to_allocating_runs() {
        let cluster = Cluster::env_e1();
        let bw = BandwidthTrace::fixed_mbps(100.0);
        let shapes = [(0.0, 1, 4), (2.5, 2, 7), (2.5, 1, 0), (9.0, 3, 2)];

        let mut fresh = ExecutorCore::new(jitter_policy(), &cluster, &bw, &common(), &Script::none());
        let want: Vec<RequestRun> = shapes
            .iter()
            .map(|&(at, m, t)| fresh.run_request(at, m, t).unwrap())
            .collect();

        let mut reused =
            ExecutorCore::new(jitter_policy(), &cluster, &bw, &common(), &Script::none());
        let mut arena = CoreArena::new();
        for (w, &(at, m, t)) in want.iter().zip(&shapes) {
            let run = reused.run_request_in(at, m, t, &mut arena).unwrap();
            assert_eq!(run, w, "arena run diverged at shape {:?}", (at, m, t));
        }
        let (a, b) = (fresh.into_totals(), reused.into_totals());
        assert_eq!(a.step_times, b.step_times);
        assert_eq!(a.step_time_sum.to_bits(), b.step_time_sum.to_bits());
    }

    /// A policy whose step slows 4× while any device is down — enough
    /// structure to exercise the core's recovery tracking without a real
    /// schedule.
    struct ChurnSensitive {
        dur: f64,
    }

    impl SchedulePolicy for ChurnSensitive {
        fn begin_request(
            &mut self,
            _core: &mut CoreState,
            at: f64,
            _micro: usize,
            _global_step: usize,
        ) -> f64 {
            at
        }

        fn step(&mut self, core: &mut CoreState, ctx: &StepCtx) -> f64 {
            let slow = (0..core.mem_caps.len()).any(|i| core.device_down(i));
            ctx.step_start + if slow { self.dur * 4.0 } else { self.dur }
        }
    }

    #[test]
    fn churn_down_zeroes_the_cap_and_up_restores_it_with_pressure() {
        use crate::adapt::{ChurnEvent, ScriptEvent};
        let cluster = Cluster::env_e1();
        let bw = BandwidthTrace::fixed_mbps(100.0);
        let base_cap = cluster.devices[0].usable_mem();
        let squeeze = 1024i64;
        let script = Script::from_events(
            "churn-mem",
            vec![
                ScriptEvent::Mem(MemEvent {
                    at_step: 1,
                    device: 0,
                    delta_bytes: -squeeze,
                }),
                ScriptEvent::Churn(ChurnEvent {
                    at_step: 2,
                    device: 0,
                    kind: ChurnKind::Down,
                }),
                ScriptEvent::Churn(ChurnEvent {
                    at_step: 4,
                    device: 0,
                    kind: ChurnKind::Up,
                }),
            ],
        );
        // env_e1 must have >1 device for a lone Down to be legal.
        assert!(cluster.len() > 1);
        let mut core = ExecutorCore::new(
            ChurnSensitive { dur: 0.5 },
            &cluster,
            &bw,
            &common(),
            &script,
        );
        let run = core.run_request(0.0, 1, 6).unwrap();
        assert_eq!(run.step_ends.len(), 6);
        // After the stream: device back up, cap = base − squeeze (the
        // scripted pressure survives the down/up cycle).
        assert!(!core.state.device_down(0));
        assert_eq!(core.state.mem_caps[0], base_cap - squeeze as u64);
        assert_eq!(core.state.survivors().len(), cluster.len());
        let totals = core.into_totals();
        // One Down event → one recovery slot; the policy recovers the
        // first step after Up: down at step 2, up at step 4 → steps
        // 2 and 3 degraded, step 4 back at baseline → 3 steps to recover.
        assert_eq!(totals.recovery_steps, vec![Some(3)]);
        assert_eq!(totals.replans_fired, 0, "default policy hook is a no-op");
        assert_eq!(totals.kv_migrated_bytes, 0);
    }

    #[test]
    fn unrecovered_fault_reports_none() {
        let cluster = Cluster::env_e1();
        let bw = BandwidthTrace::fixed_mbps(100.0);
        let script = Script::device_down_up("late-up", 0, 2, 100);
        let mut core = ExecutorCore::new(
            ChurnSensitive { dur: 0.5 },
            &cluster,
            &bw,
            &common(),
            &script,
        );
        core.run_request(0.0, 1, 8).unwrap();
        // Still down at stream end: the cap stays pinned at zero.
        assert!(core.state.device_down(0));
        assert_eq!(core.state.mem_caps[0], 0);
        let totals = core.into_totals();
        assert_eq!(totals.recovery_steps, vec![None], "stream ended degraded");
    }

    #[test]
    fn down_of_last_surviving_device_is_a_structured_error() {
        use crate::adapt::ChurnEvent;
        let cluster = Cluster::env_e1();
        let bw = BandwidthTrace::fixed_mbps(100.0);
        let d = cluster.len();
        // Take every device down, one per step; the last one must error.
        let churn: Vec<crate::adapt::ScriptEvent> = (0..d)
            .map(|i| {
                crate::adapt::ScriptEvent::Churn(ChurnEvent {
                    at_step: i + 1,
                    device: i,
                    kind: ChurnKind::Down,
                })
            })
            .collect();
        let script = Script::from_events("kill-all", churn);
        let mut core = ExecutorCore::new(
            ChurnSensitive { dur: 0.5 },
            &cluster,
            &bw,
            &common(),
            &script,
        );
        let err = core.run_request(0.0, 1, d + 2).unwrap_err();
        assert_eq!(err.device, d - 1);
        assert_eq!(err.at_step, d);
        let msg = err.to_string();
        assert!(msg.contains("no surviving devices"), "got: {msg}");
    }

    #[test]
    fn repeated_down_events_are_idempotent() {
        use crate::adapt::ChurnEvent;
        let cluster = Cluster::env_e1();
        let bw = BandwidthTrace::fixed_mbps(100.0);
        let script = Script::from_events(
            "double-down",
            vec![
                crate::adapt::ScriptEvent::Churn(ChurnEvent {
                    at_step: 1,
                    device: 0,
                    kind: ChurnKind::Down,
                }),
                crate::adapt::ScriptEvent::Churn(ChurnEvent {
                    at_step: 2,
                    device: 0,
                    kind: ChurnKind::Down,
                }),
            ],
        );
        let mut core = ExecutorCore::new(
            ChurnSensitive { dur: 0.5 },
            &cluster,
            &bw,
            &common(),
            &script,
        );
        core.run_request(0.0, 1, 4).unwrap();
        // Two Down events → two recovery slots, both unrecovered.
        assert_eq!(core.state.survivors().len(), cluster.len() - 1);
        let totals = core.into_totals();
        assert_eq!(totals.recovery_steps.len(), 2);
    }

    #[test]
    fn dropping_step_times_keeps_the_sum_bit_identical() {
        let cluster = Cluster::env_e1();
        let bw = BandwidthTrace::fixed_mbps(100.0);
        let mut retained =
            ExecutorCore::new(jitter_policy(), &cluster, &bw, &common(), &Script::none());
        let mut flat = ExecutorCore::new(jitter_policy(), &cluster, &bw, &common(), &Script::none());
        flat.retain_step_times(false);
        let mut arena = CoreArena::new();
        let mut t = 0.0;
        for _ in 0..5 {
            let a = retained.run_request(t, 1, 6).unwrap();
            let b = flat.run_request_in(t, 1, 6, &mut arena).unwrap();
            assert_eq!(&a, b);
            t = a.finish();
        }
        let (a, b) = (retained.into_totals(), flat.into_totals());
        assert_eq!(a.step_times.len(), 30);
        assert!(b.step_times.is_empty(), "memory-flat mode retains nothing");
        assert_eq!(a.step_times.iter().sum::<f64>().to_bits(), a.step_time_sum.to_bits());
        assert_eq!(a.step_time_sum.to_bits(), b.step_time_sum.to_bits());
    }
}
