//! Traditional pipeline executor — GPipe-style stages, optionally with
//! naive model offloading (the paper's "Pipeline + offloading" baseline and
//! the strawman of Figs 3a / 4a).
//!
//! The two pathologies the paper motivates fall straight out of the
//! schedule shape:
//!
//! * **Incomplete loading-delay coverage** — all of a device's offloaded
//!   layers live inside its single stage, so their SSD loads serialize with
//!   the *device's own* compute at the point of use rather than hiding
//!   behind other devices' compute or communication.
//! * **Multiple loading delay** — the offload slot is reused within the
//!   stage, so a micro-batch pays the load every time it reaches an evicted
//!   layer, and the next micro-batch pays it again (no cross-segment reuse
//!   window like the interleaved schedule has).
//!
//! The schedule lives in [`TraditionalPolicy`]; the unified core
//! ([`crate::pipeline::core`]) owns resources, link-stall accounting,
//! scripted-event application and result assembly, which also gives this
//! baseline a scripted entry point ([`run_traditional_scripted`]) and a
//! continuous-serving path through `serve::simqueue` for free.

use crate::adapt::Script;
use crate::cluster::Cluster;
use crate::cost;
use crate::model::ModelSpec;
use crate::net::{link_transfer_secs, BandwidthTrace};
use crate::pipeline::core::{run_single, CommonOptions, CoreState, SchedulePolicy, StepCtx};
use crate::pipeline::result::SimResult;
use crate::plan::allocation::Allocation;
use crate::sim::{Label, MicroPhase, SpanKind, TraceMode};

/// Options for the traditional executor: the policy-specific knob plus the
/// [`CommonOptions`] fields (converted via `From<&TradOptions>`).
#[derive(Debug, Clone, Copy)]
pub struct TradOptions {
    pub prompt_tokens: usize,
    pub seed: u64,
    /// When memory saturates with no offload capability, baselines
    /// *recompute* evicted KV instead (paper §V-A). `true` enables that
    /// recompute fallback; `false` spills KV to SSD.
    pub recompute_fallback: bool,
    /// Span recording detail (never affects `SimResult` timing fields).
    pub trace_mode: TraceMode,
}

impl Default for TradOptions {
    fn default() -> Self {
        TradOptions {
            prompt_tokens: 64,
            seed: 0xBA5E,
            recompute_fallback: true,
            trace_mode: TraceMode::Full,
        }
    }
}

impl From<&TradOptions> for CommonOptions {
    fn from(o: &TradOptions) -> CommonOptions {
        CommonOptions {
            prompt_tokens: o.prompt_tokens,
            seed: o.seed,
            trace_mode: o.trace_mode,
        }
    }
}

/// Sweep entry point: every `(micro_batches, tokens)` scenario of the
/// traditional executor on the work-stealing pool, results in scenario
/// order (bit-identical to the sequential loop; nested-submission safe).
pub fn sweep_traditional(
    alloc: &Allocation,
    cluster: &Cluster,
    bw_trace: &BandwidthTrace,
    scenarios: &[(usize, usize)],
    opts: &TradOptions,
) -> Vec<SimResult> {
    crate::util::pool::map_indexed(scenarios, |&(micro_batches, tokens)| {
        run_traditional(alloc, cluster, bw_trace, micro_batches, tokens, opts)
    })
}

/// Simulate `tokens` decode steps of a traditional (single-stage-per-device)
/// pipeline under `alloc` (whose `seg` is ignored: one stage per device).
pub fn run_traditional(
    alloc: &Allocation,
    cluster: &Cluster,
    bw_trace: &BandwidthTrace,
    micro_batches: usize,
    tokens: usize,
    opts: &TradOptions,
) -> SimResult {
    run_traditional_scripted(
        alloc,
        cluster,
        bw_trace,
        micro_batches,
        tokens,
        opts,
        &Script::none(),
    )
}

/// [`run_traditional`] under a scripted joint fluctuation [`Script`]:
/// memory events shift the effective per-device caps the KV-overflow
/// fallback judges saturation against, bandwidth events scale the link
/// capacity. Baselines have no online planner, so memory pressure shows up
/// directly as recompute/spill work. An empty script is bit-identical to
/// [`run_traditional`].
pub fn run_traditional_scripted(
    alloc: &Allocation,
    cluster: &Cluster,
    bw_trace: &BandwidthTrace,
    micro_batches: usize,
    tokens: usize,
    opts: &TradOptions,
    script: &Script,
) -> SimResult {
    run_single(
        TraditionalPolicy::new(alloc, cluster, opts),
        cluster,
        bw_trace,
        micro_batches,
        tokens,
        &CommonOptions::from(opts),
        script,
    )
}

struct TradState {
    kv_held: Vec<usize>,
    /// Reused across steps — no per-step allocation in the decode loop.
    fronts: Vec<f64>,
}

/// The GPipe-style single-stage-per-device schedule as a
/// [`SchedulePolicy`].
pub struct TraditionalPolicy<'a> {
    alloc: &'a Allocation,
    cluster: &'a Cluster,
    spec: ModelSpec,
    opts: TradOptions,
    st: Option<TradState>,
}

impl<'a> TraditionalPolicy<'a> {
    pub fn new(alloc: &'a Allocation, cluster: &'a Cluster, opts: &TradOptions) -> Self {
        TraditionalPolicy {
            alloc,
            cluster,
            spec: alloc.spec.clone(),
            opts: *opts,
            st: None,
        }
    }

    /// Prefill charge for a `micro`-wide admission beginning at `at` (not
    /// measured). The traditional schedule has no cross-segment overlap
    /// window, so load and compute serialize. Pure time arithmetic — no
    /// per-request state touched, so the continuous driver may overlap it
    /// with an in-flight batch's decode.
    fn charge_prefill(&self, at: f64, micro: usize, bw0: f64) -> f64 {
        let mut t_prefill = at;
        for i in 0..self.cluster.len() {
            let a = &self.alloc.devices[i];
            let flops = self.spec.layer_prefill_flops(self.opts.prompt_tokens)
                * a.total_layers as f64
                * micro as f64;
            t_prefill += flops / self.cluster.devices[i].flops
                + cost::load_time(&self.spec, &self.cluster.devices[i], a)
                + link_transfer_secs(
                    self.spec.h_size(micro) * self.opts.prompt_tokens as u64,
                    bw0,
                );
        }
        t_prefill
    }

    fn install_state(&mut self, micro: usize) {
        self.st = Some(TradState {
            kv_held: vec![self.opts.prompt_tokens; self.cluster.len()],
            fronts: vec![0.0f64; micro],
        });
    }
}

impl SchedulePolicy for TraditionalPolicy<'_> {
    fn begin_request(
        &mut self,
        core: &mut CoreState,
        at: f64,
        micro: usize,
        global_step: usize,
    ) -> f64 {
        let bw0 = core.bw_at(global_step);
        let t_prefill = self.charge_prefill(at, micro, bw0);
        self.install_state(micro);
        t_prefill
    }

    fn prefill_end(
        &mut self,
        core: &mut CoreState,
        at: f64,
        micro: usize,
        global_step: usize,
    ) -> f64 {
        let bw0 = core.bw_at(global_step);
        self.charge_prefill(at, micro, bw0)
    }

    fn begin_batch(
        &mut self,
        _core: &mut CoreState,
        at: f64,
        micro: usize,
        _global_step: usize,
    ) -> f64 {
        // Prefill already charged through `prefill_end` during the
        // previous epoch's decode; just rebuild the per-batch state.
        self.install_state(micro);
        at
    }

    fn on_batch_resize(&mut self, _core: &mut CoreState, micro: usize) {
        // `step` fills `fronts` with the step start, so resizing is all a
        // width change needs.
        if let Some(st) = self.st.as_mut() {
            st.fronts.resize(micro, 0.0);
        }
    }

    fn step(&mut self, core: &mut CoreState, ctx: &StepCtx) -> f64 {
        let st = self.st.as_mut().expect("begin_request precedes step");
        let d = self.cluster.len();
        let micro = ctx.micro;
        let bw = core.bw_at(ctx.global_step);
        let tok = self.opts.prompt_tokens + ctx.local_step;
        let step_start = ctx.step_start;
        st.fronts.fill(step_start);

        for i in 0..d {
            let a = &self.alloc.devices[i];
            let res = a.non_offloaded_layers();
            let off = a.offloaded_count();

            for (m, front) in st.fronts.iter_mut().enumerate() {
                let label = |phase| Label::Micro { m: m as u32, phase };
                let hop = core.link_acquire(*front, link_transfer_secs(self.spec.h_size(1), bw));
                core.trace
                    .push(i, SpanKind::Comm, label(MicroPhase::Hop), hop.start, hop.end);
                let mut cursor = hop.end;

                // Resident layers compute first.
                let comp_res = cost::comp_time(&self.spec, &self.cluster.devices[i], res, tok, 1);
                let iv = core.gpus[i].acquire(cursor, comp_res);
                if comp_res > 0.0 {
                    core.trace.push(
                        i,
                        SpanKind::Compute,
                        label(MicroPhase::Resident),
                        iv.start,
                        iv.end,
                    );
                }
                cursor = iv.end;

                // Offloaded layers: load-then-compute *per micro-batch* —
                // the "multiple loading delay" pathology. Loads start only
                // when the micro-batch reaches them (no lookahead window).
                if off > 0 {
                    let bytes = a.load_bytes(&self.spec);
                    let load = core.ssds[i].read(cursor, bytes);
                    core.trace
                        .push(i, SpanKind::Load, label(MicroPhase::Load), load.start, load.end);
                    if load.end > cursor {
                        core.trace
                            .push(i, SpanKind::Stall, label(MicroPhase::Wait), cursor, load.end);
                    }
                    let comp_off =
                        cost::comp_time(&self.spec, &self.cluster.devices[i], off, tok, 1);
                    let iv2 = core.gpus[i].acquire(load.end, comp_off);
                    core.trace.push(
                        i,
                        SpanKind::Compute,
                        label(MicroPhase::Offloaded),
                        iv2.start,
                        iv2.end,
                    );
                    cursor = iv2.end;
                }
                *front = cursor;
            }
        }

        let mut step_end = st.fronts.iter().cloned().fold(step_start, f64::max);

        // KV growth + saturation fallback (judged against the scripted
        // effective caps). The core counts a step as an emergency step at
        // most once.
        for i in 0..d {
            st.kv_held[i] += micro;
            // Overflow grows with context: each step the evicted window is
            // whatever no longer fits (baselines have no adaptation).
            let overflow =
                cost::overflow_tokens_with_cap(self.alloc, i, tok * micro, 0, core.mem_caps[i])
                    .min(tok * micro);
            if overflow > 0 {
                core.mark_emergency();
                if self.opts.recompute_fallback {
                    // Recompute evicted KV: an extra prefill-shaped pass
                    // over the overflow window (paper §V-A baseline note).
                    let flops = self.spec.layer_prefill_flops(overflow)
                        * self.alloc.devices[i].total_layers as f64;
                    let t = flops / self.cluster.devices[i].flops;
                    let iv = core.gpus[i].acquire(step_end, t);
                    core.trace
                        .push(i, SpanKind::Compute, "recompute", iv.start, iv.end);
                    step_end = step_end.max(iv.end);
                } else {
                    let bytes = self.spec.kv_bytes_per_token_layer()
                        * self.alloc.devices[i].total_layers as u64
                        * overflow as u64;
                    let w = core.ssds[i].write(step_end, bytes);
                    let r = core.ssds[i].read(w.end, bytes);
                    core.trace.push(i, SpanKind::Store, "kv-spill", w.start, w.end);
                    core.trace.push(i, SpanKind::Load, "kv-fetch", r.start, r.end);
                    step_end = step_end.max(r.end);
                }
            }
        }

        step_end
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::ModelSpec;
    use crate::pipeline::interleaved::{run_interleaved, ExecOptions};
    use crate::plan::{plan, PlanOptions};
    use crate::util::bytes::mbps;

    fn lowmem() -> (Allocation, Cluster) {
        let spec = ModelSpec::llama33_70b();
        let cluster = Cluster::lowmem_setting1();
        let opts = PlanOptions {
            empirical_tokens: 256,
            micro_batch: 1,
            bandwidth: mbps(200.0),
        };
        (plan(&spec, &cluster, &opts).unwrap().allocation, cluster)
    }

    #[test]
    fn traditional_runs_and_progresses() {
        let (alloc, cluster) = lowmem();
        let bw = BandwidthTrace::fixed_mbps(200.0);
        let r = run_traditional(&alloc, &cluster, &bw, 1, 8, &TradOptions::default());
        assert_eq!(r.step_times.len(), 8);
        assert!(r.ms_per_token() > 0.0);
    }

    #[test]
    fn interleaved_beats_traditional_under_offload() {
        // The headline motivation (Figs 3-4): same allocation, same
        // hardware — the interleaved schedule hides loads the traditional
        // schedule cannot.
        let (alloc, cluster) = lowmem();
        let bw = BandwidthTrace::fixed_mbps(200.0);
        let lime = run_interleaved(&alloc, &cluster, &bw, 1, 12, &ExecOptions::default());
        let trad = run_traditional(&alloc, &cluster, &bw, 1, 12, &TradOptions::default());
        assert!(
            lime.ms_per_token() < trad.ms_per_token(),
            "interleaved {:.1} !< traditional {:.1}",
            lime.ms_per_token(),
            trad.ms_per_token()
        );
    }

    #[test]
    fn bursty_multiplies_loading_delay() {
        // "Multiple loading delay": per-micro-batch loads make the bursty
        // pattern scale badly for the traditional schedule.
        let (alloc, cluster) = lowmem();
        let bw = BandwidthTrace::fixed_mbps(200.0);
        let b1 = run_traditional(&alloc, &cluster, &bw, 1, 6, &TradOptions::default());
        let b4 = run_traditional(&alloc, &cluster, &bw, 4, 6, &TradOptions::default());
        // Per-token latency improves less than 4x (loads repeat per micro).
        assert!(b4.mean_step() > b1.mean_step());
    }

    #[test]
    fn scripted_squeeze_inflates_fallback_work() {
        // A hard squeeze on device 0 forces the overflow fallback earlier
        // than the unscripted run — the baseline now reacts to scripted
        // pressure through the shared core.
        use crate::adapt::MemScenario;
        let (alloc, cluster) = lowmem();
        let bw = BandwidthTrace::fixed_mbps(200.0);
        let opts = TradOptions {
            trace_mode: TraceMode::Off,
            ..TradOptions::default()
        };
        let plain = run_traditional(&alloc, &cluster, &bw, 1, 12, &opts);
        let squeezed = run_traditional_scripted(
            &alloc,
            &cluster,
            &bw,
            1,
            12,
            &opts,
            &Script::from_mem(MemScenario::squeeze(
                "sq",
                0,
                crate::util::bytes::gib(40.0),
                2,
            )),
        );
        assert!(
            squeezed.emergency_steps >= plain.emergency_steps,
            "squeeze {} !>= plain {}",
            squeezed.emergency_steps,
            plain.emergency_steps
        );
        assert!(squeezed.emergency_steps > 0, "a 40 GiB squeeze must overflow");
        // Empty script stays bit-identical.
        let empty = run_traditional_scripted(&alloc, &cluster, &bw, 1, 12, &opts, &Script::none());
        assert_eq!(empty.step_times, plain.step_times);
        assert_eq!(empty.total_time, plain.total_time);
    }
}
