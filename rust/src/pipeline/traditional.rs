//! Traditional pipeline executor — GPipe-style stages, optionally with
//! naive model offloading (the paper's "Pipeline + offloading" baseline and
//! the strawman of Figs 3a / 4a).
//!
//! The two pathologies the paper motivates fall straight out of the
//! schedule shape:
//!
//! * **Incomplete loading-delay coverage** — all of a device's offloaded
//!   layers live inside its single stage, so their SSD loads serialize with
//!   the *device's own* compute at the point of use rather than hiding
//!   behind other devices' compute or communication.
//! * **Multiple loading delay** — the offload slot is reused within the
//!   stage, so a micro-batch pays the load every time it reaches an evicted
//!   layer, and the next micro-batch pays it again (no cross-segment reuse
//!   window like the interleaved schedule has).

use crate::cluster::Cluster;
use crate::cost;
use crate::net::{link_transfer_secs, BandwidthTrace};
use crate::pipeline::result::SimResult;
use crate::plan::allocation::Allocation;
use crate::sim::{Label, MicroPhase, Resource, SpanKind, SsdModel, Trace, TraceMode};

/// Options for the traditional executor.
#[derive(Debug, Clone, Copy)]
pub struct TradOptions {
    pub prompt_tokens: usize,
    pub seed: u64,
    /// When memory saturates with no offload capability, baselines
    /// *recompute* evicted KV instead (paper §V-A). `true` enables that
    /// recompute fallback; `false` spills KV to SSD.
    pub recompute_fallback: bool,
    /// Span recording detail (never affects `SimResult` timing fields).
    pub trace_mode: TraceMode,
}

impl Default for TradOptions {
    fn default() -> Self {
        TradOptions {
            prompt_tokens: 64,
            seed: 0xBA5E,
            recompute_fallback: true,
            trace_mode: TraceMode::Full,
        }
    }
}

/// Sweep entry point: every `(micro_batches, tokens)` scenario of the
/// traditional executor on the work-stealing pool, results in scenario
/// order (bit-identical to the sequential loop; nested-submission safe).
pub fn sweep_traditional(
    alloc: &Allocation,
    cluster: &Cluster,
    bw_trace: &BandwidthTrace,
    scenarios: &[(usize, usize)],
    opts: &TradOptions,
) -> Vec<SimResult> {
    crate::util::pool::map_indexed(scenarios, |&(micro_batches, tokens)| {
        run_traditional(alloc, cluster, bw_trace, micro_batches, tokens, opts)
    })
}

/// Simulate `tokens` decode steps of a traditional (single-stage-per-device)
/// pipeline under `alloc` (whose `seg` is ignored: one stage per device).
pub fn run_traditional(
    alloc: &Allocation,
    cluster: &Cluster,
    bw_trace: &BandwidthTrace,
    micro_batches: usize,
    tokens: usize,
    opts: &TradOptions,
) -> SimResult {
    let spec = alloc.spec.clone();
    let d = cluster.len();
    let micro = micro_batches.max(1);

    let mut trace = Trace::with_mode(opts.trace_mode);
    let mut gpus: Vec<Resource> = (0..d).map(|_| Resource::new()).collect();
    let mut ssds: Vec<SsdModel> = (0..d)
        .map(|i| {
            SsdModel::new(
                cluster.devices[i].ssd_read_bps,
                cluster.devices[i].ssd_write_bps,
                opts.seed ^ (i as u64) << 8,
            )
        })
        .collect();
    let mut net = Resource::new();

    // Prefill charge (not measured).
    let bw0 = bw_trace.at(0);
    let mut t_prefill = 0.0;
    for i in 0..d {
        let a = &alloc.devices[i];
        let flops =
            spec.layer_prefill_flops(opts.prompt_tokens) * a.total_layers as f64 * micro as f64;
        t_prefill += flops / cluster.devices[i].flops
            + cost::load_time(&spec, &cluster.devices[i], a)
            + link_transfer_secs(spec.h_size(micro) * opts.prompt_tokens as u64, bw0);
    }
    let decode_start = t_prefill;

    let mut kv_held: Vec<usize> = vec![opts.prompt_tokens; d];
    let mut emergency_steps = 0usize;
    let mut bw_stalls: u64 = 0;
    let mut step_times = Vec::with_capacity(tokens);
    let mut t_prev = decode_start;
    // Reused across steps — no per-step allocation in the decode loop.
    let mut fronts = vec![0.0f64; micro];

    for step in 0..tokens {
        let bw = bw_trace.at(step);
        let ctx = opts.prompt_tokens + step;
        let step_start = t_prev;
        fronts.fill(step_start);

        for i in 0..d {
            let a = &alloc.devices[i];
            let res = a.non_offloaded_layers();
            let off = a.offloaded_count();

            for (m, front) in fronts.iter_mut().enumerate() {
                let label = |phase| Label::Micro { m: m as u32, phase };
                let hop = net.acquire(*front, link_transfer_secs(spec.h_size(1), bw));
                if hop.start > *front {
                    bw_stalls += 1;
                }
                trace.push(i, SpanKind::Comm, label(MicroPhase::Hop), hop.start, hop.end);
                let mut cursor = hop.end;

                // Resident layers compute first.
                let comp_res = cost::comp_time(&spec, &cluster.devices[i], res, ctx, 1);
                let iv = gpus[i].acquire(cursor, comp_res);
                if comp_res > 0.0 {
                    trace.push(
                        i,
                        SpanKind::Compute,
                        label(MicroPhase::Resident),
                        iv.start,
                        iv.end,
                    );
                }
                cursor = iv.end;

                // Offloaded layers: load-then-compute *per micro-batch* —
                // the "multiple loading delay" pathology. Loads start only
                // when the micro-batch reaches them (no lookahead window).
                if off > 0 {
                    let bytes = a.load_bytes(&spec);
                    let load = ssds[i].read(cursor, bytes);
                    trace.push(i, SpanKind::Load, label(MicroPhase::Load), load.start, load.end);
                    if load.end > cursor {
                        trace.push(i, SpanKind::Stall, label(MicroPhase::Wait), cursor, load.end);
                    }
                    let comp_off = cost::comp_time(&spec, &cluster.devices[i], off, ctx, 1);
                    let iv2 = gpus[i].acquire(load.end, comp_off);
                    trace.push(
                        i,
                        SpanKind::Compute,
                        label(MicroPhase::Offloaded),
                        iv2.start,
                        iv2.end,
                    );
                    cursor = iv2.end;
                }
                *front = cursor;
            }
        }

        let mut step_end = fronts.iter().cloned().fold(step_start, f64::max);

        // KV growth + saturation fallback. As in the interleaved executor,
        // a step counts as an emergency step at most once.
        let mut emergency_this_step = false;
        for i in 0..d {
            kv_held[i] += micro;
            // Overflow grows with context: each step the evicted window is
            // whatever no longer fits (baselines have no adaptation).
            let overflow = cost::overflow_tokens(alloc, cluster, i, ctx * micro, 0).min(ctx * micro);
            if overflow > 0 {
                emergency_this_step = true;
                if opts.recompute_fallback {
                    // Recompute evicted KV: an extra prefill-shaped pass
                    // over the overflow window (paper §V-A baseline note).
                    let flops = spec.layer_prefill_flops(overflow)
                        * alloc.devices[i].total_layers as f64;
                    let t = flops / cluster.devices[i].flops;
                    let iv = gpus[i].acquire(step_end, t);
                    trace.push(i, SpanKind::Compute, "recompute", iv.start, iv.end);
                    step_end = step_end.max(iv.end);
                } else {
                    let bytes = spec.kv_bytes_per_token_layer()
                        * alloc.devices[i].total_layers as u64
                        * overflow as u64;
                    let w = ssds[i].write(step_end, bytes);
                    let r = ssds[i].read(w.end, bytes);
                    trace.push(i, SpanKind::Store, "kv-spill", w.start, w.end);
                    trace.push(i, SpanKind::Load, "kv-fetch", r.start, r.end);
                    step_end = step_end.max(r.end);
                }
            }
        }
        if emergency_this_step {
            emergency_steps += 1;
        }

        step_times.push(step_end - step_start);
        t_prev = step_end;
    }

    SimResult {
        tokens,
        micro_batches: micro,
        total_time: t_prev - decode_start,
        step_times,
        trace,
        kv_tokens_transferred: 0,
        online_plans_fired: 0,
        emergency_steps,
        bw_stalls,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::ModelSpec;
    use crate::pipeline::interleaved::{run_interleaved, ExecOptions};
    use crate::plan::{plan, PlanOptions};
    use crate::util::bytes::mbps;

    fn lowmem() -> (Allocation, Cluster) {
        let spec = ModelSpec::llama33_70b();
        let cluster = Cluster::lowmem_setting1();
        let opts = PlanOptions {
            empirical_tokens: 256,
            micro_batch: 1,
            bandwidth: mbps(200.0),
        };
        (plan(&spec, &cluster, &opts).unwrap().allocation, cluster)
    }

    #[test]
    fn traditional_runs_and_progresses() {
        let (alloc, cluster) = lowmem();
        let bw = BandwidthTrace::fixed_mbps(200.0);
        let r = run_traditional(&alloc, &cluster, &bw, 1, 8, &TradOptions::default());
        assert_eq!(r.step_times.len(), 8);
        assert!(r.ms_per_token() > 0.0);
    }

    #[test]
    fn interleaved_beats_traditional_under_offload() {
        // The headline motivation (Figs 3-4): same allocation, same
        // hardware — the interleaved schedule hides loads the traditional
        // schedule cannot.
        let (alloc, cluster) = lowmem();
        let bw = BandwidthTrace::fixed_mbps(200.0);
        let lime = run_interleaved(&alloc, &cluster, &bw, 1, 12, &ExecOptions::default());
        let trad = run_traditional(&alloc, &cluster, &bw, 1, 12, &TradOptions::default());
        assert!(
            lime.ms_per_token() < trad.ms_per_token(),
            "interleaved {:.1} !< traditional {:.1}",
            lime.ms_per_token(),
            trad.ms_per_token()
        );
    }

    #[test]
    fn bursty_multiplies_loading_delay() {
        // "Multiple loading delay": per-micro-batch loads make the bursty
        // pattern scale badly for the traditional schedule.
        let (alloc, cluster) = lowmem();
        let bw = BandwidthTrace::fixed_mbps(200.0);
        let b1 = run_traditional(&alloc, &cluster, &bw, 1, 6, &TradOptions::default());
        let b4 = run_traditional(&alloc, &cluster, &bw, 4, 6, &TradOptions::default());
        // Per-token latency improves less than 4x (loads repeat per micro).
        assert!(b4.mean_step() > b1.mean_step());
    }
}
