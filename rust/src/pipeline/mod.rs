//! Pipeline executors over the discrete-event substrate: LIME's interleaved
//! schedule (§IV-A), the traditional PP(+offload) schedule (Figs 3a/4a),
//! and the tensor-parallel family used by the TP baselines.

pub mod interleaved;
pub mod result;
pub mod tensor;
pub mod traditional;

pub use interleaved::{
    run_interleaved, run_interleaved_scripted, sweep_interleaved, ExecOptions, PlannerMode,
};
pub use result::SimResult;
pub use tensor::{run_tensor_parallel, sweep_tensor_parallel, TpOptions};
pub use traditional::{run_traditional, sweep_traditional, TradOptions};
