//! Pipeline executors over the discrete-event substrate: LIME's interleaved
//! schedule (§IV-A), the traditional PP(+offload) schedule (Figs 3a/4a),
//! and the tensor-parallel family used by the TP baselines.
//!
//! All three are [`SchedulePolicy`] impls driven by the unified executor
//! core ([`crate::pipeline::core`]): the core owns the shared mechanics
//! (resources, link-stall accounting, scripted fluctuation application on
//! the stream timeline, emergency-step counting, `SimResult` assembly),
//! each policy owns only its schedule-specific decisions. The `run_*`
//! entry points are single-request streams over the core;
//! `serve::simqueue` drives the same policies continuously over queued
//! request streams.

pub mod core;
pub mod interleaved;
pub mod result;
pub mod tensor;
pub mod traditional;

pub use self::core::{
    run_single_checked, ChurnCtx, ChurnError, CommonOptions, CoreArena, CoreState, ExecutorCore,
    RequestRun, SchedulePolicy, StepCtx,
};
pub use interleaved::{
    run_interleaved, run_interleaved_scripted, sweep_interleaved, ExecOptions, InterleavedPolicy,
    PlannerMode,
};
pub use result::SimResult;
pub use tensor::{
    run_tensor_parallel, run_tensor_parallel_scripted, sweep_tensor_parallel, TensorParallelPolicy,
    TpOptions,
};
pub use traditional::{
    run_traditional, run_traditional_scripted, sweep_traditional, TradOptions, TraditionalPolicy,
};
