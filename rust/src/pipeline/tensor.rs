//! Tensor-parallel executor family — the simulation substrate behind the
//! Galaxy, TPI-LLM and TPI-LLM+offloading baselines.
//!
//! Every device holds a `1/|D|` shard of *every* layer and computes each
//! layer cooperatively; each layer costs two collective synchronizations
//! (attention output + MLP output, Megatron-style). On edge LANs the
//! collectives dominate — the paper's §III motivation for preferring PP.
//!
//! Variants:
//! * `seq_parallel` (Galaxy): overlapped sequence-parallel collectives —
//!   a fraction of the communication hides behind compute.
//! * `sliding_window` (TPI-LLM): shards stream from SSD through a sliding
//!   window, so devices below shard size still run; loading serializes
//!   with compute when the window stalls.
//!
//! The schedule lives in [`TensorParallelPolicy`], driven by the unified
//! core ([`crate::pipeline::core`]) — which also gives the TP family a
//! scripted entry point ([`run_tensor_parallel_scripted`]; KV overflow is
//! judged against the scripted effective caps) and a continuous-serving
//! path through `serve::simqueue`.

use crate::adapt::Script;
use crate::cluster::Cluster;
use crate::cost;
use crate::model::ModelSpec;
use crate::net::{link_transfer_secs, BandwidthTrace};
use crate::pipeline::core::{run_single, CommonOptions, CoreState, SchedulePolicy, StepCtx};
use crate::pipeline::result::SimResult;
use crate::sim::{Label, SpanKind, TraceMode};

/// Tensor-parallel baseline options: the policy-specific knobs plus the
/// [`CommonOptions`] fields (converted via `From<&TpOptions>`).
#[derive(Debug, Clone, Copy)]
pub struct TpOptions {
    pub prompt_tokens: usize,
    pub seed: u64,
    /// Galaxy-style sequence-parallel overlap factor: fraction of collective
    /// time hidden behind compute (0 = none, Galaxy ≈ 0.3).
    pub comm_overlap: f64,
    /// TPI-LLM sliding-window weight streaming from SSD.
    pub sliding_window: bool,
    /// Extra window slack for "TPI-LLM + offloading" (larger window instead
    /// of recomputation for KV overflow).
    pub offload_kv: bool,
    /// Per-collective software overhead (seconds): barrier + framework
    /// costs of a TCP/gloo-style all-reduce on edge boards, paid once per
    /// sync on top of wire time. Measured gloo all-reduces on LAN are
    /// ms-scale even for tiny payloads.
    pub sync_overhead: f64,
    /// Span recording detail (never affects `SimResult` timing fields).
    pub trace_mode: TraceMode,
}

impl Default for TpOptions {
    fn default() -> Self {
        TpOptions {
            prompt_tokens: 64,
            seed: 0x7E4,
            comm_overlap: 0.0,
            sliding_window: false,
            offload_kv: false,
            sync_overhead: 1.5e-3,
            trace_mode: TraceMode::Full,
        }
    }
}

impl From<&TpOptions> for CommonOptions {
    fn from(o: &TpOptions) -> CommonOptions {
        CommonOptions {
            prompt_tokens: o.prompt_tokens,
            seed: o.seed,
            trace_mode: o.trace_mode,
        }
    }
}

/// Sweep entry point: every `(micro_batches, tokens)` scenario of the
/// tensor-parallel executor on the work-stealing pool, results in scenario
/// order (bit-identical to the sequential loop; nested-submission safe).
pub fn sweep_tensor_parallel(
    spec: &ModelSpec,
    cluster: &Cluster,
    bw_trace: &BandwidthTrace,
    scenarios: &[(usize, usize)],
    opts: &TpOptions,
) -> Vec<SimResult> {
    crate::util::pool::map_indexed(scenarios, |&(micro_batches, tokens)| {
        run_tensor_parallel(spec, cluster, bw_trace, micro_batches, tokens, opts)
    })
}

/// Simulate `tokens` decode steps of tensor-parallel inference.
pub fn run_tensor_parallel(
    spec: &ModelSpec,
    cluster: &Cluster,
    bw_trace: &BandwidthTrace,
    micro_batches: usize,
    tokens: usize,
    opts: &TpOptions,
) -> SimResult {
    run_tensor_parallel_scripted(
        spec,
        cluster,
        bw_trace,
        micro_batches,
        tokens,
        opts,
        &Script::none(),
    )
}

/// [`run_tensor_parallel`] under a scripted joint fluctuation [`Script`]:
/// memory events shift the effective caps the KV-overflow handling judges
/// saturation against, bandwidth events scale every collective round. An
/// empty script is bit-identical to [`run_tensor_parallel`].
pub fn run_tensor_parallel_scripted(
    spec: &ModelSpec,
    cluster: &Cluster,
    bw_trace: &BandwidthTrace,
    micro_batches: usize,
    tokens: usize,
    opts: &TpOptions,
    script: &Script,
) -> SimResult {
    run_single(
        TensorParallelPolicy::new(spec, cluster, opts),
        cluster,
        bw_trace,
        micro_batches,
        tokens,
        &CommonOptions::from(opts),
        script,
    )
}

/// Per-request state (the only pieces that vary with the admitted batch
/// size; the shard geometry is batch-independent and lives on the
/// policy).
struct TpState {
    round_bytes: u64,
}

/// The Megatron-style tensor-parallel schedule as a [`SchedulePolicy`].
pub struct TensorParallelPolicy<'a> {
    spec: &'a ModelSpec,
    cluster: &'a Cluster,
    opts: TpOptions,
    /// Per-device shard fractions (by usable memory, heterogeneous).
    frac: Vec<f64>,
    /// Streaming need per pass (sliding window): shard bytes that exceed
    /// the window resident in memory.
    stream_bytes: Vec<u64>,
    /// Serialized wire rounds per all-reduce: 2(d−1).
    sync_rounds: usize,
    st: Option<TpState>,
}

impl<'a> TensorParallelPolicy<'a> {
    pub fn new(spec: &'a ModelSpec, cluster: &'a Cluster, opts: &TpOptions) -> Self {
        let d = cluster.len();
        // Per-device shard: Galaxy/TPI-LLM partition workload by device
        // capability, so shard fractions follow usable memory
        // (heterogeneous), not 1/d. Window sizing is a deployment-time
        // decision, so it uses the nominal capacities — scripted pressure
        // only moves the KV-overflow judgement in `step`.
        let total_usable: f64 = cluster.devices.iter().map(|x| x.usable_mem() as f64).sum();
        let frac: Vec<f64> = (0..d)
            .map(|i| cluster.devices[i].usable_mem() as f64 / total_usable)
            .collect();
        let stream_bytes: Vec<u64> = (0..d)
            .map(|i| {
                if !opts.sliding_window {
                    return 0;
                }
                let total_shard =
                    (spec.layer_bytes() as f64 * spec.layers as f64 * frac[i]) as u64
                        + (spec.embed_bytes() as f64 * frac[i]) as u64;
                let window = cluster.devices[i].usable_mem() * 7 / 10;
                total_shard.saturating_sub(window)
            })
            .collect();
        TensorParallelPolicy {
            spec,
            cluster,
            opts: *opts,
            frac,
            stream_bytes,
            // One all-reduce = 2(d−1) serialized rounds on the shared
            // medium (reduce-scatter + all-gather), each moving the full
            // activation payload across the switch and paying the
            // per-message latency floor — this latency amplification is
            // why TP hurts on edge LANs (§III).
            sync_rounds: 2 * (d.max(2) - 1),
            st: None,
        }
    }
}

impl SchedulePolicy for TensorParallelPolicy<'_> {
    fn begin_request(
        &mut self,
        _core: &mut CoreState,
        at: f64,
        micro: usize,
        _global_step: usize,
    ) -> f64 {
        self.st = Some(TpState {
            round_bytes: self.spec.h_size(micro),
        });
        // TP charges no pipeline prefill pass: decoding starts immediately.
        // (The default `prefill_end`/`begin_batch` hooks are therefore
        // exactly right for this policy: prefill-ahead charges nothing and
        // a batch epoch just reinstalls the state above.)
        at
    }

    fn on_batch_resize(&mut self, _core: &mut CoreState, micro: usize) {
        // The collective payload scales with the live batch width.
        if let Some(st) = self.st.as_mut() {
            st.round_bytes = self.spec.h_size(micro);
        }
    }

    fn step(&mut self, core: &mut CoreState, ctx: &StepCtx) -> f64 {
        let st = self.st.as_ref().expect("begin_request precedes step");
        let d = self.cluster.len();
        let micro = ctx.micro;
        let bw = core.bw_at(ctx.global_step);
        let tok = self.opts.prompt_tokens + ctx.local_step;
        let step_start = ctx.step_start;

        // Compute: every device works on every layer's shard; the step is
        // paced by the slowest device (synchronous TP).
        let comp_slowest = (0..d)
            .map(|i| {
                let full = cost::comp_time(
                    self.spec,
                    &self.cluster.devices[i],
                    self.spec.layers,
                    tok,
                    micro,
                );
                full * self.frac[i]
            })
            .fold(0.0f64, f64::max);

        // Collectives: 2 syncs per layer, each 2(d−1) serialized rounds on
        // the wire plus a per-sync software overhead (barrier + framework).
        let mut comm_total = 0.0;
        for _ in 0..(2 * self.spec.layers * self.sync_rounds) {
            let at = step_start + comm_total;
            let iv = core.link_acquire(at, link_transfer_secs(st.round_bytes, bw));
            comm_total = iv.end - step_start;
        }
        comm_total += 2.0 * self.spec.layers as f64 * self.opts.sync_overhead;
        core.trace.push(
            0,
            SpanKind::Comm,
            Label::Step {
                tag: "sync",
                step: ctx.global_step as u32,
            },
            step_start,
            step_start + comm_total,
        );
        let comm_visible = comm_total * (1.0 - self.opts.comm_overlap);

        // Sliding-window streaming: overlaps with compute+comm, pays the
        // uncovered remainder (slowest device).
        let mut load_uncovered = 0.0f64;
        for i in 0..d {
            if self.stream_bytes[i] == 0 {
                continue;
            }
            let iv = core.ssds[i].read(step_start, self.stream_bytes[i]);
            core.trace.push(
                i,
                SpanKind::Load,
                Label::Step {
                    tag: "w",
                    step: ctx.global_step as u32,
                },
                iv.start,
                iv.end,
            );
            let load = iv.end - step_start;
            load_uncovered = load_uncovered.max((load - comp_slowest - comm_visible).max(0.0));
        }

        let mut step_end = step_start + comp_slowest + comm_visible + load_uncovered;
        core.trace.push(
            0,
            SpanKind::Compute,
            Label::Step {
                tag: "tp",
                step: ctx.global_step as u32,
            },
            step_start + comm_visible,
            step_start + comm_visible + comp_slowest,
        );

        // KV overflow handling, judged against the (possibly scripted)
        // effective caps.
        let kv_bytes_i = |i: usize| {
            (self.spec.kv_bytes_per_token_layer() as f64 * self.frac[i]) as u64
                * self.spec.layers as u64
                * (tok * micro) as u64
                + (self.spec.layer_bytes() as f64 * self.spec.layers as f64 * self.frac[i]) as u64
                    * u64::from(self.stream_bytes[i] == 0)
        };
        // As in the pipeline executors, the core counts one step at most
        // once.
        for i in 0..d {
            let over_bytes = kv_bytes_i(i).saturating_sub(core.mem_caps[i]);
            if over_bytes > 0 {
                core.mark_emergency();
                let kv_tok = ((self.spec.kv_bytes_per_token_layer() as f64 * self.frac[i]) as u64
                    * self.spec.layers as u64)
                    .max(1);
                let overflow = (over_bytes.div_ceil(kv_tok) as usize).min(tok * micro);
                if self.opts.offload_kv {
                    // Larger sliding window: stream the overflow through SSD.
                    let bytes = kv_tok * overflow as u64;
                    let w = core.ssds[i].write(step_end, bytes);
                    let r = core.ssds[i].read(w.end, bytes);
                    core.trace.push(i, SpanKind::Store, "kv-window", w.start, w.end);
                    step_end = step_end.max(r.end);
                } else {
                    // Recompute evicted KV (paper §V-A fallback).
                    let flops = self.spec.layer_prefill_flops(overflow)
                        * self.spec.layers as f64
                        * self.frac[i];
                    step_end += flops / self.cluster.devices[i].flops;
                }
            }
        }

        step_end
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn tp_runs() {
        let spec = ModelSpec::qwen3_32b();
        let cluster = Cluster::env_e2();
        let bw = BandwidthTrace::fixed_mbps(200.0);
        let r = run_tensor_parallel(&spec, &cluster, &bw, 1, 8, &TpOptions::default());
        assert_eq!(r.step_times.len(), 8);
        assert!(r.ms_per_token() > 0.0);
    }

    #[test]
    fn tp_suffers_at_low_bandwidth() {
        let spec = ModelSpec::qwen3_32b();
        let cluster = Cluster::env_e2();
        let hi = run_tensor_parallel(
            &spec,
            &cluster,
            &BandwidthTrace::fixed_mbps(200.0),
            4,
            8,
            &TpOptions::default(),
        );
        let lo = run_tensor_parallel(
            &spec,
            &cluster,
            &BandwidthTrace::fixed_mbps(100.0),
            4,
            8,
            &TpOptions::default(),
        );
        // Per-layer collectives make TP markedly bandwidth-sensitive in the
        // bursty pattern (bigger activation payloads).
        assert!(
            lo.ms_per_token() > 1.2 * hi.ms_per_token(),
            "lo {:.1} vs hi {:.1}",
            lo.ms_per_token(),
            hi.ms_per_token()
        );
    }

    #[test]
    fn seq_parallel_overlap_helps() {
        let spec = ModelSpec::qwen3_32b();
        let cluster = Cluster::env_e2();
        let bw = BandwidthTrace::fixed_mbps(100.0);
        let plain = run_tensor_parallel(&spec, &cluster, &bw, 1, 8, &TpOptions::default());
        let galaxy = run_tensor_parallel(
            &spec,
            &cluster,
            &bw,
            1,
            8,
            &TpOptions {
                comm_overlap: 0.3,
                ..TpOptions::default()
            },
        );
        assert!(galaxy.ms_per_token() < plain.ms_per_token());
    }

    #[test]
    fn sliding_window_pays_streaming() {
        let spec = ModelSpec::llama33_70b();
        let cluster = Cluster::lowmem_setting1();
        let bw = BandwidthTrace::fixed_mbps(200.0);
        let window = run_tensor_parallel(
            &spec,
            &cluster,
            &bw,
            1,
            4,
            &TpOptions {
                sliding_window: true,
                ..TpOptions::default()
            },
        );
        let no_window = run_tensor_parallel(&spec, &cluster, &bw, 1, 4, &TpOptions::default());
        assert!(window.ms_per_token() >= no_window.ms_per_token());
    }
}
