//! Simulation outcome shared by the LIME executor and all baselines.

use crate::sim::Trace;

/// Result of simulating a full generation run.
#[derive(Debug, Clone)]
pub struct SimResult {
    /// Decode steps simulated.
    pub tokens: usize,
    /// Micro-batches in flight (1 sporadic, |D| bursty).
    pub micro_batches: usize,
    /// Wall-clock seconds from decode start to last token.
    pub total_time: f64,
    /// Per-step completion latency (seconds per decode step).
    pub step_times: Vec<f64>,
    /// Device/time activity for Gantt rendering + overlap accounting.
    pub trace: Trace,
    /// KV tokens shipped between devices by the transfer protocol.
    pub kv_tokens_transferred: u64,
    /// Online offload plans fired.
    pub online_plans_fired: usize,
    /// Steps that needed the emergency KV-to-SSD fallback.
    pub emergency_steps: usize,
    /// Link acquisitions (activation hops, KV shipments, collective
    /// rounds) that had to wait on the busy shared medium. Observational:
    /// the count never feeds back into timing, it surfaces link
    /// contention — which scripted bandwidth sags inflate — in sweep
    /// artifacts.
    pub bw_stalls: u64,
    /// Churn-triggered re-plans (Down re-plan onto survivors + Up
    /// re-expansion) fired by the policy.
    pub replans_fired: usize,
    /// KV bytes migrated off departing / onto rejoining devices over the
    /// shared link (Eq. 8 volume model — migration traffic contends, so
    /// `bw_stalls` sees it).
    pub kv_migrated_bytes: u64,
    /// Per-`Down`-event recovery latency in decode steps (firing order):
    /// steps until step latency returns within tolerance of the
    /// pre-fault mean, `None` when the run ends still degraded.
    pub recovery_steps: Vec<Option<usize>>,
    /// KV pages handed out by the paged allocator (`serve::kvpages`) —
    /// cumulative over the run. Zero on runs that model KV as contiguous
    /// preallocation (every single-request run and the FIFO serving path).
    pub kv_pages_allocated: u64,
    /// KV pages spilled to SSD when the page budget ran dry, costed
    /// through the Eq. 8 volume model. Zero without paged accounting.
    pub kv_pages_spilled: u64,
    /// Peak internal fragmentation of the paged allocator:
    /// max over steps of `1 − used_tokens / (pages_held × page_tokens)`.
    /// 0.0 without paged accounting.
    pub kv_fragmentation: f64,
}

impl SimResult {
    /// The paper's headline metric. For bursty runs the batch dimension
    /// divides through: milliseconds per *generated token*.
    pub fn ms_per_token(&self) -> f64 {
        self.total_time * 1e3 / (self.tokens.max(1) * self.micro_batches.max(1)) as f64
    }

    /// Mean step latency in seconds.
    pub fn mean_step(&self) -> f64 {
        if self.step_times.is_empty() {
            0.0
        } else {
            self.step_times.iter().sum::<f64>() / self.step_times.len() as f64
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ms_per_token_divides_batch() {
        let r = SimResult {
            tokens: 10,
            micro_batches: 4,
            total_time: 2.0,
            step_times: vec![0.2; 10],
            trace: Trace::new(),
            kv_tokens_transferred: 0,
            online_plans_fired: 0,
            emergency_steps: 0,
            bw_stalls: 0,
            replans_fired: 0,
            kv_migrated_bytes: 0,
            recovery_steps: Vec::new(),
            kv_pages_allocated: 0,
            kv_pages_spilled: 0,
            kv_fragmentation: 0.0,
        };
        assert!((r.ms_per_token() - 50.0).abs() < 1e-9);
        assert!((r.mean_step() - 0.2).abs() < 1e-12);
    }
}
