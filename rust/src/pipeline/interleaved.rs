//! The interleaved pipeline executor — LIME's §IV-A schedule, simulated.
//!
//! Every device hosts one stage of *every* segment; a micro-batch traverses
//! `#Seg × |D|` stages per decode step. Offloaded layers stream from SSD
//! with cross-segment overlap: the load for segment `s+1` starts the moment
//! the slot frees (last micro-batch finishes segment `s` on that device) and
//! only gates the *offloaded fraction* of stage `s+1`'s compute — the
//! resident fraction, other devices' compute, and activation hops all run
//! underneath it. That is exactly the overlap structure the Eq. 1 cost
//! model scores, and `rust/tests/` cross-checks the two.
//!
//! The schedule-specific logic lives in [`InterleavedPolicy`], an impl of
//! [`SchedulePolicy`] driven by the unified executor core
//! ([`crate::pipeline::core`]) — the core owns the shared mechanics
//! (resources, link-stall accounting, scripted-event application,
//! emergency-step counting, result assembly). The policy also drives the
//! §IV-D machinery between steps: the online memory-aware planner (KV
//! pressure → block-granular offload plans, with one-time reload charges
//! when plans swap blocks, Fig. 9) and the bandwidth-sensitive KV transfer
//! protocol (Alg. 2). Both can be disabled independently for the Table V
//! ablations. [`run_interleaved_scripted`] additionally consumes a joint
//! fluctuation [`Script`]: scripted memory events shift effective
//! per-device caps and the planner's thresholds mid-run, and scripted
//! bandwidth events scale the link capacity every comm term (and Alg. 2's
//! monitor) sees — both channels in one run.

use crate::adapt::{
    resident_kv_bytes, ChurnEvent, ChurnKind, KvTransferProtocol, MemEvent, OffloadPlan,
    OnlinePlanner, Script,
};
use crate::cluster::Cluster;
use crate::cost;
use crate::model::ModelSpec;
use crate::net::link_transfer_secs;
use crate::net::BandwidthTrace;
use crate::pipeline::core::{
    run_single, ChurnCtx, CommonOptions, CoreState, SchedulePolicy, StepCtx,
};
use crate::pipeline::result::SimResult;
use crate::plan::allocation::{Allocation, DeviceAssignment};
use crate::plan::{plan, PlanOptions};
use crate::sim::{Label, MicroPhase, SpanKind, TraceMode};

/// Online-adaptation configuration (Table V ablation axes).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum PlannerMode {
    /// Full LIME: fine-grained (MHA/MLP block) online plans.
    FineGrained,
    /// Ablation "LIME without memory-aware planner": full-layer offloading
    /// only (the paper's substitute strategy).
    FullLayer,
    /// No reaction to KV pressure at all (falls back to emergency KV-to-SSD
    /// swapping when memory saturates).
    Off,
}

/// Executor options: the policy-specific knobs plus the [`CommonOptions`]
/// fields every executor shares (converted via `From<&ExecOptions>`).
#[derive(Debug, Clone, Copy)]
pub struct ExecOptions {
    pub planner: PlannerMode,
    pub kv_transfer: bool,
    /// Prompt length charged as a prefill pass before decoding.
    pub prompt_tokens: usize,
    /// RNG seed for the SSD write-jitter streams.
    pub seed: u64,
    /// Span recording detail. `Full` (the default) is needed for Gantt
    /// rendering and `Trace::uncovered_load`; experiment sweeps run `Off`.
    /// The mode never changes any `SimResult` timing field.
    pub trace_mode: TraceMode,
}

impl Default for ExecOptions {
    fn default() -> Self {
        ExecOptions {
            planner: PlannerMode::FineGrained,
            kv_transfer: true,
            prompt_tokens: 64,
            seed: 0xC0FFEE,
            trace_mode: TraceMode::Full,
        }
    }
}

impl From<&ExecOptions> for CommonOptions {
    fn from(o: &ExecOptions) -> CommonOptions {
        CommonOptions {
            prompt_tokens: o.prompt_tokens,
            seed: o.seed,
            trace_mode: o.trace_mode,
        }
    }
}

/// Max KV tokens shipped per device per step (pacing, Alg. 2 line 2).
const KV_SHIP_CAP: usize = 16;

/// Sweep entry point: run every `(micro_batches, tokens)` scenario of the
/// interleaved executor on the persistent work-stealing pool, results in
/// scenario order (bit-identical to the sequential loop at any worker
/// count; nested-submission safe, so harness grids may call this from
/// inside a pool job). Sweeps usually pass `TraceMode::Off` (or
/// `Aggregate` when they need `uncovered_load`) in `opts`.
pub fn sweep_interleaved(
    alloc: &Allocation,
    cluster: &Cluster,
    bw_trace: &BandwidthTrace,
    scenarios: &[(usize, usize)],
    opts: &ExecOptions,
) -> Vec<SimResult> {
    crate::util::pool::map_indexed(scenarios, |&(micro_batches, tokens)| {
        run_interleaved(alloc, cluster, bw_trace, micro_batches, tokens, opts)
    })
}

/// Simulate `tokens` decode steps of the interleaved pipeline.
///
/// `micro_batches` = 1 reproduces the sporadic pattern, `|D|` the bursty
/// pattern (paper §V-A: micro-batch size 1, count = device count).
pub fn run_interleaved(
    alloc: &Allocation,
    cluster: &Cluster,
    bw_trace: &BandwidthTrace,
    micro_batches: usize,
    tokens: usize,
    opts: &ExecOptions,
) -> SimResult {
    run_interleaved_scripted(
        alloc,
        cluster,
        bw_trace,
        micro_batches,
        tokens,
        opts,
        &Script::none(),
    )
}

/// [`run_interleaved`] under a scripted joint fluctuation [`Script`],
/// both channels applied before each decode step:
///
/// * each memory event shifts one device's *effective* usable memory and
///   simultaneously shifts the online planner's slack
///   (`OnlinePlanner::apply_pressure`) so offload thresholds move with
///   the pressure; the emergency KV-spill fallback and the `FullLayer`
///   ablation judge saturation against the same shifted caps;
/// * bandwidth events scale the link capacity the run sees (activation
///   hops, KV shipments, Alg. 2's bandwidth monitor — the Eq. 2 comm
///   terms all react) via [`BandwidthTrace::overlay_scales`].
///
/// An empty script is bit-identical to [`run_interleaved`]
/// (property-tested in `rust/tests/adapt_online.rs`).
pub fn run_interleaved_scripted(
    alloc: &Allocation,
    cluster: &Cluster,
    bw_trace: &BandwidthTrace,
    micro_batches: usize,
    tokens: usize,
    opts: &ExecOptions,
    script: &Script,
) -> SimResult {
    run_single(
        InterleavedPolicy::new(alloc, cluster, opts),
        cluster,
        bw_trace,
        micro_batches,
        tokens,
        &CommonOptions::from(opts),
        script,
    )
}

/// Per-request state of the interleaved schedule: rebuilt by
/// `begin_request` so continuous serving starts every request with a fresh
/// KV context and the offline allocation (scripted pressure accumulated on
/// the stream carries over via `CoreState::mem_pressure`).
struct ReqState {
    planner: OnlinePlanner,
    protocol: KvTransferProtocol,
    /// Current working allocation (online plans mutate offload sets).
    live: Allocation,
    last_plan: Vec<OffloadPlan>,
    /// KV tokens physically held per device (per micro-batch context).
    kv_held: Vec<usize>,
    /// One-time reload bytes queued for the next step's segment-0 load.
    pending_reload: Vec<u64>,
    /// When device i's offload slot last freed (gates the next segment's
    /// SSD load).
    slot_free: Vec<f64>,
    /// Completion time of (micro m, previous stage) within the current
    /// step. Reused across steps — the decode loop allocates nothing per
    /// span.
    micro_front: Vec<f64>,
}

/// LIME's interleaved schedule as a [`SchedulePolicy`].
pub struct InterleavedPolicy<'a> {
    alloc: &'a Allocation,
    cluster: &'a Cluster,
    spec: ModelSpec,
    seg: usize,
    opts: ExecOptions,
    st: Option<ReqState>,
    kv_shipped_total: u64,
    plans_fired: usize,
    /// Churn overlay: the current re-planned allocation, full cluster
    /// length with 0-layer entries for down devices. `None` (no churn has
    /// fired, or the full fleet is restored) means the offline allocation
    /// rules — so churn-free runs never touch this path.
    churn_alloc: Option<Allocation>,
    replans: usize,
    migrated_bytes: u64,
    /// Per-active-slot `(prompt_len, completed_steps)` installed by the
    /// serving driver through [`SchedulePolicy::set_slot_lengths`]. Empty
    /// — every non-serving entry point — means "use the global
    /// `prompt_tokens` knob", reproducing the pre-mix arithmetic bit for
    /// bit; homogeneous installed slots take the same fast paths with the
    /// shared per-request value.
    slot_lens: Vec<(usize, usize)>,
}

impl<'a> InterleavedPolicy<'a> {
    pub fn new(alloc: &'a Allocation, cluster: &'a Cluster, opts: &ExecOptions) -> Self {
        InterleavedPolicy {
            alloc,
            cluster,
            spec: alloc.spec.clone(),
            seg: alloc.seg.max(1),
            opts: *opts,
            st: None,
            kv_shipped_total: 0,
            plans_fired: 0,
            churn_alloc: None,
            replans: 0,
            migrated_bytes: 0,
            slot_lens: Vec::new(),
        }
    }

    /// Test hook: drop the per-request state so the next `begin_request`
    /// takes the fresh-build path (the arena pin test streams both paths).
    #[cfg(test)]
    fn clear_request_state(&mut self) {
        self.st = None;
    }

    /// Prompt length of slot `m` — the global knob when no slot lengths
    /// are installed (every non-serving path).
    fn prompt_of(&self, m: usize) -> usize {
        self.slot_lens
            .get(m)
            .map_or(self.opts.prompt_tokens, |&(p, _)| p)
    }

    /// The single prompt shared by every slot: the global knob when no
    /// slot lengths are installed, `Some(p)` when all installed slots
    /// agree (the homogeneous fast path reuses the exact pre-mix
    /// expressions, keeping fixed-length serving bit-identical), `None`
    /// when ragged.
    fn uniform_prompt(&self) -> Option<usize> {
        match self.slot_lens.first() {
            None => Some(self.opts.prompt_tokens),
            Some(&(p0, _)) => self.slot_lens.iter().all(|&(p, _)| p == p0).then_some(p0),
        }
    }

    /// Largest per-slot prompt — the stand-in for the scalar
    /// `prompt_tokens` knob in the per-device KV bookkeeping (`kv_held`
    /// is device-replicated token space, so the widest context governs).
    fn effective_prompt(&self) -> usize {
        self.slot_lens
            .iter()
            .map(|&(p, _)| p)
            .max()
            .unwrap_or(self.opts.prompt_tokens)
    }

    /// Scalar context driving planner thresholds, Alg. 2 and overflow
    /// checks: max over slots of `prompt + completed steps`; the pre-mix
    /// `prompt_tokens + local_step` when no slot lengths are installed.
    fn effective_tok(&self, local_step: usize) -> usize {
        self.slot_lens
            .iter()
            .map(|&(p, done)| p + done)
            .max()
            .unwrap_or(self.opts.prompt_tokens + local_step)
    }

    /// Rebuild the per-request adaptation state for a batch of `micro`
    /// micro-batches: fresh on the first request, reset in place
    /// afterwards (the arena lever — a long stream touches the allocator
    /// O(1) times on the policy side). `reset` mirrors `new`
    /// field-for-field on the planner/protocol (pinned by their
    /// `reset_equals_new_after_use` tests) and the vectors are
    /// clear+resize'd to the exact values the fresh path builds, so both
    /// paths are bit-identical (`in_place_request_reset_matches_fresh_
    /// rebuild` streams both). Scripted pressure accumulated earlier on
    /// the stream carries into the reset planner, so mid-stream requests
    /// plan under the same shifted slack the effective caps describe.
    fn reset_request_state(&mut self, core: &mut CoreState, micro: usize, bw0: f64) {
        let d = self.cluster.len();
        // Per-request prompt for the KV/protocol bookkeeping: the widest
        // installed slot, or the global knob on non-serving paths.
        let prompt = self.effective_prompt();
        // Effective base allocation: the churn overlay when a re-plan is
        // in force, the offline allocation otherwise (always, churn-free).
        let alloc = self.churn_alloc.as_ref().unwrap_or(self.alloc);
        if let Some(st) = self.st.as_mut() {
            st.planner.reset(alloc, self.cluster, micro);
            for i in 0..d {
                let pressure = core.mem_pressure(i);
                if pressure != 0 {
                    st.planner.apply_pressure(i, pressure);
                }
            }
            st.protocol.reset(
                alloc,
                self.cluster,
                &st.planner,
                prompt,
                micro,
                bw0,
            );
            // Field-wise: `Vec::clone_from` reuses the buffer (a derived
            // whole-struct `clone_from` would reallocate). The spec never
            // changes mid-stream and online plans only mutate `devices`.
            st.live.devices.clone_from(&alloc.devices);
            st.live.seg = alloc.seg;
            debug_assert!(st.live.spec == alloc.spec);
            st.last_plan.clear();
            st.last_plan.resize(d, OffloadPlan::default());
            st.kv_held.clear();
            st.kv_held.resize(d, prompt);
            st.pending_reload.clear();
            st.pending_reload.resize(d, 0);
            st.micro_front.clear();
            st.micro_front.resize(micro, 0.0);
        } else {
            let mut planner = OnlinePlanner::new(alloc, self.cluster, micro);
            for i in 0..d {
                let pressure = core.mem_pressure(i);
                if pressure != 0 {
                    planner.apply_pressure(i, pressure);
                }
            }
            let protocol = KvTransferProtocol::new(
                alloc,
                self.cluster,
                &planner,
                prompt,
                micro,
                bw0,
            );
            self.st = Some(ReqState {
                planner,
                protocol,
                live: alloc.clone(),
                last_plan: vec![OffloadPlan::default(); d],
                kv_held: vec![prompt; d],
                pending_reload: vec![0; d],
                slot_free: Vec::new(), // filled once decode_start is known
                micro_front: vec![0.0; micro],
            });
        }
    }

    /// Prefill-pass charge for a `micro`-wide admission beginning at `at`
    /// (charged, not measured). Pure time arithmetic over the effective
    /// base allocation — touches no per-request state, so the continuous
    /// driver may overlap it with an in-flight batch's decode. Down
    /// devices (0 layers under a churn re-plan) host no stage, so they
    /// neither compute nor relay activations.
    fn charge_prefill(&self, at: f64, micro: usize, bw0: f64) -> f64 {
        let alloc = self.churn_alloc.as_ref().unwrap_or(self.alloc);
        // Homogeneous prompts — every non-serving call, and fixed-length
        // serving — reuse the exact pre-mix expressions (bit-identity
        // pin); ragged slots sum per-request FLOPs and activation volume.
        let uniform = self.uniform_prompt();
        let mut t_prefill = at;
        for i in 0..self.cluster.len() {
            let a = &alloc.devices[i];
            if a.total_layers == 0 {
                continue;
            }
            let flops = match uniform {
                Some(p) => {
                    self.spec.layer_prefill_flops(p) * a.total_layers as f64 * micro as f64
                }
                None => {
                    let per_slot: f64 = (0..micro)
                        .map(|m| self.spec.layer_prefill_flops(self.prompt_of(m)))
                        .sum();
                    per_slot * a.total_layers as f64
                }
            };
            let comp = flops / self.cluster.devices[i].flops;
            let load = cost::load_time(&self.spec, &self.cluster.devices[i], a);
            t_prefill += comp.max(load);
            let act_bytes = match uniform {
                Some(p) => self.spec.h_size(micro) * p as u64,
                None => (0..micro)
                    .map(|m| self.spec.h_size(1) * self.prompt_of(m) as u64)
                    .sum(),
            };
            t_prefill += link_transfer_secs(act_bytes, bw0);
        }
        t_prefill
    }
}

impl SchedulePolicy for InterleavedPolicy<'_> {
    fn begin_request(
        &mut self,
        core: &mut CoreState,
        at: f64,
        micro: usize,
        global_step: usize,
    ) -> f64 {
        let d = self.cluster.len();
        let bw0 = core.bw_at(global_step);
        self.reset_request_state(core, micro, bw0);
        let decode_start = self.charge_prefill(at, micro, bw0);
        let st = self.st.as_mut().expect("state installed above");
        st.slot_free.clear();
        st.slot_free.resize(d, decode_start);
        decode_start
    }

    fn prefill_end(
        &mut self,
        core: &mut CoreState,
        at: f64,
        micro: usize,
        global_step: usize,
    ) -> f64 {
        let bw0 = core.bw_at(global_step);
        self.charge_prefill(at, micro, bw0)
    }

    fn begin_batch(
        &mut self,
        core: &mut CoreState,
        at: f64,
        micro: usize,
        global_step: usize,
    ) -> f64 {
        // Prefill was already charged through `prefill_end` while the
        // previous epoch decoded; only the per-request state resets here.
        let d = self.cluster.len();
        let bw0 = core.bw_at(global_step);
        self.reset_request_state(core, micro, bw0);
        let st = self.st.as_mut().expect("state installed above");
        st.slot_free.clear();
        st.slot_free.resize(d, at);
        at
    }

    fn set_slot_lengths(&mut self, slots: &[(usize, usize)]) {
        self.slot_lens.clear();
        self.slot_lens.extend_from_slice(slots);
    }

    fn on_batch_resize(&mut self, _core: &mut CoreState, micro: usize) {
        // `step` fills `micro_front` with the step start, so resizing is
        // the only bookkeeping a width change needs. The planner/protocol
        // keep the epoch's admission-time micro — a modeling
        // simplification documented in docs/SERVING.md.
        if let Some(st) = self.st.as_mut() {
            st.micro_front.resize(micro, 0.0);
        }
    }

    fn on_mem_event(&mut self, ev: &MemEvent) {
        if let Some(st) = self.st.as_mut() {
            st.planner.apply_pressure(ev.device, ev.delta_bytes);
        }
    }

    /// Online re-planning + KV migration on device churn (the robustness
    /// half of §IV-D): `Down` re-plans the model onto the surviving
    /// subset and ships the departed device's resident KV to survivors
    /// over the shared link (Eq. 8's volume model — the migration
    /// contends, so it stalls and delays whatever else needs the
    /// medium); `Up` re-expands onto the restored set and ships the KV
    /// the rejoined device's new layers need back onto it. When the
    /// survivors cannot fit the model, the current allocation is kept
    /// and the run degrades honestly through the zeroed cap (emergency
    /// spills, stalls) until capacity returns.
    fn on_churn_event(&mut self, core: &mut CoreState, ev: &ChurnEvent, ctx: &ChurnCtx) {
        let d = self.cluster.len();
        let bw = core.bw_at(ctx.global_step);

        // A departing device's holdings move out *before* its assignment
        // is dropped — price the migration under the current live alloc.
        if ev.kind == ChurnKind::Down {
            if let Some(st) = self.st.as_ref() {
                let bytes = resident_kv_bytes(&st.live, ev.device, st.kv_held[ev.device]);
                if bytes > 0 {
                    let iv = core.link_acquire(ctx.at, link_transfer_secs(bytes, bw));
                    core.trace
                        .push(ev.device, SpanKind::KvTransfer, "kv-migrate", iv.start, iv.end);
                    self.migrated_bytes += bytes;
                }
            }
        }

        // Re-plan onto the post-event survivor set (Alg. 1 reused on the
        // subset), then expand back to full cluster length with 0-layer
        // entries for down devices so every index keeps meaning the same
        // physical device.
        let survivors = core.survivors();
        debug_assert!(!survivors.is_empty(), "the core rejects a last-device Down");
        let overlay = if survivors.len() == d {
            // Full fleet restored: drop the overlay, the offline
            // allocation rules again.
            Some(None)
        } else {
            let popts = PlanOptions {
                empirical_tokens: 256,
                micro_batch: ctx.micro,
                bandwidth: bw,
            };
            plan(&self.spec, &self.cluster.subset(&survivors), &popts)
                .ok()
                .map(|report| {
                    let mut devices = vec![DeviceAssignment::resident(0); d];
                    for (k, &i) in survivors.iter().enumerate() {
                        devices[i] = report.allocation.devices[k].clone();
                    }
                    Some(Allocation::new(
                        self.spec.clone(),
                        report.allocation.seg,
                        devices,
                    ))
                })
        };
        let Some(overlay) = overlay else {
            return; // survivors can't fit the model: keep degrading
        };
        self.replans += 1;
        self.churn_alloc = overlay;
        let alloc = self.churn_alloc.as_ref().unwrap_or(self.alloc);
        self.seg = alloc.seg.max(1);

        // A rejoining device receives from survivors the KV its newly
        // assigned layers need for the context built so far.
        if ev.kind == ChurnKind::Up {
            if let Some(st) = self.st.as_ref() {
                let bytes = resident_kv_bytes(alloc, ev.device, st.kv_held[ev.device]);
                if bytes > 0 {
                    let iv = core.link_acquire(ctx.at, link_transfer_secs(bytes, bw));
                    core.trace
                        .push(ev.device, SpanKind::KvTransfer, "kv-migrate", iv.start, iv.end);
                    self.migrated_bytes += bytes;
                }
            }
        }

        // Rebuild the in-flight request's adaptation state on the new
        // allocation; shared-resource clocks (slot_free, micro_front,
        // the link) keep their times — the schedule resumes from
        // wherever the simulated hardware actually is.
        let tok = self.effective_tok(ctx.local_step);
        let prompt = self.effective_prompt();
        if let Some(st) = self.st.as_mut() {
            st.planner.reset(alloc, self.cluster, ctx.micro);
            for i in 0..d {
                let pressure = core.mem_pressure(i);
                if pressure != 0 {
                    st.planner.apply_pressure(i, pressure);
                }
            }
            st.protocol
                .reset(alloc, self.cluster, &st.planner, tok, ctx.micro, bw);
            st.live.devices.clone_from(&alloc.devices);
            st.live.seg = alloc.seg;
            st.last_plan.clear();
            st.last_plan.resize(d, OffloadPlan::default());
            st.pending_reload.clear();
            st.pending_reload.resize(d, 0);
            // KV holdings follow the migration.
            match ev.kind {
                ChurnKind::Down => {
                    let moved = st.kv_held[ev.device];
                    st.kv_held[ev.device] = 0;
                    let target = st.planner.highest_threshold_device();
                    st.kv_held[target] += moved;
                }
                ChurnKind::Up => {
                    st.kv_held[ev.device] = prompt + ctx.micro * ctx.local_step;
                }
            }
        }
    }

    fn step(&mut self, core: &mut CoreState, ctx: &StepCtx) -> f64 {
        // Scalar context (planner thresholds, Alg. 2, overflow): the
        // widest slot's prompt + completed steps; pre-mix arithmetic when
        // no slot lengths are installed. Computed before `st` is borrowed.
        let tok = self.effective_tok(ctx.local_step);
        let st = self.st.as_mut().expect("begin_request precedes step");
        let d = self.cluster.len();
        let seg = self.seg;
        let micro = ctx.micro;
        let bw = core.bw_at(ctx.global_step);

        // ---- Alg. 2 lines 8-9: monitor bandwidth, adapt transfers ----
        if self.opts.kv_transfer {
            st.protocol.on_bandwidth(
                &st.live,
                self.cluster,
                &st.planner,
                ctx.local_step,
                tok,
                micro,
                bw,
            );
        }

        let step_start = ctx.step_start;
        st.micro_front.fill(step_start);

        for s in 0..seg {
            for i in 0..d {
                let a = &st.live.devices[i];
                let layers_here = st.live.layers_in_segment(i, s);
                if layers_here == 0 {
                    continue;
                }
                let off_here = st.live.offloaded_in_segment(i, s);
                let res_here = layers_here - off_here.min(layers_here);

                // Per-segment streamed bytes: the device's per-pass load
                // spread across segments, plus any one-time reload.
                let mut seg_load_bytes = a.load_bytes(&self.spec) / seg as u64;
                if s == 0 {
                    seg_load_bytes += st.pending_reload[i];
                    st.pending_reload[i] = 0;
                }
                // SSD load for this segment: starts when the slot freed.
                let load_iv = if seg_load_bytes > 0 {
                    let iv = core.ssds[i].read(st.slot_free[i], seg_load_bytes);
                    core.trace.push(
                        i,
                        SpanKind::Load,
                        Label::SegLoad {
                            step: ctx.global_step as u32,
                            seg: s as u32,
                        },
                        iv.start,
                        iv.end,
                    );
                    Some(iv)
                } else {
                    None
                };

                let mut last_micro_end = step_start;
                for (m, front) in st.micro_front.iter_mut().enumerate() {
                    // Slot m computes at its own request's context when
                    // slot lengths are installed (ragged-length serving);
                    // the scalar `tok` otherwise — identical by value on
                    // every homogeneous path.
                    let tok_m = self
                        .slot_lens
                        .get(m)
                        .map_or(tok, |&(p, done)| p + done);
                    // Activation hop onto device i (shared medium).
                    let hop =
                        core.link_acquire(*front, link_transfer_secs(self.spec.h_size(1), bw));
                    let label = |phase| Label::Micro { m: m as u32, phase };
                    core.trace
                        .push(i, SpanKind::Comm, label(MicroPhase::Hop), hop.start, hop.end);
                    let arrive = hop.end;

                    // Resident fraction computes immediately.
                    let comp_res =
                        cost::comp_time(&self.spec, &self.cluster.devices[i], res_here, tok_m, 1);
                    let iv1 = core.gpus[i].acquire(arrive, comp_res);
                    if comp_res > 0.0 {
                        core.trace.push(
                            i,
                            SpanKind::Compute,
                            label(MicroPhase::Resident),
                            iv1.start,
                            iv1.end,
                        );
                    }
                    // Offloaded fraction gates on the load.
                    let mut end = iv1.end;
                    if off_here > 0 {
                        let gate = load_iv.map(|iv| iv.end).unwrap_or(end);
                        if gate > end {
                            core.trace
                                .push(i, SpanKind::Stall, label(MicroPhase::Wait), end, gate);
                        }
                        let comp_off = cost::comp_time(
                            &self.spec,
                            &self.cluster.devices[i],
                            off_here,
                            tok_m,
                            1,
                        );
                        let iv2 = core.gpus[i].acquire(end.max(gate), comp_off);
                        core.trace.push(
                            i,
                            SpanKind::Compute,
                            label(MicroPhase::Offloaded),
                            iv2.start,
                            iv2.end,
                        );
                        end = iv2.end;
                    }
                    *front = end;
                    last_micro_end = last_micro_end.max(end);
                }
                // Slot frees once the last micro-batch leaves this segment.
                if off_here > 0 || seg_load_bytes > 0 {
                    st.slot_free[i] = last_micro_end;
                }
            }
        }

        let mut step_end = st.micro_front.iter().cloned().fold(step_start, f64::max);

        // ---- KV bookkeeping + online adaptation between steps ----
        for i in 0..d {
            st.kv_held[i] += micro;
        }

        // KV transfer protocol: ship paced chunks to d_target. Shipping
        // costs link time, so it only pays when it delays an *imminent*
        // offload threshold (Fig. 10's motivation) — gate on proximity.
        if self.opts.kv_transfer {
            for i in 0..d {
                let ts_next = st.planner.next_threshold(i);
                let imminent = ts_next != usize::MAX && tok + 96 >= ts_next;
                if !imminent {
                    continue;
                }
                let target = st.protocol.states[i].target;
                let ship = st.protocol.ship_now(i, st.kv_held[i], KV_SHIP_CAP);
                if ship > 0 {
                    let t = target.unwrap();
                    let bytes = self.spec.kv_bytes_per_token_layer()
                        * st.live.devices[i].total_layers as u64
                        * ship as u64;
                    let iv = core.link_acquire(step_end, link_transfer_secs(bytes, bw));
                    core.trace.push(
                        i,
                        SpanKind::KvTransfer,
                        Label::KvTo { device: t as u32 },
                        iv.start,
                        iv.end,
                    );
                    // Asynchronous: does not extend the step unless the link
                    // is still busy when the next step's first hop needs it
                    // (the shared link Resource captures that naturally).
                    st.kv_held[i] -= ship;
                    st.kv_held[t] += ship;
                    st.protocol.record_receipt(t, ship);
                    self.kv_shipped_total += ship as u64;
                }
            }
        }

        // Memory-aware planner (Eqs. 5-7) or its ablation substitutes.
        for i in 0..d {
            let n_trans = if self.opts.kv_transfer {
                st.protocol.n_trans(i)
            } else {
                0
            };
            match self.opts.planner {
                PlannerMode::FineGrained => {
                    if let Some(plan) = st.planner.on_token(i, tok, n_trans) {
                        self.plans_fired += 1;
                        // Apply the plan to the live allocation.
                        let prev = st.last_plan[i];
                        let da = plan.alpha as i64 - prev.alpha as i64;
                        let db = plan.beta as i64 - prev.beta as i64;
                        apply_block_plan(&mut st.live, i, da, db);
                        // Reload swapped-back blocks once (Fig. 9: the
                        // previously evicted block returns to GPU).
                        let reload = reload_bytes(&self.spec, da, db);
                        st.pending_reload[i] += reload;
                        st.last_plan[i] = plan;
                    }
                }
                PlannerMode::FullLayer => {
                    // Ablation: when memory saturates, offload a whole layer.
                    if mem_saturated(&st.live, i, tok * micro, n_trans, core.mem_caps[i])
                        && st.live.devices[i].non_offloaded_layers() > 0
                    {
                        self.plans_fired += 1;
                        st.live.devices[i].full_offload += 1;
                    }
                }
                PlannerMode::Off => {}
            }
        }

        // Emergency fallback: devices still saturated swap KV to SSD
        // (write + read per step — the naive strategy of §III / Fig. 2b).
        // The core counts a step as an emergency step at most once,
        // however many devices overflow within it.
        for i in 0..d {
            if st.live.devices[i].total_layers == 0 {
                // Churned-out device: hosts no layers, holds no KV — the
                // positional embedding charge in `mem_demand` must not
                // saturate it against its zeroed cap.
                continue;
            }
            let n_trans = if self.opts.kv_transfer {
                st.protocol.n_trans(i)
            } else {
                0
            };
            let overflow =
                cost::overflow_tokens_with_cap(&st.live, i, tok * micro, n_trans, core.mem_caps[i])
                    .min(st.kv_held[i]);
            if overflow > 0 {
                core.mark_emergency();
                let bytes = self.spec.kv_bytes_per_token_layer()
                    * st.live.devices[i].total_layers as u64
                    * overflow as u64;
                let w = core.ssds[i].write(step_end, bytes);
                core.trace.push(i, SpanKind::Store, "kv-spill", w.start, w.end);
                let r = core.ssds[i].read(w.end, bytes);
                core.trace.push(i, SpanKind::Load, "kv-fetch", r.start, r.end);
                step_end = step_end.max(r.end);
            }
        }

        step_end
    }

    fn kv_tokens_transferred(&self) -> u64 {
        self.kv_shipped_total
    }

    fn online_plans_fired(&self) -> usize {
        self.plans_fired
    }

    fn replans_fired(&self) -> usize {
        self.replans
    }

    fn kv_migrated_bytes(&self) -> u64 {
        self.migrated_bytes
    }
}

/// Apply a (Δα, Δβ) block plan to device `i`'s live assignment.
fn apply_block_plan(live: &mut Allocation, i: usize, da: i64, db: i64) {
    let a = &mut live.devices[i];
    // +Δα: evict MHA blocks of resident layers (layer becomes mha_offload).
    // −Δα: reload (mha_offload layer becomes resident again). Same for β/MLP.
    if da > 0 {
        let take = (da as usize).min(a.non_offloaded_layers());
        a.mha_offload += take;
    } else if da < 0 {
        let take = ((-da) as usize).min(a.mha_offload);
        a.mha_offload -= take;
    }
    if db > 0 {
        let take = (db as usize).min(a.non_offloaded_layers());
        a.mlp_offload += take;
    } else if db < 0 {
        let take = ((-db) as usize).min(a.mlp_offload);
        a.mlp_offload -= take;
    }
}

/// Bytes to read back when a plan swap reloads previously evicted blocks.
fn reload_bytes(spec: &ModelSpec, da: i64, db: i64) -> u64 {
    let mut bytes = 0u64;
    if da < 0 {
        bytes += (-da) as u64 * spec.mha_bytes();
    }
    if db < 0 {
        bytes += (-db) as u64 * spec.mlp_bytes();
    }
    bytes
}

/// Is device `i` out of memory at context `ctx` under the live allocation
/// and its (possibly pressure-shifted) effective capacity?
fn mem_saturated(live: &Allocation, i: usize, ctx: usize, n_trans: i64, cap: u64) -> bool {
    cost::mem_demand(live, i, ctx, n_trans) > cap
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::plan::{plan, PlanOptions};
    use crate::util::bytes::mbps;

    fn setup(env: &str) -> (Allocation, Cluster) {
        let spec = ModelSpec::llama33_70b();
        let cluster = match env {
            "e3" => Cluster::env_e3(),
            "low1" => Cluster::lowmem_setting1(),
            "low3" => Cluster::lowmem_setting3(),
            _ => unreachable!(),
        };
        let opts = PlanOptions {
            empirical_tokens: 256,
            micro_batch: 1,
            bandwidth: mbps(200.0),
        };
        (plan(&spec, &cluster, &opts).unwrap().allocation, cluster)
    }

    #[test]
    fn sporadic_run_produces_monotone_progress() {
        let (alloc, cluster) = setup("e3");
        let bw = BandwidthTrace::fixed_mbps(200.0);
        let r = run_interleaved(&alloc, &cluster, &bw, 1, 16, &ExecOptions::default());
        assert_eq!(r.tokens, 16);
        assert_eq!(r.step_times.len(), 16);
        assert!(r.total_time > 0.0);
        assert!(r.step_times.iter().all(|&t| t > 0.0));
    }

    #[test]
    fn bursty_improves_per_token_latency() {
        let (alloc, cluster) = setup("e3");
        let bw = BandwidthTrace::fixed_mbps(200.0);
        let spor = run_interleaved(&alloc, &cluster, &bw, 1, 12, &ExecOptions::default());
        let burst =
            run_interleaved(&alloc, &cluster, &bw, cluster.len(), 12, &ExecOptions::default());
        assert!(
            burst.ms_per_token() < spor.ms_per_token(),
            "bursty {:.1} !< sporadic {:.1}",
            burst.ms_per_token(),
            spor.ms_per_token()
        );
    }

    #[test]
    fn lower_bandwidth_is_slower() {
        let (alloc, cluster) = setup("e3");
        let hi = run_interleaved(
            &alloc,
            &cluster,
            &BandwidthTrace::fixed_mbps(200.0),
            1,
            12,
            &ExecOptions::default(),
        );
        let lo = run_interleaved(
            &alloc,
            &cluster,
            &BandwidthTrace::fixed_mbps(100.0),
            1,
            12,
            &ExecOptions::default(),
        );
        assert!(lo.ms_per_token() > hi.ms_per_token());
    }

    #[test]
    fn offload_pressure_engages_loads() {
        let (alloc, cluster) = setup("low3");
        let bw = BandwidthTrace::fixed_mbps(200.0);
        let r = run_interleaved(&alloc, &cluster, &bw, 1, 8, &ExecOptions::default());
        let load_busy: f64 = (0..cluster.len())
            .map(|i| r.trace.busy(i, SpanKind::Load))
            .sum();
        assert!(load_busy > 0.0, "low-memory setting must stream layers");
    }

    #[test]
    fn planner_beats_full_layer_ablation_under_pressure() {
        let (alloc, cluster) = setup("low1");
        let bw = BandwidthTrace::fixed_mbps(200.0);
        let long = 192; // enough steps for KV pressure to build
        let fine = run_interleaved(&alloc, &cluster, &bw, 1, long, &ExecOptions::default());
        let full = run_interleaved(
            &alloc,
            &cluster,
            &bw,
            1,
            long,
            &ExecOptions {
                planner: PlannerMode::FullLayer,
                ..ExecOptions::default()
            },
        );
        assert!(
            fine.ms_per_token() <= full.ms_per_token() * 1.02,
            "fine-grained {:.1} should not lose to full-layer {:.1}",
            fine.ms_per_token(),
            full.ms_per_token()
        );
    }

    #[test]
    fn emergency_steps_count_each_step_at_most_once() {
        // With adaptation disabled, KV pressure eventually saturates several
        // devices in the same step; the counter must still be per-step.
        let (alloc, cluster) = setup("low3");
        let bw = BandwidthTrace::fixed_mbps(100.0);
        let tokens = 256;
        let r = run_interleaved(
            &alloc,
            &cluster,
            &bw,
            cluster.len(),
            tokens,
            &ExecOptions {
                planner: PlannerMode::Off,
                kv_transfer: false,
                ..ExecOptions::default()
            },
        );
        assert!(
            r.emergency_steps <= tokens,
            "emergency_steps {} exceeds the {} simulated steps",
            r.emergency_steps,
            tokens
        );
    }

    #[test]
    fn trace_off_matches_full_timing() {
        let (alloc, cluster) = setup("low1");
        let bw = BandwidthTrace::fixed_mbps(150.0);
        let full = run_interleaved(&alloc, &cluster, &bw, 2, 24, &ExecOptions::default());
        let off = run_interleaved(
            &alloc,
            &cluster,
            &bw,
            2,
            24,
            &ExecOptions {
                trace_mode: crate::sim::TraceMode::Off,
                ..ExecOptions::default()
            },
        );
        assert_eq!(full.total_time, off.total_time);
        assert_eq!(full.step_times, off.step_times);
        assert_eq!(full.kv_tokens_transferred, off.kv_tokens_transferred);
        assert_eq!(full.emergency_steps, off.emergency_steps);
        assert!(full.trace.span_count() > 0);
        assert_eq!(off.trace.span_count(), 0);
    }

    #[test]
    fn in_place_request_reset_matches_fresh_rebuild() {
        // The arena pin at stream level: one policy resets its request
        // state in place (the normal path); the other is forced to rebuild
        // from scratch before every request. Driven through identical
        // cores — including scripted mem pressure landing mid-stream — the
        // two must stay bit-identical, request for request.
        use crate::adapt::MemScenario;
        use crate::pipeline::core::ExecutorCore;
        use crate::util::bytes::gib;

        let (alloc, cluster) = setup("low1");
        let bw = BandwidthTrace::fixed_mbps(150.0);
        let opts = ExecOptions {
            trace_mode: crate::sim::TraceMode::Off,
            ..ExecOptions::default()
        };
        let common = CommonOptions::from(&opts);
        let script =
            Script::from_mem(MemScenario::squeeze("sq", 0, gib(2.0), 20)).with_label("sq");
        let mut reset_path = ExecutorCore::new(
            InterleavedPolicy::new(&alloc, &cluster, &opts),
            &cluster,
            &bw,
            &common,
            &script,
        );
        let mut rebuild_path = ExecutorCore::new(
            InterleavedPolicy::new(&alloc, &cluster, &opts),
            &cluster,
            &bw,
            &common,
            &script,
        );
        let (mut t_a, mut t_b) = (0.0, 0.0);
        for (micro, tokens) in [(1usize, 12usize), (2, 24), (1, 48), (3, 8)] {
            let a = reset_path.run_request(t_a, micro, tokens).unwrap();
            rebuild_path.policy.clear_request_state();
            let b = rebuild_path.run_request(t_b, micro, tokens).unwrap();
            assert_eq!(a, b, "stream diverged at shape ({micro},{tokens})");
            t_a = a.finish();
            t_b = b.finish();
        }
        let (ta, tb) = (reset_path.into_totals(), rebuild_path.into_totals());
        assert_eq!(ta.step_times, tb.step_times);
        assert_eq!(ta.kv_tokens_transferred, tb.kv_tokens_transferred);
        assert_eq!(ta.online_plans_fired, tb.online_plans_fired);
        assert_eq!(ta.emergency_steps, tb.emergency_steps);
    }

    #[test]
    fn churn_down_replans_migrates_and_tracks_recovery() {
        let (alloc, cluster) = setup("low1");
        let bw = BandwidthTrace::fixed_mbps(200.0);
        // Take down the weakest device that actually hosts layers, so the
        // Down migration has resident KV to ship and the survivors (which
        // include every stronger device) can re-fit the model.
        let dev = (0..cluster.len())
            .rev()
            .find(|&i| alloc.devices[i].total_layers > 0)
            .expect("offline plan assigns layers somewhere");
        let script = Script::device_down_up("blip", dev, 4, 12);
        let r = run_interleaved_scripted(
            &alloc,
            &cluster,
            &bw,
            1,
            24,
            &ExecOptions::default(),
            &script,
        );
        assert_eq!(r.tokens, 24);
        assert_eq!(r.replans_fired, 2, "Down re-plan + Up re-expansion");
        assert!(
            r.kv_migrated_bytes > 0,
            "the departed device's resident KV must ship over the link"
        );
        assert_eq!(r.recovery_steps.len(), 1, "one Down event, one recovery slot");
    }

    #[test]
    fn unfired_churn_is_bit_identical_to_plain_run() {
        // Churn scheduled beyond the horizon never fires: the run must be
        // byte-identical to the script-free one (the policy's churn
        // overlay stays None and no churn-only code path executes).
        let (alloc, cluster) = setup("e3");
        let bw = BandwidthTrace::fixed_mbps(200.0);
        let plain = run_interleaved(&alloc, &cluster, &bw, 2, 16, &ExecOptions::default());
        let scripted = run_interleaved_scripted(
            &alloc,
            &cluster,
            &bw,
            2,
            16,
            &ExecOptions::default(),
            &Script::device_down_up("never", 0, 1_000, 1_001),
        );
        assert_eq!(plain.total_time, scripted.total_time);
        assert_eq!(plain.step_times, scripted.step_times);
        assert_eq!(plain.kv_tokens_transferred, scripted.kv_tokens_transferred);
        assert_eq!(scripted.replans_fired, 0);
        assert_eq!(scripted.kv_migrated_bytes, 0);
        assert!(scripted.recovery_steps.is_empty());
    }

    #[test]
    fn deterministic_given_seed() {
        let (alloc, cluster) = setup("low1");
        let bw = BandwidthTrace::fixed_mbps(150.0);
        let a = run_interleaved(&alloc, &cluster, &bw, 2, 24, &ExecOptions::default());
        let b = run_interleaved(&alloc, &cluster, &bw, 2, 24, &ExecOptions::default());
        assert_eq!(a.total_time, b.total_time);
        assert_eq!(a.kv_tokens_transferred, b.kv_tokens_transferred);
    }
}
