//! Online memory adaptation strategy (paper §IV-D): the memory-aware
//! planner (Eqs. 5–7), the bandwidth-sensitive KV-cache transfer
//! protocol (Alg. 2, Eq. 8), and scripted memory-fluctuation scenarios
//! that drive both from the scenario-matrix sweeps.

pub mod kvtransfer;
pub mod planner;
pub mod pressure;

pub use kvtransfer::{eq8_tokens, KvTransferProtocol, TransferState};
pub use planner::{DeviceMemState, OffloadPlan, OnlinePlanner};
pub use pressure::{MemEvent, MemScenario};
