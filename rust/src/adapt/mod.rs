//! Online memory adaptation (paper §IV-D) and the fluctuation scripts
//! that stress it.
//!
//! Three cooperating pieces:
//!
//! * [`planner`] — the memory-aware online planner (Eqs. 5–7, Fig. 9):
//!   per-device thresholds `TS_i^j` over KV growth, block-granular
//!   `(α, β)` offload plans chosen to minimize extra streamed bytes, and
//!   [`OnlinePlanner::apply_pressure`] for scripted slack shifts;
//! * [`kvtransfer`] — the bandwidth-sensitive KV-cache transfer protocol
//!   (Alg. 2, Eq. 8, Fig. 10): pacing KV to a high-threshold `d_target`,
//!   reacting asymmetrically to bandwidth decreases (immediate) vs
//!   increases (lazy unless a threshold is imminent);
//! * [`scripts`] — composable disturbance timelines ([`MemScenario`],
//!   [`Script`]): single- and multi-device memory pressure (correlated
//!   thermal dips with lag, staggered squeezes, recovery ramps), a
//!   bandwidth event channel ([`BwEvent`]), and a device-churn channel
//!   ([`ChurnEvent`]: Down/Up faults triggering online re-planning and
//!   KV migration), consumed jointly by
//!   `pipeline::run_interleaved_scripted` and swept by
//!   `experiments::scenario::ScenarioMatrix`'s pressure and churn axes.
//!
//! The planner and protocol are pure state machines: the discrete-event
//! simulator and the real PJRT serving engine drive the same types.

pub mod kvtransfer;
pub mod planner;
pub mod scripts;

pub use kvtransfer::{eq8_tokens, resident_kv_bytes, KvTransferProtocol, TransferState};
pub use planner::{DeviceMemState, OffloadPlan, OnlinePlanner};
pub use scripts::{BwEvent, ChurnEvent, ChurnKind, MemEvent, MemScenario, Script, ScriptEvent};
