//! Online memory adaptation strategy (paper §IV-D): the memory-aware
//! planner (Eqs. 5–7) and the bandwidth-sensitive KV-cache transfer
//! protocol (Alg. 2, Eq. 8).

pub mod kvtransfer;
pub mod planner;

pub use kvtransfer::{eq8_tokens, KvTransferProtocol, TransferState};
pub use planner::{DeviceMemState, OffloadPlan, OnlinePlanner};
