//! Fluctuation scripts — scripted memory *and* bandwidth disturbance
//! timelines for the §IV-D online-adaptation machinery.
//!
//! Real edge clusters are not disturbed one device at a time: a thermal
//! event in a cabinet throttles co-located neighbours within seconds of
//! each other, a co-tenant rollout squeezes devices in deployment order,
//! and Wi-Fi/LAN contention sags the shared link *while* memory shrinks.
//! This module scripts those shapes as plain data:
//!
//! * [`MemEvent`] / [`MemScenario`] — per-device usable-memory deltas,
//!   with single-device ([`MemScenario::dip`], [`MemScenario::squeeze`])
//!   and multi-device ([`MemScenario::correlated_dip`],
//!   [`MemScenario::staggered_squeeze`], [`MemScenario::dip_with_ramp`])
//!   constructors, composable via [`MemScenario::merged`];
//! * [`BwEvent`] — a multiplicative link-capacity factor that takes
//!   effect before a decode step (`scale < 1` is a sag, `1.0` restores),
//!   applied on top of whatever base [`crate::net::BandwidthTrace`] the
//!   run uses so scripts compose with the sweep's bandwidth axis;
//! * [`ChurnEvent`] — a device leaving ([`ChurnKind::Down`]) or
//!   rejoining ([`ChurnKind::Up`]) the cluster mid-stream, the
//!   intermittent-participation regime of real edge fleets. The executor
//!   core zeroes a down device's effective capacity; adaptive policies
//!   re-plan onto the survivors and migrate the departed device's
//!   resident KV (Eq. 8 volume over the shared link), non-adaptive
//!   policies degrade honestly through their overflow fallbacks. At
//!   fleet level the same events (with `device` read as a cluster index
//!   and `at_step` as an arrival index) drain a dead cluster's queue
//!   back through the router;
//! * [`Script`] — a labelled joint timeline of all three event kinds
//!   ([`ScriptEvent`]), consumed by
//!   `pipeline::run_interleaved_scripted`: memory events shift effective
//!   caps and the online planner's thresholds
//!   (`OnlinePlanner::apply_pressure`), bandwidth events scale the link
//!   capacity the Eq. 2 comm terms and Alg. 2's bandwidth monitor see,
//!   churn events remove/restore whole devices — in the same run.
//!
//! Scripts are deterministic given their event lists, replayable at any
//! worker count, and serialized verbatim into the `lime-sweep-v3` axis
//! metadata so artifacts are self-describing. An empty script is the
//! baseline every non-adaptive method is measured at, and running one is
//! bit-identical to the unscripted executor (property-tested in
//! `rust/tests/adapt_online.rs`).

/// One scripted change to a device's usable memory.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct MemEvent {
    /// Decode step (0-based) *before* which the event applies.
    pub at_step: usize,
    /// Device index in the cluster.
    pub device: usize,
    /// Signed change in usable bytes (negative = pressure, positive =
    /// restoration). Applied saturating at zero.
    pub delta_bytes: i64,
}

/// One scripted change to the shared link's capacity: from `at_step`
/// onward the effective bandwidth is `base × scale` (the latest event at
/// or before a step wins; before any event the factor is 1.0).
///
/// Scales are *factors*, not absolute rates, so the same sag script
/// composes with every point of a sweep's bandwidth axis.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct BwEvent {
    /// Decode step (0-based) *before* which the factor takes effect.
    pub at_step: usize,
    /// Link-capacity factor (must be finite and > 0; 1.0 restores).
    pub scale: f64,
}

/// Direction of a churn event: a device leaving or rejoining.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub enum ChurnKind {
    /// The device drops out of the cluster (fault, battery, mobility).
    Down,
    /// The device rejoins the cluster.
    Up,
}

impl ChurnKind {
    /// Stable artifact name (`"down"` / `"up"`), used by sweep metadata.
    pub fn name(&self) -> &'static str {
        match self {
            ChurnKind::Down => "down",
            ChurnKind::Up => "up",
        }
    }
}

/// One scripted churn event: `device` goes [`ChurnKind::Down`] or comes
/// back [`ChurnKind::Up`] before decode step `at_step`. At fleet level
/// (`serve::fleet`), `device` is a cluster index and `at_step` an arrival
/// index — the same timeline type drives both granularities.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct ChurnEvent {
    /// Decode step (0-based) *before* which the event applies.
    pub at_step: usize,
    /// Device index in the cluster (cluster index at fleet level).
    pub device: usize,
    pub kind: ChurnKind,
}

/// One entry of a joint fluctuation timeline.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum ScriptEvent {
    Mem(MemEvent),
    Bw(BwEvent),
    Churn(ChurnEvent),
}

impl ScriptEvent {
    /// The decode step this event applies before.
    pub fn at_step(&self) -> usize {
        match self {
            ScriptEvent::Mem(e) => e.at_step,
            ScriptEvent::Bw(e) => e.at_step,
            ScriptEvent::Churn(e) => e.at_step,
        }
    }
}

/// A named memory-fluctuation scenario: a label (stable across sweep
/// artifacts) plus its event script. An empty script is the "none"
/// baseline every non-adaptive method is measured at.
#[derive(Debug, Clone, PartialEq)]
pub struct MemScenario {
    pub label: String,
    pub events: Vec<MemEvent>,
}

impl MemScenario {
    /// The no-pressure baseline scenario.
    pub fn none() -> Self {
        MemScenario {
            label: "none".into(),
            events: Vec::new(),
        }
    }

    /// A dip: `device` loses `bytes` before `down_step`, regains them
    /// before `up_step` — the transient-co-tenant shape.
    ///
    /// ```
    /// use lime::adapt::MemScenario;
    /// let s = MemScenario::dip("dip-d1", 1, 1024, 3, 7);
    /// assert_eq!(s.events.len(), 2);
    /// assert_eq!(s.events[0].delta_bytes, -1024);
    /// assert_eq!(s.events[1].delta_bytes, 1024);
    /// ```
    pub fn dip(label: &str, device: usize, bytes: u64, down_step: usize, up_step: usize) -> Self {
        assert!(down_step < up_step, "dip must release after it squeezes");
        MemScenario {
            label: label.into(),
            events: vec![
                MemEvent {
                    at_step: down_step,
                    device,
                    delta_bytes: -(bytes as i64),
                },
                MemEvent {
                    at_step: up_step,
                    device,
                    delta_bytes: bytes as i64,
                },
            ],
        }
    }

    /// A squeeze: `device` loses `bytes` before `at_step` and never gets
    /// them back — the persistent-co-tenant shape.
    pub fn squeeze(label: &str, device: usize, bytes: u64, at_step: usize) -> Self {
        MemScenario {
            label: label.into(),
            events: vec![MemEvent {
                at_step,
                device,
                delta_bytes: -(bytes as i64),
            }],
        }
    }

    /// Correlated thermal dip: every device of `devices` dips by `bytes`,
    /// the k-th one `k × lag` steps after the first (thermal events reach
    /// co-located neighbours with a propagation delay, not instantly).
    /// Each device recovers at `up_step + k × lag`, preserving its dip
    /// duration.
    ///
    /// ```
    /// use lime::adapt::MemScenario;
    /// let s = MemScenario::correlated_dip("thermal", &[0, 1], 2, 1024, 4, 10);
    /// // Two devices × (down + up) events; device 1 lags device 0 by 2 steps.
    /// assert_eq!(s.events.len(), 4);
    /// assert_eq!(s.events[0].at_step, 4);
    /// assert_eq!(s.events[1].at_step, 6);
    /// ```
    pub fn correlated_dip(
        label: &str,
        devices: &[usize],
        lag: usize,
        bytes: u64,
        down_step: usize,
        up_step: usize,
    ) -> Self {
        assert!(!devices.is_empty(), "correlated dip needs devices");
        assert!(down_step < up_step, "dip must release after it squeezes");
        let mut events = Vec::with_capacity(devices.len() * 2);
        for (k, &device) in devices.iter().enumerate() {
            events.push(MemEvent {
                at_step: down_step + k * lag,
                device,
                delta_bytes: -(bytes as i64),
            });
        }
        for (k, &device) in devices.iter().enumerate() {
            events.push(MemEvent {
                at_step: up_step + k * lag,
                device,
                delta_bytes: bytes as i64,
            });
        }
        events.sort_by_key(|e| (e.at_step, e.device));
        MemScenario {
            label: label.into(),
            events,
        }
    }

    /// Staggered squeeze: the k-th device of `devices` loses `bytes`
    /// before `at_step + k × stagger` and never recovers — the
    /// rolling-deployment co-tenant shape.
    ///
    /// ```
    /// use lime::adapt::MemScenario;
    /// let s = MemScenario::staggered_squeeze("rollout", &[2, 0], 3, 512, 1);
    /// assert_eq!(s.events.len(), 2);
    /// assert_eq!((s.events[0].device, s.events[0].at_step), (2, 1));
    /// assert_eq!((s.events[1].device, s.events[1].at_step), (0, 4));
    /// ```
    pub fn staggered_squeeze(
        label: &str,
        devices: &[usize],
        stagger: usize,
        bytes: u64,
        at_step: usize,
    ) -> Self {
        assert!(!devices.is_empty(), "staggered squeeze needs devices");
        let events = devices
            .iter()
            .enumerate()
            .map(|(k, &device)| MemEvent {
                at_step: at_step + k * stagger,
                device,
                delta_bytes: -(bytes as i64),
            })
            .collect();
        MemScenario {
            label: label.into(),
            events,
        }
    }

    /// A dip whose recovery is a ramp: `device` loses `bytes` before
    /// `down_step`, then regains them in `ramp_steps` equal increments
    /// starting at `ramp_start` (one per step). The increments sum to
    /// exactly `bytes`, so the scenario is a no-op once the ramp finishes.
    ///
    /// ```
    /// use lime::adapt::MemScenario;
    /// let s = MemScenario::dip_with_ramp("recover", 0, 10, 2, 5, 3);
    /// let restored: i64 = s.events[1..].iter().map(|e| e.delta_bytes).sum();
    /// assert_eq!(s.events[0].delta_bytes, -10);
    /// assert_eq!(restored, 10);
    /// assert_eq!(s.events.len(), 1 + 3);
    /// ```
    pub fn dip_with_ramp(
        label: &str,
        device: usize,
        bytes: u64,
        down_step: usize,
        ramp_start: usize,
        ramp_steps: usize,
    ) -> Self {
        assert!(ramp_steps >= 1, "ramp needs at least one increment");
        assert!(down_step < ramp_start, "ramp must start after the dip");
        let mut events = vec![MemEvent {
            at_step: down_step,
            device,
            delta_bytes: -(bytes as i64),
        }];
        let base = bytes / ramp_steps as u64;
        let remainder = bytes - base * ramp_steps as u64;
        for k in 0..ramp_steps {
            let inc = base + if k + 1 == ramp_steps { remainder } else { 0 };
            events.push(MemEvent {
                at_step: ramp_start + k,
                device,
                delta_bytes: inc as i64,
            });
        }
        MemScenario {
            label: label.into(),
            events,
        }
    }

    /// Merge several scenarios into one (events re-sorted by step then
    /// device; same-step deltas on one device sum, so order within a step
    /// does not matter).
    pub fn merged(label: &str, parts: &[MemScenario]) -> Self {
        let mut events: Vec<MemEvent> = parts.iter().flat_map(|p| p.events.clone()).collect();
        events.sort_by_key(|e| (e.at_step, e.device));
        MemScenario {
            label: label.into(),
            events,
        }
    }

    pub fn is_none(&self) -> bool {
        self.events.is_empty()
    }
}

/// A labelled joint fluctuation script: memory pressure events and
/// bandwidth capacity events on one timeline. The interleaved executor
/// applies both channels before each decode step, so Alg. 2's bandwidth
/// monitor and the online planner's thresholds react *together* — the
/// paper's "memory shrinks while the link sags" edge regime.
#[derive(Debug, Clone, PartialEq)]
pub struct Script {
    pub label: String,
    /// Memory-pressure channel (kept sorted by constructor, but any order
    /// is valid: same-step deltas commute).
    pub mem: Vec<MemEvent>,
    /// Bandwidth channel, sorted by `at_step`; the latest event at or
    /// before a step wins.
    pub bw: Vec<BwEvent>,
    /// Churn channel, sorted by `(at_step, device)`. Empty for every
    /// pre-churn script shape — an empty channel is bit-identical to the
    /// churn-free executor (property-tested in `rust/tests/churn.rs`).
    pub churn: Vec<ChurnEvent>,
}

impl Script {
    /// The no-fluctuation baseline script.
    pub fn none() -> Self {
        Script {
            label: "none".into(),
            mem: Vec::new(),
            bw: Vec::new(),
            churn: Vec::new(),
        }
    }

    /// Lift a pure memory scenario into a joint script (no bandwidth
    /// events), keeping its label.
    pub fn from_mem(scenario: MemScenario) -> Self {
        Script {
            label: scenario.label,
            mem: scenario.events,
            bw: Vec::new(),
            churn: Vec::new(),
        }
    }

    /// A labelled memory-only script from raw events (test/harness
    /// convenience; prefer the [`MemScenario`] constructors for shapes).
    pub fn from_mem_events(label: &str, events: Vec<MemEvent>) -> Self {
        Script {
            label: label.into(),
            mem: events,
            bw: Vec::new(),
            churn: Vec::new(),
        }
    }

    /// A single device fault window: `device` goes down before
    /// `down_step` and rejoins before `up_step` — the transient-fault
    /// shape every recovery experiment starts from.
    ///
    /// ```
    /// use lime::adapt::{ChurnKind, Script};
    /// let s = Script::device_down_up("fault-d1", 1, 8, 24);
    /// assert_eq!(s.churn.len(), 2);
    /// assert_eq!(s.churn[0].kind, ChurnKind::Down);
    /// assert_eq!(s.churn[1].kind, ChurnKind::Up);
    /// assert!(s.mem.is_empty() && s.bw.is_empty());
    /// ```
    pub fn device_down_up(label: &str, device: usize, down_step: usize, up_step: usize) -> Self {
        assert!(down_step < up_step, "device must rejoin after it departs");
        Script {
            label: label.into(),
            mem: Vec::new(),
            bw: Vec::new(),
            churn: vec![
                ChurnEvent {
                    at_step: down_step,
                    device,
                    kind: ChurnKind::Down,
                },
                ChurnEvent {
                    at_step: up_step,
                    device,
                    kind: ChurnKind::Up,
                },
            ],
        }
    }

    /// Rolling fleet churn: the k-th member of `members` goes down
    /// before `down_step + k × stagger` and rejoins before
    /// `up_step + k × stagger` (each keeps its outage duration) — the
    /// cascading-outage shape, mirroring
    /// [`MemScenario::correlated_dip`]'s lag semantics. At fleet level
    /// `members` are cluster indices and steps are arrival indices.
    ///
    /// ```
    /// use lime::adapt::Script;
    /// let s = Script::fleet_churn("wave", &[0, 2], 3, 4, 10);
    /// let steps: Vec<usize> = s.churn.iter().map(|e| e.at_step).collect();
    /// assert_eq!(steps, vec![4, 7, 10, 13]);
    /// ```
    pub fn fleet_churn(
        label: &str,
        members: &[usize],
        stagger: usize,
        down_step: usize,
        up_step: usize,
    ) -> Self {
        assert!(!members.is_empty(), "fleet churn needs members");
        assert!(down_step < up_step, "members must rejoin after departing");
        let mut churn = Vec::with_capacity(members.len() * 2);
        for (k, &device) in members.iter().enumerate() {
            churn.push(ChurnEvent {
                at_step: down_step + k * stagger,
                device,
                kind: ChurnKind::Down,
            });
            churn.push(ChurnEvent {
                at_step: up_step + k * stagger,
                device,
                kind: ChurnKind::Up,
            });
        }
        churn.sort_by_key(|e| (e.at_step, e.device));
        Script {
            label: label.into(),
            mem: Vec::new(),
            bw: Vec::new(),
            churn,
        }
    }

    /// MTBF-driven probabilistic churn: each of `devices` alternates
    /// exponentially-distributed up-times (mean `1/rate` steps — `rate`
    /// is faults per step) and down-times (mean a quarter of that, so
    /// repair is faster than failure) from an independent seeded stream,
    /// emitting Down/Up pairs until `horizon`. Per-device streams are
    /// seeded as `seed ^ (device+1)·φ64`, so the timeline of one device
    /// never depends on which others churn, and the whole schedule is
    /// reproducible from `(seed, rate, devices, horizon)` alone. Events
    /// come back sorted by `(at_step, device)` — the same channel shape
    /// the executor and the fleet router consume.
    ///
    /// ```
    /// use lime::adapt::{ChurnKind, Script};
    /// let s = Script::churn_mtbf("mtbf", 9, 0.05, &[0, 1], 200);
    /// let again = Script::churn_mtbf("mtbf", 9, 0.05, &[0, 1], 200);
    /// assert_eq!(s, again);
    /// assert!(s.churn.iter().any(|e| e.kind == ChurnKind::Down));
    /// assert!(s.churn.windows(2).all(|w| (w[0].at_step, w[0].device)
    ///     <= (w[1].at_step, w[1].device)));
    /// ```
    pub fn churn_mtbf(
        label: &str,
        seed: u64,
        rate: f64,
        devices: &[usize],
        horizon: usize,
    ) -> Self {
        assert!(rate.is_finite() && rate > 0.0, "fault rate must be finite and > 0");
        assert!(horizon > 0, "churn needs a positive horizon");
        assert!(!devices.is_empty(), "mtbf churn needs devices");
        let mut churn = Vec::new();
        for &device in devices {
            let mut rng = crate::util::rng::Rng::new(
                seed ^ (device as u64 + 1).wrapping_mul(0x9E37_79B9_7F4A_7C15),
            );
            let mut t = 0.0f64;
            loop {
                // Exponential up-time, then a shorter exponential outage.
                t += rng.exponential(rate);
                let down = t.ceil() as usize;
                if down >= horizon {
                    break;
                }
                let up_t = t + rng.exponential(rate * 4.0);
                // An outage always spans at least one arrival/step.
                let up = (up_t.ceil() as usize).max(down + 1);
                churn.push(ChurnEvent {
                    at_step: down,
                    device,
                    kind: ChurnKind::Down,
                });
                if up >= horizon {
                    break;
                }
                churn.push(ChurnEvent {
                    at_step: up,
                    device,
                    kind: ChurnKind::Up,
                });
                t = up as f64;
            }
        }
        churn.sort_by_key(|e| (e.at_step, e.device));
        Script {
            label: label.into(),
            mem: Vec::new(),
            bw: Vec::new(),
            churn,
        }
    }

    /// A bandwidth sag: the link runs at `scale × base` from `from_step`
    /// until `to_step`, then restores. The restore is an absolute
    /// `scale: 1.0` event — see [`Script::with_bandwidth_sag`] for the
    /// replace (not compose) semantics of overlapping windows.
    ///
    /// ```
    /// use lime::adapt::Script;
    /// let s = Script::bandwidth_sag("sag-half", 0.5, 4, 12);
    /// assert_eq!(s.bw.len(), 2);
    /// assert_eq!(s.bw[0].scale, 0.5);
    /// assert_eq!(s.bw[1].scale, 1.0);
    /// assert!(s.mem.is_empty());
    /// ```
    pub fn bandwidth_sag(label: &str, scale: f64, from_step: usize, to_step: usize) -> Self {
        assert!(scale.is_finite() && scale > 0.0, "sag scale must be finite and > 0");
        assert!(from_step < to_step, "sag must restore after it starts");
        Script {
            label: label.into(),
            mem: Vec::new(),
            bw: vec![
                BwEvent {
                    at_step: from_step,
                    scale,
                },
                BwEvent {
                    at_step: to_step,
                    scale: 1.0,
                },
            ],
            churn: Vec::new(),
        }
    }

    /// Build from a joint `(MemEvent | BwEvent | ChurnEvent)` timeline
    /// (events split per channel; bandwidth events re-sorted by step,
    /// stably, so the later entry of a same-step pair still wins; churn
    /// events re-sorted by `(at_step, device)`).
    pub fn from_events(label: &str, events: Vec<ScriptEvent>) -> Self {
        let mut mem = Vec::new();
        let mut bw = Vec::new();
        let mut churn = Vec::new();
        for ev in events {
            match ev {
                ScriptEvent::Mem(e) => mem.push(e),
                ScriptEvent::Bw(e) => bw.push(e),
                ScriptEvent::Churn(e) => churn.push(e),
            }
        }
        bw.sort_by_key(|e| e.at_step);
        churn.sort_by_key(|e: &ChurnEvent| (e.at_step, e.device));
        Script {
            label: label.into(),
            mem,
            bw,
            churn,
        }
    }

    /// Add a bandwidth sag to this script (joint-scenario builder),
    /// keeping the current label.
    ///
    /// Scales are **absolute factors, not multiplied together**: at any
    /// step the latest event at or before it wins, so a sag's restore
    /// event (`scale: 1.0`) also ends any earlier sag still in flight.
    /// Keep sag windows disjoint when stacking several on one script —
    /// overlapping windows replace each other, they do not compose.
    ///
    /// ```
    /// use lime::adapt::{MemScenario, Script};
    /// let joint = Script::from_mem(MemScenario::squeeze("sq", 0, 1024, 3))
    ///     .with_bandwidth_sag(0.5, 3, 9)
    ///     .with_label("joint-sag-squeeze");
    /// assert_eq!(joint.label, "joint-sag-squeeze");
    /// assert!(!joint.mem.is_empty() && !joint.bw.is_empty());
    /// ```
    pub fn with_bandwidth_sag(mut self, scale: f64, from_step: usize, to_step: usize) -> Self {
        let sag = Script::bandwidth_sag("sag", scale, from_step, to_step);
        self.bw.extend(sag.bw);
        self.bw.sort_by_key(|e| e.at_step);
        self
    }

    /// Add a device fault window to this script (joint-scenario
    /// builder), keeping the current label — churn composed with the
    /// mem/bw channels, e.g. a thermal dip plus a link sag plus a device
    /// dropping out, all in one run.
    ///
    /// ```
    /// use lime::adapt::{MemScenario, Script};
    /// let joint = Script::from_mem(MemScenario::correlated_dip("c", &[0, 1], 2, 1024, 4, 10))
    ///     .with_bandwidth_sag(0.5, 4, 12)
    ///     .with_device_down_up(1, 6, 20)
    ///     .with_label("dip-sag-fault");
    /// assert!(!joint.mem.is_empty() && !joint.bw.is_empty() && !joint.churn.is_empty());
    /// ```
    pub fn with_device_down_up(mut self, device: usize, down_step: usize, up_step: usize) -> Self {
        let fault = Script::device_down_up("fault", device, down_step, up_step);
        self.churn.extend(fault.churn);
        self.churn.sort_by_key(|e| (e.at_step, e.device));
        self
    }

    /// Rename the script (stable label used in sweep artifacts).
    pub fn with_label(mut self, label: &str) -> Self {
        self.label = label.into();
        self
    }

    /// True when the script has no events on any channel.
    pub fn is_none(&self) -> bool {
        self.mem.is_empty() && self.bw.is_empty() && self.churn.is_empty()
    }

    /// The joint timeline, sorted by step (memory, then bandwidth, then
    /// churn within a step) — the serialization/display order.
    pub fn events(&self) -> Vec<ScriptEvent> {
        let mut out: Vec<ScriptEvent> = self
            .mem
            .iter()
            .map(|&e| ScriptEvent::Mem(e))
            .chain(self.bw.iter().map(|&e| ScriptEvent::Bw(e)))
            .chain(self.churn.iter().map(|&e| ScriptEvent::Churn(e)))
            .collect();
        out.sort_by_key(|e| {
            let rank = match e {
                ScriptEvent::Mem(_) => 0u8,
                ScriptEvent::Bw(_) => 1,
                ScriptEvent::Churn(_) => 2,
            };
            (e.at_step(), rank)
        });
        out
    }

    /// `(at_step, scale)` points for
    /// [`crate::net::BandwidthTrace::overlay_scales`].
    pub fn bw_scale_points(&self) -> Vec<(usize, f64)> {
        self.bw.iter().map(|e| (e.at_step, e.scale)).collect()
    }

    /// The memory channel as a [`MemScenario`] (label shared) — the shape
    /// `lime-sweep-v3` serializes under the v2-compatible
    /// `axes.mem_scenarios` key.
    pub fn mem_scenario(&self) -> MemScenario {
        MemScenario {
            label: self.label.clone(),
            events: self.mem.clone(),
        }
    }
}

impl From<MemScenario> for Script {
    fn from(scenario: MemScenario) -> Self {
        Script::from_mem(scenario)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn none_has_no_events() {
        assert!(MemScenario::none().is_none());
        assert_eq!(MemScenario::none().label, "none");
        assert!(Script::none().is_none());
        assert_eq!(Script::none().label, "none");
    }

    #[test]
    fn dip_squeezes_then_releases() {
        let s = MemScenario::dip("d", 1, 100, 3, 7);
        assert_eq!(s.events.len(), 2);
        assert_eq!(s.events[0].delta_bytes, -100);
        assert_eq!(s.events[1].delta_bytes, 100);
        assert!(s.events[0].at_step < s.events[1].at_step);
        assert!(!s.is_none());
    }

    #[test]
    #[should_panic]
    fn dip_rejects_inverted_steps() {
        MemScenario::dip("bad", 0, 1, 5, 5);
    }

    #[test]
    fn squeeze_never_releases() {
        let s = MemScenario::squeeze("s", 0, 64, 2);
        assert_eq!(s.events.len(), 1);
        assert!(s.events[0].delta_bytes < 0);
    }

    #[test]
    fn correlated_dip_lags_neighbours_and_restores_everyone() {
        let s = MemScenario::correlated_dip("c", &[0, 2, 3], 2, 100, 4, 10);
        assert_eq!(s.events.len(), 6);
        // Down events at 4/6/8, up events at 10/12/14, same device order.
        let downs: Vec<(usize, usize)> = s
            .events
            .iter()
            .filter(|e| e.delta_bytes < 0)
            .map(|e| (e.device, e.at_step))
            .collect();
        assert_eq!(downs, vec![(0, 4), (2, 6), (3, 8)]);
        // Net delta per device is zero.
        for dev in [0, 2, 3] {
            let net: i64 = s
                .events
                .iter()
                .filter(|e| e.device == dev)
                .map(|e| e.delta_bytes)
                .sum();
            assert_eq!(net, 0, "device {dev}");
        }
    }

    #[test]
    fn correlated_dip_with_zero_lag_is_simultaneous() {
        let s = MemScenario::correlated_dip("c0", &[1, 3], 0, 50, 2, 5);
        assert!(s.events.iter().filter(|e| e.delta_bytes < 0).all(|e| e.at_step == 2));
        assert!(s.events.iter().filter(|e| e.delta_bytes > 0).all(|e| e.at_step == 5));
    }

    #[test]
    fn staggered_squeeze_orders_by_position() {
        let s = MemScenario::staggered_squeeze("r", &[5, 1, 2], 4, 64, 3);
        let steps: Vec<usize> = s.events.iter().map(|e| e.at_step).collect();
        assert_eq!(steps, vec![3, 7, 11]);
        assert!(s.events.iter().all(|e| e.delta_bytes == -64));
    }

    #[test]
    fn ramp_restores_exactly_including_remainder() {
        let s = MemScenario::dip_with_ramp("r", 0, 100, 1, 4, 3);
        // 100 / 3 = 33 + 33 + 34.
        let incs: Vec<i64> = s.events[1..].iter().map(|e| e.delta_bytes).collect();
        assert_eq!(incs, vec![33, 33, 34]);
        assert_eq!(s.events.iter().map(|e| e.delta_bytes).sum::<i64>(), 0);
        let steps: Vec<usize> = s.events[1..].iter().map(|e| e.at_step).collect();
        assert_eq!(steps, vec![4, 5, 6]);
    }

    #[test]
    fn merged_sorts_and_keeps_all_events() {
        let a = MemScenario::squeeze("a", 1, 10, 8);
        let b = MemScenario::dip("b", 0, 5, 2, 6);
        let m = MemScenario::merged("m", &[a, b]);
        assert_eq!(m.events.len(), 3);
        assert!(m.events.windows(2).all(|w| w[0].at_step <= w[1].at_step));
    }

    #[test]
    fn bandwidth_sag_restores_scale() {
        let s = Script::bandwidth_sag("sag", 0.25, 3, 9);
        assert_eq!(s.bw_scale_points(), vec![(3, 0.25), (9, 1.0)]);
        assert!(!s.is_none());
    }

    #[test]
    #[should_panic]
    fn sag_rejects_nonpositive_scale() {
        Script::bandwidth_sag("bad", 0.0, 1, 2);
    }

    #[test]
    fn joint_timeline_interleaves_channels_in_step_order() {
        let sq = Script::from_mem(MemScenario::squeeze("sq", 0, 10, 5));
        let s = sq.with_bandwidth_sag(0.5, 3, 7);
        let steps: Vec<usize> = s.events().iter().map(ScriptEvent::at_step).collect();
        assert_eq!(steps, vec![3, 5, 7]);
    }

    #[test]
    fn from_events_splits_channels() {
        let restore = BwEvent { at_step: 6, scale: 1.0 };
        let sag = BwEvent { at_step: 2, scale: 0.5 };
        let squeeze = MemEvent {
            at_step: 2,
            device: 0,
            delta_bytes: -8,
        };
        let s = Script::from_events(
            "j",
            vec![
                ScriptEvent::Bw(restore),
                ScriptEvent::Mem(squeeze),
                ScriptEvent::Bw(sag),
            ],
        );
        assert_eq!(s.mem.len(), 1);
        assert_eq!(s.bw_scale_points(), vec![(2, 0.5), (6, 1.0)]);
    }

    #[test]
    fn device_down_up_orders_fault_then_recovery() {
        let s = Script::device_down_up("f", 2, 5, 9);
        assert_eq!(s.churn.len(), 2);
        assert_eq!(
            (s.churn[0].at_step, s.churn[0].device, s.churn[0].kind),
            (5, 2, ChurnKind::Down)
        );
        assert_eq!(
            (s.churn[1].at_step, s.churn[1].device, s.churn[1].kind),
            (9, 2, ChurnKind::Up)
        );
        assert!(!s.is_none());
    }

    #[test]
    #[should_panic]
    fn device_down_up_rejects_inverted_steps() {
        Script::device_down_up("bad", 0, 7, 7);
    }

    #[test]
    fn fleet_churn_staggers_and_restores_everyone() {
        let s = Script::fleet_churn("wave", &[1, 3], 4, 2, 8);
        assert_eq!(s.churn.len(), 4);
        for &m in &[1usize, 3] {
            let downs = s
                .churn
                .iter()
                .filter(|e| e.device == m && e.kind == ChurnKind::Down)
                .count();
            let ups = s
                .churn
                .iter()
                .filter(|e| e.device == m && e.kind == ChurnKind::Up)
                .count();
            assert_eq!((downs, ups), (1, 1), "member {m}");
        }
        assert!(s.churn.windows(2).all(|w| w[0].at_step <= w[1].at_step));
    }

    #[test]
    fn churn_mtbf_is_deterministic_and_well_formed() {
        let a = Script::churn_mtbf("mtbf", 0xC0FFEE, 0.03, &[0, 2], 400);
        let b = Script::churn_mtbf("mtbf", 0xC0FFEE, 0.03, &[0, 2], 400);
        assert_eq!(a, b, "same inputs must reproduce the same schedule");
        assert!(
            a.churn.iter().any(|e| e.kind == ChurnKind::Down),
            "mean up-time ~33 steps over a 400-step horizon must fault"
        );
        assert!(
            a.churn.windows(2).all(|w| (w[0].at_step, w[0].device) <= (w[1].at_step, w[1].device)),
            "channel must come back sorted by (step, device)"
        );
        assert!(a.churn.iter().all(|e| e.at_step < 400), "no event past the horizon");
        // Per device, kinds strictly alternate starting with Down.
        for &d in &[0usize, 2] {
            let kinds: Vec<ChurnKind> = a
                .churn
                .iter()
                .filter(|e| e.device == d)
                .map(|e| e.kind)
                .collect();
            assert!(!kinds.is_empty(), "device {d} must churn at this rate");
            for (i, k) in kinds.iter().enumerate() {
                let want = if i % 2 == 0 { ChurnKind::Down } else { ChurnKind::Up };
                assert_eq!(*k, want, "device {d} event {i}");
            }
        }
        let different = Script::churn_mtbf("mtbf", 0xBEEF, 0.03, &[0, 2], 400);
        assert_ne!(a.churn, different.churn, "the seed must matter");
    }

    #[test]
    fn churn_mtbf_streams_are_independent_per_device() {
        // Adding a device must not perturb the schedule of an existing
        // one — streams are seeded per device index, not shared.
        let solo = Script::churn_mtbf("m", 42, 0.05, &[7], 300);
        let duo = Script::churn_mtbf("m", 42, 0.05, &[7, 9], 300);
        let solo_d7: Vec<_> = solo.churn.iter().filter(|e| e.device == 7).collect();
        let duo_d7: Vec<_> = duo.churn.iter().filter(|e| e.device == 7).collect();
        assert_eq!(solo_d7, duo_d7);
    }

    #[test]
    #[should_panic]
    fn churn_mtbf_rejects_a_degenerate_rate() {
        Script::churn_mtbf("bad", 1, 0.0, &[0], 100);
    }

    #[test]
    fn churn_composes_with_mem_and_bw_channels() {
        let joint = Script::from_mem(MemScenario::correlated_dip("c", &[0, 1], 2, 64, 4, 10))
            .with_bandwidth_sag(0.5, 4, 12)
            .with_device_down_up(1, 6, 20);
        assert!(!joint.mem.is_empty());
        assert!(!joint.bw.is_empty());
        assert_eq!(joint.churn.len(), 2);
        // Joint timeline keeps all three channels, step-ordered with
        // mem < bw < churn within a step.
        let evs = joint.events();
        assert!(evs
            .windows(2)
            .all(|w| w[0].at_step() <= w[1].at_step()));
        assert_eq!(
            evs.len(),
            joint.mem.len() + joint.bw.len() + joint.churn.len()
        );
    }

    #[test]
    fn from_events_splits_churn_channel_and_sorts_it() {
        let s = Script::from_events(
            "j",
            vec![
                ScriptEvent::Churn(ChurnEvent {
                    at_step: 9,
                    device: 0,
                    kind: ChurnKind::Up,
                }),
                ScriptEvent::Mem(MemEvent {
                    at_step: 2,
                    device: 1,
                    delta_bytes: -8,
                }),
                ScriptEvent::Churn(ChurnEvent {
                    at_step: 3,
                    device: 0,
                    kind: ChurnKind::Down,
                }),
            ],
        );
        assert_eq!(s.mem.len(), 1);
        assert_eq!(s.churn.len(), 2);
        assert_eq!(s.churn[0].kind, ChurnKind::Down);
        assert_eq!(s.churn[1].kind, ChurnKind::Up);
    }

    #[test]
    fn empty_churn_channel_keeps_legacy_scripts_none_free() {
        // Every pre-churn constructor must leave the churn channel empty
        // (the executor's empty-channel fast path depends on it).
        assert!(Script::none().churn.is_empty());
        assert!(Script::from_mem(MemScenario::squeeze("s", 0, 8, 1)).churn.is_empty());
        assert!(Script::bandwidth_sag("b", 0.5, 1, 2).churn.is_empty());
        assert!(Script::from_mem_events("m", Vec::new()).churn.is_empty());
        assert!(Script::from_events("e", Vec::new()).churn.is_empty());
    }

    #[test]
    fn mem_scenario_projection_shares_label() {
        let sq = Script::from_mem(MemScenario::squeeze("sq", 0, 10, 5));
        let s = sq.with_bandwidth_sag(0.5, 1, 3);
        let m = s.mem_scenario();
        assert_eq!(m.label, "sq");
        assert_eq!(m.events, s.mem);
    }
}
