//! Online memory-aware planner (paper §IV-D, Eqs. 5–7, Fig. 9).
//!
//! As the KV cache grows past per-device thresholds `TS_i^j`, the planner
//! triggers block-granular offload plans `(α, β)` — α MHA blocks and β MLP
//! blocks evicted from residency — chosen to *minimize the extra bytes
//! streamed per step* (Eq. 6) subject to freeing enough memory for the KV
//! cache to keep growing (Eq. 7). Because the same plan applies to every
//! segment of the interleaved pipeline, the freed memory is
//! `(α·p_A + β·p_M)·l_size·(#Seg−1)/#Seg` (one segment's slot stays mapped)
//! and the extra loading cost is overlapped across segments — "only a
//! single additional loading overhead".
//!
//! The planner is a pure state machine: both the discrete-event simulator
//! and the real PJRT serving engine drive it with
//! [`OnlinePlanner::on_token`].

use crate::cluster::Cluster;
use crate::cost;
use crate::model::ModelSpec;
use crate::plan::allocation::Allocation;

/// One triggered offload plan.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct OffloadPlan {
    /// Generated-token count at which this plan fired (`TS_i^j`).
    pub at_tokens: usize,
    /// MHA blocks to stream from SSD (beyond the offline allocation).
    pub alpha: usize,
    /// MLP blocks to stream from SSD (beyond the offline allocation).
    pub beta: usize,
}

impl OffloadPlan {
    /// Extra bytes streamed per token pass under this plan (Eq. 6 value).
    pub fn extra_load_bytes(&self, spec: &ModelSpec) -> u64 {
        self.alpha as u64 * spec.mha_bytes() + self.beta as u64 * spec.mlp_bytes()
    }
}

/// Per-device planner state.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct DeviceMemState {
    /// Free bytes right after offline allocation (before any KV),
    /// net of scripted pressure (`slack_base` shifted by `pressure_bytes`,
    /// clamped at zero).
    pub slack_bytes: u64,
    /// Unpressured slack at planner construction.
    pub slack_base: u64,
    /// Cumulative scripted pressure (negative = memory taken away).
    /// Tracked separately so a dip (−X then +X) restores `slack_bytes`
    /// exactly even when the squeeze saturated it at zero.
    pub pressure_bytes: i64,
    /// KV bytes appended per generated token on this device.
    pub kv_per_token: u64,
    /// MHA blocks still resident and evictable.
    pub alpha_avail: usize,
    /// MLP blocks still resident and evictable.
    pub beta_avail: usize,
    /// Current cumulative plan (α, β) in force.
    pub current: OffloadPlan,
    /// Next trigger threshold `TS_i^{j+1}` in generated tokens
    /// (`usize::MAX` once nothing more can be freed).
    pub next_threshold: usize,
    /// All plans fired so far (for reporting / tests).
    pub history: Vec<OffloadPlan>,
}

/// Online planner over all devices of one allocation.
#[derive(Debug, Clone, PartialEq)]
pub struct OnlinePlanner {
    spec: ModelSpec,
    seg: usize,
    pub states: Vec<DeviceMemState>,
}

impl OnlinePlanner {
    /// Build from the offline allocation at token 0. `micro` scales the KV
    /// growth rate (bursty pattern appends `micro` tokens per step).
    pub fn new(alloc: &Allocation, cluster: &Cluster, micro: usize) -> Self {
        let mut p = OnlinePlanner {
            spec: alloc.spec.clone(),
            seg: alloc.seg.max(2), // plan granularity even for seg=1 plans
            states: Vec::with_capacity(alloc.devices.len()),
        };
        p.reset(alloc, cluster, micro);
        p
    }

    /// Re-initialize in place to exactly the state [`OnlinePlanner::new`]
    /// builds (pinned by `reset_equals_new_after_use`), reusing the state
    /// and history buffers — the per-request arena path: a stream's
    /// `begin_request` calls this instead of reallocating a planner.
    pub fn reset(&mut self, alloc: &Allocation, cluster: &Cluster, micro: usize) {
        if self.spec != alloc.spec {
            self.spec = alloc.spec.clone();
        }
        self.seg = alloc.seg.max(2);
        self.states.resize_with(alloc.devices.len(), DeviceMemState::default);
        for (i, st) in self.states.iter_mut().enumerate() {
            let a = &alloc.devices[i];
            let used = cost::mem_demand(alloc, i, 0, 0);
            let slack = cluster.devices[i].usable_mem().saturating_sub(used);
            st.slack_bytes = slack;
            st.slack_base = slack;
            st.pressure_bytes = 0;
            st.kv_per_token =
                self.spec.kv_bytes_per_token_layer() * a.total_layers as u64 * micro as u64;
            // Evictable blocks: fully-resident layers expose both blocks;
            // split layers expose their pinned block.
            st.alpha_avail = a.non_offloaded_layers() + a.mlp_offload;
            st.beta_avail = a.non_offloaded_layers() + a.mha_offload;
            st.current = OffloadPlan::default();
            st.history.clear();
            st.next_threshold = first_threshold(st);
        }
    }

    pub fn seg(&self) -> usize {
        self.seg
    }

    /// Advance device `i` to `tokens` generated tokens with
    /// `kv_transferred` KV tokens shipped to a peer (negative = received).
    /// Returns the new plan if a threshold fired.
    pub fn on_token(
        &mut self,
        i: usize,
        tokens: usize,
        kv_transferred: i64,
    ) -> Option<OffloadPlan> {
        let spec = self.spec.clone();
        let seg = self.seg;
        let st = &mut self.states[i];
        let effective = effective_tokens(tokens, kv_transferred);
        if effective < st.next_threshold {
            return None;
        }
        // Eq. 7 deficit at the trigger point, projected over a lookahead
        // horizon so plans don't fire every token.
        let lookahead = (effective / 4).clamp(32, 256);
        let need = st.kv_per_token * (effective + lookahead) as u64;
        let have = st.slack_bytes;
        let deficit = need.saturating_sub(have);
        let plan = choose_plan(&spec, seg, st, effective, deficit)?;
        // Apply: blocks move from resident to streamed.
        let da = plan.alpha as i64 - st.current.alpha as i64;
        let db = plan.beta as i64 - st.current.beta as i64;
        st.alpha_avail = (st.alpha_avail as i64 - da).max(0) as usize;
        st.beta_avail = (st.beta_avail as i64 - db).max(0) as usize;
        st.current = plan;
        st.history.push(plan);
        st.next_threshold = next_threshold(&spec, seg, st);
        Some(plan)
    }

    /// Apply a scripted memory-fluctuation event to device `i`: shift its
    /// post-allocation slack by `delta_bytes` (negative = external
    /// pressure) and re-derive the next trigger threshold from the plan
    /// currently in force. Shrinking slack pulls `TS_i^{j+1}` forward —
    /// possibly below the current token count, in which case the very
    /// next [`OnlinePlanner::on_token`] fires a plan; restoring slack
    /// pushes it back out. Pressure accumulates against the unpressured
    /// base and only the *effective* slack clamps at zero, so a dip
    /// (−X then +X) is exactly a no-op even when the squeeze exceeded the
    /// available slack.
    pub fn apply_pressure(&mut self, i: usize, delta_bytes: i64) {
        let spec = self.spec.clone();
        let seg = self.seg;
        let st = &mut self.states[i];
        st.pressure_bytes = st.pressure_bytes.saturating_add(delta_bytes);
        st.slack_bytes = shifted(st.slack_base, st.pressure_bytes);
        st.next_threshold = next_threshold(&spec, seg, st);
    }

    /// Current extra streamed bytes per pass for device `i`.
    pub fn extra_load_bytes(&self, i: usize) -> u64 {
        self.states[i].current.extra_load_bytes(&self.spec)
    }

    /// `TS_i^{j+1}` — used by the KV-transfer protocol's bandwidth-increase
    /// rule (Alg. 2 line 15).
    pub fn next_threshold(&self, i: usize) -> usize {
        self.states[i].next_threshold
    }

    /// Device with the largest next threshold — the preferred `d_target`.
    /// Devices with no KV growth (`kv_per_token == 0`, i.e. zero assigned
    /// layers — the shape churn re-plans give a departed device) are
    /// skipped: they hold no model state, so they cannot receive KV.
    pub fn highest_threshold_device(&self) -> usize {
        (0..self.states.len())
            .filter(|&i| self.states[i].kv_per_token > 0)
            .max_by_key(|&i| self.states[i].next_threshold)
            .unwrap_or(0)
    }
}

fn effective_tokens(tokens: usize, kv_transferred: i64) -> usize {
    (tokens as i64 - kv_transferred).max(0) as usize
}

/// `base` shifted by a signed cumulative `pressure`, clamped at zero.
pub(crate) fn shifted(base: u64, pressure: i64) -> u64 {
    if pressure >= 0 {
        base.saturating_add(pressure as u64)
    } else {
        base.saturating_sub(pressure.unsigned_abs())
    }
}

/// `TS_i^1` (Eq. 5): slack divided by per-token KV growth.
fn first_threshold(st: &DeviceMemState) -> usize {
    if st.kv_per_token == 0 {
        return usize::MAX;
    }
    (st.slack_bytes / st.kv_per_token) as usize
}

/// Freed bytes of a cumulative plan (Eq. 7 right-hand side).
fn freed_bytes(spec: &ModelSpec, seg: usize, plan: &OffloadPlan) -> u64 {
    let raw = plan.extra_load_bytes(spec);
    raw * (seg as u64 - 1) / seg as u64
}

/// Eq. 6: minimal-extra-load cumulative plan covering `deficit` bytes.
fn choose_plan(
    spec: &ModelSpec,
    seg: usize,
    st: &DeviceMemState,
    at_tokens: usize,
    deficit: u64,
) -> Option<OffloadPlan> {
    let max_alpha = st.current.alpha + st.alpha_avail;
    let max_beta = st.current.beta + st.beta_avail;
    let mut best: Option<OffloadPlan> = None;
    for alpha in 0..=max_alpha {
        for beta in 0..=max_beta {
            let cand = OffloadPlan {
                at_tokens,
                alpha,
                beta,
            };
            if freed_bytes(spec, seg, &cand) < deficit {
                continue;
            }
            let better = match &best {
                None => true,
                Some(b) => cand.extra_load_bytes(spec) < b.extra_load_bytes(spec),
            };
            if better {
                best = Some(cand);
            }
        }
    }
    // Plans never shrink below what is already in force.
    best.filter(|p| p.alpha >= st.current.alpha || p.beta >= st.current.beta)
}

/// `TS_i^{j+1}` after a plan: when KV growth eats slack + freed bytes.
fn next_threshold(spec: &ModelSpec, seg: usize, st: &DeviceMemState) -> usize {
    if st.kv_per_token == 0 {
        return usize::MAX;
    }
    let capacity = st.slack_bytes + freed_bytes(spec, seg, &st.current);
    let t = (capacity / st.kv_per_token) as usize;
    if st.alpha_avail == 0 && st.beta_avail == 0 {
        // Nothing more to free: after `t` the device is hard-saturated and
        // only KV transfer can help.
        return usize::MAX.min(t.max(st.current.at_tokens + 1));
    }
    t.max(st.current.at_tokens + 1)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::plan::allocation::DeviceAssignment;
    use crate::plan::{plan, PlanOptions};
    use crate::util::bytes::mbps;

    fn lowmem_setup() -> (Allocation, Cluster) {
        let spec = ModelSpec::llama33_70b();
        let cluster = Cluster::lowmem_setting1();
        let opts = PlanOptions {
            empirical_tokens: 256,
            micro_batch: 1,
            bandwidth: mbps(200.0),
        };
        (plan(&spec, &cluster, &opts).unwrap().allocation, cluster)
    }

    #[test]
    fn thresholds_positive_and_finite_under_pressure() {
        let (alloc, cluster) = lowmem_setup();
        let planner = OnlinePlanner::new(&alloc, &cluster, 1);
        for (i, st) in planner.states.iter().enumerate() {
            assert!(st.kv_per_token > 0, "device {i} has layers, so KV grows");
            assert!(st.next_threshold > 0);
        }
    }

    #[test]
    fn plan_fires_when_threshold_crossed() {
        let (alloc, cluster) = lowmem_setup();
        let mut planner = OnlinePlanner::new(&alloc, &cluster, 1);
        let i = (0..planner.states.len())
            .filter(|&i| planner.states[i].next_threshold < usize::MAX)
            .min_by_key(|&i| planner.states[i].next_threshold)
            .unwrap();
        let ts1 = planner.states[i].next_threshold;
        assert!(planner.on_token(i, ts1.saturating_sub(1), 0).is_none());
        let fired = planner.on_token(i, ts1 + 1, 0);
        if let Some(p) = fired {
            assert!(p.alpha + p.beta > 0);
            assert!(planner.extra_load_bytes(i) > 0);
            assert!(planner.next_threshold(i) > ts1);
        }
    }

    #[test]
    fn eq6_prefers_smaller_block_for_small_deficit() {
        let spec = ModelSpec::llama33_70b(); // MHA block < MLP block
        let st = DeviceMemState {
            slack_bytes: 0,
            slack_base: 0,
            pressure_bytes: 0,
            kv_per_token: 1,
            alpha_avail: 4,
            beta_avail: 4,
            current: OffloadPlan {
                at_tokens: 0,
                alpha: 0,
                beta: 0,
            },
            next_threshold: 0,
            history: vec![],
        };
        // Deficit smaller than a freed MHA block -> plan = 1 MHA, 0 MLP.
        let deficit = spec.mha_bytes() / 4;
        let plan = choose_plan(&spec, 2, &st, 10, deficit).unwrap();
        assert_eq!((plan.alpha, plan.beta), (1, 0));
    }

    #[test]
    fn eq6_uses_mlp_when_deficit_bigger() {
        let spec = ModelSpec::llama33_70b();
        let st = DeviceMemState {
            slack_bytes: 0,
            slack_base: 0,
            pressure_bytes: 0,
            kv_per_token: 1,
            alpha_avail: 4,
            beta_avail: 4,
            current: OffloadPlan {
                at_tokens: 0,
                alpha: 0,
                beta: 0,
            },
            next_threshold: 0,
            history: vec![],
        };
        // Deficit bigger than freed(MHA) but under freed(MLP): swap to MLP
        // (Fig. 9's TS^2 step) rather than stacking two plans.
        let deficit = spec.mha_bytes(); // freed(mha)=mha/2 at seg=2 < deficit
        let plan = choose_plan(&spec, 2, &st, 10, deficit).unwrap();
        assert!(plan.extra_load_bytes(&spec) >= deficit * 2 - 1);
        assert!(
            plan.extra_load_bytes(&spec) <= spec.mlp_bytes(),
            "should pick one MLP block (or cheaper), got {plan:?}"
        );
    }

    #[test]
    fn kv_transfer_delays_threshold() {
        let (alloc, cluster) = lowmem_setup();
        let mut planner = OnlinePlanner::new(&alloc, &cluster, 1);
        let i = (0..planner.states.len())
            .filter(|&i| planner.states[i].next_threshold < usize::MAX)
            .min_by_key(|&i| planner.states[i].next_threshold)
            .unwrap();
        let ts1 = planner.states[i].next_threshold;
        // Having shipped `ts1` tokens of KV away, the same token count does
        // not trigger.
        assert!(planner.on_token(i, ts1 + 1, ts1 as i64).is_none());
    }

    #[test]
    fn exhausted_device_reports_saturation() {
        let spec = ModelSpec::llama2_13b();
        let alloc = Allocation::new(
            spec.clone(),
            2,
            vec![DeviceAssignment {
                total_layers: 40,
                full_offload: 40,
                mha_offload: 0,
                mlp_offload: 0,
            }],
        );
        let cluster = Cluster::new(vec![crate::cluster::DeviceSpec::xavier_nx_16()]);
        let planner = OnlinePlanner::new(&alloc, &cluster, 1);
        // All layers already streamed: nothing evictable.
        assert_eq!(planner.states[0].alpha_avail, 0);
        assert_eq!(planner.states[0].beta_avail, 0);
    }

    #[test]
    fn reset_equals_new_after_use() {
        // The arena contract: however far a planner has been driven —
        // fired plans, scripted pressure, shipped KV — `reset` must land on
        // exactly the state a fresh `new` builds, for any micro width.
        let (alloc, cluster) = lowmem_setup();
        let mut used = OnlinePlanner::new(&alloc, &cluster, 1);
        for i in 0..used.states.len() {
            used.apply_pressure(i, -(1 << 28));
            for tok in (0..4096).step_by(64) {
                used.on_token(i, tok, (tok / 8) as i64);
            }
        }
        for micro in [1usize, 3] {
            used.reset(&alloc, &cluster, micro);
            assert_eq!(used, OnlinePlanner::new(&alloc, &cluster, micro));
        }
    }

    #[test]
    fn micro_batch_accelerates_thresholds() {
        let (alloc, cluster) = lowmem_setup();
        let p1 = OnlinePlanner::new(&alloc, &cluster, 1);
        let p4 = OnlinePlanner::new(&alloc, &cluster, 4);
        for i in 0..p1.states.len() {
            if p1.states[i].next_threshold < usize::MAX {
                assert!(p4.states[i].next_threshold <= p1.states[i].next_threshold);
            }
        }
    }
}
