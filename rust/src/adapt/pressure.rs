//! Scripted memory-fluctuation events — the stand-in for real-world
//! memory pressure on shared edge devices (a camera pipeline waking up, a
//! containerized co-tenant ballooning, thermal throttling of the unified
//! pool). The scenario-matrix sweep drives these through the interleaved
//! executor: each event shrinks (or restores) one device's usable memory
//! *mid-simulation*, which lowers the online planner's offload thresholds
//! (Eqs. 5–7) and pulls the KV-transfer protocol's imminence window
//! forward — the paper's §IV-D machinery finally shows up in sweep
//! outputs instead of only firing when the KV cache alone outgrows slack.
//!
//! Scripts are plain data: deterministic given the event list, replayable
//! at any worker count, and serialized verbatim into the `lime-sweep-v2`
//! axis metadata so artifacts are self-describing.

/// One scripted change to a device's usable memory.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct MemEvent {
    /// Decode step (0-based) *before* which the event applies.
    pub at_step: usize,
    /// Device index in the cluster.
    pub device: usize,
    /// Signed change in usable bytes (negative = pressure, positive =
    /// restoration). Applied saturating at zero.
    pub delta_bytes: i64,
}

/// A named memory-fluctuation scenario: a label (stable across sweep
/// artifacts) plus its event script. An empty script is the "none"
/// baseline every non-adaptive method is measured at.
#[derive(Debug, Clone, PartialEq)]
pub struct MemScenario {
    pub label: String,
    pub events: Vec<MemEvent>,
}

impl MemScenario {
    /// The no-pressure baseline scenario.
    pub fn none() -> Self {
        MemScenario {
            label: "none".into(),
            events: Vec::new(),
        }
    }

    /// A dip: `device` loses `bytes` before `down_step`, regains them
    /// before `up_step` — the transient-co-tenant shape.
    pub fn dip(label: &str, device: usize, bytes: u64, down_step: usize, up_step: usize) -> Self {
        assert!(down_step < up_step, "dip must release after it squeezes");
        MemScenario {
            label: label.into(),
            events: vec![
                MemEvent {
                    at_step: down_step,
                    device,
                    delta_bytes: -(bytes as i64),
                },
                MemEvent {
                    at_step: up_step,
                    device,
                    delta_bytes: bytes as i64,
                },
            ],
        }
    }

    /// A squeeze: `device` loses `bytes` before `at_step` and never gets
    /// them back — the persistent-co-tenant shape.
    pub fn squeeze(label: &str, device: usize, bytes: u64, at_step: usize) -> Self {
        MemScenario {
            label: label.into(),
            events: vec![MemEvent {
                at_step,
                device,
                delta_bytes: -(bytes as i64),
            }],
        }
    }

    pub fn is_none(&self) -> bool {
        self.events.is_empty()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn none_has_no_events() {
        assert!(MemScenario::none().is_none());
        assert_eq!(MemScenario::none().label, "none");
    }

    #[test]
    fn dip_squeezes_then_releases() {
        let s = MemScenario::dip("d", 1, 100, 3, 7);
        assert_eq!(s.events.len(), 2);
        assert_eq!(s.events[0].delta_bytes, -100);
        assert_eq!(s.events[1].delta_bytes, 100);
        assert!(s.events[0].at_step < s.events[1].at_step);
        assert!(!s.is_none());
    }

    #[test]
    #[should_panic]
    fn dip_rejects_inverted_steps() {
        MemScenario::dip("bad", 0, 1, 5, 5);
    }

    #[test]
    fn squeeze_never_releases() {
        let s = MemScenario::squeeze("s", 0, 64, 2);
        assert_eq!(s.events.len(), 1);
        assert!(s.events[0].delta_bytes < 0);
    }
}
