//! Network-bandwidth-sensitive KV-cache transfer protocol (paper §IV-D,
//! Alg. 2, Eq. 8, Fig. 10).
//!
//! Devices whose SSD loading cannot be hidden behind compute+communication
//! (`load(L~_i) > T_i^idle`) ship part of their KV cache to a dedicated
//! high-threshold peer `d_target`, freeing memory that *delays their next
//! offload threshold* and keeping the pipeline's loading overlapped. The
//! shipped volume follows Eq. 8:
//!
//! ```text
//! mem(n_i^trans) = (load(L~_i) − T_i^idle) · bw_net   (clamped at ≥ 0)
//! ```
//!
//! Bandwidth reactions are asymmetric (Alg. 2 lines 8–18):
//! * **decrease** — recompute `n_trans` immediately (continuing to ship the
//!   old volume would stall the pipeline);
//! * **increase** — lazily skip unless the device is about to hit its next
//!   threshold `TS_i^{j+1}` (line 15), avoiding churn;
//! * changes smaller than the hysteresis threshold `n_ts` are ignored
//!   (line 14).

use crate::adapt::planner::OnlinePlanner;
use crate::cluster::Cluster;
use crate::cost;
use crate::plan::allocation::Allocation;

/// Per-device transfer state.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct TransferState {
    /// Dedicated receiver of this device's KV cache (None = this device is
    /// itself a `d_target` or never needs to ship).
    pub target: Option<usize>,
    /// Tokens of KV currently held by the peer on this device's behalf
    /// (`n_i^trans`; negative on receivers).
    pub n_trans: i64,
    /// Desired steady-state shipment (recomputed on bandwidth changes).
    pub desired: i64,
}

/// The protocol driver.
#[derive(Debug, Clone, PartialEq)]
pub struct KvTransferProtocol {
    pub states: Vec<TransferState>,
    /// Hysteresis threshold `n_ts` in tokens.
    pub n_ts: i64,
    last_bw: f64,
    /// Tokens of safety margin before a threshold counts as "about to be
    /// reached" for the lazy bandwidth-increase rule.
    pub threshold_margin: usize,
}

impl KvTransferProtocol {
    /// Pair every uncovered device with the highest-threshold peer and
    /// compute initial `n_trans` via Eq. 8.
    pub fn new(
        alloc: &Allocation,
        cluster: &Cluster,
        planner: &OnlinePlanner,
        ctx: usize,
        micro: usize,
        bw: f64,
    ) -> Self {
        let mut p = KvTransferProtocol {
            states: Vec::with_capacity(alloc.devices.len()),
            n_ts: 8,
            last_bw: bw,
            threshold_margin: 16,
        };
        p.reset(alloc, cluster, planner, ctx, micro, bw);
        p
    }

    /// Re-initialize in place to exactly the state
    /// [`KvTransferProtocol::new`] builds (pinned by
    /// `reset_equals_new_after_use`), reusing the state buffer — the
    /// per-request arena path for continuous streams.
    pub fn reset(
        &mut self,
        alloc: &Allocation,
        cluster: &Cluster,
        planner: &OnlinePlanner,
        ctx: usize,
        micro: usize,
        bw: f64,
    ) {
        let n = alloc.devices.len();
        self.states.clear();
        self.states.resize_with(n, TransferState::default);

        let target = planner.highest_threshold_device();
        for i in 0..n {
            if i == target {
                continue; // the target receives; it never ships its own
            }
            let desired = eq8_tokens(alloc, cluster, i, ctx, micro, bw);
            if desired > 0 {
                self.states[i].target = Some(target);
                self.states[i].desired = desired;
            }
        }
        self.n_ts = 8;
        self.last_bw = bw;
        self.threshold_margin = 16;
    }

    /// Alg. 2 lines 8–18: react to the bandwidth observed before an
    /// auto-regressive step. Returns the devices whose desired shipment
    /// changed.
    pub fn on_bandwidth(
        &mut self,
        alloc: &Allocation,
        cluster: &Cluster,
        planner: &OnlinePlanner,
        tokens: usize,
        ctx: usize,
        micro: usize,
        bw_now: f64,
    ) -> Vec<usize> {
        let mut changed = Vec::new();
        let decreased = bw_now < self.last_bw;
        for i in 0..self.states.len() {
            if self.states[i].target.is_none() {
                continue;
            }
            let fresh = eq8_tokens(alloc, cluster, i, ctx, micro, bw_now);
            let delta = (fresh - self.states[i].desired).abs();
            if delta < self.n_ts {
                continue; // line 14: ignore minor fluctuations
            }
            if !decreased {
                // Bandwidth increased: only act if the next threshold is
                // imminent (line 15), otherwise skip entirely (line 16).
                let ts_next = planner.next_threshold(i);
                let imminent = ts_next != usize::MAX
                    && tokens + self.states[i].n_trans.unsigned_abs() as usize
                        + self.threshold_margin
                        >= ts_next;
                if !imminent {
                    continue;
                }
            }
            self.states[i].desired = fresh;
            changed.push(i);
        }
        self.last_bw = bw_now;
        changed
    }

    /// Tokens to ship from device `i` this step (pacing toward `desired`),
    /// given it currently holds `held_tokens` of KV.
    pub fn ship_now(&mut self, i: usize, held_tokens: usize, per_step_cap: usize) -> usize {
        let st = &mut self.states[i];
        if st.target.is_none() {
            return 0;
        }
        let gap = st.desired - st.n_trans;
        if gap <= 0 {
            return 0;
        }
        let ship = (gap as usize).min(per_step_cap).min(held_tokens);
        st.n_trans += ship as i64;
        if let Some(t) = st.target {
            // `t` is guaranteed not to be a shipper itself.
            debug_assert!(self.states[t].target.is_none());
        }
        ship
    }

    /// Record the receiving side (negative `n_trans`).
    pub fn record_receipt(&mut self, target: usize, tokens: usize) {
        self.states[target].n_trans -= tokens as i64;
    }

    /// Net shipped tokens for device `i` (feeds `cost::mem_demand` and the
    /// planner's `kv_transferred`).
    pub fn n_trans(&self, i: usize) -> i64 {
        self.states[i].n_trans
    }
}

/// KV bytes resident on device `i` for a context of `tokens` tokens — the
/// volume churn migration ships over the shared link when `i` departs
/// (its whole holding moves to survivors) or rejoins (survivors ship the
/// KV its newly assigned layers need). Same per-token-per-layer unit as
/// Eq. 8's denominator, so migrated volume and Eq. 8 shipments stay
/// directly comparable in artifacts.
///
/// Known limit: the `kv_ctx` window cap applies here, but the executor's
/// `kv_held` token bookkeeping grows uncapped — a sliding-window spec
/// served long enough would migrate fewer bytes than `kv_held` implies.
/// Latent today: window variants are unit-test constructors only (no
/// matrix/fleet path builds one — see the ROADMAP follow-on about
/// promoting KV-shape variants to a matrix axis).
pub fn resident_kv_bytes(alloc: &Allocation, i: usize, tokens: usize) -> u64 {
    alloc.spec.kv_bytes_per_token_layer()
        * alloc.devices[i].total_layers as u64
        * alloc.spec.kv_ctx(tokens) as u64
}

/// Eq. 8: KV tokens whose transfer hides the uncovered load of device `i`.
pub fn eq8_tokens(
    alloc: &Allocation,
    cluster: &Cluster,
    i: usize,
    ctx: usize,
    micro: usize,
    bw: f64,
) -> i64 {
    let spec = &alloc.spec;
    let load = cost::load_time(spec, &cluster.devices[i], &alloc.devices[i]);
    let idle = cost::t_idle(alloc, cluster, i, ctx, micro, bw);
    let uncovered = (load - idle).max(0.0);
    let bytes = uncovered * bw;
    let kv_tok = spec.kv_bytes_per_token_layer() * alloc.devices[i].total_layers as u64;
    if kv_tok == 0 {
        return 0;
    }
    (bytes / kv_tok as f64) as i64
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::ModelSpec;
    use crate::plan::{plan, PlanOptions};
    use crate::util::bytes::mbps;

    fn setup(bw_mbps: f64) -> (Allocation, Cluster, OnlinePlanner, KvTransferProtocol) {
        let spec = ModelSpec::llama33_70b();
        let cluster = Cluster::lowmem_setting2();
        let opts = PlanOptions {
            empirical_tokens: 256,
            micro_batch: 1,
            bandwidth: mbps(bw_mbps),
        };
        let alloc = plan(&spec, &cluster, &opts).unwrap().allocation;
        let planner = OnlinePlanner::new(&alloc, &cluster, 1);
        let proto = KvTransferProtocol::new(&alloc, &cluster, &planner, 256, 1, mbps(bw_mbps));
        (alloc, cluster, planner, proto)
    }

    #[test]
    fn target_is_not_a_shipper() {
        let (_, _, planner, proto) = setup(200.0);
        let target = planner.highest_threshold_device();
        assert!(proto.states[target].target.is_none());
        for (i, st) in proto.states.iter().enumerate() {
            if let Some(t) = st.target {
                assert_eq!(t, target);
                assert_ne!(i, t);
            }
        }
    }

    #[test]
    fn eq8_zero_when_load_covered() {
        let spec = ModelSpec::tiny_lm();
        let cluster = Cluster::env_e2();
        let opts = PlanOptions::default();
        let alloc = plan(&spec, &cluster, &opts).unwrap().allocation;
        for i in 0..cluster.len() {
            assert_eq!(eq8_tokens(&alloc, &cluster, i, 64, 1, mbps(200.0)), 0);
        }
    }

    #[test]
    fn ship_now_paces_toward_desired() {
        let (_, _, _, mut proto) = setup(200.0);
        let shipper = (0..proto.states.len()).find(|&i| proto.states[i].desired > 0);
        let Some(i) = shipper else {
            return; // plan fully covered: nothing to test
        };
        let desired = proto.states[i].desired;
        let mut total = 0usize;
        for _ in 0..1000 {
            let s = proto.ship_now(i, usize::MAX, 4);
            if s == 0 {
                break;
            }
            assert!(s <= 4);
            total += s;
        }
        assert_eq!(total as i64, desired);
        assert_eq!(proto.n_trans(i), desired);
    }

    #[test]
    fn receipt_goes_negative() {
        let (_, _, _, mut proto) = setup(200.0);
        proto.record_receipt(0, 10);
        assert_eq!(proto.n_trans(0), -10);
    }

    #[test]
    fn bandwidth_decrease_reacts_immediately() {
        let (alloc, cluster, planner, mut proto) = setup(200.0);
        let shipper = (0..proto.states.len()).find(|&i| proto.states[i].desired > 0);
        let Some(i) = shipper else { return };
        let before = proto.states[i].desired;
        let changed =
            proto.on_bandwidth(&alloc, &cluster, &planner, 10, 256, 1, mbps(50.0));
        // A 4x bandwidth drop shrinks Eq. 8's shippable volume; if the delta
        // clears hysteresis the shipper must be updated.
        let after = proto.states[i].desired;
        if (after - before).abs() >= proto.n_ts {
            assert!(changed.contains(&i));
        }
        assert!(after <= before);
    }

    #[test]
    fn bandwidth_increase_is_lazy_far_from_threshold() {
        let (alloc, cluster, planner, mut proto) = setup(100.0);
        let shipper = (0..proto.states.len()).find(|&i| proto.states[i].desired > 0);
        let Some(i) = shipper else { return };
        let before = proto.states[i].desired;
        // Token 0, thresholds far away -> increase must be skipped.
        let changed =
            proto.on_bandwidth(&alloc, &cluster, &planner, 0, 256, 1, mbps(250.0));
        assert!(!changed.contains(&i));
        assert_eq!(proto.states[i].desired, before);
    }

    #[test]
    fn reset_equals_new_after_use() {
        // The arena contract: after shipping, receipts, and bandwidth
        // reactions, `reset` must land on exactly what a fresh `new`
        // builds for the same (ctx, micro, bw) arguments.
        let (alloc, cluster, planner, mut used) = setup(200.0);
        for i in 0..used.states.len() {
            used.ship_now(i, usize::MAX, 4);
        }
        used.record_receipt(0, 5);
        used.on_bandwidth(&alloc, &cluster, &planner, 10, 256, 1, mbps(50.0));
        for (ctx, micro, bw) in [(256usize, 1usize, 200.0), (64, 3, 120.0)] {
            used.reset(&alloc, &cluster, &planner, ctx, micro, mbps(bw));
            let fresh = KvTransferProtocol::new(&alloc, &cluster, &planner, ctx, micro, mbps(bw));
            assert_eq!(used, fresh);
        }
    }

    #[test]
    fn resident_kv_scales_with_layers_and_tokens() {
        let (alloc, _, _, _) = setup(200.0);
        let per = alloc.spec.kv_bytes_per_token_layer();
        for (i, d) in alloc.devices.iter().enumerate() {
            assert_eq!(
                resident_kv_bytes(&alloc, i, 7),
                per * d.total_layers as u64 * 7
            );
        }
        // A 0-layer (churned-out) device holds nothing.
        let mut gone = alloc.clone();
        gone.devices[0].total_layers = 0;
        assert_eq!(resident_kv_bytes(&gone, 0, 1000), 0);
    }

    #[test]
    fn hysteresis_suppresses_small_changes() {
        let (alloc, cluster, planner, mut proto) = setup(200.0);
        let changed =
            proto.on_bandwidth(&alloc, &cluster, &planner, 10, 256, 1, mbps(199.5));
        assert!(changed.is_empty(), "0.25% wiggle must not trigger updates");
    }
}
