//! Offload-oriented cost model for the interleaved pipeline (paper §IV-B,
//! Eq. 1) plus memory feasibility (the Eq. 1 constraint set).
//!
//! For one auto-regressive step of one micro-batch:
//!
//! ```text
//! T_total = T_comp + T_comm + T_uncover
//! T_comp    = Σ_i comp(L_i)
//! T_comm    = #Seg · |D| · h_size / bw_net
//! T_uncover = max_i max( load(L~_i) − T_i^idle , 0 )
//! T_i^idle  = comp(L_i − L~_i) + Σ_{i'≠i} comp(L_i') + |D| · h_size / bw_net   (Eq. 2)
//! ```
//!
//! `comp` converts layer FLOPs to seconds through the device's effective
//! rate; `load` converts the bytes of offloaded parameters (full layers, or
//! the MHA/MLP *fraction* of split layers — the fine-grained granularity of
//! §IV-C) through the device's SSD read bandwidth.

use crate::cluster::{Cluster, DeviceSpec};
use crate::model::ModelSpec;
use crate::plan::allocation::{Allocation, DeviceAssignment};

/// Decomposed per-token latency prediction.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct CostBreakdown {
    pub t_comp: f64,
    pub t_comm: f64,
    pub t_uncover: f64,
}

impl CostBreakdown {
    pub fn total(&self) -> f64 {
        self.t_comp + self.t_comm + self.t_uncover
    }
}

/// Why an allocation cannot run.
#[derive(Debug, Clone, thiserror::Error, PartialEq)]
pub enum MemError {
    #[error("device {device} over capacity: need {need} bytes, usable {usable}")]
    OverCapacity {
        device: usize,
        need: u64,
        usable: u64,
    },
}

/// Seconds for device `dev` to compute `layers` decoder layers for one
/// decode step with `ctx` cached tokens and micro-batch `micro`.
///
/// Roofline: decode streams every weight byte once per step regardless of
/// batch (so micro-batching amortizes the memory-bound term for free), while
/// FLOPs scale linearly with `micro`. `t = max(flops/peak, bytes/mem_bw)`.
pub fn comp_time(
    spec: &ModelSpec,
    dev: &DeviceSpec,
    layers: usize,
    ctx: usize,
    micro: usize,
) -> f64 {
    if layers == 0 {
        return 0.0;
    }
    let flops = spec.layer_decode_flops(ctx) * layers as f64 * micro as f64;
    let weight_bytes = spec.layer_bytes() as f64 * layers as f64;
    // Sliding-window specs stream at most `window` cached tokens per step.
    let kv_ctx = spec.kv_ctx(ctx);
    let kv_bytes =
        (spec.kv_bytes_per_token_layer() * kv_ctx as u64 * layers as u64 * micro as u64) as f64;
    let t_flops = flops / dev.flops;
    let t_mem = (weight_bytes + kv_bytes) / dev.mem_bw;
    t_flops.max(t_mem)
}

/// Seconds for `dev` to load `assignment`'s offloaded bytes from SSD
/// (one full pass over all segments: every offloaded unit exactly once).
pub fn load_time(spec: &ModelSpec, dev: &DeviceSpec, a: &DeviceAssignment) -> f64 {
    let bytes = a.load_bytes(spec);
    if bytes == 0 {
        return 0.0;
    }
    bytes as f64 / dev.ssd_read_bps
}

/// `T_comm` for one token pass: every segment hop crosses one link.
pub fn t_comm(seg: usize, num_devices: usize, spec: &ModelSpec, micro: usize, bw: f64) -> f64 {
    let h = spec.h_size(micro);
    seg as f64 * num_devices as f64 * crate::net::link_transfer_secs(h, bw)
}

/// `T_i^idle` (Eq. 2): time on device `i` that loading can hide behind.
pub fn t_idle(
    alloc: &Allocation,
    cluster: &Cluster,
    i: usize,
    ctx: usize,
    micro: usize,
    bw: f64,
) -> f64 {
    let spec = &alloc.spec;
    let a = &alloc.devices[i];
    let own = comp_time(
        spec,
        &cluster.devices[i],
        a.non_offloaded_layers(),
        ctx,
        micro,
    );
    let others: f64 = alloc
        .devices
        .iter()
        .enumerate()
        .filter(|(j, _)| *j != i)
        .map(|(j, aj)| comp_time(spec, &cluster.devices[j], aj.total_layers, ctx, micro))
        .sum();
    let comm = cluster.devices.len() as f64
        * crate::net::link_transfer_secs(spec.h_size(micro), bw);
    own + others + comm
}

/// Full Eq. 1 evaluation.
pub fn t_total(
    alloc: &Allocation,
    cluster: &Cluster,
    ctx: usize,
    micro: usize,
    bw: f64,
) -> CostBreakdown {
    let spec = &alloc.spec;
    let t_comp: f64 = alloc
        .devices
        .iter()
        .enumerate()
        .map(|(i, a)| comp_time(spec, &cluster.devices[i], a.total_layers, ctx, micro))
        .sum();
    let comm = t_comm(alloc.seg, cluster.len(), spec, micro, bw);
    let t_uncover = alloc
        .devices
        .iter()
        .enumerate()
        .map(|(i, a)| {
            let load = load_time(spec, &cluster.devices[i], a);
            (load - t_idle(alloc, cluster, i, ctx, micro, bw)).max(0.0)
        })
        .fold(0.0, f64::max);
    CostBreakdown {
        t_comp,
        t_comm: comm,
        t_uncover,
    }
}

/// Memoized [`comp_time`] over every `(device, layer-count)` pair.
///
/// The offline scheduler's `#Seg` sweep evaluates `t_idle`/`t_total` for
/// dozens of candidate × repair-loop states, and none of the per-layer
/// compute terms depend on `seg` — so `plan()` builds this table once and
/// every candidate shares it. Entries are produced by calling
/// [`comp_time`] itself (memoization, not algebraic re-derivation), so a
/// lookup is **bit-identical** to the direct call — pinned by the property
/// test `prop_comp_table_matches_comp_time_bitwise`.
#[derive(Debug, Clone)]
pub struct CompTimeTable {
    /// `per_device[i][l]` = `comp_time(spec, device i, l, ctx, micro)`.
    per_device: Vec<Vec<f64>>,
}

impl CompTimeTable {
    /// Tabulate `comp_time` for layer counts `0..=spec.layers` on every
    /// device, at the planner's `(ctx, micro)` operating point.
    pub fn build(spec: &ModelSpec, cluster: &Cluster, ctx: usize, micro: usize) -> Self {
        CompTimeTable {
            per_device: cluster
                .devices
                .iter()
                .map(|dev| {
                    (0..=spec.layers)
                        .map(|l| comp_time(spec, dev, l, ctx, micro))
                        .collect()
                })
                .collect(),
        }
    }

    /// `comp_time(spec, device, layers, ctx, micro)` — O(1) lookup.
    pub fn get(&self, device: usize, layers: usize) -> f64 {
        self.per_device[device][layers]
    }
}

/// The network term of Eq. 2 — `|D| · h_size / bw` — shared by every
/// device and every `#Seg` candidate. Precompute once per sweep and pass
/// to the `*_cached` evaluators.
pub fn idle_comm_term(spec: &ModelSpec, cluster: &Cluster, micro: usize, bw: f64) -> f64 {
    cluster.devices.len() as f64 * crate::net::link_transfer_secs(spec.h_size(micro), bw)
}

/// [`t_idle`] evaluated through a [`CompTimeTable`] (plus the precomputed
/// [`idle_comm_term`]). Bit-identical to the direct call — same terms in
/// the same order, each fetched from the memo table.
pub fn t_idle_cached(table: &CompTimeTable, alloc: &Allocation, i: usize, comm: f64) -> f64 {
    let a = &alloc.devices[i];
    let own = table.get(i, a.non_offloaded_layers());
    let others: f64 = alloc
        .devices
        .iter()
        .enumerate()
        .filter(|(j, _)| *j != i)
        .map(|(j, aj)| table.get(j, aj.total_layers))
        .sum();
    own + others + comm
}

/// [`t_total`] evaluated through a [`CompTimeTable`]. Bit-identical to the
/// direct call for any allocation whose layer counts fit the table.
pub fn t_total_cached(
    table: &CompTimeTable,
    alloc: &Allocation,
    cluster: &Cluster,
    micro: usize,
    bw: f64,
    comm: f64,
) -> CostBreakdown {
    let spec = &alloc.spec;
    let t_comp: f64 = alloc
        .devices
        .iter()
        .enumerate()
        .map(|(i, a)| table.get(i, a.total_layers))
        .sum();
    let t_comm_v = t_comm(alloc.seg, cluster.len(), spec, micro, bw);
    let t_uncover = alloc
        .devices
        .iter()
        .enumerate()
        .map(|(i, a)| {
            let load = load_time(spec, &cluster.devices[i], a);
            (load - t_idle_cached(table, alloc, i, comm)).max(0.0)
        })
        .fold(0.0, f64::max);
    CostBreakdown {
        t_comp,
        t_comm: t_comm_v,
        t_uncover,
    }
}

/// Memory demand of device `i` under `alloc` after `n_tokens` of KV have
/// accumulated (Eq. 1 constraint, with `n_i^trans` KV tokens shipped away).
pub fn mem_demand(
    alloc: &Allocation,
    i: usize,
    n_tokens: usize,
    kv_transferred: i64,
) -> u64 {
    let spec = &alloc.spec;
    let a = &alloc.devices[i];
    let weights = a.resident_bytes(spec, alloc.seg);
    // Embedding table on the first device, LM head on the last.
    let embed = if i == 0 || i + 1 == alloc.devices.len() {
        spec.embed_bytes() / 2
    } else {
        0
    };
    // A sliding-window spec only ever holds `window` tokens of KV; the
    // window caps what is *resident*, so transferred tokens come out of
    // the capped count (cap-then-subtract, not subtract-then-cap —
    // otherwise shipping KV away would not relieve a windowed device
    // until the raw context itself dropped below the window).
    let kv_tokens =
        (spec.kv_ctx(n_tokens) as i64 - kv_transferred).max(0) as u64;
    let kv = kv_tokens
        * spec.kv_bytes_per_token_layer()
        * a.total_layers as u64;
    weights + embed + kv
}

/// KV tokens device `i` can hold beyond its resident weights; negative
/// means even the weights + embedding don't fit.
pub fn kv_capacity_tokens(alloc: &Allocation, cluster: &Cluster, i: usize) -> i64 {
    let spec = &alloc.spec;
    let a = &alloc.devices[i];
    let fixed = mem_demand(alloc, i, 0, 0);
    let per_tok = (spec.kv_bytes_per_token_layer() * a.total_layers.max(1) as u64).max(1);
    let usable = cluster.devices[i].usable_mem();
    (usable as i64 - fixed as i64) / per_tok as i64
}

/// Tokens of KV that overflow device `i`'s memory when it holds
/// `tokens_held` KV tokens (net of transfers). Zero when everything fits.
pub fn overflow_tokens(
    alloc: &Allocation,
    cluster: &Cluster,
    i: usize,
    tokens_held: usize,
    kv_transferred: i64,
) -> usize {
    overflow_tokens_with_cap(
        alloc,
        i,
        tokens_held,
        kv_transferred,
        cluster.devices[i].usable_mem(),
    )
}

/// [`overflow_tokens`] against an explicit usable-memory cap — the
/// scripted memory-fluctuation path, where a device's effective capacity
/// diverges from its `DeviceSpec` mid-simulation.
pub fn overflow_tokens_with_cap(
    alloc: &Allocation,
    i: usize,
    tokens_held: usize,
    kv_transferred: i64,
    usable: u64,
) -> usize {
    let need = mem_demand(alloc, i, tokens_held, kv_transferred);
    if need <= usable {
        return 0;
    }
    let spec = &alloc.spec;
    let per_tok = (spec.kv_bytes_per_token_layer() * alloc.devices[i].total_layers.max(1) as u64)
        .max(1);
    ((need - usable).div_ceil(per_tok)) as usize
}

/// Check the Eq. 1 memory constraint for every device at `n_tokens`.
pub fn feasible(alloc: &Allocation, cluster: &Cluster, n_tokens: usize) -> Result<(), MemError> {
    for i in 0..alloc.devices.len() {
        let need = mem_demand(alloc, i, n_tokens, 0);
        let usable = cluster.devices[i].usable_mem();
        if need > usable {
            return Err(MemError::OverCapacity {
                device: i,
                need,
                usable,
            });
        }
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::plan::allocation::Allocation;

    fn toy() -> (ModelSpec, Cluster) {
        (ModelSpec::llama2_13b(), Cluster::env_e1())
    }

    fn alloc_with(
        spec: &ModelSpec,
        counts: &[(usize, usize)], // (total, full_offload)
        seg: usize,
    ) -> Allocation {
        let mut devices = Vec::new();
        for &(total, off) in counts {
            devices.push(DeviceAssignment {
                total_layers: total,
                full_offload: off,
                mha_offload: 0,
                mlp_offload: 0,
            });
        }
        Allocation::new(spec.clone(), seg, devices)
    }

    #[test]
    fn comp_time_scales_with_layers_and_device() {
        let (spec, cluster) = toy();
        let fast = comp_time(&spec, &cluster.devices[0], 10, 512, 1);
        let slow = comp_time(&spec, &cluster.devices[1], 10, 512, 1);
        assert!(slow > fast, "NX must be slower than Orin");
        let twenty = comp_time(&spec, &cluster.devices[0], 20, 512, 1);
        assert!((twenty / fast - 2.0).abs() < 1e-9);
    }

    #[test]
    fn load_time_zero_without_offload() {
        let (spec, cluster) = toy();
        let a = DeviceAssignment {
            total_layers: 10,
            full_offload: 0,
            mha_offload: 0,
            mlp_offload: 0,
        };
        assert_eq!(load_time(&spec, &cluster.devices[0], &a), 0.0);
    }

    #[test]
    fn fine_grained_load_cheaper_than_full() {
        let (spec, cluster) = toy();
        let full = DeviceAssignment {
            total_layers: 10,
            full_offload: 2,
            mha_offload: 0,
            mlp_offload: 0,
        };
        let split = DeviceAssignment {
            total_layers: 10,
            full_offload: 1,
            mha_offload: 1, // MLP pinned -> only the MHA block is loaded
            mlp_offload: 0,
        };
        assert!(
            load_time(&spec, &cluster.devices[0], &split)
                < load_time(&spec, &cluster.devices[0], &full)
        );
    }

    #[test]
    fn t_comm_scales_with_segments_and_inverse_bw() {
        let (spec, _) = toy();
        let a = t_comm(2, 2, &spec, 1, crate::util::bytes::mbps(200.0));
        let b = t_comm(4, 2, &spec, 1, crate::util::bytes::mbps(200.0));
        let c = t_comm(2, 2, &spec, 1, crate::util::bytes::mbps(100.0));
        assert!((b / a - 2.0).abs() < 1e-9);
        assert!(c > a);
    }

    #[test]
    fn uncover_zero_when_idle_dominates() {
        let (spec, mut cluster) = toy();
        // 1 offloaded layer on device 0 with a fast SSD: the system's
        // compute time fully hides the 1-layer load.
        cluster.devices[0].ssd_read_bps = 20e9;
        let alloc = alloc_with(&spec, &[(20, 1), (20, 0)], 2);
        let cb = t_total(&alloc, &cluster, 1024, 1, crate::util::bytes::mbps(200.0));
        assert_eq!(cb.t_uncover, 0.0);
        assert!(cb.t_comp > 0.0 && cb.t_comm > 0.0);
    }

    #[test]
    fn uncover_positive_when_load_dominates() {
        let (spec, cluster) = toy();
        // Offload nearly everything on the slow-SSD device, tiny compute.
        let alloc = alloc_with(&spec, &[(2, 0), (38, 36)], 2);
        let cb = t_total(&alloc, &cluster, 16, 1, crate::util::bytes::mbps(200.0));
        assert!(cb.t_uncover > 0.0);
    }

    #[test]
    fn feasibility_detects_oom() {
        let (spec, cluster) = toy();
        // 40 fp16 llama-13b layers on a 16 GB NX alone: layer ~0.6 GiB =>
        // 40 resident layers ~ 25 GiB >> 16 GiB usable.
        let alloc = alloc_with(&spec, &[(2, 0), (38, 0)], 2);
        assert!(feasible(&alloc, &cluster, 0).is_err());
        // With most layers offloaded it fits again.
        let alloc2 = alloc_with(&spec, &[(20, 8), (20, 14)], 4);
        assert!(feasible(&alloc2, &cluster, 0).is_ok());
    }

    #[test]
    fn kv_growth_eventually_breaks_feasibility() {
        let (spec, cluster) = toy();
        let alloc = alloc_with(&spec, &[(20, 8), (20, 14)], 4);
        assert!(feasible(&alloc, &cluster, 0).is_ok());
        let mut n = 1usize;
        while feasible(&alloc, &cluster, n).is_ok() {
            n *= 2;
            assert!(n < 1 << 30, "kv growth never broke feasibility");
        }
    }

    #[test]
    fn overflow_with_cap_matches_cluster_path_and_tracks_pressure() {
        let (spec, cluster) = toy();
        let alloc = alloc_with(&spec, &[(20, 8), (20, 14)], 4);
        let usable = cluster.devices[0].usable_mem();
        // Same cap -> same answer as the cluster-based entry point.
        for held in [0usize, 500, 5000, 50_000] {
            assert_eq!(
                overflow_tokens(&alloc, &cluster, 0, held, 0),
                overflow_tokens_with_cap(&alloc, 0, held, 0, usable)
            );
        }
        // A squeezed cap overflows at a token count the full cap absorbs.
        let held = 100usize;
        assert_eq!(overflow_tokens(&alloc, &cluster, 0, held, 0), 0);
        let squeezed = mem_demand(&alloc, 0, held, 0).saturating_sub(1);
        assert!(overflow_tokens_with_cap(&alloc, 0, held, 0, squeezed) > 0);
    }

    #[test]
    fn kv_transfer_relieves_memory() {
        let (spec, _) = toy();
        let alloc = alloc_with(&spec, &[(20, 8), (20, 14)], 4);
        let with = mem_demand(&alloc, 0, 1000, 400);
        let without = mem_demand(&alloc, 0, 1000, 0);
        assert!(with < without);
        // Negative transfer = receiving KV from peers -> more demand.
        let recv = mem_demand(&alloc, 0, 1000, -400);
        assert!(recv > without);
    }

    #[test]
    fn kv_transfer_relieves_windowed_memory() {
        let (spec, _) = toy();
        let swa = spec.clone().with_sliding_window(256);
        let alloc = alloc_with(&swa, &[(20, 8), (20, 14)], 4);
        // Context far past the window: 256 tokens are resident, and
        // shipping 100 away must shrink demand (cap-then-subtract; the
        // subtract-then-cap ordering would leave demand flat until the
        // raw context itself fell below the window).
        let full = mem_demand(&alloc, 0, 10_000, 0);
        let relieved = mem_demand(&alloc, 0, 10_000, 100);
        assert!(relieved < full);
        assert_eq!(relieved, mem_demand(&alloc, 0, 256, 100));
        // Shipping at least the whole window leaves zero resident KV.
        assert_eq!(mem_demand(&alloc, 0, 10_000, 400), mem_demand(&alloc, 0, 0, 0));
    }

    #[test]
    fn sliding_window_bounds_kv_memory_and_compute() {
        let (spec, cluster) = toy();
        let swa = spec.clone().with_sliding_window(256);
        let alloc_full = alloc_with(&spec, &[(20, 8), (20, 14)], 4);
        let alloc_swa = alloc_with(&swa, &[(20, 8), (20, 14)], 4);
        // Below the window the variant is the identity; above it KV memory
        // and per-step streaming cost saturate at the window.
        assert_eq!(
            mem_demand(&alloc_swa, 0, 100, 0),
            mem_demand(&alloc_full, 0, 100, 0)
        );
        assert_eq!(
            mem_demand(&alloc_swa, 0, 10_000, 0),
            mem_demand(&alloc_swa, 0, 256, 0)
        );
        assert!(mem_demand(&alloc_swa, 0, 10_000, 0) < mem_demand(&alloc_full, 0, 10_000, 0));
        let c_full = comp_time(&spec, &cluster.devices[0], 10, 8192, 1);
        let c_swa = comp_time(&swa, &cluster.devices[0], 10, 8192, 1);
        assert!(c_swa < c_full);
        assert_eq!(
            c_swa.to_bits(),
            comp_time(&swa, &cluster.devices[0], 10, 256, 1).to_bits()
        );
    }

    #[test]
    fn micro_batch_amortizes_compute() {
        let (spec, cluster) = toy();
        let one = comp_time(&spec, &cluster.devices[0], 10, 128, 1);
        let four = comp_time(&spec, &cluster.devices[0], 10, 128, 4);
        assert!(four > one, "more tokens cost more in total");
        assert!(four < 4.0 * one, "but sublinearly (weight reuse)");
    }

    // ----- incremental-planning memoization: bitwise-equality pins -----
    //
    // The #Seg sweep substitutes CompTimeTable lookups (and the hoisted
    // idle_comm_term) for direct cost calls; these properties pin that the
    // substitution is *exact*, so the incremental planner provably equals
    // the term-by-term evaluation it replaced.

    use crate::util::prop::{check, pair, usize_in, Config, PropResult};

    #[test]
    fn prop_comp_table_matches_comp_time_bitwise() {
        let (spec, cluster) = toy();
        let gen = pair(
            pair(usize_in(0, 1), usize_in(0, 40)),
            pair(usize_in(1, 2048), usize_in(1, 8)),
        );
        let cfg = Config {
            cases: 40,
            seed: 0xC057,
            max_shrink_steps: 64,
        };
        let result = check(&cfg, &gen, |&((dev, layers), (ctx, micro))| {
            let table = CompTimeTable::build(&spec, &cluster, ctx, micro);
            let direct = comp_time(&spec, &cluster.devices[dev], layers, ctx, micro);
            let cached = table.get(dev, layers);
            if direct.to_bits() != cached.to_bits() {
                return Err(format!("table {cached} != direct {direct}"));
            }
            Ok(())
        });
        assert!(matches!(result, PropResult::Pass { .. }), "{result:?}");
    }

    #[test]
    fn prop_cached_idle_and_total_match_direct_bitwise() {
        let (spec, cluster) = toy();
        // Random allocations: per-device totals plus offload splits.
        let gen = pair(
            pair(usize_in(0, 20), usize_in(0, 20)),
            pair(pair(usize_in(0, 6), usize_in(0, 6)), usize_in(1, 6)),
        );
        let cfg = Config {
            cases: 40,
            seed: 0x1D1E,
            max_shrink_steps: 64,
        };
        let result = check(&cfg, &gen, |&((t0, t1), ((off0, off1), seg))| {
            let alloc = alloc_with(
                &spec,
                &[(t0 + off0, off0), (t1 + off1, off1)],
                seg,
            );
            let ctx = 256;
            let micro = 2;
            let bw = crate::util::bytes::mbps(180.0);
            let table = CompTimeTable::build(&spec, &cluster, ctx, micro);
            let comm = idle_comm_term(&spec, &cluster, micro, bw);
            for i in 0..cluster.len() {
                let direct = t_idle(&alloc, &cluster, i, ctx, micro, bw);
                let cached = t_idle_cached(&table, &alloc, i, comm);
                if direct.to_bits() != cached.to_bits() {
                    return Err(format!("t_idle dev{i}: {cached} != {direct}"));
                }
            }
            let direct = t_total(&alloc, &cluster, ctx, micro, bw);
            let cached = t_total_cached(&table, &alloc, &cluster, micro, bw, comm);
            if direct.t_comp.to_bits() != cached.t_comp.to_bits()
                || direct.t_comm.to_bits() != cached.t_comm.to_bits()
                || direct.t_uncover.to_bits() != cached.t_uncover.to_bits()
            {
                return Err(format!("t_total: {cached:?} != {direct:?}"));
            }
            Ok(())
        });
        assert!(matches!(result, PropResult::Pass { .. }), "{result:?}");
    }
}
