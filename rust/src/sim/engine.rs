//! Discrete-event simulation engine.
//!
//! Two cooperating layers:
//!
//! * [`Engine`] — a classic event-calendar DES: schedule closures at future
//!   times, run to quiescence. Used where *reactive* behaviour matters
//!   (request arrival processes, bandwidth-change reactions).
//! * [`Resource`] — exclusive FIFO server algebra: `acquire(at, dur)` returns
//!   the granted interval and advances the server's ready time. Pipeline
//!   executors are expressed as ready-time recurrences over Resources (one
//!   per device GPU, SSD channel, and network link), which is both faster
//!   than event-per-op simulation and exactly the max(...) structure of the
//!   paper's cost model — so the simulator and Eq. 1 can be cross-checked.

use std::cmp::Ordering;
use std::collections::BinaryHeap;

/// Time in seconds.
pub type Time = f64;

type EventFn<W> = Box<dyn FnOnce(&mut Engine<W>, &mut W)>;

struct Event<W> {
    at: Time,
    seq: u64,
    run: EventFn<W>,
}

impl<W> PartialEq for Event<W> {
    fn eq(&self, other: &Self) -> bool {
        self.at == other.at && self.seq == other.seq
    }
}
impl<W> Eq for Event<W> {}
impl<W> PartialOrd for Event<W> {
    fn partial_cmp(&self, other: &Self) -> Option<Ordering> {
        Some(self.cmp(other))
    }
}
impl<W> Ord for Event<W> {
    fn cmp(&self, other: &Self) -> Ordering {
        // Min-heap by (time, seq): BinaryHeap is a max-heap, so reverse.
        other
            .at
            .partial_cmp(&self.at)
            .unwrap_or(Ordering::Equal)
            .then(other.seq.cmp(&self.seq))
    }
}

/// Event-calendar simulator over a world state `W`.
pub struct Engine<W> {
    now: Time,
    seq: u64,
    queue: BinaryHeap<Event<W>>,
    executed: u64,
}

impl<W> Engine<W> {
    pub fn new() -> Self {
        Engine {
            now: 0.0,
            seq: 0,
            queue: BinaryHeap::new(),
            executed: 0,
        }
    }

    /// Current simulation time.
    pub fn now(&self) -> Time {
        self.now
    }

    /// Number of events executed so far.
    pub fn executed(&self) -> u64 {
        self.executed
    }

    /// Events currently on the calendar. Streaming drivers (the fleet DES
    /// router) assert on this to guarantee the calendar stays O(clusters)
    /// instead of O(requests) — flat memory at 10^6-request scale.
    pub fn pending(&self) -> usize {
        self.queue.len()
    }

    /// Schedule `f` to run `delay` seconds from now (FIFO among ties).
    pub fn schedule(&mut self, delay: Time, f: impl FnOnce(&mut Engine<W>, &mut W) + 'static) {
        assert!(delay >= 0.0, "cannot schedule into the past");
        let at = self.now + delay;
        self.seq += 1;
        self.queue.push(Event {
            at,
            seq: self.seq,
            run: Box::new(f),
        });
    }

    /// Schedule at an absolute time (>= now).
    pub fn schedule_at(&mut self, at: Time, f: impl FnOnce(&mut Engine<W>, &mut W) + 'static) {
        assert!(at >= self.now, "cannot schedule into the past");
        self.seq += 1;
        self.queue.push(Event {
            at,
            seq: self.seq,
            run: Box::new(f),
        });
    }

    /// Run until the calendar is empty; returns final time.
    pub fn run(&mut self, world: &mut W) -> Time {
        while let Some(ev) = self.queue.pop() {
            self.now = ev.at;
            self.executed += 1;
            (ev.run)(self, world);
        }
        self.now
    }

    /// Run until `deadline` (events after it stay queued).
    pub fn run_until(&mut self, world: &mut W, deadline: Time) -> Time {
        while let Some(ev) = self.queue.peek() {
            if ev.at > deadline {
                break;
            }
            let ev = self.queue.pop().unwrap();
            self.now = ev.at;
            self.executed += 1;
            (ev.run)(self, world);
        }
        self.now = self.now.max(deadline.min(self.peek_time().unwrap_or(deadline)));
        self.now
    }

    fn peek_time(&self) -> Option<Time> {
        self.queue.peek().map(|e| e.at)
    }
}

impl<W> Default for Engine<W> {
    fn default() -> Self {
        Self::new()
    }
}

/// A granted busy interval on a resource.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Interval {
    pub start: Time,
    pub end: Time,
}

impl Interval {
    pub fn duration(&self) -> Time {
        self.end - self.start
    }
}

/// Exclusive FIFO server: one op at a time, requests queue in arrival order.
#[derive(Debug, Clone)]
pub struct Resource {
    ready: Time,
    busy: Time,
    ops: u64,
}

impl Resource {
    pub fn new() -> Self {
        Resource {
            ready: 0.0,
            busy: 0.0,
            ops: 0,
        }
    }

    /// Request `dur` seconds of service, arriving at time `at`.
    pub fn acquire(&mut self, at: Time, dur: Time) -> Interval {
        assert!(dur >= 0.0);
        let start = at.max(self.ready);
        let end = start + dur;
        self.ready = end;
        self.busy += dur;
        self.ops += 1;
        Interval { start, end }
    }

    /// Earliest time a new request could start service.
    pub fn ready_at(&self) -> Time {
        self.ready
    }

    /// Total busy seconds granted.
    pub fn busy_time(&self) -> Time {
        self.busy
    }

    /// Utilization over a horizon.
    pub fn utilization(&self, horizon: Time) -> f64 {
        if horizon <= 0.0 {
            0.0
        } else {
            (self.busy / horizon).min(1.0)
        }
    }

    pub fn ops(&self) -> u64 {
        self.ops
    }
}

impl Default for Resource {
    fn default() -> Self {
        Self::new()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn events_run_in_time_order() {
        let mut eng: Engine<Vec<u32>> = Engine::new();
        let mut world = Vec::new();
        eng.schedule(3.0, |_, w: &mut Vec<u32>| w.push(3));
        eng.schedule(1.0, |_, w| w.push(1));
        eng.schedule(2.0, |_, w| w.push(2));
        let end = eng.run(&mut world);
        assert_eq!(world, vec![1, 2, 3]);
        assert_eq!(end, 3.0);
    }

    #[test]
    fn ties_run_fifo() {
        let mut eng: Engine<Vec<u32>> = Engine::new();
        let mut world = Vec::new();
        for i in 0..10 {
            eng.schedule(1.0, move |_, w: &mut Vec<u32>| w.push(i));
        }
        eng.run(&mut world);
        assert_eq!(world, (0..10).collect::<Vec<_>>());
    }

    #[test]
    fn events_can_schedule_events() {
        let mut eng: Engine<Vec<f64>> = Engine::new();
        let mut world = Vec::new();
        eng.schedule(1.0, |e, _w: &mut Vec<f64>| {
            e.schedule(2.0, |e2, w2: &mut Vec<f64>| w2.push(e2.now()));
        });
        eng.run(&mut world);
        assert_eq!(world, vec![3.0]);
    }

    #[test]
    fn pending_tracks_the_calendar() {
        let mut eng: Engine<u32> = Engine::new();
        assert_eq!(eng.pending(), 0);
        eng.schedule(1.0, |_, w: &mut u32| *w += 1);
        eng.schedule(2.0, |_, w| *w += 1);
        assert_eq!(eng.pending(), 2);
        let mut world = 0u32;
        eng.run(&mut world);
        assert_eq!(eng.pending(), 0);
        assert_eq!(world, 2);
    }

    #[test]
    fn run_until_stops() {
        let mut eng: Engine<Vec<u32>> = Engine::new();
        let mut world = Vec::new();
        eng.schedule(1.0, |_, w: &mut Vec<u32>| w.push(1));
        eng.schedule(5.0, |_, w| w.push(5));
        eng.run_until(&mut world, 2.0);
        assert_eq!(world, vec![1]);
        eng.run(&mut world);
        assert_eq!(world, vec![1, 5]);
    }

    #[test]
    fn resource_serializes() {
        let mut r = Resource::new();
        let a = r.acquire(0.0, 2.0);
        let b = r.acquire(1.0, 3.0); // arrives while busy -> queues
        let c = r.acquire(10.0, 1.0); // arrives idle -> starts immediately
        assert_eq!((a.start, a.end), (0.0, 2.0));
        assert_eq!((b.start, b.end), (2.0, 5.0));
        assert_eq!((c.start, c.end), (10.0, 11.0));
        assert_eq!(r.busy_time(), 6.0);
        assert_eq!(r.ops(), 3);
    }

    #[test]
    fn utilization_bounded() {
        let mut r = Resource::new();
        r.acquire(0.0, 5.0);
        assert!((r.utilization(10.0) - 0.5).abs() < 1e-12);
        assert_eq!(r.utilization(0.0), 0.0);
    }

    #[test]
    #[should_panic]
    fn negative_delay_panics() {
        let mut eng: Engine<()> = Engine::new();
        eng.schedule(-1.0, |_, _| {});
    }
}
