//! SSD offload-channel model (motivates Fig. 2b).
//!
//! Jetson-class NVMe exhibits stable sequential reads but slower and
//! *jittery* writes (SLC-cache exhaustion, FTL garbage collection). The
//! paper's Fig. 2b observation — model-shard offload (pure reads of a fixed
//! size) eventually beats KV-cache offload (growing, mixed read+write) —
//! falls out of exactly these two asymmetries.

use crate::sim::engine::{Interval, Resource, Time};
use crate::util::rng::Rng;

/// A device's SSD channel: one queue shared by reads and writes.
#[derive(Debug, Clone)]
pub struct SsdModel {
    read_bps: f64,
    write_bps: f64,
    channel: Resource,
    rng: Rng,
    /// Fixed per-op submission/completion overhead.
    op_latency: Time,
    /// Probability a write hits an FTL stall.
    write_stall_p: f64,
    /// Multiplier applied to a stalled write.
    write_stall_factor: f64,
}

impl SsdModel {
    pub fn new(read_bps: f64, write_bps: f64, seed: u64) -> Self {
        assert!(read_bps > 0.0 && write_bps > 0.0);
        SsdModel {
            read_bps,
            write_bps,
            channel: Resource::new(),
            rng: Rng::new(seed),
            op_latency: 80e-6,
            write_stall_p: 0.04,
            write_stall_factor: 6.0,
        }
    }

    /// Pure service time of a read (no queueing).
    pub fn read_service(&self, bytes: u64) -> Time {
        self.op_latency + bytes as f64 / self.read_bps
    }

    /// Expected (jitter-free) service time of a write.
    pub fn write_service_nominal(&self, bytes: u64) -> Time {
        self.op_latency + bytes as f64 / self.write_bps
    }

    /// Enqueue a read arriving at `at`; returns the granted interval.
    /// Reads are deterministic — model shards live at fixed SSD offsets
    /// (paper §III: "model slices are fixed in SSD ... more stable").
    pub fn read(&mut self, at: Time, bytes: u64) -> Interval {
        let dur = self.read_service(bytes);
        self.channel.acquire(at, dur)
    }

    /// Enqueue a write arriving at `at`. Writes carry multiplicative jitter
    /// plus occasional long stalls (paper §III: "high-overhead write
    /// operations", "more unstable write latency").
    pub fn write(&mut self, at: Time, bytes: u64) -> Interval {
        let mut dur = self.write_service_nominal(bytes);
        // Log-normal-ish multiplicative jitter, mean ~1.15.
        let jitter = (0.3 * self.rng.normal()).exp();
        dur *= jitter.clamp(0.5, 4.0);
        if self.rng.chance(self.write_stall_p) {
            dur *= self.write_stall_factor;
        }
        self.channel.acquire(at, dur)
    }

    /// Earliest time a new op could start.
    pub fn ready_at(&self) -> Time {
        self.channel.ready_at()
    }

    pub fn ops(&self) -> u64 {
        self.channel.ops()
    }

    pub fn busy_time(&self) -> Time {
        self.channel.busy_time()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::bytes::MIB;

    fn ssd() -> SsdModel {
        SsdModel::new(2e9, 0.5e9, 42)
    }

    #[test]
    fn read_time_scales_with_bytes() {
        let s = ssd();
        let small = s.read_service(10 * MIB);
        let big = s.read_service(100 * MIB);
        assert!(big > 9.0 * small && big < 11.0 * small);
    }

    #[test]
    fn reads_are_deterministic() {
        let mut a = ssd();
        let mut b = ssd();
        for i in 0..50 {
            let t = i as f64;
            assert_eq!(a.read(t, 64 * MIB), b.read(t, 64 * MIB));
        }
    }

    #[test]
    fn writes_jitter_but_reads_do_not() {
        let mut s = ssd();
        let reads: Vec<f64> = (0..20)
            .map(|i| s.read(1000.0 + i as f64 * 100.0, 32 * MIB).duration())
            .collect();
        assert!(reads.windows(2).all(|w| (w[0] - w[1]).abs() < 1e-12));

        let writes: Vec<f64> = (0..20)
            .map(|i| s.write(10_000.0 + i as f64 * 100.0, 32 * MIB).duration())
            .collect();
        assert!(writes.windows(2).any(|w| (w[0] - w[1]).abs() > 1e-9));
    }

    #[test]
    fn writes_slower_on_average_than_reads() {
        let mut s = ssd();
        let n = 200;
        let read_mean: f64 = (0..n)
            .map(|i| s.read(1e6 + i as f64, 32 * MIB).duration())
            .sum::<f64>()
            / n as f64;
        let write_mean: f64 = (0..n)
            .map(|i| s.write(2e6 + i as f64 * 10.0, 32 * MIB).duration())
            .sum::<f64>()
            / n as f64;
        assert!(write_mean > 2.0 * read_mean);
    }

    #[test]
    fn channel_queues_mixed_ops() {
        let mut s = ssd();
        let r1 = s.read(0.0, 100 * MIB);
        let w1 = s.write(0.0, 10 * MIB);
        assert!(w1.start >= r1.end, "write must queue behind read");
    }

    #[test]
    fn stalls_occur_at_expected_rate() {
        let mut s = ssd();
        let nominal = s.write_service_nominal(8 * MIB);
        let n = 2000;
        let stalled = (0..n)
            .filter(|i| {
                s.write(1e9 + *i as f64 * 1e3, 8 * MIB).duration() > 3.0 * nominal
            })
            .count();
        let rate = stalled as f64 / n as f64;
        assert!((0.01..0.10).contains(&rate), "stall rate {rate}");
    }
}
