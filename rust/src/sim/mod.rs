//! Discrete-event simulation substrate: engine, SSD channel model, and
//! execution timeline traces.

pub mod engine;
pub mod ssd;
pub mod trace;

pub use engine::{Engine, Interval, Resource, Time};
pub use ssd::SsdModel;
pub use trace::{Label, MicroPhase, Span, SpanKind, Trace, TraceMode};
