//! Execution timeline traces (Gantt charts) — the data behind the paper's
//! schedule figures (Figs 3, 4, 6, 7, 8). Executors emit [`Span`]s; the
//! renderer prints an ASCII Gantt per device.
//!
//! The trace is on the simulator's innermost loop (`tokens × #Seg × |D| ×
//! micro` pushes per run), so it is built for zero-allocation recording:
//!
//! * [`Label`] is a small `Copy` enum instead of a heap `String` — the
//!   executors construct labels from indices without ever calling
//!   `format!` on the hot path; rendering formats lazily via `Display`.
//! * [`TraceMode`] lets experiment sweeps drop span materialization
//!   entirely (`Off`), or keep only the incrementally-maintained per-device
//!   busy accumulators (`Aggregate`) that back O(1) [`Trace::busy`].
//! * Spans are stored in per-device lanes, so rendering and per-device
//!   queries never scan other devices' spans, and
//!   [`Trace::uncovered_load`] runs as a sort + sweep-line interval
//!   subtraction instead of the old O(loads × computes) double loop.
//!
//! Recording never influences simulated timing: a run produces bit-identical
//! `SimResult` timing fields under every mode (tested in
//! `rust/tests/trace_modes.rs`).

use std::fmt;

use crate::sim::engine::Time;

/// What a device lane was doing during a span.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum SpanKind {
    /// Forward computation of (micro-batch, layer range).
    Compute,
    /// Loading offloaded weights from SSD.
    Load,
    /// Writing to SSD (KV offload or first-time layer eviction).
    Store,
    /// Activation send/receive on the network.
    Comm,
    /// KV-cache transfer to/from a peer (Alg. 2).
    KvTransfer,
    /// Blocked waiting (uncovered load / missing input).
    Stall,
}

impl SpanKind {
    /// Number of kinds — sizes the per-lane busy accumulators.
    pub const COUNT: usize = 6;

    /// Dense index for accumulator arrays.
    pub fn index(self) -> usize {
        self as usize
    }

    pub fn glyph(self) -> char {
        match self {
            SpanKind::Compute => '#',
            SpanKind::Load => 'L',
            SpanKind::Store => 'S',
            SpanKind::Comm => '~',
            SpanKind::KvTransfer => 'K',
            SpanKind::Stall => '.',
        }
    }
}

/// Pipeline phase of a micro-batch span (see [`Label::Micro`]).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum MicroPhase {
    /// Activation hop onto the device.
    Hop,
    /// Compute over the resident layer fraction.
    Resident,
    /// Compute over the offloaded layer fraction.
    Offloaded,
    /// Stalled waiting for an SSD load.
    Wait,
    /// Per-micro-batch SSD load (traditional schedule).
    Load,
}

/// Zero-allocation span annotation. `Copy`, built from indices on the hot
/// path; formatted only when a trace is actually rendered.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Label {
    /// No annotation.
    None,
    /// Fixed descriptive label (e.g. "kv-spill").
    Static(&'static str),
    /// Segment-granular SSD load: decode step + segment index.
    SegLoad { step: u32, seg: u32 },
    /// Micro-batch activity: micro index + phase.
    Micro { m: u32, phase: MicroPhase },
    /// Step-indexed activity with a short tag (e.g. "sync", "tp", "w").
    Step { tag: &'static str, step: u32 },
    /// KV tokens shipped to a peer device.
    KvTo { device: u32 },
}

impl From<&'static str> for Label {
    fn from(s: &'static str) -> Self {
        Label::Static(s)
    }
}

impl fmt::Display for Label {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match *self {
            Label::None => Ok(()),
            Label::Static(s) => f.write_str(s),
            Label::SegLoad { step, seg } => write!(f, "s{step}g{seg}"),
            Label::Micro { m, phase } => match phase {
                MicroPhase::Hop => write!(f, "m{m}"),
                MicroPhase::Resident => write!(f, "m{m}r"),
                MicroPhase::Offloaded => write!(f, "m{m}o"),
                MicroPhase::Wait => write!(f, "m{m}w"),
                MicroPhase::Load => write!(f, "m{m}l"),
            },
            Label::Step { tag, step } => write!(f, "{tag}{step}"),
            Label::KvTo { device } => write!(f, "->d{device}"),
        }
    }
}

/// How much timeline detail an executor records.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum TraceMode {
    /// Record nothing beyond the trace horizon. The cheapest mode —
    /// experiment grids use it, since they only read `SimResult` numbers.
    Off,
    /// Maintain per-device busy-time accumulators (O(1) [`Trace::busy`])
    /// plus an incrementally merged compute-union and per-device
    /// uncovered-load pieces — so [`Trace::uncovered_load`] answers without
    /// materializing spans. Cross-checked against the `Full` sweep-line in
    /// tests.
    Aggregate,
    /// Record every span: required for [`Trace::render`]. The default,
    /// matching historic behavior.
    #[default]
    Full,
}

/// One busy interval on one device lane. The device index is implied by
/// the lane the span is stored under (see [`Trace::device_spans`] /
/// [`Trace::spans`]) rather than duplicated per span.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Span {
    pub kind: SpanKind,
    pub label: Label,
    pub start: Time,
    pub end: Time,
}

/// One device's recorded activity.
#[derive(Debug, Clone, Default)]
struct Lane {
    spans: Vec<Span>,
    busy: [Time; SpanKind::COUNT],
    /// Aggregate mode only: load-interval pieces not (yet) covered by any
    /// compute span, sorted by start. A later compute span can still shrink
    /// these — pushes are not globally time-ordered — so pieces stay live
    /// until queried. Pieces from different loads are NOT merged: the
    /// Full-mode sweep sums uncovered time per load span, so overlapping
    /// loads each count.
    pending_uncovered: Vec<(Time, Time)>,
    /// Longest piece ever inserted into `pending_uncovered` (never shrunk
    /// on splits — a conservative bound). Lets [`pieces_subtract`] binary-
    /// search a window instead of scanning every stale piece: any piece
    /// overlapping `[s, e)` has `start > s - max_len` and `start < e`.
    pending_max_len: Time,
}

/// Collector for executor timelines.
#[derive(Debug, Clone)]
pub struct Trace {
    mode: TraceMode,
    lanes: Vec<Lane>,
    end: Time,
    /// Aggregate mode only: the merged (sorted, disjoint) union of every
    /// compute interval pushed so far — maintained incrementally so
    /// `uncovered_load` needs no span storage.
    agg_compute_union: Vec<(Time, Time)>,
}

impl Default for Trace {
    fn default() -> Self {
        Trace::new()
    }
}

impl Trace {
    /// A full-detail trace (historic default).
    pub fn new() -> Self {
        Trace::with_mode(TraceMode::Full)
    }

    pub fn with_mode(mode: TraceMode) -> Self {
        Trace {
            mode,
            lanes: Vec::new(),
            end: 0.0,
            agg_compute_union: Vec::new(),
        }
    }

    pub fn mode(&self) -> TraceMode {
        self.mode
    }

    /// Record one busy interval. In `Off` mode this only advances the trace
    /// horizon; in `Aggregate` it updates the busy accumulators and the
    /// online compute-union/uncovered-load structures; in `Full` it
    /// materializes the span. Never allocates for the label.
    pub fn push(
        &mut self,
        device: usize,
        kind: SpanKind,
        label: impl Into<Label>,
        start: Time,
        end: Time,
    ) {
        debug_assert!(end >= start, "span ends before it starts");
        if end > self.end {
            self.end = end;
        }
        if self.mode == TraceMode::Off {
            return;
        }
        if device >= self.lanes.len() {
            self.lanes.resize_with(device + 1, Lane::default);
        }
        if self.mode == TraceMode::Aggregate {
            match kind {
                SpanKind::Compute => {
                    // Grow the union, then retroactively cover any pending
                    // uncovered-load pieces (loads overlap with *system*
                    // compute, so every lane's pending set shrinks).
                    interval_insert(&mut self.agg_compute_union, start, end);
                    for lane in &mut self.lanes {
                        let max_len = lane.pending_max_len;
                        pieces_subtract(&mut lane.pending_uncovered, max_len, start, end);
                    }
                }
                SpanKind::Load => {
                    // Only the portion not already covered by the compute
                    // union recorded so far stays pending.
                    let union = &self.agg_compute_union;
                    let lane = &mut self.lanes[device];
                    interval_minus_set(start, end, union, |s, e| {
                        // Keep the lane sorted by start (loads arrive in
                        // roughly increasing time, so this is append-cheap).
                        let at = lane
                            .pending_uncovered
                            .partition_point(|&(ps, _)| ps <= s);
                        lane.pending_uncovered.insert(at, (s, e));
                        if e - s > lane.pending_max_len {
                            lane.pending_max_len = e - s;
                        }
                    });
                }
                _ => {}
            }
        }
        let lane = &mut self.lanes[device];
        lane.busy[kind.index()] += end - start;
        if self.mode == TraceMode::Full {
            lane.spans.push(Span {
                kind,
                label: label.into(),
                start,
                end,
            });
        }
    }

    /// Latest span end observed (all modes).
    pub fn end_time(&self) -> Time {
        self.end
    }

    /// Total busy time of `device` in spans of `kind`. O(1) — reads the
    /// incrementally-maintained accumulator. Zero in `Off` mode.
    pub fn busy(&self, device: usize, kind: SpanKind) -> Time {
        self.lanes
            .get(device)
            .map_or(0.0, |l| l.busy[kind.index()])
    }

    /// All recorded spans as `(device, span)`, in per-device lanes (device
    /// order, then push order within a device). Empty unless the mode is
    /// `Full`.
    pub fn spans(&self) -> impl Iterator<Item = (usize, &Span)> + '_ {
        self.lanes
            .iter()
            .enumerate()
            .flat_map(|(device, l)| l.spans.iter().map(move |s| (device, s)))
    }

    /// Spans of one device lane (empty unless the mode is `Full`).
    pub fn device_spans(&self, device: usize) -> &[Span] {
        match self.lanes.get(device) {
            Some(lane) => lane.spans.as_slice(),
            None => &[],
        }
    }

    /// Number of materialized spans.
    pub fn span_count(&self) -> usize {
        self.lanes.iter().map(|l| l.spans.len()).sum()
    }

    /// Loading time on `device` NOT overlapped by compute — the empirical
    /// counterpart of the cost model's `T_uncover` term. Loads overlap with
    /// *system* work, so compute anywhere in the pipeline covers them.
    ///
    /// In `Full` mode this is a sort/sweep-line over the materialized
    /// spans: the compute spans of all lanes are merged into a disjoint
    /// interval union once, then each load subtracts its covered portion
    /// with a monotone cursor — O((L + C) log C) versus the old O(L × C)
    /// double loop (which also double-counted overlapping compute spans
    /// from different devices). Querying every device? Use
    /// [`Trace::uncovered_loads`], which builds the union once.
    ///
    /// In `Aggregate` mode the same quantity is maintained *online*: each
    /// `push` merges computes into a running union and keeps only the
    /// still-uncovered load pieces per lane, so the answer needs no span
    /// storage (cross-checked against the `Full` sweep in tests). `Off`
    /// mode returns 0.0.
    pub fn uncovered_load(&self, device: usize) -> Time {
        match self.mode {
            TraceMode::Off => 0.0,
            TraceMode::Aggregate => self
                .lanes
                .get(device)
                .map_or(0.0, |l| l.pending_uncovered.iter().map(|&(s, e)| e - s).sum()),
            TraceMode::Full => self.uncovered_load_against(device, &self.compute_union()),
        }
    }

    /// [`Trace::uncovered_load`] for every device lane. In `Full` mode one
    /// compute-union construction is shared across the queries; in
    /// `Aggregate` mode each lane's answer is already materialized.
    pub fn uncovered_loads(&self) -> Vec<Time> {
        match self.mode {
            TraceMode::Off => vec![0.0; self.lanes.len()],
            TraceMode::Aggregate => (0..self.lanes.len())
                .map(|device| self.uncovered_load(device))
                .collect(),
            TraceMode::Full => {
                let union = self.compute_union();
                (0..self.lanes.len())
                    .map(|device| self.uncovered_load_against(device, &union))
                    .collect()
            }
        }
    }

    /// Disjoint, sorted union of all compute intervals across every lane.
    fn compute_union(&self) -> Vec<(Time, Time)> {
        let mut computes: Vec<(Time, Time)> = self
            .spans()
            .filter(|(_, s)| s.kind == SpanKind::Compute)
            .map(|(_, s)| (s.start, s.end))
            .collect();
        computes.sort_by(|a, b| a.0.partial_cmp(&b.0).unwrap());
        let mut union: Vec<(Time, Time)> = Vec::with_capacity(computes.len());
        for (s, e) in computes {
            match union.last_mut() {
                Some(last) if s <= last.1 => last.1 = last.1.max(e),
                _ => union.push((s, e)),
            }
        }
        union
    }

    fn uncovered_load_against(&self, device: usize, union: &[(Time, Time)]) -> Time {
        let Some(lane) = self.lanes.get(device) else {
            return 0.0;
        };
        let mut loads: Vec<(Time, Time)> = lane
            .spans
            .iter()
            .filter(|s| s.kind == SpanKind::Load)
            .map(|s| (s.start, s.end))
            .collect();
        if loads.is_empty() {
            return 0.0;
        }
        loads.sort_by(|a, b| a.0.partial_cmp(&b.0).unwrap());

        // Sweep: loads and the union are both sorted, so the cursor into
        // the union only moves forward across loads.
        let mut uncovered = 0.0;
        let mut ci = 0usize;
        for (ls, le) in loads {
            let mut covered = 0.0;
            // Skip covered intervals that end before this load starts.
            while ci < union.len() && union[ci].1 <= ls {
                ci += 1;
            }
            let mut j = ci;
            while j < union.len() && union[j].0 < le {
                let lo = ls.max(union[j].0);
                let hi = le.min(union[j].1);
                if hi > lo {
                    covered += hi - lo;
                }
                if union[j].1 >= le {
                    break;
                }
                j += 1;
            }
            uncovered += ((le - ls) - covered).max(0.0);
        }
        uncovered
    }

    /// Render an ASCII Gantt chart with `width` columns (needs `Full`).
    pub fn render(&self, devices: usize, width: usize) -> String {
        let horizon = self.end_time().max(1e-9);
        let scale = width as f64 / horizon;
        let mut out = String::new();
        out.push_str(&format!(
            "timeline 0 .. {:.1} ms  ('#' compute, 'L' load, 'S' store, '~' comm, 'K' kv-transfer)\n",
            horizon * 1e3
        ));
        for dev in 0..devices {
            let mut lane = vec![' '; width];
            for s in self.device_spans(dev) {
                let a = ((s.start * scale) as usize).min(width - 1);
                let b = ((s.end * scale).ceil() as usize).clamp(a + 1, width);
                for c in lane.iter_mut().take(b).skip(a) {
                    // Compute wins visual conflicts; stalls lose.
                    let g = s.kind.glyph();
                    if *c == ' ' || *c == '.' || g == '#' {
                        *c = g;
                    }
                }
            }
            out.push_str(&format!("dev{dev} |{}|\n", lane.iter().collect::<String>()));
        }
        out
    }
}

// ------------------------------------------------------- interval algebra
//
// The Aggregate-mode online structures are sorted, disjoint interval lists
// over `Time`. Touching intervals merge (same convention as the Full-mode
// sweep's compute union), which never changes total measure.

/// Insert `[s, e)` into a sorted disjoint list, merging overlaps/touches.
fn interval_insert(ivs: &mut Vec<(Time, Time)>, s: Time, e: Time) {
    if e <= s {
        return;
    }
    // First interval that could merge with [s, e) (its end reaches s)...
    let lo = ivs.partition_point(|&(_, ie)| ie < s);
    // ...and one past the last (its start is still <= e).
    let hi = ivs.partition_point(|&(is, _)| is <= e);
    if lo == hi {
        ivs.insert(lo, (s, e));
    } else {
        let merged = (ivs[lo].0.min(s), ivs[hi - 1].1.max(e));
        ivs[lo] = merged;
        ivs.drain(lo + 1..hi);
    }
}

/// Remove `[s, e)` from a start-sorted (possibly overlapping) piece list.
/// Unlike a merged union, pieces that came from different load spans are
/// kept separate so overlapping loads each retain their own measure.
///
/// `max_len` is an upper bound on every piece's length: a piece
/// overlapping `[s, e)` must start after `s - max_len` and before `e`, so
/// only that binary-searched window is touched — stale fully-uncovered
/// pieces from earlier in the timeline cost nothing per compute push.
fn pieces_subtract(pieces: &mut Vec<(Time, Time)>, max_len: Time, s: Time, e: Time) {
    if e <= s || pieces.is_empty() {
        return;
    }
    let lo = pieces.partition_point(|&(ps, _)| ps <= s - max_len);
    let hi = pieces.partition_point(|&(ps, _)| ps < e);
    if lo >= hi {
        return;
    }
    // Rebuild the window: survivors and left remainders keep their order
    // (starts unchanged); right remainders all start at `e`, which is ≥
    // every window start and ≤ every post-window start, so appending them
    // keeps the list sorted.
    let mut keep: Vec<(Time, Time)> = Vec::new();
    let mut rights: Vec<(Time, Time)> = Vec::new();
    for &(ps, pe) in &pieces[lo..hi] {
        if pe <= s {
            keep.push((ps, pe)); // entirely before the cut: untouched
            continue;
        }
        if ps < s {
            keep.push((ps, s)); // left remainder
        }
        if pe > e {
            rights.push((e, pe)); // right remainder
        }
    }
    keep.append(&mut rights);
    pieces.splice(lo..hi, keep);
}

/// Emit the pieces of `[s, e)` not covered by the sorted disjoint `cover`.
fn interval_minus_set(
    s: Time,
    e: Time,
    cover: &[(Time, Time)],
    mut emit: impl FnMut(Time, Time),
) {
    if e <= s {
        return;
    }
    let mut cur = s;
    let start = cover.partition_point(|&(_, ce)| ce <= s);
    for &(cs, ce) in &cover[start..] {
        if cs >= e {
            break;
        }
        if cs > cur {
            emit(cur, cs);
        }
        cur = cur.max(ce);
        if cur >= e {
            break;
        }
    }
    if cur < e {
        emit(cur, e);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn busy_sums_by_kind() {
        let mut t = Trace::new();
        t.push(0, SpanKind::Compute, "a", 0.0, 1.0);
        t.push(0, SpanKind::Compute, "b", 2.0, 3.0);
        t.push(0, SpanKind::Load, "l", 1.0, 2.0);
        t.push(1, SpanKind::Compute, "c", 0.0, 5.0);
        assert_eq!(t.busy(0, SpanKind::Compute), 2.0);
        assert_eq!(t.busy(0, SpanKind::Load), 1.0);
        assert_eq!(t.busy(1, SpanKind::Compute), 5.0);
        assert_eq!(t.end_time(), 5.0);
        assert_eq!(t.span_count(), 4);
    }

    #[test]
    fn uncovered_load_subtracts_any_compute() {
        let mut t = Trace::new();
        // Load on dev0 from 0..4; dev1 computes 1..2 and dev0 computes 3..4.
        t.push(0, SpanKind::Load, "l", 0.0, 4.0);
        t.push(1, SpanKind::Compute, "c1", 1.0, 2.0);
        t.push(0, SpanKind::Compute, "c0", 3.0, 4.0);
        assert!((t.uncovered_load(0) - 2.0).abs() < 1e-12);
    }

    #[test]
    fn fully_covered_load_is_zero() {
        let mut t = Trace::new();
        t.push(0, SpanKind::Load, "l", 1.0, 2.0);
        t.push(1, SpanKind::Compute, "c", 0.0, 3.0);
        assert_eq!(t.uncovered_load(0), 0.0);
    }

    #[test]
    fn overlapping_computes_do_not_double_cover() {
        let mut t = Trace::new();
        // Two overlapping computes cover [0, 3]; the load is 0..4, so one
        // second must remain uncovered (the old quadratic implementation
        // would have counted 5s of cover and clamped to zero).
        t.push(0, SpanKind::Load, "l", 0.0, 4.0);
        t.push(1, SpanKind::Compute, "a", 0.0, 3.0);
        t.push(2, SpanKind::Compute, "b", 1.0, 3.0);
        assert!((t.uncovered_load(0) - 1.0).abs() < 1e-12);
    }

    #[test]
    fn multiple_loads_sweep_correctly() {
        let mut t = Trace::new();
        t.push(0, SpanKind::Load, "l1", 0.0, 2.0);
        t.push(0, SpanKind::Load, "l2", 5.0, 8.0);
        t.push(1, SpanKind::Compute, "c1", 1.0, 6.0);
        // l1 covered for 1s (1..2), l2 covered for 1s (5..6).
        assert!((t.uncovered_load(0) - 3.0).abs() < 1e-12);
    }

    #[test]
    fn uncovered_loads_matches_per_device_queries() {
        let mut t = Trace::new();
        t.push(0, SpanKind::Load, "l", 0.0, 4.0);
        t.push(1, SpanKind::Load, "l", 2.0, 6.0);
        t.push(2, SpanKind::Compute, "c", 1.0, 3.0);
        let all = t.uncovered_loads();
        assert_eq!(all.len(), 3);
        for (dev, &v) in all.iter().enumerate() {
            assert!((v - t.uncovered_load(dev)).abs() < 1e-12, "device {dev}");
        }
        assert_eq!(all[2], 0.0, "compute-only lane has no loads");
    }

    #[test]
    fn render_shows_lanes() {
        let mut t = Trace::new();
        t.push(0, SpanKind::Compute, "a", 0.0, 0.5);
        t.push(1, SpanKind::Load, "l", 0.5, 1.0);
        let s = t.render(2, 40);
        assert!(s.contains("dev0"));
        assert!(s.contains("dev1"));
        assert!(s.contains('#'));
        assert!(s.contains('L'));
    }

    #[test]
    fn aggregate_mode_accumulates_without_spans() {
        let mut t = Trace::with_mode(TraceMode::Aggregate);
        t.push(0, SpanKind::Compute, Label::None, 0.0, 1.5);
        t.push(0, SpanKind::Compute, Label::None, 2.0, 3.0);
        assert_eq!(t.span_count(), 0);
        assert!((t.busy(0, SpanKind::Compute) - 2.5).abs() < 1e-12);
        assert_eq!(t.end_time(), 3.0);
    }

    // ----------------- Aggregate-mode online uncovered_load -----------------

    #[test]
    fn interval_insert_merges_and_sorts() {
        let mut ivs: Vec<(Time, Time)> = Vec::new();
        interval_insert(&mut ivs, 5.0, 6.0);
        interval_insert(&mut ivs, 1.0, 2.0);
        interval_insert(&mut ivs, 3.0, 4.0);
        assert_eq!(ivs, vec![(1.0, 2.0), (3.0, 4.0), (5.0, 6.0)]);
        // Bridge the middle two (touching endpoints merge).
        interval_insert(&mut ivs, 2.0, 3.0);
        assert_eq!(ivs, vec![(1.0, 4.0), (5.0, 6.0)]);
        // Swallow everything.
        interval_insert(&mut ivs, 0.0, 10.0);
        assert_eq!(ivs, vec![(0.0, 10.0)]);
        // Zero-length inserts are no-ops.
        interval_insert(&mut ivs, 20.0, 20.0);
        assert_eq!(ivs.len(), 1);
    }

    #[test]
    fn pieces_subtract_splits_and_trims() {
        let ml = 10.0; // conservative max piece length for these fixtures
        let mut ivs = vec![(0.0, 10.0)];
        pieces_subtract(&mut ivs, ml, 3.0, 4.0);
        assert_eq!(ivs, vec![(0.0, 3.0), (4.0, 10.0)]);
        pieces_subtract(&mut ivs, ml, 2.0, 5.0);
        assert_eq!(ivs, vec![(0.0, 2.0), (5.0, 10.0)]);
        pieces_subtract(&mut ivs, ml, 5.0, 10.0);
        assert_eq!(ivs, vec![(0.0, 2.0)]);
        pieces_subtract(&mut ivs, ml, 7.0, 9.0); // disjoint: no-op
        assert_eq!(ivs, vec![(0.0, 2.0)]);
        pieces_subtract(&mut ivs, ml, 0.0, 2.0);
        assert!(ivs.is_empty());
        // Overlapping pieces (two loads sharing time) are trimmed
        // independently — both keep their uncovered remainders — and the
        // result stays start-sorted without any re-sort.
        let mut overlapping = vec![(0.0, 4.0), (1.0, 5.0)];
        pieces_subtract(&mut overlapping, 4.0, 2.0, 3.0);
        assert_eq!(
            overlapping,
            vec![(0.0, 2.0), (1.0, 2.0), (3.0, 4.0), (3.0, 5.0)]
        );
    }

    #[test]
    fn pieces_subtract_window_skips_stale_pieces() {
        // Pieces whose start is at or before `s - max_len` cannot overlap
        // [s, e) and must survive untouched (the windowing invariant).
        let mut ivs = vec![(0.0, 1.0), (2.0, 3.0), (10.0, 11.0), (12.0, 13.0)];
        pieces_subtract(&mut ivs, 1.0, 10.5, 12.5);
        assert_eq!(
            ivs,
            vec![(0.0, 1.0), (2.0, 3.0), (10.0, 10.5), (12.5, 13.0)]
        );
    }

    #[test]
    fn interval_minus_set_emits_gaps() {
        let cover = vec![(1.0, 2.0), (3.0, 4.0)];
        let mut got = Vec::new();
        interval_minus_set(0.0, 5.0, &cover, |s, e| got.push((s, e)));
        assert_eq!(got, vec![(0.0, 1.0), (2.0, 3.0), (4.0, 5.0)]);
        got.clear();
        interval_minus_set(1.2, 1.8, &cover, |s, e| got.push((s, e)));
        assert!(got.is_empty(), "{got:?}");
    }

    #[test]
    fn aggregate_uncovered_matches_full_with_retroactive_compute() {
        // The tricky case for the online structure: a compute span pushed
        // AFTER the load it covers must retroactively shrink the pending
        // pieces.
        let mut full = Trace::with_mode(TraceMode::Full);
        let mut agg = Trace::with_mode(TraceMode::Aggregate);
        for t in [&mut full, &mut agg] {
            t.push(0, SpanKind::Load, Label::None, 0.0, 4.0);
            t.push(1, SpanKind::Compute, Label::None, 1.0, 2.0); // after the load
            t.push(0, SpanKind::Compute, Label::None, 3.0, 4.0);
            t.push(1, SpanKind::Load, Label::None, 2.0, 6.0);
            t.push(2, SpanKind::Compute, Label::None, 5.0, 5.5);
        }
        assert_eq!(agg.span_count(), 0, "Aggregate must not materialize spans");
        let f = full.uncovered_loads();
        let a = agg.uncovered_loads();
        assert_eq!(f.len(), a.len());
        for (dev, (fv, av)) in f.iter().zip(&a).enumerate() {
            assert!((fv - av).abs() < 1e-12, "dev{dev}: full {fv} vs agg {av}");
        }
        assert!((a[0] - 2.0).abs() < 1e-12);
    }

    #[test]
    fn aggregate_uncovered_matches_full_randomized() {
        // Fuzz the online maintenance against the Full sweep-line oracle
        // over random interleavings of loads and computes on 3 lanes.
        let mut rng = crate::util::rng::Rng::new(0xA66);
        for _case in 0..50 {
            let mut full = Trace::with_mode(TraceMode::Full);
            let mut agg = Trace::with_mode(TraceMode::Aggregate);
            let events = rng.range(1, 40);
            for _ in 0..events {
                let dev = rng.range(0, 3);
                let s = rng.range_f64(0.0, 20.0);
                let e = s + rng.range_f64(0.0, 5.0);
                let kind = if rng.chance(0.5) {
                    SpanKind::Compute
                } else {
                    SpanKind::Load
                };
                full.push(dev, kind, Label::None, s, e);
                agg.push(dev, kind, Label::None, s, e);
            }
            let f = full.uncovered_loads();
            let a = agg.uncovered_loads();
            assert_eq!(f.len(), a.len());
            for (dev, (fv, av)) in f.iter().zip(&a).enumerate() {
                assert!(
                    (fv - av).abs() < 1e-9 * fv.abs().max(1.0),
                    "dev{dev}: full {fv} vs aggregate {av}"
                );
            }
        }
    }

    #[test]
    fn off_mode_records_only_horizon() {
        let mut t = Trace::with_mode(TraceMode::Off);
        t.push(3, SpanKind::Load, Label::None, 0.0, 2.0);
        assert_eq!(t.span_count(), 0);
        assert_eq!(t.busy(3, SpanKind::Load), 0.0);
        assert_eq!(t.uncovered_load(3), 0.0);
        assert_eq!(t.end_time(), 2.0);
    }

    #[test]
    fn labels_format_like_the_old_strings() {
        assert_eq!(Label::SegLoad { step: 3, seg: 1 }.to_string(), "s3g1");
        assert_eq!(
            Label::Micro { m: 2, phase: MicroPhase::Hop }.to_string(),
            "m2"
        );
        assert_eq!(
            Label::Micro { m: 2, phase: MicroPhase::Resident }.to_string(),
            "m2r"
        );
        assert_eq!(
            Label::Micro { m: 0, phase: MicroPhase::Offloaded }.to_string(),
            "m0o"
        );
        assert_eq!(Label::KvTo { device: 4 }.to_string(), "->d4");
        assert_eq!(Label::Step { tag: "sync", step: 7 }.to_string(), "sync7");
        assert_eq!(Label::Static("kv-spill").to_string(), "kv-spill");
        assert_eq!(Label::from("x"), Label::Static("x"));
    }

    #[test]
    fn labels_are_small_and_copy() {
        // The whole point: a span must stay cheap enough to emit millions
        // of times without heap traffic (and carries no redundant device
        // index — the lane implies it).
        assert!(std::mem::size_of::<Label>() <= 24);
        assert!(std::mem::size_of::<Span>() <= 48);
        let l = Label::SegLoad { step: 1, seg: 2 };
        let l2 = l; // Copy
        assert_eq!(l, l2);
    }

    #[test]
    fn device_spans_are_per_lane() {
        let mut t = Trace::new();
        t.push(1, SpanKind::Compute, "a", 0.0, 1.0);
        t.push(0, SpanKind::Load, "b", 0.0, 1.0);
        t.push(1, SpanKind::Comm, "c", 1.0, 2.0);
        assert_eq!(t.device_spans(0).len(), 1);
        assert_eq!(t.device_spans(1).len(), 2);
        assert_eq!(t.device_spans(9).len(), 0);
        assert_eq!(t.spans().count(), 3);
    }
}
