//! Execution timeline traces (Gantt charts) — the data behind the paper's
//! schedule figures (Figs 3, 4, 6, 7, 8). Executors emit [`Span`]s; the
//! renderer prints an ASCII Gantt per device.

use crate::sim::engine::Time;

/// What a device lane was doing during a span.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum SpanKind {
    /// Forward computation of (micro-batch, layer range).
    Compute,
    /// Loading offloaded weights from SSD.
    Load,
    /// Writing to SSD (KV offload or first-time layer eviction).
    Store,
    /// Activation send/receive on the network.
    Comm,
    /// KV-cache transfer to/from a peer (Alg. 2).
    KvTransfer,
    /// Blocked waiting (uncovered load / missing input).
    Stall,
}

impl SpanKind {
    pub fn glyph(self) -> char {
        match self {
            SpanKind::Compute => '#',
            SpanKind::Load => 'L',
            SpanKind::Store => 'S',
            SpanKind::Comm => '~',
            SpanKind::KvTransfer => 'K',
            SpanKind::Stall => '.',
        }
    }
}

/// One busy interval on one device lane.
#[derive(Debug, Clone)]
pub struct Span {
    pub device: usize,
    pub kind: SpanKind,
    pub label: String,
    pub start: Time,
    pub end: Time,
}

/// Collector for executor timelines.
#[derive(Debug, Default, Clone)]
pub struct Trace {
    pub spans: Vec<Span>,
}

impl Trace {
    pub fn new() -> Self {
        Trace { spans: Vec::new() }
    }

    pub fn push(&mut self, device: usize, kind: SpanKind, label: impl Into<String>, start: Time, end: Time) {
        debug_assert!(end >= start, "span ends before it starts");
        self.spans.push(Span {
            device,
            kind,
            label: label.into(),
            start,
            end,
        });
    }

    pub fn end_time(&self) -> Time {
        self.spans.iter().map(|s| s.end).fold(0.0, f64::max)
    }

    /// Total busy time of `device` in spans of `kind`.
    pub fn busy(&self, device: usize, kind: SpanKind) -> Time {
        self.spans
            .iter()
            .filter(|s| s.device == device && s.kind == kind)
            .map(|s| s.end - s.start)
            .sum()
    }

    /// Loading time on `device` NOT overlapped by its own compute — the
    /// empirical counterpart of the cost model's `T_uncover` term.
    pub fn uncovered_load(&self, device: usize) -> Time {
        let loads: Vec<(Time, Time)> = self
            .spans
            .iter()
            .filter(|s| s.device == device && s.kind == SpanKind::Load)
            .map(|s| (s.start, s.end))
            .collect();
        let computes: Vec<(Time, Time)> = self
            .spans
            .iter()
            .filter(|s| s.kind == SpanKind::Compute)
            .map(|s| (s.start, s.end))
            .collect();
        let mut uncovered = 0.0;
        for (ls, le) in loads {
            // Subtract the portion of [ls, le] covered by any compute span
            // anywhere in the pipeline (loads overlap with *system* work).
            let mut covered = 0.0;
            for &(cs, ce) in &computes {
                let lo = ls.max(cs);
                let hi = le.min(ce);
                if hi > lo {
                    covered += hi - lo;
                }
            }
            uncovered += ((le - ls) - covered).max(0.0);
        }
        uncovered
    }

    /// Render an ASCII Gantt chart with `width` columns.
    pub fn render(&self, devices: usize, width: usize) -> String {
        let horizon = self.end_time().max(1e-9);
        let scale = width as f64 / horizon;
        let mut out = String::new();
        out.push_str(&format!(
            "timeline 0 .. {:.1} ms  ('#' compute, 'L' load, 'S' store, '~' comm, 'K' kv-transfer)\n",
            horizon * 1e3
        ));
        for dev in 0..devices {
            let mut lane = vec![' '; width];
            for s in self.spans.iter().filter(|s| s.device == dev) {
                let a = ((s.start * scale) as usize).min(width - 1);
                let b = ((s.end * scale).ceil() as usize).clamp(a + 1, width);
                for c in lane.iter_mut().take(b).skip(a) {
                    // Compute wins visual conflicts; stalls lose.
                    let g = s.kind.glyph();
                    if *c == ' ' || *c == '.' || g == '#' {
                        *c = g;
                    }
                }
            }
            out.push_str(&format!("dev{dev} |{}|\n", lane.iter().collect::<String>()));
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn busy_sums_by_kind() {
        let mut t = Trace::new();
        t.push(0, SpanKind::Compute, "a", 0.0, 1.0);
        t.push(0, SpanKind::Compute, "b", 2.0, 3.0);
        t.push(0, SpanKind::Load, "l", 1.0, 2.0);
        t.push(1, SpanKind::Compute, "c", 0.0, 5.0);
        assert_eq!(t.busy(0, SpanKind::Compute), 2.0);
        assert_eq!(t.busy(0, SpanKind::Load), 1.0);
        assert_eq!(t.busy(1, SpanKind::Compute), 5.0);
        assert_eq!(t.end_time(), 5.0);
    }

    #[test]
    fn uncovered_load_subtracts_any_compute() {
        let mut t = Trace::new();
        // Load on dev0 from 0..4; dev1 computes 1..2 and dev0 computes 3..4.
        t.push(0, SpanKind::Load, "l", 0.0, 4.0);
        t.push(1, SpanKind::Compute, "c1", 1.0, 2.0);
        t.push(0, SpanKind::Compute, "c0", 3.0, 4.0);
        assert!((t.uncovered_load(0) - 2.0).abs() < 1e-12);
    }

    #[test]
    fn fully_covered_load_is_zero() {
        let mut t = Trace::new();
        t.push(0, SpanKind::Load, "l", 1.0, 2.0);
        t.push(1, SpanKind::Compute, "c", 0.0, 3.0);
        assert_eq!(t.uncovered_load(0), 0.0);
    }

    #[test]
    fn render_shows_lanes() {
        let mut t = Trace::new();
        t.push(0, SpanKind::Compute, "a", 0.0, 0.5);
        t.push(1, SpanKind::Load, "l", 0.5, 1.0);
        let s = t.render(2, 40);
        assert!(s.contains("dev0"));
        assert!(s.contains("dev1"));
        assert!(s.contains('#'));
        assert!(s.contains('L'));
    }
}
