//! Workloads: the paper's two request patterns (§V-A) plus arrival-process
//! and synthetic-corpus generators for the real serving path.

pub mod lengths;
pub mod requests;

pub use lengths::LengthDist;
pub use requests::{
    assign_sessions, poisson_arrivals, stream_requests, stream_requests_mix,
    stream_requests_sessions, Request, RequestGen,
};

use crate::cluster::Cluster;
use crate::util::rng::Rng;

/// The paper's two edge request patterns.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Pattern {
    /// Individual requests arrive occasionally as single inputs
    /// (micro-batch size 1, one micro-batch in flight).
    Sporadic,
    /// Multiple inference requests submitted simultaneously
    /// (micro-batch size 1, |D| micro-batches in flight).
    Bursty,
}

impl Pattern {
    /// Micro-batches in flight for this pattern on `cluster`.
    pub fn micro_batches(&self, cluster: &Cluster) -> usize {
        match self {
            Pattern::Sporadic => 1,
            Pattern::Bursty => cluster.len(),
        }
    }

    /// OOT (out-of-time) classification threshold, ms/token (§V-C).
    pub fn oot_limit_ms(&self) -> f64 {
        match self {
            Pattern::Sporadic => 40_000.0,
            Pattern::Bursty => 15_000.0,
        }
    }
}

/// A synthetic token prompt (no HF tokenizer offline — see DESIGN.md).
pub fn synthetic_prompt(seed: u64, len: usize, vocab: usize) -> Vec<i32> {
    let mut rng = Rng::new(seed);
    (0..len).map(|_| rng.below(vocab as u64) as i32).collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn pattern_micro_batches() {
        let c = Cluster::env_e3();
        assert_eq!(Pattern::Sporadic.micro_batches(&c), 1);
        assert_eq!(Pattern::Bursty.micro_batches(&c), 4);
    }

    #[test]
    fn oot_limits_match_paper() {
        assert_eq!(Pattern::Sporadic.oot_limit_ms(), 40_000.0);
        assert_eq!(Pattern::Bursty.oot_limit_ms(), 15_000.0);
    }

    #[test]
    fn synthetic_prompt_in_vocab() {
        let p = synthetic_prompt(1, 64, 256);
        assert_eq!(p.len(), 64);
        assert!(p.iter().all(|&t| (0..256).contains(&t)));
        assert_eq!(p, synthetic_prompt(1, 64, 256));
        assert_ne!(p, synthetic_prompt(2, 64, 256));
    }
}
