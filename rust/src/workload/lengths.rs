//! Deterministic request-length distributions — the mixed-length workload
//! axis (EdgeShard-style serving realism; SNIPPETS §3C motivation).
//!
//! Every request in a stream carries its own `(prompt_len, steps)` pair.
//! A [`LengthDist`] draws that pair from the stream's seeded [`Rng`], so
//! length mixes are exactly as reproducible as the arrival process:
//! same seed, same stream, bit for bit, at any worker count.
//!
//! [`LengthDist::Fixed`] is the degenerate distribution every pre-mix
//! stream used implicitly. It samples **without touching the RNG**, so a
//! `Fixed` stream consumes the identical draw sequence the old
//! fixed-length generator consumed — the property that lets
//! `rust/tests/workload_mix.rs` pin `Fixed` bit-identical to the pre-axis
//! path end-to-end (request ids, arrivals, prompt tokens, and every
//! downstream timing).

use crate::util::rng::Rng;

/// A per-request `(prompt_len, steps)` sampler. All variants are
/// deterministic functions of the stream's `Rng` state.
#[derive(Debug, Clone, PartialEq)]
pub enum LengthDist {
    /// Every request prefills `prompt_tokens` and decodes `steps` tokens.
    /// Draws nothing from the RNG — bit-identical to the pre-mix path.
    Fixed { prompt_tokens: usize, steps: usize },
    /// Independent uniform draws over inclusive `[min, max]` ranges.
    Uniform {
        prompt: (usize, usize),
        steps: (usize, usize),
    },
    /// Short-chat / long-context mixture: with probability `long_frac`
    /// the request is a `long` `(prompt, steps)` pair, otherwise `short`.
    Bimodal {
        short: (usize, usize),
        long: (usize, usize),
        long_frac: f64,
    },
    /// Fixed prompt, truncated-geometric decode length: steps are
    /// `1 + Geom(1/mean_steps)` capped at `max_steps` (inversion method),
    /// the classic open-ended-generation length model.
    Geometric {
        prompt_tokens: usize,
        mean_steps: usize,
        max_steps: usize,
    },
}

impl LengthDist {
    /// The pre-mix default: one fixed `(prompt_tokens, steps)` shape.
    pub fn fixed(prompt_tokens: usize, steps: usize) -> Self {
        LengthDist::Fixed {
            prompt_tokens,
            steps,
        }
    }

    /// Draw one request's `(prompt_len, steps)`.
    ///
    /// `Fixed` returns its pair without advancing `rng`; every other
    /// variant draws a deterministic number of values (prompt first,
    /// then steps, then the mixture coin where applicable).
    pub fn sample(&self, rng: &mut Rng) -> (usize, usize) {
        match *self {
            LengthDist::Fixed {
                prompt_tokens,
                steps,
            } => (prompt_tokens, steps),
            LengthDist::Uniform { prompt, steps } => {
                let p = sample_inclusive(rng, prompt);
                let s = sample_inclusive(rng, steps);
                (p, s)
            }
            LengthDist::Bimodal {
                short,
                long,
                long_frac,
            } => {
                if rng.chance(long_frac) {
                    long
                } else {
                    short
                }
            }
            LengthDist::Geometric {
                prompt_tokens,
                mean_steps,
                max_steps,
            } => {
                let s = sample_truncated_geometric(rng, mean_steps, max_steps);
                (prompt_tokens, s)
            }
        }
    }

    /// True for the degenerate (pre-mix-identical) distribution.
    pub fn is_fixed(&self) -> bool {
        matches!(self, LengthDist::Fixed { .. })
    }

    /// Schema tag for artifacts (`axes.workloads[].kind`).
    pub fn kind(&self) -> &'static str {
        match self {
            LengthDist::Fixed { .. } => "fixed",
            LengthDist::Uniform { .. } => "uniform",
            LengthDist::Bimodal { .. } => "bimodal",
            LengthDist::Geometric { .. } => "geometric",
        }
    }

    /// Short human/axis label (`axes.workloads[].label`, per-cell
    /// `workload` coordinate). Unique across any sanely-built axis:
    /// parameters are baked in for the non-fixed variants.
    pub fn label(&self) -> String {
        match *self {
            LengthDist::Fixed { .. } => "fixed".into(),
            LengthDist::Uniform { prompt, steps } => {
                format!("uni{}-{}x{}-{}", prompt.0, prompt.1, steps.0, steps.1)
            }
            LengthDist::Bimodal { long_frac, .. } => {
                format!("bimix{}", (long_frac * 100.0).round() as u32)
            }
            LengthDist::Geometric { mean_steps, .. } => format!("geo{mean_steps}"),
        }
    }

    /// Largest prompt the distribution can emit (sizing KV page budgets).
    pub fn max_prompt_tokens(&self) -> usize {
        match *self {
            LengthDist::Fixed { prompt_tokens, .. } => prompt_tokens,
            LengthDist::Uniform { prompt, .. } => prompt.1,
            LengthDist::Bimodal { short, long, .. } => short.0.max(long.0),
            LengthDist::Geometric { prompt_tokens, .. } => prompt_tokens,
        }
    }

    /// Largest decode length the distribution can emit.
    pub fn max_steps(&self) -> usize {
        match *self {
            LengthDist::Fixed { steps, .. } => steps,
            LengthDist::Uniform { steps, .. } => steps.1,
            LengthDist::Bimodal { short, long, .. } => short.1.max(long.1),
            LengthDist::Geometric { max_steps, .. } => max_steps,
        }
    }
}

/// Uniform draw over an inclusive `[min, max]` range (degenerate ranges
/// still consume one draw, keeping the draw count shape-independent).
fn sample_inclusive(rng: &mut Rng, (lo, hi): (usize, usize)) -> usize {
    assert!(lo <= hi, "inclusive range must be ordered: [{lo}, {hi}]");
    lo + rng.below((hi - lo + 1) as u64) as usize
}

/// `1 + Geom(p)` with `p = 1/mean`, truncated to `max` (inversion of one
/// uniform draw; mean ≤ 1 degenerates to constant 1, still one draw).
fn sample_truncated_geometric(rng: &mut Rng, mean: usize, max: usize) -> usize {
    assert!(max >= 1, "truncation bound must allow one step");
    let u = rng.f64();
    if mean <= 1 {
        return 1.min(max);
    }
    let p = 1.0 / mean as f64;
    // (1-u) in (0, 1]: ln is finite; u = 0 maps to exactly 1 step.
    let k = ((1.0 - u).ln() / (1.0 - p).ln()).floor();
    (1 + k as usize).min(max)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fixed_never_touches_the_rng() {
        let dist = LengthDist::fixed(64, 8);
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..16 {
            assert_eq!(dist.sample(&mut a), (64, 8));
        }
        // a saw zero draws: its stream still matches a fresh twin.
        for _ in 0..8 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn uniform_respects_inclusive_bounds() {
        let dist = LengthDist::Uniform {
            prompt: (16, 64),
            steps: (2, 9),
        };
        let mut rng = Rng::new(7);
        let (mut saw_plo, mut saw_phi) = (false, false);
        for _ in 0..2000 {
            let (p, s) = dist.sample(&mut rng);
            assert!((16..=64).contains(&p), "prompt {p}");
            assert!((2..=9).contains(&s), "steps {s}");
            saw_plo |= p == 16;
            saw_phi |= p == 64;
        }
        assert!(saw_plo && saw_phi, "inclusive endpoints must be reachable");
    }

    #[test]
    fn bimodal_mixes_both_modes_at_the_requested_rate() {
        let dist = LengthDist::Bimodal {
            short: (32, 4),
            long: (256, 24),
            long_frac: 0.25,
        };
        let mut rng = Rng::new(11);
        let mut longs = 0usize;
        let n = 10_000;
        for _ in 0..n {
            match dist.sample(&mut rng) {
                (256, 24) => longs += 1,
                (32, 4) => {}
                other => panic!("off-mode sample {other:?}"),
            }
        }
        let frac = longs as f64 / n as f64;
        assert!((frac - 0.25).abs() < 0.02, "long fraction {frac}");
    }

    #[test]
    fn geometric_truncates_and_hits_its_mean() {
        let dist = LengthDist::Geometric {
            prompt_tokens: 64,
            mean_steps: 8,
            max_steps: 64,
        };
        let mut rng = Rng::new(3);
        let n = 20_000;
        let mut sum = 0usize;
        for _ in 0..n {
            let (p, s) = dist.sample(&mut rng);
            assert_eq!(p, 64);
            assert!((1..=64).contains(&s), "steps {s}");
            sum += s;
        }
        let mean = sum as f64 / n as f64;
        assert!((mean - 8.0).abs() < 0.5, "mean steps {mean}");
    }

    #[test]
    fn samples_are_seed_deterministic() {
        for dist in [
            LengthDist::Uniform {
                prompt: (8, 128),
                steps: (1, 16),
            },
            LengthDist::Bimodal {
                short: (32, 4),
                long: (256, 24),
                long_frac: 0.3,
            },
            LengthDist::Geometric {
                prompt_tokens: 48,
                mean_steps: 6,
                max_steps: 32,
            },
        ] {
            let mut a = Rng::new(0xD15E);
            let mut b = Rng::new(0xD15E);
            let xs: Vec<_> = (0..64).map(|_| dist.sample(&mut a)).collect();
            let ys: Vec<_> = (0..64).map(|_| dist.sample(&mut b)).collect();
            assert_eq!(xs, ys, "{dist:?}");
        }
    }

    #[test]
    fn labels_and_bounds_line_up() {
        let bi = LengthDist::Bimodal {
            short: (32, 4),
            long: (256, 24),
            long_frac: 0.25,
        };
        assert_eq!(bi.label(), "bimix25");
        assert_eq!(bi.kind(), "bimodal");
        assert_eq!(bi.max_prompt_tokens(), 256);
        assert_eq!(bi.max_steps(), 24);
        let fixed = LengthDist::fixed(64, 8);
        assert_eq!(fixed.label(), "fixed");
        assert!(fixed.is_fixed());
        assert_eq!((fixed.max_prompt_tokens(), fixed.max_steps()), (64, 8));
    }
}
