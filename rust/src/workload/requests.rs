//! Request arrival generators for the serving engine.

use crate::util::rng::Rng;

/// One inference request.
#[derive(Debug, Clone, PartialEq)]
pub struct Request {
    pub id: u64,
    /// Arrival time in seconds from workload start.
    pub arrival: f64,
    /// Prompt token ids.
    pub prompt: Vec<i32>,
    /// Decode steps requested.
    pub steps: usize,
}

/// Poisson arrival times with rate `lambda` (req/s) for `count` requests.
pub fn poisson_arrivals(seed: u64, lambda: f64, count: usize) -> Vec<f64> {
    let mut rng = Rng::new(seed);
    let mut t = 0.0;
    (0..count)
        .map(|_| {
            t += rng.exponential(lambda);
            t
        })
        .collect()
}

/// Deterministic request stream generator.
#[derive(Debug)]
pub struct RequestGen {
    rng: Rng,
    next_id: u64,
    vocab: usize,
    prompt_len: usize,
    steps: usize,
}

impl RequestGen {
    pub fn new(seed: u64, vocab: usize, prompt_len: usize, steps: usize) -> Self {
        RequestGen {
            rng: Rng::new(seed),
            next_id: 0,
            vocab,
            prompt_len,
            steps,
        }
    }

    /// Sporadic stream: `count` requests with Poisson arrivals.
    pub fn sporadic(&mut self, count: usize, lambda: f64) -> Vec<Request> {
        let arrivals = poisson_arrivals(self.rng.next_u64(), lambda, count);
        arrivals.into_iter().map(|a| self.make(a)).collect()
    }

    /// Bursty stream: `count` requests all arriving at t=0.
    pub fn bursty(&mut self, count: usize) -> Vec<Request> {
        (0..count).map(|_| self.make(0.0)).collect()
    }

    fn make(&mut self, arrival: f64) -> Request {
        let id = self.next_id;
        self.next_id += 1;
        let prompt = (0..self.prompt_len)
            .map(|_| self.rng.below(self.vocab as u64) as i32)
            .collect();
        Request {
            id,
            arrival,
            prompt,
            steps: self.steps,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn poisson_is_increasing() {
        let a = poisson_arrivals(3, 2.0, 100);
        assert!(a.windows(2).all(|w| w[1] > w[0]));
        // Mean inter-arrival ~ 1/lambda.
        let mean = a.last().unwrap() / 100.0;
        assert!((mean - 0.5).abs() < 0.15, "mean gap {mean}");
    }

    #[test]
    fn bursty_all_at_zero() {
        let mut g = RequestGen::new(1, 256, 16, 8);
        let reqs = g.bursty(4);
        assert_eq!(reqs.len(), 4);
        assert!(reqs.iter().all(|r| r.arrival == 0.0));
        // Ids unique, prompts differ.
        assert_ne!(reqs[0].prompt, reqs[1].prompt);
        assert_ne!(reqs[0].id, reqs[1].id);
    }

    #[test]
    fn sporadic_spaced_out() {
        let mut g = RequestGen::new(2, 256, 16, 8);
        let reqs = g.sporadic(5, 0.5);
        assert!(reqs.windows(2).all(|w| w[1].arrival > w[0].arrival));
        assert!(reqs.iter().all(|r| r.prompt.len() == 16 && r.steps == 8));
    }
}
