//! Request arrival generators for the serving engine and the
//! continuous-serving simulator (`serve::simqueue`).

use crate::util::rng::Rng;
use crate::workload::{LengthDist, Pattern};

/// One inference request.
#[derive(Debug, Clone, PartialEq)]
pub struct Request {
    pub id: u64,
    /// Arrival time in seconds from workload start.
    pub arrival: f64,
    /// Prompt token ids.
    pub prompt: Vec<i32>,
    /// Decode steps requested.
    pub steps: usize,
    /// Session this request belongs to (sticky-session fleet routing).
    /// Generators default it to the request id — every request its own
    /// session — so session-free paths behave exactly as before; fleet
    /// affinity specs overwrite it via [`assign_sessions`].
    pub session_id: u64,
    /// Prompt-prefix tokens already resident in the serving cluster's KV
    /// pool (a session-affinity hit). The simulator skips re-prefill
    /// FLOPs, activation volume and page registration for this prefix —
    /// capped so at least one prompt token is always recomputed (the
    /// final position's logits are needed regardless). Always 0 outside
    /// affinity-routed fleet shards.
    pub cached_prefix: u32,
}

/// Poisson arrival times with rate `lambda` (req/s) for `count` requests.
pub fn poisson_arrivals(seed: u64, lambda: f64, count: usize) -> Vec<f64> {
    let mut rng = Rng::new(seed);
    let mut t = 0.0;
    (0..count)
        .map(|_| {
            t += rng.exponential(lambda);
            t
        })
        .collect()
}

/// Deterministic request stream generator.
///
/// Request shapes come from a [`LengthDist`]: each request first samples
/// its `(prompt_len, steps)` pair, then draws `prompt_len` tokens.
/// [`LengthDist::Fixed`] samples without touching the RNG, so fixed-shape
/// streams consume the exact draw sequence the pre-mix generator did —
/// bit-identical requests (pinned in `rust/tests/workload_mix.rs`).
#[derive(Debug)]
pub struct RequestGen {
    rng: Rng,
    next_id: u64,
    vocab: usize,
    lengths: LengthDist,
}

impl RequestGen {
    pub fn new(seed: u64, vocab: usize, prompt_len: usize, steps: usize) -> Self {
        Self::with_lengths(seed, vocab, LengthDist::fixed(prompt_len, steps))
    }

    pub fn with_lengths(seed: u64, vocab: usize, lengths: LengthDist) -> Self {
        RequestGen {
            rng: Rng::new(seed),
            next_id: 0,
            vocab,
            lengths,
        }
    }

    /// Sporadic stream: `count` requests with Poisson arrivals.
    pub fn sporadic(&mut self, count: usize, lambda: f64) -> Vec<Request> {
        let arrivals = poisson_arrivals(self.rng.next_u64(), lambda, count);
        arrivals.into_iter().map(|a| self.make(a)).collect()
    }

    /// Bursty stream: `count` requests all arriving at t=0.
    pub fn bursty(&mut self, count: usize) -> Vec<Request> {
        (0..count).map(|_| self.make(0.0)).collect()
    }

    fn make(&mut self, arrival: f64) -> Request {
        let id = self.next_id;
        self.next_id += 1;
        let (prompt_len, steps) = self.lengths.sample(&mut self.rng);
        let prompt = (0..prompt_len)
            .map(|_| self.rng.below(self.vocab as u64) as i32)
            .collect();
        Request {
            id,
            arrival,
            prompt,
            steps,
            session_id: id,
            cached_prefix: 0,
        }
    }
}

/// Salt for the session-id stream: sessions draw from their own derived
/// generator, so attaching sessions to a stream never perturbs the
/// arrival/shape/prompt draw sequence — a sessioned stream is
/// bit-identical to [`stream_requests`] in every pre-existing field
/// (pinned in the tests below).
const SESSION_STREAM_SALT: u64 = 0x5E55_1011_D00D_F00D;

/// Overwrite each request's `session_id` with a Zipf(`zipf_s`) draw over
/// `[0, sessions)` — hot sessions exist by construction, which is what
/// gives sticky-session fleet routing something to reuse. Deterministic
/// given `seed`; uses a salted generator independent of the stream RNG.
pub fn assign_sessions(requests: &mut [Request], seed: u64, sessions: u64, zipf_s: f64) {
    assert!(sessions >= 1, "need at least one session");
    let mut rng = Rng::new(seed ^ SESSION_STREAM_SALT);
    for r in requests.iter_mut() {
        r.session_id = rng.zipf(sessions, zipf_s) - 1;
    }
}

/// [`stream_requests`] plus Zipf-distributed session ids (see
/// [`assign_sessions`]). The non-session fields are bit-identical to the
/// plain stream for the same arguments.
#[allow(clippy::too_many_arguments)]
pub fn stream_requests_sessions(
    pattern: Pattern,
    seed: u64,
    count: usize,
    lambda: f64,
    prompt_len: usize,
    steps: usize,
    sessions: u64,
    zipf_s: f64,
) -> Vec<Request> {
    let mut reqs = stream_requests(pattern, seed, count, lambda, prompt_len, steps);
    assign_sessions(&mut reqs, seed, sessions, zipf_s);
    reqs
}

/// Synthetic vocabulary for stream prompts. Prompt *content* only matters
/// to the real PJRT serving path; the discrete-event simulator reads a
/// request's arrival time, `prompt.len()` and step count — per-request
/// prefill FLOPs, activation volume and KV page registration all follow
/// the request's own lengths (see `serve::simqueue`).
const STREAM_VOCAB: usize = 32_000;

/// A request stream for the continuous-serving simulator, drawn per the
/// paper's §V-A arrival patterns: `Sporadic` requests arrive occasionally
/// (Poisson at `lambda` req/s), `Bursty` submits all `count` requests
/// simultaneously at t = 0. Deterministic given `seed`; arrivals are
/// sorted (the admission queue is FIFO).
pub fn stream_requests(
    pattern: Pattern,
    seed: u64,
    count: usize,
    lambda: f64,
    prompt_len: usize,
    steps: usize,
) -> Vec<Request> {
    stream_requests_mix(
        pattern,
        seed,
        count,
        lambda,
        &LengthDist::fixed(prompt_len, steps),
    )
}

/// [`stream_requests`] with per-request shapes drawn from `lengths`.
/// `LengthDist::Fixed` reproduces [`stream_requests`] bit for bit (same
/// RNG draw sequence); mixed distributions give every request its own
/// `(prompt_len, steps)` while keeping the stream seed-deterministic.
pub fn stream_requests_mix(
    pattern: Pattern,
    seed: u64,
    count: usize,
    lambda: f64,
    lengths: &LengthDist,
) -> Vec<Request> {
    let mut gen = RequestGen::with_lengths(seed, STREAM_VOCAB, lengths.clone());
    match pattern {
        Pattern::Sporadic => gen.sporadic(count, lambda),
        Pattern::Bursty => gen.bursty(count),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn poisson_is_increasing() {
        let a = poisson_arrivals(3, 2.0, 100);
        assert!(a.windows(2).all(|w| w[1] > w[0]));
        // Mean inter-arrival ~ 1/lambda.
        let mean = a.last().unwrap() / 100.0;
        assert!((mean - 0.5).abs() < 0.15, "mean gap {mean}");
    }

    #[test]
    fn bursty_all_at_zero() {
        let mut g = RequestGen::new(1, 256, 16, 8);
        let reqs = g.bursty(4);
        assert_eq!(reqs.len(), 4);
        assert!(reqs.iter().all(|r| r.arrival == 0.0));
        // Ids unique, prompts differ.
        assert_ne!(reqs[0].prompt, reqs[1].prompt);
        assert_ne!(reqs[0].id, reqs[1].id);
    }

    #[test]
    fn sporadic_spaced_out() {
        let mut g = RequestGen::new(2, 256, 16, 8);
        let reqs = g.sporadic(5, 0.5);
        assert!(reqs.windows(2).all(|w| w[1].arrival > w[0].arrival));
        assert!(reqs.iter().all(|r| r.prompt.len() == 16 && r.steps == 8));
    }

    #[test]
    fn stream_requests_follow_the_pattern() {
        let spor = stream_requests(Pattern::Sporadic, 7, 6, 2.0, 16, 4);
        assert_eq!(spor.len(), 6);
        assert!(spor.windows(2).all(|w| w[1].arrival > w[0].arrival));
        assert!(spor[0].arrival > 0.0);
        let burst = stream_requests(Pattern::Bursty, 7, 6, 2.0, 16, 4);
        assert_eq!(burst.len(), 6);
        assert!(burst.iter().all(|r| r.arrival == 0.0 && r.steps == 4));
        // Deterministic given the seed.
        assert_eq!(spor, stream_requests(Pattern::Sporadic, 7, 6, 2.0, 16, 4));
    }

    #[test]
    fn fixed_mix_reproduces_stream_requests_exactly() {
        for pattern in [Pattern::Sporadic, Pattern::Bursty] {
            let plain = stream_requests(pattern, 9, 8, 0.5, 64, 6);
            let mixed =
                stream_requests_mix(pattern, 9, 8, 0.5, &LengthDist::fixed(64, 6));
            assert_eq!(plain, mixed, "{pattern:?}");
        }
    }

    #[test]
    fn sessions_never_perturb_the_base_stream() {
        for pattern in [Pattern::Sporadic, Pattern::Bursty] {
            let plain = stream_requests(pattern, 11, 32, 1.5, 0, 4);
            let sessioned =
                stream_requests_sessions(pattern, 11, 32, 1.5, 0, 4, 8, 1.1);
            assert_eq!(plain.len(), sessioned.len());
            for (p, s) in plain.iter().zip(&sessioned) {
                assert_eq!(p.id, s.id);
                assert_eq!(p.arrival, s.arrival);
                assert_eq!(p.prompt, s.prompt);
                assert_eq!(p.steps, s.steps);
                assert_eq!(p.cached_prefix, 0);
                assert_eq!(s.cached_prefix, 0);
                assert!(s.session_id < 8);
            }
            // Default sessions are one-per-request (the id).
            assert!(plain.iter().all(|r| r.session_id == r.id));
        }
    }

    #[test]
    fn session_assignment_is_deterministic_and_zipf_hot() {
        let a = stream_requests_sessions(Pattern::Sporadic, 23, 400, 2.0, 0, 3, 16, 1.2);
        let b = stream_requests_sessions(Pattern::Sporadic, 23, 400, 2.0, 0, 3, 16, 1.2);
        assert_eq!(a, b);
        let mut counts = [0usize; 16];
        for r in &a {
            counts[r.session_id as usize] += 1;
        }
        assert!(
            counts[0] > counts[8] && counts[0] * 4 > a.len() / 2,
            "session 0 must be hot: {counts:?}"
        );
        assert!(counts.iter().filter(|&&c| c > 0).count() >= 4, "{counts:?}");
    }

    #[test]
    fn mixed_streams_are_seed_deterministic_and_actually_ragged() {
        let dist = LengthDist::Bimodal {
            short: (32, 2),
            long: (128, 12),
            long_frac: 0.4,
        };
        let a = stream_requests_mix(Pattern::Sporadic, 13, 24, 1.0, &dist);
        let b = stream_requests_mix(Pattern::Sporadic, 13, 24, 1.0, &dist);
        assert_eq!(a, b);
        assert!(
            a.iter().any(|r| r.prompt.len() != a[0].prompt.len()),
            "24 bimodal draws at 40% long must mix both modes"
        );
        assert!(a.iter().all(|r| r.prompt.len() == 32 || r.prompt.len() == 128));
        assert!(a.iter().all(|r| r.steps == 2 || r.steps == 12));
    }
}
