//! Layer allocation: the allocation data model and the fine-grained offline
//! scheduler (paper §IV-C, Alg. 1).

pub mod allocation;
pub mod offline;

pub use allocation::{Allocation, DeviceAssignment};
pub use offline::{
    plan, plan_on_pool, plan_with_seg, plan_with_segs, plan_with_threads, PlanError,
    PlanOptions, PlanReport,
};
