//! Layer allocation: the allocation data model and the fine-grained offline
//! scheduler (paper §IV-C, Alg. 1).

pub mod allocation;
pub mod offline;

pub use allocation::{Allocation, DeviceAssignment};
pub use offline::{plan, plan_with_seg, plan_with_threads, PlanError, PlanOptions, PlanReport};
