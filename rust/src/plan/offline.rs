//! Fine-grained offline allocation scheduler (paper §IV-C, Alg. 1).
//!
//! Pipeline of phases, re-run for every candidate segment count `#Seg`:
//!
//! 1. **Greedy fill** (Alg. 1 lines 27–30): every device takes as many
//!    *resident* layers as its memory allows, after reserving room for the
//!    embedding/LM-head shares, the empirical-`n` KV cache, and one shared
//!    offload slot.
//! 2. **DP over offloaded layers** (lines 1–11): the remaining layers
//!    `L_left` must stream from SSD; `F_allo(l, i)` = minimum extra delay
//!    after placing the first `l` of them on the first `i` devices, with
//!    the clamped accumulation of lines 6–7 and predecessor table
//!    `P_pre(l, i)` for backtracking.
//! 3. **Fine-grained refinement** (lines 12–27): a max-heap over per-device
//!    uncovered time; the bottleneck device pins the MHA or MLP block of
//!    one offloaded layer into spare memory (halving-ish its load) until no
//!    further improvement fits.
//! 4. **Feasibility repair**: if the Eq. 1 memory constraint fails at the
//!    empirical token count, one resident layer of the offending device is
//!    pushed into the offload pool and the DP re-runs.
//!
//! The best `#Seg` is chosen by evaluating the full Eq. 1 cost
//! ([`crate::cost::t_total`]) — lines 31–38.
//!
//! **Incremental sweep.** None of the per-layer `comp_time`/`load_time`
//! terms depend on `seg`, and neither does the phase-1 greedy fill — so the
//! sweep hoists them into one shared `SegSweepCtx` (a memoized
//! [`cost::CompTimeTable`], the Eq. 2 comm term, per-device one-layer SSD
//! load times, the greedy resident fill, and the per-slot offload units).
//! Each candidate then runs phases 2–4 against O(1) lookups instead of
//! re-deriving identical costs. Every substituted term is **bit-identical**
//! to the direct evaluation it replaced (pinned by property tests in
//! `cost::tests` and below), so the chosen plan is exactly the one the
//! non-incremental scheduler produced.
//!
//! Candidates are independent and evaluate on the persistent work-stealing
//! pool ([`crate::util::pool`]); results are written by index and reduced
//! in ascending-`seg` order, so the outcome is bit-identical to the
//! sequential sweep at any worker count — including when `plan()` itself
//! runs inside a pool job (experiment grid cells), where the candidates
//! are submitted as nested jobs on the same pool.

use std::cmp::Ordering;
use std::collections::BinaryHeap;

use crate::cluster::Cluster;
use crate::cost;
use crate::model::ModelSpec;
use crate::plan::allocation::{Allocation, DeviceAssignment};
use crate::util::pool::Pool;

/// Tuning inputs for planning (the paper's empirical constants).
#[derive(Debug, Clone, Copy)]
pub struct PlanOptions {
    /// Empirical value of the total generated tokens `n` (§IV-C: fixed
    /// constant; `n_i^trans` is taken as 0 during offline planning).
    pub empirical_tokens: usize,
    /// Micro-batch size (1 = sporadic; |D| = bursty).
    pub micro_batch: usize,
    /// Network bandwidth assumed by the planner, bytes/s.
    pub bandwidth: f64,
}

impl Default for PlanOptions {
    fn default() -> Self {
        PlanOptions {
            empirical_tokens: 512,
            micro_batch: 1,
            bandwidth: crate::util::bytes::mbps(200.0),
        }
    }
}

/// Planning failure.
#[derive(Debug, Clone, thiserror::Error, PartialEq)]
pub enum PlanError {
    #[error("cluster cannot host the model even with maximal offloading: {0}")]
    OutOfMemory(String),
}

/// Outcome: the chosen allocation plus the per-#Seg cost curve
/// (regenerates Figs 7–8).
#[derive(Debug, Clone)]
pub struct PlanReport {
    pub allocation: Allocation,
    pub cost: cost::CostBreakdown,
    /// (seg, total cost) for every feasible candidate examined.
    pub seg_curve: Vec<(usize, f64)>,
}

/// Everything the `#Seg` candidates share — computed once per sweep.
struct SegSweepCtx {
    /// Memoized `comp_time` per (device, layer-count).
    comp: cost::CompTimeTable,
    /// Eq. 2 network term `|D| · h_size / bw`.
    comm: f64,
    /// Seconds for device `i` to stream one full layer from SSD.
    load_one: Vec<f64>,
    /// Phase-1 greedy resident fill (seg-independent).
    resident0: Vec<usize>,
    /// Offload slots device `i` can host; candidate capacity = `units × seg`.
    slot_units: Vec<usize>,
}

impl SegSweepCtx {
    fn new(spec: &ModelSpec, cluster: &Cluster, opts: &PlanOptions) -> Self {
        let d = cluster.len();
        let kv_per_layer = opts.empirical_tokens as u64 * spec.kv_bytes_per_token_layer();

        // Phase 1: greedy resident fill with one offload slot reserved.
        let mut resident0: Vec<usize> = (0..d)
            .map(|i| {
                let budget = layer_budget(spec, cluster, i).saturating_sub(spec.layer_bytes()); // slot
                (budget / (spec.layer_bytes() + kv_per_layer)) as usize
            })
            .collect();
        let cap_total: usize = resident0.iter().sum();
        if cap_total > spec.layers {
            // Offload is mandatory here (try_all_resident failed only because
            // of the slot reserve) — trim the surplus from the slowest devices
            // so the DP still has layers to place.
            let mut surplus = cap_total - spec.layers.saturating_sub(d.min(spec.layers));
            while surplus > 0 {
                let i = (0..d)
                    .filter(|&i| resident0[i] > 0)
                    .min_by(|&a, &b| {
                        cluster.devices[a]
                            .flops
                            .partial_cmp(&cluster.devices[b].flops)
                            .unwrap()
                    })
                    .unwrap();
                let take = surplus.min(resident0[i]);
                resident0[i] -= take;
                surplus -= take;
            }
        }

        // Per-device offload slots: `k` offloaded layers need `ceil(k/#Seg)`
        // shared slots resident, so k <= #Seg * floor(budget/l). The slot
        // count is seg-independent; candidates multiply by their `seg`.
        let slot_units: Vec<usize> = (0..d)
            .map(|i| {
                let kv = kv_per_layer; // at least one layer's KV accompanies a slot
                let budget = layer_budget(spec, cluster, i)
                    .saturating_sub(resident0[i] as u64 * (spec.layer_bytes() + kv_per_layer));
                (budget / (spec.layer_bytes() + kv)) as usize
            })
            .collect();

        SegSweepCtx {
            comp: cost::CompTimeTable::build(spec, cluster, opts.empirical_tokens, opts.micro_batch),
            comm: cost::idle_comm_term(spec, cluster, opts.micro_batch, opts.bandwidth),
            load_one: (0..d)
                .map(|i| spec.layer_bytes() as f64 / cluster.devices[i].ssd_read_bps)
                .collect(),
            resident0,
            slot_units,
        }
    }

    /// `T_i^idle` (Eq. 2) for the all-resident base allocation implied by
    /// `resident` — bit-identical to `cost::t_idle` on that base (the memo
    /// table reproduces each `comp_time` term; same summation order).
    fn idle_from_resident(&self, resident: &[usize]) -> Vec<f64> {
        let d = resident.len();
        (0..d)
            .map(|i| {
                let own = self.comp.get(i, resident[i]);
                let others: f64 = resident
                    .iter()
                    .enumerate()
                    .filter(|(j, _)| *j != i)
                    .map(|(j, &r)| self.comp.get(j, r))
                    .sum();
                own + others + self.comm
            })
            .collect()
    }
}

/// Run the full offline scheduler: try every `#Seg` in `2..=⌈|L|/|D|⌉`
/// (plus the no-offload degenerate case) and keep the cheapest plan.
///
/// Candidates evaluate on the global work-stealing pool (nested-submission
/// safe); the chosen allocation and `seg_curve` are identical to the
/// sequential sweep.
pub fn plan(spec: &ModelSpec, cluster: &Cluster, opts: &PlanOptions) -> Result<PlanReport, PlanError> {
    plan_on_pool(spec, cluster, opts, Some(crate::util::pool::global()))
}

/// [`plan`] with an explicit worker count: `threads <= 1` is the exact
/// sequential reference; larger counts run on a dedicated pool of that
/// size. The result does not depend on `threads` — asserted by the
/// property tests in `rust/tests/trace_modes.rs` and `rust/tests/pool.rs`.
pub fn plan_with_threads(
    spec: &ModelSpec,
    cluster: &Cluster,
    opts: &PlanOptions,
    threads: usize,
) -> Result<PlanReport, PlanError> {
    if threads <= 1 {
        plan_on_pool(spec, cluster, opts, None)
    } else {
        let pool = Pool::new(threads);
        plan_on_pool(spec, cluster, opts, Some(&pool))
    }
}

/// [`plan`] on an explicit pool (`None` = sequential reference path).
pub fn plan_on_pool(
    spec: &ModelSpec,
    cluster: &Cluster,
    opts: &PlanOptions,
    pool: Option<&Pool>,
) -> Result<PlanReport, PlanError> {
    // Degenerate case first: everything fits resident -> plain pipeline.
    if let Some(alloc) = try_all_resident(spec, cluster, opts) {
        let cb = cost::t_total(&alloc, cluster, opts.empirical_tokens, opts.micro_batch, opts.bandwidth);
        return Ok(PlanReport {
            allocation: alloc,
            cost: cb,
            seg_curve: vec![(1, cb.total())],
        });
    }

    let ctx = SegSweepCtx::new(spec, cluster, opts);
    let seg_max = spec.layers.div_ceil(cluster.len()).max(2);
    let segs: Vec<usize> = (2..=seg_max).collect();
    let eval = |&seg: &usize| {
        plan_with_seg_ctx(spec, cluster, seg, opts, &ctx).ok().map(|alloc| {
            let cb = cost::t_total_cached(
                &ctx.comp,
                &alloc,
                cluster,
                opts.micro_batch,
                opts.bandwidth,
                ctx.comm,
            );
            (alloc, cb)
        })
    };
    let evaluated = match pool {
        Some(p) => p.map_indexed(&segs, eval),
        None => segs.iter().map(eval).collect(),
    };

    // Sequential reduction in candidate order: ties resolve exactly as the
    // old single-threaded loop did (first strictly-cheaper candidate wins).
    let mut best: Option<(Allocation, cost::CostBreakdown)> = None;
    let mut seg_curve = Vec::new();
    for (&seg, evaluated) in segs.iter().zip(evaluated) {
        let Some((alloc, cb)) = evaluated else {
            continue;
        };
        seg_curve.push((seg, cb.total()));
        let better = match &best {
            None => true,
            Some((_, b)) => cb.total() < b.total(),
        };
        if better {
            best = Some((alloc, cb));
        }
    }
    match best {
        Some((allocation, cb)) => Ok(PlanReport {
            allocation,
            cost: cb,
            seg_curve,
        }),
        None => Err(PlanError::OutOfMemory(format!(
            "{} on {} devices: no feasible #Seg in 2..={}",
            spec.name,
            cluster.len(),
            seg_max
        ))),
    }
}

/// Memory available to device `i` for decoder layers at planning time.
fn layer_budget(spec: &ModelSpec, cluster: &Cluster, i: usize) -> u64 {
    let embed = if i == 0 || i + 1 == cluster.len() {
        spec.embed_bytes() / 2
    } else {
        0
    };
    cluster.devices[i].usable_mem().saturating_sub(embed)
}

/// Try the no-offload allocation: all layers resident, compute-balanced.
fn try_all_resident(spec: &ModelSpec, cluster: &Cluster, opts: &PlanOptions) -> Option<Allocation> {
    let kv_per_layer = opts.empirical_tokens as u64 * spec.kv_bytes_per_token_layer();
    let per_layer = spec.layer_bytes() + kv_per_layer;
    let caps: Vec<usize> = (0..cluster.len())
        .map(|i| (layer_budget(spec, cluster, i) / per_layer) as usize)
        .collect();
    if caps.iter().sum::<usize>() < spec.layers {
        return None;
    }
    // Balance by compute rate, clamped by capacity.
    let total_flops: f64 = cluster.devices.iter().map(|d| d.flops).sum();
    let mut counts: Vec<usize> = cluster
        .devices
        .iter()
        .zip(&caps)
        .map(|(d, &cap)| (((spec.layers as f64) * d.flops / total_flops).round() as usize).min(cap))
        .collect();
    // Repair rounding drift against capacities.
    let mut assigned: usize = counts.iter().sum();
    while assigned > spec.layers {
        let i = (0..counts.len()).max_by_key(|&i| counts[i]).unwrap();
        counts[i] -= 1;
        assigned -= 1;
    }
    let mut guard = 0;
    while assigned < spec.layers {
        // Give to the fastest device with headroom.
        let candidates: Vec<usize> = (0..counts.len()).filter(|&i| counts[i] < caps[i]).collect();
        let &i = candidates
            .iter()
            .max_by(|&&a, &&b| cluster.devices[a].flops.partial_cmp(&cluster.devices[b].flops).unwrap())?;
        counts[i] += 1;
        assigned += 1;
        guard += 1;
        if guard > spec.layers * 2 {
            return None;
        }
    }
    let alloc = Allocation::new(
        spec.clone(),
        1,
        counts.into_iter().map(DeviceAssignment::resident).collect(),
    );
    cost::feasible(&alloc, cluster, opts.empirical_tokens).ok()?;
    Some(alloc)
}

/// Plan for a fixed `#Seg` (phases 1–4 above). Standalone entry point —
/// builds the shared sweep context for just this candidate; sweeping
/// several candidates? Use [`plan_with_segs`] (or `plan()`), which
/// amortizes one context across all of them.
pub fn plan_with_seg(
    spec: &ModelSpec,
    cluster: &Cluster,
    seg: usize,
    opts: &PlanOptions,
) -> Result<Allocation, PlanError> {
    let ctx = SegSweepCtx::new(spec, cluster, opts);
    plan_with_seg_ctx(spec, cluster, seg, opts, &ctx)
}

/// Plan every candidate in `segs` against one shared `SegSweepCtx` on
/// the global pool (nested-submission safe). Entry `k` is `None` when
/// `segs[k]` is infeasible; each `Some` is exactly
/// `plan_with_seg(spec, cluster, segs[k], opts).ok()` — the context is
/// deterministic, so sharing it changes nothing but the cost of
/// rebuilding it per candidate.
pub fn plan_with_segs(
    spec: &ModelSpec,
    cluster: &Cluster,
    segs: &[usize],
    opts: &PlanOptions,
) -> Vec<Option<Allocation>> {
    let ctx = SegSweepCtx::new(spec, cluster, opts);
    crate::util::pool::global().map_indexed(segs, |&seg| {
        plan_with_seg_ctx(spec, cluster, seg, opts, &ctx).ok()
    })
}

/// Phases 2–4 for one `#Seg` candidate against the shared context.
fn plan_with_seg_ctx(
    spec: &ModelSpec,
    cluster: &Cluster,
    seg: usize,
    opts: &PlanOptions,
    ctx: &SegSweepCtx,
) -> Result<Allocation, PlanError> {
    assert!(seg >= 2);
    let d = cluster.len();
    let mut resident = ctx.resident0.clone();
    let slot_caps: Vec<usize> = ctx.slot_units.iter().map(|&units| units * seg).collect();

    // Phases 2-4 with feasibility-repair loop.
    let mut guard = 0usize;
    loop {
        let left = spec.layers - resident.iter().sum::<usize>().min(spec.layers);
        let idle = ctx.idle_from_resident(&resident);
        let Some(offload) = dp_assign_offload(&idle, &ctx.load_one, &slot_caps, left) else {
            return Err(PlanError::OutOfMemory(format!(
                "{}: {left} layers cannot be placed within slot capacities {slot_caps:?}",
                spec.name
            )));
        };
        let mut alloc = build_allocation(spec, seg, &resident, &offload);
        refine_fine_grained(&mut alloc, cluster, opts, ctx);

        match cost::feasible(&alloc, cluster, opts.empirical_tokens) {
            Ok(()) => return Ok(alloc),
            Err(cost::MemError::OverCapacity { device, .. }) => {
                if resident[device] == 0 {
                    return Err(PlanError::OutOfMemory(format!(
                        "device {device} cannot hold even one offload slot for {}",
                        spec.name
                    )));
                }
                resident[device] -= 1;
            }
        }
        guard += 1;
        if guard > spec.layers * d + 8 {
            return Err(PlanError::OutOfMemory("repair loop did not converge".into()));
        }
    }
}

/// Phase 2 — the Alg. 1 DP over precomputed per-device idle times and
/// one-layer load times. Returns offloaded-layer counts per device, or
/// `None` when `left` layers cannot fit within the per-device slot caps.
fn dp_assign_offload(
    idle: &[f64],
    load_one: &[f64],
    slot_caps: &[usize],
    left: usize,
) -> Option<Vec<usize>> {
    let d = idle.len();
    if left == 0 {
        return Some(vec![0; d]);
    }

    const INF: f64 = f64::INFINITY;
    // f[l][i] over l in 0..=left, i in 0..d (device index, 0-based).
    let mut f = vec![vec![INF; d]; left + 1];
    let mut pre = vec![vec![0usize; d]; left + 1];
    for l in 0..=left.min(slot_caps[0]) {
        f[l][0] = (load_one[0] * l as f64 - idle[0]).max(0.0); // Eq. 3, clamped
        pre[l][0] = l;
    }
    for i in 1..d {
        for l in 0..=left {
            for k in 0..=l.min(slot_caps[i]) {
                let prev = f[l - k][i - 1];
                if !prev.is_finite() {
                    continue;
                }
                let t_cur = (prev + load_one[i] * k as f64 - idle[i]).max(0.0); // lines 6-7
                if t_cur <= f[l][i] {
                    f[l][i] = t_cur;
                    pre[l][i] = k;
                }
            }
        }
    }
    if !f[left][d - 1].is_finite() {
        return None; // slot capacities cannot absorb `left` layers
    }
    // Backtrack (line 11).
    let mut counts = vec![0usize; d];
    let mut l = left;
    for i in (0..d).rev() {
        let k = pre[l][i];
        counts[i] = k;
        l -= k;
    }
    debug_assert_eq!(l, 0);
    Some(counts)
}

fn build_allocation(
    spec: &ModelSpec,
    seg: usize,
    resident: &[usize],
    offload: &[usize],
) -> Allocation {
    let devices = resident
        .iter()
        .zip(offload)
        .map(|(&r, &o)| DeviceAssignment {
            total_layers: r + o,
            full_offload: o,
            mha_offload: 0,
            mlp_offload: 0,
        })
        .collect();
    Allocation::new(spec.clone(), seg, devices)
}

#[derive(PartialEq)]
struct HeapEntry {
    uncovered: f64,
    device: usize,
}
impl Eq for HeapEntry {}
impl PartialOrd for HeapEntry {
    fn partial_cmp(&self, other: &Self) -> Option<Ordering> {
        Some(self.cmp(other))
    }
}
impl Ord for HeapEntry {
    fn cmp(&self, other: &Self) -> Ordering {
        self.uncovered
            .partial_cmp(&other.uncovered)
            .unwrap_or(Ordering::Equal)
            .then(other.device.cmp(&self.device))
    }
}

/// Phase 3 — Alg. 1 lines 12–27: bottleneck-first block pinning. Uncovered
/// times read the shared memo table (`cost::t_idle_cached` is bit-identical
/// to `cost::t_idle`).
fn refine_fine_grained(
    alloc: &mut Allocation,
    cluster: &Cluster,
    opts: &PlanOptions,
    ctx: &SegSweepCtx,
) {
    let spec = alloc.spec.clone();
    let uncovered = |alloc: &Allocation, i: usize| -> f64 {
        let load = cost::load_time(&spec, &cluster.devices[i], &alloc.devices[i]);
        let idle = cost::t_idle_cached(&ctx.comp, alloc, i, ctx.comm);
        (load - idle).max(0.0)
    };
    let free_mem = |alloc: &Allocation, i: usize| -> u64 {
        cluster.devices[i]
            .usable_mem()
            .saturating_sub(cost::mem_demand(alloc, i, opts.empirical_tokens, 0))
    };

    let mut heap: BinaryHeap<HeapEntry> = (0..cluster.len())
        .map(|i| HeapEntry {
            uncovered: uncovered(alloc, i),
            device: i,
        })
        .collect();

    let mut steps = 0usize;
    while let Some(top) = heap.pop() {
        if top.uncovered <= 0.0 || steps > 4 * spec.layers {
            break;
        }
        let i = top.device;
        let free = free_mem(alloc, i);
        let a = &mut alloc.devices[i];
        // Prefer pinning the larger block (bigger load reduction); a full
        // offloaded layer is needed to split.
        let pinned = if a.full_offload >= 1 && free >= spec.mlp_bytes() {
            a.full_offload -= 1;
            a.mha_offload += 1; // MLP pinned, MHA still streamed
            true
        } else if a.full_offload >= 1 && free >= spec.mha_bytes() {
            a.full_offload -= 1;
            a.mlp_offload += 1; // MHA pinned, MLP still streamed
            true
        } else if a.mha_offload >= 1 && free >= spec.mha_bytes() {
            a.mha_offload -= 1; // pin the remaining MHA too -> fully resident
            true
        } else if a.mlp_offload >= 1 && free >= spec.mlp_bytes() {
            a.mlp_offload -= 1;
            true
        } else {
            false
        };
        if !pinned {
            // Alg. 1 line 24-25: bottleneck can't improve; optimum reached.
            break;
        }
        steps += 1;
        heap.push(HeapEntry {
            uncovered: uncovered(alloc, i),
            device: i,
        });
    }
}

/// Exhaustive reference for the Phase-2 objective (test oracle): minimum of
/// the clamped accumulation over *all* ways to split `left` layers across
/// devices. Exponential — only for tiny instances in tests. Deliberately
/// evaluates `cost::t_idle` directly (not the memo table) so it also pins
/// the incremental DP inputs against the term-by-term originals.
pub fn exhaustive_offload_reference(
    spec: &ModelSpec,
    cluster: &Cluster,
    resident: &[usize],
    left: usize,
    seg: usize,
    opts: &PlanOptions,
) -> (f64, Vec<usize>) {
    let d = cluster.len();
    let base = Allocation::new(
        spec.clone(),
        seg,
        resident.iter().map(|&r| DeviceAssignment::resident(r)).collect(),
    );
    let idle: Vec<f64> = (0..d)
        .map(|i| cost::t_idle(&base, cluster, i, opts.empirical_tokens, opts.micro_batch, opts.bandwidth))
        .collect();
    let load_one: Vec<f64> = (0..d)
        .map(|i| spec.layer_bytes() as f64 / cluster.devices[i].ssd_read_bps)
        .collect();

    let mut best = (f64::INFINITY, vec![0usize; d]);
    let mut counts = vec![0usize; d];
    fn rec(
        i: usize,
        remaining: usize,
        counts: &mut Vec<usize>,
        d: usize,
        load_one: &[f64],
        idle: &[f64],
        best: &mut (f64, Vec<usize>),
    ) {
        if i == d {
            if remaining != 0 {
                return;
            }
            let mut acc = 0.0f64;
            for j in 0..d {
                acc = (acc + load_one[j] * counts[j] as f64 - idle[j]).max(0.0);
            }
            if acc < best.0 {
                *best = (acc, counts.clone());
            }
            return;
        }
        for k in 0..=remaining {
            counts[i] = k;
            rec(i + 1, remaining - k, counts, d, load_one, idle, best);
        }
        counts[i] = 0;
    }
    rec(0, left, &mut counts, d, &load_one, &idle, &mut best);
    best
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::bytes::mbps;

    fn opts() -> PlanOptions {
        PlanOptions {
            empirical_tokens: 512,
            micro_batch: 1,
            bandwidth: mbps(200.0),
        }
    }

    #[test]
    fn e1_llama13b_plans() {
        let spec = ModelSpec::llama2_13b();
        let cluster = Cluster::env_e1();
        let report = plan(&spec, &cluster, &opts()).unwrap();
        assert!(report.allocation.covers_model());
        assert!(report.cost.total() > 0.0);
    }

    #[test]
    fn e3_llama70b_fits_marginally() {
        // Fig. 14 regime: in E3 the model *barely* fits (plain Pipeline is
        // not marked OOM in the paper), so LIME may choose the degenerate
        // all-resident plan.
        let spec = ModelSpec::llama33_70b();
        let cluster = Cluster::env_e3();
        let report = plan(&spec, &cluster, &opts()).unwrap();
        assert!(report.allocation.covers_model());
        assert!(cost::feasible(&report.allocation, &cluster, 512).is_ok());
    }

    #[test]
    fn lowmem_setting3_requires_offload() {
        // Figs 15-17 regime: the reduced-memory settings cannot hold the
        // model resident, so the offload machinery must engage.
        let spec = ModelSpec::llama33_70b();
        let cluster = Cluster::lowmem_setting3();
        let report = plan(&spec, &cluster, &opts()).unwrap();
        let alloc = &report.allocation;
        assert!(alloc.covers_model());
        let offloaded: usize = alloc.devices.iter().map(|d| d.offloaded_count()).sum();
        assert!(offloaded > 0, "{}", alloc.describe());
        assert!(alloc.seg >= 2);
        assert!(cost::feasible(alloc, &cluster, 512).is_ok());
    }

    #[test]
    fn small_model_on_big_cluster_needs_no_offload() {
        let spec = ModelSpec::tiny_lm();
        let cluster = Cluster::env_e2();
        let report = plan(&spec, &cluster, &opts()).unwrap();
        let offloaded: usize = report.allocation.devices.iter().map(|d| d.offloaded_count()).sum();
        assert_eq!(offloaded, 0);
        assert_eq!(report.allocation.seg, 1);
    }

    #[test]
    fn infeasible_cluster_reports_oom() {
        use crate::cluster::DeviceSpec;
        use crate::util::bytes::gib;
        let spec = ModelSpec::llama33_70b();
        // Two 4 GB devices can't even hold slots + embed shares.
        let cluster = Cluster::new(vec![
            DeviceSpec::xavier_nx_16().with_mem_limit(gib(4.0)),
            DeviceSpec::xavier_nx_16().with_mem_limit(gib(4.0)),
        ]);
        assert!(plan(&spec, &cluster, &opts()).is_err());
    }

    #[test]
    fn dp_matches_exhaustive_reference() {
        let spec = ModelSpec::llama2_13b();
        let cluster = Cluster::env_e2();
        let resident = vec![8, 6, 4];
        let o = opts();
        let caps = vec![usize::MAX; cluster.len()];
        // DP inputs exactly as plan_with_seg_ctx derives them.
        let idle: Vec<f64> = {
            let base = Allocation::new(
                spec.clone(),
                2,
                resident.iter().map(|&r| DeviceAssignment::resident(r)).collect(),
            );
            (0..cluster.len())
                .map(|i| cost::t_idle(&base, &cluster, i, o.empirical_tokens, o.micro_batch, o.bandwidth))
                .collect()
        };
        let load_one: Vec<f64> = (0..cluster.len())
            .map(|i| spec.layer_bytes() as f64 / cluster.devices[i].ssd_read_bps)
            .collect();
        for left in [1usize, 3, 5, 7] {
            let dp = dp_assign_offload(&idle, &load_one, &caps, left).unwrap();
            let (ref_cost, _) = exhaustive_offload_reference(&spec, &cluster, &resident, left, 2, &o);
            let mut acc = 0.0f64;
            for j in 0..cluster.len() {
                acc = (acc + load_one[j] * dp[j] as f64 - idle[j]).max(0.0);
            }
            assert!(
                acc <= ref_cost + 1e-9,
                "left={left}: dp cost {acc} > exhaustive {ref_cost}"
            );
        }
    }

    #[test]
    fn ctx_idle_matches_direct_t_idle_bitwise() {
        // The planner-equality pin: the hoisted idle table feeding the DP
        // must reproduce cost::t_idle on the all-resident base exactly, for
        // every repair-loop resident vector the sweep can visit.
        let spec = ModelSpec::llama33_70b();
        let cluster = Cluster::lowmem_setting1();
        let o = opts();
        let ctx = SegSweepCtx::new(&spec, &cluster, &o);
        let mut resident = ctx.resident0.clone();
        for _round in 0..4 {
            let fast = ctx.idle_from_resident(&resident);
            let base = Allocation::new(
                spec.clone(),
                2,
                resident.iter().map(|&r| DeviceAssignment::resident(r)).collect(),
            );
            for i in 0..cluster.len() {
                let direct =
                    cost::t_idle(&base, &cluster, i, o.empirical_tokens, o.micro_batch, o.bandwidth);
                assert_eq!(
                    fast[i].to_bits(),
                    direct.to_bits(),
                    "dev{i} resident={resident:?}: {} != {}",
                    fast[i],
                    direct
                );
            }
            // Mimic the repair loop: shed a layer from the fullest device.
            if let Some(i) = (0..resident.len()).max_by_key(|&i| resident[i]) {
                if resident[i] > 0 {
                    resident[i] -= 1;
                }
            }
        }
    }

    #[test]
    fn plan_with_segs_matches_per_candidate_plan_with_seg() {
        let spec = ModelSpec::llama33_70b();
        let cluster = Cluster::lowmem_setting1();
        let o = opts();
        let segs: Vec<usize> = (2..=8).collect();
        let shared = plan_with_segs(&spec, &cluster, &segs, &o);
        assert_eq!(shared.len(), segs.len());
        for (&seg, got) in segs.iter().zip(&shared) {
            let standalone = plan_with_seg(&spec, &cluster, seg, &o).ok();
            assert_eq!(got, &standalone, "seg={seg}");
        }
    }

    #[test]
    fn standalone_plan_with_seg_matches_sweep_candidate() {
        // plan_with_seg (fresh ctx) and the sweep (shared ctx) must agree:
        // the context is deterministic, so a candidate planned either way
        // is the same allocation.
        let spec = ModelSpec::llama33_70b();
        let cluster = Cluster::lowmem_setting1();
        let o = opts();
        let ctx = SegSweepCtx::new(&spec, &cluster, &o);
        for seg in 2..=6 {
            let standalone = plan_with_seg(&spec, &cluster, seg, &o);
            let shared = plan_with_seg_ctx(&spec, &cluster, seg, &o, &ctx);
            assert_eq!(standalone, shared, "seg={seg}");
        }
    }

    #[test]
    fn refinement_never_increases_load() {
        let spec = ModelSpec::llama33_70b();
        let cluster = Cluster::env_e3();
        let o = opts();
        let ctx = SegSweepCtx::new(&spec, &cluster, &o);
        let mut alloc = plan_with_seg(&spec, &cluster, 2, &o).unwrap();
        let before: u64 = alloc.devices.iter().map(|d| d.load_bytes(&spec)).sum();
        refine_fine_grained(&mut alloc, &cluster, &o, &ctx);
        let after: u64 = alloc.devices.iter().map(|d| d.load_bytes(&spec)).sum();
        assert!(after <= before);
    }

    #[test]
    fn seg_curve_has_interior_optimum_shape() {
        // Figs 7-8: both too-few and too-many segments should not beat the
        // chosen optimum.
        let spec = ModelSpec::llama33_70b();
        let cluster = Cluster::lowmem_setting1();
        let report = plan(&spec, &cluster, &opts()).unwrap();
        let best_cost = report.cost.total();
        for &(s, c) in &report.seg_curve {
            assert!(c + 1e-12 >= best_cost, "seg={s} cost {c} < best {best_cost}");
        }
    }

    #[test]
    fn plans_are_deterministic() {
        let spec = ModelSpec::qwen3_32b();
        let cluster = Cluster::env_e2();
        let a = plan(&spec, &cluster, &opts()).unwrap();
        let b = plan(&spec, &cluster, &opts()).unwrap();
        assert_eq!(a.allocation, b.allocation);
    }

    #[test]
    fn thread_count_does_not_change_the_plan() {
        let spec = ModelSpec::llama33_70b();
        let cluster = Cluster::lowmem_setting1();
        let o = opts();
        let seq = plan_with_threads(&spec, &cluster, &o, 1).unwrap();
        for threads in [2, 4, 8] {
            let par = plan_with_threads(&spec, &cluster, &o, threads).unwrap();
            assert_eq!(seq.allocation, par.allocation, "threads={threads}");
            assert_eq!(seq.seg_curve, par.seg_curve, "threads={threads}");
            assert_eq!(seq.cost, par.cost, "threads={threads}");
        }
    }
}
