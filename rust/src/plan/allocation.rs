//! Allocation data model: which layers live on which device, which of them
//! are offloaded (fully, or at MHA/MLP block granularity — §IV-C's
//! fine-grained offloading), and how they spread across interleaved-pipeline
//! segments.

use crate::model::ModelSpec;

/// Per-device slice of the allocation.
///
/// Layer counts decompose as
/// `total = fully_resident + full_offload + mha_offload + mlp_offload`
/// where `mha_offload` layers keep their MLP block pinned in GPU memory and
/// stream only the MHA block from SSD (and vice versa for `mlp_offload`).
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct DeviceAssignment {
    /// `|L_i|` — all layers this device computes.
    pub total_layers: usize,
    /// Layers whose full parameter set streams from SSD each pass.
    pub full_offload: usize,
    /// Layers streaming only the MHA block (MLP pinned resident).
    pub mha_offload: usize,
    /// Layers streaming only the MLP block (MHA pinned resident).
    pub mlp_offload: usize,
}

impl DeviceAssignment {
    pub fn resident(total_layers: usize) -> Self {
        DeviceAssignment {
            total_layers,
            full_offload: 0,
            mha_offload: 0,
            mlp_offload: 0,
        }
    }

    /// `|L~_i|` — layers touching SSD every pass (any granularity).
    pub fn offloaded_count(&self) -> usize {
        self.full_offload + self.mha_offload + self.mlp_offload
    }

    /// `|L_i − L~_i|` — layers that never touch SSD.
    pub fn non_offloaded_layers(&self) -> usize {
        self.total_layers - self.offloaded_count()
    }

    /// Bytes read from SSD per full token pass.
    pub fn load_bytes(&self, spec: &ModelSpec) -> u64 {
        self.full_offload as u64 * spec.layer_bytes()
            + self.mha_offload as u64 * spec.mha_bytes()
            + self.mlp_offload as u64 * spec.mlp_bytes()
    }

    /// Resident GPU bytes for parameters: fully-resident layers, pinned
    /// blocks of split layers, plus the shared offload *slots* — one
    /// segment's worth of streamed bytes stays mapped at a time (slots are
    /// reused across segments; that sharing is the interleaved pipeline's
    /// memory trick).
    pub fn resident_bytes(&self, spec: &ModelSpec, seg: usize) -> u64 {
        let seg = seg.max(1) as u64;
        let fully = self.non_offloaded_layers() as u64 * spec.layer_bytes();
        let pinned = self.mha_offload as u64 * spec.mlp_bytes()
            + self.mlp_offload as u64 * spec.mha_bytes();
        let slots = div_ceil_u64(self.full_offload as u64, seg) * spec.layer_bytes()
            + div_ceil_u64(self.mha_offload as u64, seg) * spec.mha_bytes()
            + div_ceil_u64(self.mlp_offload as u64, seg) * spec.mlp_bytes();
        fully + pinned + slots
    }

    /// Internal consistency.
    pub fn valid(&self) -> bool {
        self.offloaded_count() <= self.total_layers
    }
}

fn div_ceil_u64(a: u64, b: u64) -> u64 {
    a.div_ceil(b)
}

/// A complete plan: the model, the segment count `#Seg`, and one
/// [`DeviceAssignment`] per device in pipeline order. Layers are assigned
/// contiguously in pipeline order (device 0 gets layers `0..n_0`, etc.).
#[derive(Debug, Clone, PartialEq)]
pub struct Allocation {
    pub spec: ModelSpec,
    /// `#Seg` — interleaved-pipeline segment count (1 = plain pipeline).
    pub seg: usize,
    pub devices: Vec<DeviceAssignment>,
}

impl Allocation {
    pub fn new(spec: ModelSpec, seg: usize, devices: Vec<DeviceAssignment>) -> Self {
        let a = Allocation { spec, seg, devices };
        debug_assert!(a.devices.iter().all(|d| d.valid()));
        a
    }

    /// Total layers covered by the plan.
    pub fn layer_sum(&self) -> usize {
        self.devices.iter().map(|d| d.total_layers).sum()
    }

    /// The contiguous global layer range `[start, end)` of device `i`.
    pub fn layer_range(&self, i: usize) -> (usize, usize) {
        let start: usize = self.devices[..i].iter().map(|d| d.total_layers).sum();
        (start, start + self.devices[i].total_layers)
    }

    /// Does the plan cover every layer exactly once?
    pub fn covers_model(&self) -> bool {
        self.layer_sum() == self.spec.layers
    }

    /// Layers of device `i` active in segment `s` (even split, earlier
    /// segments take the remainder).
    pub fn layers_in_segment(&self, i: usize, s: usize) -> usize {
        let total = self.devices[i].total_layers;
        let base = total / self.seg;
        let rem = total % self.seg;
        base + usize::from(s < rem)
    }

    /// Offloaded-unit count of device `i` active in segment `s`.
    pub fn offloaded_in_segment(&self, i: usize, s: usize) -> usize {
        let total = self.devices[i].offloaded_count();
        let base = total / self.seg;
        let rem = total % self.seg;
        base + usize::from(s < rem)
    }

    /// Human-readable summary.
    pub fn describe(&self) -> String {
        let mut s = format!(
            "{}: {} layers over {} devices, #Seg={}\n",
            self.spec.name,
            self.spec.layers,
            self.devices.len(),
            self.seg
        );
        for (i, d) in self.devices.iter().enumerate() {
            let (lo, hi) = self.layer_range(i);
            s.push_str(&format!(
                "  dev{i}: layers [{lo},{hi}) total={} resident={} offload(full={}, mha={}, mlp={})\n",
                d.total_layers,
                d.non_offloaded_layers(),
                d.full_offload,
                d.mha_offload,
                d.mlp_offload
            ));
        }
        s
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn spec() -> ModelSpec {
        ModelSpec::llama2_13b()
    }

    #[test]
    fn counts_decompose() {
        let a = DeviceAssignment {
            total_layers: 10,
            full_offload: 2,
            mha_offload: 1,
            mlp_offload: 1,
        };
        assert_eq!(a.offloaded_count(), 4);
        assert_eq!(a.non_offloaded_layers(), 6);
        assert!(a.valid());
    }

    #[test]
    fn load_bytes_by_granularity() {
        let s = spec();
        let full = DeviceAssignment {
            total_layers: 4,
            full_offload: 1,
            mha_offload: 0,
            mlp_offload: 0,
        };
        let mha_only = DeviceAssignment {
            total_layers: 4,
            full_offload: 0,
            mha_offload: 1,
            mlp_offload: 0,
        };
        let mlp_only = DeviceAssignment {
            total_layers: 4,
            full_offload: 0,
            mha_offload: 0,
            mlp_offload: 1,
        };
        assert_eq!(full.load_bytes(&s), s.layer_bytes());
        assert_eq!(mha_only.load_bytes(&s), s.mha_bytes());
        assert_eq!(mlp_only.load_bytes(&s), s.mlp_bytes());
        assert_eq!(
            mha_only.load_bytes(&s) + mlp_only.load_bytes(&s),
            full.load_bytes(&s)
        );
    }

    #[test]
    fn resident_bytes_fall_with_more_segments() {
        let s = spec();
        let a = DeviceAssignment {
            total_layers: 12,
            full_offload: 6,
            mha_offload: 0,
            mlp_offload: 0,
        };
        let seg2 = a.resident_bytes(&s, 2);
        let seg6 = a.resident_bytes(&s, 6);
        assert!(seg6 < seg2, "more segments share slots harder");
    }

    #[test]
    fn pinned_blocks_count_as_resident() {
        let s = spec();
        let plain = DeviceAssignment {
            total_layers: 12,
            full_offload: 6,
            mha_offload: 0,
            mlp_offload: 0,
        };
        let split = DeviceAssignment {
            total_layers: 12,
            full_offload: 5,
            mha_offload: 1, // MLP pinned
            mlp_offload: 0,
        };
        assert!(split.resident_bytes(&s, 3) > plain.resident_bytes(&s, 3));
        assert!(split.load_bytes(&s) < plain.load_bytes(&s));
    }

    #[test]
    fn allocation_ranges_partition() {
        let alloc = Allocation::new(
            spec(),
            2,
            vec![
                DeviceAssignment::resident(25),
                DeviceAssignment::resident(15),
            ],
        );
        assert!(alloc.covers_model());
        assert_eq!(alloc.layer_range(0), (0, 25));
        assert_eq!(alloc.layer_range(1), (25, 40));
    }

    #[test]
    fn segment_split_even_with_remainder() {
        let alloc = Allocation::new(
            spec(),
            3,
            vec![DeviceAssignment::resident(40)],
        );
        let per: Vec<usize> = (0..3).map(|s| alloc.layers_in_segment(0, s)).collect();
        assert_eq!(per.iter().sum::<usize>(), 40);
        assert_eq!(per, vec![14, 13, 13]);
    }

    #[test]
    fn describe_mentions_devices() {
        let alloc = Allocation::new(
            spec(),
            2,
            vec![
                DeviceAssignment::resident(20),
                DeviceAssignment::resident(20),
            ],
        );
        let d = alloc.describe();
        assert!(d.contains("dev0") && d.contains("dev1") && d.contains("#Seg=2"));
    }
}
