//! Threaded request server over the real PJRT engine.
//!
//! A producer thread emits requests on a channel (Poisson arrivals for the
//! sporadic pattern, an instantaneous burst for the bursty pattern); the
//! serving loop batches what is queued and drives the engine, recording
//! prefill latency, per-token decode latency, and end-to-end throughput.
//! (PJRT handles are not `Send`, so the engine itself stays on the serving
//! thread — the paper's leader/worker split maps onto channels here.)

use std::sync::mpsc;
use std::time::Instant;

use anyhow::Result;

use crate::metrics::LatencyRecorder;
use crate::serve::engine::{Engine, Generation};
use crate::workload::requests::{Request, RequestGen};

/// Serving statistics for one run.
#[derive(Debug, Clone)]
pub struct ServeReport {
    pub requests: usize,
    pub tokens: usize,
    /// Mean prefill latency (s).
    pub prefill_mean: f64,
    /// Per-token decode latency summary (s).
    pub token_p50: f64,
    pub token_p99: f64,
    pub token_mean: f64,
    /// End-to-end tokens/second over the busy time.
    pub throughput: f64,
    /// Generations, for losslessness checks.
    pub generations: Vec<Generation>,
}

/// Drive `engine` over a request stream.
pub fn serve(
    engine: &mut Engine,
    requests: Vec<Request>,
    realtime_arrivals: bool,
) -> Result<ServeReport> {
    let (tx, rx) = mpsc::channel::<Request>();
    let producer = std::thread::spawn(move || {
        let t0 = Instant::now();
        for r in requests {
            if realtime_arrivals {
                let target = r.arrival;
                let now = t0.elapsed().as_secs_f64();
                if target > now {
                    std::thread::sleep(std::time::Duration::from_secs_f64(target - now));
                }
            }
            if tx.send(r).is_err() {
                return;
            }
        }
    });

    let mut prefills = LatencyRecorder::new();
    let mut tokens = LatencyRecorder::new();
    let mut generations = Vec::new();
    let mut n_requests = 0usize;
    let mut n_tokens = 0usize;
    let busy_t0 = Instant::now();
    let mut busy = 0.0f64;

    while let Ok(req) = rx.recv() {
        n_requests += 1;
        let t_start = busy_t0.elapsed().as_secs_f64();

        engine.reset();
        let t0 = Instant::now();
        let x_last = engine.prefill(&req.prompt)?;
        prefills.record(t0.elapsed().as_secs_f64());

        // Greedy decode with per-token timing.
        let cfg = engine.model().clone();
        let ln_f = engine.weights.get("ln_f")?;
        let w_out = engine.weights.get("lm_head")?;
        let mut logits = engine
            .runtime
            .execute("lm_head", &[x_last, ln_f, w_out])?
            .remove(0);
        let table = engine.weights.get("embed")?;
        let mut out_tokens = Vec::with_capacity(req.steps);
        let mut final_logits: Vec<f32> = logits.to_vec()?;
        for step in 0..req.steps {
            let t0 = Instant::now();
            let tok = crate::runtime::argmax_logits(&logits)?;
            out_tokens.push(tok);
            let pos = cfg.prefill_len + step;
            let ids = crate::runtime::literal_from_i32(&[tok], &[1, 1])?;
            let x = engine
                .runtime
                .execute("embed_decode", &[ids, table.clone()])?
                .remove(0);
            let (_, l) = engine.decode_step(x, pos)?;
            logits = l;
            final_logits = logits.to_vec()?;
            tokens.record(t0.elapsed().as_secs_f64());
            n_tokens += 1;
        }
        generations.push(Generation {
            tokens: out_tokens,
            final_logits,
        });
        busy += busy_t0.elapsed().as_secs_f64() - t_start;
    }
    producer.join().ok();

    let tsum = tokens.summary();
    Ok(ServeReport {
        requests: n_requests,
        tokens: n_tokens,
        prefill_mean: prefills.summary().mean,
        token_p50: tsum.p50,
        token_p99: tsum.p99,
        token_mean: tsum.mean,
        throughput: if busy > 0.0 { n_tokens as f64 / busy } else { 0.0 },
        generations,
    })
}

/// Build the request stream for a pattern.
pub fn make_requests(
    pattern_bursty: bool,
    count: usize,
    steps: usize,
    prompt_len: usize,
    vocab: usize,
    seed: u64,
) -> Vec<Request> {
    let mut gen = RequestGen::new(seed, vocab, prompt_len, steps);
    if pattern_bursty {
        gen.bursty(count)
    } else {
        gen.sporadic(count, 4.0)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::Manifest;
    use std::path::PathBuf;

    fn artifacts_dir() -> PathBuf {
        PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts")
    }

    #[test]
    fn serves_a_burst() {
        if !artifacts_dir().join("manifest.json").exists() {
            eprintln!("skipping: run `make artifacts`");
            return;
        }
        let mut engine = Engine::new(Manifest::load(artifacts_dir()).unwrap()).unwrap();
        let cfg = engine.model().clone();
        let reqs = make_requests(true, 3, 4, cfg.prefill_len, cfg.vocab, 9);
        let report = serve(&mut engine, reqs, false).unwrap();
        assert_eq!(report.requests, 3);
        assert_eq!(report.tokens, 12);
        assert!(report.throughput > 0.0);
        assert!(report.token_mean > 0.0);
        assert_eq!(report.generations.len(), 3);
    }
}
