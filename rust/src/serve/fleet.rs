//! Fleet-scale serving: N heterogeneous edge clusters behind one global
//! admission router.
//!
//! The paper deploys LIME on *one* memory-constrained cluster; a real edge
//! site runs several — an E3-class testbed next to a pair of Orins next to
//! a mixed rack — and requests hit a front door that must pick a cluster
//! before LIME's per-cluster scheduling even starts. This module models
//! that layer on top of [`crate::serve::simqueue`]:
//!
//! * a fleet is a list of [`FleetCluster`]s, each a [`Cluster::subset`] of
//!   some testbed with its own offline plan and network bandwidth;
//! * a [`RouterPolicy`] assigns every arriving request to one cluster —
//!   round-robin, join-shortest-queue on estimated backlog, or plan-aware
//!   (route to the cluster whose *planned* ms/token finishes the request
//!   earliest);
//! * routing runs as an **event-driven simulation** on the binary-heap
//!   DES core ([`crate::sim::Engine`]): the arrival cursor advances the
//!   calendar, per-cluster completion-feedback events retire estimates
//!   mid-stream, and the per-cluster state lives in version-stamped lazy
//!   min-heaps keyed by estimated free time — O(log C) per decision
//!   instead of the legacy O(C) scan ([`route_scan`], kept as the
//!   reference), with **identical decisions** when affinity is off
//!   (property-pinned in `rust/tests/fleet_des.rs`). The calendar holds
//!   at most one arrival plus C feedback events, so routing a
//!   10^6-request stream stays memory-flat;
//! * an optional **affinity router** ([`AffinitySpec`]) adds sticky
//!   sessions on top of the base policy: requests carry Zipf-distributed
//!   `session_id`s, a session returns to its resident cluster while the
//!   estimated-backlog penalty stays under a spill threshold, and a hit
//!   skips re-prefill for whatever prompt prefix is still resident in
//!   that cluster's [`KvPagePool`] (modeled as a shorter effective
//!   prompt: prefill FLOPs, activation volume and page registration are
//!   all charged from the non-cached suffix only — at least one token is
//!   always recomputed). Hits/reuse/spill counters flow through
//!   `StreamResult` into the `lime-fleet-v2` artifact;
//! * the expensive per-cluster stream simulations then fan out **one
//!   cluster per job** on the work-stealing pool and merge by index, so
//!   a 10^6-request fleet stream is embarrassingly parallel yet
//!   bit-identical to the sequential reference at any worker count;
//! * per-cluster shards fold requests into O(1) state as they finish —
//!   running sums, [`P2Quantile`] markers and a capped [`Reservoir`] per
//!   metric — never a per-request vector, so memory stays flat however
//!   long the stream runs ([`simulate_stream_sink`] with
//!   `retain_step_times = false`). Shards run under **FIFO batching**
//!   ([`simulate_stream_sink`] delegates to the FIFO admission path, not
//!   [`crate::serve::simqueue::BatchingOpts::continuous`]), which keeps
//!   `lime-fleet-v1` artifacts byte-identical to runs predating the
//!   continuous-batching axis — see `docs/SERVING.md` for the policy
//!   semantics;
//! * results serialize as schema `lime-fleet-v1` — or `lime-fleet-v2`, a
//!   strict superset adding an `affinity` header plus per-cell/per-shard
//!   reuse counters, if and only if the spec enables affinity (the
//!   singleton-downgrade rule: an affinity-free run *must* serialize as
//!   plain v1, byte-identical to earlier releases) — through the
//!   incremental [`StreamWriter`] (bytes identical to `Json::Display`,
//!   pinned in `util::json`); [`validate_fleet`] is the strict machine
//!   check behind `lime sweep-check` and the CI artifact gate.
//!
//! Determinism: request streams, routing, P² updates and reservoir
//! replacement are all seeded and sequential *within* a shard, and shards
//! never share mutable state — `run_fleet` equals `run_fleet_sequential`
//! byte-for-byte on the serialized artifact (pinned in
//! `rust/tests/fleet.rs`, and byte-diffed across `LIME_THREADS={1,4}` in
//! CI).

use crate::adapt::{ChurnEvent, ChurnKind, Script};
use crate::cluster::Cluster;
use crate::model::ModelSpec;
use crate::net::BandwidthTrace;
use crate::pipeline::core::CommonOptions;
use crate::pipeline::{ExecOptions, InterleavedPolicy};
use crate::plan::allocation::Allocation;
use crate::plan::{plan, PlanOptions};
use crate::serve::kvpages::{KvPagePool, KvPageSpec};
use crate::serve::simqueue::{simulate_stream_sink, RequestMetrics, StreamSink};
use crate::sim::engine::Engine as DesEngine;
use crate::sim::TraceMode;
use crate::util::json::{obj, Json, StreamWriter};
use crate::util::pool::Pool;
use crate::util::stats::{weighted_percentile, P2Quantile, Reservoir};
use crate::workload::requests::Request;
use crate::workload::{assign_sessions, stream_requests, Pattern};
use std::cmp::{Ordering, Reverse};
use std::collections::{BTreeSet, BinaryHeap, HashMap};

/// Prompt tokens charged per admitted batch (requests themselves are
/// generated with empty prompts so million-request streams stay flat).
const PROMPT_TOKENS: usize = 64;

/// Retained samples per metric per shard — the reservoir bound that keeps
/// tail-latency estimation O(1) in stream length.
const RESERVOIR_CAP: usize = 512;

/// One cluster of the fleet: a device subset with its own offline plan
/// and network bandwidth.
#[derive(Debug, Clone)]
pub struct FleetCluster {
    pub label: String,
    pub cluster: Cluster,
    pub alloc: Allocation,
    /// Network bandwidth of this cluster's interconnect, Mbps.
    pub bw_mbps: f64,
    /// Offline cost-model estimate (Eq. 2 total) of one decode step,
    /// seconds/token — the signal the plan-aware router routes on.
    pub planned_s_per_token: f64,
}

impl FleetCluster {
    /// Build one fleet member: subset `indices` of `testbed`, planned for
    /// `spec` at `bw_mbps`.
    pub fn new(
        label: &str,
        testbed: &Cluster,
        indices: &[usize],
        spec: &ModelSpec,
        bw_mbps: f64,
    ) -> Result<FleetCluster, String> {
        let cluster = testbed.subset(indices);
        let opts = PlanOptions {
            empirical_tokens: 256,
            micro_batch: 1,
            bandwidth: crate::util::bytes::mbps(bw_mbps),
        };
        let report = plan(spec, &cluster, &opts)
            .map_err(|e| format!("fleet cluster '{label}' does not plan: {e}"))?;
        Ok(FleetCluster {
            label: label.to_string(),
            cluster,
            planned_s_per_token: report.cost.total(),
            alloc: report.allocation,
            bw_mbps,
        })
    }
}

/// Global admission policy: which cluster serves an arriving request.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum RouterPolicy {
    /// Cycle through clusters by global request index.
    RoundRobin,
    /// Estimated-backlog join-shortest-queue: route to the cluster whose
    /// estimated free time is nearest (ties to the lowest index).
    JoinShortestQueue,
    /// Route to the cluster that *finishes* the request earliest under
    /// its offline plan: `max(est_free, arrival) + steps · planned_s/tok`.
    PlanAware,
}

impl RouterPolicy {
    pub fn key(self) -> &'static str {
        match self {
            RouterPolicy::RoundRobin => "rr",
            RouterPolicy::JoinShortestQueue => "jsq",
            RouterPolicy::PlanAware => "plan",
        }
    }

    pub fn all() -> [RouterPolicy; 3] {
        [
            RouterPolicy::RoundRobin,
            RouterPolicy::JoinShortestQueue,
            RouterPolicy::PlanAware,
        ]
    }
}

/// Artifact key for a request pattern.
pub fn pattern_key(p: Pattern) -> &'static str {
    match p {
        Pattern::Sporadic => "sporadic",
        Pattern::Bursty => "bursty",
    }
}

/// Session-affinity routing knobs. `Some` on a [`FleetSpec`] turns the
/// base policy into a sticky-session router: requests gain
/// Zipf-distributed `session_id`s, a session returns to its resident
/// cluster while the backlog penalty stays under `spill_threshold_s`,
/// and a hit skips re-prefill for the prompt prefix still resident in
/// that cluster's [`KvPagePool`]. `None` keeps routing — and the
/// serialized artifact — byte-identical to the affinity-free v1 fleet.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct AffinitySpec {
    /// Session population per stream (ids `0..sessions`).
    pub sessions: u64,
    /// Zipf exponent of the session popularity distribution (> 0; larger
    /// means a hotter head and more reuse).
    pub zipf_s: f64,
    /// Maximum estimated-backlog penalty (seconds) a session tolerates on
    /// its resident cluster before spilling to the policy's pick.
    pub spill_threshold_s: f64,
    /// Tokens per KV page in the per-cluster resident-context pools.
    pub page_tokens: usize,
    /// KV page budget per cluster, tokens — bounds resident contexts;
    /// overflow spills coldest pages and decays future reuse.
    pub budget_tokens: usize,
}

impl AffinitySpec {
    /// The demo affinity config behind `lime fleet --affinity` and the CI
    /// v2 determinism artifact: a 256-session Zipf(1.1) population, a
    /// half-second spill threshold, and a page budget of 64 full prompts
    /// per cluster.
    pub fn demo() -> AffinitySpec {
        AffinitySpec {
            sessions: 256,
            zipf_s: 1.1,
            spill_threshold_s: 0.5,
            page_tokens: 16,
            budget_tokens: 64 * PROMPT_TOKENS,
        }
    }
}

/// A fleet experiment: the cluster list crossed with router policies and
/// arrival patterns, one stream of `count` requests per pattern.
#[derive(Debug, Clone)]
pub struct FleetSpec {
    pub name: String,
    pub clusters: Vec<FleetCluster>,
    pub routers: Vec<RouterPolicy>,
    pub patterns: Vec<Pattern>,
    /// Requests per (router, pattern) cell.
    pub count: usize,
    /// Sporadic Poisson arrival rate, req/s.
    pub lambda: f64,
    /// Decode steps per request.
    pub steps: usize,
    pub seed: u64,
    /// Cluster-level churn: only the script's churn channel is read at
    /// fleet level, with `ChurnEvent::device` indexing this spec's
    /// cluster list and `at_step` the global *arrival index* the event
    /// fires before. `Script::none()` (the default everywhere churn is
    /// not under test) keeps routing — and the serialized artifact —
    /// byte-identical to the pre-churn fleet.
    pub churn: Script,
    /// Sticky-session routing with KV reuse; `None` (the default) emits
    /// exactly the v1 artifact. Does not compose with `churn` yet —
    /// [`run_fleet`] asserts the combination away.
    pub affinity: Option<AffinitySpec>,
}

/// Fixed seed of the demo fleet (`lime fleet`, benches, CI determinism).
pub const FLEET_SEED: u64 = 0x51DE_0A01;

impl FleetSpec {
    /// The demo fleet: four heterogeneous subsets of the E3 testbed
    /// serving Qwen3-32B, bandwidth rising with cluster size. This is the
    /// fleet behind `lime fleet`, the CI determinism artifact and the
    /// `fleet_stream_100k` bench entries.
    pub fn demo(count: usize, steps: usize) -> FleetSpec {
        let spec = ModelSpec::qwen3_32b();
        let e3 = Cluster::env_e3();
        let members: [(&str, &[usize], f64); 4] = [
            ("orin2", &[0, 1], 100.0),
            ("edge2", &[0, 2], 150.0),
            ("edge3", &[0, 2, 3], 200.0),
            ("edge4", &[0, 1, 2, 3], 250.0),
        ];
        let clusters = members
            .iter()
            .map(|(label, idx, bw)| {
                FleetCluster::new(label, &e3, idx, &spec, *bw).expect("demo fleet plans")
            })
            .collect();
        FleetSpec {
            name: "e3-demo-fleet".to_string(),
            clusters,
            routers: RouterPolicy::all().to_vec(),
            patterns: vec![Pattern::Sporadic, Pattern::Bursty],
            count,
            lambda: 200.0,
            steps,
            seed: FLEET_SEED,
            churn: Script::none(),
            affinity: None,
        }
    }

    /// [`FleetSpec::demo`] with the demo affinity config enabled — the
    /// spec behind `lime fleet --affinity` and the `lime-fleet-v2` CI
    /// determinism artifact.
    pub fn demo_affinity(count: usize, steps: usize) -> FleetSpec {
        let mut spec = FleetSpec::demo(count, steps);
        spec.name = "e3-demo-fleet-affinity".to_string();
        spec.affinity = Some(AffinitySpec::demo());
        spec
    }

    pub fn model(&self) -> &str {
        &self.clusters[0].alloc.spec.name
    }
}

/// Partition `requests` (sorted by arrival) across `clusters` under
/// `policy`. Returns per-cluster *index* lists into `requests` (4 bytes
/// per routed request instead of a `Request` clone — routing a
/// 10^6-request stream for every cell stays cheap); each list is
/// ascending, so materializing it yields a subsequence of the sorted
/// stream that feeds [`simulate_stream_sink`] directly.
///
/// Since the DES rebuild this delegates to [`route_des`]: an
/// event-driven simulation over heap-indexed routing state, O(log C)
/// per decision, with decisions identical to the legacy [`route_scan`]
/// reference (property-pinned in `rust/tests/fleet_des.rs`).
pub fn route(
    policy: RouterPolicy,
    requests: &[Request],
    clusters: &[FleetCluster],
) -> Vec<Vec<u32>> {
    route_des(policy, requests, clusters)
}

/// The legacy O(C)-per-decision routing scan, kept verbatim as the
/// decision reference for [`route_des`] (property tests, and the
/// `fleet_stream_1M_scan` bench side of the DES-vs-scan pair).
/// Sequential in global arrival order — the router sees only arrival
/// times, step counts and the offline plans, and tracks one
/// estimated-free-time scalar per cluster.
pub fn route_scan(
    policy: RouterPolicy,
    requests: &[Request],
    clusters: &[FleetCluster],
) -> Vec<Vec<u32>> {
    let n = clusters.len();
    assert!(n > 0, "routing needs at least one cluster");
    assert!(u32::try_from(requests.len()).is_ok(), "stream exceeds u32 indexing");
    let mut est_free = vec![0.0f64; n];
    let mut parts: Vec<Vec<u32>> = vec![Vec::new(); n];
    let alive = vec![true; n];
    for (k, r) in requests.iter().enumerate() {
        let pick = pick_cluster(policy, k, r, clusters, &est_free, &alive);
        // The estimate advances identically under every policy: service
        // begins when the cluster frees (or the request arrives) and runs
        // at the planned per-token rate.
        est_free[pick] =
            est_free[pick].max(r.arrival) + r.steps as f64 * plan_rate(clusters, pick);
        parts[pick].push(k as u32);
    }
    parts
}

/// [`route`] under a cluster-churn timeline. `ChurnEvent::device` indexes
/// `clusters` and `at_step` is the global *arrival index* the event fires
/// before. A `Down` marks the cluster unroutable and drains its
/// queued-but-not-started requests (estimated start still in the future
/// at the fault) back through `policy` to the surviving clusters, in
/// arrival order; in-service requests stay where they are. An `Up` makes
/// the cluster routable again. Returns the per-cluster ascending index
/// lists plus the re-route count. With an empty event list this routes
/// exactly like [`route`].
pub fn route_churn(
    policy: RouterPolicy,
    requests: &[Request],
    clusters: &[FleetCluster],
    churn: &[ChurnEvent],
) -> (Vec<Vec<u32>>, u64) {
    let n = clusters.len();
    assert!(n > 0, "routing needs at least one cluster");
    assert!(u32::try_from(requests.len()).is_ok(), "stream exceeds u32 indexing");
    for ev in churn {
        assert!(
            ev.device < n,
            "churn event targets cluster {} of a {n}-cluster fleet",
            ev.device
        );
    }
    let mut alive = vec![true; n];
    let mut est_free = vec![0.0f64; n];
    // Committed work per cluster: (request index, est_start, est_end),
    // est_start non-decreasing within a queue.
    let mut queues: Vec<Vec<(u32, f64, f64)>> = vec![Vec::new(); n];
    let mut rerouted = 0u64;
    for (k, r) in requests.iter().enumerate() {
        for ev in churn.iter().filter(|ev| ev.at_step == k) {
            match ev.kind {
                ChurnKind::Down => {
                    if !alive[ev.device] {
                        continue; // idempotent, like the pipeline core
                    }
                    alive[ev.device] = false;
                    assert!(
                        alive.iter().any(|&a| a),
                        "churn script leaves no routable cluster at arrival {k}"
                    );
                    // Drain everything that has not started by the fault
                    // time; the cluster keeps only its in-service work.
                    let now = r.arrival;
                    let q = &mut queues[ev.device];
                    let keep = q.partition_point(|&(_, start, _)| start < now);
                    let drained = q.split_off(keep);
                    est_free[ev.device] = q.last().map_or(0.0, |&(_, _, end)| end);
                    for (idx, _, _) in drained {
                        let rr = &requests[idx as usize];
                        let pick =
                            pick_cluster(policy, idx as usize, rr, clusters, &est_free, &alive);
                        // Re-dispatch happens at the fault: the drained
                        // request cannot start before `now`.
                        let start = est_free[pick].max(now);
                        let end = start + rr.steps as f64 * plan_rate(clusters, pick);
                        est_free[pick] = end;
                        queues[pick].push((idx, start, end));
                        rerouted += 1;
                    }
                }
                ChurnKind::Up => alive[ev.device] = true,
            }
        }
        let pick = pick_cluster(policy, k, r, clusters, &est_free, &alive);
        let start = est_free[pick].max(r.arrival);
        let end = start + r.steps as f64 * plan_rate(clusters, pick);
        est_free[pick] = end;
        queues[pick].push((k as u32, start, end));
    }
    let parts = queues
        .into_iter()
        .map(|q| {
            // Re-routes append out of arrival order; the shard contract
            // (and `simulate_stream_sink`) wants ascending indices.
            let mut idx: Vec<u32> = q.into_iter().map(|(i, _, _)| i).collect();
            idx.sort_unstable();
            idx
        })
        .collect();
    (parts, rerouted)
}

/// Planned seconds/token of cluster `c`, guarded: a non-finite or
/// non-positive offline signal (a corrupted plan, a division blow-up)
/// contributes zero service-time estimate instead of poisoning `est_free`
/// for every later routing decision.
fn plan_rate(clusters: &[FleetCluster], c: usize) -> f64 {
    let s = clusters[c].planned_s_per_token;
    if s.is_finite() && s > 0.0 {
        s
    } else {
        0.0
    }
}

/// Is the plan-aware signal usable across the whole fleet?
fn plan_signal_ok(clusters: &[FleetCluster]) -> bool {
    clusters
        .iter()
        .all(|c| c.planned_s_per_token.is_finite() && c.planned_s_per_token > 0.0)
}

/// One routing decision among the currently-alive clusters. `PlanAware`
/// falls back to the JSQ criterion per request whenever any cluster's
/// `planned_s_per_token` is non-finite or non-positive — a degenerate
/// signal must not silently route every request to the "free" cluster.
fn pick_cluster(
    policy: RouterPolicy,
    k: usize,
    r: &Request,
    clusters: &[FleetCluster],
    est_free: &[f64],
    alive: &[bool],
) -> usize {
    let n = clusters.len();
    match policy {
        RouterPolicy::RoundRobin => {
            let mut pick = k % n;
            while !alive[pick] {
                pick = (pick + 1) % n;
            }
            pick
        }
        RouterPolicy::JoinShortestQueue => {
            argmin_alive(alive, |c| (est_free[c] - r.arrival).max(0.0))
        }
        RouterPolicy::PlanAware if plan_signal_ok(clusters) => argmin_alive(alive, |c| {
            est_free[c].max(r.arrival) + r.steps as f64 * clusters[c].planned_s_per_token
        }),
        RouterPolicy::PlanAware => argmin_alive(alive, |c| (est_free[c] - r.arrival).max(0.0)),
    }
}

/// First alive index minimizing `f` (strict comparison — ties go low,
/// keeping routing deterministic across worker counts).
fn argmin_alive(alive: &[bool], f: impl Fn(usize) -> f64) -> usize {
    let mut best: Option<(usize, f64)> = None;
    for c in 0..alive.len() {
        if !alive[c] {
            continue;
        }
        let v = f(c);
        match best {
            Some((_, bv)) if v >= bv => {}
            _ => best = Some((c, v)),
        }
    }
    best.expect("at least one cluster must be alive").0
}

// ---------------------------------------------------------------------
// Event-driven router: heap-indexed state on the DES engine.
// ---------------------------------------------------------------------

/// Version-stamped lazy min-heap entry: `(key, cluster index, version)`.
/// Entries whose version no longer matches the cluster's are discarded
/// on pop instead of being removed eagerly (classic lazy deletion — the
/// heap never needs decrease-key).
#[derive(Debug, Clone, Copy)]
struct HeapEntry {
    key: f64,
    idx: usize,
    version: u64,
}

impl PartialEq for HeapEntry {
    fn eq(&self, other: &Self) -> bool {
        self.cmp(other) == Ordering::Equal
    }
}
impl Eq for HeapEntry {}
impl PartialOrd for HeapEntry {
    fn partial_cmp(&self, other: &Self) -> Option<Ordering> {
        Some(self.cmp(other))
    }
}
impl Ord for HeapEntry {
    fn cmp(&self, other: &Self) -> Ordering {
        // (key, index) lexicographic — index second reproduces the scan's
        // ties-go-low rule among equal keys. Keys are never NaN (est_free
        // advances by guarded `plan_rate`), so total_cmp == IEEE order.
        self.key
            .total_cmp(&other.key)
            .then(self.idx.cmp(&other.idx))
            .then(self.version.cmp(&other.version))
    }
}

/// Per-cluster routing state of the event-driven router. Estimated free
/// times are indexed three ways so every policy picks in O(log C):
///
/// * `idle_by_index` — idle clusters ordered by index (JSQ prefers the
///   lowest-index zero-backlog cluster);
/// * `idle_by_rank` — idle clusters ordered by `(planned rate, index)`
///   (PlanAware's best idle candidate is the fastest idle cluster);
/// * `free_heap` / `plan_heap` — busy clusters in version-stamped lazy
///   min-heaps keyed by estimated free time, respectively estimated
///   plan-finish time.
///
/// Decisions reproduce [`route_scan`]'s exactly (all clusters alive):
/// the final comparison re-evaluates the scan's float expressions on the
/// heap-selected candidates, and every tie breaks to the lowest index.
/// Pinned by the heap-vs-scan property test in `rust/tests/fleet_des.rs`.
struct RouterState {
    policy: RouterPolicy,
    plan_ok: bool,
    /// Guarded planned s/token per cluster ([`plan_rate`]).
    rates: Vec<f64>,
    est_free: Vec<f64>,
    /// Bumped on every estimate advance; stale heap entries are detected
    /// by version mismatch.
    version: Vec<u64>,
    busy: Vec<bool>,
    idle_by_index: BTreeSet<usize>,
    /// Idle clusters stored as plan *ranks* (position in `by_rank`).
    idle_by_rank: BTreeSet<usize>,
    /// Cluster index at each plan rank — ascending `(rate, index)`.
    by_rank: Vec<usize>,
    rank_of: Vec<usize>,
    free_heap: BinaryHeap<Reverse<HeapEntry>>,
    /// Plan-finish keys are computed for `plan_steps` decode steps and
    /// rebuilt (O(C log C)) whenever a request's step count differs, so
    /// mixed-length streams stay exact.
    plan_heap: BinaryHeap<Reverse<HeapEntry>>,
    plan_steps: usize,
}

impl RouterState {
    fn new(policy: RouterPolicy, clusters: &[FleetCluster]) -> RouterState {
        let n = clusters.len();
        assert!(n > 0, "routing needs at least one cluster");
        let rates: Vec<f64> = (0..n).map(|c| plan_rate(clusters, c)).collect();
        let mut by_rank: Vec<usize> = (0..n).collect();
        by_rank.sort_by(|&a, &b| rates[a].total_cmp(&rates[b]).then(a.cmp(&b)));
        let mut rank_of = vec![0usize; n];
        for (rank, &c) in by_rank.iter().enumerate() {
            rank_of[c] = rank;
        }
        RouterState {
            policy,
            plan_ok: plan_signal_ok(clusters),
            rates,
            est_free: vec![0.0; n],
            version: vec![0; n],
            busy: vec![false; n],
            idle_by_index: (0..n).collect(),
            idle_by_rank: (0..n).collect(),
            by_rank,
            rank_of,
            free_heap: BinaryHeap::new(),
            plan_heap: BinaryHeap::new(),
            plan_steps: usize::MAX,
        }
    }

    fn len(&self) -> usize {
        self.est_free.len()
    }

    fn uses_plan(&self) -> bool {
        self.policy == RouterPolicy::PlanAware && self.plan_ok
    }

    fn set_idle(&mut self, c: usize) {
        if self.busy[c] {
            self.busy[c] = false;
            self.idle_by_index.insert(c);
            self.idle_by_rank.insert(self.rank_of[c]);
        }
    }

    fn set_busy(&mut self, c: usize) {
        if self.busy[c] {
            return;
        }
        self.busy[c] = true;
        self.idle_by_index.remove(&c);
        self.idle_by_rank.remove(&self.rank_of[c]);
    }

    /// Retire clusters whose estimate expired by `now` into the idle
    /// sets. Amortized O(log C): each heap entry is popped once.
    /// Decision-time sweeping is authoritative — completion-feedback
    /// events only keep the idle sets warm, so same-timestamp event
    /// ordering can never change a routing decision.
    fn sweep(&mut self, now: f64) {
        while let Some(&Reverse(top)) = self.free_heap.peek() {
            if self.busy[top.idx] && self.version[top.idx] == top.version {
                if top.key > now {
                    break;
                }
                self.free_heap.pop();
                self.set_idle(top.idx);
            } else {
                self.free_heap.pop(); // stale entry, lazily discarded
            }
        }
    }

    /// Fresh minimum of `free_heap` — the busy cluster freeing earliest.
    fn busy_min_free(&mut self) -> Option<(f64, usize)> {
        while let Some(&Reverse(top)) = self.free_heap.peek() {
            if self.busy[top.idx] && self.version[top.idx] == top.version {
                return Some((top.key, top.idx));
            }
            self.free_heap.pop();
        }
        None
    }

    /// Fresh minimum of `plan_heap` — the busy cluster with the earliest
    /// plan-finish estimate for `plan_steps` decode steps.
    fn busy_min_plan(&mut self) -> Option<(f64, usize)> {
        while let Some(&Reverse(top)) = self.plan_heap.peek() {
            if self.busy[top.idx] && self.version[top.idx] == top.version {
                return Some((top.key, top.idx));
            }
            self.plan_heap.pop();
        }
        None
    }

    fn rebuild_plan_heap(&mut self, steps: usize) {
        self.plan_steps = steps;
        self.plan_heap.clear();
        for c in 0..self.len() {
            if self.busy[c] {
                self.plan_heap.push(Reverse(HeapEntry {
                    key: self.est_free[c] + steps as f64 * self.rates[c],
                    idx: c,
                    version: self.version[c],
                }));
            }
        }
    }

    /// JSQ backlog of cluster `c` at `now` — the scan's exact expression.
    fn backlog(&self, c: usize, now: f64) -> f64 {
        (self.est_free[c] - now).max(0.0)
    }

    /// One routing decision for request `r` at global index `k` —
    /// decision-identical to [`pick_cluster`] with every cluster alive.
    fn pick(&mut self, k: usize, r: &Request) -> usize {
        if self.policy == RouterPolicy::RoundRobin {
            return k % self.len();
        }
        self.sweep(r.arrival);
        if self.uses_plan() {
            if self.plan_steps != r.steps {
                self.rebuild_plan_heap(r.steps);
            }
            let s = r.steps as f64;
            let idle = self.idle_by_rank.iter().next().map(|&rank| {
                let c = self.by_rank[rank];
                // The scan's key verbatim: on an idle cluster est_free is
                // at most the arrival, so max() returns the arrival and
                // the fastest idle cluster minimizes the key.
                (self.est_free[c].max(r.arrival) + s * self.rates[c], c)
            });
            match (idle, self.busy_min_plan()) {
                (Some((ik, ic)), Some((bk, bc))) => {
                    // Busy keys were pushed as est_free + s·rate; busy
                    // implies est_free > arrival, so that is bitwise the
                    // scan's max(est_free, arrival) + s·rate. Ties break
                    // to the lowest index, like the scan's strict argmin.
                    if bk < ik || (bk == ik && bc < ic) {
                        bc
                    } else {
                        ic
                    }
                }
                (Some((_, ic)), None) => ic,
                (None, Some((_, bc))) => bc,
                (None, None) => unreachable!("every cluster is idle or busy"),
            }
        } else {
            // JSQ (and PlanAware under a degenerate signal): idle
            // clusters have exactly zero backlog and busy ones strictly
            // positive, so the lowest idle index wins whenever one
            // exists — precisely the scan's ties-go-low argmin.
            match self.idle_by_index.iter().next() {
                Some(&c) => c,
                None => self.busy_min_free().expect("all clusters busy").1,
            }
        }
    }

    /// Advance `c`'s estimate for `r` — the same recurrence the scan
    /// applies — and re-key the heaps. Returns the new estimated free
    /// time (where the completion-feedback event aims).
    fn commit(&mut self, c: usize, r: &Request) -> f64 {
        let end = self.est_free[c].max(r.arrival) + r.steps as f64 * self.rates[c];
        self.est_free[c] = end;
        self.version[c] += 1;
        self.set_busy(c);
        self.free_heap.push(Reverse(HeapEntry {
            key: end,
            idx: c,
            version: self.version[c],
        }));
        if self.uses_plan() && self.plan_steps != usize::MAX {
            self.plan_heap.push(Reverse(HeapEntry {
                key: end + self.plan_steps as f64 * self.rates[c],
                idx: c,
                version: self.version[c],
            }));
        }
        end
    }
}

/// World state of the event-driven router: the heap-indexed routing
/// state plus the per-cluster armed-feedback flags. Fully owned (no
/// borrows), so feedback closures satisfy the engine's `'static` event
/// bound while capturing only a cluster index and a version stamp.
struct RouteWorld {
    state: RouterState,
    /// Whether cluster `c` has a completion-feedback event armed. At
    /// most one per cluster is ever on the calendar (a live event
    /// re-aims itself on stale versions), so the calendar stays O(C)
    /// regardless of stream length.
    pending_free: Vec<bool>,
}

impl RouteWorld {
    fn new(policy: RouterPolicy, clusters: &[FleetCluster]) -> RouteWorld {
        RouteWorld {
            state: RouterState::new(policy, clusters),
            pending_free: vec![false; clusters.len()],
        }
    }
}

/// Arm a completion-feedback event for cluster `c` at its estimated free
/// time.
fn des_watch(eng: &mut DesEngine<RouteWorld>, w: &mut RouteWorld, c: usize, at: f64) {
    if w.pending_free[c] {
        return;
    }
    w.pending_free[c] = true;
    let v = w.state.version[c];
    eng.schedule_at(at.max(eng.now()), move |e, w| des_free(e, w, c, v));
}

/// Completion feedback: cluster `c`'s estimate expired. If the estimate
/// advanced since scheduling (version mismatch), re-aim at the current
/// estimate; otherwise retire the cluster to the idle sets. The
/// decision-time sweep in [`RouterState::pick`] stays authoritative
/// either way — feedback only keeps the idle sets warm between
/// arrivals, so event tie-ordering can never change a decision.
fn des_free(eng: &mut DesEngine<RouteWorld>, w: &mut RouteWorld, c: usize, v: u64) {
    w.pending_free[c] = false;
    if w.state.version[c] == v {
        w.state.set_idle(c);
    } else if w.state.busy[c] {
        let at = w.state.est_free[c];
        des_watch(eng, w, c, at);
    }
}

/// [`route`]'s engine: the routing pass as a discrete-event simulation
/// on [`crate::sim::Engine`]. The arrival cursor advances the calendar
/// (`run_until` fires every completion-feedback event due by the
/// arrival), each decision reads the heap-indexed [`RouterState`] in
/// O(log C), and each commit arms a feedback event that retires the
/// cluster's estimate mid-stream. Decisions are identical to
/// [`route_scan`] (pinned in `rust/tests/fleet_des.rs`).
fn route_des(
    policy: RouterPolicy,
    requests: &[Request],
    clusters: &[FleetCluster],
) -> Vec<Vec<u32>> {
    let n = clusters.len();
    assert!(n > 0, "routing needs at least one cluster");
    assert!(u32::try_from(requests.len()).is_ok(), "stream exceeds u32 indexing");
    let mut parts: Vec<Vec<u32>> = vec![Vec::new(); n];
    let mut eng: DesEngine<RouteWorld> = DesEngine::new();
    let mut w = RouteWorld::new(policy, clusters);
    for (k, r) in requests.iter().enumerate() {
        eng.run_until(&mut w, r.arrival);
        let pick = w.state.pick(k, r);
        let end = w.state.commit(pick, r);
        parts[pick].push(k as u32);
        des_watch(&mut eng, &mut w, pick, end);
        debug_assert!(eng.pending() <= n, "routing calendar must stay O(clusters)");
    }
    eng.run(&mut w);
    parts
}

/// Output of [`route_affinity`]: per-cluster index lists, the parallel
/// per-request reusable-prefix token counts, and the cell-level session
/// counters.
struct AffinityParts {
    parts: Vec<Vec<u32>>,
    /// `cached[c][i]` = reusable prefix tokens of request `parts[c][i]`.
    cached: Vec<Vec<u32>>,
    hits: u64,
    reuse_tokens: u64,
    spilled_sessions: u64,
}

/// Sticky-session routing on the DES router. The base `policy` proposes
/// a cluster; a request whose session is resident elsewhere sticks to
/// its resident cluster while the backlog penalty stays under the spill
/// threshold, reusing the prompt prefix still resident in that
/// cluster's [`KvPagePool`]. Returns the partition plus per-request
/// cached-prefix tokens and the session counters.
fn route_affinity(
    policy: RouterPolicy,
    requests: &[Request],
    clusters: &[FleetCluster],
    aff: &AffinitySpec,
) -> AffinityParts {
    let n = clusters.len();
    assert!(n > 0, "routing needs at least one cluster");
    assert!(u32::try_from(requests.len()).is_ok(), "stream exceeds u32 indexing");
    let page_spec = KvPageSpec::new(aff.page_tokens, aff.budget_tokens);
    // Session id → resident cluster. A session's pool context lives on
    // exactly the cluster this map names.
    let mut resident: HashMap<u64, usize> = HashMap::new();
    let mut pools: Vec<KvPagePool> = (0..n).map(|_| KvPagePool::new(page_spec)).collect();
    let mut out = AffinityParts {
        parts: vec![Vec::new(); n],
        cached: vec![Vec::new(); n],
        hits: 0,
        reuse_tokens: 0,
        spilled_sessions: 0,
    };
    let mut eng: DesEngine<RouteWorld> = DesEngine::new();
    let mut w = RouteWorld::new(policy, clusters);
    for (k, r) in requests.iter().enumerate() {
        eng.run_until(&mut w, r.arrival);
        let policy_pick = w.state.pick(k, r);
        let session = r.session_id;
        let (pick, cached) = match resident.get(&session).copied() {
            Some(c)
                if c == policy_pick
                    || w.state.backlog(c, r.arrival) - w.state.backlog(policy_pick, r.arrival)
                        <= aff.spill_threshold_s =>
            {
                // Sticky hit: reuse whatever prefix is still resident
                // (the budget may have spilled part of it since the last
                // visit). At least the final prompt position is always
                // recomputed, mirroring `applied_reuse` in the shard
                // simulator.
                let reuse = pools[c]
                    .resident_tokens(session)
                    .unwrap_or(0)
                    .min(PROMPT_TOKENS - 1);
                // Re-prefilling the non-resident suffix re-registers its
                // pages.
                pools[c].rewarm(session, PROMPT_TOKENS);
                (c, reuse as u32)
            }
            Some(c) => {
                // Backlog penalty above the threshold: the session
                // spills to the policy's pick and its context migrates
                // (old pages dropped — the new cluster prefills from
                // scratch).
                out.spilled_sessions += 1;
                pools[c].release(session);
                pools[policy_pick].register(session, PROMPT_TOKENS);
                resident.insert(session, policy_pick);
                (policy_pick, 0)
            }
            None => {
                pools[policy_pick].register(session, PROMPT_TOKENS);
                resident.insert(session, policy_pick);
                (policy_pick, 0)
            }
        };
        if cached > 0 {
            out.hits += 1;
            out.reuse_tokens += u64::from(cached);
        }
        let end = w.state.commit(pick, r);
        out.parts[pick].push(k as u32);
        out.cached[pick].push(cached);
        des_watch(&mut eng, &mut w, pick, end);
        debug_assert!(eng.pending() <= n, "routing calendar must stay O(clusters)");
    }
    eng.run(&mut w);
    out
}

// ---------------------------------------------------------------------
// Shard aggregation: O(1)-memory per-metric state.
// ---------------------------------------------------------------------

/// Streaming aggregate of one latency metric within one shard: running
/// sum (means), P² markers (shard-local quantiles) and a capped reservoir
/// (cell-level quantiles across shards).
struct MetricAgg {
    sum: f64,
    p50: P2Quantile,
    p95: P2Quantile,
    p99: P2Quantile,
    res: Reservoir,
}

impl MetricAgg {
    fn new(seed: u64) -> MetricAgg {
        MetricAgg {
            sum: 0.0,
            p50: P2Quantile::new(0.50),
            p95: P2Quantile::new(0.95),
            p99: P2Quantile::new(0.99),
            res: Reservoir::new(RESERVOIR_CAP, seed),
        }
    }

    fn push(&mut self, x: f64) {
        self.sum += x;
        self.p50.push(x);
        self.p95.push(x);
        self.p99.push(x);
        self.res.push(x);
    }

    fn freeze(self, n: usize) -> MetricShard {
        let v = |p: &P2Quantile| if n == 0 { 0.0 } else { p.value() };
        // The three P² estimators run independently, so their estimates
        // can invert by a hair on small heavy-tailed shards; clamp to the
        // monotone order the validator enforces (deterministic — same
        // clamp on the sequential and pooled paths).
        let p50 = v(&self.p50);
        let p95 = v(&self.p95).max(p50);
        let p99 = v(&self.p99).max(p95);
        MetricShard {
            sum: self.sum,
            p50,
            p95,
            p99,
            samples: self.res.into_samples(),
        }
    }
}

/// Frozen per-shard metric state (what a pool job sends back).
#[derive(Debug, Clone)]
pub struct MetricShard {
    /// Σ metric over the shard's requests (mean = sum / count).
    pub sum: f64,
    /// Shard-local P² quantile estimates (0.0 on empty shards).
    pub p50: f64,
    pub p95: f64,
    pub p99: f64,
    /// Reservoir sample retained for cell-level weighted percentiles.
    pub samples: Vec<f64>,
}

/// Per-request folding sink for one shard — the memory-flat consumer
/// behind `retain_step_times = false`.
struct ShardSink {
    n: usize,
    ttft: MetricAgg,
    tbt: MetricAgg,
    queueing: MetricAgg,
}

impl ShardSink {
    fn new(seed: u64) -> ShardSink {
        ShardSink {
            n: 0,
            ttft: MetricAgg::new(seed ^ 0x7f),
            tbt: MetricAgg::new(seed ^ 0xb3),
            queueing: MetricAgg::new(seed ^ 0xd5),
        }
    }
}

impl StreamSink for ShardSink {
    fn on_request(&mut self, m: &RequestMetrics) {
        self.n += 1;
        self.ttft.push(m.ttft);
        self.tbt.push(m.tbt);
        self.queueing.push(m.queueing_delay);
    }
}

/// Outcome of one cluster's stream within one (router, pattern) cell.
#[derive(Debug, Clone)]
pub struct ShardResult {
    pub label: String,
    pub count: usize,
    pub makespan: f64,
    pub decode_time: f64,
    pub ttft: MetricShard,
    pub tbt: MetricShard,
    pub queueing: MetricShard,
    /// Requests admitted with a nonzero cached prefix (0 unless the
    /// fleet ran with affinity routing) — counted *in the simulator* at
    /// admission, which the router's own tally must match.
    pub affinity_hits: u64,
    /// Prompt tokens skipped at prefill across those hits.
    pub reuse_tokens_saved: u64,
}

/// Cell-level latency summary: mean plus weighted-reservoir percentiles
/// across every shard of the cell.
#[derive(Debug, Clone, Copy)]
pub struct CellMetric {
    pub mean: f64,
    pub p50: f64,
    pub p95: f64,
    pub p99: f64,
}

/// Session-affinity counters of one cell. `Some` if and only if the
/// spec enabled affinity — absence keeps affinity-free artifacts
/// byte-identical `lime-fleet-v1`.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct CellAffinity {
    /// Requests that reused a nonzero cached prefix (Σ over shards —
    /// the validator pins the sum).
    pub hits: u64,
    /// Prompt tokens skipped at prefill (Σ over shards; at least one per
    /// hit).
    pub reuse_tokens_saved: u64,
    /// Sessions that abandoned their resident cluster for the policy's
    /// pick because the backlog penalty exceeded the spill threshold —
    /// a router-side count, cell-level only.
    pub spilled_sessions: u64,
}

/// One (router, pattern) cell of the fleet matrix.
#[derive(Debug, Clone)]
pub struct CellResult {
    pub router: RouterPolicy,
    pub pattern: Pattern,
    pub count: usize,
    pub makespan: f64,
    pub ttft: CellMetric,
    pub tbt: CellMetric,
    pub queueing: CellMetric,
    pub shards: Vec<ShardResult>,
    /// Requests drained off churned-down clusters and re-routed —
    /// `Some` only when the fleet ran with a non-empty churn channel, so
    /// churn-free artifacts stay byte-identical to `lime-fleet-v1` before
    /// the churn axis existed.
    pub rerouted: Option<u64>,
    /// Session-affinity counters; `Some` iff the spec enabled affinity.
    pub affinity: Option<CellAffinity>,
}

/// Merge shard metrics into a cell metric: exact mean from the running
/// sums, percentiles from the reservoir union with each sample weighted
/// by `shard_count / retained` so a big shard's tail is not diluted by a
/// small shard's equal-size reservoir.
fn cell_metric(shards: &[&MetricShard], counts: &[usize], total: usize) -> CellMetric {
    let mean = if total == 0 {
        0.0
    } else {
        shards.iter().map(|m| m.sum).sum::<f64>() / total as f64
    };
    let mut weighted: Vec<(f64, f64)> = Vec::new();
    for (m, &n) in shards.iter().zip(counts) {
        if n == 0 || m.samples.is_empty() {
            continue;
        }
        let w = n as f64 / m.samples.len() as f64;
        weighted.extend(m.samples.iter().map(|&s| (s, w)));
    }
    if weighted.is_empty() {
        return CellMetric { mean, p50: 0.0, p95: 0.0, p99: 0.0 };
    }
    CellMetric {
        mean,
        p50: weighted_percentile(&mut weighted, 50.0),
        p95: weighted_percentile(&mut weighted, 95.0),
        p99: weighted_percentile(&mut weighted, 99.0),
    }
}

// ---------------------------------------------------------------------
// The runner.
// ---------------------------------------------------------------------

/// One pool job: one cluster's routed slice of one (router, pattern)
/// cell. Jobs are fully self-contained — they reference the shared
/// per-pattern stream plus their own routed index list, and materialize
/// the sub-stream only while running (peak clones bounded by the worker
/// count, not the cell count) — so the pool can execute them in any
/// order on any worker without affecting a single output bit.
struct ShardJob<'a> {
    fc: &'a FleetCluster,
    pattern: Pattern,
    stream: &'a [Request],
    indices: Vec<u32>,
    /// Reusable-prefix tokens per routed request, parallel to `indices`
    /// — empty unless the fleet ran with affinity routing.
    cached: Vec<u32>,
    exec_seed: u64,
    res_seed: u64,
}

fn run_shard(job: &ShardJob) -> ShardResult {
    let requests: Vec<Request> = job
        .indices
        .iter()
        .enumerate()
        .map(|(i, &idx)| {
            let mut r = job.stream[idx as usize].clone();
            if let Some(&c) = job.cached.get(i) {
                r.cached_prefix = c;
            }
            r
        })
        .collect();
    let bw = BandwidthTrace::fixed_mbps(job.fc.bw_mbps);
    let opts = ExecOptions {
        trace_mode: TraceMode::Off,
        prompt_tokens: PROMPT_TOKENS,
        seed: job.exec_seed,
        ..ExecOptions::default()
    };
    let mut sink = ShardSink::new(job.res_seed);
    let stats = simulate_stream_sink(
        InterleavedPolicy::new(&job.fc.alloc, &job.fc.cluster, &opts),
        &job.fc.cluster,
        &bw,
        job.pattern.micro_batches(&job.fc.cluster),
        &CommonOptions::from(&opts),
        &Script::none(),
        &requests,
        &mut sink,
        false,
    );
    let n = sink.n;
    ShardResult {
        label: job.fc.label.clone(),
        count: n,
        makespan: stats.makespan,
        decode_time: stats.decode_time,
        ttft: sink.ttft.freeze(n),
        tbt: sink.tbt.freeze(n),
        queueing: sink.queueing.freeze(n),
        affinity_hits: stats.affinity_hits,
        reuse_tokens_saved: stats.reuse_tokens_saved,
    }
}

/// Run the fleet matrix on the process-wide work-stealing pool.
pub fn run_fleet(spec: &FleetSpec) -> Vec<CellResult> {
    run_fleet_on(spec, Some(crate::util::pool::global()))
}

/// The exact sequential reference ([`run_fleet`] is pinned byte-identical
/// to it on the serialized artifact).
pub fn run_fleet_sequential(spec: &FleetSpec) -> Vec<CellResult> {
    run_fleet_on(spec, None)
}

/// [`run_fleet`] on an explicit pool (`None` = in-place sequential).
/// Cells come back router-major ordered: `(router[0], pattern[0]),
/// (router[0], pattern[1]), …` — the artifact's `cells` order.
pub fn run_fleet_on(spec: &FleetSpec, pool: Option<&Pool>) -> Vec<CellResult> {
    assert!(!spec.clusters.is_empty(), "fleet needs at least one cluster");
    assert!(!spec.routers.is_empty() && !spec.patterns.is_empty());
    assert!(
        spec.affinity.is_none() || spec.churn.churn.is_empty(),
        "affinity routing does not compose with the fleet churn channel yet"
    );
    let nc = spec.clusters.len();

    // One request stream per pattern, shared by every router so policies
    // are compared on identical arrivals. Prompts are empty (prefill is
    // charged from `PROMPT_TOKENS`), keeping 10^6-request streams flat.
    // Affinity specs overlay Zipf session ids from a salted side stream —
    // the base arrival/step fields stay bit-identical to the v1 stream.
    let mut streams: Vec<Vec<Request>> = spec
        .patterns
        .iter()
        .enumerate()
        .map(|(pi, &p)| {
            stream_requests(p, spec.seed.wrapping_add(pi as u64), spec.count, spec.lambda, 0, spec.steps)
        })
        .collect();
    if let Some(aff) = &spec.affinity {
        for (pi, s) in streams.iter_mut().enumerate() {
            assign_sessions(s, spec.seed.wrapping_add(pi as u64), aff.sessions, aff.zipf_s);
        }
    }
    let streams = streams;

    // Phase 1 — event-driven routing on the DES engine, O(count · log C)
    // per cell. The churn-aware router runs only when the spec's churn
    // channel is non-empty; otherwise this is exactly the pre-churn path.
    let mut jobs: Vec<ShardJob> = Vec::with_capacity(spec.routers.len() * spec.patterns.len() * nc);
    let mut cell_rerouted: Vec<Option<u64>> =
        Vec::with_capacity(spec.routers.len() * spec.patterns.len());
    let mut cell_affinity: Vec<Option<CellAffinity>> =
        Vec::with_capacity(spec.routers.len() * spec.patterns.len());
    for (ri, &router) in spec.routers.iter().enumerate() {
        for (pi, &pattern) in spec.patterns.iter().enumerate() {
            let (parts, cached, rerouted, affinity) = if let Some(aff) = &spec.affinity {
                let routed = route_affinity(router, &streams[pi], &spec.clusters, aff);
                let counters = CellAffinity {
                    hits: routed.hits,
                    reuse_tokens_saved: routed.reuse_tokens,
                    spilled_sessions: routed.spilled_sessions,
                };
                (routed.parts, Some(routed.cached), None, Some(counters))
            } else if spec.churn.churn.is_empty() {
                (route(router, &streams[pi], &spec.clusters), None, None, None)
            } else {
                let (p, n) = route_churn(router, &streams[pi], &spec.clusters, &spec.churn.churn);
                (p, None, Some(n), None)
            };
            cell_rerouted.push(rerouted);
            cell_affinity.push(affinity);
            let mut cached = cached;
            for (ci, indices) in parts.into_iter().enumerate() {
                let idx = ((ri * 97 + pi) * 97 + ci) as u64 + 1;
                jobs.push(ShardJob {
                    fc: &spec.clusters[ci],
                    pattern,
                    stream: &streams[pi],
                    indices,
                    cached: cached
                        .as_mut()
                        .map(|c| std::mem::take(&mut c[ci]))
                        .unwrap_or_default(),
                    exec_seed: spec.seed,
                    res_seed: spec.seed ^ idx.wrapping_mul(0x9E37_79B9_7F4A_7C15),
                });
            }
        }
    }

    // Phase 2 — one cluster per job on the pool, merged by index.
    let shards: Vec<ShardResult> = match pool {
        Some(p) => p.map_indexed(&jobs, run_shard),
        None => jobs.iter().map(run_shard).collect(),
    };

    shards
        .chunks(nc)
        .enumerate()
        .map(|(cell_i, chunk)| {
            let ri = cell_i / spec.patterns.len();
            let pi = cell_i % spec.patterns.len();
            let counts: Vec<usize> = chunk.iter().map(|s| s.count).collect();
            let total: usize = counts.iter().sum();
            debug_assert_eq!(total, spec.count, "routing must partition the stream");
            let pick = |f: fn(&ShardResult) -> &MetricShard| {
                let refs: Vec<&MetricShard> = chunk.iter().map(f).collect();
                cell_metric(&refs, &counts, total)
            };
            CellResult {
                router: spec.routers[ri],
                pattern: spec.patterns[pi],
                count: total,
                makespan: chunk.iter().fold(0.0f64, |m, s| m.max(s.makespan)),
                ttft: pick(|s| &s.ttft),
                tbt: pick(|s| &s.tbt),
                queueing: pick(|s| &s.queueing),
                shards: chunk.to_vec(),
                rerouted: cell_rerouted[cell_i],
                affinity: cell_affinity[cell_i].map(|router_side| {
                    // The cell's hit/reuse counters come from the shard
                    // simulators (what was actually admitted); the
                    // router's own tally must agree because the router
                    // caps reuse at PROMPT_TOKENS − 1, below the shard
                    // charge base.
                    let sim_side = CellAffinity {
                        hits: chunk.iter().map(|s| s.affinity_hits).sum(),
                        reuse_tokens_saved: chunk.iter().map(|s| s.reuse_tokens_saved).sum(),
                        spilled_sessions: router_side.spilled_sessions,
                    };
                    debug_assert_eq!(sim_side, router_side, "router and simulator reuse tallies must agree");
                    sim_side
                }),
            }
        })
        .collect()
}

// ---------------------------------------------------------------------
// Artifact: schema lime-fleet-v1 / lime-fleet-v2.
// ---------------------------------------------------------------------

/// Schema tag this spec serializes under. `lime-fleet-v2` is a strict
/// superset of v1 (an `affinity` header plus per-cell/per-shard reuse
/// counters) emitted if and only if the spec enables affinity — the
/// singleton-downgrade rule [`validate_fleet`] enforces from the other
/// side.
pub fn schema_tag(spec: &FleetSpec) -> &'static str {
    if spec.affinity.is_some() {
        "lime-fleet-v2"
    } else {
        "lime-fleet-v1"
    }
}

fn metric_json(m: &CellMetric) -> Json {
    obj(&[
        ("mean", m.mean.into()),
        ("p50", m.p50.into()),
        ("p95", m.p95.into()),
        ("p99", m.p99.into()),
    ])
}

fn shard_json(s: &ShardResult, affinity: bool) -> Json {
    let stat = |m: &MetricShard| {
        let mean = if s.count == 0 { 0.0 } else { m.sum / s.count as f64 };
        obj(&[
            ("mean", mean.into()),
            ("p50", m.p50.into()),
            ("p95", m.p95.into()),
            ("p99", m.p99.into()),
        ])
    };
    // Keys ascending; the two counter keys appear only on v2 artifacts.
    let mut fields: Vec<(&str, Json)> = Vec::with_capacity(9);
    if affinity {
        fields.push(("affinity_hits", s.affinity_hits.into()));
    }
    fields.push(("count", s.count.into()));
    fields.push(("decode_s", s.decode_time.into()));
    fields.push(("label", s.label.as_str().into()));
    fields.push(("makespan_s", s.makespan.into()));
    fields.push(("queueing_delay_s", stat(&s.queueing)));
    if affinity {
        fields.push(("reuse_tokens_saved", s.reuse_tokens_saved.into()));
    }
    fields.push(("tbt_s", stat(&s.tbt)));
    fields.push(("ttft_s", stat(&s.ttft)));
    obj(&fields)
}

fn cell_json(c: &CellResult) -> Json {
    // Keys ascending; "rerouted" slots between "queueing_delay_s" and
    // "router" and appears only on churn runs; the three affinity
    // counters appear only on v2 runs.
    let mut fields: Vec<(&str, Json)> = Vec::with_capacity(12);
    if let Some(a) = &c.affinity {
        fields.push(("affinity_hits", a.hits.into()));
    }
    fields.push(("count", c.count.into()));
    fields.push(("makespan_s", c.makespan.into()));
    fields.push(("pattern", pattern_key(c.pattern).into()));
    fields.push((
        "per_cluster",
        Json::Arr(
            c.shards
                .iter()
                .map(|s| shard_json(s, c.affinity.is_some()))
                .collect(),
        ),
    ));
    fields.push(("queueing_delay_s", metric_json(&c.queueing)));
    if let Some(n) = c.rerouted {
        fields.push(("rerouted", n.into()));
    }
    if let Some(a) = &c.affinity {
        fields.push(("reuse_tokens_saved", a.reuse_tokens_saved.into()));
    }
    fields.push(("router", c.router.key().into()));
    if let Some(a) = &c.affinity {
        fields.push(("spilled_sessions", a.spilled_sessions.into()));
    }
    fields.push(("tbt_s", metric_json(&c.tbt)));
    fields.push(("ttft_s", metric_json(&c.ttft)));
    obj(&fields)
}

/// Stream the `lime-fleet-v1`/`lime-fleet-v2` artifact to `out` cell by
/// cell — the whole tree is never materialized (bytes are pinned
/// identical to `Json::Display`). Returns the sink.
pub fn write_fleet<W: std::io::Write>(
    spec: &FleetSpec,
    cells: &[CellResult],
    out: W,
) -> std::io::Result<W> {
    let mut w = StreamWriter::new(out);
    w.begin_obj()?;
    // "affinity" < "cells": the v2 header leads, and is absent entirely
    // on affinity-free runs (byte-identity with v1 artifacts).
    if let Some(aff) = &spec.affinity {
        w.key("affinity")?;
        w.value(&obj(&[
            ("budget_tokens", aff.budget_tokens.into()),
            ("page_tokens", aff.page_tokens.into()),
            ("sessions", aff.sessions.into()),
            ("spill_threshold_s", aff.spill_threshold_s.into()),
            ("zipf_s", aff.zipf_s.into()),
        ]))?;
    }
    w.key("cells")?;
    w.begin_arr()?;
    for c in cells {
        w.value(&cell_json(c))?;
    }
    w.end()?;
    // "cells" < "churn" < "clusters": the optional header keeps keys
    // ascending, and is absent entirely on churn-free runs (byte-identity
    // with pre-churn artifacts).
    if !spec.churn.churn.is_empty() {
        w.key("churn")?;
        w.begin_arr()?;
        for ev in &spec.churn.churn {
            w.value(&obj(&[
                ("at_arrival", ev.at_step.into()),
                ("cluster", ev.device.into()),
                ("kind", ev.kind.name().into()),
            ]))?;
        }
        w.end()?;
    }
    w.key("clusters")?;
    w.begin_arr()?;
    for fc in &spec.clusters {
        w.value(&obj(&[
            ("bw_mbps", fc.bw_mbps.into()),
            ("devices", fc.cluster.len().into()),
            ("label", fc.label.as_str().into()),
            ("planned_ms_per_token", (fc.planned_s_per_token * 1e3).into()),
        ]))?;
    }
    w.end()?;
    w.key("count")?;
    w.value(&spec.count.into())?;
    w.key("lambda")?;
    w.value(&spec.lambda.into())?;
    w.key("model")?;
    w.value(&spec.model().into())?;
    w.key("name")?;
    w.value(&spec.name.as_str().into())?;
    w.key("patterns")?;
    w.value(&Json::Arr(
        spec.patterns.iter().map(|&p| pattern_key(p).into()).collect(),
    ))?;
    w.key("routers")?;
    w.value(&Json::Arr(
        spec.routers.iter().map(|r| r.key().into()).collect(),
    ))?;
    w.key("schema")?;
    w.value(&schema_tag(spec).into())?;
    w.key("seed")?;
    w.value(&spec.seed.into())?;
    w.key("steps")?;
    w.value(&spec.steps.into())?;
    w.end()?;
    w.finish()
}

/// [`write_fleet`] into a byte buffer — what the determinism tests diff.
pub fn fleet_artifact_bytes(spec: &FleetSpec, cells: &[CellResult]) -> Vec<u8> {
    write_fleet(spec, cells, Vec::new()).expect("writing to a Vec cannot fail")
}

/// Summary returned by a successful [`validate_fleet`] pass.
#[derive(Debug, Clone, PartialEq)]
pub struct FleetSummary {
    pub name: String,
    pub model: String,
    pub schema: String,
    pub clusters: usize,
    pub cells: usize,
    /// Requests per cell.
    pub requests: usize,
}

fn field<'a>(json: &'a Json, key: &str, what: &str) -> Result<&'a Json, String> {
    json.get(key).ok_or_else(|| format!("{what} missing '{key}'"))
}

fn finite_ge0(json: &Json, key: &str, what: &str) -> Result<f64, String> {
    let v = field(json, key, what)?
        .as_f64()
        .ok_or_else(|| format!("{what}.{key} must be a number"))?;
    if !v.is_finite() || v < 0.0 {
        return Err(format!("{what}.{key} must be finite and >= 0, got {v}"));
    }
    Ok(v)
}

/// Validate latency-summary shape: mean/p50/p95/p99, finite, non-negative
/// and monotone in p when the cell is populated.
fn check_stat(json: &Json, key: &str, what: &str, populated: bool) -> Result<(), String> {
    let stat = field(json, key, what)?;
    let here = format!("{what}.{key}");
    let mean = finite_ge0(stat, "mean", &here)?;
    let p50 = finite_ge0(stat, "p50", &here)?;
    let p95 = finite_ge0(stat, "p95", &here)?;
    let p99 = finite_ge0(stat, "p99", &here)?;
    if populated && !(p50 <= p95 && p95 <= p99) {
        return Err(format!(
            "{here}: percentiles must be monotone, got p50={p50} p95={p95} p99={p99}"
        ));
    }
    if !populated && (mean != 0.0 || p99 != 0.0) {
        return Err(format!("{here}: empty shard must report zero stats"));
    }
    Ok(())
}

/// Validate one artifact strictly against the `lime-fleet-v1` /
/// `lime-fleet-v2` schemas — the machine check behind `lime sweep-check`
/// for `FLEET_*.json` files and the CI artifact gate. v2 must carry the
/// `affinity` header and its counters everywhere; v1 must carry none of
/// them (the singleton-downgrade rule, enforced in both directions).
pub fn validate_fleet(json: &Json) -> Result<FleetSummary, String> {
    let schema = match json.get("schema").and_then(Json::as_str) {
        Some(s @ ("lime-fleet-v1" | "lime-fleet-v2")) => s.to_string(),
        other => {
            return Err(format!(
                "expected schema lime-fleet-v1 or lime-fleet-v2, got {other:?}"
            ))
        }
    };
    let v2 = schema == "lime-fleet-v2";
    let name = field(json, "name", "artifact")?
        .as_str()
        .ok_or("'name' must be a string")?
        .to_string();
    let model = field(json, "model", "artifact")?
        .as_str()
        .ok_or("'model' must be a string")?
        .to_string();
    if name.is_empty() || model.is_empty() {
        return Err("'name' and 'model' must be non-empty".into());
    }
    let count = field(json, "count", "artifact")?
        .as_usize()
        .filter(|&c| c > 0)
        .ok_or("'count' must be a positive integer")?;
    let steps = field(json, "steps", "artifact")?
        .as_usize()
        .filter(|&s| s > 0)
        .ok_or("'steps' must be a positive integer")?;
    let _ = steps;
    let lambda = field(json, "lambda", "artifact")?
        .as_f64()
        .ok_or("'lambda' must be a number")?;
    if !lambda.is_finite() || lambda <= 0.0 {
        return Err(format!("'lambda' must be finite and positive, got {lambda}"));
    }
    field(json, "seed", "artifact")?
        .as_u64()
        .ok_or("'seed' must be a non-negative integer")?;

    // Header: clusters.
    let clusters = field(json, "clusters", "artifact")?
        .as_arr()
        .ok_or("'clusters' must be an array")?;
    if clusters.is_empty() {
        return Err("'clusters' must be non-empty".into());
    }
    let mut labels: Vec<&str> = Vec::with_capacity(clusters.len());
    for (i, c) in clusters.iter().enumerate() {
        let what = format!("clusters[{i}]");
        let label = field(c, "label", &what)?
            .as_str()
            .ok_or_else(|| format!("{what}.label must be a string"))?;
        if label.is_empty() || labels.contains(&label) {
            return Err(format!("{what}.label must be non-empty and unique"));
        }
        labels.push(label);
        let bw = finite_ge0(c, "bw_mbps", &what)?;
        let ms = finite_ge0(c, "planned_ms_per_token", &what)?;
        if bw == 0.0 || ms == 0.0 {
            return Err(format!("{what}: bw_mbps and planned_ms_per_token must be positive"));
        }
        field(c, "devices", &what)?
            .as_usize()
            .filter(|&d| d > 0)
            .ok_or_else(|| format!("{what}.devices must be a positive integer"))?;
    }

    // Header: routers / patterns.
    let keyset = |key: &str, allowed: &[&str]| -> Result<Vec<String>, String> {
        let arr = field(json, key, "artifact")?
            .as_arr()
            .ok_or_else(|| format!("'{key}' must be an array"))?;
        if arr.is_empty() {
            return Err(format!("'{key}' must be non-empty"));
        }
        let mut out: Vec<String> = Vec::with_capacity(arr.len());
        for v in arr {
            let s = v
                .as_str()
                .ok_or_else(|| format!("'{key}' entries must be strings"))?;
            if !allowed.contains(&s) {
                return Err(format!("'{key}' entry {s:?} not in {allowed:?}"));
            }
            if out.iter().any(|o| o == s) {
                return Err(format!("'{key}' entries must be unique, {s:?} repeats"));
            }
            out.push(s.to_string());
        }
        Ok(out)
    };
    let routers = keyset("routers", &["rr", "jsq", "plan"])?;
    let patterns = keyset("patterns", &["sporadic", "bursty"])?;

    // Header: affinity — present iff the schema says v2 (the
    // singleton-downgrade rule: an affinity-free run must serialize as
    // plain lime-fleet-v1).
    let has_affinity = match json.get("affinity") {
        None => {
            if v2 {
                return Err(
                    "lime-fleet-v2 requires an 'affinity' header (affinity-free runs must \
                     downgrade to lime-fleet-v1)"
                        .into(),
                );
            }
            false
        }
        Some(a) => {
            if !v2 {
                return Err("an 'affinity' header requires schema lime-fleet-v2".into());
            }
            let what = "affinity";
            field(a, "sessions", what)?
                .as_u64()
                .filter(|&s| s >= 1)
                .ok_or("affinity.sessions must be a positive integer")?;
            let z = field(a, "zipf_s", what)?
                .as_f64()
                .ok_or("affinity.zipf_s must be a number")?;
            if !z.is_finite() || z <= 0.0 {
                return Err(format!("affinity.zipf_s must be finite and positive, got {z}"));
            }
            finite_ge0(a, "spill_threshold_s", what)?;
            let pt = field(a, "page_tokens", what)?
                .as_usize()
                .filter(|&p| p >= 1)
                .ok_or("affinity.page_tokens must be a positive integer")?;
            let bt = field(a, "budget_tokens", what)?
                .as_usize()
                .ok_or("affinity.budget_tokens must be an integer")?;
            if bt < pt {
                return Err(format!(
                    "affinity.budget_tokens {bt} must hold at least one page of {pt} tokens"
                ));
            }
            true
        }
    };

    // Header: optional churn channel (absent on churn-free artifacts — its
    // absence is part of the byte-identity contract with older runs).
    let has_churn = match json.get("churn") {
        None => false,
        Some(ch) => {
            let arr = ch.as_arr().ok_or("'churn' must be an array")?;
            if arr.is_empty() {
                return Err("'churn' must be absent rather than empty".into());
            }
            for (i, ev) in arr.iter().enumerate() {
                let what = format!("churn[{i}]");
                field(ev, "at_arrival", &what)?
                    .as_u64()
                    .ok_or_else(|| format!("{what}.at_arrival must be a non-negative integer"))?;
                let c = field(ev, "cluster", &what)?
                    .as_usize()
                    .ok_or_else(|| format!("{what}.cluster must be an integer"))?;
                if c >= clusters.len() {
                    return Err(format!(
                        "{what}.cluster {c} out of range for {} clusters",
                        clusters.len()
                    ));
                }
                match field(ev, "kind", &what)?.as_str() {
                    Some("down") | Some("up") => {}
                    other => {
                        return Err(format!("{what}.kind must be \"down\" or \"up\", got {other:?}"))
                    }
                }
            }
            true
        }
    };
    if has_affinity && has_churn {
        return Err("'affinity' and 'churn' headers cannot coexist (the runner rejects the combination)".into());
    }

    // Cells: exactly the router × pattern cross, each cell a partition of
    // the stream across the header's clusters.
    let cells = field(json, "cells", "artifact")?
        .as_arr()
        .ok_or("'cells' must be an array")?;
    if cells.len() != routers.len() * patterns.len() {
        return Err(format!(
            "expected {} cells from the router x pattern cross, found {}",
            routers.len() * patterns.len(),
            cells.len()
        ));
    }
    let mut seen: Vec<(String, String)> = Vec::with_capacity(cells.len());
    for (i, cell) in cells.iter().enumerate() {
        let what = format!("cells[{i}]");
        let router = field(cell, "router", &what)?
            .as_str()
            .ok_or_else(|| format!("{what}.router must be a string"))?;
        let pattern = field(cell, "pattern", &what)?
            .as_str()
            .ok_or_else(|| format!("{what}.pattern must be a string"))?;
        if !routers.iter().any(|r| r == router) {
            return Err(format!("{what}.router {router:?} not in header 'routers'"));
        }
        if !patterns.iter().any(|p| p == pattern) {
            return Err(format!("{what}.pattern {pattern:?} not in header 'patterns'"));
        }
        let combo = (router.to_string(), pattern.to_string());
        if seen.contains(&combo) {
            return Err(format!("duplicate cell for router={router} pattern={pattern}"));
        }
        seen.push(combo);
        let cell_count = field(cell, "count", &what)?
            .as_usize()
            .ok_or_else(|| format!("{what}.count must be an integer"))?;
        if cell_count != count {
            return Err(format!(
                "{what}.count {cell_count} != artifact count {count} (routing must not drop requests)"
            ));
        }
        let cell_makespan = finite_ge0(cell, "makespan_s", &what)?;
        if has_churn {
            field(cell, "rerouted", &what)?
                .as_u64()
                .ok_or_else(|| format!("{what}.rerouted must be a non-negative integer"))?;
        } else if cell.get("rerouted").is_some() {
            return Err(format!("{what}.rerouted requires a 'churn' header"));
        }
        let cell_counters = if has_affinity {
            let hits = field(cell, "affinity_hits", &what)?
                .as_u64()
                .ok_or_else(|| format!("{what}.affinity_hits must be a non-negative integer"))?;
            if hits > cell_count as u64 {
                return Err(format!(
                    "{what}.affinity_hits {hits} exceeds the cell's {cell_count} requests"
                ));
            }
            let reuse = field(cell, "reuse_tokens_saved", &what)?
                .as_u64()
                .ok_or_else(|| format!("{what}.reuse_tokens_saved must be a non-negative integer"))?;
            if reuse < hits {
                return Err(format!(
                    "{what}: reuse_tokens_saved {reuse} < affinity_hits {hits} (every hit reuses at least one token)"
                ));
            }
            field(cell, "spilled_sessions", &what)?
                .as_u64()
                .ok_or_else(|| format!("{what}.spilled_sessions must be a non-negative integer"))?;
            Some((hits, reuse))
        } else {
            for key in ["affinity_hits", "reuse_tokens_saved", "spilled_sessions"] {
                if cell.get(key).is_some() {
                    return Err(format!("{what}.{key} requires an 'affinity' header"));
                }
            }
            None
        };
        check_stat(cell, "queueing_delay_s", &what, cell_count > 0)?;
        check_stat(cell, "tbt_s", &what, cell_count > 0)?;
        check_stat(cell, "ttft_s", &what, cell_count > 0)?;

        let per = field(cell, "per_cluster", &what)?
            .as_arr()
            .ok_or_else(|| format!("{what}.per_cluster must be an array"))?;
        if per.len() != clusters.len() {
            return Err(format!(
                "{what}.per_cluster must have one entry per header cluster ({} != {})",
                per.len(),
                clusters.len()
            ));
        }
        let mut sum = 0usize;
        let mut max_shard_makespan = 0.0f64;
        let mut shard_hits = 0u64;
        let mut shard_reuse = 0u64;
        for (j, shard) in per.iter().enumerate() {
            let swhat = format!("{what}.per_cluster[{j}]");
            let label = field(shard, "label", &swhat)?
                .as_str()
                .ok_or_else(|| format!("{swhat}.label must be a string"))?;
            if label != labels[j] {
                return Err(format!(
                    "{swhat}.label {label:?} must match header clusters[{j}] ({:?})",
                    labels[j]
                ));
            }
            let n = field(shard, "count", &swhat)?
                .as_usize()
                .ok_or_else(|| format!("{swhat}.count must be an integer"))?;
            sum += n;
            let mk = finite_ge0(shard, "makespan_s", &swhat)?;
            max_shard_makespan = max_shard_makespan.max(mk);
            finite_ge0(shard, "decode_s", &swhat)?;
            if has_affinity {
                let h = field(shard, "affinity_hits", &swhat)?
                    .as_u64()
                    .ok_or_else(|| format!("{swhat}.affinity_hits must be a non-negative integer"))?;
                if h > n as u64 {
                    return Err(format!(
                        "{swhat}.affinity_hits {h} exceeds the shard's {n} requests"
                    ));
                }
                let rt = field(shard, "reuse_tokens_saved", &swhat)?
                    .as_u64()
                    .ok_or_else(|| {
                        format!("{swhat}.reuse_tokens_saved must be a non-negative integer")
                    })?;
                if rt < h {
                    return Err(format!(
                        "{swhat}: reuse_tokens_saved {rt} < affinity_hits {h}"
                    ));
                }
                shard_hits += h;
                shard_reuse += rt;
            } else {
                for key in ["affinity_hits", "reuse_tokens_saved"] {
                    if shard.get(key).is_some() {
                        return Err(format!("{swhat}.{key} requires an 'affinity' header"));
                    }
                }
            }
            check_stat(shard, "queueing_delay_s", &swhat, n > 0)?;
            check_stat(shard, "tbt_s", &swhat, n > 0)?;
            check_stat(shard, "ttft_s", &swhat, n > 0)?;
        }
        if sum != cell_count {
            return Err(format!(
                "{what}: per-cluster counts sum to {sum}, cell count is {cell_count}"
            ));
        }
        if let Some((hits, reuse)) = cell_counters {
            if shard_hits != hits || shard_reuse != reuse {
                return Err(format!(
                    "{what}: cell counters (hits {hits}, reuse {reuse}) must equal the \
                     per-cluster sums (hits {shard_hits}, reuse {shard_reuse})"
                ));
            }
        }
        if cell_makespan != max_shard_makespan {
            return Err(format!(
                "{what}.makespan_s {cell_makespan} != max per-cluster makespan {max_shard_makespan}"
            ));
        }
    }
    Ok(FleetSummary {
        name,
        model,
        schema,
        clusters: clusters.len(),
        cells: cells.len(),
        requests: count,
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    /// A cheap two-cluster fleet over TinyLM — E3 split into its Orin pair
    /// and its mixed pair.
    fn tiny_fleet(count: usize) -> FleetSpec {
        let spec = ModelSpec::tiny_lm();
        let e3 = Cluster::env_e3();
        let clusters = vec![
            FleetCluster::new("a-orin2", &e3, &[0, 1], &spec, 100.0).unwrap(),
            FleetCluster::new("b-mixed2", &e3, &[2, 3], &spec, 200.0).unwrap(),
        ];
        FleetSpec {
            name: "tiny-fleet".to_string(),
            clusters,
            routers: RouterPolicy::all().to_vec(),
            patterns: vec![Pattern::Sporadic, Pattern::Bursty],
            count,
            lambda: 2.0,
            steps: 3,
            seed: 7,
            churn: Script::none(),
            affinity: None,
        }
    }

    /// [`tiny_fleet`] with a small hot session population and a generous
    /// spill threshold — every repeat visit should stick and hit.
    fn tiny_affinity_fleet(count: usize) -> FleetSpec {
        let mut spec = tiny_fleet(count);
        spec.name = "tiny-fleet-affinity".to_string();
        spec.affinity = Some(AffinitySpec {
            sessions: 8,
            zipf_s: 1.2,
            spill_threshold_s: 5.0,
            page_tokens: 16,
            budget_tokens: 16 * PROMPT_TOKENS,
        });
        spec
    }

    #[test]
    fn routing_partitions_every_request_exactly_once() {
        let spec = tiny_fleet(50);
        let reqs = stream_requests(Pattern::Sporadic, 11, 50, 2.0, 0, 3);
        for router in RouterPolicy::all() {
            let parts = route(router, &reqs, &spec.clusters);
            assert_eq!(parts.len(), 2);
            let total: usize = parts.iter().map(Vec::len).sum();
            assert_eq!(total, reqs.len(), "{router:?} dropped or duplicated");
            let mut idxs: Vec<u32> = parts.iter().flatten().copied().collect();
            idxs.sort_unstable();
            let want: Vec<u32> = (0..reqs.len() as u32).collect();
            assert_eq!(idxs, want);
            for p in &parts {
                assert!(
                    p.windows(2).all(|w| w[0] < w[1]),
                    "{router:?} must preserve arrival order (ascending indices)"
                );
            }
        }
    }

    #[test]
    fn round_robin_cycles_by_global_index() {
        let spec = tiny_fleet(8);
        let reqs = stream_requests(Pattern::Bursty, 5, 8, 1.0, 0, 2);
        let parts = route(RouterPolicy::RoundRobin, &reqs, &spec.clusters);
        assert_eq!(parts[0].len(), 4);
        assert_eq!(parts[1].len(), 4);
        // Even global indices to cluster 0, odd to cluster 1.
        for k in 0..reqs.len() {
            assert!(parts[k % 2].contains(&(k as u32)));
        }
    }

    #[test]
    fn plan_aware_prefers_the_faster_cluster_jsq_ties_low() {
        let mut spec = tiny_fleet(1);
        // Make cluster 1 decisively faster on paper.
        spec.clusters[0].planned_s_per_token = 1.0;
        spec.clusters[1].planned_s_per_token = 0.1;
        let reqs = stream_requests(Pattern::Bursty, 1, 1, 1.0, 0, 4);
        let plan_parts = route(RouterPolicy::PlanAware, &reqs, &spec.clusters);
        assert_eq!(plan_parts[1].len(), 1, "plan-aware routes to the fast cluster");
        // Both clusters idle: JSQ's backlog ties at 0 and goes low-index.
        let jsq_parts = route(RouterPolicy::JoinShortestQueue, &reqs, &spec.clusters);
        assert_eq!(jsq_parts[0].len(), 1, "idle tie breaks to the lowest index");
    }

    #[test]
    fn degenerate_plan_signal_falls_back_to_jsq() {
        let mut spec = tiny_fleet(8);
        spec.clusters[0].planned_s_per_token = f64::NAN;
        let reqs = stream_requests(Pattern::Bursty, 5, 8, 1.0, 0, 2);
        let plan_parts = route(RouterPolicy::PlanAware, &reqs, &spec.clusters);
        let total: usize = plan_parts.iter().map(Vec::len).sum();
        assert_eq!(total, reqs.len(), "a NaN plan signal must not drop requests");
        // With the plan criterion unusable, PlanAware is defined to route
        // exactly like JSQ — not to compare against NaN.
        let jsq_parts = route(RouterPolicy::JoinShortestQueue, &reqs, &spec.clusters);
        assert_eq!(plan_parts, jsq_parts);
    }

    #[test]
    fn churn_reroutes_the_dead_clusters_backlog_and_conserves_the_stream() {
        let mut spec = tiny_fleet(24);
        // A slow cluster 0 accumulates a queue under round-robin, so the
        // mid-stream fault finds queued-but-unstarted work to drain.
        spec.clusters[0].planned_s_per_token = 10.0;
        let script = Script::device_down_up("c0-blip", 0, 6, 18);
        let reqs = stream_requests(Pattern::Sporadic, 11, 24, 2.0, 0, 3);
        let (parts, rerouted) =
            route_churn(RouterPolicy::RoundRobin, &reqs, &spec.clusters, &script.churn);
        assert!(rerouted > 0, "the dead cluster's backlog must drain to survivors");
        // Conservation: every request routed exactly once, parts ascending.
        let mut idxs: Vec<u32> = parts.iter().flatten().copied().collect();
        idxs.sort_unstable();
        assert_eq!(idxs, (0..reqs.len() as u32).collect::<Vec<_>>());
        for p in &parts {
            assert!(p.windows(2).all(|w| w[0] < w[1]), "parts must stay ascending");
        }
        // No arrival in the outage window lands on the dead cluster.
        assert!(
            parts[0].iter().all(|&k| k < 6 || k >= 18),
            "cluster 0 must not be routable while down: {:?}",
            parts[0]
        );
    }

    #[test]
    fn empty_churn_routes_exactly_like_route() {
        let spec = tiny_fleet(24);
        let reqs = stream_requests(Pattern::Bursty, 11, 24, 2.0, 0, 3);
        for router in RouterPolicy::all() {
            let plain = route(router, &reqs, &spec.clusters);
            let (churned, rerouted) = route_churn(router, &reqs, &spec.clusters, &[]);
            assert_eq!(plain, churned, "{router:?} diverged with an empty timeline");
            assert_eq!(rerouted, 0);
        }
    }

    #[test]
    fn churned_fleet_pool_matches_sequential_and_validates() {
        let mut spec = tiny_fleet(24);
        spec.churn = Script::device_down_up("c0-blip", 0, 6, 18);
        let seq = run_fleet_sequential(&spec);
        let pool = Pool::new(4);
        let par = run_fleet_on(&spec, Some(&pool));
        let seq_bytes = fleet_artifact_bytes(&spec, &seq);
        assert_eq!(
            seq_bytes,
            fleet_artifact_bytes(&spec, &par),
            "churned pool fleet must serialize byte-identically to sequential"
        );
        let parsed = Json::parse(std::str::from_utf8(&seq_bytes).unwrap()).unwrap();
        let summary = validate_fleet(&parsed).expect("churned artifact validates");
        assert_eq!(summary.requests, 24);
        assert!(parsed.get("churn").is_some(), "churn header must be emitted");
        for cell in &seq {
            assert_eq!(cell.count, 24, "churn must not drop requests");
            assert!(cell.rerouted.is_some(), "every cell reports a reroute count");
        }
    }

    #[test]
    fn jsq_spills_to_the_idle_cluster_under_backlog() {
        let mut spec = tiny_fleet(4);
        spec.clusters[0].planned_s_per_token = 10.0; // huge backlog per request
        spec.clusters[1].planned_s_per_token = 10.0;
        let reqs = stream_requests(Pattern::Bursty, 2, 4, 1.0, 0, 2);
        let parts = route(RouterPolicy::JoinShortestQueue, &reqs, &spec.clusters);
        // Simultaneous arrivals: each admission loads one cluster, so JSQ
        // alternates rather than piling onto cluster 0.
        assert_eq!(parts[0].len(), 2);
        assert_eq!(parts[1].len(), 2);
    }

    #[test]
    fn fleet_pool_matches_sequential_bytes_and_validates() {
        let spec = tiny_fleet(24);
        let seq = run_fleet_sequential(&spec);
        let pool = Pool::new(4);
        let par = run_fleet_on(&spec, Some(&pool));
        let seq_bytes = fleet_artifact_bytes(&spec, &seq);
        let par_bytes = fleet_artifact_bytes(&spec, &par);
        assert_eq!(
            seq_bytes, par_bytes,
            "pool fleet must serialize byte-identically to sequential"
        );

        let parsed = Json::parse(std::str::from_utf8(&seq_bytes).unwrap()).unwrap();
        let summary = validate_fleet(&parsed).expect("artifact validates");
        assert_eq!(summary.schema, "lime-fleet-v1");
        assert_eq!(summary.cells, 6);
        assert_eq!(summary.clusters, 2);
        assert_eq!(summary.requests, 24);
        assert_eq!(summary.model, "TinyLM");

        // Every cell serves the full stream and reports sane tails.
        for cell in &seq {
            assert_eq!(cell.count, 24);
            assert!(cell.makespan > 0.0);
            assert!(cell.ttft.p50 <= cell.ttft.p95 && cell.ttft.p95 <= cell.ttft.p99);
            assert!(cell.ttft.mean > 0.0);
        }
    }

    #[test]
    fn validator_rejects_corruptions() {
        let spec = tiny_fleet(12);
        let cells = run_fleet_sequential(&spec);
        let bytes = fleet_artifact_bytes(&spec, &cells);
        let good = Json::parse(std::str::from_utf8(&bytes).unwrap()).unwrap();
        assert!(validate_fleet(&good).is_ok());

        let corrupt = |f: &dyn Fn(&mut std::collections::BTreeMap<String, Json>)| {
            let Json::Obj(mut map) = good.clone() else {
                panic!("artifact must be an object")
            };
            f(&mut map);
            validate_fleet(&Json::Obj(map))
        };

        // Wrong schema tag.
        assert!(corrupt(&|m| {
            m.insert("schema".into(), "lime-sweep-v4".into());
        })
        .is_err());
        // A dropped cell breaks the router x pattern cross.
        assert!(corrupt(&|m| {
            if let Some(Json::Arr(cells)) = m.get_mut("cells") {
                cells.pop();
            }
        })
        .is_err());
        // A cell that lost requests must be caught.
        assert!(corrupt(&|m| {
            if let Some(Json::Arr(cells)) = m.get_mut("cells") {
                if let Json::Obj(c0) = &mut cells[0] {
                    c0.insert("count".into(), 11usize.into());
                }
            }
        })
        .is_err());
        // Cell makespan must equal the max per-cluster makespan.
        assert!(corrupt(&|m| {
            if let Some(Json::Arr(cells)) = m.get_mut("cells") {
                if let Json::Obj(c0) = &mut cells[0] {
                    c0.insert("makespan_s".into(), 1e9.into());
                }
            }
        })
        .is_err());
        // A reroute counter without a churn header is a schema violation.
        assert!(corrupt(&|m| {
            if let Some(Json::Arr(cells)) = m.get_mut("cells") {
                if let Json::Obj(c0) = &mut cells[0] {
                    c0.insert("rerouted".into(), 3usize.into());
                }
            }
        })
        .is_err());
        // A churn header obliges every cell to carry a reroute counter.
        assert!(corrupt(&|m| {
            m.insert(
                "churn".into(),
                Json::Arr(vec![obj(&[
                    ("at_arrival", 6usize.into()),
                    ("cluster", 0usize.into()),
                    ("kind", "down".into()),
                ])]),
            );
        })
        .is_err());
        // Non-monotone percentiles are a stats bug, not data.
        assert!(corrupt(&|m| {
            if let Some(Json::Arr(cells)) = m.get_mut("cells") {
                if let Json::Obj(c0) = &mut cells[0] {
                    if let Some(Json::Obj(t)) = c0.get_mut("ttft_s") {
                        t.insert("p95".into(), 1e12.into());
                    }
                }
            }
        })
        .is_err());
    }

    #[test]
    fn des_router_matches_the_legacy_scan() {
        // In-module smoke; the full property sweep (tie-heavy rate
        // tables, mixed-length streams, degenerate signals) lives in
        // rust/tests/fleet_des.rs.
        let spec = tiny_fleet(200);
        for &pattern in &[Pattern::Sporadic, Pattern::Bursty] {
            let reqs = stream_requests(pattern, 23, 200, 5.0, 0, 3);
            for router in RouterPolicy::all() {
                assert_eq!(
                    route(router, &reqs, &spec.clusters),
                    route_scan(router, &reqs, &spec.clusters),
                    "{router:?}/{pattern:?}: DES decisions must equal the scan's"
                );
            }
        }
    }

    #[test]
    fn affinity_fleet_counts_hits_and_validates_v2() {
        let spec = tiny_affinity_fleet(24);
        let seq = run_fleet_sequential(&spec);
        let pool = Pool::new(4);
        let par = run_fleet_on(&spec, Some(&pool));
        let seq_bytes = fleet_artifact_bytes(&spec, &seq);
        assert_eq!(
            seq_bytes,
            fleet_artifact_bytes(&spec, &par),
            "affinity pool fleet must serialize byte-identically to sequential"
        );

        let parsed = Json::parse(std::str::from_utf8(&seq_bytes).unwrap()).unwrap();
        let summary = validate_fleet(&parsed).expect("v2 artifact validates");
        assert_eq!(summary.schema, "lime-fleet-v2");
        assert!(parsed.get("affinity").is_some(), "v2 header must be emitted");

        for cell in &seq {
            let aff = cell.affinity.expect("every cell carries counters");
            // 24 requests over 8 Zipf(1.2) sessions with a generous spill
            // threshold: repeats stick and reuse the resident prefix.
            assert!(aff.hits > 0, "{:?}: expected session hits", cell.router);
            assert!(aff.reuse_tokens_saved >= aff.hits);
            assert_eq!(
                aff.hits,
                cell.shards.iter().map(|s| s.affinity_hits).sum::<u64>(),
                "cell hits must be the shard sum"
            );
            assert_eq!(cell.count, 24, "affinity must not drop requests");
        }
    }

    #[test]
    fn affinity_free_spec_serializes_as_v1() {
        let spec = tiny_fleet(12);
        assert_eq!(schema_tag(&spec), "lime-fleet-v1");
        let bytes = fleet_artifact_bytes(&spec, &run_fleet_sequential(&spec));
        let text = std::str::from_utf8(&bytes).unwrap();
        for key in ["affinity", "affinity_hits", "reuse_tokens_saved", "spilled_sessions"] {
            assert!(
                !text.contains(key),
                "affinity-free artifact must not mention {key:?}"
            );
        }
    }

    #[test]
    fn validator_enforces_the_affinity_downgrade_rule() {
        let v1_spec = tiny_fleet(12);
        let v1_bytes = fleet_artifact_bytes(&v1_spec, &run_fleet_sequential(&v1_spec));
        let v1 = Json::parse(std::str::from_utf8(&v1_bytes).unwrap()).unwrap();
        let v2_spec = tiny_affinity_fleet(12);
        let v2_bytes = fleet_artifact_bytes(&v2_spec, &run_fleet_sequential(&v2_spec));
        let v2 = Json::parse(std::str::from_utf8(&v2_bytes).unwrap()).unwrap();
        assert!(validate_fleet(&v1).is_ok());
        assert!(validate_fleet(&v2).is_ok());

        let corrupt = |base: &Json, f: &dyn Fn(&mut std::collections::BTreeMap<String, Json>)| {
            let Json::Obj(mut map) = base.clone() else {
                panic!("artifact must be an object")
            };
            f(&mut map);
            validate_fleet(&Json::Obj(map))
        };

        // A v2 tag without the affinity header must downgrade, not pass.
        assert!(corrupt(&v1, &|m| {
            m.insert("schema".into(), "lime-fleet-v2".into());
        })
        .is_err());
        // An affinity header under the v1 tag is equally malformed.
        assert!(corrupt(&v2, &|m| {
            m.insert("schema".into(), "lime-fleet-v1".into());
        })
        .is_err());
        // v1 cells must not carry counter keys.
        assert!(corrupt(&v1, &|m| {
            if let Some(Json::Arr(cells)) = m.get_mut("cells") {
                if let Json::Obj(c0) = &mut cells[0] {
                    c0.insert("affinity_hits".into(), 1usize.into());
                }
            }
        })
        .is_err());
        // Cell counters must equal the per-cluster sums.
        assert!(corrupt(&v2, &|m| {
            if let Some(Json::Arr(cells)) = m.get_mut("cells") {
                if let Json::Obj(c0) = &mut cells[0] {
                    let hits = c0.get("affinity_hits").and_then(Json::as_u64).unwrap();
                    c0.insert("affinity_hits".into(), (hits + 1).into());
                }
            }
        })
        .is_err());
        // Every hit saves at least one token.
        assert!(corrupt(&v2, &|m| {
            if let Some(Json::Arr(cells)) = m.get_mut("cells") {
                if let Json::Obj(c0) = &mut cells[0] {
                    c0.insert("reuse_tokens_saved".into(), 0usize.into());
                }
            }
        })
        .is_err());
        // A degenerate affinity header (zero sessions) is rejected.
        assert!(corrupt(&v2, &|m| {
            if let Some(Json::Obj(a)) = m.get_mut("affinity") {
                a.insert("sessions".into(), 0usize.into());
            }
        })
        .is_err());
    }

    #[test]
    #[should_panic(expected = "affinity routing does not compose")]
    fn affinity_and_churn_do_not_compose() {
        let mut spec = tiny_affinity_fleet(12);
        spec.churn = Script::device_down_up("c0-blip", 0, 3, 9);
        run_fleet_sequential(&spec);
    }
}
