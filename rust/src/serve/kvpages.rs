//! Paged KV-cache allocator model — the vLLM-style PagedAttention memory
//! discipline (SNIPPETS §3B) in token-space accounting.
//!
//! The FIFO serving path (and every single-request run) models KV as
//! **contiguous preallocation**: a request's whole KV extent is implicitly
//! reserved for its lifetime. Real engines instead hand out fixed-size
//! **pages** (`page_tokens` KV slots each) from a bounded pool with a free
//! list, which is what makes step-level continuous batching viable under
//! memory pressure: an evicted request's pages return to the free list
//! immediately, a joining request takes pages as its context grows, and
//! only the *last* page of each context is internally fragmented.
//!
//! [`KvPagePool`] is pure accounting — deterministic integer/f64
//! arithmetic, no clocks, no RNG — so the continuous-batching driver
//! (`serve::simqueue`) stays bit-deterministic across worker counts. The
//! pool is device-replicated in token space: every device holds the same
//! token counts for its own layer slice, so one token-space pool models
//! all devices, and per-device *bytes* come out by scaling with the Eq. 8
//! per-token-per-layer unit ([`crate::adapt::resident_kv_bytes`], wired
//! through [`KvPageConfig::bytes_per_token`]).
//!
//! When the free list runs dry the pool **spills**: the context holding
//! the most resident pages (ties broken toward the lowest request id)
//! loses its coldest page to SSD. The driver drains
//! [`KvPagePool::take_spilled_tokens`] each step and costs the write on
//! every layer-hosting device through the same [`crate::sim::SsdModel`]
//! channel the emergency KV fallback uses — so spill traffic shows up in
//! step timing, not just counters. Spilled pages are modeled
//! write-only (no read-back on a later step; the simplification is
//! documented in `docs/SERVING.md`).
//!
//! Known limit: page accounting tracks the *raw* context (prompt +
//! generated tokens) and never applies a sliding-window `kv_ctx` cap —
//! a window spec's pages would keep growing past the window here while
//! `cost::mem_demand` saturates. Latent today: window variants are
//! unit-test constructors only (no matrix/fleet path builds one — see
//! the ROADMAP follow-on about promoting KV-shape variants to a matrix
//! axis).

/// Shape of the paged allocator: the page-size knob and the pool budget.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct KvPageSpec {
    /// KV token slots per page (the sweep's page-size knob; ≥ 1).
    pub page_tokens: usize,
    /// Total KV token slots the pool may hold resident across all
    /// contexts; the pool carves `ceil(budget_tokens / page_tokens)`
    /// pages out of it.
    pub budget_tokens: usize,
}

impl KvPageSpec {
    pub fn new(page_tokens: usize, budget_tokens: usize) -> Self {
        assert!(page_tokens >= 1, "page must hold at least one token");
        assert!(
            budget_tokens >= page_tokens,
            "budget must fit at least one page"
        );
        KvPageSpec {
            page_tokens,
            budget_tokens,
        }
    }

    /// Pages the pool holds (`ceil(budget_tokens / page_tokens)`).
    pub fn total_pages(&self) -> usize {
        self.budget_tokens.div_ceil(self.page_tokens)
    }
}

/// Paged-allocator wiring for one allocation: the pool shape plus the
/// per-device byte scale that turns spilled *tokens* into SSD-write
/// *bytes* (Eq. 8 unit: `kv_bytes_per_token_layer × device layers`).
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct KvPageConfig {
    pub spec: KvPageSpec,
    /// KV bytes one token occupies on each device
    /// (`resident_kv_bytes(alloc, i, 1)`); zero for devices hosting no
    /// layers, which the spill costing skips.
    pub bytes_per_token: Vec<u64>,
}

impl KvPageConfig {
    /// Build the config for `alloc`: page-size knob, token budget, and the
    /// per-device byte scales from the Eq. 8 volume model.
    pub fn for_alloc(
        alloc: &crate::plan::allocation::Allocation,
        page_tokens: usize,
        budget_tokens: usize,
    ) -> Self {
        KvPageConfig {
            spec: KvPageSpec::new(page_tokens, budget_tokens),
            bytes_per_token: (0..alloc.devices.len())
                .map(|i| crate::adapt::resident_kv_bytes(alloc, i, 1))
                .collect(),
        }
    }
}

/// One context's page accounting.
#[derive(Debug, Clone)]
struct Ctx {
    /// Total KV tokens the context has produced (prompt + decoded).
    tokens: usize,
    /// Pages currently resident in the pool.
    resident_pages: usize,
    /// Pages spilled to SSD (write-only; never read back).
    spilled_pages: usize,
    /// Tokens backed by resident pages (`tokens − spilled tokens`).
    resident_tokens: usize,
}

/// The paged KV allocator: a bounded page pool with free-list accounting,
/// per-context growth, immediate release on eviction, and deterministic
/// spill victim selection. See the module docs for the model.
#[derive(Debug, Clone)]
pub struct KvPagePool {
    spec: KvPageSpec,
    /// Pages not held by any context.
    free_pages: usize,
    /// Live contexts, keyed by request id — a `BTreeMap` so victim scans
    /// iterate in deterministic id order.
    contexts: std::collections::BTreeMap<u64, Ctx>,
    /// Cumulative pages handed out (fresh or recycled).
    pages_allocated: u64,
    /// Cumulative pages spilled to SSD.
    pages_spilled: u64,
    /// Spilled tokens not yet drained by the driver for SSD costing.
    spilled_tokens_pending: usize,
    /// Peak internal fragmentation observed (see [`KvPagePool::fragmentation`]).
    frag_peak: f64,
}

impl KvPagePool {
    pub fn new(spec: KvPageSpec) -> Self {
        KvPagePool {
            free_pages: spec.total_pages(),
            spec,
            contexts: std::collections::BTreeMap::new(),
            pages_allocated: 0,
            pages_spilled: 0,
            spilled_tokens_pending: 0,
            frag_peak: 0.0,
        }
    }

    pub fn spec(&self) -> KvPageSpec {
        self.spec
    }

    /// Admit a context holding `tokens` KV tokens (its prompt), allocating
    /// `ceil(tokens / page_tokens)` pages (spilling others' pages if the
    /// free list runs dry). Panics if `id` is already live.
    pub fn register(&mut self, id: u64, tokens: usize) {
        let pages = tokens.div_ceil(self.spec.page_tokens);
        assert!(
            self.contexts
                .insert(
                    id,
                    Ctx {
                        tokens,
                        resident_pages: 0,
                        spilled_pages: 0,
                        resident_tokens: tokens,
                    },
                )
                .is_none(),
            "context {id} already registered"
        );
        for _ in 0..pages {
            self.take_page_for(id);
        }
        self.note_fragmentation();
    }

    /// Grow context `id` by one decoded token, allocating a page when the
    /// token crosses a page boundary.
    pub fn append_token(&mut self, id: u64) {
        let page_tokens = self.spec.page_tokens;
        let ctx = self.contexts.get_mut(&id).expect("context not registered");
        ctx.tokens += 1;
        ctx.resident_tokens += 1;
        if ctx.resident_tokens > ctx.resident_pages * page_tokens {
            self.take_page_for(id);
        }
        self.note_fragmentation();
    }

    /// Release every resident page of context `id` back to the free list
    /// (the eviction path: pages free the moment a request finishes).
    /// Spilled pages are SSD-side and simply forgotten.
    pub fn release(&mut self, id: u64) {
        let ctx = self.contexts.remove(&id).expect("context not registered");
        debug_assert!(
            ctx.resident_tokens <= ctx.resident_pages * self.spec.page_tokens
                && ctx.resident_pages + ctx.spilled_pages
                    >= ctx.tokens.div_ceil(self.spec.page_tokens),
            "page accounting must cover the context's tokens"
        );
        self.free_pages += ctx.resident_pages;
        self.note_fragmentation();
    }

    /// Whether context `id` is live in the pool.
    pub fn is_registered(&self, id: u64) -> bool {
        self.contexts.contains_key(&id)
    }

    /// Tokens of context `id` still backed by resident pages (`None` if
    /// the context is not live). This is what session-affinity routing
    /// reads to size a hit's reusable prefix: spilled pages are modeled
    /// write-only, so only the resident portion skips re-prefill.
    pub fn resident_tokens(&self, id: u64) -> Option<usize> {
        self.contexts.get(&id).map(|c| c.resident_tokens)
    }

    /// Re-warm context `id` up to `target_tokens` resident tokens (capped
    /// at the context's own size), re-taking one page per spilled page —
    /// the affinity-hit path: the re-prefill of the non-resident suffix
    /// puts its pages back in the pool. Stops early if the only spill
    /// victim left is `id` itself (re-warming by cannibalizing the same
    /// context would not terminate); reuse simply decays in that regime.
    pub fn rewarm(&mut self, id: u64, target_tokens: usize) {
        let page_tokens = self.spec.page_tokens;
        let ctx = self.contexts.get(&id).expect("context not registered");
        let target = target_tokens.min(ctx.tokens);
        let mut resident = ctx.resident_tokens;
        while resident < target {
            if self.free_pages == 0 && self.spill_victim() == Some(id) {
                break;
            }
            self.take_page_for(id);
            let ctx = self.contexts.get_mut(&id).expect("context is live");
            let credit = (target - resident).min(page_tokens);
            ctx.resident_tokens += credit;
            ctx.spilled_pages = ctx.spilled_pages.saturating_sub(1);
            resident += credit;
        }
        self.note_fragmentation();
    }

    /// Hand one page to `ctx_id`, spilling a victim's page when the free
    /// list is empty.
    fn take_page_for(&mut self, ctx_id: u64) {
        if self.free_pages == 0 {
            self.spill_one(ctx_id);
        }
        assert!(self.free_pages > 0, "spill must have freed a page");
        self.free_pages -= 1;
        self.pages_allocated += 1;
        self.contexts
            .get_mut(&ctx_id)
            .expect("context not registered")
            .resident_pages += 1;
    }

    /// Spill the coldest page of the context holding the most resident
    /// pages (ties → lowest id; the requester itself is eligible, as in
    /// vLLM preemption). The page's resident tokens (a full page except
    /// for a context down to its last, partial page) queue for SSD
    /// costing via [`KvPagePool::take_spilled_tokens`].
    fn spill_one(&mut self, _requester: u64) {
        let victim = self
            .spill_victim()
            .expect("a pool with zero free pages holds resident pages");
        let page_tokens = self.spec.page_tokens;
        let ctx = self.contexts.get_mut(&victim).expect("victim is live");
        let moved = ctx.resident_tokens.min(page_tokens);
        ctx.resident_pages -= 1;
        ctx.spilled_pages += 1;
        ctx.resident_tokens -= moved;
        self.free_pages += 1;
        self.pages_spilled += 1;
        self.spilled_tokens_pending += moved;
    }

    /// The context the next spill would take a page from: most resident
    /// pages, ties toward the lowest id (as in vLLM preemption).
    fn spill_victim(&self) -> Option<u64> {
        self.contexts
            .iter()
            .filter(|(_, c)| c.resident_pages > 0)
            .max_by_key(|(id, c)| (c.resident_pages, std::cmp::Reverse(**id)))
            .map(|(id, _)| *id)
    }

    /// Tokens spilled since the last drain — the driver converts these to
    /// per-device SSD-write bytes through [`KvPageConfig::bytes_per_token`].
    pub fn take_spilled_tokens(&mut self) -> usize {
        std::mem::take(&mut self.spilled_tokens_pending)
    }

    /// Internal fragmentation right now: `1 − resident_tokens /
    /// (resident_pages × page_tokens)` across all live contexts (0.0 when
    /// no pages are held). Only the last page of each context can be
    /// partial, so this measures exactly the paged-vs-contiguous overhead.
    pub fn fragmentation(&self) -> f64 {
        let held: usize = self.contexts.values().map(|c| c.resident_pages).sum();
        if held == 0 {
            return 0.0;
        }
        let used: usize = self.contexts.values().map(|c| c.resident_tokens).sum();
        1.0 - used as f64 / (held * self.spec.page_tokens) as f64
    }

    fn note_fragmentation(&mut self) {
        let f = self.fragmentation();
        if f > self.frag_peak {
            self.frag_peak = f;
        }
    }

    /// Peak of [`KvPagePool::fragmentation`] over every mutation so far.
    pub fn fragmentation_peak(&self) -> f64 {
        self.frag_peak
    }

    /// Cumulative pages handed out.
    pub fn pages_allocated(&self) -> u64 {
        self.pages_allocated
    }

    /// Cumulative pages spilled to SSD.
    pub fn pages_spilled(&self) -> u64 {
        self.pages_spilled
    }

    /// Pages on the free list right now.
    pub fn free_pages(&self) -> usize {
        self.free_pages
    }

    /// Pages held by live contexts right now (summed from the contexts, so
    /// the free-list/held split is independently checkable:
    /// `pages_in_use() + free_pages() == spec.total_pages()` always).
    pub fn pages_in_use(&self) -> usize {
        self.contexts.values().map(|c| c.resident_pages).sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn register_grow_release_round_trips_the_free_list() {
        let mut pool = KvPagePool::new(KvPageSpec::new(4, 64)); // 16 pages
        assert_eq!(pool.free_pages(), 16);
        pool.register(1, 6); // ceil(6/4) = 2 pages
        assert_eq!(pool.pages_in_use(), 2);
        assert_eq!(pool.pages_allocated(), 2);
        // Tokens 7, 8 fit the second page; token 9 crosses the boundary.
        pool.append_token(1);
        pool.append_token(1);
        assert_eq!(pool.pages_in_use(), 2);
        pool.append_token(1);
        assert_eq!(pool.pages_in_use(), 3);
        assert_eq!(pool.pages_allocated(), 3);
        pool.release(1);
        assert_eq!(pool.free_pages(), 16);
        assert_eq!(pool.pages_spilled(), 0);
    }

    #[test]
    fn fragmentation_tracks_the_partial_last_page() {
        let mut pool = KvPagePool::new(KvPageSpec::new(8, 64));
        pool.register(1, 9); // 2 pages for 9 tokens → 7/16 wasted
        let f = pool.fragmentation();
        assert!((f - 7.0 / 16.0).abs() < 1e-12, "{f}");
        assert!(pool.fragmentation_peak() >= f);
        // Filling the page shrinks live fragmentation; the peak stays.
        for _ in 0..7 {
            pool.append_token(1);
        }
        assert!(pool.fragmentation() < 1e-12);
        assert!((pool.fragmentation_peak() - 7.0 / 16.0).abs() < 1e-12);
    }

    #[test]
    fn exhausted_pool_spills_the_largest_context() {
        // 4 pages of 2 tokens. Two contexts fill them; growth spills.
        let mut pool = KvPagePool::new(KvPageSpec::new(2, 8));
        pool.register(10, 6); // 3 pages
        pool.register(20, 2); // 1 page — pool full
        assert_eq!(pool.free_pages(), 0);
        pool.append_token(20); // needs a page → spills one of ctx 10's
        assert_eq!(pool.pages_spilled(), 1);
        assert_eq!(pool.take_spilled_tokens(), 2, "a full page moved");
        assert_eq!(pool.take_spilled_tokens(), 0, "drain is one-shot");
        // Victim was the largest context (10), ties impossible here.
        pool.release(10); // 2 resident pages return (1 spilled)
        assert_eq!(pool.free_pages(), 2);
    }

    #[test]
    fn spill_victim_ties_break_toward_lowest_id() {
        let mut pool = KvPagePool::new(KvPageSpec::new(2, 4)); // 2 pages
        pool.register(7, 2);
        pool.register(3, 2);
        pool.append_token(7); // boundary cross → spill; 3 and 7 tie at 1 page
        assert_eq!(pool.pages_spilled(), 1);
        // Context 3 lost its page: releasing it returns nothing.
        pool.release(3);
        assert_eq!(pool.free_pages(), 0);
        pool.release(7);
        assert_eq!(pool.free_pages(), 2);
    }

    #[test]
    fn rewarm_restores_spilled_residency() {
        // 4 pages of 2 tokens. Ctx 10 loses a page to ctx 20's growth,
        // then re-warms: residency and page conservation both recover.
        let mut pool = KvPagePool::new(KvPageSpec::new(2, 8));
        pool.register(10, 6); // 3 pages
        pool.register(20, 2); // 1 page — pool full
        pool.append_token(20); // spills a page of ctx 10
        assert_eq!(pool.resident_tokens(10), Some(4));
        assert!(pool.is_registered(10) && !pool.is_registered(99));
        pool.release(20); // frees ctx 20's 2 pages
        pool.rewarm(10, 6);
        assert_eq!(pool.resident_tokens(10), Some(6));
        assert_eq!(
            pool.pages_in_use() + pool.free_pages(),
            pool.spec().total_pages()
        );
        pool.release(10);
        assert_eq!(pool.free_pages(), pool.spec().total_pages());
    }

    #[test]
    fn rewarm_gives_up_rather_than_cannibalize_itself() {
        // 2 pages of 2 tokens; a single 6-token context can keep at most
        // 2 pages resident. Rewarming to full size must terminate with
        // whatever fits instead of spilling its own pages forever.
        let mut pool = KvPagePool::new(KvPageSpec::new(2, 4));
        pool.register(1, 6); // 3 pages needed → 1 already spilled
        assert_eq!(pool.resident_tokens(1), Some(4));
        pool.rewarm(1, 6);
        assert_eq!(pool.resident_tokens(1), Some(4), "capped by the pool");
        pool.release(1);
        assert_eq!(pool.free_pages(), 2);
    }

    #[test]
    fn no_page_leaks_under_fuzzed_churn() {
        // Deterministic LCG fuzz: random register/append/release against a
        // small pool; every page must be accounted for at every step, and
        // releasing everything must restore the full free list.
        let spec = KvPageSpec::new(4, 32); // 8 pages
        let mut pool = KvPagePool::new(spec);
        let mut live: Vec<u64> = Vec::new();
        let mut next_id = 0u64;
        let mut state = 0x2545_F491_4F6C_DD1Du64;
        let mut rng = move || {
            state = state.wrapping_mul(6364136223846793005).wrapping_add(1442695040888963407);
            (state >> 33) as usize
        };
        for _ in 0..2000 {
            match rng() % 4 {
                0 => {
                    pool.register(next_id, rng() % 9);
                    live.push(next_id);
                    next_id += 1;
                }
                _ if !live.is_empty() => {
                    let k = rng() % live.len();
                    if rng() % 3 == 0 {
                        pool.release(live.swap_remove(k));
                    } else {
                        pool.append_token(live[k]);
                    }
                }
                _ => {}
            }
            assert!(
                pool.pages_in_use() + pool.free_pages() == spec.total_pages(),
                "page conservation violated"
            );
            let f = pool.fragmentation();
            assert!((0.0..=1.0).contains(&f), "fragmentation out of range: {f}");
        }
        for id in live.drain(..) {
            pool.release(id);
        }
        assert_eq!(pool.free_pages(), spec.total_pages(), "pages leaked");
        pool.take_spilled_tokens();
    }
}
