//! Serving: the continuous-serving *simulator* ([`simqueue`], plain Rust,
//! always builds) plus real serving over PJRT — generation engine,
//! virtual-cluster deployment, and the threaded request server (the
//! end-to-end driver behind `examples/serve_cluster.rs`).
//!
//! The engine and server execute real HLO through the `xla` PJRT bindings
//! and are gated behind the off-by-default `pjrt` cargo feature; the
//! deployment planning helpers (and [`LayerResidency`], the contract
//! between the scheduler and the engine) are plain Rust and always build,
//! as does [`simqueue`] — the request-queue simulation over the unified
//! executor core (FIFO or step-level continuous batching, selected by
//! [`BatchingOpts`]) that the scenario matrix's arrival-process and
//! batching axes evaluate — [`kvpages`], the paged KV allocator model the
//! continuous driver can account pages through — and [`fleet`], the
//! multi-cluster admission-router layer: an event-driven DES router
//! (O(log C) heap decisions, optional sticky-session affinity with KV
//! reuse) that shards million-request streams across the work-stealing
//! pool and streams `lime-fleet-v1`/`lime-fleet-v2` tail-latency
//! artifacts.

pub mod deployment;
#[cfg(feature = "pjrt")]
pub mod engine;
#[cfg(feature = "pjrt")]
pub mod server;
pub mod fleet;
pub mod kvpages;
pub mod simqueue;

pub use deployment::{plan_tiny, residency_plan, virtual_cluster};
pub use fleet::{
    run_fleet, run_fleet_sequential, validate_fleet, write_fleet, AffinitySpec, FleetCluster,
    FleetSpec, FleetSummary, RouterPolicy,
};
pub use kvpages::{KvPageConfig, KvPagePool, KvPageSpec};
pub use simqueue::{
    serve_interleaved, serve_interleaved_opts, serve_tensor_parallel, serve_traditional,
    simulate_stream, simulate_stream_opts, simulate_stream_sink, simulate_stream_sink_opts,
    BatchingMode, BatchingOpts, RequestMetrics, StreamResult, StreamSink, StreamStats,
};
#[cfg(feature = "pjrt")]
pub use engine::{Engine, Generation};
#[cfg(feature = "pjrt")]
pub use server::{make_requests, serve, ServeReport};

use anyhow::Result;

/// Residency plan for one layer on the real path.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum LayerResidency {
    /// Both blocks pinned; executes the fused `layer_decode` artifact.
    Resident,
    /// Both blocks streamed from SSD; fused artifact, weights re-read.
    FullOffload,
    /// MHA streamed / MLP pinned; executes `mha_decode` + `mlp_decode`.
    MhaOffload,
    /// MLP streamed / MHA pinned; executes `mha_decode` + `mlp_decode`.
    MlpOffload,
}

/// The `lime serve` subcommand / quick demo: plan TinyLM over a virtual
/// memory-constrained cluster, serve a request stream, report latency and
/// throughput, and optionally verify losslessness against the fully
/// resident engine.
#[cfg(feature = "pjrt")]
pub fn run_server_demo(
    artifacts_dir: &str,
    requests: usize,
    steps: usize,
    bursty: bool,
    devices: usize,
    verify: bool,
) -> Result<()> {
    let manifest = crate::runtime::Manifest::load(artifacts_dir)?;
    let cfg = manifest.model.clone();
    let mut engine = Engine::new(manifest)?;
    println!(
        "loaded {} ({} layers, hidden {}) on PJRT [{}]",
        cfg.name,
        cfg.layers,
        cfg.hidden,
        engine.runtime.platform()
    );

    // Deploy across a memory-constrained virtual edge cluster.
    let per_dev = vec![1usize; devices.max(1)];
    let cluster = virtual_cluster(per_dev.len(), &per_dev);
    let alloc = plan_tiny(&cluster, steps)
        .map_err(|e| anyhow::anyhow!("planning failed: {e}"))?;
    print!("{}", alloc.describe());
    let plan = residency_plan(&alloc);
    engine.set_residency(&plan)?;

    let reqs = make_requests(bursty, requests, steps, cfg.prefill_len, cfg.vocab, 42);
    let reqs_verify = reqs.clone();
    let report = serve(&mut engine, reqs, false)?;
    println!(
        "served {} requests / {} tokens  pattern={}  prefill {:.1} ms  \
         token p50 {:.2} ms  p99 {:.2} ms  throughput {:.1} tok/s  \
         ssd loads {}",
        report.requests,
        report.tokens,
        if bursty { "bursty" } else { "sporadic" },
        report.prefill_mean * 1e3,
        report.token_p50 * 1e3,
        report.token_p99 * 1e3,
        report.throughput,
        engine.weights.loads_from_disk(),
    );

    if verify {
        // Lossless check: re-serve fully resident and compare outputs.
        engine.set_residency(&vec![LayerResidency::Resident; cfg.layers])?;
        let resident = serve(&mut engine, reqs_verify, false)?;
        let same = resident
            .generations
            .iter()
            .zip(&report.generations)
            .all(|(a, b)| a == b);
        if same {
            println!("losslessness verified: offloaded run bit-identical to resident run");
        } else {
            anyhow::bail!("LOSSLESS CHECK FAILED: offloaded outputs differ");
        }
    }
    Ok(())
}

/// Stub when the `pjrt` feature is disabled: the simulator stack has no
/// PJRT client, so real serving is unavailable.
#[cfg(not(feature = "pjrt"))]
pub fn run_server_demo(
    _artifacts_dir: &str,
    _requests: usize,
    _steps: usize,
    _bursty: bool,
    _devices: usize,
    _verify: bool,
) -> Result<()> {
    anyhow::bail!(
        "this binary was built without the `pjrt` feature; rebuild with \
         `--features pjrt` (requires the xla/xla_extension dependency — see Cargo.toml)"
    )
}
