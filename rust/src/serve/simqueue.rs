//! Continuous request-serving simulation over the unified executor core.
//!
//! The paper's headline 3.7× speedup (§V, Tab. V) is a claim about serving
//! a *stream* of queued requests under bursty arrivals — not about one
//! request in isolation. This module closes that gap for the simulation
//! stack (it is plain Rust, independent of the `pjrt` feature that gates
//! the real serving engine):
//!
//! * requests arrive per `workload::stream_requests` (§V-A: sporadic
//!   Poisson arrivals, or bursty simultaneous submission);
//! * a FIFO admission queue batches up to `max_batch` already-arrived
//!   requests into one pipelined run (the paper's execution model:
//!   micro-batch size 1, micro-batch count = admitted batch size);
//! * batches run **back-to-back on one shared cluster timeline** through
//!   [`ExecutorCore`]: resources, SSD jitter streams, the bandwidth trace
//!   and any fluctuation [`Script`] carry across requests — scripted
//!   events fire on the *stream* step counter, so a pressure dip scripted
//!   at step 40 lands mid-stream even when every request only decodes 16
//!   tokens;
//! * per-request metrics come out the other end: queueing delay, TTFT
//!   (time to first token, measured from arrival), mean time between
//!   tokens, and completion time — plus the stream makespan and the
//!   aggregated §IV-D adaptation counters.
//!
//! [`simulate_stream`] is generic over [`SchedulePolicy`], so LIME and
//! both baseline schedules serve streams through the same queue; the
//! `serve_*` helpers wrap the three policies. A single-request stream is
//! bit-identical to the corresponding `run_*` entry point
//! (property-tested in `rust/tests/serving_stream.rs`).
//!
//! Two admission disciplines share the queue (selected by
//! [`BatchingOpts`], see `docs/SERVING.md`):
//!
//! * **FIFO** (the default, and the only pre-v6 behaviour): a batch is
//!   formed once, runs to its longest member's completion, and the next
//!   admission waits for the whole batch — KV is modeled as contiguous
//!   preallocation.
//! * **Continuous** (step-level re-batching, SNIPPETS §3C): finished
//!   requests are evicted between decode steps, waiting prefilled
//!   requests join mid-epoch, and up to `prefill_ahead` pending
//!   admissions charge their prefill *while the current batch decodes*
//!   (the overlap that shrinks queueing delay under bursty arrivals).
//!   Decode advances through [`ExecutorCore::step_stream`] — the same
//!   single-step primitive `run_request_into` loops over — so scripted
//!   pressure/churn, emergency accounting and recovery tracking ride the
//!   identical path. Optionally a paged KV allocator
//!   ([`super::kvpages::KvPagePool`]) accounts pages per step and costs
//!   page spills as SSD writes through the Eq. 8 byte scales. With
//!   `max_batch = 1` and `prefill_ahead = 0` the continuous driver is
//!   bit-identical to FIFO (property-pinned in
//!   `rust/tests/serving_batching.rs`).

use super::kvpages::{KvPageConfig, KvPagePool};
use crate::adapt::Script;
use crate::cluster::Cluster;
use crate::model::ModelSpec;
use crate::net::BandwidthTrace;
use crate::pipeline::core::{CommonOptions, ExecutorCore, SchedulePolicy};
use crate::pipeline::{
    ExecOptions, InterleavedPolicy, TensorParallelPolicy, TpOptions, TradOptions,
    TraditionalPolicy,
};
use crate::plan::allocation::Allocation;
use crate::sim::{SpanKind, Trace};
use crate::workload::requests::Request;

/// Admission discipline of the serving queue.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum BatchingMode {
    /// Batch once, run to the longest member's completion, admit again.
    Fifo,
    /// Re-batch every decode step: evict finished requests immediately,
    /// join prefilled ones, overlap pending prefills with decode.
    Continuous,
}

/// Batching-policy knobs for [`simulate_stream_opts`] /
/// [`simulate_stream_sink_opts`].
#[derive(Debug, Clone)]
pub struct BatchingOpts {
    pub mode: BatchingMode,
    /// Continuous mode only: how many pending admissions may charge their
    /// prefill concurrently with the current batch's decode (each through
    /// [`SchedulePolicy::prefill_end`], micro-batch count 1). `0` disables
    /// the overlap — new epochs then admit exactly like FIFO.
    pub prefill_ahead: usize,
    /// Continuous mode only: when set, a [`KvPagePool`] tracks KV pages
    /// per step and page spills are costed as SSD writes through the
    /// config's Eq. 8 byte scales. `None` (and the whole FIFO path) models
    /// contiguous preallocation and reports zero page counters.
    pub kv_pages: Option<KvPageConfig>,
}

impl BatchingOpts {
    /// The pre-v6 behaviour: FIFO admission, contiguous KV.
    pub fn fifo() -> Self {
        BatchingOpts {
            mode: BatchingMode::Fifo,
            prefill_ahead: 0,
            kv_pages: None,
        }
    }

    /// Continuous batching with up to `prefill_ahead` overlapped prefills.
    pub fn continuous(prefill_ahead: usize) -> Self {
        BatchingOpts {
            mode: BatchingMode::Continuous,
            prefill_ahead,
            kv_pages: None,
        }
    }

    /// Attach a paged KV allocator (continuous mode only).
    pub fn with_kv_pages(mut self, cfg: KvPageConfig) -> Self {
        self.kv_pages = Some(cfg);
        self
    }
}

/// Request-level metrics of one served request.
#[derive(Debug, Clone, PartialEq)]
pub struct RequestMetrics {
    pub id: u64,
    /// Arrival time (seconds from stream start).
    pub arrival: f64,
    /// When the request's batch was admitted (prefill begin).
    pub admitted_at: f64,
    /// `admitted_at - arrival`: time spent waiting in the FIFO queue.
    pub queueing_delay: f64,
    /// First-token latency measured from arrival (queueing + prefill +
    /// first decode step).
    pub ttft: f64,
    /// Mean time between tokens over the request's decode steps.
    pub tbt: f64,
    /// Absolute completion time of the request's last token.
    pub finish: f64,
}

/// Outcome of serving one request stream.
#[derive(Debug, Clone)]
pub struct StreamResult {
    /// Per-request metrics: admission order on the FIFO path, completion
    /// order on the continuous path (requests finish independently there).
    pub requests: Vec<RequestMetrics>,
    /// Batched runs executed: admissions on the FIFO path, batch *epochs*
    /// (batch formed from an empty cluster) on the continuous path.
    pub batches: usize,
    /// Completion time of the last request (arrivals start at t = 0).
    pub makespan: f64,
    /// Tokens generated across all requests (Σ per-request steps).
    pub tokens_generated: usize,
    /// Decode time summed over every step of every batch (excludes
    /// queueing and prefill).
    pub decode_time: f64,
    /// Per-step decode latencies across the whole stream, in order.
    pub step_times: Vec<f64>,
    /// Device/time activity across the whole stream.
    pub trace: Trace,
    pub kv_tokens_transferred: u64,
    pub online_plans_fired: usize,
    pub emergency_steps: usize,
    pub bw_stalls: u64,
    /// Churn-triggered re-plans fired across the stream.
    pub replans_fired: usize,
    /// KV bytes migrated off departing / onto rejoining devices.
    pub kv_migrated_bytes: u64,
    /// Per-`Down`-event recovery latency in decode steps, stream-wide
    /// firing order (`None` = the stream ended still degraded).
    pub recovery_steps: Vec<Option<usize>>,
    /// KV pages handed out by the paged allocator, cumulative over the
    /// stream. Zero on the FIFO path and on continuous runs without
    /// [`BatchingOpts::kv_pages`] (contiguous preallocation).
    pub kv_pages_allocated: u64,
    /// KV pages spilled to SSD when the page budget ran dry, costed as
    /// SSD writes through the Eq. 8 byte scales. Zero without paging.
    pub kv_pages_spilled: u64,
    /// Peak internal fragmentation of the paged allocator
    /// ([`KvPagePool::fragmentation_peak`]); 0.0 without paging.
    pub kv_fragmentation: f64,
    /// Requests admitted with a nonzero reused KV prefix
    /// (`Request::cached_prefix` after the at-least-one-token cap) —
    /// session-affinity hits routed back to their resident cluster.
    /// Always 0 outside affinity-routed fleet shards.
    pub affinity_hits: u64,
    /// Prompt tokens whose prefill was skipped thanks to KV reuse
    /// (Σ applied cached prefix over admitted requests).
    pub reuse_tokens_saved: u64,
}

impl StreamResult {
    /// Mean decode latency per generated token, in milliseconds — the
    /// stream analogue of `SimResult::ms_per_token` (queueing shows up in
    /// [`StreamResult::mean_queueing_delay`]/TTFT instead).
    pub fn ms_per_token(&self) -> f64 {
        self.decode_time * 1e3 / self.tokens_generated.max(1) as f64
    }

    pub fn mean_queueing_delay(&self) -> f64 {
        mean(self.requests.iter().map(|r| r.queueing_delay))
    }

    pub fn mean_ttft(&self) -> f64 {
        mean(self.requests.iter().map(|r| r.ttft))
    }

    pub fn mean_tbt(&self) -> f64 {
        mean(self.requests.iter().map(|r| r.tbt))
    }
}

fn mean(it: impl Iterator<Item = f64>) -> f64 {
    let (mut sum, mut n) = (0.0f64, 0usize);
    for v in it {
        sum += v;
        n += 1;
    }
    if n == 0 {
        0.0
    } else {
        sum / n as f64
    }
}

/// Consumer of per-request metrics as the stream produces them. The
/// memory-flat path for million-request streams: a sink folds each
/// request into O(1) state (means, P²/reservoir quantiles) instead of the
/// driver retaining a `Vec<RequestMetrics>`. `Vec<RequestMetrics>` itself
/// implements the trait — [`simulate_stream`] is the collecting special
/// case of [`simulate_stream_sink`].
pub trait StreamSink {
    fn on_request(&mut self, m: &RequestMetrics);
}

impl StreamSink for Vec<RequestMetrics> {
    fn on_request(&mut self, m: &RequestMetrics) {
        self.push(m.clone());
    }
}

/// Aggregate outcome of a sink-driven stream — everything
/// [`StreamResult`] holds except the per-request vector.
#[derive(Debug, Clone)]
pub struct StreamStats {
    pub batches: usize,
    pub makespan: f64,
    pub tokens_generated: usize,
    pub decode_time: f64,
    /// Empty when `retain_step_times` was off (memory-flat mode);
    /// `decode_time` still sums every step either way.
    pub step_times: Vec<f64>,
    pub trace: Trace,
    pub kv_tokens_transferred: u64,
    pub online_plans_fired: usize,
    pub emergency_steps: usize,
    pub bw_stalls: u64,
    pub replans_fired: usize,
    pub kv_migrated_bytes: u64,
    pub recovery_steps: Vec<Option<usize>>,
    pub kv_pages_allocated: u64,
    pub kv_pages_spilled: u64,
    pub kv_fragmentation: f64,
    pub affinity_hits: u64,
    pub reuse_tokens_saved: u64,
}

/// Serve `requests` (sorted by arrival) through `policy` on one shared
/// cluster timeline.
///
/// Admission: when the cluster frees at `t_free`, the earliest pending
/// request sets the service start `t = max(t_free, arrival)`; every
/// further request that has arrived by `t` joins the batch, up to
/// `max_batch` (pass `Pattern::micro_batches(..)` for the paper's
/// per-pattern batching: 1 sporadic, `|D|` bursty). The batch runs as one
/// pipelined generation with micro-batch count = batch size; heterogeneous
/// step counts are allowed (the batch decodes to the longest request, and
/// each request's finish/TBT are measured at its own step count).
///
/// Each request's *own* lengths are honored end-to-end: prefill FLOPs,
/// activation volume and KV context are charged from `r.prompt.len()`,
/// decode advances each slot's context by its completed step count, and
/// the paged allocator registers `r.prompt.len()` tokens per request.
/// An *empty* prompt falls back to `common.prompt_tokens` for all of the
/// above — the memory-flat convention `serve::fleet` uses to stream
/// 10^6 requests without materializing token vectors.
/// The driver installs the per-slot `(prompt_len, completed_steps)`
/// pairs through [`SchedulePolicy::set_slot_lengths`] before every
/// admission charge and decode step; policies that ignore the hook keep
/// charging from `common.prompt_tokens` as before. When every request
/// carries `prompt_len == common.prompt_tokens` and a uniform step
/// count — i.e. any `LengthDist::Fixed` stream — the timings are
/// bit-identical to the pre-mix global-knob path (property-pinned in
/// `rust/tests/workload_mix.rs`).
pub fn simulate_stream<P: SchedulePolicy>(
    policy: P,
    cluster: &Cluster,
    bw_trace: &BandwidthTrace,
    max_batch: usize,
    common: &CommonOptions,
    script: &Script,
    requests: &[Request],
) -> StreamResult {
    simulate_stream_opts(
        policy,
        cluster,
        bw_trace,
        max_batch,
        common,
        script,
        requests,
        &BatchingOpts::fifo(),
    )
}

/// [`simulate_stream`] under an explicit batching policy
/// ([`BatchingOpts`]): `BatchingOpts::fifo()` reproduces
/// [`simulate_stream`] bit-for-bit, `BatchingOpts::continuous(..)`
/// selects the step-level re-batching driver.
#[allow(clippy::too_many_arguments)]
pub fn simulate_stream_opts<P: SchedulePolicy>(
    policy: P,
    cluster: &Cluster,
    bw_trace: &BandwidthTrace,
    max_batch: usize,
    common: &CommonOptions,
    script: &Script,
    requests: &[Request],
    batching: &BatchingOpts,
) -> StreamResult {
    let mut metrics: Vec<RequestMetrics> = Vec::with_capacity(requests.len());
    let stats = simulate_stream_sink_opts(
        policy,
        cluster,
        bw_trace,
        max_batch,
        common,
        script,
        requests,
        batching,
        &mut metrics,
        true,
    );
    StreamResult {
        requests: metrics,
        batches: stats.batches,
        makespan: stats.makespan,
        tokens_generated: stats.tokens_generated,
        decode_time: stats.decode_time,
        step_times: stats.step_times,
        trace: stats.trace,
        kv_tokens_transferred: stats.kv_tokens_transferred,
        online_plans_fired: stats.online_plans_fired,
        emergency_steps: stats.emergency_steps,
        bw_stalls: stats.bw_stalls,
        replans_fired: stats.replans_fired,
        kv_migrated_bytes: stats.kv_migrated_bytes,
        recovery_steps: stats.recovery_steps,
        kv_pages_allocated: stats.kv_pages_allocated,
        kv_pages_spilled: stats.kv_pages_spilled,
        kv_fragmentation: stats.kv_fragmentation,
        affinity_hits: stats.affinity_hits,
        reuse_tokens_saved: stats.reuse_tokens_saved,
    }
}

/// [`simulate_stream`], metrics delivered through `sink` instead of
/// collected — with `retain_step_times = false` this is the memory-flat
/// driver for million-request fleet streams: per-request/per-batch state
/// lives in one reused [`CoreArena`], the core keeps only a running
/// decode-time sum, and the sink decides what (if anything) to retain.
/// All aggregates are accumulated left-to-right in stream order, so they
/// are bit-identical to the collecting path's post-hoc folds.
#[allow(clippy::too_many_arguments)]
pub fn simulate_stream_sink<P: SchedulePolicy, S: StreamSink>(
    policy: P,
    cluster: &Cluster,
    bw_trace: &BandwidthTrace,
    max_batch: usize,
    common: &CommonOptions,
    script: &Script,
    requests: &[Request],
    sink: &mut S,
    retain_step_times: bool,
) -> StreamStats {
    simulate_stream_sink_opts(
        policy,
        cluster,
        bw_trace,
        max_batch,
        common,
        script,
        requests,
        &BatchingOpts::fifo(),
        sink,
        retain_step_times,
    )
}

/// [`simulate_stream_sink`] under an explicit batching policy — the one
/// driver both entry points funnel into.
#[allow(clippy::too_many_arguments)]
pub fn simulate_stream_sink_opts<P: SchedulePolicy, S: StreamSink>(
    policy: P,
    cluster: &Cluster,
    bw_trace: &BandwidthTrace,
    max_batch: usize,
    common: &CommonOptions,
    script: &Script,
    requests: &[Request],
    batching: &BatchingOpts,
    sink: &mut S,
    retain_step_times: bool,
) -> StreamStats {
    assert!(
        requests.windows(2).all(|w| w[0].arrival <= w[1].arrival),
        "requests must be sorted by arrival (FIFO admission)"
    );
    match batching.mode {
        BatchingMode::Fifo => run_fifo(
            policy,
            cluster,
            bw_trace,
            max_batch,
            common,
            script,
            requests,
            sink,
            retain_step_times,
        ),
        BatchingMode::Continuous => run_continuous(
            policy,
            cluster,
            bw_trace,
            max_batch,
            common,
            script,
            requests,
            batching,
            sink,
            retain_step_times,
        ),
    }
}

/// A request's effective prompt length for slot installation and paged-KV
/// registration. An *empty* prompt means "charge from the global knob"
/// (`common.prompt_tokens`): `serve::fleet` deliberately streams
/// zero-token prompts to stay memory-flat at 10^6 requests, and any
/// pre-mix caller that never materialized tokens relied on the knob. A
/// non-empty prompt always wins over the knob.
fn slot_prompt(r: &Request, common: &CommonOptions) -> usize {
    slot_base(r, common) - applied_reuse(r, common)
}

/// The request's raw prompt length before KV reuse (empty prompt ⇒ the
/// global knob).
fn slot_base(r: &Request, common: &CommonOptions) -> usize {
    if r.prompt.is_empty() {
        common.prompt_tokens
    } else {
        r.prompt.len()
    }
}

/// Prompt-prefix tokens actually skipped for `r`: the session-affinity
/// cached prefix, capped so at least one prompt token is always recomputed
/// — even a full-prefix hit must run the final prompt position to produce
/// the first logits. Zero (and `slot_prompt == slot_base`) whenever
/// `cached_prefix` is zero, i.e. on every non-affinity path.
fn applied_reuse(r: &Request, common: &CommonOptions) -> usize {
    (r.cached_prefix as usize).min(slot_base(r, common).saturating_sub(1))
}

/// The FIFO admission loop. The batch loop replicates
/// [`ExecutorCore::run_request_into`]'s arithmetic step for step
/// (`begin_request`, then one `step_stream` per decode token) so the
/// driver can re-install each slot's `(prompt_len, completed_steps)`
/// between steps; with uniform lengths the sequence of calls — and thus
/// every timing — is identical to the pre-mix `run_request_in` path.
#[allow(clippy::too_many_arguments)]
fn run_fifo<P: SchedulePolicy, S: StreamSink>(
    policy: P,
    cluster: &Cluster,
    bw_trace: &BandwidthTrace,
    max_batch: usize,
    common: &CommonOptions,
    script: &Script,
    requests: &[Request],
    sink: &mut S,
    retain_step_times: bool,
) -> StreamStats {
    let max_batch = max_batch.max(1);
    let mut core = ExecutorCore::new(policy, cluster, bw_trace, common, script);
    core.retain_step_times(retain_step_times);
    let mut batches = 0usize;
    let mut makespan = 0.0f64;
    let mut affinity_hits = 0u64;
    let mut reuse_tokens_saved = 0u64;
    let mut t_free = 0.0f64;
    let mut i = 0usize;
    // Reused across batches: per-step completion times and the per-slot
    // (prompt_len, completed_steps) pairs installed before every charge.
    let mut step_ends: Vec<f64> = Vec::new();
    let mut slots: Vec<(usize, usize)> = Vec::new();
    while i < requests.len() {
        let t_start = t_free.max(requests[i].arrival);
        let mut j = i + 1;
        while j < requests.len() && j - i < max_batch && requests[j].arrival <= t_start {
            j += 1;
        }
        let batch = &requests[i..j];
        let tokens = batch.iter().map(|r| r.steps).max().unwrap_or(0);
        let micro = batch.len().max(1);
        slots.clear();
        slots.extend(batch.iter().map(|r| (slot_prompt(r, common), 0usize)));
        for r in batch {
            let cached = applied_reuse(r, common);
            if cached > 0 {
                affinity_hits += 1;
                reuse_tokens_saved += cached as u64;
            }
        }
        core.policy.set_slot_lengths(&slots);
        let g = core.global_step();
        let decode_start = core.policy.begin_request(&mut core.state, t_start, micro, g);
        let mut t_prev = decode_start;
        step_ends.clear();
        step_ends.reserve(tokens);
        for local in 0..tokens {
            // A member that already finished keeps its batch slot (FIFO
            // runs to the longest request) but its context stops growing
            // at its own step count.
            for (s, r) in slots.iter_mut().zip(batch) {
                s.1 = local.min(r.steps);
            }
            core.policy.set_slot_lengths(&slots);
            // Scripted churn that would take down the last surviving
            // device is a scenario-authoring error, rejected by
            // `ScenarioMatrix::assert_valid` before any stream runs; fail
            // loudly if one slips through rather than serving from an
            // empty cluster.
            let step_end = core
                .step_stream(t_prev, micro, local)
                .expect("churn script must leave at least one surviving device");
            step_ends.push(step_end);
            t_prev = step_end;
        }
        for r in batch {
            let finish = if r.steps == 0 {
                decode_start
            } else {
                step_ends[r.steps - 1]
            };
            // A zero-step request emits no token: its "first token" time
            // degenerates to its own finish (prefill end), never to a
            // batch-mate's first decode step.
            let first = if r.steps == 0 {
                decode_start
            } else {
                step_ends[0]
            };
            let m = RequestMetrics {
                id: r.id,
                arrival: r.arrival,
                admitted_at: t_start,
                queueing_delay: t_start - r.arrival,
                ttft: first - r.arrival,
                tbt: if r.steps == 0 {
                    0.0
                } else {
                    (finish - decode_start) / r.steps as f64
                },
                finish,
            };
            makespan = makespan.max(m.finish);
            sink.on_request(&m);
        }
        t_free = step_ends.last().copied().unwrap_or(decode_start);
        batches += 1;
        i = j;
    }
    core.policy.set_slot_lengths(&[]);
    let totals = core.into_totals();
    StreamStats {
        batches,
        makespan,
        tokens_generated: requests.iter().map(|r| r.steps).sum(),
        decode_time: totals.step_time_sum,
        step_times: totals.step_times,
        trace: totals.trace,
        kv_tokens_transferred: totals.kv_tokens_transferred,
        online_plans_fired: totals.online_plans_fired,
        emergency_steps: totals.emergency_steps,
        bw_stalls: totals.bw_stalls,
        replans_fired: totals.replans_fired,
        kv_migrated_bytes: totals.kv_migrated_bytes,
        recovery_steps: totals.recovery_steps,
        // FIFO models KV as contiguous preallocation: no pages, ever.
        kv_pages_allocated: 0,
        kv_pages_spilled: 0,
        kv_fragmentation: 0.0,
        affinity_hits,
        reuse_tokens_saved,
    }
}

/// One in-flight request of the continuous driver.
struct ActiveSlot {
    /// Index into the request slice.
    idx: usize,
    /// Decode steps completed so far.
    done: usize,
    /// When this request's decode began (its batch epoch's decode start,
    /// or the step boundary it joined at).
    decode_start: f64,
    /// When admission work for it began (epoch formation or prefill-ahead
    /// launch) — the moment it left the queue.
    admitted_at: f64,
    /// First-token time (set when `done` reaches 1).
    first: f64,
}

/// A request whose prefill was overlapped with the current batch's decode
/// and is waiting for a free batch slot.
struct ReadyReq {
    idx: usize,
    admitted_at: f64,
    /// Prefill-end time: the request may join at the first step boundary
    /// at or after this.
    ready_at: f64,
}

/// The step-level continuous-batching driver (module docs, SNIPPETS §3C).
///
/// Structure per iteration: (1) with an empty cluster, form a new batch
/// epoch — from already-prefilled [`ReadyReq`]s via
/// [`SchedulePolicy::begin_batch`], else from the FIFO queue via
/// [`SchedulePolicy::begin_request`] (exactly the FIFO path's admission,
/// which is what makes `max_batch = 1, prefill_ahead = 0` bit-identical
/// to FIFO); (2) launch up to `prefill_ahead` pending prefills through
/// [`SchedulePolicy::prefill_end`] (micro-batch count 1, pure time
/// arithmetic overlapped with decode); (3) advance one decode step via
/// [`ExecutorCore::step_stream`] with the *current* batch width and the
/// oldest member's completed-step count; (4) grow/spill KV pages and cost
/// spills as SSD writes; (5) evict finished members (emitting their
/// metrics and releasing their pages immediately) and join ready ones,
/// signalling a width change through [`SchedulePolicy::on_batch_resize`].
#[allow(clippy::too_many_arguments)]
fn run_continuous<P: SchedulePolicy, S: StreamSink>(
    policy: P,
    cluster: &Cluster,
    bw_trace: &BandwidthTrace,
    max_batch: usize,
    common: &CommonOptions,
    script: &Script,
    requests: &[Request],
    batching: &BatchingOpts,
    sink: &mut S,
    retain_step_times: bool,
) -> StreamStats {
    let max_batch = max_batch.max(1);
    let mut core = ExecutorCore::new(policy, cluster, bw_trace, common, script);
    core.retain_step_times(retain_step_times);
    let kv_cfg = batching.kv_pages.as_ref();
    let mut pool = kv_cfg.map(|cfg| KvPagePool::new(cfg.spec));

    let mut active: Vec<ActiveSlot> = Vec::new();
    let mut ready: std::collections::VecDeque<ReadyReq> = std::collections::VecDeque::new();
    let mut next = 0usize; // FIFO cursor into `requests`
    let mut batches = 0usize;
    let mut makespan = 0.0f64;
    let mut affinity_hits = 0u64;
    let mut reuse_tokens_saved = 0u64;
    let mut t = 0.0f64;
    // Reused per-slot (prompt_len, completed_steps) buffer, installed
    // through `SchedulePolicy::set_slot_lengths` before every admission
    // charge and decode step.
    let mut slots: Vec<(usize, usize)> = Vec::new();

    // Emits a finished request. A zero-step request "finishes" the moment
    // its prefill does (it generates no token), mirroring the FIFO path's
    // degenerate metrics: first = finish = prefill end, TBT = 0.
    fn emit<S: StreamSink>(
        sink: &mut S,
        makespan: &mut f64,
        r: &Request,
        admitted_at: f64,
        decode_start: f64,
        first: f64,
        finish: f64,
    ) {
        let m = RequestMetrics {
            id: r.id,
            arrival: r.arrival,
            admitted_at,
            queueing_delay: admitted_at - r.arrival,
            ttft: first - r.arrival,
            tbt: if r.steps == 0 {
                0.0
            } else {
                (finish - decode_start) / r.steps as f64
            },
            finish,
        };
        *makespan = makespan.max(m.finish);
        sink.on_request(&m);
    }

    while next < requests.len() || !ready.is_empty() || !active.is_empty() {
        if active.is_empty() {
            // ---- form a new batch epoch on the idle cluster ----
            if !ready.is_empty() {
                let take = ready.len().min(max_batch);
                let members: Vec<ReadyReq> = ready.drain(..take).collect();
                let t_dec = members.iter().fold(t, |acc, r| acc.max(r.ready_at));
                slots.clear();
                slots.extend(
                    members
                        .iter()
                        .map(|m| (slot_prompt(&requests[m.idx], common), 0usize)),
                );
                core.policy.set_slot_lengths(&slots);
                let g = core.global_step();
                let decode_start = core.policy.begin_batch(&mut core.state, t_dec, take, g);
                batches += 1;
                for m in members {
                    let r = &requests[m.idx];
                    if let Some(pool) = pool.as_mut() {
                        pool.register(r.id, slot_prompt(r, common));
                    }
                    active.push(ActiveSlot {
                        idx: m.idx,
                        done: 0,
                        decode_start,
                        admitted_at: m.admitted_at,
                        first: decode_start,
                    });
                }
                t = decode_start;
            } else if next < requests.len() {
                // FIFO-style admission: identical gather + begin_request
                // arithmetic to `run_fifo`, so prefill-ahead-free
                // single-slot streams stay bit-identical.
                let t_start = t.max(requests[next].arrival);
                let mut j = next + 1;
                while j < requests.len() && j - next < max_batch && requests[j].arrival <= t_start {
                    j += 1;
                }
                slots.clear();
                slots.extend(
                    requests[next..j]
                        .iter()
                        .map(|r| (slot_prompt(r, common), 0usize)),
                );
                core.policy.set_slot_lengths(&slots);
                let g = core.global_step();
                let decode_start =
                    core.policy.begin_request(&mut core.state, t_start, j - next, g);
                batches += 1;
                for idx in next..j {
                    let r = &requests[idx];
                    let cached = applied_reuse(r, common);
                    if cached > 0 {
                        affinity_hits += 1;
                        reuse_tokens_saved += cached as u64;
                    }
                    if r.steps == 0 {
                        emit(
                            sink,
                            &mut makespan,
                            r,
                            t_start,
                            decode_start,
                            decode_start,
                            decode_start,
                        );
                        continue;
                    }
                    if let Some(pool) = pool.as_mut() {
                        pool.register(r.id, slot_prompt(r, common));
                    }
                    active.push(ActiveSlot {
                        idx,
                        done: 0,
                        decode_start,
                        admitted_at: t_start,
                        first: decode_start,
                    });
                }
                next = j;
                t = decode_start;
            } else {
                break;
            }
            if active.is_empty() {
                continue; // the whole epoch was zero-step requests
            }
        }

        // ---- overlap pending admissions' prefill with this decode ----
        while batching.prefill_ahead > 0
            && ready.len() < batching.prefill_ahead
            && next < requests.len()
            && requests[next].arrival <= t
        {
            let r = &requests[next];
            let cached = applied_reuse(r, common);
            if cached > 0 {
                affinity_hits += 1;
                reuse_tokens_saved += cached as u64;
            }
            core.policy.set_slot_lengths(&[(slot_prompt(r, common), 0)]);
            let g = core.global_step();
            let ready_at = core.policy.prefill_end(&mut core.state, t, 1, g);
            if r.steps == 0 {
                emit(sink, &mut makespan, r, t, ready_at, ready_at, ready_at);
            } else {
                ready.push_back(ReadyReq {
                    idx: next,
                    admitted_at: t,
                    ready_at,
                });
            }
            next += 1;
        }

        // ---- one decode step at the current batch width ----
        let local = active.iter().map(|s| s.done).max().unwrap_or(0);
        slots.clear();
        slots.extend(
            active
                .iter()
                .map(|s| (slot_prompt(&requests[s.idx], common), s.done)),
        );
        core.policy.set_slot_lengths(&slots);
        // Scripted churn that would take down the last surviving device is
        // rejected by `ScenarioMatrix::assert_valid` before any stream
        // runs; fail loudly if one slips through.
        let step_end = core
            .step_stream(t, active.len(), local)
            .expect("churn script must leave at least one surviving device");
        let mut t_next = step_end;

        // ---- paged-KV growth + spill costing ----
        if let (Some(pool), Some(cfg)) = (pool.as_mut(), kv_cfg) {
            for s in &active {
                pool.append_token(requests[s.idx].id);
            }
            let spilled = pool.take_spilled_tokens();
            if spilled > 0 {
                for (i, &bpt) in cfg.bytes_per_token.iter().enumerate() {
                    if bpt == 0 {
                        continue;
                    }
                    let w = core.state.ssds[i].write(step_end, bpt * spilled as u64);
                    core.state.trace.push(i, SpanKind::Store, "kv-page-spill", w.start, w.end);
                    t_next = t_next.max(w.end);
                }
            }
        }

        // ---- evict finished members, join ready ones ----
        let width_before = active.len();
        for s in active.iter_mut() {
            s.done += 1;
            if s.done == 1 {
                s.first = step_end;
            }
        }
        let mut k = 0;
        while k < active.len() {
            if active[k].done >= requests[active[k].idx].steps {
                let s = active.remove(k);
                let r = &requests[s.idx];
                if let Some(pool) = pool.as_mut() {
                    pool.release(r.id);
                }
                emit(
                    sink,
                    &mut makespan,
                    r,
                    s.admitted_at,
                    s.decode_start,
                    s.first,
                    step_end,
                );
            } else {
                k += 1;
            }
        }
        while active.len() < max_batch && ready.front().is_some_and(|r| r.ready_at <= step_end) {
            let m = ready.pop_front().expect("front checked above");
            let r = &requests[m.idx];
            if let Some(pool) = pool.as_mut() {
                pool.register(r.id, slot_prompt(r, common));
            }
            active.push(ActiveSlot {
                idx: m.idx,
                done: 0,
                decode_start: step_end,
                admitted_at: m.admitted_at,
                first: step_end,
            });
        }
        if active.len() != width_before && !active.is_empty() {
            let width = active.len();
            core.policy.on_batch_resize(&mut core.state, width);
        }

        t = t_next;
    }

    core.policy.set_slot_lengths(&[]);
    let (kv_pages_allocated, kv_pages_spilled, kv_fragmentation) = pool
        .map(|p| (p.pages_allocated(), p.pages_spilled(), p.fragmentation_peak()))
        .unwrap_or((0, 0, 0.0));
    let totals = core.into_totals();
    StreamStats {
        batches,
        makespan,
        tokens_generated: requests.iter().map(|r| r.steps).sum(),
        decode_time: totals.step_time_sum,
        step_times: totals.step_times,
        trace: totals.trace,
        kv_tokens_transferred: totals.kv_tokens_transferred,
        online_plans_fired: totals.online_plans_fired,
        emergency_steps: totals.emergency_steps,
        bw_stalls: totals.bw_stalls,
        replans_fired: totals.replans_fired,
        kv_migrated_bytes: totals.kv_migrated_bytes,
        recovery_steps: totals.recovery_steps,
        kv_pages_allocated,
        kv_pages_spilled,
        kv_fragmentation,
        affinity_hits,
        reuse_tokens_saved,
    }
}

/// [`simulate_stream`] with LIME's interleaved schedule (the policy the
/// scenario matrix's arrival-process axis runs).
pub fn serve_interleaved(
    alloc: &Allocation,
    cluster: &Cluster,
    bw_trace: &BandwidthTrace,
    max_batch: usize,
    opts: &ExecOptions,
    script: &Script,
    requests: &[Request],
) -> StreamResult {
    simulate_stream(
        InterleavedPolicy::new(alloc, cluster, opts),
        cluster,
        bw_trace,
        max_batch,
        &CommonOptions::from(opts),
        script,
        requests,
    )
}

/// [`serve_interleaved`] under an explicit batching policy — the entry
/// point the scenario matrix's v6 batching axis runs (`fifo` cells call
/// it with [`BatchingOpts::fifo`] and stay bit-identical to
/// [`serve_interleaved`]).
#[allow(clippy::too_many_arguments)]
pub fn serve_interleaved_opts(
    alloc: &Allocation,
    cluster: &Cluster,
    bw_trace: &BandwidthTrace,
    max_batch: usize,
    opts: &ExecOptions,
    script: &Script,
    requests: &[Request],
    batching: &BatchingOpts,
) -> StreamResult {
    simulate_stream_opts(
        InterleavedPolicy::new(alloc, cluster, opts),
        cluster,
        bw_trace,
        max_batch,
        &CommonOptions::from(opts),
        script,
        requests,
        batching,
    )
}

/// [`simulate_stream`] with the traditional PP(+offload) schedule.
pub fn serve_traditional(
    alloc: &Allocation,
    cluster: &Cluster,
    bw_trace: &BandwidthTrace,
    max_batch: usize,
    opts: &TradOptions,
    script: &Script,
    requests: &[Request],
) -> StreamResult {
    simulate_stream(
        TraditionalPolicy::new(alloc, cluster, opts),
        cluster,
        bw_trace,
        max_batch,
        &CommonOptions::from(opts),
        script,
        requests,
    )
}

/// [`simulate_stream`] with the tensor-parallel schedule.
pub fn serve_tensor_parallel(
    spec: &ModelSpec,
    cluster: &Cluster,
    bw_trace: &BandwidthTrace,
    max_batch: usize,
    opts: &TpOptions,
    script: &Script,
    requests: &[Request],
) -> StreamResult {
    simulate_stream(
        TensorParallelPolicy::new(spec, cluster, opts),
        cluster,
        bw_trace,
        max_batch,
        &CommonOptions::from(opts),
        script,
        requests,
    )
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::ModelSpec;
    use crate::plan::{plan, PlanOptions};
    use crate::sim::TraceMode;
    use crate::util::bytes::mbps;
    use crate::workload::{stream_requests, Pattern};

    fn setup() -> (Allocation, Cluster) {
        let spec = ModelSpec::llama2_13b();
        let cluster = Cluster::env_e1();
        let opts = PlanOptions {
            empirical_tokens: 128,
            micro_batch: 1,
            bandwidth: mbps(200.0),
        };
        (plan(&spec, &cluster, &opts).unwrap().allocation, cluster)
    }

    fn exec_off() -> ExecOptions {
        ExecOptions {
            trace_mode: TraceMode::Off,
            ..ExecOptions::default()
        }
    }

    #[test]
    fn sporadic_stream_serves_every_request_in_order() {
        let (alloc, cluster) = setup();
        let bw = BandwidthTrace::fixed_mbps(200.0);
        let reqs = stream_requests(Pattern::Sporadic, 3, 6, 0.5, 64, 4);
        let sr = serve_interleaved(&alloc, &cluster, &bw, 1, &exec_off(), &Script::none(), &reqs);
        assert_eq!(sr.requests.len(), 6);
        assert_eq!(sr.tokens_generated, 24);
        assert_eq!(sr.step_times.len(), sr.batches * 4);
        // FIFO on a shared timeline: admissions never move backwards and
        // every request finishes after it was admitted.
        assert!(sr.requests.windows(2).all(|w| w[0].admitted_at <= w[1].admitted_at));
        for r in &sr.requests {
            assert!(r.queueing_delay >= 0.0, "{r:?}");
            assert!(r.ttft >= r.queueing_delay, "{r:?}");
            assert!(r.finish > r.admitted_at, "{r:?}");
            assert!(r.tbt > 0.0, "{r:?}");
        }
        assert!(sr.makespan >= sr.requests.last().unwrap().finish);
        assert!(sr.ms_per_token() > 0.0);
    }

    #[test]
    fn bursty_stream_batches_up_to_max_batch() {
        let (alloc, cluster) = setup();
        let bw = BandwidthTrace::fixed_mbps(200.0);
        let d = cluster.len();
        let reqs = stream_requests(Pattern::Bursty, 3, 2 * d, 0.5, 64, 3);
        let sr = serve_interleaved(&alloc, &cluster, &bw, d, &exec_off(), &Script::none(), &reqs);
        // 2·|D| simultaneous requests at max_batch |D| → exactly 2 batches.
        assert_eq!(sr.batches, 2);
        // The first batch is admitted instantly; the second waits a full
        // batch service time.
        let first = &sr.requests[0];
        let last = sr.requests.last().unwrap();
        assert_eq!(first.queueing_delay, 0.0);
        assert!(last.queueing_delay > 0.0);
        assert!(sr.mean_queueing_delay() > 0.0);
    }

    #[test]
    fn zero_max_batch_clamps_to_one() {
        let (alloc, cluster) = setup();
        let bw = BandwidthTrace::fixed_mbps(200.0);
        let reqs = stream_requests(Pattern::Bursty, 3, 3, 0.5, 64, 2);
        let sr = serve_interleaved(&alloc, &cluster, &bw, 0, &exec_off(), &Script::none(), &reqs);
        assert_eq!(sr.batches, 3);
    }

    #[test]
    fn memory_flat_sink_stream_equals_collected_stream() {
        // The collecting path IS the sink path with a Vec sink, so the
        // pin that matters is retention: a memory-flat run (no step-times
        // vector, fold-as-you-go sink) must agree bit-for-bit on every
        // aggregate and every per-request metric.
        struct Fold {
            n: usize,
            ttft_sum: f64,
            last_finish: f64,
            max_finish: f64,
        }
        impl StreamSink for Fold {
            fn on_request(&mut self, m: &RequestMetrics) {
                self.n += 1;
                self.ttft_sum += m.ttft;
                self.last_finish = m.finish;
                self.max_finish = self.max_finish.max(m.finish);
            }
        }

        let (alloc, cluster) = setup();
        let bw = BandwidthTrace::fixed_mbps(200.0);
        let reqs = stream_requests(Pattern::Sporadic, 9, 8, 0.4, 64, 4);
        let opts = exec_off();
        let collected =
            serve_interleaved(&alloc, &cluster, &bw, 2, &opts, &Script::none(), &reqs);

        let mut fold = Fold {
            n: 0,
            ttft_sum: 0.0,
            last_finish: 0.0,
            max_finish: 0.0,
        };
        let flat = simulate_stream_sink(
            InterleavedPolicy::new(&alloc, &cluster, &opts),
            &cluster,
            &bw,
            2,
            &CommonOptions::from(&opts),
            &Script::none(),
            &reqs,
            &mut fold,
            false,
        );
        assert!(flat.step_times.is_empty(), "memory-flat retains no steps");
        assert_eq!(fold.n, collected.requests.len());
        let ttft_sum: f64 = collected.requests.iter().map(|r| r.ttft).sum();
        assert_eq!(fold.ttft_sum.to_bits(), ttft_sum.to_bits());
        assert_eq!(fold.max_finish.to_bits(), collected.makespan.to_bits());
        assert_eq!(flat.makespan.to_bits(), collected.makespan.to_bits());
        assert_eq!(flat.decode_time.to_bits(), collected.decode_time.to_bits());
        assert_eq!(
            collected.step_times.iter().sum::<f64>().to_bits(),
            collected.decode_time.to_bits(),
            "retained sum must equal the running sum"
        );
        assert_eq!(flat.batches, collected.batches);
        assert_eq!(flat.kv_tokens_transferred, collected.kv_tokens_transferred);
        assert_eq!(flat.emergency_steps, collected.emergency_steps);
        assert_eq!(flat.bw_stalls, collected.bw_stalls);
    }

    #[test]
    fn continuous_driver_smoke_with_pages() {
        let (alloc, cluster) = setup();
        let bw = BandwidthTrace::fixed_mbps(200.0);
        let d = cluster.len();
        let reqs = stream_requests(Pattern::Bursty, 3, 2 * d, 0.5, 64, 3);
        let batching =
            BatchingOpts::continuous(2).with_kv_pages(KvPageConfig::for_alloc(&alloc, 16, 80));
        let sr = serve_interleaved_opts(
            &alloc,
            &cluster,
            &bw,
            d,
            &exec_off(),
            &Script::none(),
            &reqs,
            &batching,
        );
        assert_eq!(sr.requests.len(), 2 * d);
        assert_eq!(sr.tokens_generated, 6 * d);
        assert!(sr.kv_pages_allocated > 0);
        assert!(
            sr.kv_pages_spilled > 0,
            "an 80-token budget must spill under {} 64-token prompts",
            2 * d
        );
        assert!((0.0..=1.0).contains(&sr.kv_fragmentation));
        for r in &sr.requests {
            assert!(r.queueing_delay >= 0.0, "{r:?}");
            assert!(r.finish >= r.admitted_at, "{r:?}");
            assert!(r.ttft >= 0.0, "{r:?}");
        }
    }

    #[test]
    fn baseline_policies_serve_streams_too() {
        let (alloc, cluster) = setup();
        let spec = alloc.spec.clone();
        let bw = BandwidthTrace::fixed_mbps(200.0);
        let reqs = stream_requests(Pattern::Bursty, 3, 4, 0.5, 64, 2);
        let trad = serve_traditional(
            &alloc,
            &cluster,
            &bw,
            2,
            &TradOptions {
                trace_mode: TraceMode::Off,
                ..TradOptions::default()
            },
            &Script::none(),
            &reqs,
        );
        assert_eq!(trad.requests.len(), 4);
        assert_eq!(trad.batches, 2);
        let tp = serve_tensor_parallel(
            &spec,
            &cluster,
            &bw,
            2,
            &TpOptions {
                trace_mode: TraceMode::Off,
                ..TpOptions::default()
            },
            &Script::none(),
            &reqs,
        );
        assert_eq!(tp.requests.len(), 4);
        assert!(tp.ms_per_token() > 0.0);
    }
}
