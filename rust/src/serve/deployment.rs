//! Mapping the offline scheduler onto the *real* TinyLM deployment: a
//! virtual edge cluster whose memory budgets force the same layer-residency
//! decisions LIME makes on Jetson-scale hardware, translated into per-layer
//! [`LayerResidency`] plans for the PJRT engine.

use crate::cluster::{Cluster, DeviceSpec};
use crate::model::ModelSpec;
use crate::plan::allocation::Allocation;
use crate::plan::{plan, PlanError, PlanOptions};
use crate::serve::LayerResidency;
use crate::util::bytes::gib;

/// A virtual cluster of `n` devices, each able to hold about
/// `resident_layers` TinyLM layers beyond the runtime reserve — small
/// enough that the scheduler must offload the remainder.
pub fn virtual_cluster(n: usize, resident_layers: &[usize]) -> Cluster {
    assert_eq!(n, resident_layers.len());
    let spec = ModelSpec::tiny_lm();
    let devices = resident_layers
        .iter()
        .enumerate()
        .map(|(i, &k)| {
            // usable_mem subtracts max(18%, 1.2 GiB); pick total memory so
            // usable ≈ k layers + embed share + KV slack.
            let embed = spec.embed_bytes() / 2; // this device's share
            let slack = spec.layer_bytes() / 4; // KV room only
            let usable = k as u64 * spec.layer_bytes() + embed + slack;
            let mem = usable + gib(1.2).max((usable as f64 * 0.22) as u64);
            DeviceSpec {
                name: format!("virt{i}"),
                mem_bytes: mem,
                flops: 1e11,
                mem_bw: 10e9,
                ssd_read_bps: 0.5e9,
                ssd_write_bps: 0.2e9,
            }
        })
        .collect();
    Cluster::new(devices)
}

/// Plan TinyLM over the virtual cluster.
pub fn plan_tiny(cluster: &Cluster, tokens: usize) -> Result<Allocation, PlanError> {
    let spec = ModelSpec::tiny_lm();
    let opts = PlanOptions {
        empirical_tokens: tokens,
        micro_batch: 1,
        bandwidth: crate::util::bytes::mbps(200.0),
    };
    plan(&spec, cluster, &opts).map(|r| r.allocation)
}

/// Translate an allocation into a per-layer residency plan. Within each
/// device's contiguous range the offloaded layers are placed *last* (the
/// deepest layers of the device's slice stream from SSD).
pub fn residency_plan(alloc: &Allocation) -> Vec<LayerResidency> {
    let mut out = Vec::with_capacity(alloc.spec.layers);
    for a in &alloc.devices {
        let resident = a.non_offloaded_layers();
        for _ in 0..resident {
            out.push(LayerResidency::Resident);
        }
        for _ in 0..a.mha_offload {
            out.push(LayerResidency::MhaOffload);
        }
        for _ in 0..a.mlp_offload {
            out.push(LayerResidency::MlpOffload);
        }
        for _ in 0..a.full_offload {
            out.push(LayerResidency::FullOffload);
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn tight_cluster_forces_offload() {
        let cluster = virtual_cluster(4, &[1, 1, 1, 1]);
        let alloc = plan_tiny(&cluster, 64).unwrap();
        assert!(alloc.covers_model());
        let offloaded: usize = alloc.devices.iter().map(|d| d.offloaded_count()).sum();
        assert!(offloaded > 0, "{}", alloc.describe());
        let plan = residency_plan(&alloc);
        assert_eq!(plan.len(), 8);
        assert!(plan.iter().any(|r| *r != LayerResidency::Resident));
    }

    #[test]
    fn roomy_cluster_stays_resident() {
        let cluster = virtual_cluster(2, &[8, 8]);
        let alloc = plan_tiny(&cluster, 64).unwrap();
        let plan = residency_plan(&alloc);
        assert!(plan.iter().all(|r| *r == LayerResidency::Resident));
    }

    #[test]
    fn plan_length_always_matches_layers() {
        for spec in [&[2usize, 2, 2, 2][..], &[1, 3][..], &[4, 2, 1][..]] {
            let cluster = virtual_cluster(spec.len(), spec);
            if let Ok(alloc) = plan_tiny(&cluster, 64) {
                assert_eq!(residency_plan(&alloc).len(), 8);
            }
        }
    }
}
