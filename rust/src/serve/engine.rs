//! The real generation engine: TinyLM flowing through PJRT executables with
//! Rust-owned KV caches and weight residency.
//!
//! The engine emulates LIME's distributed deployment in-process: layers are
//! assigned to virtual edge devices by the offline scheduler; offloaded
//! layers *really* stream from SSD blobs on every use; split layers run
//! through the separate `mha_decode`/`mlp_decode` artifacts (the
//! fine-grained path). Losslessness — the paper's core property — is
//! checked by comparing generated tokens and final logits against a fully
//! resident run: both paths execute the same HLO with the same weights, so
//! they must agree bit-for-bit.

use anyhow::{anyhow, Result};

use crate::metrics::Counters;
use crate::runtime::{
    argmax_logits, literal_from_f32, literal_from_i32, literal_scalar_i32, Manifest, PjrtRuntime,
    WeightStore,
};
pub use crate::serve::LayerResidency;

/// The engine.
pub struct Engine {
    pub runtime: PjrtRuntime,
    pub weights: WeightStore,
    residency: Vec<LayerResidency>,
    /// KV caches per layer as ready-to-feed Literals of shape
    /// [1, S, KVH, hd] — kept in PJRT form between steps so the hot path
    /// never round-trips through host Vec<f32> (§Perf).
    k_cache: Vec<xla::Literal>,
    v_cache: Vec<xla::Literal>,
    pub counters: Counters,
}

/// Output of one generation call.
#[derive(Debug, Clone, PartialEq)]
pub struct Generation {
    pub tokens: Vec<i32>,
    /// Final-step logits (for losslessness comparison).
    pub final_logits: Vec<f32>,
}

impl Engine {
    pub fn new(manifest: Manifest) -> Result<Engine> {
        let runtime = PjrtRuntime::load(&manifest)?;
        let cfg = manifest.model.clone();
        let weights = WeightStore::new(manifest);
        let zero = Self::zero_cache(&cfg)?;
        Ok(Engine {
            runtime,
            weights,
            residency: vec![LayerResidency::Resident; cfg.layers],
            k_cache: (0..cfg.layers).map(|_| zero.clone()).collect(),
            v_cache: (0..cfg.layers).map(|_| zero.clone()).collect(),
            counters: Counters::default(),
        })
    }

    fn zero_cache(cfg: &crate::runtime::ModelConfig) -> Result<xla::Literal> {
        let elems = cfg.max_seq * cfg.kv_heads * cfg.head_dim;
        literal_from_f32(
            &vec![0.0f32; elems],
            &[1, cfg.max_seq, cfg.kv_heads, cfg.head_dim],
        )
    }

    pub fn model(&self) -> &crate::runtime::ModelConfig {
        &self.weights.manifest().model
    }

    /// Apply a residency plan (from the offline scheduler or online planner).
    pub fn set_residency(&mut self, plan: &[LayerResidency]) -> Result<()> {
        if plan.len() != self.residency.len() {
            return Err(anyhow!(
                "plan covers {} layers, model has {}",
                plan.len(),
                self.residency.len()
            ));
        }
        for (li, &r) in plan.iter().enumerate() {
            let (mha_off, mlp_off) = match r {
                LayerResidency::Resident => (false, false),
                LayerResidency::FullOffload => (true, true),
                LayerResidency::MhaOffload => (true, false),
                LayerResidency::MlpOffload => (false, true),
            };
            self.weights.apply_layer_residency(li, mha_off, mlp_off)?;
        }
        self.residency = plan.to_vec();
        Ok(())
    }

    pub fn residency(&self) -> &[LayerResidency] {
        &self.residency
    }

    /// Reset KV caches between requests.
    pub fn reset(&mut self) {
        let cfg = self.model().clone();
        let zero = Self::zero_cache(&cfg).expect("zero cache");
        for c in self.k_cache.iter_mut().chain(self.v_cache.iter_mut()) {
            *c = zero.clone();
        }
    }

    fn layer_weight_literals(&mut self, li: usize, names: &[String]) -> Result<Vec<xla::Literal>> {
        names
            .iter()
            .map(|w| self.weights.get(&format!("layer{li}.{w}")))
            .collect()
    }

    /// Run prefill over `prompt` (must be exactly `prefill_len` tokens —
    /// the fixed-length paradigm the paper adopts from EdgeShard).
    pub fn prefill(&mut self, prompt: &[i32]) -> Result<xla::Literal> {
        let cfg = self.model().clone();
        if prompt.len() != cfg.prefill_len {
            return Err(anyhow!(
                "prompt must be exactly {} tokens, got {}",
                cfg.prefill_len,
                prompt.len()
            ));
        }
        self.counters.prefills += 1;
        let tokens = literal_from_i32(prompt, &[1, cfg.prefill_len])?;
        let table = self.weights.get("embed")?;
        let mut x = self
            .runtime
            .execute("embed_prefill", &[tokens, table])?
            .remove(0);

        let names = self.weights.manifest().layer_weight_names.clone();
        let row = cfg.kv_heads * cfg.head_dim;
        let cache_shape = [1usize, cfg.max_seq, cfg.kv_heads, cfg.head_dim];
        for li in 0..cfg.layers {
            let mut params = vec![x];
            params.extend(self.layer_weight_literals(li, &names)?);
            let mut out = self.runtime.execute("layer_prefill", &params)?;
            // out = (y, k [1,P,KVH,hd], v [1,P,KVH,hd])
            x = out.remove(0);
            let k: Vec<f32> = out.remove(0).to_vec()?;
            let v: Vec<f32> = out.remove(0).to_vec()?;
            let mut kc = vec![0.0f32; cfg.max_seq * row];
            let mut vc = vec![0.0f32; cfg.max_seq * row];
            kc[..cfg.prefill_len * row].copy_from_slice(&k);
            vc[..cfg.prefill_len * row].copy_from_slice(&v);
            self.k_cache[li] = literal_from_f32(&kc, &cache_shape)?;
            self.v_cache[li] = literal_from_f32(&vc, &cache_shape)?;
        }
        // Last position's hidden state feeds the first lm_head call.
        let all: Vec<f32> = x.to_vec()?;
        let h = cfg.hidden;
        let last = &all[(cfg.prefill_len - 1) * h..];
        literal_from_f32(last, &[1, 1, h])
    }

    /// One decode step at position `pos`; returns the next-token logits.
    ///
    /// Hot path (§Perf): KV caches stay as Literals between steps, resident
    /// weights are borrowed from the warmed cache (`execute_ref`) so nothing
    /// larger than the activation is copied per layer; only offloaded
    /// weights are re-materialized (deliberately — that is the streamed
    /// cost LIME schedules).
    pub fn decode_step(&mut self, x: xla::Literal, pos: usize) -> Result<(xla::Literal, xla::Literal)> {
        let cfg = self.model().clone();
        let names = self.weights.manifest().layer_weight_names.clone();
        let attn_names = self.weights.manifest().attn_weight_names.clone();
        let mlp_names = self.weights.manifest().mlp_weight_names.clone();
        let pos_lit = literal_scalar_i32(pos as i32);

        let mut x = x;
        for li in 0..cfg.layers {
            let (artifact_names, fused): (&[String], bool) = match self.residency[li] {
                LayerResidency::Resident | LayerResidency::FullOffload => (&names, true),
                _ => (&attn_names, false),
            };
            if self.residency[li] != LayerResidency::Resident {
                self.counters.layer_loads += 1;
            }
            // Warm resident weights; materialize offloaded ones as temps.
            let mut temps: Vec<(usize, xla::Literal)> = Vec::new();
            for (wi, w) in artifact_names.iter().enumerate() {
                let key = format!("layer{li}.{w}");
                self.weights.ensure_cached(&key)?;
                if self.weights.peek(&key).is_none() {
                    temps.push((wi, self.weights.get(&key)?));
                }
            }
            let mut params: Vec<&xla::Literal> =
                vec![&x, &self.k_cache[li], &self.v_cache[li], &pos_lit];
            let mut temp_it = temps.iter().peekable();
            for (wi, w) in artifact_names.iter().enumerate() {
                if let Some((ti, t)) = temp_it.peek() {
                    if *ti == wi {
                        params.push(t);
                        temp_it.next();
                        continue;
                    }
                }
                let key = format!("layer{li}.{w}");
                params.push(self.weights.peek(&key).expect("warmed resident weight"));
            }
            let artifact = if fused { "layer_decode" } else { "mha_decode" };
            let mut out = self.runtime.execute_ref(artifact, &params)?;
            let y = out.remove(0);
            self.k_cache[li] = out.remove(0);
            self.v_cache[li] = out.remove(0);
            if fused {
                x = y;
            } else {
                // Fine-grained path: the MLP block runs separately.
                let mut temps: Vec<(usize, xla::Literal)> = Vec::new();
                for (wi, w) in mlp_names.iter().enumerate() {
                    let key = format!("layer{li}.{w}");
                    self.weights.ensure_cached(&key)?;
                    if self.weights.peek(&key).is_none() {
                        temps.push((wi, self.weights.get(&key)?));
                    }
                }
                let mut params: Vec<&xla::Literal> = vec![&y];
                let mut temp_it = temps.iter().peekable();
                for (wi, w) in mlp_names.iter().enumerate() {
                    if let Some((ti, t)) = temp_it.peek() {
                        if *ti == wi {
                            params.push(t);
                            temp_it.next();
                            continue;
                        }
                    }
                    let key = format!("layer{li}.{w}");
                    params.push(self.weights.peek(&key).expect("warmed resident weight"));
                }
                x = self.runtime.execute_ref("mlp_decode", &params)?.remove(0);
            }
        }
        self.weights.ensure_cached("ln_f")?;
        self.weights.ensure_cached("lm_head")?;
        let params: Vec<&xla::Literal> = vec![
            &x,
            self.weights.peek("ln_f").expect("ln_f resident"),
            self.weights.peek("lm_head").expect("lm_head resident"),
        ];
        let logits = self.runtime.execute_ref("lm_head", &params)?.remove(0);
        Ok((x, logits))
    }

    /// Greedy generation: prefill + `steps` decode steps.
    pub fn generate(&mut self, prompt: &[i32], steps: usize) -> Result<Generation> {
        let cfg = self.model().clone();
        self.reset();
        self.counters.requests += 1;
        let x_last = self.prefill(prompt)?;
        let (_, mut logits) = {
            // The first decode position processes the last prompt hidden
            // state through lm_head only (prefill already ran the layers).
            let l = self
                .runtime
                .execute(
                    "lm_head",
                    &[
                        x_last,
                        self.weights.get("ln_f")?,
                        self.weights.get("lm_head")?,
                    ],
                )?
                .remove(0);
            (0, l)
        };

        let table = self.weights.get("embed")?;
        let mut tokens = Vec::with_capacity(steps);
        let mut final_logits: Vec<f32> = logits.to_vec()?;
        for step in 0..steps {
            let tok = argmax_logits(&logits)?;
            tokens.push(tok);
            self.counters.tokens_generated += 1;
            let pos = cfg.prefill_len + step;
            if pos >= cfg.max_seq {
                return Err(anyhow!("exceeded max_seq {}", cfg.max_seq));
            }
            let ids = literal_from_i32(&[tok], &[1, 1])?;
            let x = self
                .runtime
                .execute("embed_decode", &[ids, table.clone()])?
                .remove(0);
            let (_, l) = self.decode_step(x, pos)?;
            logits = l;
            final_logits = logits.to_vec()?;
        }
        Ok(Generation {
            tokens,
            final_logits,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::workload::synthetic_prompt;
    use std::path::PathBuf;

    fn artifacts_dir() -> PathBuf {
        PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts")
    }

    fn engine() -> Option<Engine> {
        if !artifacts_dir().join("manifest.json").exists() {
            eprintln!("skipping: run `make artifacts`");
            return None;
        }
        Some(Engine::new(Manifest::load(artifacts_dir()).unwrap()).unwrap())
    }

    #[test]
    fn generates_deterministically() {
        let Some(mut e) = engine() else { return };
        let prompt = synthetic_prompt(7, e.model().prefill_len, e.model().vocab);
        let a = e.generate(&prompt, 4).unwrap();
        let b = e.generate(&prompt, 4).unwrap();
        assert_eq!(a, b);
        assert_eq!(a.tokens.len(), 4);
        assert!(a.tokens.iter().all(|&t| (t as usize) < e.model().vocab));
    }

    #[test]
    fn offload_is_lossless() {
        // The paper's core claim, verified on real numerics: streaming
        // weights from SSD (full layers AND split blocks) yields exactly
        // the tokens and logits of the fully resident model.
        let Some(mut e) = engine() else { return };
        let prompt = synthetic_prompt(3, e.model().prefill_len, e.model().vocab);
        let resident = e.generate(&prompt, 4).unwrap();

        let layers = e.model().layers;
        let mut plan = vec![LayerResidency::Resident; layers];
        plan[1] = LayerResidency::FullOffload;
        plan[2] = LayerResidency::MhaOffload;
        plan[3] = LayerResidency::MlpOffload;
        e.set_residency(&plan).unwrap();
        let offloaded = e.generate(&prompt, 4).unwrap();

        assert_eq!(resident.tokens, offloaded.tokens, "token mismatch");
        assert_eq!(
            resident.final_logits, offloaded.final_logits,
            "logit mismatch: offload path is not lossless"
        );
        assert!(e.weights.loads_from_disk() > 0, "offload path never hit SSD");
    }

    #[test]
    fn different_prompts_different_outputs() {
        let Some(mut e) = engine() else { return };
        let p1 = synthetic_prompt(1, e.model().prefill_len, e.model().vocab);
        let p2 = synthetic_prompt(2, e.model().prefill_len, e.model().vocab);
        let a = e.generate(&p1, 4).unwrap();
        let b = e.generate(&p2, 4).unwrap();
        assert_ne!(a.final_logits, b.final_logits);
    }

    #[test]
    fn rejects_wrong_prompt_length() {
        let Some(mut e) = engine() else { return };
        assert!(e.generate(&[1, 2, 3], 2).is_err());
    }
}
