//! Edge-device descriptions (paper Tab. II) and the rates the simulator and
//! cost model consume.
//!
//! Compute rates are *effective* decode throughput, calibrated from the
//! boards' relative AI performance (Tab. II: 21 / 200 / 275 TOPS) with a
//! memory-bound derating: autoregressive decode is dominated by weight
//! streaming, so effective FLOP/s is far below peak TOPS. Absolute scale only
//! multiplies every latency; *ratios* between devices (what the allocation
//! algorithms act on) follow Tab. II.

use crate::util::bytes::{gib, GIB};

/// One edge device.
#[derive(Debug, Clone, PartialEq)]
pub struct DeviceSpec {
    pub name: String,
    /// GPU-visible memory capacity in bytes (`Mem_i`).
    pub mem_bytes: u64,
    /// Effective compute rate in FLOP/s for decode-shaped matmuls.
    pub flops: f64,
    /// Unified-memory bandwidth, bytes/s. Autoregressive decode streams
    /// every resident weight byte once per token, so this — not TOPS —
    /// bounds decode latency (roofline in `cost::comp_time`).
    pub mem_bw: f64,
    /// SSD sequential read bandwidth, bytes/s (model-shard loads).
    pub ssd_read_bps: f64,
    /// SSD write bandwidth, bytes/s (KV-cache offload writes; slower and
    /// jittery on Jetson-class NVMe — drives Fig. 2b).
    pub ssd_write_bps: f64,
}

impl DeviceSpec {
    /// Jetson Xavier NX 16 GB: 21 TOPS, 20 W, LPDDR4x ~59.7 GB/s.
    pub fn xavier_nx_16() -> Self {
        DeviceSpec {
            name: "XavierNX-16G".into(),
            mem_bytes: gib(16.0),
            flops: 0.9e12,
            mem_bw: 48e9, // ~80% of the 59.7 GB/s spec is realizable
            ssd_read_bps: 1.2e9,
            ssd_write_bps: 0.35e9,
        }
    }

    /// Jetson AGX Orin 32 GB: 200 TOPS, 50 W, LPDDR5 ~204.8 GB/s.
    pub fn agx_orin_32() -> Self {
        DeviceSpec {
            name: "AGXOrin-32G".into(),
            mem_bytes: gib(32.0),
            flops: 6.5e12,
            mem_bw: 160e9,
            ssd_read_bps: 2.2e9,
            ssd_write_bps: 0.7e9,
        }
    }

    /// Jetson AGX Orin 64 GB: 275 TOPS, 60 W, LPDDR5 ~204.8 GB/s.
    pub fn agx_orin_64() -> Self {
        DeviceSpec {
            name: "AGXOrin-64G".into(),
            mem_bytes: gib(64.0),
            flops: 8.5e12,
            mem_bw: 170e9,
            ssd_read_bps: 2.5e9,
            ssd_write_bps: 0.8e9,
        }
    }

    pub fn by_name(name: &str) -> Option<Self> {
        match name.to_ascii_lowercase().as_str() {
            "xavier-nx-16" | "xaviernx-16g" | "nx16" => Some(Self::xavier_nx_16()),
            "agx-orin-32" | "agxorin-32g" | "orin32" => Some(Self::agx_orin_32()),
            "agx-orin-64" | "agxorin-64g" | "orin64" => Some(Self::agx_orin_64()),
            _ => None,
        }
    }

    /// Restrict usable memory (Figs 15–17: half an NX, Orin32 − 8 GB).
    pub fn with_mem_limit(mut self, mem_bytes: u64) -> Self {
        assert!(mem_bytes > 0);
        self.name = format!(
            "{}@{:.0}G",
            self.name,
            mem_bytes as f64 / GIB as f64
        );
        self.mem_bytes = mem_bytes;
        self
    }

    /// Memory reserved for runtime/framework overhead before layers and KV
    /// cache are placed. Jetson memory is *unified*: the OS, CUDA context,
    /// activations and allocator fragmentation all bite from the same pool,
    /// so the reserve is substantial (~18%, floor 1.2 GiB).
    pub fn usable_mem(&self) -> u64 {
        let reserve = (self.mem_bytes as f64 * 0.18) as u64;
        self.mem_bytes
            .saturating_sub(reserve.max((1.2 * GIB as f64) as u64))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn presets_match_table_ii_memory() {
        assert_eq!(DeviceSpec::xavier_nx_16().mem_bytes, gib(16.0));
        assert_eq!(DeviceSpec::agx_orin_32().mem_bytes, gib(32.0));
        assert_eq!(DeviceSpec::agx_orin_64().mem_bytes, gib(64.0));
    }

    #[test]
    fn compute_ordering_follows_tops() {
        let nx = DeviceSpec::xavier_nx_16();
        let o32 = DeviceSpec::agx_orin_32();
        let o64 = DeviceSpec::agx_orin_64();
        assert!(nx.flops < o32.flops && o32.flops < o64.flops);
        // Tab. II ratio Orin64:NX = 275:21 ≈ 13; our effective ratio is
        // compressed by the memory-bound derating but stays > 5x.
        assert!(o64.flops / nx.flops > 5.0);
    }

    #[test]
    fn writes_slower_than_reads() {
        for d in [
            DeviceSpec::xavier_nx_16(),
            DeviceSpec::agx_orin_32(),
            DeviceSpec::agx_orin_64(),
        ] {
            assert!(d.ssd_write_bps < d.ssd_read_bps);
        }
    }

    #[test]
    fn mem_limit_restricts() {
        let d = DeviceSpec::xavier_nx_16().with_mem_limit(gib(8.0));
        assert_eq!(d.mem_bytes, gib(8.0));
        assert!(d.name.contains("8G"));
    }

    #[test]
    fn usable_mem_below_capacity() {
        let d = DeviceSpec::agx_orin_64();
        assert!(d.usable_mem() < d.mem_bytes);
        assert!(d.usable_mem() > d.mem_bytes / 2);
    }

    #[test]
    fn by_name_lookup() {
        assert!(DeviceSpec::by_name("nx16").is_some());
        assert!(DeviceSpec::by_name("agx-orin-64").is_some());
        assert!(DeviceSpec::by_name("h100").is_none());
    }
}
