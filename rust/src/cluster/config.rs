//! Config-file cluster definitions: describe a deployment in TOML
//! (`configs/*.toml`) instead of code — the launcher path a downstream
//! user actually touches.
//!
//! ```toml
//! name = "my-edge-rack"
//! model = "llama3.3-70b"
//! bandwidth_mbps = 200.0
//!
//! [[device]]
//! kind = "agx-orin-64"
//!
//! [[device]]
//! kind = "xavier-nx-16"
//! mem_gb = 8            # optional cap (lowmem experiments)
//! ssd_read_gbps = 1.0   # optional overrides
//! ```

use anyhow::{anyhow, Context, Result};

use crate::cluster::{Cluster, DeviceSpec};
use crate::model::ModelSpec;
use crate::util::bytes::{gib, mbps};
use crate::util::toml::Document;

/// A full deployment description parsed from TOML.
#[derive(Debug, Clone)]
pub struct Deployment {
    pub name: String,
    pub model: ModelSpec,
    pub cluster: Cluster,
    /// Planner/simulator bandwidth, bytes/s.
    pub bandwidth: f64,
}

impl Deployment {
    pub fn parse(src: &str) -> Result<Deployment> {
        let doc = Document::parse(src).map_err(|e| anyhow!("{e}"))?;
        let name = doc
            .get("", "name")
            .and_then(|v| v.as_str())
            .unwrap_or("unnamed")
            .to_string();
        let model_name = doc
            .get("", "model")
            .and_then(|v| v.as_str())
            .ok_or_else(|| anyhow!("config missing top-level `model = \"...\"`"))?;
        let model = ModelSpec::by_name(model_name)
            .ok_or_else(|| anyhow!("unknown model preset '{model_name}'"))?;
        let bandwidth = mbps(
            doc.get("", "bandwidth_mbps")
                .and_then(|v| v.as_f64())
                .unwrap_or(200.0),
        );

        let entries = doc
            .table_arrays
            .get("device")
            .ok_or_else(|| anyhow!("config needs at least one [[device]]"))?;
        let mut devices = Vec::new();
        for (i, t) in entries.iter().enumerate() {
            let kind = t
                .get("kind")
                .and_then(|v| v.as_str())
                .ok_or_else(|| anyhow!("device #{i} missing `kind`"))?;
            let mut dev = DeviceSpec::by_name(kind)
                .ok_or_else(|| anyhow!("device #{i}: unknown kind '{kind}'"))?;
            if let Some(mem_gb) = t.get("mem_gb").and_then(|v| v.as_f64()) {
                if mem_gb <= 0.0 {
                    return Err(anyhow!("device #{i}: mem_gb must be positive"));
                }
                dev = dev.with_mem_limit(gib(mem_gb));
            }
            if let Some(r) = t.get("ssd_read_gbps").and_then(|v| v.as_f64()) {
                dev.ssd_read_bps = r * 1e9;
            }
            if let Some(w) = t.get("ssd_write_gbps").and_then(|v| v.as_f64()) {
                dev.ssd_write_bps = w * 1e9;
            }
            devices.push(dev);
        }
        Ok(Deployment {
            name,
            model,
            cluster: Cluster::new(devices),
            bandwidth,
        })
    }

    pub fn load(path: &str) -> Result<Deployment> {
        let src = std::fs::read_to_string(path).with_context(|| format!("reading {path}"))?;
        Self::parse(&src).with_context(|| format!("parsing {path}"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = r#"
name = "e3-like"
model = "llama3.3-70b"
bandwidth_mbps = 100.0

[[device]]
kind = "agx-orin-64"

[[device]]
kind = "agx-orin-64"

[[device]]
kind = "agx-orin-32"
mem_gb = 24

[[device]]
kind = "xavier-nx-16"
ssd_read_gbps = 0.9
"#;

    #[test]
    fn parses_full_deployment() {
        let d = Deployment::parse(SAMPLE).unwrap();
        assert_eq!(d.name, "e3-like");
        assert_eq!(d.model.layers, 80);
        assert_eq!(d.cluster.len(), 4);
        assert_eq!(d.cluster.devices[2].mem_bytes, gib(24.0));
        assert!((d.cluster.devices[3].ssd_read_bps - 0.9e9).abs() < 1.0);
        assert!((d.bandwidth - mbps(100.0)).abs() < 1.0);
    }

    #[test]
    fn rejects_unknown_model() {
        let src = SAMPLE.replace("llama3.3-70b", "gpt-5");
        assert!(Deployment::parse(&src).is_err());
    }

    #[test]
    fn rejects_missing_devices() {
        assert!(Deployment::parse("model = \"tiny\"\n").is_err());
    }

    #[test]
    fn rejects_bad_mem() {
        let src = format!("{SAMPLE}\n[[device]]\nkind = \"xavier-nx-16\"\nmem_gb = -1\n");
        assert!(Deployment::parse(&src).is_err());
    }

    #[test]
    fn config_feeds_the_planner() {
        let d = Deployment::parse(SAMPLE).unwrap();
        let opts = crate::plan::PlanOptions {
            empirical_tokens: 128,
            micro_batch: 1,
            bandwidth: d.bandwidth,
        };
        let report = crate::plan::plan(&d.model, &d.cluster, &opts).unwrap();
        assert!(report.allocation.covers_model());
    }
}
