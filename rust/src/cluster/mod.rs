//! Cluster = heterogeneous device set + shared network, with the experiment
//! environments from paper Tab. IV and §V-C Settings 1–3 as constructors.

pub mod config;
pub mod device;

pub use config::Deployment;
pub use device::DeviceSpec;

use crate::util::bytes::gib;

/// A set of edge devices cooperating over one shared network.
#[derive(Debug, Clone)]
pub struct Cluster {
    pub devices: Vec<DeviceSpec>,
}

impl Cluster {
    pub fn new(devices: Vec<DeviceSpec>) -> Self {
        assert!(!devices.is_empty(), "cluster needs at least one device");
        Cluster { devices }
    }

    pub fn len(&self) -> usize {
        self.devices.len()
    }

    pub fn is_empty(&self) -> bool {
        self.devices.is_empty()
    }

    /// Total usable memory across devices.
    pub fn total_usable_mem(&self) -> u64 {
        self.devices.iter().map(|d| d.usable_mem()).sum()
    }

    /// A sub-cluster keeping `indices` (in the given order) — the
    /// cluster-size sweep axis carves 2/3/4-device subsets of the
    /// heterogeneous environments with this.
    ///
    /// Panics on an empty or out-of-range selection (axis definitions are
    /// static data; a bad index is a bug, not an input error).
    pub fn subset(&self, indices: &[usize]) -> Cluster {
        assert!(!indices.is_empty(), "subset needs at least one device");
        Cluster::new(
            indices
                .iter()
                .map(|&i| {
                    assert!(i < self.devices.len(), "device index {i} out of range");
                    self.devices[i].clone()
                })
                .collect(),
        )
    }

    /// Device names, for artifact metadata.
    pub fn device_names(&self) -> Vec<String> {
        self.devices.iter().map(|d| d.name.clone()).collect()
    }

    // ------------------------- paper environments (Tab. IV) -------------

    /// E1: 1x Xavier NX 16 GB + 1x AGX Orin 32 GB (Llama2-13B).
    pub fn env_e1() -> Self {
        Cluster::new(vec![
            DeviceSpec::agx_orin_32(),
            DeviceSpec::xavier_nx_16(),
        ])
    }

    /// E2: NX16 + Orin32 + Orin64 (Qwen3-32B).
    pub fn env_e2() -> Self {
        Cluster::new(vec![
            DeviceSpec::agx_orin_64(),
            DeviceSpec::agx_orin_32(),
            DeviceSpec::xavier_nx_16(),
        ])
    }

    /// E3: NX16 + Orin32 + 2x Orin64 (Llama3.3-70B).
    pub fn env_e3() -> Self {
        Cluster::new(vec![
            DeviceSpec::agx_orin_64(),
            DeviceSpec::agx_orin_64(),
            DeviceSpec::agx_orin_32(),
            DeviceSpec::xavier_nx_16(),
        ])
    }

    // ----------------- extremely-low-memory settings (§V-C) -------------

    /// Setting 1: Orin64 + 2x Orin32 + 2x NX16.
    pub fn lowmem_setting1() -> Self {
        Cluster::new(vec![
            DeviceSpec::agx_orin_64(),
            DeviceSpec::agx_orin_32(),
            DeviceSpec::agx_orin_32(),
            DeviceSpec::xavier_nx_16(),
            DeviceSpec::xavier_nx_16(),
        ])
    }

    /// Setting 2: Setting 1 with one NX16 limited to half its memory.
    pub fn lowmem_setting2() -> Self {
        Cluster::new(vec![
            DeviceSpec::agx_orin_64(),
            DeviceSpec::agx_orin_32(),
            DeviceSpec::agx_orin_32(),
            DeviceSpec::xavier_nx_16(),
            DeviceSpec::xavier_nx_16().with_mem_limit(gib(8.0)),
        ])
    }

    /// Setting 3: Setting 2 with 8 GB made unavailable on one Orin32.
    pub fn lowmem_setting3() -> Self {
        Cluster::new(vec![
            DeviceSpec::agx_orin_64(),
            DeviceSpec::agx_orin_32(),
            DeviceSpec::agx_orin_32().with_mem_limit(gib(24.0)),
            DeviceSpec::xavier_nx_16(),
            DeviceSpec::xavier_nx_16().with_mem_limit(gib(8.0)),
        ])
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn env_sizes_match_table_iv() {
        assert_eq!(Cluster::env_e1().len(), 2);
        assert_eq!(Cluster::env_e2().len(), 3);
        assert_eq!(Cluster::env_e3().len(), 4);
    }

    #[test]
    fn lowmem_settings_shrink_monotonically() {
        let m1 = Cluster::lowmem_setting1().total_usable_mem();
        let m2 = Cluster::lowmem_setting2().total_usable_mem();
        let m3 = Cluster::lowmem_setting3().total_usable_mem();
        assert!(m1 > m2 && m2 > m3);
    }

    #[test]
    fn e3_fits_llama70b_marginally() {
        // Tab. IV pairs E3 (64+64+32+16 = 176 GB raw) with the ~140 GiB
        // Llama3.3-70B: feasible only with most memory spent on weights —
        // exactly the regime LIME targets.
        use crate::model::ModelSpec;
        let c = Cluster::env_e3();
        let spec = ModelSpec::llama33_70b();
        assert!(c.total_usable_mem() > spec.total_bytes());
        let slack = c.total_usable_mem() - spec.total_bytes();
        assert!((slack as f64) < 0.35 * c.total_usable_mem() as f64);
    }

    #[test]
    #[should_panic]
    fn empty_cluster_panics() {
        Cluster::new(vec![]);
    }

    #[test]
    fn subset_keeps_selected_devices_in_order() {
        let e3 = Cluster::env_e3();
        let sub = e3.subset(&[0, 2]);
        assert_eq!(sub.len(), 2);
        assert_eq!(sub.devices[0].name, e3.devices[0].name);
        assert_eq!(sub.devices[1].name, e3.devices[2].name);
        assert_eq!(
            e3.subset(&[0, 1, 2, 3]).device_names(),
            e3.device_names()
        );
    }

    #[test]
    #[should_panic]
    fn subset_rejects_out_of_range() {
        Cluster::env_e1().subset(&[0, 5]);
    }
}
