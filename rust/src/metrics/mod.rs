//! Metrics: latency recorders and throughput counters for the serving
//! engine and experiment harness.

pub mod recorder;

pub use recorder::{LatencyRecorder, Counters};
