//! Latency/throughput recording for the real serving path.

use std::time::Instant;

use crate::util::stats::{summarize, Summary};

/// Records per-token latencies and derives serving metrics.
#[derive(Debug, Default)]
pub struct LatencyRecorder {
    samples: Vec<f64>,
    started: Option<Instant>,
}

impl LatencyRecorder {
    pub fn new() -> Self {
        Self::default()
    }

    /// Mark the start of a timed region.
    pub fn start(&mut self) {
        self.started = Some(Instant::now());
    }

    /// Record the elapsed time since `start` as one sample (seconds).
    pub fn lap(&mut self) -> f64 {
        let t = self
            .started
            .expect("lap() without start()")
            .elapsed()
            .as_secs_f64();
        self.samples.push(t);
        self.started = Some(Instant::now());
        t
    }

    /// Record an externally-measured sample.
    pub fn record(&mut self, secs: f64) {
        self.samples.push(secs);
    }

    pub fn len(&self) -> usize {
        self.samples.len()
    }

    pub fn is_empty(&self) -> bool {
        self.samples.is_empty()
    }

    pub fn summary(&self) -> Summary {
        summarize(&self.samples)
    }

    /// Tokens per second over all recorded samples.
    pub fn throughput(&self) -> f64 {
        let total: f64 = self.samples.iter().sum();
        if total <= 0.0 {
            0.0
        } else {
            self.samples.len() as f64 / total
        }
    }
}

/// Simple named counters.
#[derive(Debug, Default)]
pub struct Counters {
    pub requests: u64,
    pub tokens_generated: u64,
    pub prefills: u64,
    pub layer_loads: u64,
    pub kv_transfers: u64,
    pub online_plans: u64,
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn record_and_summarize() {
        let mut r = LatencyRecorder::new();
        r.record(0.1);
        r.record(0.2);
        r.record(0.3);
        let s = r.summary();
        assert_eq!(s.n, 3);
        assert!((s.mean - 0.2).abs() < 1e-12);
        assert!((r.throughput() - 5.0).abs() < 1e-9);
    }

    #[test]
    fn lap_measures_time() {
        let mut r = LatencyRecorder::new();
        r.start();
        std::thread::sleep(std::time::Duration::from_millis(5));
        let t = r.lap();
        assert!(t >= 0.004, "lap {t}");
        assert_eq!(r.len(), 1);
    }

    #[test]
    fn empty_throughput_zero() {
        let r = LatencyRecorder::new();
        assert_eq!(r.throughput(), 0.0);
        assert!(r.is_empty());
    }
}
