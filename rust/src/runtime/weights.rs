//! Weight store with *real* offloading: the Rust coordinator owns weight
//! residency. Resident tensors are cached as PJRT-ready Literals; offloaded
//! tensors live only in their SSD blob and are re-read (real file I/O) every
//! time the layer streams through — exactly the cost LIME schedules around.

use std::collections::BTreeMap;

use anyhow::{anyhow, Result};

use crate::runtime::manifest::Manifest;
use crate::runtime::pjrt::literal_from_f32_file;

/// Residency state of one weight tensor.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Residency {
    /// Pinned in memory (the simulated device's "GPU").
    Resident,
    /// On SSD only; every access re-reads the blob.
    Offloaded,
}

/// Per-tensor entry.
struct Entry {
    residency: Residency,
    cached: Option<xla::Literal>,
}

/// The store.
pub struct WeightStore {
    manifest: Manifest,
    entries: BTreeMap<String, Entry>,
    /// Count of SSD re-reads (offloaded accesses) — hot-path accounting.
    loads_from_disk: std::cell::Cell<u64>,
}

impl WeightStore {
    /// All tensors start Resident.
    pub fn new(manifest: Manifest) -> Self {
        let entries = manifest
            .tensors
            .keys()
            .map(|name| {
                (
                    name.clone(),
                    Entry {
                        residency: Residency::Resident,
                        cached: None,
                    },
                )
            })
            .collect();
        WeightStore {
            manifest,
            entries,
            loads_from_disk: std::cell::Cell::new(0),
        }
    }

    pub fn manifest(&self) -> &Manifest {
        &self.manifest
    }

    /// Change residency. Evicting drops the cached Literal (frees memory).
    pub fn set_residency(&mut self, tensor: &str, residency: Residency) -> Result<()> {
        let e = self
            .entries
            .get_mut(tensor)
            .ok_or_else(|| anyhow!("unknown tensor '{tensor}'"))?;
        e.residency = residency;
        if residency == Residency::Offloaded {
            e.cached = None;
        }
        Ok(())
    }

    pub fn residency(&self, tensor: &str) -> Option<Residency> {
        self.entries.get(tensor).map(|e| e.residency)
    }

    /// Bytes currently pinned in memory.
    pub fn resident_bytes(&self) -> u64 {
        self.entries
            .iter()
            .filter(|(_, e)| e.cached.is_some())
            .map(|(name, _)| self.manifest.tensors[name].bytes())
            .sum()
    }

    pub fn loads_from_disk(&self) -> u64 {
        self.loads_from_disk.get()
    }

    /// Warm the cache for a resident tensor (no-op for offloaded ones).
    /// Pair with [`WeightStore::peek`] on the hot path to avoid clones.
    pub fn ensure_cached(&mut self, tensor: &str) -> Result<()> {
        let spec = self
            .manifest
            .tensors
            .get(tensor)
            .ok_or_else(|| anyhow!("unknown tensor '{tensor}'"))?
            .clone();
        let path = self.manifest.dir.join(&spec.file);
        let e = self.entries.get_mut(tensor).unwrap();
        if e.residency == Residency::Resident && e.cached.is_none() {
            e.cached = Some(literal_from_f32_file(&path, &spec.shape)?);
        }
        Ok(())
    }

    /// Borrow a cached resident tensor (None if offloaded / not warmed).
    pub fn peek(&self, tensor: &str) -> Option<&xla::Literal> {
        self.entries.get(tensor).and_then(|e| e.cached.as_ref())
    }

    /// Fetch a tensor as a Literal. Resident tensors are read once and
    /// cached; offloaded tensors hit the SSD on every call.
    pub fn get(&mut self, tensor: &str) -> Result<xla::Literal> {
        let spec = self
            .manifest
            .tensors
            .get(tensor)
            .ok_or_else(|| anyhow!("unknown tensor '{tensor}'"))?
            .clone();
        let path = self.manifest.dir.join(&spec.file);
        let e = self.entries.get_mut(tensor).unwrap();
        match e.residency {
            Residency::Resident => {
                if e.cached.is_none() {
                    e.cached = Some(literal_from_f32_file(&path, &spec.shape)?);
                }
                // Literal implements (deep-copy) Clone; the perf pass keeps
                // resident weights cached so the copy is memory-to-memory.
                Ok(e.cached.as_ref().unwrap().clone())
            }
            Residency::Offloaded => {
                self.loads_from_disk.set(self.loads_from_disk.get() + 1);
                literal_from_f32_file(&path, &spec.shape)
            }
        }
    }

    /// Apply a layer-level residency plan: `full` streams both blocks,
    /// `mha_only`/`mlp_only` stream one block and pin the other.
    pub fn apply_layer_residency(
        &mut self,
        layer: usize,
        mha_offloaded: bool,
        mlp_offloaded: bool,
    ) -> Result<()> {
        let attn = self.manifest.attn_weight_names.clone();
        let mlp = self.manifest.mlp_weight_names.clone();
        for w in &attn {
            self.set_residency(
                &format!("layer{layer}.{w}"),
                if mha_offloaded {
                    Residency::Offloaded
                } else {
                    Residency::Resident
                },
            )?;
        }
        for w in &mlp {
            self.set_residency(
                &format!("layer{layer}.{w}"),
                if mlp_offloaded {
                    Residency::Offloaded
                } else {
                    Residency::Resident
                },
            )?;
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::path::PathBuf;

    fn artifacts_dir() -> PathBuf {
        PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts")
    }

    fn store() -> Option<WeightStore> {
        if !artifacts_dir().join("manifest.json").exists() {
            eprintln!("skipping: run `make artifacts`");
            return None;
        }
        Some(WeightStore::new(Manifest::load(artifacts_dir()).unwrap()))
    }

    #[test]
    fn resident_get_caches() {
        let Some(mut s) = store() else { return };
        assert_eq!(s.resident_bytes(), 0);
        let a = s.get("layer0.wq").unwrap();
        assert!(s.resident_bytes() > 0);
        let b = s.get("layer0.wq").unwrap();
        assert_eq!(a.to_vec::<f32>().unwrap(), b.to_vec::<f32>().unwrap());
        assert_eq!(s.loads_from_disk(), 0);
    }

    #[test]
    fn offloaded_get_rereads_disk() {
        let Some(mut s) = store() else { return };
        s.set_residency("layer0.wq", Residency::Offloaded).unwrap();
        let _ = s.get("layer0.wq").unwrap();
        let _ = s.get("layer0.wq").unwrap();
        assert_eq!(s.loads_from_disk(), 2);
        assert_eq!(s.resident_bytes(), 0);
    }

    #[test]
    fn eviction_frees_memory() {
        let Some(mut s) = store() else { return };
        let _ = s.get("layer1.w_up").unwrap();
        let before = s.resident_bytes();
        assert!(before > 0);
        s.set_residency("layer1.w_up", Residency::Offloaded).unwrap();
        assert_eq!(s.resident_bytes(), 0);
    }

    #[test]
    fn layer_residency_plan() {
        let Some(mut s) = store() else { return };
        s.apply_layer_residency(2, true, false).unwrap();
        assert_eq!(
            s.residency("layer2.wq"),
            Some(Residency::Offloaded)
        );
        assert_eq!(
            s.residency("layer2.w_gate"),
            Some(Residency::Resident)
        );
    }

    #[test]
    fn values_match_blob_regardless_of_residency() {
        let Some(mut s) = store() else { return };
        let resident = s.get("layer3.wo").unwrap().to_vec::<f32>().unwrap();
        s.set_residency("layer3.wo", Residency::Offloaded).unwrap();
        let offloaded = s.get("layer3.wo").unwrap().to_vec::<f32>().unwrap();
        assert_eq!(resident, offloaded, "offload must be lossless");
    }
}
