//! PJRT runtime: load AOT-compiled HLO text, compile once on the CPU
//! client, execute from the (Python-free) request path.
//!
//! The interchange format is HLO *text* — xla_extension 0.5.1 rejects
//! jax≥0.5 serialized protos (64-bit instruction ids); the text parser
//! reassigns ids. See `python/compile/aot.py` and DESIGN.md.

use std::collections::BTreeMap;

use anyhow::{anyhow, Context, Result};

use crate::runtime::manifest::Manifest;

/// Compiled-executable cache over one PJRT client.
pub struct PjrtRuntime {
    client: xla::PjRtClient,
    executables: BTreeMap<String, xla::PjRtLoadedExecutable>,
    exec_calls: std::cell::Cell<u64>,
}

impl PjrtRuntime {
    /// Create a CPU PJRT client and compile every artifact in the manifest.
    pub fn load(manifest: &Manifest) -> Result<PjrtRuntime> {
        let client = xla::PjRtClient::cpu().context("creating PJRT CPU client")?;
        let mut executables = BTreeMap::new();
        for name in manifest.artifacts.keys() {
            let path = manifest.artifact_path(name)?;
            let proto = xla::HloModuleProto::from_text_file(
                path.to_str().ok_or_else(|| anyhow!("non-utf8 path"))?,
            )
            .with_context(|| format!("parsing HLO text for '{name}'"))?;
            let comp = xla::XlaComputation::from_proto(&proto);
            let exe = client
                .compile(&comp)
                .with_context(|| format!("compiling '{name}'"))?;
            executables.insert(name.clone(), exe);
        }
        Ok(PjrtRuntime {
            client,
            executables,
            exec_calls: std::cell::Cell::new(0),
        })
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    pub fn artifact_names(&self) -> Vec<&str> {
        self.executables.keys().map(String::as_str).collect()
    }

    /// Number of `execute` calls issued (hot-path accounting).
    pub fn exec_calls(&self) -> u64 {
        self.exec_calls.get()
    }

    /// Execute artifact `name` with parameters in manifest order. Returns
    /// the flattened output tuple.
    pub fn execute(&self, name: &str, params: &[xla::Literal]) -> Result<Vec<xla::Literal>> {
        self.execute_inner(name, params)
    }

    /// Zero-copy variant: parameters by reference (hot path — avoids the
    /// deep `Literal` clones of cached weights; see EXPERIMENTS.md §Perf).
    pub fn execute_ref(&self, name: &str, params: &[&xla::Literal]) -> Result<Vec<xla::Literal>> {
        self.execute_inner(name, params)
    }

    fn execute_inner<L: std::borrow::Borrow<xla::Literal>>(
        &self,
        name: &str,
        params: &[L],
    ) -> Result<Vec<xla::Literal>> {
        let exe = self
            .executables
            .get(name)
            .ok_or_else(|| anyhow!("artifact '{name}' not loaded"))?;
        self.exec_calls.set(self.exec_calls.get() + 1);
        let result = exe
            .execute::<L>(params)
            .with_context(|| format!("executing '{name}'"))?[0][0]
            .to_literal_sync()?;
        // aot.py lowers with return_tuple=True: always a tuple.
        Ok(result.to_tuple()?)
    }
}

/// Read a raw little-endian f32 blob into a Literal of the given shape.
pub fn literal_from_f32_file(path: &std::path::Path, shape: &[usize]) -> Result<xla::Literal> {
    let bytes = std::fs::read(path).with_context(|| format!("reading {path:?}"))?;
    let expect = 4 * shape.iter().product::<usize>();
    if bytes.len() != expect {
        return Err(anyhow!(
            "{path:?}: expected {expect} bytes for shape {shape:?}, found {}",
            bytes.len()
        ));
    }
    let floats: Vec<f32> = bytes
        .chunks_exact(4)
        .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
        .collect();
    literal_from_f32(&floats, shape)
}

/// Build a Literal from an f32 slice and shape.
pub fn literal_from_f32(data: &[f32], shape: &[usize]) -> Result<xla::Literal> {
    assert_eq!(data.len(), shape.iter().product::<usize>());
    let dims: Vec<i64> = shape.iter().map(|&d| d as i64).collect();
    Ok(xla::Literal::vec1(data).reshape(&dims)?)
}

/// Build an i32 Literal from a slice and shape.
pub fn literal_from_i32(data: &[i32], shape: &[usize]) -> Result<xla::Literal> {
    assert_eq!(data.len(), shape.iter().product::<usize>());
    let dims: Vec<i64> = shape.iter().map(|&d| d as i64).collect();
    Ok(xla::Literal::vec1(data).reshape(&dims)?)
}

/// Scalar i32 Literal (e.g. the `pos` parameter).
pub fn literal_scalar_i32(v: i32) -> xla::Literal {
    xla::Literal::scalar(v)
}

/// Argmax over a logits Literal of shape [1, vocab].
pub fn argmax_logits(logits: &xla::Literal) -> Result<i32> {
    let v: Vec<f32> = logits.to_vec()?;
    let (mut best, mut best_val) = (0usize, f32::NEG_INFINITY);
    for (i, &x) in v.iter().enumerate() {
        if x > best_val {
            best = i;
            best_val = x;
        }
    }
    Ok(best as i32)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn f32_literal_roundtrip() {
        let data = vec![1.0f32, 2.0, 3.0, 4.0, 5.0, 6.0];
        let lit = literal_from_f32(&data, &[2, 3]).unwrap();
        assert_eq!(lit.to_vec::<f32>().unwrap(), data);
    }

    #[test]
    fn argmax_picks_max() {
        let lit = literal_from_f32(&[0.1, 0.9, -3.0, 0.5], &[1, 4]).unwrap();
        assert_eq!(argmax_logits(&lit).unwrap(), 1);
    }

    #[test]
    fn blob_size_mismatch_rejected() {
        let dir = std::env::temp_dir().join("lime_blob_test");
        std::fs::create_dir_all(&dir).unwrap();
        let p = dir.join("bad.bin");
        std::fs::write(&p, [0u8; 12]).unwrap();
        assert!(literal_from_f32_file(&p, &[4]).is_err()); // needs 16 bytes
        assert!(literal_from_f32_file(&p, &[3]).is_ok());
    }
}
